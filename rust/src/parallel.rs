//! Scoped thread-pool parallelism — the OpenMP substitute for the CPU-CELL
//! baseline (the offline vendor set has no `rayon`).
//!
//! `parallel_for_chunks` splits an index range into contiguous chunks and
//! runs one std thread per chunk via `std::thread::scope`; worker closures
//! get `(thread_id, range)` so callers can keep per-thread accumulation
//! buffers (the standard race-free pattern for force scatter).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use: `ORCS_THREADS` env override, else the
/// available hardware parallelism.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("ORCS_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// `ORCS_SIMD=scalar` escape hatch for the BVH lane kernels
/// ([`crate::bvh::simd`]): force the portable scalar kernel even where
/// SSE2/NEON is available (the CI matrix runs a leg with it set so the
/// fallback stays exercised). Lives here with [`num_threads`] — this module
/// is the one blessed site for runtime-tuning env reads, so determinism
/// lint scope stays a single file. Results are bit-identical whichever
/// kernel runs; this knob only changes *how* the lane test is computed.
pub fn simd_force_scalar() -> bool {
    matches!(std::env::var("ORCS_SIMD").as_deref(), Ok("scalar"))
}

/// Run `body(thread_id, start..end)` over `0..n` split into `threads`
/// contiguous chunks. Blocks until all workers finish.
pub fn parallel_for_chunks<F>(n: usize, threads: usize, body: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n < 2 {
        body(0, 0..n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let body = &body;
            s.spawn(move || body(t, lo..hi));
        }
    });
}

/// Like [`parallel_for_chunks`] but caps the worker count so every worker
/// gets at least `min_grain` items. For sweeps whose per-item work is tiny
/// (e.g. per-node BVH refit levels), spawning a thread for a handful of
/// items costs more than it saves; this keeps small inputs on few threads
/// while preserving the deterministic chunk partition of the capped count.
pub fn parallel_for_chunks_grained<F>(n: usize, threads: usize, min_grain: usize, body: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    let cap = (n / min_grain.max(1)).max(1);
    parallel_for_chunks(n, threads.min(cap), body);
}

/// Dynamic work-stealing variant: workers atomically grab blocks of
/// `block` indices. Better for irregular per-item cost (clustered scenes,
/// variable radii) where static chunking load-imbalances.
pub fn parallel_for_dynamic<F>(n: usize, threads: usize, block: usize, body: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n < 2 {
        body(0, 0..n);
        return;
    }
    let cursor = AtomicUsize::new(0);
    let block = block.max(1);
    std::thread::scope(|s| {
        for t in 0..threads {
            let body = &body;
            let cursor = &cursor;
            s.spawn(move || loop {
                let lo = cursor.fetch_add(block, Ordering::Relaxed);
                if lo >= n {
                    break;
                }
                let hi = (lo + block).min(n);
                body(t, lo..hi);
            });
        }
    });
}

/// Map `0..n` in parallel into a pre-allocated output vector. `f` must be
/// pure per-index.
///
/// Writes go straight into the vector's spare capacity (`MaybeUninit`), so
/// there is no `T: Default + Clone` bound and no redundant zero-init pass
/// over large buffers (force arrays, per-primitive AABBs).
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<T> = Vec::with_capacity(n);
    {
        let out_ptr = SendPtr(out.spare_capacity_mut().as_mut_ptr() as *mut T);
        parallel_for_chunks(n, threads, |_, range| {
            let p = out_ptr; // copy the Send wrapper into the closure
            for i in range {
                // SAFETY: chunks are disjoint; each index written once, so
                // every slot in 0..n is initialized exactly once.
                unsafe { p.0.add(i).write(f(i)) };
            }
        });
    }
    // SAFETY: parallel_for_chunks covered 0..n, initializing every element.
    unsafe { out.set_len(n) };
    out
}

/// Work-stealing chunked map: workers atomically grab `block`-sized chunks
/// of `0..n`; each worker owns a thread-local state built by `init` (scratch
/// buffers, accumulators) that lives for the worker's whole run. Chunk
/// outputs are returned **in chunk order** — independent of which worker
/// processed which chunk — so callers that merge them sequentially get
/// bitwise-deterministic results under dynamic scheduling. The per-worker
/// states are returned in thread order (for merging order-insensitive
/// accumulators such as counters).
pub fn parallel_chunk_map<A, O, I, F>(
    n: usize,
    threads: usize,
    block: usize,
    init: I,
    body: F,
) -> (Vec<O>, Vec<A>)
where
    A: Send,
    O: Send,
    I: Fn() -> A + Sync,
    F: Fn(&mut A, std::ops::Range<usize>) -> O + Sync,
{
    let block = block.max(1);
    let n_chunks = n.div_ceil(block);
    let threads = threads.max(1).min(n_chunks.max(1));
    if threads == 1 || n_chunks <= 1 {
        let mut state = init();
        let outs = (0..n_chunks)
            .map(|c| body(&mut state, c * block..((c + 1) * block).min(n)))
            .collect();
        return (outs, vec![state]);
    }
    let mut outs: Vec<Option<O>> = (0..n_chunks).map(|_| None).collect();
    let out_ptr = SendPtr(outs.as_mut_ptr());
    let cursor = AtomicUsize::new(0);
    let states = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            let init = &init;
            let body = &body;
            let cursor = &cursor;
            handles.push(s.spawn(move || {
                let mut state = init();
                loop {
                    let c = cursor.fetch_add(1, Ordering::Relaxed);
                    if c >= n_chunks {
                        break;
                    }
                    let lo = c * block;
                    let hi = (lo + block).min(n);
                    let o = body(&mut state, lo..hi);
                    // SAFETY: chunk indices are claimed exactly once, so
                    // each slot is written by exactly one worker; the scope
                    // join provides the happens-before for the final read.
                    unsafe { *out_ptr.0.add(c) = Some(o) };
                }
                state
            }));
        }
        // lint:allow(P-PANIC): a worker panic must propagate, not be swallowed
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });
    // lint:allow(P-PANIC): every chunk index is claimed exactly once above
    let outs = outs.into_iter().map(|o| o.expect("chunk not produced")).collect();
    (outs, states)
}

/// Chunked parallel reduction: each worker builds a private accumulator
/// with `init`, folds its index range into it with `body`, and the
/// per-thread accumulators are returned in thread order (deterministic
/// merging is the caller's job — this is the race-free substitute for GPU
/// atomic scatter, see DESIGN.md §Hardware-Adaptation).
pub fn parallel_reduce<R, I, F>(n: usize, threads: usize, init: I, body: F) -> Vec<R>
where
    R: Send,
    I: Fn() -> R + Sync,
    F: Fn(&mut R, usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        let mut acc = init();
        for i in 0..n {
            body(&mut acc, i);
        }
        return vec![acc];
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let init = &init;
            let body = &body;
            handles.push(s.spawn(move || {
                let mut acc = init();
                for i in lo..hi {
                    body(&mut acc, i);
                }
                acc
            }));
        }
        // lint:allow(P-PANIC): a worker panic must propagate, not be swallowed
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    })
}

/// Exclusive prefix sum of `lens` into a CSR offsets array of length
/// `lens.len() + 1` (`offsets[0] == 0`, `offsets[n] ==` the total). The
/// classic three-phase parallel scan: per-chunk sums in parallel, a serial
/// exclusive scan over the (few) chunk totals, then a parallel fill of each
/// chunk's offsets from its base. Integer addition is associative, so the
/// output is identical for every thread count; small inputs fall back to
/// the serial scan (the parallel passes only pay off once the array no
/// longer fits cache).
pub fn exclusive_scan_u32(lens: &[u32], threads: usize) -> Vec<u32> {
    let n = lens.len();
    let threads = threads.max(1).min(n.max(1));
    let mut offsets: Vec<u32> = Vec::with_capacity(n + 1);
    if threads == 1 || n < 1 << 15 {
        let mut acc = 0u32;
        offsets.push(0);
        for &l in lens {
            acc += l;
            offsets.push(acc);
        }
        return offsets;
    }
    // Phase 1: per-chunk sums. `parallel_for_chunks` assigns chunk t the
    // range [t*ceil(n/threads), ...) — the same partition phase 3 sees.
    let mut sums = vec![0u32; threads];
    {
        let sums_ptr = SendPtr(sums.as_mut_ptr());
        parallel_for_chunks(n, threads, |t, range| {
            let mut s = 0u32;
            for i in range {
                s += lens[i];
            }
            // SAFETY: one slot per worker, written exactly once.
            unsafe { *sums_ptr.0.add(t) = s };
        });
    }
    // Phase 2: serial exclusive scan over the chunk sums.
    let mut bases = Vec::with_capacity(threads);
    let mut acc = 0u32;
    for &s in &sums {
        bases.push(acc);
        acc += s;
    }
    let total = acc;
    // Phase 3: fill each chunk's offsets from its base.
    {
        let out_ptr = SendPtr(offsets.spare_capacity_mut().as_mut_ptr() as *mut u32);
        let bases_ref = &bases;
        parallel_for_chunks(n, threads, |t, range| {
            let mut acc = bases_ref[t];
            for i in range {
                // SAFETY: chunks are disjoint; offsets[i] written once.
                unsafe { out_ptr.0.add(i).write(acc) };
                acc += lens[i];
            }
        });
        // SAFETY: every slot in 0..n was initialized by exactly one chunk.
        unsafe { offsets.set_len(n) };
    }
    offsets.push(total);
    offsets
}

/// Pointer wrapper asserting Send for disjoint-range writes.
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
// SAFETY: SendPtr is only handed to scoped workers that write disjoint index
// ranges of the pointee; the scope join supplies the happens-before edge for
// the owner's subsequent reads, so cross-thread access is data-race free.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn chunks_cover_all_indices_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for_chunks(1000, 7, |_, range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn grained_covers_all_indices_once_and_caps_workers() {
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        let max_tid = AtomicU64::new(0);
        parallel_for_chunks_grained(100, 16, 50, |t, range| {
            max_tid.fetch_max(t as u64, Ordering::Relaxed);
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        // 100 items / 50 grain -> at most 2 workers (thread ids 0 and 1)
        assert!(max_tid.load(Ordering::Relaxed) <= 1);
    }

    #[test]
    fn dynamic_covers_all_indices_once() {
        let hits: Vec<AtomicU64> = (0..1003).map(|_| AtomicU64::new(0)).collect();
        parallel_for_dynamic(1003, 5, 16, |_, range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_matches_serial() {
        let v = parallel_map(257, 4, |i| i * i);
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i * i);
        }
    }

    #[test]
    fn reduce_sums_correctly() {
        let parts = parallel_reduce(1000, 8, || 0u64, |acc, i| *acc += i as u64);
        let total: u64 = parts.into_iter().sum();
        assert_eq!(total, 999 * 1000 / 2);
    }

    #[test]
    fn reduce_single_thread() {
        let parts = parallel_reduce(10, 1, || 0u64, |acc, i| *acc += i as u64);
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0], 45);
    }

    #[test]
    fn single_thread_and_empty() {
        parallel_for_chunks(0, 4, |_, r| assert!(r.is_empty()));
        let v = parallel_map(5, 1, |i| i + 1);
        assert_eq!(v, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn map_supports_non_default_types() {
        // String has Default but &'static str references inside a struct
        // without Default exercise the MaybeUninit path.
        struct NoDefault(usize);
        let v = parallel_map(100, 4, NoDefault);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(x.0, i);
        }
    }

    #[test]
    fn exclusive_scan_matches_serial_for_any_thread_count() {
        // above the serial fallback threshold, with an uneven tail chunk
        let n = (1 << 15) + 123;
        let lens: Vec<u32> = (0..n).map(|i| (i as u32 * 2654435761) % 17).collect();
        let mut want = Vec::with_capacity(n + 1);
        let mut acc = 0u32;
        want.push(0);
        for &l in &lens {
            acc += l;
            want.push(acc);
        }
        for threads in [1, 2, 5, 8] {
            let got = exclusive_scan_u32(&lens, threads);
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn exclusive_scan_tiny_and_empty() {
        assert_eq!(exclusive_scan_u32(&[], 4), vec![0]);
        assert_eq!(exclusive_scan_u32(&[3, 0, 2], 4), vec![0, 3, 3, 5]);
    }

    #[test]
    fn chunk_map_outputs_in_chunk_order() {
        // chunk c covers [c*7, min((c+1)*7, n)) and must land in slot c
        let (outs, states) = parallel_chunk_map(
            100,
            5,
            7,
            || 0usize,
            |count, range| {
                *count += range.len();
                range.start
            },
        );
        assert_eq!(outs.len(), 100usize.div_ceil(7));
        for (c, &start) in outs.iter().enumerate() {
            assert_eq!(start, c * 7);
        }
        let total: usize = states.iter().sum();
        assert_eq!(total, 100);
    }

    #[test]
    fn chunk_map_single_thread_and_tiny() {
        let (outs, states) = parallel_chunk_map(3, 1, 16, || (), |_, r| r.len());
        assert_eq!(outs, vec![3]);
        assert_eq!(states.len(), 1);
        let (outs, _) = parallel_chunk_map(0, 4, 16, || (), |_, r| r.len());
        assert!(outs.is_empty());
    }
}
