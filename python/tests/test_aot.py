"""AOT pipeline: artifacts lower, contain parseable HLO text with the
expected entry layouts, and the kernel inside computes the same numbers
when round-tripped through the XLA client (the same path the Rust runtime
takes)."""

import os
import subprocess
import sys

import numpy as np
import pytest

from compile import aot
from compile.shapes import CHUNK, K_BUCKETS


def test_lower_lj_forces_text_shape():
    text = aot.lower_lj_forces(256, 16)
    assert "ENTRY" in text
    assert "f32[256,16,3]" in text  # nbr_pos input
    assert "f32[256,3]" in text     # pos input / force output
    assert text.startswith("HloModule")


def test_lower_integrate_text_shape():
    text = aot.lower_integrate(128)
    assert "ENTRY" in text
    assert "f32[128,3]" in text
    assert "f32[2]" in text  # (dt, f_max)


def test_hlo_text_parses_back():
    """The emitted text must parse as a valid HLO module with the expected
    entry signature — the same parse the Rust runtime performs. (Execution
    equivalence vs the Rust PJRT path is covered by the cargo test
    `integration_runtime`.)"""
    from jax._src.lib import xla_client as xc

    c, k = 128, 16
    text = aot.lower_lj_forces(c, k)
    module = xc._xla.hlo_module_from_text(text)
    # parse succeeded and the round-tripped text keeps the entry signature
    rendered = module.to_string()
    assert "ENTRY" in rendered
    assert f"f32[{c},{k},3]" in rendered
    assert module.name.startswith("jit_lj_forces_graph")
    # the proto serializes (what from_text_file consumes on the Rust side)
    assert len(module.as_serialized_hlo_module_proto()) > 100


def test_aot_main_writes_all_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--chunk", "256"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    names = sorted(os.listdir(out))
    for k in K_BUCKETS:
        assert f"lj_forces_c256_k{k}.hlo.txt" in names
    assert "integrate_c256.hlo.txt" in names
    assert "manifest.txt" in names
    manifest = (out / "manifest.txt").read_text()
    assert str(256) in manifest


def test_default_chunk_is_shared_constant():
    # guard against drift between shapes.py and the Rust runtime constants
    assert CHUNK == 4096
    assert K_BUCKETS == (16, 64, 256)
