//! BVH construction: median split and binned-SAH builders.
//!
//! Both builders produce the same node layout (children consecutive, always
//! after the parent) so refit and traversal are builder-agnostic. The
//! median builder models fast hardware LBVH-style construction; binned SAH
//! models a high-quality build. The timing model charges builds by
//! primitive count regardless of kind (hardware builds are opaque), but the
//! *query* cost difference between tree qualities is real and measured.

use super::{Bvh, BuildKind, Node, LEAF_SIZE};
use crate::core::aabb::Aabb;
use crate::core::vec3::Vec3;

/// Number of SAH bins per axis.
const SAH_BINS: usize = 16;

/// SAH traversal/intersection cost ratio (standard ~1:1 for AABB vs sphere
/// tests on RT hardware).
const COST_TRAVERSE: f32 = 1.0;
const COST_INTERSECT: f32 = 1.0;

struct BuildCtx<'a> {
    centroids: Vec<Vec3>,
    prim_bbs: Vec<Aabb>,
    order: &'a mut [u32],
    nodes: Vec<Node>,
}

impl Bvh {
    /// Build a fresh BVH over spheres `(pos[i], radius[i])`.
    pub fn build(pos: &[Vec3], radius: &[f32], kind: BuildKind) -> Bvh {
        assert_eq!(pos.len(), radius.len());
        assert!(!pos.is_empty(), "cannot build a BVH over zero primitives");
        let n = pos.len();
        let mut order: Vec<u32> = (0..n as u32).collect();

        if kind == BuildKind::Lbvh {
            // Z-order the primitives once; range-midpoint splits below then
            // approximate morton-prefix splits (HLBVH-style).
            let bb = pos.iter().zip(radius).fold(Aabb::EMPTY, |mut a, (&p, &r)| {
                a.grow(&Aabb::of_sphere(p, r));
                a
            });
            let span = (bb.hi - bb.lo).max_component().max(1e-6);
            let mut keys: Vec<u32> = pos
                .iter()
                .map(|&p| crate::frnn::gpu_cell::morton30((p - bb.lo) * (1000.0 / span), 1000.0))
                .collect();
            crate::frnn::gpu_cell::radix_sort_pairs(&mut keys, &mut order);
        }
        let prim_bbs: Vec<Aabb> =
            (0..n).map(|i| Aabb::of_sphere(pos[i], radius[i])).collect();
        let centroids: Vec<Vec3> = pos.to_vec();

        let mut ctx = BuildCtx {
            centroids,
            prim_bbs,
            order: &mut order,
            nodes: Vec::with_capacity(2 * n / LEAF_SIZE + 2),
        };
        // reserve root
        ctx.nodes.push(Node { aabb: Aabb::EMPTY, left_first: 0, count: 0 });
        build_range(&mut ctx, 0, 0, n, kind);
        let nodes = ctx.nodes;

        Bvh { nodes, prim_order: order, n_prims: n, kind, refits_since_build: 0 }
    }
}

/// Recursively build the subtree for `order[lo..hi]` into `nodes[node_idx]`.
fn build_range(ctx: &mut BuildCtx, node_idx: usize, lo: usize, hi: usize, kind: BuildKind) {
    let count = hi - lo;
    let mut bb = Aabb::EMPTY;
    let mut cb = Aabb::EMPTY; // centroid bounds
    for k in lo..hi {
        let p = ctx.order[k] as usize;
        bb.grow(&ctx.prim_bbs[p]);
        let c = ctx.centroids[p];
        cb.grow(&Aabb::new(c, c));
    }

    if count <= LEAF_SIZE {
        ctx.nodes[node_idx] =
            Node { aabb: bb, left_first: lo as u32, count: count as u32 };
        return;
    }

    let split = match kind {
        BuildKind::Median => split_median(ctx, lo, hi, &cb),
        BuildKind::BinnedSah => {
            split_sah(ctx, lo, hi, &cb, &bb).unwrap_or_else(|| split_median(ctx, lo, hi, &cb))
        }
        // order is already morton-sorted: midpoint = prefix split
        BuildKind::Lbvh => lo + count / 2,
    };

    // Degenerate split (all centroids identical): force a half split.
    let mid = if split <= lo || split >= hi { lo + count / 2 } else { split };

    let left = ctx.nodes.len();
    ctx.nodes.push(Node { aabb: Aabb::EMPTY, left_first: 0, count: 0 });
    ctx.nodes.push(Node { aabb: Aabb::EMPTY, left_first: 0, count: 0 });
    ctx.nodes[node_idx] = Node { aabb: bb, left_first: left as u32, count: 0 };
    build_range(ctx, left, lo, mid, kind);
    build_range(ctx, left + 1, mid, hi, kind);
}

/// Median split: partition around the median centroid on the longest axis.
fn split_median(ctx: &mut BuildCtx, lo: usize, hi: usize, cb: &Aabb) -> usize {
    let axis = cb.longest_axis();
    let mid = lo + (hi - lo) / 2;
    let (order, centroids) = (&mut *ctx.order, &ctx.centroids);
    order[lo..hi].select_nth_unstable_by(mid - lo, |&a, &b| {
        centroids[a as usize]
            .axis(axis)
            .partial_cmp(&centroids[b as usize].axis(axis))
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    mid
}

/// Binned SAH: try SAH_BINS buckets on each axis, pick the cheapest split.
/// Returns `None` when no split beats the leaf cost or bounds are degenerate.
fn split_sah(ctx: &mut BuildCtx, lo: usize, hi: usize, cb: &Aabb, bb: &Aabb) -> Option<usize> {
    let count = hi - lo;
    let ext = cb.hi - cb.lo;
    let mut best: Option<(f32, usize, usize)> = None; // (cost, axis, bin)

    for axis in 0..3 {
        let extent = ext.axis(axis);
        if extent <= 1e-6 {
            continue;
        }
        let k0 = cb.lo.axis(axis);
        let scale = SAH_BINS as f32 * (1.0 - 1e-6) / extent;

        let mut bin_bb = [Aabb::EMPTY; SAH_BINS];
        let mut bin_n = [0usize; SAH_BINS];
        for k in lo..hi {
            let p = ctx.order[k] as usize;
            let b = (((ctx.centroids[p].axis(axis) - k0) * scale) as usize).min(SAH_BINS - 1);
            bin_bb[b].grow(&ctx.prim_bbs[p]);
            bin_n[b] += 1;
        }

        // prefix/suffix sweeps
        let mut left_bb = [Aabb::EMPTY; SAH_BINS];
        let mut left_n = [0usize; SAH_BINS];
        let mut acc_bb = Aabb::EMPTY;
        let mut acc_n = 0;
        for b in 0..SAH_BINS {
            acc_bb.grow(&bin_bb[b]);
            acc_n += bin_n[b];
            left_bb[b] = acc_bb;
            left_n[b] = acc_n;
        }
        let mut acc_bb = Aabb::EMPTY;
        let mut acc_n = 0;
        for b in (1..SAH_BINS).rev() {
            acc_bb.grow(&bin_bb[b]);
            acc_n += bin_n[b];
            let nl = left_n[b - 1];
            if nl == 0 || acc_n == 0 {
                continue;
            }
            let sa = bb.surface_area().max(1e-12);
            let cost = COST_TRAVERSE
                + COST_INTERSECT
                    * (left_bb[b - 1].surface_area() * nl as f32
                        + acc_bb.surface_area() * acc_n as f32)
                    / sa;
            if best.map_or(true, |(bc, _, _)| cost < bc) {
                best = Some((cost, axis, b));
            }
        }
    }

    let (cost, axis, bin) = best?;
    // compare against leaf cost
    if cost >= COST_INTERSECT * count as f32 {
        return None;
    }
    // partition by bin
    let k0 = cb.lo.axis(axis);
    let extent = ext.axis(axis);
    let scale = SAH_BINS as f32 * (1.0 - 1e-6) / extent;
    let (order, centroids) = (&mut *ctx.order, &ctx.centroids);
    let mut i = lo;
    let mut j = hi;
    while i < j {
        let p = order[i] as usize;
        let b = (((centroids[p].axis(axis) - k0) * scale) as usize).min(SAH_BINS - 1);
        if b < bin {
            i += 1;
        } else {
            j -= 1;
            order.swap(i, j);
        }
    }
    Some(i)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Rng;

    fn scene(n: usize, seed: u64) -> (Vec<Vec3>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (
            (0..n)
                .map(|_| {
                    Vec3::new(
                        rng.range_f32(0.0, 50.0),
                        rng.range_f32(0.0, 50.0),
                        rng.range_f32(0.0, 50.0),
                    )
                })
                .collect(),
            (0..n).map(|_| rng.range_f32(0.1, 2.0)).collect(),
        )
    }

    #[test]
    fn node_count_bounds() {
        let (pos, radius) = scene(1000, 1);
        let bvh = Bvh::build(&pos, &radius, BuildKind::Median);
        // binary tree over ceil(n/LEAF) leaves
        assert!(bvh.node_count() >= 2 * (1000 / LEAF_SIZE) - 1);
        assert!(bvh.node_count() <= 2 * 1000);
    }

    #[test]
    fn identical_centroids_dont_recurse_forever() {
        let pos = vec![Vec3::splat(5.0); 50];
        let radius = vec![1.0f32; 50];
        for kind in [BuildKind::Median, BuildKind::BinnedSah] {
            let bvh = Bvh::build(&pos, &radius, kind);
            bvh.check_invariants(&pos, &radius).unwrap();
        }
    }

    #[test]
    fn sah_tree_not_worse_than_median() {
        let (pos, radius) = scene(3000, 3);
        let med = Bvh::build(&pos, &radius, BuildKind::Median);
        let sah = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
        let qm = crate::bvh::quality::sah_cost(&med);
        let qs = crate::bvh::quality::sah_cost(&sah);
        assert!(qs <= qm * 1.1, "sah={qs} median={qm}");
    }

    #[test]
    fn lbvh_builds_valid_tree() {
        let (pos, radius) = scene(2000, 5);
        let bvh = Bvh::build(&pos, &radius, BuildKind::Lbvh);
        bvh.check_invariants(&pos, &radius).unwrap();
        // quality ordering: SAH <= median <= ~LBVH (morton splits are the
        // cheapest build, roughest tree)
        let sah = crate::bvh::quality::sah_cost(&Bvh::build(&pos, &radius, BuildKind::BinnedSah));
        let lbvh = crate::bvh::quality::sah_cost(&bvh);
        assert!(sah <= lbvh * 1.05, "sah={sah} lbvh={lbvh}");
    }

    #[test]
    fn lbvh_queries_match_brute_force() {
        let (pos, radius) = scene(600, 6);
        let bvh = Bvh::build(&pos, &radius, BuildKind::Lbvh);
        let mut stats = crate::bvh::traverse::TraversalStats::default();
        for i in (0..pos.len()).step_by(13) {
            let mut got = bvh.query_point_collect(pos[i], i, &pos, &radius, &mut stats);
            got.sort_unstable();
            let want: Vec<usize> = (0..pos.len())
                .filter(|&j| {
                    j != i && (pos[i] - pos[j]).norm2() < radius[j] * radius[j]
                })
                .collect();
            assert_eq!(got, want, "i={i}");
        }
    }

    #[test]
    fn children_follow_parents() {
        let (pos, radius) = scene(512, 4);
        let bvh = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
        for (i, n) in bvh.nodes.iter().enumerate() {
            if !n.is_leaf() {
                assert!(n.left_first as usize > i);
            }
        }
    }
}
