//! BVH traversal with exact operation counters — the simulated RT-core
//! query, plus the batched traversal engine every RT backend routes through.
//!
//! The paper's FRNN scheme launches an *infinitesimal ray* at each particle
//! position and collects sphere intersections (Fig. 1): geometrically this is
//! a point query — `p_i` hits sphere `j` iff `|p_i - p_j| < r_j`. Traversal
//! visits every node whose AABB contains the query point and tests spheres
//! at the leaves. Counters mirror what RT silicon does per ray: box tests
//! (RT-core units) and intersection-shader invocations (SM units).
//!
//! # The batched engine
//!
//! RT hardware gets its throughput from sweeping *batches* of coherent rays,
//! not from one-at-a-time launches (RTNN, Zhu 2022). The CPU model mirrors
//! that in two layers:
//!
//! * [`QueryScratch`] — per-worker reusable state (fixed traversal stack +
//!   heap spill + gamma-origin buffer + stats accumulator), so a single ray
//!   through [`Bvh::query_point`] touches **no allocator** in steady state;
//! * [`Bvh::query_batch`] — sweeps a whole query set with thread-local
//!   scratch and chunked work-stealing ([`crate::parallel`]), merging
//!   [`TraversalStats`] once per worker instead of once per ray. Chunk
//!   outputs come back in chunk order, so callers that fold them
//!   sequentially stay bitwise deterministic under dynamic scheduling.

use super::Bvh;
use crate::core::vec3::Vec3;

/// Fixed traversal-stack depth. Tree height is ~log2(n/LEAF_SIZE) for sane
/// builds; 96 covers every realistic scene, and deeper (degenerate-refit)
/// trees spill to the scratch's heap vector.
const STACK_DEPTH: usize = 96;

/// Per-query (or accumulated) traversal statistics. These feed
/// [`crate::rtcore::timing`] to produce simulated GPU time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraversalStats {
    /// Ray–AABB tests executed (RT-core box units).
    pub aabb_tests: u64,
    /// Sphere (primitive) tests — intersection-shader invocations.
    pub sphere_tests: u64,
    /// Intersections found (hits = discovered neighbor candidates).
    pub hits: u64,
    /// Rays launched (primary + gamma).
    pub rays: u64,
}

impl TraversalStats {
    pub fn add(&mut self, o: &TraversalStats) {
        self.aabb_tests += o.aabb_tests;
        self.sphere_tests += o.sphere_tests;
        self.hits += o.hits;
        self.rays += o.rays;
    }
}

/// Reusable per-worker traversal state: fixed stack + spill vector + gamma
/// origin buffer + stats accumulator. One ray performs zero heap
/// allocations once the scratch is warm; allocations happen only at worker
/// setup (and on first-ever spill/gamma growth, whose capacity is retained).
pub struct QueryScratch {
    stack: [u32; STACK_DEPTH],
    spill: Vec<u32>,
    /// Gamma-ray origin buffer (periodic BC) — filled and drained by
    /// [`crate::frnn::rt_common::launch_rays`]; capacity retained across
    /// particles.
    pub gamma: Vec<Vec3>,
    /// Stats accumulated by every query through this scratch. Merge into
    /// step counters once per worker/chunk, not per ray.
    pub stats: TraversalStats,
}

impl QueryScratch {
    pub fn new() -> Self {
        QueryScratch {
            stack: [0; STACK_DEPTH],
            spill: Vec::new(),
            gamma: Vec::new(),
            stats: TraversalStats::default(),
        }
    }

    /// Extract and reset the accumulated stats.
    pub fn take_stats(&mut self) -> TraversalStats {
        std::mem::take(&mut self.stats)
    }
}

impl Default for QueryScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl Bvh {
    /// Query all spheres containing point `p`, excluding primitive
    /// `exclude` (a particle never neighbors itself; pass `usize::MAX` to
    /// keep all). Calls `visit(j)` for every hit and accumulates counters
    /// into `scratch.stats`.
    ///
    /// `pos`/`radius` are the *current* particle arrays: the BVH prunes by
    /// node bounds (possibly stale-loose after refits — exactly like RT
    /// hardware), but the sphere test itself is exact.
    #[inline]
    pub fn query_point<F: FnMut(usize)>(
        &self,
        p: Vec3,
        exclude: usize,
        pos: &[Vec3],
        radius: &[f32],
        scratch: &mut QueryScratch,
        mut visit: F,
    ) {
        let QueryScratch { stack, spill, stats, .. } = scratch;
        stats.rays += 1;
        let mut sp = 0usize;
        debug_assert!(spill.is_empty());

        let mut current = 0u32;
        loop {
            // SAFETY: `current` is always a node index produced by the
            // builder (root 0, children `left_first`/`left_first+1` which
            // `check_invariants` proves in-bounds); prim_order indices are
            // a permutation of 0..n_prims. Skipping the bounds checks is
            // worth ~8% on this hottest loop (EXPERIMENTS.md §Perf #6).
            let node = unsafe { self.nodes.get_unchecked(current as usize) };
            stats.aabb_tests += 1;
            if node.aabb.contains(p) {
                if node.is_leaf() {
                    let first = node.left_first as usize;
                    for k in first..first + node.count as usize {
                        let j = unsafe { *self.prim_order.get_unchecked(k) } as usize;
                        stats.sphere_tests += 1;
                        if j != exclude {
                            let d2 = (p - *unsafe { pos.get_unchecked(j) }).norm2();
                            let r = unsafe { *radius.get_unchecked(j) };
                            if d2 < r * r {
                                stats.hits += 1;
                                visit(j);
                            }
                        }
                    }
                } else {
                    // push right, descend left
                    let l = node.left_first;
                    if sp < STACK_DEPTH {
                        stack[sp] = l + 1;
                        sp += 1;
                    } else {
                        spill.push(l + 1);
                    }
                    current = l;
                    continue;
                }
            }
            // pop
            if let Some(next) = spill.pop() {
                current = next;
            } else if sp > 0 {
                sp -= 1;
                current = stack[sp];
            } else {
                break;
            }
        }
    }

    /// Collect hit indices into a vector (convenience for tests and the
    /// neighbor-list pipeline).
    pub fn query_point_collect(
        &self,
        p: Vec3,
        exclude: usize,
        pos: &[Vec3],
        radius: &[f32],
        scratch: &mut QueryScratch,
    ) -> Vec<usize> {
        let mut out = Vec::new();
        self.query_point(p, exclude, pos, radius, scratch, |j| out.push(j));
        out
    }

    /// Batched query sweep over `0..n` query indices: chunked work-stealing
    /// across `threads` workers, each owning a thread-local accumulator
    /// from `init` plus a [`QueryScratch`] that is reused for every ray the
    /// worker processes. `body` handles one chunk of query indices (running
    /// its rays through [`Bvh::query_point`] / `launch_rays` with the
    /// provided scratch) and returns the chunk's output.
    ///
    /// Returns the chunk outputs **in chunk order** (bitwise-deterministic
    /// merging regardless of scheduling) plus the traversal stats merged
    /// once per worker.
    pub fn query_batch<A, O, I, F>(
        &self,
        n: usize,
        threads: usize,
        init: I,
        body: F,
    ) -> (Vec<O>, TraversalStats)
    where
        A: Send,
        O: Send,
        I: Fn() -> A + Sync,
        F: Fn(&mut A, &mut QueryScratch, std::ops::Range<usize>) -> O + Sync,
    {
        let block = batch_block(n);
        let (outs, states) = crate::parallel::parallel_chunk_map(
            n,
            threads,
            block,
            || (init(), QueryScratch::new()),
            |state, range| body(&mut state.0, &mut state.1, range),
        );
        let mut stats = TraversalStats::default();
        for (_, scratch) in &states {
            stats.add(&scratch.stats);
        }
        (outs, stats)
    }
}

/// Chunk size for a batched sweep: ~64 chunks total for stealing slack,
/// bounded so tiny sweeps stay single-chunk and huge sweeps keep per-chunk
/// merge overhead negligible. Deliberately independent of the worker count:
/// the chunk partition (and therefore every chunk-ordered merge downstream,
/// e.g. the ORCS-forces scatter reduction) is bitwise identical across
/// `ORCS_THREADS` settings, not just across runs at a fixed setting.
fn batch_block(n: usize) -> usize {
    (n / 64).clamp(32, 4096)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bvh::BuildKind;
    use crate::core::rng::Rng;

    fn scene(n: usize, seed: u64, rmax: f32) -> (Vec<Vec3>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (
            (0..n)
                .map(|_| {
                    Vec3::new(
                        rng.range_f32(0.0, 100.0),
                        rng.range_f32(0.0, 100.0),
                        rng.range_f32(0.0, 100.0),
                    )
                })
                .collect(),
            (0..n).map(|_| rng.range_f32(0.5, rmax)).collect(),
        )
    }

    fn brute(p: Vec3, exclude: usize, pos: &[Vec3], radius: &[f32]) -> Vec<usize> {
        let mut v: Vec<usize> = (0..pos.len())
            .filter(|&j| j != exclude && (p - pos[j]).norm2() < radius[j] * radius[j])
            .collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn query_matches_brute_force() {
        let (pos, radius) = scene(400, 21, 8.0);
        for kind in [BuildKind::Median, BuildKind::BinnedSah] {
            let bvh = Bvh::build(&pos, &radius, kind);
            let mut scratch = QueryScratch::new();
            for i in 0..pos.len() {
                let mut got = bvh.query_point_collect(pos[i], i, &pos, &radius, &mut scratch);
                got.sort_unstable();
                assert_eq!(got, brute(pos[i], i, &pos, &radius), "i={i} kind={kind:?}");
            }
            assert_eq!(scratch.stats.rays, 400);
            assert!(scratch.stats.aabb_tests > 0 && scratch.stats.sphere_tests > 0);
        }
    }

    #[test]
    fn query_correct_after_refits() {
        let (mut pos, radius) = scene(300, 22, 6.0);
        let mut bvh = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
        let mut rng = Rng::new(5);
        let mut scratch = QueryScratch::new();
        for _ in 0..4 {
            for p in pos.iter_mut() {
                *p += Vec3::new(
                    rng.range_f32(-3.0, 3.0),
                    rng.range_f32(-3.0, 3.0),
                    rng.range_f32(-3.0, 3.0),
                );
            }
            bvh.refit(&pos, &radius);
            for i in (0..pos.len()).step_by(7) {
                let mut got = bvh.query_point_collect(pos[i], i, &pos, &radius, &mut scratch);
                got.sort_unstable();
                assert_eq!(got, brute(pos[i], i, &pos, &radius));
            }
        }
    }

    #[test]
    fn refit_degradation_increases_traversal_cost() {
        // the phenomenon gradient exploits: after motion + refit, queries
        // touch more nodes than after a rebuild of the same configuration
        let (mut pos, radius) = scene(2000, 23, 3.0);
        let mut bvh = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
        let mut rng = Rng::new(6);
        for _ in 0..10 {
            for p in pos.iter_mut() {
                *p += Vec3::new(
                    rng.range_f32(-4.0, 4.0),
                    rng.range_f32(-4.0, 4.0),
                    rng.range_f32(-4.0, 4.0),
                );
            }
            bvh.refit(&pos, &radius);
        }
        let mut scratch = QueryScratch::new();
        for i in 0..pos.len() {
            bvh.query_point(pos[i], i, &pos, &radius, &mut scratch, |_| {});
        }
        let refit_stats = scratch.take_stats();
        let fresh = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
        for i in 0..pos.len() {
            fresh.query_point(pos[i], i, &pos, &radius, &mut scratch, |_| {});
        }
        let fresh_stats = scratch.take_stats();
        // hits identical (correctness), cost strictly larger (degradation)
        assert_eq!(refit_stats.hits, fresh_stats.hits);
        assert!(
            refit_stats.aabb_tests > fresh_stats.aabb_tests,
            "refit={} fresh={}",
            refit_stats.aabb_tests,
            fresh_stats.aabb_tests
        );
    }

    #[test]
    fn exclude_max_keeps_self() {
        let pos = vec![Vec3::ZERO, Vec3::new(1.0, 0.0, 0.0)];
        let radius = vec![2.0f32, 2.0];
        let bvh = Bvh::build(&pos, &radius, BuildKind::Median);
        let mut scratch = QueryScratch::new();
        let got = bvh.query_point_collect(Vec3::ZERO, usize::MAX, &pos, &radius, &mut scratch);
        assert_eq!(got.len(), 2); // both spheres contain the origin
    }

    #[test]
    fn batch_matches_per_point_queries() {
        let (pos, radius) = scene(700, 24, 7.0);
        for kind in [BuildKind::Median, BuildKind::BinnedSah, BuildKind::Lbvh] {
            let bvh = Bvh::build(&pos, &radius, kind);
            // per-point reference
            let mut scratch = QueryScratch::new();
            let serial: Vec<Vec<usize>> = (0..pos.len())
                .map(|i| bvh.query_point_collect(pos[i], i, &pos, &radius, &mut scratch))
                .collect();
            let serial_stats = scratch.take_stats();
            for threads in [1, 4] {
                let (chunks, stats) = bvh.query_batch(
                    pos.len(),
                    threads,
                    || (),
                    |_, scratch, range| {
                        range
                            .map(|i| {
                                bvh.query_point_collect(pos[i], i, &pos, &radius, scratch)
                            })
                            .collect::<Vec<_>>()
                    },
                );
                let batched: Vec<Vec<usize>> = chunks.into_iter().flatten().collect();
                assert_eq!(batched, serial, "kind={kind:?} threads={threads}");
                assert_eq!(stats, serial_stats, "kind={kind:?} threads={threads}");
            }
        }
    }
}
