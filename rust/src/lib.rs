//! # ORCS — Optimized Ray-tracing Core Simulation
//!
//! Reproduction of *"Advancing RT Core-Accelerated Fixed-Radius Nearest
//! Neighbor Search"* (CS.DC 2026) on a three-layer Rust + JAX/Pallas + PJRT
//! stack. The crate provides:
//!
//! * a software **BVH substrate** standing in for the GPU RT cores, with
//!   exact operation counters ([`bvh`]);
//! * the paper's three contributions: the **gradient** BVH update/rebuild
//!   optimizer ([`gradient`]), the neighbor-list-free **ORCS** pipelines and
//!   the ray-traced **periodic boundary conditions** ([`frnn`]);
//! * reference baselines (CPU-CELL, GPU-CELL, RT-REF) ([`frnn`]);
//! * a roofline **timing + power model** over four GPU generations,
//!   including heterogeneous multi-device fleet aggregation ([`rtcore`]);
//! * a **PJRT runtime** executing AOT-lowered JAX/Pallas HLO artifacts on the
//!   hot path ([`runtime`]);
//! * the **coordinator** engine, metrics and reporting ([`coordinator`]);
//! * the **sharded domain decomposition**: per-shard BVHs and rebuild
//!   policies over an `S³` grid with periodic halo exchange, per-shard OOM
//!   metering and heterogeneous multi-device stepping ([`shard`]);
//! * the **resilience runtime**: typed error taxonomy, seeded fault
//!   injection, OOM degradation ladder, numerical watchdog and
//!   checkpointed shard recovery ([`resilience`]);
//! * the **benchmark suite** regenerating every table and figure of the
//!   paper's evaluation, plus the sharded-scaling study ([`benchsuite`]);
//! * `orcs lint` — a dependency-free **static-analysis pass** enforcing the
//!   determinism and panic-safety contracts above as machine-checked rules
//!   ([`analysis`], `docs/LINTS.md`);
//! * the **telemetry subsystem**: deterministic per-step phase spans over
//!   simulated device time, a labeled metrics registry, Chrome-trace
//!   export and a flight recorder for fault forensics ([`telemetry`],
//!   `docs/OBSERVABILITY.md`).
//!
//! See `DESIGN.md` for the system inventory and the hardware-substitution
//! rationale, and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod core;
pub mod parallel;
pub mod physics;
pub mod bvh;
pub mod frnn;
pub mod gradient;
pub mod rtcore;
pub mod runtime;
pub mod coordinator;
pub mod resilience;
pub mod shard;
pub mod telemetry;
pub mod analysis;
pub mod benchsuite;
pub mod cli;
pub mod testutil;

pub use crate::core::{aabb::Aabb, rng::Rng, vec3::Vec3};
