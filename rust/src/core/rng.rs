//! Deterministic pseudo-random number generation.
//!
//! Every experiment in the benchmark suite must be exactly reproducible, so
//! we ship our own splitmix64-seeded xoshiro256++ generator instead of
//! depending on an external `rand` (not available in the offline vendor set).
//! Includes uniform, normal (Box–Muller) and log-normal sampling — the three
//! distributions the paper's evaluation uses (§4.1).

/// xoshiro256++ PRNG seeded via splitmix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box–Muller.
    spare_normal: Option<f64>,
}

#[inline(always)]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    /// Derive an independent stream (for per-thread / per-case seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Standard normal variate (Box–Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        // Rejection-free polar-less form; avoid u1 == 0.
        let mut u1 = self.f64();
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = std::f64::consts::TAU * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean `mu`, std-dev `sigma`.
    #[inline]
    pub fn normal_ms(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Log-normal: `exp(N(mu, sigma))`.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_is_positive_and_skewed() {
        let mut r = Rng::new(13);
        let mut max = 0.0f64;
        let mut below_med = 0usize;
        let n = 10_000;
        for _ in 0..n {
            let v = r.lognormal(1.0, 2.0);
            assert!(v > 0.0);
            if v < std::f64::consts::E {
                below_med += 1; // median of LN(1,2) is e^1
            }
            max = max.max(v);
        }
        let frac = below_med as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.03, "median frac={frac}");
        assert!(max > 100.0, "heavy tail expected, max={max}");
    }

    #[test]
    fn below_in_range_and_shuffle_permutes() {
        let mut r = Rng::new(17);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
