//! PJRT runtime integration: artifacts load and compile, the XLA kernels
//! agree with the pure-Rust oracle (the L1/L2 ↔ L3 numeric contract), and
//! the bucket/chunk plumbing handles every shape edge.
//!
//! Requires `make artifacts` (skips with a message otherwise, but `make
//! test` always builds them first).

use std::sync::Arc;

use orcs::coordinator::{Engine, EngineConfig};
use orcs::core::config::{Boundary, ParticleDist, RadiusDist, SimConfig};
use orcs::frnn::{ApproachKind, NeighborLists, PhysicsKernels, RustKernels};
use orcs::physics::state::SimState;
use orcs::rtcore::OpCounts;
use orcs::runtime::kernels::XlaKernels;

fn load_kernels() -> Option<XlaKernels> {
    match XlaKernels::load_default() {
        Ok(k) => Some(k),
        Err(e) => {
            eprintln!("skipping runtime tests (run `make artifacts` first): {e:#}");
            None
        }
    }
}

fn scene(n: usize, boundary: Boundary, radius: RadiusDist, seed: u64) -> SimState {
    let cfg = SimConfig {
        n,
        box_l: 150.0,
        particle_dist: ParticleDist::Disordered,
        radius_dist: radius,
        boundary,
        seed,
        ..SimConfig::default()
    };
    SimState::from_config(&cfg)
}

/// Interaction neighbor lists via brute force (test input builder).
fn brute_lists(state: &SimState) -> NeighborLists {
    let lists: Vec<Vec<u32>> = (0..state.n())
        .map(|i| {
            orcs::frnn::brute::interaction_neighbors(
                i,
                &state.pos,
                &state.radius,
                state.boundary,
                state.box_l,
            )
            .into_iter()
            .map(|j| j as u32)
            .collect()
        })
        .collect();
    NeighborLists::from_vecs(&lists)
}

#[test]
fn xla_forces_match_rust_oracle() {
    let Some(xla) = load_kernels() else { return };
    let rust = RustKernels { threads: 2 };
    for boundary in Boundary::ALL {
        for radius in [RadiusDist::Const(12.0), RadiusDist::Uniform(3.0, 25.0)] {
            let state = scene(500, boundary, radius, 21);
            let lists = brute_lists(&state);
            let mut c1 = OpCounts::default();
            let mut c2 = OpCounts::default();
            let f_xla = xla.lj_forces(&state, &lists, &mut c1).unwrap();
            let f_rust = rust.lj_forces(&state, &lists, &mut c2).unwrap();
            for i in 0..state.n() {
                let d = (f_xla[i] - f_rust[i]).norm();
                let scale = f_rust[i].norm().max(1.0);
                assert!(
                    d < 1e-3 * scale,
                    "{boundary:?}/{radius:?} particle {i}: xla {:?} rust {:?}",
                    f_xla[i],
                    f_rust[i]
                );
            }
            assert!(c1.kernel_launches > 0);
        }
    }
}

#[test]
fn xla_integrate_matches_rust() {
    let Some(xla) = load_kernels() else { return };
    for boundary in Boundary::ALL {
        let mut s_xla = scene(700, boundary, RadiusDist::Const(5.0), 31);
        // nonzero forces to integrate
        for (i, f) in s_xla.force.iter_mut().enumerate() {
            let k = i as f32;
            *f = orcs::core::vec3::Vec3::new((k * 0.37).sin() * 50.0, (k * 0.11).cos() * 50.0, 1.0);
        }
        let mut s_rust = s_xla.clone();
        let mut c = OpCounts::default();
        xla.integrate(&mut s_xla, &mut c).unwrap();
        orcs::physics::integrator::step(&mut s_rust);
        for i in 0..s_rust.n() {
            let dp = (s_xla.pos[i] - s_rust.pos[i]).norm();
            let dv = (s_xla.vel[i] - s_rust.vel[i]).norm();
            assert!(dp < 1e-4 && dv < 1e-4, "{boundary:?} particle {i}: dp={dp} dv={dv}");
        }
        assert_eq!(s_xla.step_count, 1);
    }
}

#[test]
fn bucket_segmentation_handles_wide_lists() {
    let Some(xla) = load_kernels() else { return };
    let rust = RustKernels { threads: 1 };
    // dense scene: some lists exceed the widest bucket (256)
    let state = scene(2_000, Boundary::Periodic, RadiusDist::Const(50.0), 41);
    let lists = brute_lists(&state);
    assert!(lists.k_max() > 256, "test needs k_max > widest bucket, got {}", lists.k_max());
    let mut c1 = OpCounts::default();
    let mut c2 = OpCounts::default();
    let f_xla = xla.lj_forces(&state, &lists, &mut c1).unwrap();
    let f_rust = rust.lj_forces(&state, &lists, &mut c2).unwrap();
    for i in 0..state.n() {
        let d = (f_xla[i] - f_rust[i]).norm();
        assert!(d < 2e-3 * f_rust[i].norm().max(1.0), "particle {i}: {d}");
    }
    // multiple launches required for the segmented lists
    assert!(c1.kernel_launches > 1);
}

#[test]
fn empty_lists_are_fine() {
    let Some(xla) = load_kernels() else { return };
    let state = scene(64, Boundary::Wall, RadiusDist::Const(0.1), 51);
    let lists = NeighborLists::from_vecs(&vec![Vec::new(); 64]);
    let mut c = OpCounts::default();
    let f = xla.lj_forces(&state, &lists, &mut c).unwrap();
    assert!(f.iter().all(|v| v.norm() == 0.0));
}

#[test]
fn rt_ref_on_xla_path_matches_rust_path_end_to_end() {
    let Some(_probe) = load_kernels() else { return };
    let cfg = SimConfig {
        n: 400,
        box_l: 150.0,
        particle_dist: ParticleDist::Disordered,
        radius_dist: RadiusDist::Uniform(3.0, 20.0),
        boundary: Boundary::Periodic,
        seed: 61,
        ..SimConfig::default()
    };
    let run = |kernels: Arc<dyn PhysicsKernels>| {
        let ec = EngineConfig {
            policy: "fixed-4".into(),
            threads: 2,
            check_oom: false,
            ..EngineConfig::new(cfg.clone(), ApproachKind::RtRef)
        };
        let mut e = Engine::new(ec, kernels).unwrap();
        e.run(5, false).unwrap();
        e.state.pos.clone()
    };
    let pos_rust = run(Arc::new(RustKernels { threads: 2 }));
    let pos_xla = run(Arc::new(XlaKernels::load_default().unwrap()));
    for i in 0..cfg.n {
        let d = (pos_rust[i] - pos_xla[i]).norm();
        assert!(d < 1e-2, "particle {i} diverged between force paths: {d}");
    }
}
