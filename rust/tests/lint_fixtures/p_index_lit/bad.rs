// Fixture: seeded P-INDEX-LIT violation (literal index in a step path).
pub fn root(nodes: &[u32]) -> u32 {
    nodes[0]
}
