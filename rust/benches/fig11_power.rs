//! `cargo bench --bench fig11_power [-- --quick]`
//! Regenerates paper Figs. 11 & 12 (power series + energy efficiency).
fn main() {
    let opts = orcs::benchsuite::common::BenchOpts::from_env().expect("bench options");
    orcs::benchsuite::fig11_12::run(&opts).expect("fig11/12 bench");
}
