//! Axis-aligned bounding boxes — the primitive the (simulated) RT cores
//! traverse. Each particle's search sphere (center `p`, radius `r`) bounds
//! to `[p - r, p + r]`.

use super::vec3::Vec3;

/// An axis-aligned bounding box.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Aabb {
    pub lo: Vec3,
    pub hi: Vec3,
}

impl Aabb {
    /// The empty box (identity for [`Aabb::union`]).
    pub const EMPTY: Aabb = Aabb {
        lo: Vec3::splat(f32::INFINITY),
        hi: Vec3::splat(f32::NEG_INFINITY),
    };

    #[inline(always)]
    pub fn new(lo: Vec3, hi: Vec3) -> Self {
        Aabb { lo, hi }
    }

    /// Bounding box of a sphere at `c` with radius `r`.
    #[inline(always)]
    pub fn of_sphere(c: Vec3, r: f32) -> Self {
        Aabb {
            lo: c - Vec3::splat(r),
            hi: c + Vec3::splat(r),
        }
    }

    /// Smallest box containing both operands.
    #[inline(always)]
    pub fn union(self, o: Aabb) -> Aabb {
        Aabb {
            lo: self.lo.min(o.lo),
            hi: self.hi.max(o.hi),
        }
    }

    /// Grow in place — hot loop of refit, avoids a copy.
    #[inline(always)]
    pub fn grow(&mut self, o: &Aabb) {
        self.lo = self.lo.min(o.lo);
        self.hi = self.hi.max(o.hi);
    }

    /// Does `p` lie inside (or on the surface of) the box?
    #[inline(always)]
    pub fn contains(&self, p: Vec3) -> bool {
        p.x >= self.lo.x
            && p.x <= self.hi.x
            && p.y >= self.lo.y
            && p.y <= self.hi.y
            && p.z >= self.lo.z
            && p.z <= self.hi.z
    }

    /// Box center.
    #[inline(always)]
    pub fn center(&self) -> Vec3 {
        (self.lo + self.hi) * 0.5
    }

    /// Surface area (the SAH quality measure). Zero for the empty box.
    #[inline(always)]
    pub fn surface_area(&self) -> f32 {
        let d = self.hi - self.lo;
        if d.x < 0.0 || d.y < 0.0 || d.z < 0.0 {
            return 0.0;
        }
        2.0 * (d.x * d.y + d.y * d.z + d.z * d.x)
    }

    /// True when the box contains no points.
    #[inline(always)]
    pub fn is_empty(&self) -> bool {
        self.lo.x > self.hi.x
    }

    /// Longest axis (0/1/2) — split axis for median builds.
    #[inline(always)]
    pub fn longest_axis(&self) -> usize {
        let d = self.hi - self.lo;
        if d.x >= d.y && d.x >= d.z {
            0
        } else if d.y >= d.z {
            1
        } else {
            2
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sphere_bounds() {
        let b = Aabb::of_sphere(Vec3::new(1.0, 2.0, 3.0), 0.5);
        assert_eq!(b.lo, Vec3::new(0.5, 1.5, 2.5));
        assert_eq!(b.hi, Vec3::new(1.5, 2.5, 3.5));
        assert!(b.contains(Vec3::new(1.0, 2.0, 3.0)));
        assert!(!b.contains(Vec3::new(2.0, 2.0, 3.0)));
    }

    #[test]
    fn union_and_empty() {
        let a = Aabb::of_sphere(Vec3::ZERO, 1.0);
        let b = Aabb::of_sphere(Vec3::splat(5.0), 1.0);
        let u = a.union(b);
        assert_eq!(u.lo, Vec3::splat(-1.0));
        assert_eq!(u.hi, Vec3::splat(6.0));
        assert!(Aabb::EMPTY.is_empty());
        assert_eq!(Aabb::EMPTY.union(a), a);
        assert_eq!(Aabb::EMPTY.surface_area(), 0.0);
    }

    #[test]
    fn surface_area_unit_cube() {
        let b = Aabb::new(Vec3::ZERO, Vec3::splat(1.0));
        assert_eq!(b.surface_area(), 6.0);
        assert_eq!(b.center(), Vec3::splat(0.5));
    }

    #[test]
    fn longest_axis_picks_max_extent() {
        let b = Aabb::new(Vec3::ZERO, Vec3::new(1.0, 5.0, 2.0));
        assert_eq!(b.longest_axis(), 1);
    }
}
