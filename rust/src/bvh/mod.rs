//! The BVH substrate — our stand-in for the GPU RT cores' acceleration
//! structure.
//!
//! The paper manages the OptiX BVH through exactly two operations: **build**
//! (full reconstruction, optimal tree for the current particle positions)
//! and **update** (refit: recompute node bounds over the existing topology).
//! We reproduce both, plus a stack traversal with *exact operation counters*
//! (AABB tests, sphere tests) that feed the RT-core timing model
//! ([`crate::rtcore`]). Refit-induced degradation — the phenomenon the
//! `gradient` optimizer exploits — emerges structurally: as particles move,
//! refitted node bounds overlap more and traversal touches more nodes.
//!
//! # Node layout: 4-wide SoA (BVH4)
//!
//! Nodes are **4-wide** ([`Bvh4Node`]), mirroring the wide BVHs RT silicon
//! actually traverses: each node stores the AABBs of up to four children in
//! transposed structure-of-arrays form (`min_x[4]; min_y[4]; …`), so one
//! point-in-box step tests all four children from a single 128-byte node
//! fetch. The array is laid out in **breadth-first order** — all nodes of
//! depth `d` precede depth `d + 1` (ranges recorded in
//! [`Bvh::level_starts`]) — which makes a reverse index sweep a valid
//! bottom-up order *and* lets [`Bvh::refit`] process each level as an
//! embarrassingly parallel slice (level-partitioned refit, bit-identical to
//! the serial sweep).
//!
//! Builds collapse a binary topology into this layout (see [`builder`]) and
//! are multi-threaded; queries run through the batched, allocation-free
//! traversal engine (see [`traverse`]: [`traverse::QueryScratch`] /
//! [`Bvh::query_batch`] / [`Bvh::query_batch_ordered`]); builds, refits and
//! queries all scale with `ORCS_THREADS`.

pub mod builder;
pub mod quality;
pub mod traverse;

use crate::core::aabb::Aabb;
use crate::core::vec3::Vec3;
use crate::parallel;

/// Maximum primitives per leaf lane. 4 mirrors typical hardware BVH widths.
pub const LEAF_SIZE: usize = 4;

/// Branching factor of the wide SoA node layout.
pub const BVH4_WIDTH: usize = 4;

/// Sentinel child value marking an unused lane.
pub const INVALID_LANE: u32 = u32::MAX;

/// One 4-wide SoA BVH node. Child AABBs are stored transposed (per-axis
/// lanes) so a point query tests four boxes with straight-line array code.
/// Lane `l` is:
///
/// * **internal** when `count[l] == 0` and `child[l] != INVALID_LANE` —
///   `child[l]` is the node index of the subtree;
/// * **leaf** when `count[l] > 0` — `child[l]` is the first index of a
///   `count[l]`-long range of [`Bvh::prim_order`];
/// * **empty** when `child[l] == INVALID_LANE` — its bounds are
///   `+inf/-inf`, so every point-in-box test fails and no special-casing is
///   needed on the traversal hot path.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Bvh4Node {
    pub min_x: [f32; BVH4_WIDTH],
    pub min_y: [f32; BVH4_WIDTH],
    pub min_z: [f32; BVH4_WIDTH],
    pub max_x: [f32; BVH4_WIDTH],
    pub max_y: [f32; BVH4_WIDTH],
    pub max_z: [f32; BVH4_WIDTH],
    /// Per-lane child reference (node index or `prim_order` start).
    pub child: [u32; BVH4_WIDTH],
    /// Per-lane primitive count (0 for internal and empty lanes).
    pub count: [u32; BVH4_WIDTH],
}

impl Bvh4Node {
    /// A node with four empty lanes (all boxes inverted-infinite).
    pub const EMPTY: Bvh4Node = Bvh4Node {
        min_x: [f32::INFINITY; BVH4_WIDTH],
        min_y: [f32::INFINITY; BVH4_WIDTH],
        min_z: [f32::INFINITY; BVH4_WIDTH],
        max_x: [f32::NEG_INFINITY; BVH4_WIDTH],
        max_y: [f32::NEG_INFINITY; BVH4_WIDTH],
        max_z: [f32::NEG_INFINITY; BVH4_WIDTH],
        child: [INVALID_LANE; BVH4_WIDTH],
        count: [0; BVH4_WIDTH],
    };

    #[inline(always)]
    pub fn lane_used(&self, lane: usize) -> bool {
        self.child[lane] != INVALID_LANE
    }

    #[inline(always)]
    pub fn lane_is_leaf(&self, lane: usize) -> bool {
        self.count[lane] > 0
    }

    /// Reassemble one lane's box from the SoA fields.
    #[inline(always)]
    pub fn lane_aabb(&self, lane: usize) -> Aabb {
        Aabb::new(
            Vec3::new(self.min_x[lane], self.min_y[lane], self.min_z[lane]),
            Vec3::new(self.max_x[lane], self.max_y[lane], self.max_z[lane]),
        )
    }

    /// Write one lane's box into the SoA fields.
    #[inline(always)]
    pub fn set_lane_aabb(&mut self, lane: usize, bb: &Aabb) {
        self.min_x[lane] = bb.lo.x;
        self.min_y[lane] = bb.lo.y;
        self.min_z[lane] = bb.lo.z;
        self.max_x[lane] = bb.hi.x;
        self.max_y[lane] = bb.hi.y;
        self.max_z[lane] = bb.hi.z;
    }

    /// Populate a lane (box + child reference + count).
    #[inline(always)]
    pub fn set_lane(&mut self, lane: usize, bb: &Aabb, child: u32, count: u32) {
        self.set_lane_aabb(lane, bb);
        self.child[lane] = child;
        self.count[lane] = count;
    }

    /// Union of all used lane boxes = overall bounds of this node's subtree.
    /// (Empty lanes carry inverted-infinite boxes, so growing by them is a
    /// no-op.)
    #[inline]
    pub fn lanes_union(&self) -> Aabb {
        let mut bb = Aabb::EMPTY;
        for lane in 0..BVH4_WIDTH {
            bb.grow(&self.lane_aabb(lane));
        }
        bb
    }
}

/// Build heuristic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BuildKind {
    /// Median split on the longest centroid axis — fast, decent quality
    /// (models hardware LBVH-style builders).
    Median,
    /// Binned surface-area heuristic — slower build, better tree (models
    /// high-quality builds). 16 bins.
    BinnedSah,
    /// Morton-order linear BVH (HLBVH-family, paper refs [29][32]): radix
    /// sort primitives by Z-order, then split sorted ranges at their
    /// midpoint. Fastest build, lowest quality — the hardware-builder
    /// extreme of the build/quality trade-off ablation.
    Lbvh,
}

/// A bounding volume hierarchy over particle search spheres.
#[derive(Clone, Debug)]
pub struct Bvh {
    /// BVH4 nodes in breadth-first order: children always live at higher
    /// indices than their parent, and each depth occupies one contiguous
    /// range (see [`Bvh::level_starts`]). Empty for a zero-primitive scene.
    pub nodes: Vec<Bvh4Node>,
    /// `level_starts[d]..level_starts[d + 1]` is the node range at depth
    /// `d`; `level_starts.last() == nodes.len()`. Drives the
    /// level-partitioned parallel refit.
    pub level_starts: Vec<u32>,
    /// Permutation of primitive ids; leaf lanes reference ranges of it.
    pub prim_order: Vec<u32>,
    pub n_prims: usize,
    pub kind: BuildKind,
    /// Number of refits applied since the last full build.
    pub refits_since_build: u32,
}

/// Minimum nodes in one depth level before the refit sweep goes parallel
/// (below this, thread spawn costs more than the per-node work saves).
const REFIT_PARALLEL_MIN: usize = 128;

impl Bvh {
    /// Number of (4-wide) nodes.
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Root bounding box ([`Aabb::EMPTY`] for a zero-primitive scene).
    pub fn root_aabb(&self) -> Aabb {
        self.nodes.first().map_or(Aabb::EMPTY, |n| n.lanes_union())
    }

    /// Refit ("update" in RT-core terms): recompute every lane's AABB from
    /// current sphere positions without changing the topology. O(nodes),
    /// parallelized over [`crate::parallel::num_threads`] workers.
    pub fn refit(&mut self, pos: &[Vec3], radius: &[f32]) {
        self.refit_with_threads(pos, radius, parallel::num_threads());
    }

    /// [`Bvh::refit`] with an explicit worker count.
    ///
    /// The sweep is **level-partitioned**: depth levels are processed
    /// bottom-up (the same reverse-topological guarantee as a reverse index
    /// sweep over the BFS layout), and the nodes *within* one level are
    /// mutually independent — a leaf lane reads only primitive data and an
    /// internal lane reads only strictly deeper (already-refit) nodes — so
    /// each level fans out across threads. Every node executes the exact
    /// same arithmetic as the serial sweep, so the result is bit-identical
    /// for any thread count.
    pub fn refit_with_threads(&mut self, pos: &[Vec3], radius: &[f32], threads: usize) {
        debug_assert_eq!(pos.len(), self.n_prims);
        let threads = threads.max(1);
        {
            let Bvh { nodes, level_starts, prim_order, .. } = self;
            let node_ptr = parallel::SendPtr(nodes.as_mut_ptr());
            let prim_order: &[u32] = prim_order.as_slice();
            let levels = level_starts.len().saturating_sub(1);
            for level in (0..levels).rev() {
                let lo = level_starts[level] as usize;
                let hi = level_starts[level + 1] as usize;
                let width = hi - lo;
                if threads == 1 || width < REFIT_PARALLEL_MIN {
                    for slot in lo..hi {
                        // SAFETY: serial sweep, no concurrent access.
                        unsafe { refit_node(node_ptr.0, slot, prim_order, pos, radius) };
                    }
                } else {
                    parallel::parallel_for_chunks_grained(width, threads, 64, |_, range| {
                        for k in range {
                            // SAFETY: slots within one level are written by
                            // exactly one worker each (disjoint chunks) and
                            // child reads target strictly deeper levels,
                            // which were completed before this level began.
                            unsafe { refit_node(node_ptr.0, lo + k, prim_order, pos, radius) };
                        }
                    });
                }
            }
        }
        self.refits_since_build += 1;
    }

    /// Validate structural invariants (tests / debug builds).
    pub fn check_invariants(&self, pos: &[Vec3], radius: &[f32]) -> Result<(), String> {
        // prim_order is a permutation
        let mut seen = vec![false; self.n_prims];
        for &p in &self.prim_order {
            let p = p as usize;
            if p >= self.n_prims {
                return Err(format!("prim id {p} out of range"));
            }
            if seen[p] {
                return Err(format!("prim id {p} duplicated"));
            }
            seen[p] = true;
        }
        if !seen.iter().all(|&s| s) {
            return Err("prim_order not a full permutation".into());
        }
        if self.n_prims == 0 {
            if !self.nodes.is_empty() {
                return Err("empty scene must have no nodes".into());
            }
            return Ok(());
        }
        if self.nodes.is_empty() {
            return Err("non-empty scene with no nodes".into());
        }
        // level table sane
        if self.level_starts.first() != Some(&0)
            || self.level_starts.last().copied() != Some(self.nodes.len() as u32)
            // lint:allow(P-INDEX-LIT): windows(2) yields exactly-2 slices
            || self.level_starts.windows(2).any(|w| w[0] >= w[1])
        {
            return Err(format!("bad level_starts {:?}", self.level_starts));
        }
        // every lane bounds its content; leaf lanes cover prim_order
        // exactly once; internal lanes point strictly forward
        let mut covered = vec![false; self.n_prims];
        for (i, n) in self.nodes.iter().enumerate() {
            for lane in 0..BVH4_WIDTH {
                if !n.lane_used(lane) {
                    if n.count[lane] != 0 {
                        return Err(format!("node {i} empty lane {lane} with count"));
                    }
                    continue;
                }
                let bb = n.lane_aabb(lane);
                if n.lane_is_leaf(lane) {
                    let first = n.child[lane] as usize;
                    let cnt = n.count[lane] as usize;
                    if first + cnt > self.prim_order.len() {
                        return Err(format!("node {i} lane {lane} range out of bounds"));
                    }
                    for k in first..first + cnt {
                        if covered[k] {
                            return Err(format!("prim slot {k} referenced twice"));
                        }
                        covered[k] = true;
                        let p = self.prim_order[k] as usize;
                        let sb = Aabb::of_sphere(pos[p], radius[p]);
                        if !contains_box(&bb, &sb) {
                            return Err(format!("node {i} lane {lane} does not bound prim {p}"));
                        }
                    }
                } else {
                    let c = n.child[lane] as usize;
                    if c <= i || c >= self.nodes.len() {
                        return Err(format!("node {i} lane {lane} bad child index {c}"));
                    }
                    let cb = self.nodes[c].lanes_union();
                    if !contains_box(&bb, &cb) {
                        return Err(format!("node {i} lane {lane} does not bound child {c}"));
                    }
                }
            }
        }
        if !covered.iter().all(|&c| c) {
            return Err("leaf lanes do not cover every prim_order slot".into());
        }
        Ok(())
    }
}

/// Recompute the lane boxes of `nodes[slot]`: leaf lanes from current
/// primitive spheres, internal lanes from the (already-refit) child node's
/// lane union. Shared by the serial and the level-parallel sweeps so both
/// produce bit-identical results.
///
/// # Safety
/// `nodes` must be valid for the whole node array; `nodes[slot]` must not
/// be accessed concurrently, and the child slots referenced by `slot` must
/// not be written concurrently (guaranteed by bottom-up level ordering).
unsafe fn refit_node(
    nodes: *mut Bvh4Node,
    slot: usize,
    prim_order: &[u32],
    pos: &[Vec3],
    radius: &[f32],
) {
    let node = &mut *nodes.add(slot);
    for lane in 0..BVH4_WIDTH {
        let c = node.child[lane];
        if c == INVALID_LANE {
            continue;
        }
        let bb = if node.count[lane] > 0 {
            let first = c as usize;
            let mut bb = Aabb::EMPTY;
            for k in first..first + node.count[lane] as usize {
                let p = prim_order[k] as usize;
                bb.grow(&Aabb::of_sphere(pos[p], radius[p]));
            }
            bb
        } else {
            // children live at higher indices -> already refit
            (*nodes.add(c as usize)).lanes_union()
        };
        node.set_lane_aabb(lane, &bb);
    }
}

fn contains_box(outer: &Aabb, inner: &Aabb) -> bool {
    const EPS: f32 = 1e-3;
    inner.is_empty()
        || (outer.lo.x <= inner.lo.x + EPS
            && outer.lo.y <= inner.lo.y + EPS
            && outer.lo.z <= inner.lo.z + EPS
            && outer.hi.x >= inner.hi.x - EPS
            && outer.hi.y >= inner.hi.y - EPS
            && outer.hi.z >= inner.hi.z - EPS)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Rng;

    fn random_scene(n: usize, seed: u64) -> (Vec<Vec3>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let pos = (0..n)
            .map(|_| {
                Vec3::new(
                    rng.range_f32(0.0, 100.0),
                    rng.range_f32(0.0, 100.0),
                    rng.range_f32(0.0, 100.0),
                )
            })
            .collect();
        let radius = (0..n).map(|_| rng.range_f32(0.5, 5.0)).collect();
        (pos, radius)
    }

    #[test]
    fn build_invariants_hold_both_kinds() {
        for kind in [BuildKind::Median, BuildKind::BinnedSah] {
            let (pos, radius) = random_scene(500, 9);
            let bvh = Bvh::build(&pos, &radius, kind);
            bvh.check_invariants(&pos, &radius).unwrap();
            assert_eq!(bvh.n_prims, 500);
            assert_eq!(bvh.refits_since_build, 0);
        }
    }

    #[test]
    fn refit_keeps_invariants_after_motion() {
        let (mut pos, radius) = random_scene(300, 10);
        let mut bvh = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
        let mut rng = Rng::new(77);
        for round in 1..=5 {
            for p in pos.iter_mut() {
                *p += Vec3::new(
                    rng.range_f32(-2.0, 2.0),
                    rng.range_f32(-2.0, 2.0),
                    rng.range_f32(-2.0, 2.0),
                );
            }
            bvh.refit(&pos, &radius);
            bvh.check_invariants(&pos, &radius).unwrap();
            assert_eq!(bvh.refits_since_build, round);
        }
    }

    #[test]
    fn single_and_tiny_inputs() {
        let pos = vec![Vec3::splat(1.0)];
        let radius = vec![2.0];
        let bvh = Bvh::build(&pos, &radius, BuildKind::Median);
        bvh.check_invariants(&pos, &radius).unwrap();
        assert_eq!(bvh.node_count(), 1);
        assert!(bvh.nodes[0].lane_is_leaf(0));
        assert_eq!(bvh.nodes[0].count[0], 1);
        assert!(!bvh.nodes[0].lane_used(1));
    }

    #[test]
    fn empty_scene_is_valid() {
        let bvh = Bvh::build(&[], &[], BuildKind::BinnedSah);
        bvh.check_invariants(&[], &[]).unwrap();
        assert_eq!(bvh.node_count(), 0);
        assert!(bvh.root_aabb().is_empty());
        let mut bvh = bvh;
        bvh.refit(&[], &[]); // must not panic
        assert_eq!(bvh.refits_since_build, 1);
    }

    #[test]
    fn refit_grows_root_when_particles_spread() {
        let (mut pos, radius) = random_scene(100, 11);
        let mut bvh = Bvh::build(&pos, &radius, BuildKind::Median);
        let before = bvh.root_aabb().surface_area();
        for p in pos.iter_mut() {
            *p = *p * 2.0; // spread out
        }
        bvh.refit(&pos, &radius);
        assert!(bvh.root_aabb().surface_area() > before);
        bvh.check_invariants(&pos, &radius).unwrap();
    }

    #[test]
    fn parallel_refit_equals_serial_node_for_node() {
        // large enough that leaf levels clear REFIT_PARALLEL_MIN
        let (mut pos, radius) = random_scene(20_000, 12);
        let base = Bvh::build_with_threads(&pos, &radius, BuildKind::BinnedSah, 1);
        let mut rng = Rng::new(13);
        let mut serial = base.clone();
        let mut par = base;
        for _ in 0..3 {
            for p in pos.iter_mut() {
                *p += Vec3::new(
                    rng.range_f32(-2.0, 2.0),
                    rng.range_f32(-2.0, 2.0),
                    rng.range_f32(-2.0, 2.0),
                );
            }
            serial.refit_with_threads(&pos, &radius, 1);
            par.refit_with_threads(&pos, &radius, 8);
            assert_eq!(serial.nodes, par.nodes, "parallel refit diverged from serial");
        }
        par.check_invariants(&pos, &radius).unwrap();
    }

    #[test]
    fn bfs_levels_partition_nodes() {
        let (pos, radius) = random_scene(5000, 14);
        let bvh = Bvh::build(&pos, &radius, BuildKind::Median);
        assert_eq!(*bvh.level_starts.last().unwrap() as usize, bvh.node_count());
        // every internal lane points into a strictly deeper level
        for level in 0..bvh.level_starts.len() - 1 {
            let next = bvh.level_starts[level + 1];
            for s in bvh.level_starts[level]..next {
                let n = &bvh.nodes[s as usize];
                for lane in 0..BVH4_WIDTH {
                    if n.lane_used(lane) && !n.lane_is_leaf(lane) {
                        assert!(n.child[lane] >= next, "child in same or earlier level");
                    }
                }
            }
        }
    }
}
