//! Analytic power/energy model — the NVML substitute.
//!
//! Board power during a phase is `idle + activity * (peak - idle)`, with the
//! activity factor determined by what the phase stresses. Calibration
//! anchors from the paper's Fig. 11 (600 W Blackwell part): RT-REF traversal
//! with heavy neighbor-list traffic ≈ 400 W (activity ≈ 0.6), ORCS variants
//! in between, GPU-CELL lowest, CPU-CELL ≈ 250 W sustained on the EPYC host.
//! Energy efficiency (Fig. 12) is interactions per Joule, Eq. 10.

use super::profile::{DeviceKind, HwProfile};
use super::timing::PhaseTimes;
use super::OpCounts;

/// Phase activity factors (fraction of dynamic power envelope engaged).
#[derive(Clone, Copy, Debug)]
pub struct ActivityFactors {
    pub build: f64,
    pub refit: f64,
    pub traverse_base: f64,
    /// Extra traverse activity when neighbor-list writes dominate (RT-REF's
    /// memory-pressure signature in Fig. 11).
    pub traverse_list_bonus: f64,
    /// Extra traverse activity from in-shader force evaluation (ORCS).
    pub traverse_shade_bonus: f64,
    pub force_kernel: f64,
    pub integrate: f64,
    pub grid: f64,
    pub cell: f64,
    /// CPU approaches run flat-out on all cores.
    pub cpu_flat: f64,
}

pub const DEFAULT_ACTIVITY: ActivityFactors = ActivityFactors {
    build: 0.45,
    refit: 0.30,
    traverse_base: 0.42,
    traverse_list_bonus: 0.20,
    traverse_shade_bonus: 0.10,
    force_kernel: 0.62,
    integrate: 0.35,
    grid: 0.45,
    cell: 0.48,
    cpu_flat: 0.80,
};

/// Power (watts) and energy (joules) for one step.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepEnergy {
    /// Time-weighted average board power over the step, watts.
    pub avg_power_w: f64,
    /// Energy consumed by the step, joules.
    pub energy_j: f64,
}

/// Compute the energy of one step from its phase times and op counts.
pub fn step_energy(times: &PhaseTimes, counts: &OpCounts, hw: &HwProfile) -> StepEnergy {
    let a = DEFAULT_ACTIVITY;
    let dyn_w = hw.peak_w - hw.idle_w;

    if hw.kind == DeviceKind::Cpu {
        let total = times.total();
        let p = hw.idle_w + a.cpu_flat * dyn_w;
        return StepEnergy { avg_power_w: p, energy_j: p * total };
    }

    // Traverse activity rises with list traffic and in-shader force work.
    let hits = counts.sphere_tests.max(1) as f64;
    let w_list = (counts.nbr_list_writes as f64 / hits).min(1.0);
    let w_shade = (counts.isect_force_evals as f64 / hits).min(1.0);
    let traverse_act =
        a.traverse_base + a.traverse_list_bonus * w_list + a.traverse_shade_bonus * w_shade;

    let mut energy = 0.0;
    let mut time = 0.0;
    let mut add = |t: f64, act: f64| {
        if t > 0.0 {
            energy += t * (hw.idle_w + act * dyn_w);
            time += t;
        }
    };
    add(times.build, a.build);
    add(times.refit, a.refit);
    add(times.traverse, traverse_act);
    add(times.force_kernel, a.force_kernel);
    add(times.integrate, a.integrate);
    add(times.grid, a.grid);
    add(times.cell, a.cell);

    let avg = if time > 0.0 { energy / time } else { hw.idle_w };
    StepEnergy { avg_power_w: avg, energy_j: energy }
}

/// Approximate board power of an isolated BVH phase (watts) — feeds the
/// gradient-ee policy's energy observations.
pub fn bvh_phase_power(hw: &HwProfile, phase: BvhPhase) -> f64 {
    let a = DEFAULT_ACTIVITY;
    let act = match phase {
        BvhPhase::Build => a.build,
        BvhPhase::Refit => a.refit,
        BvhPhase::Traverse => a.traverse_base + 0.5 * a.traverse_list_bonus,
    };
    hw.idle_w + act * (hw.peak_w - hw.idle_w)
}

/// BVH pipeline phase identifier for [`bvh_phase_power`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BvhPhase {
    Build,
    Refit,
    Traverse,
}

/// Energy efficiency: interactions per joule (paper Eq. 10).
pub fn energy_efficiency(total_interactions: u64, total_energy_j: f64) -> f64 {
    if total_energy_j <= 0.0 {
        return 0.0;
    }
    total_interactions as f64 / total_energy_j
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtcore::profile::{EPYC64, RTXPRO};
    use crate::rtcore::timing::simulate;

    #[test]
    fn rt_ref_draws_more_than_orcs_per_traverse_second() {
        // RT-REF: every hit writes the list; ORCS: every hit shades a force
        let rt_ref = OpCounts {
            rays: 1000,
            sphere_tests: 1_000_000,
            nbr_list_writes: 1_000_000,
            ..Default::default()
        };
        let orcs = OpCounts {
            rays: 1000,
            sphere_tests: 1_000_000,
            isect_force_evals: 1_000_000,
            ..Default::default()
        };
        let t = PhaseTimes { traverse: 1.0, ..Default::default() };
        let p_ref = step_energy(&t, &rt_ref, &RTXPRO).avg_power_w;
        let p_orcs = step_energy(&t, &orcs, &RTXPRO).avg_power_w;
        assert!(p_ref > p_orcs, "ref={p_ref} orcs={p_orcs}");
        // calibration anchor: RT-REF traversal well below the 600 W peak,
        // in the neighborhood of the paper's ~400 W
        assert!(p_ref > 300.0 && p_ref < 500.0, "p_ref={p_ref}");
    }

    #[test]
    fn cpu_power_near_paper_observation() {
        let t = PhaseTimes { cell: 1.0, ..Default::default() };
        let p = step_energy(&t, &OpCounts::default(), &EPYC64).avg_power_w;
        // paper: ~250 W sustained on the EPYC host
        assert!(p > 200.0 && p < 300.0, "p={p}");
    }

    #[test]
    fn energy_scales_with_time() {
        let counts = OpCounts { rays: 10, sphere_tests: 100, ..Default::default() };
        let t1 = PhaseTimes { traverse: 1.0, ..Default::default() };
        let t2 = PhaseTimes { traverse: 2.0, ..Default::default() };
        let e1 = step_energy(&t1, &counts, &RTXPRO).energy_j;
        let e2 = step_energy(&t2, &counts, &RTXPRO).energy_j;
        assert!((e2 - 2.0 * e1).abs() < 1e-9);
    }

    #[test]
    fn ee_definition() {
        assert_eq!(energy_efficiency(1000, 10.0), 100.0);
        assert_eq!(energy_efficiency(1000, 0.0), 0.0);
    }

    #[test]
    fn full_step_pipeline_energy_positive() {
        let counts = OpCounts {
            bvh_refit_prims: 10_000,
            rays: 10_000,
            aabb_tests: 500_000,
            sphere_tests: 80_000,
            nbr_list_writes: 40_000,
            force_kernel_pairs: 40_000,
            integrate_particles: 10_000,
            ..Default::default()
        };
        let t = simulate(&counts, &RTXPRO);
        let e = step_energy(&t, &counts, &RTXPRO);
        assert!(e.energy_j > 0.0);
        assert!(e.avg_power_w >= RTXPRO.idle_w && e.avg_power_w <= RTXPRO.peak_w);
    }
}
