//! Quantized-BVH property battery (PR 10): the conservative-rounding
//! contract and the bitwise-transparency chain it protects.
//!
//! * **Containment (never-miss)**: every dequantized lane box contains the
//!   *exact* content box of what the lane bounds — leaf sphere unions and,
//!   transitively, whole subtrees — with strict f32 compares, no epsilon.
//! * **Bitwise identity**: neighbor lists equal the brute oracle (and each
//!   other) across `BuildKind` × threads {1, 8}, and engine trajectories
//!   are bitwise identical single-domain vs sharded (S {1, 2}) under both
//!   boundary modes — quantization widens traversal but the exact sphere
//!   filter at the leaves keeps every downstream f32 sequence unchanged.
//! * **Degenerate anchors**: coincident particles (zero-extent frames),
//!   coordinates near f32 extremes, scale-underflow extents, and
//!   refit-degraded trees.
//! * **Kernel equivalence**: SIMD lane kernels ≡ the scalar reference,
//!   lane-for-lane, over edge-pattern lanes and the full clamped query
//!   grid (±inf inputs clamp; positions are NaN-free by the watchdog
//!   contract).

use std::sync::Arc;

use orcs::bvh::simd::{self, Kernel};
use orcs::bvh::traverse::QueryScratch;
use orcs::bvh::{BuildKind, Bvh, Bvh4Node, BVH4_WIDTH};
use orcs::coordinator::{Engine, EngineConfig};
use orcs::core::aabb::Aabb;
use orcs::core::config::{Boundary, ParticleDist, RadiusDist, ShardSpec, SimConfig};
use orcs::core::rng::Rng;
use orcs::core::vec3::Vec3;
use orcs::frnn::{ApproachKind, RustKernels};
use orcs::shard::{ShardedConfig, ShardedEngine};
use orcs::testutil::prop_check;

fn brute(p: Vec3, exclude: usize, pos: &[Vec3], radius: &[f32]) -> Vec<usize> {
    (0..pos.len())
        .filter(|&j| j != exclude && (p - pos[j]).norm2() < radius[j] * radius[j])
        .collect()
}

fn build_kind(rng: &mut Rng) -> BuildKind {
    match rng.below(3) {
        0 => BuildKind::Median,
        1 => BuildKind::BinnedSah,
        _ => BuildKind::Lbvh,
    }
}

/// Strict (no-epsilon) box containment; empty inner boxes are contained in
/// anything.
fn contains(outer: &Aabb, inner: &Aabb) -> bool {
    inner.is_empty()
        || (outer.lo.x <= inner.lo.x
            && outer.lo.y <= inner.lo.y
            && outer.lo.z <= inner.lo.z
            && outer.hi.x >= inner.hi.x
            && outer.hi.y >= inner.hi.y
            && outer.hi.z >= inner.hi.z)
}

/// Assert every dequantized lane box contains the **exact** box of its
/// content, computed bottom-up from the primitive spheres only (tighter
/// than the dequantized child unions the builder quantized against — this
/// checks the transitive conservative contract end to end).
fn assert_quantized_contains_exact(bvh: &Bvh, pos: &[Vec3], radius: &[f32]) -> Result<(), String> {
    let mut exact = vec![Aabb::EMPTY; bvh.nodes.len()];
    for slot in (0..bvh.nodes.len()).rev() {
        let n = &bvh.nodes[slot];
        let mut node_box = Aabb::EMPTY;
        for lane in 0..BVH4_WIDTH {
            if !n.lane_used(lane) {
                continue;
            }
            let lane_exact = if n.lane_is_leaf(lane) {
                let first = n.child[lane] as usize;
                let mut bb = Aabb::EMPTY;
                for k in first..first + n.count[lane] as usize {
                    let p = bvh.prim_order[k] as usize;
                    bb.grow(&Aabb::of_sphere(pos[p], radius[p]));
                }
                bb
            } else {
                exact[n.child[lane] as usize]
            };
            if !contains(&n.lane_aabb(lane), &lane_exact) {
                return Err(format!(
                    "node {slot} lane {lane}: dequantized {:?} does not contain exact {:?}",
                    n.lane_aabb(lane),
                    lane_exact
                ));
            }
            node_box.grow(&lane_exact);
        }
        exact[slot] = node_box;
    }
    Ok(())
}

fn random_scene(rng: &mut Rng, n: usize, span: f32) -> (Vec<Vec3>, Vec<f32>) {
    let pos = (0..n)
        .map(|_| {
            Vec3::new(
                rng.range_f32(0.0, span),
                rng.range_f32(0.0, span),
                rng.range_f32(0.0, span),
            )
        })
        .collect();
    let radius = (0..n).map(|_| rng.range_f32(0.01 * span, 0.08 * span)).collect();
    (pos, radius)
}

#[test]
fn prop_quantized_lanes_contain_exact_boxes() {
    prop_check("quantized-containment", 25, |rng| {
        let n = 50 + rng.below(800);
        let (mut pos, radius) = random_scene(rng, n, 100.0);
        let kind = build_kind(rng);
        let mut bvh = Bvh::build(&pos, &radius, kind);
        assert_quantized_contains_exact(&bvh, &pos, &radius)?;
        bvh.check_invariants(&pos, &radius).map_err(|e| e.to_string())?;
        // containment must survive refits (whole-node requantization)
        for _ in 0..3 {
            for p in pos.iter_mut() {
                *p += Vec3::new(
                    rng.range_f32(-2.0, 2.0),
                    rng.range_f32(-2.0, 2.0),
                    rng.range_f32(-2.0, 2.0),
                );
            }
            bvh.refit(&pos, &radius);
            assert_quantized_contains_exact(&bvh, &pos, &radius)?;
        }
        Ok(())
    });
}

#[test]
fn prop_neighbor_lists_identical_across_buildkind_and_threads() {
    // the never-miss contract, end to end: quantized traversal produces
    // the brute oracle's neighbor lists exactly, for every build kind and
    // thread count (quantization may widen which NODES are visited, never
    // which NEIGHBORS are reported)
    prop_check("quantized-lists-oracle", 12, |rng| {
        let n = 100 + rng.below(500);
        let (pos, radius) = random_scene(rng, n, 80.0);
        let want: Vec<Vec<usize>> =
            (0..n).map(|i| brute(pos[i], i, &pos, &radius)).collect();
        for kind in [BuildKind::Median, BuildKind::BinnedSah, BuildKind::Lbvh] {
            for threads in [1, 8] {
                let bvh = Bvh::build_with_threads(&pos, &radius, kind, threads);
                let mut scratch = QueryScratch::new();
                for i in 0..n {
                    let mut got =
                        bvh.query_point_collect(pos[i], i, &pos, &radius, &mut scratch);
                    got.sort_unstable();
                    if got != want[i] {
                        return Err(format!(
                            "{kind:?} threads={threads} i={i}: {got:?} != {:?}",
                            want[i]
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

fn assert_bits_equal(got: &[Vec3], want: &[Vec3], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for i in 0..want.len() {
        // bitwise, not PartialEq: a -0.0 vs +0.0 discrepancy must fail too
        let (a, b) = (got[i], want[i]);
        assert_eq!(
            (a.x.to_bits(), a.y.to_bits(), a.z.to_bits()),
            (b.x.to_bits(), b.y.to_bits(), b.z.to_bits()),
            "{ctx}: particle {i} diverged: {a:?} vs {b:?}"
        );
    }
}

/// Final (pos, vel, force) of the single-domain engine on `backend`.
fn single_backend(
    cfg: &SimConfig,
    backend: ApproachKind,
    threads: usize,
    steps: usize,
) -> (Vec<Vec3>, Vec<Vec3>, Vec<Vec3>) {
    let ec = EngineConfig {
        policy: "fixed-3".into(),
        threads,
        check_oom: false,
        ..EngineConfig::new(cfg.clone(), backend)
    };
    let mut e = Engine::new(ec, Arc::new(RustKernels { threads })).unwrap();
    e.run(steps, false).unwrap();
    (e.state.pos, e.state.vel, e.state.force)
}

fn sharded_backend(
    cfg: &SimConfig,
    backend: ApproachKind,
    s: usize,
    threads: usize,
    steps: usize,
) -> ShardedEngine {
    let sc = ShardedConfig {
        policy: "fixed-3".into(),
        threads,
        check_oom: false,
        backend,
        ..ShardedConfig::new(cfg.clone(), ShardSpec::new(s))
    };
    let mut e = ShardedEngine::new(sc, Arc::new(RustKernels { threads })).unwrap();
    e.run(steps, false).unwrap();
    e
}

#[test]
fn engine_trajectories_bitwise_identical_across_shards_threads_boundaries() {
    // the re-pinned differential battery: quantized per-shard BVHs must
    // leave the sharded ≡ single-domain transparency chain bitwise intact
    // for S {1, 2} × threads {1, 8} × both boundary modes
    for boundary in [Boundary::Periodic, Boundary::Wall] {
        let cfg = SimConfig {
            n: 600,
            box_l: 100.0,
            particle_dist: ParticleDist::Disordered,
            radius_dist: RadiusDist::Uniform(2.0, 8.0),
            boundary,
            seed: 77,
            ..SimConfig::default()
        };
        let (pos1, vel1, force1) = single_backend(&cfg, ApproachKind::RtRef, 1, 5);
        for threads in [1, 8] {
            let (p, v, f) = single_backend(&cfg, ApproachKind::RtRef, threads, 5);
            assert_bits_equal(&p, &pos1, &format!("single {boundary:?} t={threads} pos"));
            assert_bits_equal(&v, &vel1, &format!("single {boundary:?} t={threads} vel"));
            assert_bits_equal(&f, &force1, &format!("single {boundary:?} t={threads} force"));
            for s in [1, 2] {
                let e = sharded_backend(&cfg, ApproachKind::RtRef, s, threads, 5);
                let ctx = format!("S={s} {boundary:?} t={threads}");
                assert_bits_equal(&e.state.pos, &pos1, &format!("{ctx} pos"));
                assert_bits_equal(&e.state.vel, &vel1, &format!("{ctx} vel"));
                assert_bits_equal(&e.state.force, &force1, &format!("{ctx} force"));
            }
        }
    }
}

#[test]
fn prop_degenerate_anchors() {
    // (c) of the battery: zero-extent frames, f32-extreme coordinates,
    // scale-underflow extents — queries must still match the oracle and
    // invariants must hold exactly
    prop_check("quantized-degenerate-anchors", 15, |rng| {
        // coincident particles: every node frame has zero extent
        let n = 1 + rng.below(40);
        let at = Vec3::new(
            rng.range_f32(-50.0, 50.0),
            rng.range_f32(-50.0, 50.0),
            rng.range_f32(-50.0, 50.0),
        );
        let pos = vec![at; n];
        let radius: Vec<f32> = (0..n).map(|_| rng.range_f32(0.1, 5.0)).collect();
        let kind = build_kind(rng);
        let bvh = Bvh::build(&pos, &radius, kind);
        bvh.check_invariants(&pos, &radius).map_err(|e| e.to_string())?;
        assert_quantized_contains_exact(&bvh, &pos, &radius)?;
        let mut scratch = QueryScratch::new();
        for i in 0..n {
            let mut got = bvh.query_point_collect(pos[i], i, &pos, &radius, &mut scratch);
            got.sort_unstable();
            if got != brute(pos[i], i, &pos, &radius) {
                return Err(format!("{kind:?} coincident mismatch at {i}"));
            }
        }

        // f32-extreme coordinates: anchors near ±1e37 with (relatively)
        // tiny boxes — catastrophic cancellation territory for the frame
        // arithmetic; conservative rounding must absorb it
        let n = 20 + rng.below(80);
        let huge = 1e37;
        let pos: Vec<Vec3> = (0..n)
            .map(|_| {
                Vec3::new(
                    rng.range_f32(-huge, huge),
                    rng.range_f32(-huge, huge),
                    rng.range_f32(-huge, huge),
                )
            })
            .collect();
        let radius: Vec<f32> = (0..n).map(|_| rng.range_f32(1e30, 1e33)).collect();
        let kind = build_kind(rng);
        let bvh = Bvh::build(&pos, &radius, kind);
        bvh.check_invariants(&pos, &radius).map_err(|e| e.to_string())?;
        assert_quantized_contains_exact(&bvh, &pos, &radius)?;
        for i in 0..n {
            let mut got = bvh.query_point_collect(pos[i], i, &pos, &radius, &mut scratch);
            got.sort_unstable();
            if got != brute(pos[i], i, &pos, &radius) {
                return Err(format!("{kind:?} extreme-coords mismatch at {i}"));
            }
        }

        // scale underflow: extents so small the per-axis scale clamps at
        // the minimum normal exponent — frames stay valid and conservative
        let n = 10 + rng.below(30);
        let base = Vec3::new(
            rng.range_f32(-1.0, 1.0),
            rng.range_f32(-1.0, 1.0),
            rng.range_f32(-1.0, 1.0),
        );
        let pos: Vec<Vec3> = (0..n)
            .map(|_| {
                base + Vec3::new(
                    rng.range_f32(0.0, 1e-40),
                    rng.range_f32(0.0, 1e-40),
                    rng.range_f32(0.0, 1e-40),
                )
            })
            .collect();
        let radius: Vec<f32> = (0..n).map(|_| rng.range_f32(1e-42, 1e-38)).collect();
        let kind = build_kind(rng);
        let bvh = Bvh::build(&pos, &radius, kind);
        bvh.check_invariants(&pos, &radius).map_err(|e| e.to_string())?;
        assert_quantized_contains_exact(&bvh, &pos, &radius)?;
        for i in 0..n {
            let mut got = bvh.query_point_collect(pos[i], i, &pos, &radius, &mut scratch);
            got.sort_unstable();
            if got != brute(pos[i], i, &pos, &radius) {
                return Err(format!("{kind:?} underflow-extent mismatch at {i}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_refit_degraded_trees_stay_conservative() {
    // refit-degraded trees (the regime the gradient optimizer lives in)
    // requantize every node each sweep; containment and oracle equality
    // must survive arbitrarily long refit chains
    prop_check("quantized-refit-degraded", 8, |rng| {
        let n = 150 + rng.below(400);
        let (mut pos, radius) = random_scene(rng, n, 60.0);
        let kind = build_kind(rng);
        let mut bvh = Bvh::build(&pos, &radius, kind);
        for round in 0..8 {
            for p in pos.iter_mut() {
                *p += Vec3::new(
                    rng.range_f32(-3.0, 3.0),
                    rng.range_f32(-3.0, 3.0),
                    rng.range_f32(-3.0, 3.0),
                );
            }
            bvh.refit(&pos, &radius);
            bvh.check_invariants(&pos, &radius).map_err(|e| e.to_string())?;
            assert_quantized_contains_exact(&bvh, &pos, &radius)?;
            let mut scratch = QueryScratch::new();
            for i in (0..n).step_by(7) {
                let mut got = bvh.query_point_collect(pos[i], i, &pos, &radius, &mut scratch);
                got.sort_unstable();
                if got != brute(pos[i], i, &pos, &radius) {
                    return Err(format!("{kind:?} round={round} mismatch at {i}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_refit_requantizes_bit_identically() {
    // serial ≡ parallel node-for-node over the whole quantized layout
    // (anchor, scale exponents, offsets) — the assertion the level-parallel
    // refit's determinism contract rests on, re-pinned post-quantization
    prop_check("quantized-refit-parallel", 5, |rng| {
        let n = 6000 + rng.below(4000);
        let (mut pos, radius) = random_scene(rng, n, 120.0);
        let kind = build_kind(rng);
        let base = Bvh::build_with_threads(&pos, &radius, kind, 1);
        let mut serial = base.clone();
        let mut par = base;
        for _ in 0..2 {
            for p in pos.iter_mut() {
                *p += Vec3::new(
                    rng.range_f32(-2.0, 2.0),
                    rng.range_f32(-2.0, 2.0),
                    rng.range_f32(-2.0, 2.0),
                );
            }
            serial.refit_with_threads(&pos, &radius, 1);
            par.refit_with_threads(&pos, &radius, 8);
            if serial.nodes != par.nodes {
                return Err(format!("{kind:?}: parallel refit diverged from serial"));
            }
        }
        Ok(())
    });
}

/// Every kernel available on this architecture (scalar always included).
fn all_kernels() -> Vec<Kernel> {
    let mut ks = vec![Kernel::Scalar];
    #[cfg(target_arch = "x86_64")]
    ks.push(Kernel::Sse2);
    #[cfg(target_arch = "aarch64")]
    ks.push(Kernel::Neon);
    ks
}

fn random_packed_node(rng: &mut Rng) -> Bvh4Node {
    // 0..=4 used lanes (0 = the EMPTY edge pattern), mixed extents
    let k = rng.below(BVH4_WIDTH + 1);
    let mut lanes = Vec::new();
    for lane in 0..k {
        let lo = Vec3::new(
            rng.range_f32(-200.0, 200.0),
            rng.range_f32(-200.0, 200.0),
            rng.range_f32(-200.0, 200.0),
        );
        let ext = Vec3::new(
            rng.range_f32(0.0, 100.0),
            rng.range_f32(0.0, 100.0),
            rng.range_f32(0.0, 100.0),
        );
        lanes.push((Aabb::new(lo, lo + ext), lane as u32, 0u32));
    }
    Bvh4Node::pack(&lanes)
}

#[test]
fn prop_simd_kernels_equal_scalar_exhaustively() {
    // (d) of the battery: every kernel ≡ the scalar reference over random
    // edge-pattern nodes (including empty lanes / the all-empty node) and
    // the full clamped query range on one axis crossed with the endpoints
    // on the others
    prop_check("simd-equals-scalar", 40, |rng| {
        let node = random_packed_node(rng);
        let kernels = all_kernels();
        for qx in -1..=256 {
            for &(qy, qz) in &[(-1, 256), (0, 255), (128, 1), (256, -1)] {
                let qp = [qx, qy, qz];
                let want = simd::lane_mask_scalar(&node, qp);
                for &k in &kernels {
                    let got = simd::lane_mask_with(k, &node, qp);
                    if got != want {
                        return Err(format!("{k:?} qp={qp:?}: {got:#06b} != {want:#06b}"));
                    }
                }
            }
        }
        // ±inf positions (empty-lane / out-of-frame patterns) clamp into
        // the valid range; kernels must agree there too (NaN is excluded
        // by the watchdog's finite-state guarantee)
        for p in [
            Vec3::splat(f32::INFINITY),
            Vec3::splat(f32::NEG_INFINITY),
            Vec3::new(f32::INFINITY, -1e38, f32::NEG_INFINITY),
        ] {
            let qp = node.quantize_query(p);
            for a in qp {
                if !(-1..=256).contains(&a) {
                    return Err(format!("qp {qp:?} escaped the clamp range"));
                }
            }
            let want = simd::lane_mask_scalar(&node, qp);
            for &k in &kernels {
                if simd::lane_mask_with(k, &node, qp) != want {
                    return Err(format!("{k:?} diverged on p={p:?}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn kernel_selection_does_not_change_query_results() {
    // flipping the process-wide kernel (the ORCS_SIMD escape hatch / bench
    // knob) must not change hit sets or traversal stats — lane masks are
    // bit-identical, so the traversal is too
    let mut rng = Rng::new(2024);
    let (pos, radius) = random_scene(&mut rng, 800, 90.0);
    let bvh = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
    let before = simd::active_kernel();
    let mut reference: Vec<Vec<usize>> = Vec::new();
    let mut ref_stats = None;
    for k in all_kernels() {
        simd::set_kernel(k);
        let mut scratch = QueryScratch::new();
        let lists: Vec<Vec<usize>> = (0..pos.len())
            .map(|i| bvh.query_point_collect(pos[i], i, &pos, &radius, &mut scratch))
            .collect();
        let stats = scratch.take_stats();
        if reference.is_empty() {
            reference = lists;
            ref_stats = Some(stats);
        } else {
            assert_eq!(lists, reference, "kernel {k:?} changed hit sets");
            assert_eq!(Some(stats), ref_stats, "kernel {k:?} changed traversal stats");
        }
    }
    simd::set_kernel(before);
}
