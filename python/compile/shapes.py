"""Static shapes shared by the AOT pipeline and the Rust runtime.

The PJRT executables are compiled once per (chunk, K-bucket) shape; the Rust
runtime chunks particles into `CHUNK`-sized blocks and pads neighbor lists
into the smallest fitting `K_BUCKETS` entry (longer lists are split over
multiple kernel invocations and the partial forces summed).

These constants are mirrored in `rust/src/runtime/mod.rs`; change both
together.
"""

# Particles per kernel invocation (grid-tiled inside the Pallas kernel).
CHUNK = 4096

# Neighbor-slot buckets.
K_BUCKETS = (16, 64, 256)

# Pallas block sizes (particles per grid step).
BLOCK_C = 128

# Physics guards — mirror rust/src/physics/lj.rs.
R2_MIN = 1e-4

# Sentinel box length used to disable minimum-image wrapping (wall BC).
# Large enough that round(dx/box) == 0 for any real displacement, small
# enough to stay finite in f32 arithmetic.
WALL_BOX = 1e30
