//! Deterministic, seeded fault injection.
//!
//! A [`FaultPlan`] is a schedule of faults pinned to step indices. Plans
//! come from a scripted spec (`lost@6:1,squeeze@9:4096,nan@12`) or from a
//! seeded random draw (`rand:SEED:RATE`) driven by [`crate::core::rng::Rng`]
//! — the same splittable generator the scene builder uses, so a chaos run
//! is reproducible bit for bit from its seed.
//!
//! The injector is *consumed* as the run advances: each fault fires exactly
//! once at its step, which keeps checkpoint-recovery replays fault-free (a
//! replayed step boundary does not re-trigger the fault that caused the
//! recovery).

use crate::core::rng::Rng;

/// RNG fork tag for fault schedules (disjoint from scene-builder tags).
const FAULT_STREAM_TAG: u64 = 0xFA171;

/// What goes wrong.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultKind {
    /// A fleet device dies; its shards must re-bind and recover from the
    /// last checkpoint.
    DeviceLost { shard: usize },
    /// A spurious step failure: the attempt is discarded and re-run, and
    /// the wasted attempt is priced.
    Transient,
    /// The usable VRAM budget drops (e.g. a co-tenant allocates); sticky
    /// until the run ends.
    VramSqueeze { budget_bytes: u64 },
    /// One device runs slow for one step (thermal throttle); the fleet
    /// aggregate pays the straggler.
    Straggler { shard: usize, slowdown: f64 },
    /// The next integration blows up (injected divergence); exercises the
    /// numerical watchdog.
    Divergence,
}

/// One scheduled fault.
#[derive(Clone, Debug, PartialEq)]
pub struct Fault {
    /// Step index (value of `step_count` entering the step) at which the
    /// fault fires.
    pub step: u64,
    pub kind: FaultKind,
}

/// A full schedule of faults for a run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    /// The empty (fault-free) plan.
    pub fn empty() -> Self {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Parse a scripted spec: comma-separated entries of
    /// `transient@K`, `nan@K`, `lost@K:SHARD`, `squeeze@K:BYTES`,
    /// `slow@K:SHARD:FACTOR`. Returns `None` on any malformed entry.
    pub fn parse(spec: &str) -> Option<FaultPlan> {
        let mut faults = Vec::new();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let (name, rest) = entry.split_once('@')?;
            let mut parts = rest.split(':');
            let step: u64 = parts.next()?.parse().ok()?;
            let kind = match name {
                "transient" => FaultKind::Transient,
                "nan" => FaultKind::Divergence,
                "lost" => FaultKind::DeviceLost { shard: parts.next()?.parse().ok()? },
                "squeeze" => FaultKind::VramSqueeze { budget_bytes: parts.next()?.parse().ok()? },
                "slow" => FaultKind::Straggler {
                    shard: parts.next()?.parse().ok()?,
                    slowdown: parts.next()?.parse().ok()?,
                },
                _ => return None,
            };
            if parts.next().is_some() {
                return None; // trailing garbage
            }
            faults.push(Fault { step, kind });
        }
        faults.sort_by_key(|f| f.step);
        Some(FaultPlan { faults })
    }

    /// Parse either form: `rand:SEED:RATE` draws a seeded schedule over
    /// `steps` steps and `shards` shards; anything else is a scripted spec.
    pub fn from_spec(spec: &str, steps: u64, shards: usize) -> Option<FaultPlan> {
        if let Some(rest) = spec.strip_prefix("rand:") {
            let (seed, rate) = rest.split_once(':')?;
            let seed: u64 = seed.parse().ok()?;
            let rate: f64 = rate.parse().ok()?;
            return Some(FaultPlan::seeded(seed, steps, rate, shards, 2));
        }
        FaultPlan::parse(spec)
    }

    /// Draw a random schedule: each step faults with probability `rate`,
    /// the kind drawn uniformly from {transient, straggler, device-loss}
    /// with device losses capped at `max_losses` (a fleet can only shrink
    /// so far). Deterministic in `seed`.
    pub fn seeded(seed: u64, steps: u64, rate: f64, shards: usize, max_losses: usize) -> FaultPlan {
        let mut rng = Rng::new(seed).fork(FAULT_STREAM_TAG);
        let mut faults = Vec::new();
        let mut losses = 0usize;
        for step in 0..steps {
            if rng.f64() >= rate {
                continue;
            }
            let shard = rng.below(shards.max(1));
            let kind = match rng.below(3) {
                0 => FaultKind::Transient,
                1 => FaultKind::Straggler { shard, slowdown: 1.5 + 3.0 * rng.f64() },
                _ if losses < max_losses => {
                    losses += 1;
                    FaultKind::DeviceLost { shard }
                }
                _ => FaultKind::Transient,
            };
            faults.push(Fault { step, kind });
        }
        FaultPlan { faults }
    }
}

/// Consumes a [`FaultPlan`] step by step.
#[derive(Clone, Debug, Default)]
pub struct FaultInjector {
    /// Remaining faults, ascending by step.
    pending: Vec<Fault>,
}

impl FaultInjector {
    pub fn new(plan: &FaultPlan) -> Self {
        let mut pending = plan.faults.clone();
        pending.sort_by_key(|f| f.step);
        FaultInjector { pending }
    }

    /// Remove and return every fault scheduled at (or overdue by) `step`.
    /// Each fault fires exactly once.
    pub fn take(&mut self, step: u64) -> Vec<FaultKind> {
        let mut fired = Vec::new();
        self.pending.retain(|f| {
            if f.step <= step {
                fired.push(f.kind.clone());
                false
            } else {
                true
            }
        });
        fired
    }

    pub fn is_done(&self) -> bool {
        self.pending.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scripted_grammar() {
        let p = FaultPlan::parse("transient@2, lost@6:1,squeeze@9:4096,slow@3:0:4.0,nan@12")
            .expect("valid spec");
        assert_eq!(p.faults.len(), 5);
        // sorted by step
        assert_eq!(p.faults[0], Fault { step: 2, kind: FaultKind::Transient });
        assert_eq!(
            p.faults[1],
            Fault { step: 3, kind: FaultKind::Straggler { shard: 0, slowdown: 4.0 } }
        );
        assert_eq!(p.faults[2], Fault { step: 6, kind: FaultKind::DeviceLost { shard: 1 } });
        assert_eq!(
            p.faults[3],
            Fault { step: 9, kind: FaultKind::VramSqueeze { budget_bytes: 4096 } }
        );
        assert_eq!(p.faults[4], Fault { step: 12, kind: FaultKind::Divergence });
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in ["frob@2", "lost@6", "slow@3:0", "transient@x", "lost@6:1:9"] {
            assert!(FaultPlan::parse(bad).is_none(), "{bad} should not parse");
        }
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::empty());
    }

    #[test]
    fn seeded_schedule_is_deterministic_and_capped() {
        let a = FaultPlan::seeded(42, 200, 0.3, 8, 2);
        let b = FaultPlan::seeded(42, 200, 0.3, 8, 2);
        assert_eq!(a, b);
        assert!(!a.is_empty(), "30% rate over 200 steps must fire");
        let losses =
            a.faults.iter().filter(|f| matches!(f.kind, FaultKind::DeviceLost { .. })).count();
        assert!(losses <= 2, "losses capped: {losses}");
        let c = FaultPlan::seeded(43, 200, 0.3, 8, 2);
        assert_ne!(a, c, "different seed, different schedule");
    }

    #[test]
    fn from_spec_routes_rand_and_scripted() {
        let r = FaultPlan::from_spec("rand:7:0.5", 50, 4).unwrap();
        assert_eq!(r, FaultPlan::seeded(7, 50, 0.5, 4, 2));
        let s = FaultPlan::from_spec("transient@1", 50, 4).unwrap();
        assert_eq!(s.faults.len(), 1);
        assert!(FaultPlan::from_spec("rand:x:0.5", 50, 4).is_none());
    }

    #[test]
    fn injector_fires_each_fault_once() {
        let p = FaultPlan::parse("transient@2,nan@2,lost@5:0").unwrap();
        let mut inj = FaultInjector::new(&p);
        assert!(inj.take(0).is_empty());
        assert!(inj.take(1).is_empty());
        let at2 = inj.take(2);
        assert_eq!(at2.len(), 2);
        assert!(inj.take(2).is_empty(), "consumed");
        assert_eq!(inj.take(7), vec![FaultKind::DeviceLost { shard: 0 }], "overdue fires");
        assert!(inj.is_done());
    }
}
