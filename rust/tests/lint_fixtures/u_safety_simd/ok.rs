// Fixture: clean twin — the intrinsic call carries its SAFETY contract.
#[cfg(target_arch = "x86_64")]
pub fn spin_hint() {
    // SAFETY: `_mm_pause` is a scheduling hint with no memory effects, and
    // it exists on every x86_64 (SSE2 is the ABI baseline).
    unsafe { core::arch::x86_64::_mm_pause() }
}
