"""L2 correctness: the model graphs against brute-force physics and the
integration semantics the Rust coordinator expects."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model
from compile.shapes import BLOCK_C, WALL_BOX


def brute_forces(pos, rad, box_l, eps=1.0, sigma_factor=2.5, f_max=1e4):
    """O(n^2) reference over *all pairs* with interaction cutoff
    max(r_i, r_j) — mirrors rust/src/frnn/brute.rs."""
    n = len(pos)
    f = np.zeros((n, 3), np.float64)
    pe = np.zeros(n, np.float64)
    for i in range(n):
        for j in range(n):
            if i == j:
                continue
            dx = pos[i] - pos[j]
            if box_l < WALL_BOX:
                dx = dx - box_l * np.round(dx / box_l)
            r2 = float(dx @ dx)
            rc = max(rad[i], rad[j])
            if r2 >= rc * rc or r2 == 0.0:
                continue
            r2s = max(r2, 1e-4)
            sigma = (rad[i] + rad[j]) / 2 / sigma_factor
            s6 = (sigma * sigma / r2s) ** 3
            s = 24.0 * eps * (2.0 * s6 * s6 - s6) / r2s
            f[i] += np.clip(s * dx, -f_max, f_max)
            pe[i] += 4.0 * eps * (s6 * s6 - s6)
    return f, pe


@pytest.mark.parametrize("box_l", [200.0, WALL_BOX])
def test_forces_graph_matches_brute(box_l):
    """Gather neighbors into slots exactly as the Rust runtime does, then
    check the graph's output against all-pairs physics."""
    rng = np.random.default_rng(7)
    n, k = 40, 16
    real_box = 200.0
    pos = rng.uniform(0, real_box, (n, 3)).astype(np.float32)
    rad = rng.uniform(5.0, 30.0, (n,)).astype(np.float32)

    # neighbor lists: all j with |dx| < max(ri, rj), like the backends build
    c = BLOCK_C  # pad to one pallas block
    nbr_pos = np.zeros((c, k, 3), np.float32)
    nbr_rad = np.ones((c, k), np.float32)
    mask = np.zeros((c, k), np.float32)
    pos_p = np.zeros((c, 3), np.float32)
    rad_p = np.ones((c,), np.float32)
    pos_p[:n] = pos
    rad_p[:n] = rad
    for i in range(n):
        slot = 0
        for j in range(n):
            if i == j:
                continue
            dx = pos[i] - pos[j]
            if box_l < WALL_BOX:
                dx = dx - box_l * np.round(dx / box_l)
            if float(dx @ dx) < max(rad[i], rad[j]) ** 2:
                nbr_pos[i, slot] = pos[j]
                nbr_rad[i, slot] = rad[j]
                mask[i, slot] = 1.0
                slot += 1
        assert slot <= k, "test scene too dense for K"

    scal = np.array([box_l, 1.0, 2.5, 1e4], np.float32)
    force, pe = jax.jit(model.lj_forces_graph)(pos_p, nbr_pos, rad_p, nbr_rad, mask, scal)
    f_want, pe_want = brute_forces(pos, rad, box_l)
    np.testing.assert_allclose(np.asarray(force)[:n], f_want, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(pe)[:n], pe_want, rtol=1e-4, atol=1e-3)
    # padding rows untouched
    assert np.all(np.asarray(force)[n:] == 0.0)


def test_graph_and_ref_graph_agree():
    rng = np.random.default_rng(9)
    c, k = BLOCK_C, 64
    args = (
        rng.uniform(0, 1000, (c, 3)).astype(np.float32),
        rng.uniform(0, 1000, (c, k, 3)).astype(np.float32),
        rng.uniform(1, 160, (c,)).astype(np.float32),
        rng.uniform(1, 160, (c, k)).astype(np.float32),
        (rng.uniform(size=(c, k)) > 0.5).astype(np.float32),
        np.array([1000.0, 1.0, 2.5, 1e4], np.float32),
    )
    f1, p1 = jax.jit(model.lj_forces_graph)(*args)
    f2, p2 = jax.jit(model.lj_forces_graph_ref)(*args)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-5, atol=1e-4)


def test_integrate_graph_semantics():
    rng = np.random.default_rng(11)
    c = 64
    pos = rng.normal(size=(c, 3)).astype(np.float32)
    vel = rng.normal(size=(c, 3)).astype(np.float32)
    force = rng.normal(scale=100.0, size=(c, 3)).astype(np.float32)
    dt, f_max = 0.01, 5.0
    scal = np.array([dt, f_max], np.float32)
    new_pos, new_vel = jax.jit(model.integrate_graph)(pos, vel, force, scal)
    f = np.clip(force, -f_max, f_max)
    want_vel = vel + f * dt
    want_pos = pos + want_vel * dt
    np.testing.assert_allclose(np.asarray(new_vel), want_vel, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(new_pos), want_pos, rtol=1e-6)


def test_forces_graph_shapes_all_buckets():
    for k in (16, 64, 256):
        c = BLOCK_C
        z = np.zeros
        force, pe = jax.jit(model.lj_forces_graph)(
            z((c, 3), np.float32),
            z((c, k, 3), np.float32),
            np.ones((c,), np.float32),
            np.ones((c, k), np.float32),
            z((c, k), np.float32),
            np.array([1000.0, 1.0, 2.5, 1e4], np.float32),
        )
        assert force.shape == (c, 3)
        assert pe.shape == (c,)
