//! BVH construction: median split, binned-SAH and Morton (LBVH) builders,
//! parallelized across the host cores, collapsed into the 4-wide SoA node
//! layout ([`Bvh4Node`]).
//!
//! All builders produce the same binary *topology* as before (children
//! consecutive, always after the parent); the final [`Bvh`] is produced by
//! collapsing that topology into breadth-first-ordered BVH4 nodes, so refit
//! and traversal are builder-agnostic. The median builder models fast
//! hardware LBVH-style construction; binned SAH models a high-quality
//! build. The timing model charges builds by primitive count regardless of
//! kind (hardware builds are opaque), but the *query* cost difference
//! between tree qualities is real and measured.
//!
//! # Parallel construction
//!
//! Rebuilds sit on the hot path of the `gradient` update/rebuild policy, so
//! build wall time directly shapes the optimizer's cost regime (paper §i).
//! The build parallelizes in two stages, scaling with `ORCS_THREADS`:
//!
//! * **LBVH keying/sorting**: Morton codes via `parallel_map`, then a
//!   chunked parallel LSD radix sort (`radix_sort_pairs_mt`) — identical
//!   output to the serial sort (stable), so tree structure is unchanged.
//! * **Top-down splitting** (all kinds): the top of the tree is split
//!   serially until subtree ranges drop below a per-thread grain, then the
//!   subtrees build concurrently into task-local node arrays that are
//!   spliced (with index fix-up) after the join. Split decisions are
//!   identical to the serial build, so the *tree* is identical up to node
//!   array layout; traversal visits the same nodes either way.
//!
//! # BVH2 → BVH4 collapse
//!
//! [`collapse_bvh4`] turns the binary node array into the wide layout: each
//! BVH4 node's lanes are the (up to four) *grandchildren* of a binary
//! internal node — a binary child that is a leaf stays as a leaf lane; a
//! binary child that is internal contributes its two children as lanes. The
//! intermediate binary child's own box disappears (its bounds equal the
//! union of the lanes it contributed), which is exactly the memory-traffic
//! saving of wide nodes. Slots are assigned breadth-first, so children
//! always land at higher indices than their parent and every depth level is
//! one contiguous range ([`Bvh::level_starts`]) — the property the
//! level-parallel refit relies on. The collapse is deterministic, so the
//! parallel and serial builds still produce identical trees.

use super::{Bvh, Bvh4Node, BuildKind, BVH4_WIDTH, LEAF_SIZE};
use crate::core::aabb::Aabb;
use crate::core::vec3::Vec3;
use crate::parallel;

/// Number of SAH bins per axis.
const SAH_BINS: usize = 16;

/// SAH traversal/intersection cost ratio (standard ~1:1 for AABB vs sphere
/// tests on RT hardware).
const COST_TRAVERSE: f32 = 1.0;
const COST_INTERSECT: f32 = 1.0;

/// Below this primitive count a parallel build costs more than it saves.
const PARALLEL_BUILD_MIN: usize = 8192;

/// Serial top-phase depth guard against pathologically unbalanced SAH
/// splits producing O(n) serial descent.
const MAX_TOP_DEPTH: usize = 24;

/// Intermediate binary node used during construction, before the collapse
/// into [`Bvh4Node`]. Children of internal nodes are allocated
/// consecutively (`left`, `left + 1`) and always after their parent.
#[derive(Clone, Copy, Debug)]
struct BinNode {
    aabb: Aabb,
    /// Internal: index of the left child (right = left + 1).
    /// Leaf: first index into [`Bvh::prim_order`].
    left_first: u32,
    /// 0 for internal nodes; primitive count for leaves.
    count: u32,
}

impl BinNode {
    #[inline(always)]
    fn is_leaf(&self) -> bool {
        self.count > 0
    }
}

struct BuildCtx<'a> {
    centroids: &'a [Vec3],
    prim_bbs: &'a [Aabb],
    /// The slice of the global `prim_order` this context builds over.
    order: &'a mut [u32],
    /// Global index of `order[0]` — leaves store `base + local_offset`.
    base: usize,
    nodes: Vec<BinNode>,
}

const EMPTY_BIN: BinNode = BinNode { aabb: Aabb::EMPTY, left_first: 0, count: 0 };

impl Bvh {
    /// Build a fresh BVH over spheres `(pos[i], radius[i])`, parallelized
    /// over [`crate::parallel::num_threads`] workers (`ORCS_THREADS`).
    pub fn build(pos: &[Vec3], radius: &[f32], kind: BuildKind) -> Bvh {
        Self::build_with_threads(pos, radius, kind, parallel::num_threads())
    }

    /// [`Bvh::build`] with an explicit worker count.
    pub fn build_with_threads(
        pos: &[Vec3],
        radius: &[f32],
        kind: BuildKind,
        threads: usize,
    ) -> Bvh {
        Self::build_with_threads_ordered(pos, radius, kind, threads, None)
    }

    /// [`Bvh::build_with_threads`] with an optional precomputed Morton
    /// permutation of `0..n` (the per-step Z-order cache,
    /// [`crate::frnn::zorder::ZOrderCache`]). LBVH builds use it as the
    /// primitive order directly, skipping the builder's own keying + radix
    /// sort; the other kinds derive their order from splits and ignore it.
    /// Box-space keys give a marginally coarser curve than the scene-AABB
    /// normalization of the self-keying path on tightly clustered scenes,
    /// but the tree is valid for any permutation and the build/quality
    /// trade-off the ablation measures is unchanged.
    pub fn build_with_threads_ordered(
        pos: &[Vec3],
        radius: &[f32],
        kind: BuildKind,
        threads: usize,
        zorder: Option<&[u32]>,
    ) -> Bvh {
        assert_eq!(pos.len(), radius.len());
        let n = pos.len();
        if n == 0 {
            // Zero-primitive scenes are legal (empty simulation steps):
            // queries terminate immediately, refits are no-ops.
            return Bvh {
                nodes: Vec::new(),
                level_starts: vec![0],
                prim_order: Vec::new(),
                n_prims: 0,
                kind,
                refits_since_build: 0,
            };
        }
        let threads = threads.max(1);
        let mut order: Vec<u32> = (0..n as u32).collect();

        if kind == BuildKind::Lbvh {
            if let Some(z) = zorder {
                // Reuse the step's cached Z-order permutation (one sort per
                // step instead of one per phase).
                assert_eq!(z.len(), n, "zorder permutation length mismatch");
                order.copy_from_slice(z);
            } else {
                // Z-order the primitives once; range-midpoint splits below
                // then approximate morton-prefix splits (HLBVH-style).
                let bb = pos.iter().zip(radius).fold(Aabb::EMPTY, |mut a, (&p, &r)| {
                    a.grow(&Aabb::of_sphere(p, r));
                    a
                });
                let span = (bb.hi - bb.lo).max_component().max(1e-6);
                let mut keys: Vec<u32> = parallel::parallel_map(n, threads, |i| {
                    crate::frnn::gpu_cell::morton30((pos[i] - bb.lo) * (1000.0 / span), 1000.0)
                });
                crate::frnn::gpu_cell::radix_sort_pairs_mt(&mut keys, &mut order, threads);
            }
        }
        let prim_bbs: Vec<Aabb> =
            parallel::parallel_map(n, threads, |i| Aabb::of_sphere(pos[i], radius[i]));
        let centroids: Vec<Vec3> = pos.to_vec();

        let mut ctx = BuildCtx {
            centroids: &centroids,
            prim_bbs: &prim_bbs,
            order: &mut order,
            base: 0,
            nodes: Vec::with_capacity(2 * n / LEAF_SIZE + 2),
        };
        // reserve root
        ctx.nodes.push(EMPTY_BIN);

        if threads == 1 || n < PARALLEL_BUILD_MIN {
            build_range(&mut ctx, 0, 0, n, kind);
            let (nodes, level_starts) = collapse_bvh4(&ctx.nodes);
            return Bvh {
                nodes,
                level_starts,
                prim_order: order,
                n_prims: n,
                kind,
                refits_since_build: 0,
            };
        }

        // --- Parallel path: serial top split into subtree tasks ---
        let grain = (n / (threads * 4)).max(LEAF_SIZE * 8);
        let mut tasks: Vec<(usize, usize, usize)> = Vec::new(); // (node, lo, hi)
        split_top(&mut ctx, 0, 0, n, kind, grain, 0, &mut tasks);
        let mut nodes = std::mem::take(&mut ctx.nodes);
        drop(ctx);

        // Concurrent subtree builds into task-local node arrays. Each task
        // owns the disjoint `order[lo..hi]` slice.
        let mut results: Vec<Vec<BinNode>> = (0..tasks.len()).map(|_| Vec::new()).collect();
        let order_ptr = parallel::SendPtr(order.as_mut_ptr());
        let res_ptr = parallel::SendPtr(results.as_mut_ptr());
        let tasks_ref = &tasks;
        let (centroids_ref, prim_bbs_ref) = (&centroids, &prim_bbs);
        parallel::parallel_for_dynamic(tasks.len(), threads, 1, |_, range| {
            for t in range {
                let (_, lo, hi) = tasks_ref[t];
                // SAFETY: task ranges partition 0..n, so the order slices
                // are disjoint; each results slot is written exactly once.
                let sub =
                    unsafe { std::slice::from_raw_parts_mut(order_ptr.0.add(lo), hi - lo) };
                let mut sub_ctx = BuildCtx {
                    centroids: centroids_ref,
                    prim_bbs: prim_bbs_ref,
                    order: sub,
                    base: lo,
                    nodes: Vec::with_capacity(2 * (hi - lo) / LEAF_SIZE + 2),
                };
                sub_ctx.nodes.push(EMPTY_BIN);
                build_range(&mut sub_ctx, 0, 0, hi - lo, kind);
                // SAFETY: `t` values partition 0..tasks.len(), so each
                // results slot is written by exactly one worker.
                unsafe { *res_ptr.0.add(t) = sub_ctx.nodes };
            }
        });

        // Splice: task-local node 0 lands in the pre-reserved parent slot;
        // the rest append after the serial top, with child indices shifted.
        let mut base = nodes.len();
        for (t, &(node_idx, _, _)) in tasks.iter().enumerate() {
            let local = std::mem::take(&mut results[t]);
            let shift = |nd: &BinNode, b: usize| -> BinNode {
                if nd.is_leaf() {
                    *nd
                } else {
                    BinNode {
                        aabb: nd.aabb,
                        left_first: (b + nd.left_first as usize - 1) as u32,
                        count: 0,
                    }
                }
            };
            // lint:allow(P-INDEX-LIT): node 0 exists — every task pushed EMPTY_BIN
            nodes[node_idx] = shift(&local[0], base);
            for nd in &local[1..] {
                nodes.push(shift(nd, base));
            }
            base += local.len() - 1;
        }

        let (nodes4, level_starts) = collapse_bvh4(&nodes);
        Bvh {
            nodes: nodes4,
            level_starts,
            prim_order: order,
            n_prims: n,
            kind,
            refits_since_build: 0,
        }
    }
}

/// The lanes of the BVH4 node derived from binary internal node `b`: for
/// each binary child, itself when it is a leaf, otherwise its two children.
/// Returns 2–4 lane entries (binary node indices).
fn gather_lanes(bnodes: &[BinNode], b: u32) -> ([u32; BVH4_WIDTH], usize) {
    let l = bnodes[b as usize].left_first;
    let mut out = [0u32; BVH4_WIDTH];
    let mut k = 0;
    for c in [l, l + 1] {
        let cn = &bnodes[c as usize];
        if cn.is_leaf() {
            out[k] = c;
            k += 1;
        } else {
            out[k] = cn.left_first;
            out[k + 1] = cn.left_first + 1;
            k += 2;
        }
    }
    (out, k)
}

/// Collapse the binary topology into breadth-first-ordered BVH4 nodes plus
/// the per-depth level table (see module docs). Deterministic in the input
/// array, independent of thread count.
///
/// Nodes are **quantized at collapse** ([`Bvh4Node::pack`]): slots are
/// assigned top-down (BFS order), but the node array is *filled* deepest
/// level first so each parent's internal lane boxes are the **dequantized**
/// unions of its already-packed children — that makes the conservative
/// containment contract transitive through the per-node quantization
/// frames (`check_invariants` verifies it exactly, no epsilon).
fn collapse_bvh4(bnodes: &[BinNode]) -> (Vec<Bvh4Node>, Vec<u32>) {
    // lint:allow(P-INDEX-LIT): the binary builder always emits a root node
    if bnodes[0].is_leaf() {
        // whole scene fits one leaf: a single node with one leaf lane
        // lint:allow(P-INDEX-LIT): root node, guarded by the branch above
        let root = &bnodes[0];
        let node = Bvh4Node::pack(&[(root.aabb, root.left_first, root.count)]);
        return (vec![node], vec![0, 1]);
    }
    // BFS over binary internal nodes; every visited entry becomes one BVH4
    // node, slots assigned in discovery order (level by level).
    let mut slot_of = vec![u32::MAX; bnodes.len()];
    slot_of[0] = 0; // lint:allow(P-INDEX-LIT): sized from non-empty bnodes
    let mut total = 1u32;
    let mut levels: Vec<Vec<u32>> = Vec::new();
    let mut current = vec![0u32];
    while !current.is_empty() {
        let mut next = Vec::new();
        for &b in &current {
            let (lanes, k) = gather_lanes(bnodes, b);
            for &lane_bin in &lanes[..k] {
                if !bnodes[lane_bin as usize].is_leaf() {
                    slot_of[lane_bin as usize] = total;
                    total += 1;
                    next.push(lane_bin);
                }
            }
        }
        levels.push(current);
        current = next;
    }
    let mut level_starts = Vec::with_capacity(levels.len() + 1);
    level_starts.push(0u32);
    let mut acc = 0u32;
    for lv in &levels {
        acc += lv.len() as u32;
        level_starts.push(acc);
    }
    let mut nodes = vec![Bvh4Node::EMPTY; total as usize];
    // Deepest level first: internal lanes of a node in level d reference
    // nodes in level d + 1, which this order has already packed, so their
    // dequantized `lanes_union` is available (see doc comment above).
    for lv in levels.iter().rev() {
        for &b in lv {
            let slot = slot_of[b as usize] as usize;
            let (lanes, k) = gather_lanes(bnodes, b);
            let mut entries = [(Aabb::EMPTY, 0u32, 0u32); BVH4_WIDTH];
            for (lane, &lane_bin) in lanes[..k].iter().enumerate() {
                let bn = &bnodes[lane_bin as usize];
                entries[lane] = if bn.is_leaf() {
                    (bn.aabb, bn.left_first, bn.count)
                } else {
                    let c = slot_of[lane_bin as usize];
                    (nodes[c as usize].lanes_union(), c, 0)
                };
            }
            nodes[slot] = Bvh4Node::pack(&entries[..k]);
        }
    }
    (nodes, level_starts)
}

/// Bounding boxes (node + centroid) of `order[lo..hi]`.
fn range_bounds(ctx: &BuildCtx, lo: usize, hi: usize) -> (Aabb, Aabb) {
    let mut bb = Aabb::EMPTY;
    let mut cb = Aabb::EMPTY;
    for k in lo..hi {
        let p = ctx.order[k] as usize;
        bb.grow(&ctx.prim_bbs[p]);
        let c = ctx.centroids[p];
        cb.grow(&Aabb::new(c, c));
    }
    (bb, cb)
}

/// Pick the split position for `order[lo..hi]` (relative indices), with the
/// degenerate-split fallback. Shared by the serial top phase and the
/// subtree recursion so both produce identical tree structure.
fn choose_split(
    ctx: &mut BuildCtx,
    lo: usize,
    hi: usize,
    cb: &Aabb,
    bb: &Aabb,
    kind: BuildKind,
) -> usize {
    let count = hi - lo;
    let split = match kind {
        BuildKind::Median => split_median(ctx, lo, hi, cb),
        BuildKind::BinnedSah => {
            split_sah(ctx, lo, hi, cb, bb).unwrap_or_else(|| split_median(ctx, lo, hi, cb))
        }
        // order is already morton-sorted: midpoint = prefix split
        BuildKind::Lbvh => lo + count / 2,
    };
    // Degenerate split (all centroids identical): force a half split.
    if split <= lo || split >= hi {
        lo + count / 2
    } else {
        split
    }
}

/// Recursively build the subtree for `order[lo..hi]` into `nodes[node_idx]`.
/// `lo`/`hi` are relative to `ctx.order`; leaves store `ctx.base + lo`.
fn build_range(ctx: &mut BuildCtx, node_idx: usize, lo: usize, hi: usize, kind: BuildKind) {
    let count = hi - lo;
    let (bb, cb) = range_bounds(ctx, lo, hi);

    if count <= LEAF_SIZE {
        ctx.nodes[node_idx] =
            BinNode { aabb: bb, left_first: (ctx.base + lo) as u32, count: count as u32 };
        return;
    }

    let mid = choose_split(ctx, lo, hi, &cb, &bb, kind);

    let left = ctx.nodes.len();
    ctx.nodes.push(EMPTY_BIN);
    ctx.nodes.push(EMPTY_BIN);
    ctx.nodes[node_idx] = BinNode { aabb: bb, left_first: left as u32, count: 0 };
    build_range(ctx, left, lo, mid, kind);
    build_range(ctx, left + 1, mid, hi, kind);
}

/// Serial top phase of a parallel build: split exactly like [`build_range`]
/// until ranges reach the per-thread `grain` (or the depth guard), then
/// record a subtree task against the pre-reserved node slot.
#[allow(clippy::too_many_arguments)]
fn split_top(
    ctx: &mut BuildCtx,
    node_idx: usize,
    lo: usize,
    hi: usize,
    kind: BuildKind,
    grain: usize,
    depth: usize,
    tasks: &mut Vec<(usize, usize, usize)>,
) {
    let count = hi - lo;
    if count <= grain.max(LEAF_SIZE) || depth >= MAX_TOP_DEPTH {
        tasks.push((node_idx, lo, hi));
        return;
    }
    let (bb, cb) = range_bounds(ctx, lo, hi);
    let mid = choose_split(ctx, lo, hi, &cb, &bb, kind);

    let left = ctx.nodes.len();
    ctx.nodes.push(EMPTY_BIN);
    ctx.nodes.push(EMPTY_BIN);
    ctx.nodes[node_idx] = BinNode { aabb: bb, left_first: left as u32, count: 0 };
    split_top(ctx, left, lo, mid, kind, grain, depth + 1, tasks);
    split_top(ctx, left + 1, mid, hi, kind, grain, depth + 1, tasks);
}

/// Median split: partition around the median centroid on the longest axis.
fn split_median(ctx: &mut BuildCtx, lo: usize, hi: usize, cb: &Aabb) -> usize {
    let axis = cb.longest_axis();
    let mid = lo + (hi - lo) / 2;
    let (order, centroids) = (&mut *ctx.order, &ctx.centroids);
    order[lo..hi].select_nth_unstable_by(mid - lo, |&a, &b| {
        centroids[a as usize]
            .axis(axis)
            .partial_cmp(&centroids[b as usize].axis(axis))
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    mid
}

/// Binned SAH: try SAH_BINS buckets on each axis, pick the cheapest split.
/// Returns `None` when no split beats the leaf cost or bounds are degenerate.
fn split_sah(ctx: &mut BuildCtx, lo: usize, hi: usize, cb: &Aabb, bb: &Aabb) -> Option<usize> {
    let count = hi - lo;
    let ext = cb.hi - cb.lo;
    let mut best: Option<(f32, usize, usize)> = None; // (cost, axis, bin)

    for axis in 0..3 {
        let extent = ext.axis(axis);
        if extent <= 1e-6 {
            continue;
        }
        let k0 = cb.lo.axis(axis);
        let scale = SAH_BINS as f32 * (1.0 - 1e-6) / extent;

        let mut bin_bb = [Aabb::EMPTY; SAH_BINS];
        let mut bin_n = [0usize; SAH_BINS];
        for k in lo..hi {
            let p = ctx.order[k] as usize;
            let b = (((ctx.centroids[p].axis(axis) - k0) * scale) as usize).min(SAH_BINS - 1);
            bin_bb[b].grow(&ctx.prim_bbs[p]);
            bin_n[b] += 1;
        }

        // prefix/suffix sweeps
        let mut left_bb = [Aabb::EMPTY; SAH_BINS];
        let mut left_n = [0usize; SAH_BINS];
        let mut acc_bb = Aabb::EMPTY;
        let mut acc_n = 0;
        for b in 0..SAH_BINS {
            acc_bb.grow(&bin_bb[b]);
            acc_n += bin_n[b];
            left_bb[b] = acc_bb;
            left_n[b] = acc_n;
        }
        let mut acc_bb = Aabb::EMPTY;
        let mut acc_n = 0;
        for b in (1..SAH_BINS).rev() {
            acc_bb.grow(&bin_bb[b]);
            acc_n += bin_n[b];
            let nl = left_n[b - 1];
            if nl == 0 || acc_n == 0 {
                continue;
            }
            let sa = bb.surface_area().max(1e-12);
            let cost = COST_TRAVERSE
                + COST_INTERSECT
                    * (left_bb[b - 1].surface_area() * nl as f32
                        + acc_bb.surface_area() * acc_n as f32)
                    / sa;
            if best.map_or(true, |(bc, _, _)| cost < bc) {
                best = Some((cost, axis, b));
            }
        }
    }

    let (cost, axis, bin) = best?;
    // compare against leaf cost
    if cost >= COST_INTERSECT * count as f32 {
        return None;
    }
    // partition by bin
    let k0 = cb.lo.axis(axis);
    let extent = ext.axis(axis);
    let scale = SAH_BINS as f32 * (1.0 - 1e-6) / extent;
    let (order, centroids) = (&mut *ctx.order, &ctx.centroids);
    let mut i = lo;
    let mut j = hi;
    while i < j {
        let p = order[i] as usize;
        let b = (((centroids[p].axis(axis) - k0) * scale) as usize).min(SAH_BINS - 1);
        if b < bin {
            i += 1;
        } else {
            j -= 1;
            order.swap(i, j);
        }
    }
    Some(i)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Rng;

    fn scene(n: usize, seed: u64) -> (Vec<Vec3>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (
            (0..n)
                .map(|_| {
                    Vec3::new(
                        rng.range_f32(0.0, 50.0),
                        rng.range_f32(0.0, 50.0),
                        rng.range_f32(0.0, 50.0),
                    )
                })
                .collect(),
            (0..n).map(|_| rng.range_f32(0.1, 2.0)).collect(),
        )
    }

    #[test]
    fn node_count_bounds() {
        let (pos, radius) = scene(1000, 1);
        let bvh = Bvh::build(&pos, &radius, BuildKind::Median);
        // a BVH4 node holds at most BVH4_WIDTH leaf lanes of LEAF_SIZE prims
        assert!(bvh.node_count() >= 1000 / (LEAF_SIZE * BVH4_WIDTH));
        assert!(bvh.node_count() <= 1000);
    }

    #[test]
    fn identical_centroids_dont_recurse_forever() {
        let pos = vec![Vec3::splat(5.0); 50];
        let radius = vec![1.0f32; 50];
        for kind in [BuildKind::Median, BuildKind::BinnedSah] {
            let bvh = Bvh::build(&pos, &radius, kind);
            bvh.check_invariants(&pos, &radius).unwrap();
        }
    }

    #[test]
    fn sah_tree_not_worse_than_median() {
        let (pos, radius) = scene(3000, 3);
        let med = Bvh::build(&pos, &radius, BuildKind::Median);
        let sah = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
        let qm = crate::bvh::quality::sah_cost(&med);
        let qs = crate::bvh::quality::sah_cost(&sah);
        assert!(qs <= qm * 1.1, "sah={qs} median={qm}");
    }

    #[test]
    fn lbvh_builds_valid_tree() {
        let (pos, radius) = scene(2000, 5);
        let bvh = Bvh::build(&pos, &radius, BuildKind::Lbvh);
        bvh.check_invariants(&pos, &radius).unwrap();
        // quality ordering: SAH <= median <= ~LBVH (morton splits are the
        // cheapest build, roughest tree)
        let sah = crate::bvh::quality::sah_cost(&Bvh::build(&pos, &radius, BuildKind::BinnedSah));
        let lbvh = crate::bvh::quality::sah_cost(&bvh);
        assert!(sah <= lbvh * 1.05, "sah={sah} lbvh={lbvh}");
    }

    #[test]
    fn lbvh_queries_match_brute_force() {
        let (pos, radius) = scene(600, 6);
        let bvh = Bvh::build(&pos, &radius, BuildKind::Lbvh);
        let mut scratch = crate::bvh::traverse::QueryScratch::new();
        for i in (0..pos.len()).step_by(13) {
            let mut got = bvh.query_point_collect(pos[i], i, &pos, &radius, &mut scratch);
            got.sort_unstable();
            let want: Vec<usize> = (0..pos.len())
                .filter(|&j| {
                    j != i && (pos[i] - pos[j]).norm2() < radius[j] * radius[j]
                })
                .collect();
            assert_eq!(got, want, "i={i}");
        }
    }

    #[test]
    fn lbvh_with_supplied_zorder_is_valid_and_exact() {
        // a box-space Z-order permutation (the per-step cache) must yield a
        // valid tree whose queries match brute force, for serial + parallel
        let (pos, radius) = scene(PARALLEL_BUILD_MIN + 500, 7);
        let mut cache = crate::frnn::zorder::ZOrderCache::new();
        cache.compute(&pos, 50.0, 4);
        let serial =
            Bvh::build_with_threads_ordered(&pos, &radius, BuildKind::Lbvh, 1, Some(cache.order()));
        let par =
            Bvh::build_with_threads_ordered(&pos, &radius, BuildKind::Lbvh, 8, Some(cache.order()));
        serial.check_invariants(&pos, &radius).unwrap();
        assert_eq!(serial.prim_order, par.prim_order);
        assert_eq!(serial.level_starts, par.level_starts);
        let mut scratch = crate::bvh::traverse::QueryScratch::new();
        for i in (0..pos.len()).step_by(131) {
            let mut got = serial.query_point_collect(pos[i], i, &pos, &radius, &mut scratch);
            got.sort_unstable();
            let want: Vec<usize> = (0..pos.len())
                .filter(|&j| j != i && (pos[i] - pos[j]).norm2() < radius[j] * radius[j])
                .collect();
            assert_eq!(got, want, "i={i}");
        }
    }

    #[test]
    fn children_follow_parents() {
        let (pos, radius) = scene(512, 4);
        let bvh = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
        for (i, n) in bvh.nodes.iter().enumerate() {
            for lane in 0..BVH4_WIDTH {
                if n.lane_used(lane) && !n.lane_is_leaf(lane) {
                    assert!(n.child[lane] as usize > i);
                }
            }
        }
    }

    #[test]
    fn parallel_build_equals_serial_tree() {
        // Above PARALLEL_BUILD_MIN the multi-threaded path must produce a
        // tree with identical traversal behavior and invariants for every
        // build kind, and an identical primitive permutation per leaf set.
        let (pos, radius) = scene(PARALLEL_BUILD_MIN + 3000, 9);
        for kind in [BuildKind::Median, BuildKind::BinnedSah, BuildKind::Lbvh] {
            let serial = Bvh::build_with_threads(&pos, &radius, kind, 1);
            let par = Bvh::build_with_threads(&pos, &radius, kind, 8);
            par.check_invariants(&pos, &radius).unwrap();
            assert_eq!(par.n_prims, serial.n_prims);
            // same split decisions -> same primitive ordering
            assert_eq!(par.prim_order, serial.prim_order, "{kind:?}");
            assert_eq!(par.node_count(), serial.node_count(), "{kind:?}");
            assert_eq!(par.level_starts, serial.level_starts, "{kind:?}");
            // identical query results on a sample of points
            let mut s1 = crate::bvh::traverse::QueryScratch::new();
            let mut s2 = crate::bvh::traverse::QueryScratch::new();
            for i in (0..pos.len()).step_by(97) {
                let mut a = serial.query_point_collect(pos[i], i, &pos, &radius, &mut s1);
                let mut b = par.query_point_collect(pos[i], i, &pos, &radius, &mut s2);
                a.sort_unstable();
                b.sort_unstable();
                assert_eq!(a, b, "{kind:?} i={i}");
            }
        }
    }

    #[test]
    fn parallel_build_children_follow_parents() {
        let (pos, radius) = scene(PARALLEL_BUILD_MIN + 1000, 10);
        for kind in [BuildKind::Median, BuildKind::BinnedSah, BuildKind::Lbvh] {
            let bvh = Bvh::build_with_threads(&pos, &radius, kind, 6);
            for (i, n) in bvh.nodes.iter().enumerate() {
                for lane in 0..BVH4_WIDTH {
                    if n.lane_used(lane) && !n.lane_is_leaf(lane) {
                        assert!(n.child[lane] as usize > i, "{kind:?} node {i} lane {lane}");
                    }
                }
            }
        }
    }
}
