//! Sharded-scaling table (`orcs bench-sharded`): the domain-decomposition
//! study the single-device figures cannot express.
//!
//! Four parts:
//!
//! 1. **Shard-count sweep** `S ∈ {1, 2, 3}` on the paper's hardest workload
//!    (Cluster + log-normal radii, periodic BC — the RT-REF OOM column of
//!    Table 2 / Fig. 13), with per-shard rows: positions are recentered on
//!    the box center so the dense core straddles every interior shard face
//!    and divides across devices deterministically.
//! 1b. **Hot/cold policy divergence**: a slab scenario where churning
//!    shards are forced into rebuilds while static shards' gradient
//!    instances measure `Δq ≈ 0` and settle on long refit runs — the
//!    per-shard update/rebuild ratios split visibly.
//! 2. **OOM relief**: on a deliberately small device the single-domain
//!    fixed-slot list allocation (`n · k_max · 4` with `k_max → n` for
//!    log-normal clusters) exceeds VRAM, while `S = 2` sharding divides the
//!    owned count per device and completes — and the same scene runs
//!    **listless** under `--backend orcs-forces` with zero list bytes
//!    metered on any shard.
//! 3. **Heterogeneous fleet**: `S = 2` bound round-robin to TITAN RTX +
//!    L40; aggregate step time is the straggler (the Turing part), energy
//!    is the fleet sum.
//! 4. **Sharded backend matrix**: RT-REF / ORCS-forces / ORCS-persé ×
//!    `S ∈ {1, 2}` on a uniform-radius cluster — the listless backends
//!    meter zero list bytes at every grid.
//! 5. **Halo-gather scaling**: total cell-bucketed gather cost across all
//!    `S³` shards vs `S` (the old 27-shift scan was `O(n · S³)`).

use anyhow::Result;

use super::common::BenchOpts;
use crate::coordinator::metrics::fmt_ms;
use crate::coordinator::report::{results_dir, CsvWriter, TextTable};
use crate::core::config::{Boundary, ParticleDist, RadiusDist, ShardSpec, SimConfig};
use crate::frnn::ApproachKind;
use crate::physics::state::SimState;
use crate::rtcore::profile::{L40, TITANRTX};
use crate::rtcore::HwProfile;
use crate::shard::{decomp, ShardGrid, ShardedConfig, ShardedEngine, ShardedRunSummary};
use crate::telemetry::wallclock::WallTimer;

const N_DEFAULT: usize = 4_000;
const STEPS_DEFAULT: usize = 24;

/// The OOM-relief part runs at a fixed size so the `SMALL_VRAM` threshold
/// sits between the sharded and single-domain allocations regardless of
/// `--quick` / `--n` scaling.
const N_OOM: usize = 1_500;
const STEPS_OOM: usize = 4;

/// A deliberately small device: TITAN RTX rates with a 4 MB list budget,
/// so the paper's n = 1M OOM behavior reproduces at bench scale. Shared
/// with `examples/sharded_cluster.rs`.
pub static SMALL_VRAM: HwProfile = {
    let mut p = TITANRTX;
    p.name = "TITANRTX-4MB";
    p.vram_bytes = 4 * 1024 * 1024;
    p
};

/// Translate all positions so their centroid lands on the box center, then
/// wrap back into the box. Cluster scenes draw a random center; recentering
/// makes the dense core straddle every interior shard face, which (a) gives
/// the sweep a deterministic hot/cold shard split and (b) divides the
/// core's particles across devices — the per-shard OOM relief. The shift is
/// applied before the first step, so sharded and single-domain runs see the
/// identical scene.
pub fn center_positions(state: &mut SimState) {
    let n = state.n();
    if n == 0 {
        return;
    }
    let mean = state.pos.iter().fold(crate::core::vec3::Vec3::ZERO, |a, &p| a + p) / n as f32;
    let shift = crate::core::vec3::Vec3::splat(0.5 * state.box_l) - mean;
    for p in state.pos.iter_mut() {
        *p += shift;
        if state.boundary == Boundary::Periodic {
            p.x = crate::physics::boundary::wrap(p.x, state.box_l);
            p.y = crate::physics::boundary::wrap(p.y, state.box_l);
            p.z = crate::physics::boundary::wrap(p.z, state.box_l);
        } else {
            p.x = p.x.clamp(0.0, state.box_l);
            p.y = p.y.clamp(0.0, state.box_l);
            p.z = p.z.clamp(0.0, state.box_l);
        }
    }
}

fn cluster_sim(opts: &BenchOpts, n: usize) -> SimConfig {
    SimConfig {
        n,
        particle_dist: ParticleDist::Cluster,
        radius_dist: RadiusDist::LogNormal { mu: 1.0, sigma: 2.0, lo: 1.0, hi: 330.0 },
        boundary: Boundary::Periodic,
        seed: opts.seed,
        ..SimConfig::default()
    }
}

/// The hot/cold heterogeneity scenario: a non-interacting wall-BC gas
/// (radii far below any pair distance, so forces are exactly zero) where
/// only the particles in the `x ≥ 3L/4` slab move — fast and ballistic.
/// Under a 2×2×2 grid the four `x`-high shards see membership churn every
/// few steps (migration across the interior `y`/`z` faces → forced
/// rebuilds), while the four `x`-low shards are bit-static from step 2 on
/// (pure policy-scheduled refits; the measured degradation slope `Δq` is
/// exactly 0, so the per-shard gradient instances settle on "never
/// rebuild"). The movers stay well over 150 units away from the cold
/// shards (and their halos) for any plausible run length, so the contrast
/// is deterministic.
pub fn hot_cold_engine(opts: &BenchOpts, n: usize) -> anyhow::Result<ShardedEngine> {
    let sim = SimConfig {
        n,
        particle_dist: ParticleDist::Disordered,
        radius_dist: RadiusDist::Const(0.01),
        boundary: Boundary::Wall,
        seed: opts.seed,
        ..SimConfig::default()
    };
    let cfg = ShardedConfig {
        policy: "gradient".into(),
        fleet: vec![opts.hw],
        threads: opts.threads,
        check_oom: true,
        ..ShardedConfig::new(sim, ShardSpec::new(2))
    };
    let mut engine = ShardedEngine::new(cfg, opts.kernels.clone())?;
    let box_l = engine.state.box_l;
    for (i, v) in engine.state.vel.iter_mut().enumerate() {
        *v = if engine.state.pos[i].x >= 0.75 * box_l {
            // up to ~6 units of motion per axis per step at the default dt:
            // enough that several movers cross the interior y/z faces every
            // few steps, while staying far inside the x-high half over any
            // plausible run length
            crate::core::vec3::Vec3::new(
                (i % 7) as f32 - 3.0,
                (i % 5) as f32 - 2.0,
                (i % 3) as f32 - 1.0,
            ) * 2000.0
        } else {
            crate::core::vec3::Vec3::ZERO
        };
    }
    Ok(engine)
}

fn run_with(
    opts: &BenchOpts,
    sim: SimConfig,
    s: usize,
    fleet: Vec<&'static HwProfile>,
    steps: usize,
    backend: ApproachKind,
) -> Result<ShardedRunSummary> {
    let cfg = ShardedConfig {
        policy: "gradient".into(),
        fleet,
        threads: opts.threads,
        check_oom: true,
        backend,
        ..ShardedConfig::new(sim, ShardSpec::new(s))
    };
    let mut engine = ShardedEngine::new(cfg, opts.kernels.clone())?;
    center_positions(&mut engine.state);
    engine.run(steps, false)
}

fn run_sharded(
    opts: &BenchOpts,
    n: usize,
    s: usize,
    fleet: Vec<&'static HwProfile>,
    steps: usize,
) -> Result<ShardedRunSummary> {
    run_with(opts, cluster_sim(opts, n), s, fleet, steps, ApproachKind::RtRef)
}

pub fn run(opts: &BenchOpts) -> Result<()> {
    let (n, steps) = opts.size(N_DEFAULT, STEPS_DEFAULT);
    println!("== Sharded scaling: Cluster/LN/Periodic (n={n}, {steps} steps) ==\n");

    let mut csv = CsvWriter::create(
        &results_dir().join("sharded_scaling.csv"),
        &["grid", "fleet", "shard", "hw", "builds", "updates", "forced", "upd_per_build",
          "owned_avg", "ghosts_avg", "k_max", "avg_shard_ms", "agg_avg_ms", "oom"],
    )?;
    let write_summary = |csv: &mut CsvWriter, s: &ShardedRunSummary| -> Result<()> {
        for (k, t) in s.per_shard.iter().enumerate() {
            let steps = s.steps.max(1);
            csv.row(&[
                s.grid.clone(),
                s.fleet.clone(),
                k.to_string(),
                t.hw.to_string(),
                t.builds.to_string(),
                t.updates.to_string(),
                t.forced_builds.to_string(),
                format!("{:.2}", t.update_ratio()),
                format!("{:.1}", t.owned_sum as f64 / steps as f64),
                format!("{:.1}", t.ghosts_sum as f64 / steps as f64),
                t.max_k_max.to_string(),
                fmt_ms(t.total_sim_ms / steps as f64),
                fmt_ms(s.avg_sim_ms),
                s.oom.to_string(),
            ])?;
        }
        Ok(())
    };

    // --- Part 1: shard-count sweep, per-shard gradient behavior ---------
    let mut agg = TextTable::new(&["grid", "devices", "avg step ms", "migr/step", "ghosts/step"]);
    for s in [1usize, 2, 3] {
        let summary = run_sharded(opts, n, s, vec![opts.hw], steps)?;
        agg.row(vec![
            summary.grid.clone(),
            summary.per_shard.len().to_string(),
            fmt_ms(summary.avg_sim_ms),
            format!("{:.1}", summary.migrations as f64 / summary.steps.max(1) as f64),
            format!("{:.1}", summary.ghost_entries as f64 / summary.steps.max(1) as f64),
        ]);
        let mut t = TextTable::new(&[
            "shard", "owned", "ghosts", "builds", "updates", "forced", "upd/build", "k_max",
        ]);
        for (k, tot) in summary.per_shard.iter().enumerate() {
            let st = summary.steps.max(1);
            t.row(vec![
                k.to_string(),
                format!("{:.0}", tot.owned_sum as f64 / st as f64),
                format!("{:.0}", tot.ghosts_sum as f64 / st as f64),
                tot.builds.to_string(),
                tot.updates.to_string(),
                tot.forced_builds.to_string(),
                format!("{:.2}", tot.update_ratio()),
                tot.max_k_max.to_string(),
            ]);
        }
        println!("--- S = {s} ({}) — per-shard gradient policy ---", summary.grid);
        println!("{}", t.render());
        write_summary(&mut csv, &summary)?;
    }
    println!("--- aggregate (time = straggler device per step) ---");
    println!("{}", agg.render());

    // --- Part 1b: hot/cold policy divergence ----------------------------
    // The acceptance scenario for per-shard policies: under one grid, the
    // churning shards are forced into rebuilds while the static shards'
    // gradient instances measure Δq ≈ 0 and settle on long refit runs.
    let (hc_n, hc_steps) = opts.size(3_000, 12);
    // cap the horizon: past ~40 steps the fastest movers could drift into
    // the cold half and dissolve the contrast this part demonstrates
    let hc_steps = hc_steps.min(20);
    let mut hc = hot_cold_engine(opts, hc_n)?;
    let hc_summary = hc.run(hc_steps, false)?;
    let mut t = TextTable::new(&["shard", "side", "builds", "updates", "forced", "upd/build"]);
    for (k, tot) in hc_summary.per_shard.iter().enumerate() {
        t.row(vec![
            k.to_string(),
            if k % 2 == 1 { "hot" } else { "cold" }.into(),
            tot.builds.to_string(),
            tot.updates.to_string(),
            tot.forced_builds.to_string(),
            format!("{:.2}", tot.update_ratio()),
        ]);
    }
    println!("--- hot/cold slab (n={hc_n}, wall BC) — per-shard gradient ratios ---");
    println!("{}", t.render());
    write_summary(&mut csv, &hc_summary)?;

    // --- Part 2: per-shard OOM relief on a small device -----------------
    println!("--- OOM relief on {} (n={N_OOM}) ---", SMALL_VRAM.name);
    let single = run_sharded(opts, N_OOM, 1, vec![&SMALL_VRAM], STEPS_OOM)?;
    let sharded = run_sharded(opts, N_OOM, 2, vec![&SMALL_VRAM], STEPS_OOM)?;
    println!(
        "  single-domain: {} (list {} bytes vs {} VRAM)",
        if single.oom { "OOM" } else { "completed (unexpected)" },
        single.oom_bytes,
        SMALL_VRAM.vram_bytes,
    );
    let max_shard_bytes = sharded.per_shard.iter().map(|t| t.max_list_bytes).max().unwrap_or(0);
    println!(
        "  2x2x2 sharded: {} (max per-shard list {} bytes)",
        if sharded.oom { "OOM (unexpected)" } else { "completed" },
        max_shard_bytes,
    );
    write_summary(&mut csv, &single)?;
    write_summary(&mut csv, &sharded)?;
    // the same log-normal cluster, still on the tiny device, but listless:
    // ORCS-forces never allocates a neighbor list, so nothing can OOM
    let listless = run_with(
        opts,
        cluster_sim(opts, N_OOM),
        2,
        vec![&SMALL_VRAM],
        STEPS_OOM,
        ApproachKind::OrcsForces,
    )?;
    let max_listless = listless.per_shard.iter().map(|t| t.max_list_bytes).max().unwrap_or(0);
    println!(
        "  2x2x2 ORCS-forces (listless): {} (max per-shard list {} bytes)",
        if listless.oom { "OOM (unexpected)" } else { "completed" },
        max_listless,
    );
    write_summary(&mut csv, &listless)?;

    // --- Part 3: heterogeneous fleet ------------------------------------
    let fleet = run_sharded(opts, n, 2, vec![&TITANRTX, &L40], steps.min(8))?;
    println!("\n--- heterogeneous fleet: {} on S=2 ---", fleet.fleet);
    println!(
        "  avg step {} ms (straggler-gated) | energy {:.3} J | EE {:.1} int/J",
        fmt_ms(fleet.avg_sim_ms),
        fleet.total_energy_j,
        fleet.ee,
    );
    write_summary(&mut csv, &fleet)?;

    // --- Part 4: sharded backend matrix ---------------------------------
    // RT-REF / ORCS-forces / ORCS-persé × grid, on a uniform-radius cluster
    // (persé's scenario rule). The listless backends must meter zero list
    // bytes on every shard at every grid.
    let (mn, msteps) = opts.size(2_000, 8);
    let uniform = SimConfig { radius_dist: RadiusDist::Const(40.0), ..cluster_sim(opts, mn) };
    let mut t = TextTable::new(&["backend", "grid", "avg step ms", "max list B", "EE int/J"]);
    for backend in [ApproachKind::RtRef, ApproachKind::OrcsForces, ApproachKind::OrcsPerse] {
        for s in [1usize, 2] {
            let summary = run_with(opts, uniform.clone(), s, vec![opts.hw], msteps, backend)?;
            let max_bytes = summary.per_shard.iter().map(|p| p.max_list_bytes).max().unwrap_or(0);
            t.row(vec![
                backend.label().to_string(),
                summary.grid.clone(),
                fmt_ms(summary.avg_sim_ms),
                max_bytes.to_string(),
                format!("{:.1}", summary.ee),
            ]);
            write_summary(&mut csv, &summary)?;
        }
    }
    println!("\n--- sharded backend matrix (n={mn}, uniform-radius cluster) ---");
    println!("{}", t.render());

    // --- Part 5: cell-bucketed halo gather scaling ----------------------
    // The retired 27-shift gather scanned all n particles per shard: total
    // work O(n · S³). The bucketed gather touches only the cells
    // overlapping each shard's halo slab, so the total across all S³
    // shards stays near-flat as the grid refines.
    let gn = n.min(4_000);
    let mut gstate = SimState::from_config(&cluster_sim(opts, gn));
    center_positions(&mut gstate);
    let halo = gstate.r_max;
    let mut t = TextTable::new(&["grid", "shards", "ghost entries", "gather ms (all shards)"]);
    for s in [1usize, 2, 3, 4] {
        let grid = ShardGrid::new(ShardSpec::new(s), gstate.box_l);
        let owner: Vec<u32> = gstate.pos.iter().map(|&p| grid.owner_of(p) as u32).collect();
        let timer = WallTimer::start();
        let cells = decomp::halo_grid(&gstate.pos, gstate.box_l, halo);
        let mut ghosts = 0u64;
        let mut buf = Vec::new();
        for idx in 0..grid.count() {
            decomp::gather_ghosts(
                &grid,
                idx,
                &gstate.pos,
                &owner,
                halo,
                gstate.boundary,
                &cells,
                &mut buf,
            );
            ghosts += buf.len() as u64;
        }
        t.row(vec![
            format!("{s}x{s}x{s}"),
            grid.count().to_string(),
            ghosts.to_string(),
            fmt_ms(timer.elapsed_s() * 1e3),
        ]);
    }
    println!("--- cell-bucketed halo gather (n={gn}) — total cost vs S ---");
    println!("{}", t.render());

    println!("\nCSV: {}", results_dir().join("sharded_scaling.csv").display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frnn::RustKernels;
    use std::sync::Arc;

    fn opts() -> BenchOpts {
        BenchOpts {
            threads: 2,
            hw: crate::rtcore::profile::DEFAULT_GPU,
            kernels: Arc::new(RustKernels { threads: 2 }),
            quick: true,
            steps_override: None,
            n_override: None,
            seed: 0xC0FFEE,
        }
    }

    #[test]
    fn oom_relief_single_fails_sharded_completes() {
        // the acceptance scenario: log-normal cluster too wide for one
        // small device, fine once decomposed across eight
        let o = opts();
        let single = run_sharded(&o, N_OOM, 1, vec![&SMALL_VRAM], STEPS_OOM).unwrap();
        assert!(single.oom, "single-domain list must exceed {} B", SMALL_VRAM.vram_bytes);
        assert!(single.oom_bytes > SMALL_VRAM.vram_bytes);
        let sharded = run_sharded(&o, N_OOM, 2, vec![&SMALL_VRAM], STEPS_OOM).unwrap();
        assert!(!sharded.oom, "2x2x2 sharding must fit per-device");
        assert_eq!(sharded.steps, STEPS_OOM as u64);
        let max_shard = sharded.per_shard.iter().map(|t| t.max_list_bytes).max().unwrap();
        assert!(max_shard <= SMALL_VRAM.vram_bytes);
        assert!(max_shard * 2 < single.oom_bytes, "sharding must shrink the allocation");
        // the same scene listless: no list allocation exists to overflow
        let listless = run_with(
            &o,
            cluster_sim(&o, N_OOM),
            2,
            vec![&SMALL_VRAM],
            STEPS_OOM,
            ApproachKind::OrcsForces,
        )
        .unwrap();
        assert!(!listless.oom, "listless backend must never OOM");
        assert_eq!(listless.steps, STEPS_OOM as u64);
        let max_listless = listless.per_shard.iter().map(|t| t.max_list_bytes).max().unwrap();
        assert_eq!(max_listless, 0, "ORCS-forces must meter zero list bytes");
    }

    #[test]
    fn hot_and_cold_shards_diverge_in_policy_ratio() {
        // the acceptance scenario: churning (hot) shards rebuild, static
        // (cold) shards refit — per-shard gradient ratios must split
        let o = opts();
        let steps = 10usize;
        let mut e = hot_cold_engine(&o, 3_000).unwrap();
        let summary = e.run(steps, false).unwrap();
        assert!(!summary.oom);
        // shard index = x + 2(y + 2z): odd ⇒ x-high ⇒ hot side
        let mut cold_min = f64::INFINITY;
        let mut hot_min = f64::INFINITY;
        let mut hot_forced = 0u64;
        for (k, t) in summary.per_shard.iter().enumerate() {
            if k % 2 == 1 {
                hot_min = hot_min.min(t.update_ratio());
                hot_forced += t.forced_builds;
            } else {
                cold_min = cold_min.min(t.update_ratio());
                // cold shards: only the unavoidable first-step build
                assert_eq!(t.builds, 1, "cold shard {k} rebuilt: {t:?}");
                assert_eq!(t.updates, steps as u64 - 1, "cold shard {k}: {t:?}");
            }
        }
        // membership churn forced rebuilds beyond step 1 on the hot side
        // (every shard's first build is forced, so the baseline is 4)
        assert!(hot_forced > 4, "hot shards never churned (forced={hot_forced})");
        assert!(
            cold_min > hot_min,
            "expected churned hot shards below cold ratios: cold_min={cold_min} hot_min={hot_min}"
        );
    }
}
