//! Small statistics helpers used by the bench suite and reports.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation. A NaN in the input propagates to the result
/// (the mean is already NaN) instead of panicking downstream.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile via linear interpolation, `q` in [0, 100]. Empty input yields
/// 0; any NaN in the input yields NaN (total_cmp would sort NaNs to one end
/// and silently return a data value — propagating is the honest answer).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    if xs.iter().any(|x| x.is_nan()) {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// Simple linear-regression slope of y over x (the Δq estimator shape).
pub fn slope(xs: &[f64], ys: &[f64]) -> f64 {
    let n = xs.len().min(ys.len());
    if n < 2 {
        return 0.0;
    }
    let mx = mean(&xs[..n]);
    let my = mean(&ys[..n]);
    let mut num = 0.0;
    let mut den = 0.0;
    for i in 0..n {
        num += (xs[i] - mx) * (ys[i] - my);
        den += (xs[i] - mx) * (xs[i] - mx);
    }
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

/// Format a duration in ms with adaptive precision (bench tables).
pub fn fmt_ms(ms: f64) -> String {
    if ms >= 1000.0 {
        format!("{:.0}", ms)
    } else if ms >= 100.0 {
        format!("{:.1}", ms)
    } else if ms >= 1.0 {
        format!("{:.2}", ms)
    } else {
        format!("{:.3}", ms)
    }
}

/// Format big counts with SI suffixes (bench tables).
pub fn fmt_si(x: f64) -> String {
    let ax = x.abs();
    if ax >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if ax >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if ax >= 1e3 {
        format!("{:.1}k", x / 1e3)
    } else {
        format!("{:.1}", x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(mean(&xs), 3.0);
        assert!((stddev(&xs) - 1.5811388).abs() < 1e-6);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn stats_survive_nan_and_degenerate_inputs() {
        // NaN propagates instead of panicking in the sort comparator
        let with_nan = [1.0, f64::NAN, 3.0];
        assert!(percentile(&with_nan, 50.0).is_nan());
        assert!(stddev(&with_nan).is_nan());
        // degenerate shapes stay well-defined
        assert_eq!(percentile(&[], 95.0), 0.0);
        assert_eq!(percentile(&[7.5], 95.0), 7.5);
        assert_eq!(stddev(&[7.5]), 0.0);
        // infinities sort fine under total_cmp
        assert_eq!(percentile(&[f64::INFINITY, 1.0, 2.0], 0.0), 1.0);
    }

    #[test]
    fn slope_linear() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        assert!((slope(&xs, &ys) - 2.0).abs() < 1e-12);
        assert_eq!(slope(&[1.0], &[2.0]), 0.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ms(0.1234), "0.123");
        assert_eq!(fmt_ms(12.34), "12.34");
        assert_eq!(fmt_ms(123.4), "123.4");
        assert_eq!(fmt_ms(12340.0), "12340");
        assert_eq!(fmt_si(1234.0), "1.2k");
        assert_eq!(fmt_si(12_500_000.0), "12.50M");
        assert_eq!(fmt_si(3.0), "3.0");
    }
}
