//! Cross-backend neighbor/force agreement: every approach must produce the
//! same physics as the O(n²) brute-force oracle on the same scene, for all
//! boundary modes and radius distributions — including the gamma-ray
//! periodic path and the variable-radius asymmetric detection (Fig. 5).

use std::sync::Arc;

use orcs::coordinator::{Engine, EngineConfig};
use orcs::core::config::{Boundary, ParticleDist, RadiusDist, SimConfig};
use orcs::frnn::{brute, ApproachKind, RustKernels};
use orcs::physics::state::SimState;

fn scenario(
    n: usize,
    dist: ParticleDist,
    radius: RadiusDist,
    boundary: Boundary,
    seed: u64,
) -> SimConfig {
    SimConfig { n, box_l: 120.0, particle_dist: dist, radius_dist: radius, boundary, seed, ..SimConfig::default() }
}

fn reference_after_steps(cfg: &SimConfig, steps: usize) -> SimState {
    let mut s = SimState::from_config(cfg);
    for _ in 0..steps {
        s.force = brute::forces(&s);
        orcs::physics::integrator::step(&mut s);
    }
    s
}

fn engine_for(cfg: &SimConfig, approach: ApproachKind) -> Option<Engine> {
    let ec = EngineConfig {
        policy: "fixed-5".into(),
        threads: 2,
        check_oom: false,
        ..EngineConfig::new(cfg.clone(), approach)
    };
    Engine::new(ec, Arc::new(RustKernels { threads: 2 })).ok()
}

#[test]
fn all_backends_match_brute_force_over_scenario_matrix() {
    let radii = [
        RadiusDist::Const(8.0),
        RadiusDist::Uniform(2.0, 16.0),
        RadiusDist::LogNormal { mu: 0.5, sigma: 1.0, lo: 1.0, hi: 30.0 },
    ];
    for dist in ParticleDist::ALL {
        for radius in radii {
            for boundary in Boundary::ALL {
                let cfg = scenario(160, dist, radius, boundary, 99);
                let want = reference_after_steps(&cfg, 3);
                for approach in ApproachKind::ALL {
                    let Some(mut engine) = engine_for(&cfg, approach) else {
                        assert!(
                            !radius.is_uniform_radius(),
                            "{approach} refused a uniform-radius scene"
                        );
                        continue;
                    };
                    engine.run(3, false).unwrap();
                    let max_err = (0..want.n())
                        .map(|i| (engine.state.pos[i] - want.pos[i]).norm())
                        .fold(0.0f32, f32::max);
                    assert!(
                        max_err < 5e-2,
                        "{approach} diverged {max_err} on {dist:?}/{radius:?}/{boundary:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn periodic_neighbors_match_wrapped_brute_force() {
    // particles concentrated near the boundary faces stress the gamma rays
    let mut cfg = scenario(120, ParticleDist::Disordered, RadiusDist::Const(10.0), Boundary::Periodic, 7);
    cfg.box_l = 80.0;
    let mut state = SimState::from_config(&cfg);
    // push a third of the particles into a thin boundary shell
    for (k, p) in state.pos.iter_mut().enumerate() {
        if k % 3 == 0 {
            p.x = if k % 6 == 0 { 0.5 } else { 79.5 };
        }
    }
    let mut mgr = orcs::frnn::rt_common::BvhManager::new(Box::new(
        orcs::gradient::FixedKPolicy::new(4),
    ));
    let mut counts = orcs::rtcore::OpCounts::default();
    mgr.prepare(&state.pos, &state.radius, &mut counts);
    let mut scratch = orcs::bvh::traverse::QueryScratch::new();
    for i in 0..state.n() {
        let mut found = Vec::new();
        orcs::frnn::rt_common::launch_rays(
            mgr.bvh(),
            i,
            &state.pos,
            &state.radius,
            state.boundary,
            state.box_l,
            state.r_max,
            &mut scratch,
            |j, _| found.push(j),
        );
        found.sort_unstable();
        found.dedup();
        let want = brute::interaction_neighbors(i, &state.pos, &state.radius, state.boundary, state.box_l);
        assert_eq!(found, want, "particle {i}");
    }
}

#[test]
fn wall_bc_launches_no_gamma_rays() {
    let cfg = scenario(100, ParticleDist::Disordered, RadiusDist::Const(10.0), Boundary::Wall, 3);
    let mut engine = engine_for(&cfg, ApproachKind::OrcsPerse).unwrap();
    let rec = engine.step().unwrap();
    // exactly one primary ray per particle
    assert_eq!(rec.counts.rays, 100);
}

#[test]
fn periodic_bc_launches_gamma_rays_for_boundary_particles() {
    let cfg = scenario(400, ParticleDist::Disordered, RadiusDist::Const(20.0), Boundary::Periodic, 3);
    let mut engine = engine_for(&cfg, ApproachKind::OrcsPerse).unwrap();
    let rec = engine.step().unwrap();
    // r=20 in a 120 box: shell fraction 1-(1-2*20/120)^3 ~ 70%, so there
    // must be strictly more rays than particles
    assert!(rec.counts.rays > 400, "rays={}", rec.counts.rays);
    // ...but no more than 8x (primary + max 7 gammas)
    assert!(rec.counts.rays <= 8 * 400);
}
