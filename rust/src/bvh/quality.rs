//! BVH quality metrics: the SAH cost of the current tree and overlap-based
//! degradation measures. Used by tests (SAH builds beat median builds) and
//! by the benchmark reports to show how refits degrade the tree — the
//! phenomenon the `gradient` policy models as `Δq` (paper Fig. 3).

use super::Bvh;

/// Expected traversal cost under the Surface Area Heuristic:
/// `C = Ct * Σ_internal SA(n)/SA(root) + Ci * Σ_leaf SA(l)/SA(root) * count(l)`.
pub fn sah_cost(bvh: &Bvh) -> f64 {
    let root_sa = bvh.nodes[0].aabb.surface_area() as f64;
    if root_sa <= 0.0 {
        return 0.0;
    }
    let mut cost = 0.0;
    for n in &bvh.nodes {
        let sa = n.aabb.surface_area() as f64 / root_sa;
        if n.is_leaf() {
            cost += sa * n.count as f64;
        } else {
            cost += sa;
        }
    }
    cost
}

/// Sum of child-overlap surface areas normalized by the root — grows as
/// refits accumulate and sibling boxes start intersecting.
pub fn overlap_metric(bvh: &Bvh) -> f64 {
    let root_sa = bvh.nodes[0].aabb.surface_area() as f64;
    if root_sa <= 0.0 {
        return 0.0;
    }
    let mut total = 0.0;
    for n in &bvh.nodes {
        if n.is_leaf() {
            continue;
        }
        let a = bvh.nodes[n.left_first as usize].aabb;
        let b = bvh.nodes[n.left_first as usize + 1].aabb;
        let lo = a.lo.max(b.lo);
        let hi = a.hi.min(b.hi);
        let d = hi - lo;
        if d.x > 0.0 && d.y > 0.0 && d.z > 0.0 {
            total += 2.0 * (d.x * d.y + d.y * d.z + d.z * d.x) as f64 / root_sa;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bvh::BuildKind;
    use crate::core::rng::Rng;
    use crate::core::vec3::Vec3;

    #[test]
    fn refits_degrade_quality_metrics() {
        let mut rng = Rng::new(31);
        let mut pos: Vec<Vec3> = (0..1500)
            .map(|_| {
                Vec3::new(
                    rng.range_f32(0.0, 100.0),
                    rng.range_f32(0.0, 100.0),
                    rng.range_f32(0.0, 100.0),
                )
            })
            .collect();
        let radius = vec![1.5f32; 1500];
        let mut bvh = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
        let q0 = sah_cost(&bvh);
        let o0 = overlap_metric(&bvh);
        for _ in 0..12 {
            for p in pos.iter_mut() {
                *p += Vec3::new(
                    rng.range_f32(-3.0, 3.0),
                    rng.range_f32(-3.0, 3.0),
                    rng.range_f32(-3.0, 3.0),
                );
            }
            bvh.refit(&pos, &radius);
        }
        assert!(sah_cost(&bvh) > q0, "SAH cost should grow with refits");
        assert!(overlap_metric(&bvh) > o0, "overlap should grow with refits");
    }

    #[test]
    fn leaf_only_tree_cost() {
        let pos = vec![Vec3::ZERO; 2];
        let radius = vec![1.0f32; 2];
        let bvh = Bvh::build(&pos, &radius, BuildKind::Median);
        // one leaf node, sa ratio 1, two prims
        assert!((sah_cost(&bvh) - 2.0).abs() < 1e-6);
        assert_eq!(overlap_metric(&bvh), 0.0);
    }
}
