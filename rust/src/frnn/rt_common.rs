//! Shared infrastructure for the three RT-core backends: BVH lifecycle
//! management under a rebuild policy, and the parallel ray-launch loop.

use crate::bvh::traverse::{QueryScratch, TraversalStats};
use crate::bvh::{BuildKind, Bvh};
use crate::core::config::Boundary;
use crate::core::vec3::Vec3;
use crate::gradient::{BvhAction, RebuildPolicy, StepObs};
use crate::physics::state::SimState;
use crate::rtcore::{timing, HwProfile, OpCounts};

/// Owns the BVH and applies the rebuild/update policy each step.
pub struct BvhManager {
    bvh: Option<Bvh>,
    pub policy: Box<dyn RebuildPolicy>,
    pub build_kind: BuildKind,
}

impl BvhManager {
    pub fn new(policy: Box<dyn RebuildPolicy>) -> Self {
        BvhManager { bvh: None, policy, build_kind: BuildKind::BinnedSah }
    }

    /// Apply the policy's decision: build or refit the BVH for the current
    /// particle state. Returns the action taken and fills the counters.
    pub fn prepare(
        &mut self,
        pos: &[Vec3],
        radius: &[f32],
        counts: &mut OpCounts,
    ) -> BvhAction {
        let mut action = self.policy.decide();
        if self.bvh.is_none() {
            action = BvhAction::Build; // nothing to refit yet
        }
        match action {
            BvhAction::Build => {
                self.bvh = Some(Bvh::build(pos, radius, self.build_kind));
                counts.bvh_built_prims += pos.len() as u64;
            }
            BvhAction::Update => {
                self.bvh.as_mut().expect("update before first build").refit(pos, radius);
                counts.bvh_refit_prims += pos.len() as u64;
            }
        }
        action
    }

    /// Feed the policy the simulated costs of the executed step. The
    /// observation clock is the RT timing model — the reproducible
    /// substitute for the paper's NVML timers.
    pub fn observe(&mut self, action: BvhAction, counts: &OpCounts, hw: &HwProfile) {
        use crate::rtcore::power::{bvh_phase_power, BvhPhase};
        let t = timing::simulate(counts, hw);
        let op_power = bvh_phase_power(
            hw,
            if action == BvhAction::Build { BvhPhase::Build } else { BvhPhase::Refit },
        );
        let q_power = bvh_phase_power(hw, BvhPhase::Traverse);
        self.policy.observe(StepObs {
            action,
            bvh_op_time: (t.build + t.refit) * 1e3,
            query_time: t.traverse * 1e3,
            // millijoules (ms x W)
            bvh_op_energy: (t.build + t.refit) * 1e3 * op_power,
            query_energy: t.traverse * 1e3 * q_power,
        });
    }

    pub fn bvh(&self) -> &Bvh {
        self.bvh.as_ref().expect("BVH not built yet")
    }
}

/// One particle's ray set: primary origin plus gamma origins (periodic BC).
/// Visits every sphere hit by any of the rays; `visit(j, dx)` receives the
/// neighbor id and the displacement `origin - p_j` (which equals the
/// minimum-image displacement for gamma hits).
///
/// All per-ray state (traversal stack, gamma origins, stats) lives in the
/// caller-owned [`QueryScratch`]: the hot loop performs no heap
/// allocations once the scratch is warm. Batched sweeps get a per-worker
/// scratch from [`Bvh::query_batch`]; one-off callers create their own.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn launch_rays<F: FnMut(usize, Vec3)>(
    bvh: &Bvh,
    i: usize,
    pos: &[Vec3],
    radius: &[f32],
    boundary: Boundary,
    box_l: f32,
    gamma_trigger: f32,
    scratch: &mut QueryScratch,
    mut visit: F,
) {
    let p = pos[i];
    bvh.query_point(p, i, pos, radius, scratch, |j| {
        visit(j, p - pos[j]);
    });
    if boundary == Boundary::Periodic {
        // Detach the gamma buffer so the scratch can be reborrowed by the
        // gamma queries (pointer swap, no allocation).
        let mut gamma = std::mem::take(&mut scratch.gamma);
        crate::frnn::gamma::gamma_origins(p, gamma_trigger, box_l, &mut gamma);
        for &o in &gamma {
            bvh.query_point(o, i, pos, radius, scratch, |j| {
                visit(j, o - pos[j]);
            });
        }
        scratch.gamma = gamma;
    }
}

/// Fold traversal stats into the step counters.
pub fn fold_stats(counts: &mut OpCounts, stats: &TraversalStats) {
    counts.aabb_tests += stats.aabb_tests;
    counts.sphere_tests += stats.sphere_tests;
    counts.rays += stats.rays;
}

/// The gamma trigger distance for a scene (§3.3): the largest search radius
/// in the system.
pub fn gamma_trigger(state: &SimState) -> f32 {
    state.r_max
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::{Boundary, RadiusDist, SimConfig};
    use crate::frnn::brute;
    use crate::gradient::FixedKPolicy;

    fn mk_state(n: usize, boundary: Boundary, radius: RadiusDist) -> SimState {
        let cfg = SimConfig {
            n,
            boundary,
            radius_dist: radius,
            box_l: 100.0,
            ..SimConfig::default()
        };
        let mut s = SimState::from_config(&cfg);
        // shrink box positions into [0,100)
        for p in s.pos.iter_mut() {
            p.x = p.x.rem_euclid(100.0);
            p.y = p.y.rem_euclid(100.0);
            p.z = p.z.rem_euclid(100.0);
        }
        s
    }

    #[test]
    fn rays_discover_interaction_set_periodic_uniform() {
        let state = mk_state(200, Boundary::Periodic, RadiusDist::Const(8.0));
        let mut mgr = BvhManager::new(Box::new(FixedKPolicy::new(5)));
        let mut counts = OpCounts::default();
        mgr.prepare(&state.pos, &state.radius, &mut counts);
        let mut scratch = QueryScratch::new();
        for i in 0..state.n() {
            let mut found = Vec::new();
            launch_rays(
                mgr.bvh(),
                i,
                &state.pos,
                &state.radius,
                state.boundary,
                state.box_l,
                gamma_trigger(&state),
                &mut scratch,
                |j, _| found.push(j),
            );
            found.sort_unstable();
            found.dedup();
            let want = brute::interaction_neighbors(
                i,
                &state.pos,
                &state.radius,
                state.boundary,
                state.box_l,
            );
            assert_eq!(found, want, "particle {i}");
        }
        assert!(scratch.stats.rays as usize >= state.n());
    }

    #[test]
    fn gamma_displacement_equals_min_image() {
        // particle at x=1, neighbor at x=99 in a 100-box with radius 5
        let mut state = mk_state(2, Boundary::Periodic, RadiusDist::Const(5.0));
        state.pos[0] = Vec3::new(1.0, 50.0, 50.0);
        state.pos[1] = Vec3::new(99.0, 50.0, 50.0);
        state.r_max = 5.0;
        let mut mgr = BvhManager::new(Box::new(FixedKPolicy::new(5)));
        let mut counts = OpCounts::default();
        mgr.prepare(&state.pos, &state.radius, &mut counts);
        let mut scratch = QueryScratch::new();
        let mut seen = Vec::new();
        launch_rays(
            mgr.bvh(),
            0,
            &state.pos,
            &state.radius,
            state.boundary,
            state.box_l,
            5.0,
            &mut scratch,
            |j, dx| seen.push((j, dx)),
        );
        assert_eq!(seen.len(), 1);
        let (j, dx) = seen[0];
        assert_eq!(j, 1);
        // min image of (1 - 99) across 100 is +2
        assert!((dx.x - 2.0).abs() < 1e-5, "dx={dx:?}");
    }

    #[test]
    fn manager_policy_drives_rebuilds() {
        let state = mk_state(100, Boundary::Wall, RadiusDist::Const(4.0));
        let mut mgr = BvhManager::new(Box::new(FixedKPolicy::new(3)));
        let mut actions = Vec::new();
        for _ in 0..6 {
            let mut counts = OpCounts::default();
            let a = mgr.prepare(&state.pos, &state.radius, &mut counts);
            mgr.observe(a, &counts, &crate::rtcore::profile::RTXPRO);
            actions.push(a);
        }
        use BvhAction::*;
        assert_eq!(actions, vec![Build, Update, Update, Build, Update, Update]);
    }
}
