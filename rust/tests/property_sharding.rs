//! Shard-invariance properties: the sharded engine must be a *transparent*
//! decomposition — for any shard grid, any backend and any thread count,
//! forces and positions are **bitwise identical** to the single-domain
//! engine on the same backend, under both boundary modes, across
//! migrations and periodic wraps. For the ORCS backends the chain extends
//! one link further: single-domain ≡ the brute-force min-image oracle.
//!
//! Why bitwise equality is attainable at all: both engines canonicalize
//! every per-particle neighbor list to ascending global id (deduplicated),
//! and both evaluate forces/integration through the *same*
//! `PhysicsKernels` code over that CSR, so the f32 operation sequences
//! coincide exactly — not approximately.

use std::sync::Arc;

use orcs::coordinator::{Engine, EngineConfig};
use orcs::core::config::{Boundary, ParticleDist, RadiusDist, ShardSpec, SimConfig};
use orcs::core::vec3::Vec3;
use orcs::frnn::{ApproachKind, RustKernels};
use orcs::physics::state::SimState;
use orcs::shard::{ShardedConfig, ShardedEngine, ShardedRunSummary};

fn scenario(n: usize, boundary: Boundary, radius: RadiusDist, box_l: f32, seed: u64) -> SimConfig {
    SimConfig {
        n,
        box_l,
        particle_dist: ParticleDist::Disordered,
        radius_dist: radius,
        boundary,
        seed,
        ..SimConfig::default()
    }
}

/// Positions + velocities of the single-domain RT-REF engine after `steps`.
fn single_domain(cfg: &SimConfig, threads: usize, steps: usize) -> (Vec<Vec3>, Vec<Vec3>) {
    let ec = EngineConfig {
        policy: "fixed-3".into(),
        threads,
        check_oom: false,
        ..EngineConfig::new(cfg.clone(), ApproachKind::RtRef)
    };
    let mut e = Engine::new(ec, Arc::new(RustKernels { threads })).unwrap();
    e.run(steps, false).unwrap();
    (e.state.pos, e.state.vel)
}

fn sharded(cfg: &SimConfig, s: usize, threads: usize, steps: usize) -> ShardedEngine {
    let sc = ShardedConfig {
        policy: "fixed-3".into(),
        threads,
        check_oom: false,
        ..ShardedConfig::new(cfg.clone(), ShardSpec::new(s))
    };
    let mut e = ShardedEngine::new(sc, Arc::new(RustKernels { threads })).unwrap();
    e.run(steps, false).unwrap();
    e
}

/// Final (pos, vel, force) of the single-domain engine on `backend`.
fn single_backend(
    cfg: &SimConfig,
    backend: ApproachKind,
    threads: usize,
    steps: usize,
) -> (Vec<Vec3>, Vec<Vec3>, Vec<Vec3>) {
    let ec = EngineConfig {
        policy: "fixed-3".into(),
        threads,
        check_oom: false,
        ..EngineConfig::new(cfg.clone(), backend)
    };
    let mut e = Engine::new(ec, Arc::new(RustKernels { threads })).unwrap();
    e.run(steps, false).unwrap();
    (e.state.pos, e.state.vel, e.state.force)
}

fn sharded_backend(
    cfg: &SimConfig,
    backend: ApproachKind,
    s: usize,
    threads: usize,
    steps: usize,
) -> ShardedEngine {
    let sc = ShardedConfig {
        policy: "fixed-3".into(),
        threads,
        check_oom: false,
        backend,
        ..ShardedConfig::new(cfg.clone(), ShardSpec::new(s))
    };
    let mut e = ShardedEngine::new(sc, Arc::new(RustKernels { threads })).unwrap();
    e.run(steps, false).unwrap();
    e
}

/// Brute-force min-image oracle: an O(n²) pair sweep plus the explicit
/// Euler step — the physics ground truth both engines must reproduce bit
/// for bit (valid while `r_max < L/2`, where one image per pair suffices).
fn brute_trajectory(cfg: &SimConfig, steps: usize) -> (Vec<Vec3>, Vec<Vec3>, Vec<Vec3>) {
    let mut state = SimState::from_config(cfg);
    for _ in 0..steps {
        state.force = orcs::frnn::brute::forces(&state);
        orcs::physics::integrator::step(&mut state);
    }
    (state.pos, state.vel, state.force)
}

/// Run the single-domain and sharded engines on the same scene — tampered
/// identically before the first step — and assert the decomposition is
/// bitwise transparent. Returns the sharded engine and its summary for
/// extra assertions.
fn assert_transparent(
    cfg: &SimConfig,
    backend: ApproachKind,
    s: usize,
    threads: usize,
    steps: usize,
    tamper: &dyn Fn(&mut SimState),
    ctx: &str,
) -> (ShardedEngine, ShardedRunSummary) {
    let ec = EngineConfig {
        policy: "fixed-3".into(),
        threads,
        check_oom: false,
        ..EngineConfig::new(cfg.clone(), backend)
    };
    let mut single = Engine::new(ec, Arc::new(RustKernels { threads })).unwrap();
    tamper(&mut single.state);
    single.run(steps, false).unwrap();

    let sc = ShardedConfig {
        policy: "fixed-3".into(),
        threads,
        check_oom: false,
        backend,
        ..ShardedConfig::new(cfg.clone(), ShardSpec::new(s))
    };
    let mut e = ShardedEngine::new(sc, Arc::new(RustKernels { threads })).unwrap();
    tamper(&mut e.state);
    let summary = e.run(steps, false).unwrap();
    assert!(!summary.oom, "{ctx}: unexpected OOM");
    assert_eq!(summary.steps, steps as u64, "{ctx}: short run");
    assert_bits_equal(&e.state.pos, &single.state.pos, &format!("{ctx} pos"));
    assert_bits_equal(&e.state.vel, &single.state.vel, &format!("{ctx} vel"));
    assert_bits_equal(&e.state.force, &single.state.force, &format!("{ctx} force"));
    (e, summary)
}

fn assert_bits_equal(got: &[Vec3], want: &[Vec3], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for i in 0..want.len() {
        // Vec3 PartialEq is exact f32 equality; compare bits so that a
        // hypothetical -0.0 vs +0.0 discrepancy is also caught.
        let (a, b) = (got[i], want[i]);
        assert_eq!(
            (a.x.to_bits(), a.y.to_bits(), a.z.to_bits()),
            (b.x.to_bits(), b.y.to_bits(), b.z.to_bits()),
            "{ctx}: particle {i} diverged: {a:?} vs {b:?}"
        );
    }
}

#[test]
fn sharded_is_bitwise_identical_to_single_domain() {
    // the acceptance property: S ∈ {1, 2, 3} grids reproduce the unsharded
    // trajectory bit for bit, under both boundary modes, with variable
    // radii (cross-inserts) and multi-step migration
    let steps = 4;
    for boundary in Boundary::ALL {
        for radius in [RadiusDist::Const(8.0), RadiusDist::Uniform(2.0, 14.0)] {
            let cfg = scenario(220, boundary, radius, 100.0, 99);
            let (want_pos, want_vel) = single_domain(&cfg, 2, steps);
            for s in [1usize, 2, 3] {
                let e = sharded(&cfg, s, 2, steps);
                let ctx = format!("{boundary:?}/{radius:?}/S={s}");
                assert_bits_equal(&e.state.pos, &want_pos, &ctx);
                assert_bits_equal(&e.state.vel, &want_vel, &ctx);
            }
        }
    }
}

#[test]
fn sharded_is_thread_count_invariant() {
    // the chunk partitions, scans and merges are thread-count independent,
    // so any ORCS_THREADS produces the same bits as the 1-thread reference
    let cfg = scenario(300, Boundary::Periodic, RadiusDist::Uniform(2.0, 12.0), 100.0, 5);
    let (want_pos, want_vel) = single_domain(&cfg, 1, 5);
    for threads in [1usize, 3, 8] {
        let e = sharded(&cfg, 2, threads, 5);
        let ctx = format!("threads={threads}");
        assert_bits_equal(&e.state.pos, &want_pos, &ctx);
        assert_bits_equal(&e.state.vel, &want_vel, &ctx);
    }
}

#[test]
fn sharded_matches_in_large_radius_periodic_regime() {
    // r_max > box_l / 2: the single-domain path switches to the 26-image
    // dedup sweep; the sharded halo materializes the same images as ghosts
    // (an owned particle can neighbor its own shard through a wrap)
    let cfg = scenario(60, Boundary::Periodic, RadiusDist::Const(25.0), 40.0, 17);
    let (want_pos, want_vel) = single_domain(&cfg, 2, 3);
    for s in [1usize, 2] {
        let e = sharded(&cfg, s, 2, 3);
        let ctx = format!("large-radius S={s}");
        assert_bits_equal(&e.state.pos, &want_pos, &ctx);
        assert_bits_equal(&e.state.vel, &want_vel, &ctx);
    }
}

#[test]
fn migration_across_a_periodic_wrap_stays_exact() {
    // a particle rides across the box boundary: its owner must wrap from
    // the last shard back to shard 0 while the trajectory stays bitwise
    // identical to the unsharded run
    let mut cfg = scenario(64, Boundary::Periodic, RadiusDist::Const(6.0), 80.0, 23);
    cfg.particle_dist = ParticleDist::Lattice;
    let steps = 6;
    let (want_pos, _) = single_domain(&cfg, 2, steps);

    let sc = ShardedConfig {
        policy: "fixed-3".into(),
        threads: 2,
        check_oom: false,
        ..ShardedConfig::new(cfg.clone(), ShardSpec::new(2))
    };
    let mut e = ShardedEngine::new(sc, Arc::new(RustKernels { threads: 2 })).unwrap();
    // plant a tracer just inside the +x face, moving outward fast enough to
    // wrap within a couple of steps (dt = 1e-3)
    let tracer = 0usize;
    e.state.pos[tracer] = Vec3::new(79.9995, 40.0, 40.0);
    e.state.vel[tracer] = Vec3::new(0.5, 0.0, 0.0);

    // mirror the same tampering into a fresh single-domain run
    let want = {
        let ec = EngineConfig {
            policy: "fixed-3".into(),
            threads: 2,
            check_oom: false,
            ..EngineConfig::new(cfg.clone(), ApproachKind::RtRef)
        };
        let mut se = Engine::new(ec, Arc::new(RustKernels { threads: 2 })).unwrap();
        se.state.pos[tracer] = Vec3::new(79.9995, 40.0, 40.0);
        se.state.vel[tracer] = Vec3::new(0.5, 0.0, 0.0);
        se.run(steps, false).unwrap();
        se.state.pos.clone()
    };
    assert_ne!(want, want_pos, "tampering must change the trajectory");

    let mut owners = Vec::new();
    let mut migrations = 0u64;
    for _ in 0..steps {
        let rec = e.step().unwrap();
        migrations += rec.migrations;
        owners.push(e.owner(tracer));
    }
    assert_bits_equal(&e.state.pos, &want, "periodic-wrap migration");
    // the tracer started in an x-high shard (odd index) and wrapped into an
    // x-low shard (even index)
    assert_eq!(owners[0] % 2, 1, "tracer should start x-high: {owners:?}");
    assert_eq!(owners.last().unwrap() % 2, 0, "tracer should wrap to x-low: {owners:?}");
    assert!(migrations > 0, "the wrap must be metered as a migration");
}

#[test]
fn prop_random_scenes_shard_transparently() {
    // randomized sweep over distributions, radii, boundaries and shard
    // grids: the decomposition must stay bitwise transparent everywhere
    orcs::testutil::prop_check("sharding_transparent", 8, |rng| {
        let cfg = orcs::testutil::gen::small_config(rng, 40, 120);
        let s = 1 + rng.below(3); // S in {1, 2, 3}
        let steps = 2;
        let (want_pos, want_vel) = single_domain(&cfg, 2, steps);
        let e = sharded(&cfg, s, 2, steps);
        for i in 0..want_pos.len() {
            if e.state.pos[i] != want_pos[i] || e.state.vel[i] != want_vel[i] {
                return Err(format!(
                    "S={s} diverged at particle {i} ({:?} vs {:?}) on {}",
                    e.state.pos[i],
                    want_pos[i],
                    cfg.tag()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn sharded_oom_fallback_is_bitwise_transparent() {
    // ISSUE satellite: a shard tripping `check_oom` under the fallback
    // policy degrades to the listless ORCS-persé path, and the run must be
    // bitwise identical to an uncapped run of the same decomposition — the
    // switch changes metering and memory, never the canonical lists
    use orcs::resilience::{EventKind, OomPolicy, ResilienceConfig};
    use orcs::rtcore::HwProfile;
    // 16 B: any shard that finds a single neighbor overflows immediately
    static TINY_LIST: HwProfile = {
        let mut p = orcs::rtcore::profile::TITANRTX;
        p.vram_bytes = 16;
        p
    };
    let cfg = scenario(220, Boundary::Periodic, RadiusDist::Const(8.0), 100.0, 99);
    let steps = 4;
    for s in [1usize, 2] {
        for threads in [1usize, 8] {
            let ctx = format!("fallback S={s} threads={threads}");
            // reference: same decomposition, no memory limit
            let free = {
                let sc = ShardedConfig {
                    policy: "fixed-3".into(),
                    threads,
                    check_oom: false,
                    fleet: vec![&TINY_LIST],
                    ..ShardedConfig::new(cfg.clone(), ShardSpec::new(s))
                };
                let mut e = ShardedEngine::new(sc, Arc::new(RustKernels { threads })).unwrap();
                e.run(steps, false).unwrap();
                e
            };
            let sc = ShardedConfig {
                policy: "fixed-3".into(),
                threads,
                check_oom: true,
                fleet: vec![&TINY_LIST],
                resilience: ResilienceConfig {
                    on_oom: OomPolicy::Fallback,
                    ..ResilienceConfig::default()
                },
                ..ShardedConfig::new(cfg.clone(), ShardSpec::new(s))
            };
            let mut e = ShardedEngine::new(sc, Arc::new(RustKernels { threads })).unwrap();
            let summary = e.run(steps, false).unwrap();
            assert!(!summary.oom, "{ctx}: fallback must absorb the OOM");
            assert_eq!(summary.steps, steps as u64, "{ctx}");
            assert!(
                summary.events.iter().any(|ev| matches!(ev.kind, EventKind::OomFallback { .. })),
                "{ctx}: no OomFallback event: {:?}",
                summary.events
            );
            let listless: u64 = summary.per_shard.iter().map(|t| t.listless_steps).sum();
            assert!(listless > 0, "{ctx}: no shard went listless");
            assert_bits_equal(&e.state.pos, &free.state.pos, &ctx);
            assert_bits_equal(&e.state.vel, &free.state.vel, &ctx);
            assert_bits_equal(&e.state.force, &free.state.force, &ctx);
        }
    }
}

#[test]
fn per_shard_oom_relief_on_lognormal_cluster() {
    // the ISSUE acceptance criterion: a log-normal cluster that OOMs the
    // single-domain RT-REF list completes once sharded with S >= 2
    use orcs::rtcore::HwProfile;
    static TINY: HwProfile = {
        let mut p = orcs::rtcore::profile::TITANRTX;
        p.vram_bytes = 700 * 1024; // 700 KB
        p
    };
    let cfg = SimConfig {
        n: 600,
        box_l: 1000.0,
        particle_dist: ParticleDist::Cluster,
        radius_dist: RadiusDist::LogNormal { mu: 1.0, sigma: 2.0, lo: 1.0, hi: 330.0 },
        boundary: Boundary::Periodic,
        seed: 31415,
        ..SimConfig::default()
    };
    let run = |s: usize| {
        let sc = ShardedConfig {
            policy: "gradient".into(),
            threads: 2,
            check_oom: true,
            fleet: vec![&TINY],
            ..ShardedConfig::new(cfg.clone(), ShardSpec::new(s))
        };
        let mut e = ShardedEngine::new(sc, Arc::new(RustKernels { threads: 2 })).unwrap();
        orcs::benchsuite::sharded::center_positions(&mut e.state);
        e.run(3, false).unwrap()
    };
    let single = run(1);
    assert!(single.oom, "single-domain must OOM: {} bytes", single.oom_bytes);
    assert!(single.oom_bytes > TINY.vram_bytes);
    let split = run(2);
    assert!(!split.oom, "S=2 must complete (max shard {} bytes)",
        split.per_shard.iter().map(|t| t.max_list_bytes).max().unwrap_or(0));
    assert_eq!(split.steps, 3);
}

#[test]
fn sharded_orcs_backends_match_single_domain_and_brute() {
    // the tentpole acceptance: ORCS-forces and ORCS-persé as first-class
    // sharded backends — for every (S, threads, boundary) the sharded run
    // is bitwise identical to the same-backend single-domain run, which is
    // itself bitwise identical to the brute min-image oracle (pinning the
    // physics, not just the decomposition)
    let steps = 3;
    for boundary in Boundary::ALL {
        for (backend, radius) in [
            (ApproachKind::OrcsForces, RadiusDist::Uniform(2.0, 14.0)),
            (ApproachKind::OrcsForces, RadiusDist::Const(8.0)),
            (ApproachKind::OrcsPerse, RadiusDist::Const(8.0)),
        ] {
            let cfg = scenario(180, boundary, radius, 100.0, 7);
            let (bp, bv, bf) = brute_trajectory(&cfg, steps);
            let (wp, wv, wf) = single_backend(&cfg, backend, 2, steps);
            let ctx = format!("{}/{boundary:?}/{radius:?}", backend.label());
            assert_bits_equal(&wp, &bp, &format!("{ctx} single-vs-brute pos"));
            assert_bits_equal(&wv, &bv, &format!("{ctx} single-vs-brute vel"));
            assert_bits_equal(&wf, &bf, &format!("{ctx} single-vs-brute force"));
            for s in [1usize, 2, 3] {
                for threads in [1usize, 8] {
                    let e = sharded_backend(&cfg, backend, s, threads, steps);
                    let ctx = format!("{ctx} S={s} threads={threads}");
                    assert_bits_equal(&e.state.pos, &wp, &ctx);
                    assert_bits_equal(&e.state.vel, &wv, &ctx);
                    assert_bits_equal(&e.state.force, &wf, &ctx);
                }
            }
        }
    }
}

#[test]
fn sharded_orcs_backends_match_in_large_radius_regime() {
    // r_max > L/2: the 26-image periodic regime — ghosts materialize a
    // particle's own wrap images, and the listless paths must fold them
    // into the same canonical per-target sums as the single-domain engine
    let cfg = scenario(60, Boundary::Periodic, RadiusDist::Const(25.0), 40.0, 17);
    let steps = 3;
    for backend in [ApproachKind::OrcsForces, ApproachKind::OrcsPerse] {
        let (wp, wv, wf) = single_backend(&cfg, backend, 2, steps);
        for s in [1usize, 2] {
            let e = sharded_backend(&cfg, backend, s, 2, steps);
            let ctx = format!("large-radius {} S={s}", backend.label());
            assert_bits_equal(&e.state.pos, &wp, &ctx);
            assert_bits_equal(&e.state.vel, &wv, &ctx);
            assert_bits_equal(&e.state.force, &wf, &ctx);
        }
    }
}

#[test]
fn prop_random_scenes_orcs_backends_shard_transparently() {
    // randomized differential battery: sharded ORCS ≡ single-domain ORCS ≡
    // brute oracle across random distributions, grids and thread counts
    orcs::testutil::prop_check("sharding_orcs_transparent", 8, |rng| {
        let mut cfg = orcs::testutil::gen::small_config(rng, 30, 90);
        let backend = if rng.below(2) == 0 {
            ApproachKind::OrcsForces
        } else {
            // persé's scenario rule: one radius for all particles
            cfg.radius_dist = RadiusDist::Const(rng.range_f32(2.0, 12.0));
            ApproachKind::OrcsPerse
        };
        let s = 1 + rng.below(3); // S in {1, 2, 3}
        let threads = if rng.below(2) == 0 { 1 } else { 8 };
        let steps = 2;
        let (bp, bv, _) = brute_trajectory(&cfg, steps);
        let (wp, wv, _) = single_backend(&cfg, backend, threads, steps);
        let e = sharded_backend(&cfg, backend, s, threads, steps);
        for i in 0..bp.len() {
            if wp[i] != bp[i] || wv[i] != bv[i] {
                return Err(format!(
                    "single-domain {} diverged from brute at particle {i} on {}",
                    backend.label(),
                    cfg.tag()
                ));
            }
            if e.state.pos[i] != wp[i] || e.state.vel[i] != wv[i] {
                return Err(format!(
                    "S={s} threads={threads} {} diverged at particle {i} on {}",
                    backend.label(),
                    cfg.tag()
                ));
            }
        }
        Ok(())
    });
}

const ALL_BACKENDS: [ApproachKind; 3] =
    [ApproachKind::RtRef, ApproachKind::OrcsForces, ApproachKind::OrcsPerse];

#[test]
fn degenerate_shard_occupancy_stays_transparent() {
    // ISSUE satellite: empty shards, all particles crowded into one shard,
    // n < S³, and exactly-one-particle shards must neither panic nor
    // perturb bits — every backend, both boundary modes, both engines
    let noop: &dyn Fn(&mut SimState) = &|_| {};
    let crowd = |st: &mut SimState| {
        // squeeze the whole scene into [2, 20)³ — one shard of a 3×3×3
        // grid over a 90-box owns everything, 26 shards sit empty
        for p in st.pos.iter_mut() {
            *p = *p * 0.2 + Vec3::splat(2.0);
        }
    };
    let corners = |st: &mut SimState| {
        // one particle at each shard center of the 2×2×2 grid: every pair
        // interaction crosses a shard face and resolves via ghosts
        for (i, p) in st.pos.iter_mut().enumerate() {
            *p = Vec3::new(
                if i & 1 == 0 { 20.0 } else { 60.0 },
                if i & 2 == 0 { 20.0 } else { 60.0 },
                if i & 4 == 0 { 20.0 } else { 60.0 },
            );
        }
    };
    for backend in ALL_BACKENDS {
        for boundary in Boundary::ALL {
            let b = format!("{}/{boundary:?}", backend.label());
            let cfg = scenario(60, boundary, RadiusDist::Const(6.0), 90.0, 11);
            assert_transparent(&cfg, backend, 3, 2, 3, &crowd, &format!("{b} crowded"));
            // n = 5 < S³ = 27: most shards are necessarily empty
            let cfg = scenario(5, boundary, RadiusDist::Const(30.0), 90.0, 13);
            assert_transparent(&cfg, backend, 3, 2, 3, noop, &format!("{b} n<S^3"));
            // empty and singleton scenes
            for n in [0usize, 1] {
                let cfg = scenario(n, boundary, RadiusDist::Const(5.0), 60.0, 17);
                assert_transparent(&cfg, backend, 2, 2, 2, noop, &format!("{b} n={n}"));
            }
            let cfg = scenario(8, boundary, RadiusDist::Const(45.0), 80.0, 19);
            assert_transparent(&cfg, backend, 2, 2, 3, &corners, &format!("{b} one-per-shard"));
        }
    }
}

#[test]
fn migration_emptying_a_shard_mid_run_stays_transparent() {
    // ISSUE satellite: every particle owned by the x-high shards marches
    // into the x-low half mid-run — the emptied shards must keep stepping
    // (empty BVH, empty ghost set) without perturbing bits, on every
    // backend
    for backend in ALL_BACKENDS {
        let cfg = scenario(24, Boundary::Wall, RadiusDist::Const(0.5), 80.0, 29);
        let evacuate = |st: &mut SimState| {
            let dt = st.dt;
            for i in 0..st.n() {
                // radii (0.5) are far below every pair distance, so forces
                // stay exactly zero and the march is ballistic
                st.pos[i] = Vec3::new(
                    if i % 2 == 0 { 25.0 } else { 55.0 },
                    10.0 + i as f32 * 1.5,
                    30.0,
                );
                st.vel[i] = if i % 2 == 1 {
                    Vec3::new(-10.0 / dt, 0.0, 0.0) // ~10 units per step
                } else {
                    Vec3::ZERO
                };
            }
        };
        let ctx = format!("evacuate {}", backend.label());
        let (e, summary) = assert_transparent(&cfg, backend, 2, 2, 4, &evacuate, &ctx);
        assert!(summary.migrations > 0, "{ctx}: the march must be metered");
        // by the last step every mover sits below x = 40: the four x-high
        // shards (odd indices on the 2×2×2 grid) own nothing
        for i in 0..e.state.n() {
            assert_eq!(e.owner(i) % 2, 0, "{ctx}: particle {i} still x-high");
        }
    }
}

#[test]
fn lognormal_cluster_runs_listless_when_sharded() {
    // ISSUE acceptance: the log-normal cluster that OOMs the single-domain
    // RT-REF list completes *listless* at S = 2 under `--backend
    // orcs-forces`, with no neighbor-list allocation metered on any shard —
    // and still matches a memory-unconstrained run of the same scene
    use orcs::rtcore::HwProfile;
    static TINY: HwProfile = {
        let mut p = orcs::rtcore::profile::TITANRTX;
        p.vram_bytes = 700 * 1024; // 700 KB: OOMs the RT-REF list at S = 1
        p
    };
    let cfg = SimConfig {
        n: 600,
        box_l: 1000.0,
        particle_dist: ParticleDist::Cluster,
        radius_dist: RadiusDist::LogNormal { mu: 1.0, sigma: 2.0, lo: 1.0, hi: 330.0 },
        boundary: Boundary::Periodic,
        seed: 31415,
        ..SimConfig::default()
    };
    let run = |check_oom: bool| {
        let sc = ShardedConfig {
            policy: "gradient".into(),
            threads: 2,
            check_oom,
            fleet: vec![&TINY],
            backend: ApproachKind::OrcsForces,
            ..ShardedConfig::new(cfg.clone(), ShardSpec::new(2))
        };
        let mut e = ShardedEngine::new(sc, Arc::new(RustKernels { threads: 2 })).unwrap();
        orcs::benchsuite::sharded::center_positions(&mut e.state);
        let summary = e.run(3, false).unwrap();
        (e, summary)
    };
    let (e, summary) = run(true);
    assert!(!summary.oom, "listless backend must never trip the OOM check");
    assert_eq!(summary.steps, 3);
    for (k, t) in summary.per_shard.iter().enumerate() {
        assert_eq!(t.max_list_bytes, 0, "shard {k} allocated a neighbor list");
        assert_eq!(t.listless_steps, 3, "shard {k} left the listless path");
    }
    let (free, _) = run(false);
    assert_bits_equal(&e.state.pos, &free.state.pos, "listless cluster pos");
    assert_bits_equal(&e.state.vel, &free.state.vel, "listless cluster vel");
    assert_bits_equal(&e.state.force, &free.state.force, "listless cluster force");
}
