"""AOT pipeline: lower the L2 graphs to HLO *text* artifacts.

Interchange format is HLO text, not serialized ``HloModuleProto``: jax >= 0.5
emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (see `shapes.py` for the constants):

    lj_forces_c{CHUNK}_k{K}.hlo.txt   for K in K_BUCKETS
    lj_forces_ref_c{CHUNK}_k64.hlo.txt   (runtime cross-check)
    integrate_c{CHUNK}.hlo.txt
    manifest.txt

Usage: ``python -m compile.aot --out ../artifacts``
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .shapes import CHUNK, K_BUCKETS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_lj_forces(c: int, k: int, fn=model.lj_forces_graph) -> str:
    args = (
        f32((c, 3)),       # pos
        f32((c, k, 3)),    # nbr_pos
        f32((c,)),         # rad
        f32((c, k)),       # nbr_rad
        f32((c, k)),       # mask
        f32((4,)),         # (box_l, eps, sigma_factor, f_max)
    )
    return to_hlo_text(jax.jit(fn).lower(*args))


def lower_integrate(c: int) -> str:
    args = (f32((c, 3)), f32((c, 3)), f32((c, 3)), f32((2,)))
    return to_hlo_text(jax.jit(model.integrate_graph).lower(*args))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--chunk", type=int, default=CHUNK)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    c = args.chunk

    manifest = []

    for k in K_BUCKETS:
        name = f"lj_forces_c{c}_k{k}.hlo.txt"
        text = lower_lj_forces(c, k)
        with open(os.path.join(args.out, name), "w") as f:
            f.write(text)
        manifest.append(f"{name} inputs=pos({c},3),nbr_pos({c},{k},3),rad({c},),"
                        f"nbr_rad({c},{k}),mask({c},{k}),scal(4,) outputs=force({c},3),pe({c},)")
        print(f"wrote {name} ({len(text)} chars)")

    # pure-jnp variant of the K=64 bucket, for the runtime cross-check test
    name = f"lj_forces_ref_c{c}_k64.hlo.txt"
    text = lower_lj_forces(c, 64, fn=model.lj_forces_graph_ref)
    with open(os.path.join(args.out, name), "w") as f:
        f.write(text)
    manifest.append(f"{name} (jnp reference of k=64 bucket)")
    print(f"wrote {name} ({len(text)} chars)")

    name = f"integrate_c{c}.hlo.txt"
    text = lower_integrate(c)
    with open(os.path.join(args.out, name), "w") as f:
        f.write(text)
    manifest.append(f"{name} inputs=pos({c},3),vel({c},3),force({c},3),scal(2,) "
                    f"outputs=new_pos({c},3),new_vel({c},3)")
    print(f"wrote {name} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
