//! Contribution #1 demo — the `gradient` BVH update/rebuild optimizer on a
//! scenario whose dynamics change over time (collapse → relaxation): a
//! miniature of the paper's Fig. 8.
//!
//! ```sh
//! cargo run --release --example bvh_policy_demo
//! ```

use std::sync::Arc;

use orcs::coordinator::{Engine, EngineConfig};
use orcs::core::config::{Boundary, ParticleDist, RadiusDist, SimConfig};
use orcs::frnn::{ApproachKind, RustKernels};
use orcs::gradient::BvhAction;

fn main() -> anyhow::Result<()> {
    let sim = SimConfig {
        n: 4_000,
        box_l: 400.0,
        particle_dist: ParticleDist::Cluster, // collapses, then relaxes
        radius_dist: RadiusDist::Const(10.0),
        boundary: Boundary::Periodic,
        dt: 3e-3,
        seed: 7,
        ..SimConfig::default()
    };
    let steps = 150;

    println!("BVH policy comparison on a cluster with changing dynamics");
    println!("(n={}, {} steps, RT-REF pipeline, simulated RT cost)\n", sim.n, steps);

    let mut rows = Vec::new();
    for policy in ["gradient", "fixed-200", "fixed-10", "avg"] {
        let ec = EngineConfig {
            policy: policy.into(),
            threads: orcs::parallel::num_threads(),
            check_oom: false,
            ..EngineConfig::new(sim.clone(), ApproachKind::RtRef)
        };
        let mut engine = Engine::new(ec, Arc::new(RustKernels { threads: 1 }))?;
        let summary = engine.run(steps, true)?;
        let rebuild_steps: Vec<u64> = summary
            .records
            .iter()
            .filter(|r| r.bvh_action == Some(BvhAction::Build))
            .map(|r| r.step)
            .collect();
        let intervals: Vec<u64> = rebuild_steps.windows(2).map(|w| w[1] - w[0]).collect();
        println!(
            "{policy:<10} total RT {:>9.3} ms | {:>3} rebuilds | intervals {}",
            summary.total_rt_ms,
            rebuild_steps.len(),
            if intervals.is_empty() {
                "-".to_string()
            } else {
                format!(
                    "min {} max {} (adaptive policies vary them)",
                    intervals.iter().min().unwrap(),
                    intervals.iter().max().unwrap()
                )
            }
        );
        rows.push((policy, summary.total_rt_ms));
    }

    let (best_ref, best_ms) = rows[1..]
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .copied()
        .unwrap();
    println!(
        "\ngradient vs best reference ({best_ref}): {:.2}x",
        best_ms / rows[0].1
    );
    Ok(())
}
