//! Contribution #3 demo — ray-traced periodic boundary conditions.
//!
//! Validates that the gamma-ray scheme discovers exactly the minimum-image
//! neighbor set (vs. the O(n²) oracle), then measures its overhead against
//! wall BC on the same scene: the paper's claim is "no significant
//! penalty".
//!
//! ```sh
//! cargo run --release --example periodic_bc
//! ```

use std::sync::Arc;

use orcs::coordinator::{Engine, EngineConfig};
use orcs::core::config::{Boundary, ParticleDist, RadiusDist, SimConfig};
use orcs::frnn::{brute, rt_common, ApproachKind, RustKernels};
use orcs::physics::state::SimState;

fn main() -> anyhow::Result<()> {
    // --- Part 1: exactness of the gamma-ray neighbor discovery ---
    let cfg = SimConfig {
        n: 3_000,
        box_l: 300.0,
        particle_dist: ParticleDist::Disordered,
        radius_dist: RadiusDist::Uniform(5.0, 30.0),
        boundary: Boundary::Periodic,
        seed: 2024,
        ..SimConfig::default()
    };
    let state = SimState::from_config(&cfg);
    let mut mgr =
        rt_common::BvhManager::new(Box::new(orcs::gradient::GradientPolicy::new()));
    let mut counts = orcs::rtcore::OpCounts::default();
    mgr.prepare(&state.pos, &state.radius, &mut counts);

    // A single particle's rays discover its *detection* set {j : |d| < r_j}
    // (paper Fig. 5 — detection is asymmetric under variable radii). The
    // pipelines complete the *interaction* set {j : |d| < max(r_i, r_j)}
    // with the reverse edges (cross-inserts / the handler rule), so the
    // completeness property to check is: rays(i) ∪ {j : i ∈ rays(j)} must
    // equal the minimum-image interaction set, for every particle.
    let mut scratch = orcs::bvh::traverse::QueryScratch::new();
    let mut detected: Vec<Vec<usize>> = vec![Vec::new(); state.n()];
    let mut boundary_particles = 0usize;
    for i in 0..state.n() {
        rt_common::launch_rays(
            mgr.bvh(),
            i,
            &state.pos,
            &state.radius,
            state.boundary,
            state.box_l,
            state.r_max,
            &mut scratch,
            |j, _| detected[i].push(j),
        );
        if orcs::frnn::gamma::gamma_count(state.pos[i], state.r_max, state.box_l) > 0 {
            boundary_particles += 1;
        }
    }
    // union with reverse edges (what the pipelines' scatter rules provide)
    let mut full: Vec<Vec<usize>> = detected.clone();
    for i in 0..state.n() {
        for &j in &detected[i] {
            full[j].push(i);
        }
    }
    let mut mismatches = 0usize;
    for i in 0..state.n() {
        full[i].sort_unstable();
        full[i].dedup();
        let want = brute::interaction_neighbors(
            i,
            &state.pos,
            &state.radius,
            state.boundary,
            state.box_l,
        );
        if full[i] != want {
            mismatches += 1;
        }
    }
    println!("gamma-ray neighbor discovery vs minimum-image brute force:");
    println!("  particles            : {}", state.n());
    println!("  boundary particles   : {boundary_particles} (launch gamma rays)");
    println!("  rays launched        : {} (primary {} + gamma {})",
        scratch.stats.rays, state.n(), scratch.stats.rays as usize - state.n());
    println!("  mismatches           : {mismatches}  <- must be 0");
    assert_eq!(mismatches, 0, "gamma rays missed neighbors");

    // --- Part 2: overhead of periodic vs wall BC (paper: insignificant) ---
    println!("\nper-step simulated cost, ORCS-forces (same scene, both BCs):");
    let mut results = Vec::new();
    for boundary in [Boundary::Wall, Boundary::Periodic] {
        let sim = SimConfig { boundary, ..cfg.clone() };
        let ec = EngineConfig {
            threads: orcs::parallel::num_threads(),
            ..EngineConfig::new(sim, ApproachKind::OrcsForces)
        };
        let mut engine = Engine::new(ec, Arc::new(RustKernels { threads: 1 }))?;
        let summary = engine.run(30, false)?;
        println!("  {boundary:<9} : {:.4} ms/step  ({} interactions total)",
            summary.avg_sim_ms, summary.total_interactions);
        results.push(summary.avg_sim_ms);
    }
    let penalty = results[1] / results[0];
    println!("  periodic/wall ratio : {penalty:.3}x (interaction sets differ; paper: no significant penalty)");
    Ok(())
}
