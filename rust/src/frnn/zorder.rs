//! Per-step Z-order (Morton) cache — one keying + one sort per step,
//! shared by every consumer (ROADMAP item "reuse per-step Morton keys").
//!
//! Three places used to compute the *same* 30-bit Morton permutation of the
//! current particle positions independently, each with its own radix sort:
//!
//! * [`Bvh::query_batch_ordered`] — the RTNN-style coherent query schedule
//!   (re-keyed and re-sorted every step by every RT backend);
//! * LBVH builds — Z-order the primitives before midpoint splitting;
//! * GPU-CELL — the pipeline's explicit Z-order sort phase.
//!
//! [`ZOrderCache`] computes the keys and the sorted permutation once per
//! step into reusable buffers; the RT backends hand the permutation to both
//! the BVH build ([`crate::bvh::Bvh::build_with_threads_ordered`]) and the
//! query sweep ([`Bvh::query_batch_with_order`]), collapsing the previous
//! two sorts per RT step into one. GPU-CELL routes its (priced) sort phase
//! through the same cache, so all Morton machinery lives in one place.
//!
//! Determinism: keying is pure per-index and the sort is the
//! thread-count-independent [`radix_sort_pairs_mt`], so the permutation is
//! bit-identical across `ORCS_THREADS` settings — every chunk-ordered merge
//! scheduled by it stays bitwise deterministic.
//!
//! [`Bvh::query_batch_ordered`]: crate::bvh::Bvh::query_batch_ordered
//! [`Bvh::query_batch_with_order`]: crate::bvh::Bvh::query_batch_with_order
//! [`Bvh::build_with_threads_ordered`]: crate::bvh::Bvh::build_with_threads_ordered

use crate::core::vec3::Vec3;
use crate::frnn::gpu_cell::{morton30, radix_sort_pairs_mt};

/// Reusable per-step Morton keys + sorted query permutation.
#[derive(Default)]
pub struct ZOrderCache {
    /// Morton keys, sorted ascending after [`ZOrderCache::compute`]
    /// (parallel to `order`).
    keys: Vec<u32>,
    /// Particle ids permuted into Z-order.
    order: Vec<u32>,
}

impl ZOrderCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Recompute keys and the sorted permutation for the current positions.
    /// Buffers are reused across steps — no steady-state allocation, and the
    /// keys are written straight into spare capacity (no dead zero-fill
    /// before the parallel pass overwrites every slot).
    pub fn compute(&mut self, pos: &[Vec3], box_l: f32, threads: usize) {
        let n = pos.len();
        let scale = if box_l > 0.0 { box_l } else { 1.0 };
        self.keys.clear();
        self.keys.reserve(n);
        {
            let keys_ptr =
                crate::parallel::SendPtr(self.keys.spare_capacity_mut().as_mut_ptr() as *mut u32);
            crate::parallel::parallel_for_chunks(n, threads, |_, range| {
                for i in range {
                    // SAFETY: chunks are disjoint; each key written once, so
                    // every slot in 0..n is initialized exactly once.
                    unsafe { keys_ptr.0.add(i).write(morton30(pos[i], scale)) };
                }
            });
        }
        // SAFETY: the parallel pass initialized every slot in 0..n.
        unsafe { self.keys.set_len(n) };
        self.order.clear();
        self.order.extend(0..n as u32);
        radix_sort_pairs_mt(&mut self.keys, &mut self.order, threads);
    }

    /// The Z-order permutation of the last [`ZOrderCache::compute`].
    pub fn order(&self) -> &[u32] {
        &self.order
    }

    /// The sorted Morton keys of the last [`ZOrderCache::compute`].
    pub fn keys(&self) -> &[u32] {
        &self.keys
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Rng;

    fn scene(n: usize, seed: u64) -> Vec<Vec3> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                Vec3::new(
                    rng.range_f32(0.0, 100.0),
                    rng.range_f32(0.0, 100.0),
                    rng.range_f32(0.0, 100.0),
                )
            })
            .collect()
    }

    #[test]
    fn cache_matches_direct_key_sort_for_any_thread_count() {
        let pos = scene(3000, 41);
        let mut want_keys: Vec<u32> = pos.iter().map(|&p| morton30(p, 100.0)).collect();
        let mut want_order: Vec<u32> = (0..3000).collect();
        crate::frnn::gpu_cell::radix_sort_pairs(&mut want_keys, &mut want_order);
        let mut cache = ZOrderCache::new();
        for threads in [1, 3, 8] {
            cache.compute(&pos, 100.0, threads);
            assert_eq!(cache.keys(), &want_keys[..], "threads={threads}");
            assert_eq!(cache.order(), &want_order[..], "threads={threads}");
        }
    }

    #[test]
    fn cache_reuses_buffers_across_steps() {
        let mut cache = ZOrderCache::new();
        let pos = scene(500, 42);
        cache.compute(&pos, 100.0, 2);
        assert_eq!(cache.order().len(), 500);
        // shrink: a smaller step must not carry stale tail entries
        cache.compute(&pos[..100], 100.0, 2);
        assert_eq!(cache.order().len(), 100);
        assert!(cache.keys().windows(2).all(|w| w[0] <= w[1]));
        // empty scenes are legal
        cache.compute(&[], 100.0, 2);
        assert!(cache.order().is_empty());
    }
}
