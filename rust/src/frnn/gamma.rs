//! Contribution #3 — ray-traced periodic boundary conditions.
//!
//! A ray launched at a boundary-adjacent particle cannot see neighbors on
//! the opposite side of the box. Instead of replicating geometry, the paper
//! launches extra "gamma" rays with box-offset origins: one per crossed
//! face, plus the edge/corner combinations (Fig. 6 — `p_14` launches
//! `γ_x, γ_y, γ_xy`). With variable radii the trigger distance must be the
//! *largest radius in the system* so that a large sphere on the opposite
//! wall is still discovered (the Fig. 5 asymmetric case across a wall).

use crate::core::vec3::Vec3;

/// Compute the gamma-ray origins for particle position `p`.
///
/// `trigger` is the boundary proximity that fires a gamma ray: the common
/// radius for uniform scenes, `r_max` for variable radii (§3.3). Origins
/// (excluding the primary) are appended to `out` (cleared first).
/// At most 7 origins are produced (3 faces + 3 edges + 1 corner).
pub fn gamma_origins(p: Vec3, trigger: f32, box_l: f32, out: &mut Vec<Vec3>) {
    out.clear();
    // Per-axis shift that moves the origin next to the opposite wall, or 0.
    let shift_axis = |x: f32| -> f32 {
        if x < trigger {
            box_l
        } else if x > box_l - trigger {
            -box_l
        } else {
            0.0
        }
    };
    let sx = shift_axis(p.x);
    let sy = shift_axis(p.y);
    let sz = shift_axis(p.z);
    if sx == 0.0 && sy == 0.0 && sz == 0.0 {
        return;
    }
    // All non-empty subsets of the active axes.
    for mask in 1u8..8 {
        let dx = if mask & 1 != 0 { sx } else { 0.0 };
        let dy = if mask & 2 != 0 { sy } else { 0.0 };
        let dz = if mask & 4 != 0 { sz } else { 0.0 };
        if (mask & 1 != 0 && sx == 0.0)
            || (mask & 2 != 0 && sy == 0.0)
            || (mask & 4 != 0 && sz == 0.0)
        {
            continue; // subset includes an inactive axis -> duplicate
        }
        out.push(p + Vec3::new(dx, dy, dz));
    }
}

/// Number of gamma rays a particle at `p` will launch (diagnostic).
pub fn gamma_count(p: Vec3, trigger: f32, box_l: f32) -> usize {
    let active = [p.x, p.y, p.z]
        .iter()
        .filter(|&&x| x < trigger || x > box_l - trigger)
        .count() as u32;
    (1usize << active) - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interior_particle_launches_none() {
        let mut out = Vec::new();
        gamma_origins(Vec3::splat(500.0), 10.0, 1000.0, &mut out);
        assert!(out.is_empty());
        assert_eq!(gamma_count(Vec3::splat(500.0), 10.0, 1000.0), 0);
    }

    #[test]
    fn face_particle_launches_one() {
        let mut out = Vec::new();
        gamma_origins(Vec3::new(2.0, 500.0, 500.0), 10.0, 1000.0, &mut out);
        assert_eq!(out, vec![Vec3::new(1002.0, 500.0, 500.0)]);
    }

    #[test]
    fn corner_particle_launches_seven() {
        let mut out = Vec::new();
        let p = Vec3::new(1.0, 999.0, 2.0);
        gamma_origins(p, 10.0, 1000.0, &mut out);
        assert_eq!(out.len(), 7);
        assert_eq!(gamma_count(p, 10.0, 1000.0), 7);
        // all origins distinct and distinct from primary
        for (a, &oa) in out.iter().enumerate() {
            assert_ne!(oa, p);
            for &ob in &out[a + 1..] {
                assert_ne!(oa, ob);
            }
        }
        // the xy-combination exists (paper's gamma_{14_{x,y}})
        assert!(out.contains(&Vec3::new(1001.0, -1.0, 2.0)));
    }

    #[test]
    fn edge_particle_launches_three() {
        let p = Vec3::new(5.0, 5.0, 500.0);
        let mut out = Vec::new();
        gamma_origins(p, 10.0, 1000.0, &mut out);
        assert_eq!(out.len(), 3); // gamma_x, gamma_y, gamma_xy
        assert_eq!(gamma_count(p, 10.0, 1000.0), 3);
    }

    #[test]
    fn trigger_respects_both_walls() {
        let mut out = Vec::new();
        gamma_origins(Vec3::new(995.0, 500.0, 500.0), 10.0, 1000.0, &mut out);
        assert_eq!(out, vec![Vec3::new(-5.0, 500.0, 500.0)]);
    }
}
