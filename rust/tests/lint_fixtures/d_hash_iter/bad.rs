// Fixture: seeded D-HASH-ITER violation (hash-order iteration).
use std::collections::HashMap;

pub fn sum_values(map: &HashMap<u64, u32>) -> u64 {
    let mut total = 0u64;
    for (_k, v) in map.iter() {
        total += *v as u64;
    }
    total
}
