//! GPU-CELL — the GPU cell-list baseline (Crespin et al. [39], plus the
//! paper's §4.2 optimizations: out-of-place radix sort for Z-ordering and
//! no fixed-size neighbor list).
//!
//! The Morton encoding and LSD radix sort are real implementations (they
//! genuinely improve sweep locality on the host too); their operation
//! counts drive the GPU timing model.

use crate::core::vec3::Vec3;
use crate::frnn::cell_list::{cell_forces, Grid};
use crate::frnn::{Backend, StepCtx, StepResult, WallPhases};
use crate::physics::state::SimState;
use crate::resilience::SimResult;
use crate::rtcore::OpCounts;
use crate::telemetry::wallclock::WallTimer;

/// Interleave the low 10 bits of x into every 3rd bit position.
#[inline]
fn expand_bits10(mut v: u32) -> u32 {
    v &= 0x3ff;
    v = (v | (v << 16)) & 0x030000FF;
    v = (v | (v << 8)) & 0x0300F00F;
    v = (v | (v << 4)) & 0x030C30C3;
    v = (v | (v << 2)) & 0x09249249;
    v
}

/// 30-bit Morton (Z-order) code of a position in `[0, box_l)³`.
#[inline]
pub fn morton30(p: Vec3, box_l: f32) -> u32 {
    let s = 1024.0 / box_l;
    let q = |x: f32| ((x * s) as u32).min(1023);
    (expand_bits10(q(p.z)) << 2) | (expand_bits10(q(p.y)) << 1) | expand_bits10(q(p.x))
}

/// Stable LSD radix sort of `(key, value)` pairs by key, 8 bits per pass
/// (4 passes for 30-bit Morton keys). Out-of-place, as in the paper.
pub fn radix_sort_pairs(keys: &mut Vec<u32>, vals: &mut Vec<u32>) {
    let n = keys.len();
    let mut k_tmp = vec![0u32; n];
    let mut v_tmp = vec![0u32; n];
    for pass in 0..4 {
        let shift = pass * 8;
        let mut hist = [0u32; 257];
        for &k in keys.iter() {
            hist[((k >> shift) & 0xff) as usize + 1] += 1;
        }
        for b in 0..256 {
            hist[b + 1] += hist[b];
        }
        for i in 0..n {
            let b = ((keys[i] >> shift) & 0xff) as usize;
            let dst = hist[b] as usize;
            hist[b] += 1;
            k_tmp[dst] = keys[i];
            v_tmp[dst] = vals[i];
        }
        std::mem::swap(keys, &mut k_tmp);
        std::mem::swap(vals, &mut v_tmp);
    }
}

/// Parallel variant of [`radix_sort_pairs`]: per-chunk histograms, a serial
/// bucket-major prefix to assign every (chunk, bucket) a disjoint output
/// region, then parallel stable scatter. Output is bit-identical to the
/// serial sort (chunk order preserved within each bucket), so LBVH builds
/// are thread-count independent. Falls back to serial for small inputs.
pub fn radix_sort_pairs_mt(keys: &mut Vec<u32>, vals: &mut Vec<u32>, threads: usize) {
    let n = keys.len();
    if threads <= 1 || n < 1 << 14 {
        return radix_sort_pairs(keys, vals);
    }
    let threads = threads.min(n);
    let mut k_tmp = vec![0u32; n];
    let mut v_tmp = vec![0u32; n];
    for pass in 0..4 {
        let shift = pass * 8;
        // Per-chunk histograms; parallel_for_chunks assigns chunk t the
        // range [t*ceil(n/threads), ...), matching the scatter below.
        let mut hists = vec![[0u32; 256]; threads];
        {
            let hist_ptr = crate::parallel::SendPtr(hists.as_mut_ptr());
            let keys_ref: &[u32] = keys;
            crate::parallel::parallel_for_chunks(n, threads, |t, range| {
                let mut h = [0u32; 256];
                for i in range {
                    h[((keys_ref[i] >> shift) & 0xff) as usize] += 1;
                }
                // SAFETY: one slot per worker, written exactly once.
                unsafe { *hist_ptr.0.add(t) = h };
            });
        }
        // Bucket-major exclusive prefix: starts[t][b] is chunk t's first
        // output slot for bucket b.
        let mut running = 0u32;
        let mut starts = vec![[0u32; 256]; threads];
        for b in 0..256 {
            for t in 0..threads {
                starts[t][b] = running;
                running += hists[t][b];
            }
        }
        // Parallel scatter into disjoint (chunk, bucket) regions.
        {
            let kt_ptr = crate::parallel::SendPtr(k_tmp.as_mut_ptr());
            let vt_ptr = crate::parallel::SendPtr(v_tmp.as_mut_ptr());
            let keys_ref: &[u32] = keys;
            let vals_ref: &[u32] = vals;
            let starts_ref = &starts;
            crate::parallel::parallel_for_chunks(n, threads, |t, range| {
                let mut cursors = starts_ref[t];
                for i in range {
                    let b = ((keys_ref[i] >> shift) & 0xff) as usize;
                    let dst = cursors[b] as usize;
                    cursors[b] += 1;
                    // SAFETY: (chunk, bucket) output regions are disjoint
                    // by construction of `starts`.
                    unsafe {
                        *kt_ptr.0.add(dst) = keys_ref[i];
                        *vt_ptr.0.add(dst) = vals_ref[i];
                    }
                }
            });
        }
        std::mem::swap(keys, &mut k_tmp);
        std::mem::swap(vals, &mut v_tmp);
    }
}

/// GPU-CELL backend.
pub struct GpuCell {
    /// Z-order scratch reused across steps (device-resident buffers on real
    /// GPUs) — the same per-step Morton cache the RT backends use, so all
    /// keying/sorting machinery is shared.
    zcache: crate::frnn::zorder::ZOrderCache,
}

impl GpuCell {
    pub fn new() -> Self {
        GpuCell { zcache: crate::frnn::zorder::ZOrderCache::new() }
    }

    /// The Z-order permutation computed for the current step (diagnostic).
    pub fn z_order(&self) -> &[u32] {
        self.zcache.order()
    }
}

impl Default for GpuCell {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for GpuCell {
    fn name(&self) -> &'static str {
        "GPU-CELL"
    }

    fn step(&mut self, state: &mut SimState, ctx: &mut StepCtx) -> SimResult<StepResult> {
        let mut counts = OpCounts::default();
        let mut wall = WallPhases::default();
        let n = state.n();

        // Phase 1: Z-order radix sort (locality for the sweep).
        let t0 = WallTimer::start();
        self.zcache.compute(&state.pos, state.box_l, ctx.threads);
        counts.sort_elems += n as u64;

        // Phase 2: grid build (dense or compact-hashed by resolution).
        let grid = Grid::build(&state.pos, state.box_l, state.r_max);
        counts.grid_binned += n as u64;
        wall.search = t0.elapsed_s();

        // Phase 3: cell sweep force kernel.
        let t1 = WallTimer::start();
        let (forces, tests, evals, visits) = cell_forces(state, &grid, ctx.threads);
        state.force = forces;
        counts.cell_pair_tests += tests;
        counts.cell_force_evals += evals;
        counts.cell_visits += visits;
        counts.interactions += evals / 2;
        counts.kernel_launches += 2;
        wall.force = t1.elapsed_s();

        // Phase 4: integration kernel.
        let t2 = WallTimer::start();
        crate::physics::integrator::step(state);
        counts.integrate_particles += n as u64;
        counts.kernel_launches += 1;
        wall.integrate = t2.elapsed_s();

        Ok(StepResult { counts, bvh_action: None, oom_bytes: None, wall })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::{Boundary, RadiusDist, SimConfig};
    use crate::core::rng::Rng;
    use crate::frnn::{brute, RustKernels};
    use crate::rtcore::profile::RTXPRO;

    #[test]
    fn morton_orders_locally() {
        // nearby points share high bits more often than distant ones
        let a = morton30(Vec3::new(10.0, 10.0, 10.0), 1000.0);
        let b = morton30(Vec3::new(11.0, 10.0, 10.0), 1000.0);
        let c = morton30(Vec3::new(900.0, 900.0, 900.0), 1000.0);
        assert!((a ^ b).leading_zeros() > (a ^ c).leading_zeros());
        // codes stay within 30 bits
        assert_eq!(morton30(Vec3::splat(999.9), 1000.0) >> 30, 0);
    }

    #[test]
    fn radix_sort_sorts_and_permutes() {
        let mut rng = Rng::new(3);
        let mut keys: Vec<u32> = (0..5000).map(|_| rng.next_u64() as u32 & 0x3FFF_FFFF).collect();
        let orig = keys.clone();
        let mut vals: Vec<u32> = (0..5000).collect();
        radix_sort_pairs(&mut keys, &mut vals);
        assert!(keys.windows(2).all(|w| w[0] <= w[1]));
        // permutation consistent: vals maps sorted slot -> original index
        for (slot, &v) in vals.iter().enumerate() {
            assert_eq!(keys[slot], orig[v as usize]);
        }
    }

    #[test]
    fn radix_sort_mt_matches_serial() {
        // above the serial fallback threshold, with an uneven tail chunk
        let n = (1 << 14) + 37;
        let mut rng = Rng::new(9);
        let keys: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32 & 0x3FFF_FFFF).collect();
        let vals: Vec<u32> = (0..n as u32).collect();
        let (mut k1, mut v1) = (keys.clone(), vals.clone());
        radix_sort_pairs(&mut k1, &mut v1);
        for threads in [2, 5, 8] {
            let (mut k2, mut v2) = (keys.clone(), vals.clone());
            radix_sort_pairs_mt(&mut k2, &mut v2, threads);
            assert_eq!(k1, k2, "threads={threads}");
            assert_eq!(v1, v2, "threads={threads} (stability)");
        }
    }

    #[test]
    fn radix_sort_stable() {
        let mut keys = vec![5u32, 1, 5, 1, 5];
        let mut vals = vec![0u32, 1, 2, 3, 4];
        radix_sort_pairs(&mut keys, &mut vals);
        assert_eq!(keys, vec![1, 1, 5, 5, 5]);
        assert_eq!(vals, vec![1, 3, 0, 2, 4]); // equal keys keep order
    }

    #[test]
    fn gpu_cell_step_matches_brute_forces() {
        for boundary in [Boundary::Wall, Boundary::Periodic] {
            let cfg = SimConfig {
                n: 250,
                boundary,
                radius_dist: RadiusDist::Uniform(2.0, 10.0),
                box_l: 100.0,
                ..SimConfig::default()
            };
            let mut state = SimState::from_config(&cfg);
            let want = {
                let mut s2 = state.clone();
                s2.force = brute::forces(&s2);
                crate::physics::integrator::step(&mut s2);
                s2
            };
            let kernels = RustKernels { threads: 2 };
            let mut ctx = StepCtx {
                threads: 2,
                kernels: &kernels,
                hw: &RTXPRO,
                check_oom: false,
                vram_budget: None,
            };
            let mut backend = GpuCell::new();
            let r = backend.step(&mut state, &mut ctx).unwrap();
            assert!(r.counts.sort_elems == 250);
            for i in 0..state.n() {
                let d = (state.pos[i] - want.pos[i]).norm();
                assert!(d < 1e-3, "{boundary:?} particle {i} drifted {d}");
            }
        }
    }
}
