//! BVH quality metrics: the SAH cost of the current tree and overlap-based
//! degradation measures. Used by tests (SAH builds beat median builds) and
//! by the benchmark reports to show how refits degrade the tree — the
//! phenomenon the `gradient` policy models as `Δq` (paper Fig. 3).
//!
//! Both metrics walk the BVH4 lane boxes: every *used* lane corresponds to
//! one materialized binary node of the pre-collapse topology, so the sums
//! track the classic binary formulations (minus the collapsed intermediate
//! nodes, a uniform shift that preserves the build-quality ordering).

use super::{Bvh, BVH4_WIDTH};

/// Expected traversal cost under the Surface Area Heuristic:
/// `C = Ct * Σ_internal SA(lane)/SA(root) + Ci * Σ_leaf SA(lane)/SA(root) * count(lane)`.
pub fn sah_cost(bvh: &Bvh) -> f64 {
    let root_sa = bvh.root_aabb().surface_area() as f64;
    if root_sa <= 0.0 {
        return 0.0;
    }
    let mut cost = 0.0;
    for n in &bvh.nodes {
        for lane in 0..BVH4_WIDTH {
            if !n.lane_used(lane) {
                continue;
            }
            let sa = n.lane_aabb(lane).surface_area() as f64 / root_sa;
            if n.lane_is_leaf(lane) {
                cost += sa * n.count[lane] as f64;
            } else {
                cost += sa;
            }
        }
    }
    cost
}

/// Sum of pairwise lane-overlap surface areas normalized by the root —
/// grows as refits accumulate and sibling boxes start intersecting.
pub fn overlap_metric(bvh: &Bvh) -> f64 {
    let root_sa = bvh.root_aabb().surface_area() as f64;
    if root_sa <= 0.0 {
        return 0.0;
    }
    let mut total = 0.0;
    for n in &bvh.nodes {
        for a in 0..BVH4_WIDTH {
            if !n.lane_used(a) {
                continue;
            }
            let ba = n.lane_aabb(a);
            for b in (a + 1)..BVH4_WIDTH {
                if !n.lane_used(b) {
                    continue;
                }
                let bb = n.lane_aabb(b);
                let lo = ba.lo.max(bb.lo);
                let hi = ba.hi.min(bb.hi);
                let d = hi - lo;
                if d.x > 0.0 && d.y > 0.0 && d.z > 0.0 {
                    total += 2.0 * (d.x * d.y + d.y * d.z + d.z * d.x) as f64 / root_sa;
                }
            }
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bvh::BuildKind;
    use crate::core::rng::Rng;
    use crate::core::vec3::Vec3;

    #[test]
    fn refits_degrade_quality_metrics() {
        let mut rng = Rng::new(31);
        let mut pos: Vec<Vec3> = (0..1500)
            .map(|_| {
                Vec3::new(
                    rng.range_f32(0.0, 100.0),
                    rng.range_f32(0.0, 100.0),
                    rng.range_f32(0.0, 100.0),
                )
            })
            .collect();
        let radius = vec![1.5f32; 1500];
        let mut bvh = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
        let q0 = sah_cost(&bvh);
        let o0 = overlap_metric(&bvh);
        for _ in 0..12 {
            for p in pos.iter_mut() {
                *p += Vec3::new(
                    rng.range_f32(-3.0, 3.0),
                    rng.range_f32(-3.0, 3.0),
                    rng.range_f32(-3.0, 3.0),
                );
            }
            bvh.refit(&pos, &radius);
        }
        assert!(sah_cost(&bvh) > q0, "SAH cost should grow with refits");
        assert!(overlap_metric(&bvh) > o0, "overlap should grow with refits");
    }

    #[test]
    fn leaf_only_tree_cost() {
        let pos = vec![Vec3::ZERO; 2];
        let radius = vec![1.0f32; 2];
        let bvh = Bvh::build(&pos, &radius, BuildKind::Median);
        // one node with a single leaf lane, sa ratio 1, two prims
        assert!((sah_cost(&bvh) - 2.0).abs() < 1e-6);
        assert_eq!(overlap_metric(&bvh), 0.0);
    }

    #[test]
    fn empty_tree_costs_nothing() {
        let bvh = Bvh::build(&[], &[], BuildKind::Median);
        assert_eq!(sah_cost(&bvh), 0.0);
        assert_eq!(overlap_metric(&bvh), 0.0);
    }
}
