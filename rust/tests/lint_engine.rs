//! Integration tests for `orcs lint`: every seeded fixture in
//! `tests/lint_fixtures/<rule>/bad.rs` triggers exactly its rule (with the
//! expected file and line), the clean twins trigger nothing, and the
//! crate's own sources pass `--deny all` under the checked-in `lint.toml`
//! — the same invariant the CI gate enforces.

use std::path::{Path, PathBuf};

use orcs::analysis::{lint_root, DenyMode, LintConfig};

/// Fixture scopes: every rule applies everywhere, no allowlist.
fn fixture_cfg() -> LintConfig {
    let all = vec![".".to_string()];
    LintConfig { step_path: all.clone(), det_path: all.clone(), csr_path: all, allow: Vec::new() }
}

fn fixture_root(rule_dir: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/lint_fixtures").join(rule_dir)
}

/// Lint one fixture dir: exactly one finding, of `rule`, in bad.rs at
/// `line`, and it denies under `--deny all` (the clean twin contributes
/// nothing).
fn check_fixture(rule_dir: &str, rule: &str, line: u32) {
    let report = lint_root(&fixture_root(rule_dir), &fixture_cfg(), &DenyMode::All).unwrap();
    assert_eq!(
        report.findings.len(),
        1,
        "{rule_dir}: expected exactly one finding, got {:?}",
        report.findings
    );
    let f = &report.findings[0];
    assert_eq!(f.rule, rule, "{rule_dir}: wrong rule ({f:?})");
    assert_eq!(f.path, "bad.rs", "{rule_dir}: finding must be in bad.rs ({f:?})");
    assert_eq!(f.line, line, "{rule_dir}: wrong line ({f:?})");
    assert_eq!(report.deny_count(), 1, "{rule_dir}: --deny all must make it a deny");
}

#[test]
fn fixture_d_hash_iter() {
    check_fixture("d_hash_iter", "D-HASH-ITER", 6);
}

#[test]
fn fixture_d_env_threads() {
    check_fixture("d_env_threads", "D-ENV-THREADS", 3);
}

#[test]
fn fixture_d_wall_clock() {
    check_fixture("d_wall_clock", "D-WALL-CLOCK", 3);
}

/// A wall clock in a backend *step* path fires even now that the blessed
/// `telemetry::wallclock` module exists — only that one site is allowed.
#[test]
fn fixture_d_wall_clock_backend() {
    check_fixture("d_wall_clock_backend", "D-WALL-CLOCK", 3);
}

#[test]
fn fixture_d_fp_parallel() {
    check_fixture("d_fp_parallel", "D-FP-PARALLEL", 7);
}

#[test]
fn fixture_p_panic() {
    check_fixture("p_panic", "P-PANIC", 3);
}

#[test]
fn fixture_p_index_lit() {
    check_fixture("p_index_lit", "P-INDEX-LIT", 3);
}

#[test]
fn fixture_p_cast_narrow() {
    check_fixture("p_cast_narrow", "P-CAST-NARROW", 4);
}

#[test]
fn fixture_u_safety() {
    check_fixture("u_safety", "U-SAFETY", 3);
}

/// U-SAFETY also fires on undocumented `core::arch` SIMD intrinsic call
/// sites (the unsafe surface the quantized-BVH lane kernels added) — the
/// attribute line above the fn does not count as a SAFETY comment.
#[test]
fn fixture_u_safety_simd() {
    check_fixture("u_safety_simd", "U-SAFETY", 4);
}

#[test]
fn fixture_l_allow() {
    check_fixture("l_allow", "L-ALLOW", 3);
}

/// The l_allow clean twin exercises a *valid* suppression: its P-PANIC
/// finding must be absorbed (counted as suppressed), not reported.
#[test]
fn valid_suppression_is_counted_not_reported() {
    let report = lint_root(&fixture_root("l_allow"), &fixture_cfg(), &DenyMode::All).unwrap();
    assert_eq!(report.suppressed, 1, "ok.rs's lint:allow should absorb one finding");
}

/// Severity remapping: the Warn-by-default fixtures pass the gate under
/// default deny mode and fail it under `--deny all`.
#[test]
fn warn_rules_only_deny_under_deny_all() {
    for dir in ["p_index_lit", "p_cast_narrow"] {
        let dflt = lint_root(&fixture_root(dir), &fixture_cfg(), &DenyMode::Default).unwrap();
        assert_eq!(dflt.deny_count(), 0, "{dir}: warn by default");
        assert_eq!(dflt.warn_count(), 1, "{dir}: still reported");
    }
}

/// The self-clean gate: `orcs lint --deny all` over the crate's own
/// sources, with the checked-in lint.toml, reports zero findings. This is
/// the exact invariant CI enforces on every push.
#[test]
fn crate_sources_are_lint_clean_at_deny_all() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let cfg = LintConfig::load(&manifest.join("../lint.toml")).unwrap();
    let report = lint_root(&manifest.join("src"), &cfg, &DenyMode::All).unwrap();
    assert!(
        report.findings.is_empty(),
        "crate sources must be lint-clean at --deny all; findings:\n{}",
        orcs::analysis::render_human(&report)
    );
    assert!(report.files > 30, "sanity: the walk saw the whole crate ({} files)", report.files);
}
