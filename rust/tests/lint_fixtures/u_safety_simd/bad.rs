// Fixture: seeded U-SAFETY violation — undocumented `core::arch` intrinsic call.
#[cfg(target_arch = "x86_64")]
pub fn spin_hint() {
    unsafe { core::arch::x86_64::_mm_pause() }
}
