// Fixture: clean twin — non-panicking access.
pub fn root(nodes: &[u32]) -> u32 {
    nodes.first().copied().unwrap_or(0)
}
