//! BVH4 traversal with exact operation counters — the simulated RT-core
//! query, plus the batched traversal engine every RT backend routes through.
//!
//! The paper's FRNN scheme launches an *infinitesimal ray* at each particle
//! position and collects sphere intersections (Fig. 1): geometrically this is
//! a point query — `p_i` hits sphere `j` iff `|p_i - p_j| < r_j`. Traversal
//! visits every node whose AABB contains the query point and tests spheres
//! at the leaves.
//!
//! # The 4-wide hot loop and counter semantics
//!
//! Nodes are 4-wide SoA with 8-bit quantized child boxes
//! ([`crate::bvh::Bvh4Node`]): one traversal step loads a single node —
//! under 64 bytes, one cache line, versus 128 B for the uncompressed f32
//! layout — quantizes the query point into the node's integer frame once
//! ([`crate::bvh::Bvh4Node::quantize_query`]) and tests **all four child
//! boxes** with pure integer compares, no dequantization
//! ([`crate::bvh::simd::lane_mask`], explicit SSE2/NEON kernels with a
//! bit-identical scalar fallback). Quantized bounds are conservative, so a
//! lane test can pass where the exact box would have culled (never the
//! reverse); the exact sphere test at the leaves keeps hit sets bitwise
//! identical to an uncompressed tree. Counters mirror the wide sweep:
//!
//! * `aabb_tests` — **one unit per 4-wide node test**, *not* per child box.
//!   The [`crate::rtcore::timing`] model multiplies by
//!   [`crate::bvh::BVH4_WIDTH`] to price the box units and charges one
//!   (quantized-size) node fetch per unit, so simulated GPU time stays
//!   calibrated against the seed's binary-BVH traversal (see
//!   `timing::BOX_TESTS_PER_AABB_UNIT`). Quantized trees may visit *more*
//!   nodes than exact trees (conservative widening); the counter charges
//!   every one of them honestly.
//! * `sphere_tests` — intersection-shader invocations (unchanged).
//! * `hits`, `rays` — unchanged.
//!
//! Lane hits are processed leaf-lanes-first; internal lanes are pushed onto
//! the stack in reverse lane order so traversal order is deterministic
//! (first hit lane is descended first).
//!
//! # The batched engine
//!
//! RT hardware gets its throughput from sweeping *batches* of coherent rays,
//! not from one-at-a-time launches (RTNN, Zhu 2022). The CPU model mirrors
//! that in three layers:
//!
//! * [`QueryScratch`] — per-worker reusable state (fixed traversal stack +
//!   heap spill + gamma-origin buffer + dedup buffer + stats accumulator),
//!   so a single ray through [`Bvh::query_point`] touches **no allocator**
//!   in steady state;
//! * [`Bvh::query_batch`] — sweeps a query set in index order with
//!   thread-local scratch and chunked work-stealing ([`crate::parallel`]),
//!   merging [`TraversalStats`] once per worker instead of once per ray.
//!   Chunk outputs come back in chunk order, so callers that fold them
//!   sequentially stay bitwise deterministic under dynamic scheduling.
//! * [`Bvh::query_batch_ordered`] — the RTNN-style coherence win: query
//!   indices are sorted by the Z-order (Morton) key of their position (the
//!   same `morton30` keys GPU-CELL computes) and swept in that order, so
//!   consecutive rays traverse the same subtrees and the node working set
//!   stays cache-resident. Chunks are slices of the *sorted* order; callers
//!   scatter per-particle outputs back to particle order through the ids
//!   each chunk reports — the merge stays chunk-ordered and therefore
//!   bitwise deterministic across thread counts (the key sort itself is the
//!   thread-count-independent `radix_sort_pairs_mt`).

use super::{Bvh, BVH4_WIDTH};
use crate::core::vec3::Vec3;

/// Fixed traversal-stack depth. A BVH4 step can push up to `BVH4_WIDTH`
/// internal lanes (all four lanes of a node may be internal), i.e. net +3
/// per level after the pop, and BFS depth is ~log4 of the node count for
/// sane builds; 96 covers every realistic scene, and deeper
/// (degenerate-refit) trees spill to the scratch's heap vector.
const STACK_DEPTH: usize = 96;

/// Per-query (or accumulated) traversal statistics. These feed
/// [`crate::rtcore::timing`] to produce simulated GPU time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraversalStats {
    /// 4-wide node tests executed (one unit = one SoA node = `BVH4_WIDTH`
    /// child-box tests on the RT-core box units; see module docs).
    pub aabb_tests: u64,
    /// Sphere (primitive) tests — intersection-shader invocations.
    pub sphere_tests: u64,
    /// Intersections found (hits = discovered neighbor candidates).
    pub hits: u64,
    /// Rays launched (primary + gamma).
    pub rays: u64,
}

impl TraversalStats {
    pub fn add(&mut self, o: &TraversalStats) {
        self.aabb_tests += o.aabb_tests;
        self.sphere_tests += o.sphere_tests;
        self.hits += o.hits;
        self.rays += o.rays;
    }
}

/// Reusable per-worker traversal state: fixed stack + spill vector + gamma
/// origin buffer + dedup buffer + stats accumulator. One ray performs zero
/// heap allocations once the scratch is warm; allocations happen only at
/// worker setup (and on first-ever spill/gamma growth, whose capacity is
/// retained).
pub struct QueryScratch {
    stack: [u32; STACK_DEPTH],
    /// Effective fixed-stack depth before spilling. Always `STACK_DEPTH` in
    /// production; tests lower it (via [`QueryScratch::with_stack_limit`])
    /// to exercise the spill path deterministically.
    stack_limit: usize,
    spill: Vec<u32>,
    /// Gamma-ray origin buffer (periodic BC) — filled and drained by
    /// [`crate::frnn::rt_common::launch_rays`]; capacity retained across
    /// particles.
    pub gamma: Vec<Vec3>,
    /// Hit-id dedup buffer for the large-radius periodic path
    /// (`r_max > box_l / 2`, see `rt_common::launch_rays`); capacity
    /// retained across particles.
    pub hit_ids: Vec<u32>,
    /// Stats accumulated by every query through this scratch. Merge into
    /// step counters once per worker/chunk, not per ray.
    pub stats: TraversalStats,
}

impl QueryScratch {
    pub fn new() -> Self {
        QueryScratch {
            stack: [0; STACK_DEPTH],
            stack_limit: STACK_DEPTH,
            spill: Vec::new(),
            gamma: Vec::new(),
            hit_ids: Vec::new(),
            stats: TraversalStats::default(),
        }
    }

    /// A scratch whose fixed stack spills after `limit` entries — for tests
    /// that exercise the heap-spill path on trees far shallower than
    /// `STACK_DEPTH`. Results are identical to the default scratch.
    pub fn with_stack_limit(limit: usize) -> Self {
        let mut s = Self::new();
        s.stack_limit = limit.min(STACK_DEPTH);
        s
    }

    /// Extract and reset the accumulated stats.
    pub fn take_stats(&mut self) -> TraversalStats {
        std::mem::take(&mut self.stats)
    }
}

impl Default for QueryScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl Bvh {
    /// Query all spheres containing point `p`, excluding primitive
    /// `exclude` (a particle never neighbors itself; pass `usize::MAX` to
    /// keep all). Calls `visit(j)` for every hit and accumulates counters
    /// into `scratch.stats`.
    ///
    /// `pos`/`radius` are the *current* particle arrays: the BVH prunes by
    /// node bounds (possibly stale-loose after refits — exactly like RT
    /// hardware), but the sphere test itself is exact.
    #[inline]
    pub fn query_point<F: FnMut(usize)>(
        &self,
        p: Vec3,
        exclude: usize,
        pos: &[Vec3],
        radius: &[f32],
        scratch: &mut QueryScratch,
        mut visit: F,
    ) {
        let QueryScratch { stack, stack_limit, spill, stats, .. } = scratch;
        let limit = *stack_limit;
        stats.rays += 1;
        if self.nodes.is_empty() {
            return;
        }
        let mut sp = 0usize;
        debug_assert!(spill.is_empty());

        // resolve the lane kernel once per ray, not per node (the selection
        // is an atomic load; see `bvh::simd`)
        let kern = super::simd::active_kernel();
        let mut current = 0u32;
        loop {
            // SAFETY: `current` is always a node slot produced by the
            // collapse (root 0, lane children which `check_invariants`
            // proves in-bounds); prim_order indices are a permutation of
            // 0..n_prims. Skipping the bounds checks is worth ~8% on this
            // hottest loop (EXPERIMENTS.md §Perf #6).
            let node = unsafe { self.nodes.get_unchecked(current as usize) };
            stats.aabb_tests += 1; // one 4-wide SoA node test
            let mut pending = [0u32; BVH4_WIDTH];
            let mut n_pending = 0usize;
            // quantize the query point into this node's integer frame once,
            // then test all four lanes with pure integer compares (empty
            // lanes carry inverted sentinel bounds and fail automatically;
            // every kernel returns bit-identical masks, so the hit set is
            // independent of the selected kernel)
            let qp = node.quantize_query(p);
            let mut mask = super::simd::lane_mask_with(kern, node, qp);
            while mask != 0 {
                let lane = mask.trailing_zeros() as usize;
                mask &= mask - 1;
                let cnt = node.count[lane];
                if cnt > 0 {
                    let first = node.child[lane] as usize;
                    for k in first..first + cnt as usize {
                        // SAFETY: leaf ranges index into prim_order, whose
                        // length the collapse invariants guarantee.
                        let j = unsafe { *self.prim_order.get_unchecked(k) } as usize;
                        stats.sphere_tests += 1;
                        if j != exclude {
                            // SAFETY: `j` comes from the 0..n_prims
                            // permutation; pos/radius have n_prims entries.
                            let d2 = (p - *unsafe { pos.get_unchecked(j) }).norm2();
                            let r = unsafe { *radius.get_unchecked(j) };
                            if d2 < r * r {
                                stats.hits += 1;
                                visit(j);
                            }
                        }
                    }
                } else {
                    pending[n_pending] = node.child[lane];
                    n_pending += 1;
                }
            }
            // push in reverse so the first hit lane is descended first
            for k in (0..n_pending).rev() {
                if sp < limit {
                    stack[sp] = pending[k];
                    sp += 1;
                } else {
                    spill.push(pending[k]);
                }
            }
            // pop
            if let Some(next) = spill.pop() {
                current = next;
            } else if sp > 0 {
                sp -= 1;
                current = stack[sp];
            } else {
                break;
            }
        }
    }

    /// Collect hit indices into a vector (convenience for tests and the
    /// neighbor-list pipeline).
    pub fn query_point_collect(
        &self,
        p: Vec3,
        exclude: usize,
        pos: &[Vec3],
        radius: &[f32],
        scratch: &mut QueryScratch,
    ) -> Vec<usize> {
        let mut out = Vec::new();
        self.query_point(p, exclude, pos, radius, scratch, |j| out.push(j));
        out
    }

    /// Batched query sweep over `0..n` query indices: chunked work-stealing
    /// across `threads` workers, each owning a thread-local accumulator
    /// from `init` plus a [`QueryScratch`] that is reused for every ray the
    /// worker processes. `body` handles one chunk of query indices (running
    /// its rays through [`Bvh::query_point`] / `launch_rays` with the
    /// provided scratch) and returns the chunk's output.
    ///
    /// Returns the chunk outputs **in chunk order** (bitwise-deterministic
    /// merging regardless of scheduling) plus the traversal stats merged
    /// once per worker.
    pub fn query_batch<A, O, I, F>(
        &self,
        n: usize,
        threads: usize,
        init: I,
        body: F,
    ) -> (Vec<O>, TraversalStats)
    where
        A: Send,
        O: Send,
        I: Fn() -> A + Sync,
        F: Fn(&mut A, &mut QueryScratch, std::ops::Range<usize>) -> O + Sync,
    {
        let block = batch_block(n);
        let (outs, states) = crate::parallel::parallel_chunk_map(
            n,
            threads,
            block,
            || (init(), QueryScratch::new()),
            |state, range| body(&mut state.0, &mut state.1, range),
        );
        let mut stats = TraversalStats::default();
        for (_, scratch) in &states {
            stats.add(&scratch.stats);
        }
        (outs, stats)
    }

    /// Morton-ordered batched sweep — [`Bvh::query_batch`] with RTNN-style
    /// query-coherence scheduling. Query indices `0..queries.len()` are
    /// sorted by the 30-bit Z-order key of their position (scaled to
    /// `box_l`, same encoding GPU-CELL uses) and swept in that order, so
    /// consecutive rays enter the same subtrees and node fetches stay hot
    /// in cache. `body` receives each chunk as a slice of query ids (in
    /// sorted order) and must key any per-particle output by those ids so
    /// the caller can scatter results back to particle order.
    ///
    /// Determinism: the key sort (`radix_sort_pairs_mt`) and the chunk
    /// partition are both thread-count independent, and chunk outputs
    /// return in chunk order, so chunk-ordered merges downstream are
    /// bitwise identical across `ORCS_THREADS` settings.
    pub fn query_batch_ordered<A, O, I, F>(
        &self,
        queries: &[Vec3],
        box_l: f32,
        threads: usize,
        init: I,
        body: F,
    ) -> (Vec<O>, TraversalStats)
    where
        A: Send,
        O: Send,
        I: Fn() -> A + Sync,
        F: Fn(&mut A, &mut QueryScratch, &[u32]) -> O + Sync,
    {
        let n = queries.len();
        let scale = if box_l > 0.0 { box_l } else { 1.0 };
        let mut keys: Vec<u32> = crate::parallel::parallel_map(n, threads, |i| {
            crate::frnn::gpu_cell::morton30(queries[i], scale)
        });
        let mut order: Vec<u32> = (0..n as u32).collect();
        crate::frnn::gpu_cell::radix_sort_pairs_mt(&mut keys, &mut order, threads);
        self.query_batch_with_order(&order, threads, init, body)
    }

    /// [`Bvh::query_batch_ordered`] with a *caller-supplied* sweep
    /// permutation — the reuse entry point for the per-step Z-order cache
    /// ([`crate::frnn::zorder::ZOrderCache`]): RT backends key + sort once
    /// per step and hand the same permutation to the LBVH build and this
    /// sweep, instead of each phase re-sorting. `order` may be any
    /// permutation of query ids (chunks are slices of it, in order), though
    /// only a spatially coherent one delivers the cache-locality win.
    /// The caller owns the coverage contract: `order` must enumerate the
    /// intended query set exactly once and be current for this step (a
    /// stale cache after a particle-count change would silently drop or
    /// misindex queries — backends recompute their [`ZOrderCache`] at the
    /// top of every step and debug-assert the length).
    ///
    /// [`ZOrderCache`]: crate::frnn::zorder::ZOrderCache
    pub fn query_batch_with_order<A, O, I, F>(
        &self,
        order: &[u32],
        threads: usize,
        init: I,
        body: F,
    ) -> (Vec<O>, TraversalStats)
    where
        A: Send,
        O: Send,
        I: Fn() -> A + Sync,
        F: Fn(&mut A, &mut QueryScratch, &[u32]) -> O + Sync,
    {
        let n = order.len();
        let block = batch_block(n);
        let (outs, states) = crate::parallel::parallel_chunk_map(
            n,
            threads,
            block,
            || (init(), QueryScratch::new()),
            |state, range| body(&mut state.0, &mut state.1, &order[range]),
        );
        let mut stats = TraversalStats::default();
        for (_, scratch) in &states {
            stats.add(&scratch.stats);
        }
        (outs, stats)
    }
}

/// Chunk size for a batched sweep: ~64 chunks total for stealing slack,
/// bounded so tiny sweeps stay single-chunk and huge sweeps keep per-chunk
/// merge overhead negligible. Deliberately independent of the worker count:
/// the chunk partition (and therefore every chunk-ordered merge downstream,
/// e.g. the ORCS-forces scatter reduction) is bitwise identical across
/// `ORCS_THREADS` settings, not just across runs at a fixed setting.
fn batch_block(n: usize) -> usize {
    (n / 64).clamp(32, 4096)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bvh::BuildKind;
    use crate::core::rng::Rng;

    fn scene(n: usize, seed: u64, rmax: f32) -> (Vec<Vec3>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (
            (0..n)
                .map(|_| {
                    Vec3::new(
                        rng.range_f32(0.0, 100.0),
                        rng.range_f32(0.0, 100.0),
                        rng.range_f32(0.0, 100.0),
                    )
                })
                .collect(),
            (0..n).map(|_| rng.range_f32(0.5, rmax)).collect(),
        )
    }

    fn brute(p: Vec3, exclude: usize, pos: &[Vec3], radius: &[f32]) -> Vec<usize> {
        let mut v: Vec<usize> = (0..pos.len())
            .filter(|&j| j != exclude && (p - pos[j]).norm2() < radius[j] * radius[j])
            .collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn query_matches_brute_force() {
        let (pos, radius) = scene(400, 21, 8.0);
        for kind in [BuildKind::Median, BuildKind::BinnedSah] {
            let bvh = Bvh::build(&pos, &radius, kind);
            let mut scratch = QueryScratch::new();
            for i in 0..pos.len() {
                let mut got = bvh.query_point_collect(pos[i], i, &pos, &radius, &mut scratch);
                got.sort_unstable();
                assert_eq!(got, brute(pos[i], i, &pos, &radius), "i={i} kind={kind:?}");
            }
            assert_eq!(scratch.stats.rays, 400);
            assert!(scratch.stats.aabb_tests > 0 && scratch.stats.sphere_tests > 0);
        }
    }

    #[test]
    fn query_correct_after_refits() {
        let (mut pos, radius) = scene(300, 22, 6.0);
        let mut bvh = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
        let mut rng = Rng::new(5);
        let mut scratch = QueryScratch::new();
        for _ in 0..4 {
            for p in pos.iter_mut() {
                *p += Vec3::new(
                    rng.range_f32(-3.0, 3.0),
                    rng.range_f32(-3.0, 3.0),
                    rng.range_f32(-3.0, 3.0),
                );
            }
            bvh.refit(&pos, &radius);
            for i in (0..pos.len()).step_by(7) {
                let mut got = bvh.query_point_collect(pos[i], i, &pos, &radius, &mut scratch);
                got.sort_unstable();
                assert_eq!(got, brute(pos[i], i, &pos, &radius));
            }
        }
    }

    #[test]
    fn refit_degradation_increases_traversal_cost() {
        // the phenomenon gradient exploits: after motion + refit, queries
        // touch more nodes than after a rebuild of the same configuration
        let (mut pos, radius) = scene(2000, 23, 3.0);
        let mut bvh = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
        let mut rng = Rng::new(6);
        for _ in 0..10 {
            for p in pos.iter_mut() {
                *p += Vec3::new(
                    rng.range_f32(-4.0, 4.0),
                    rng.range_f32(-4.0, 4.0),
                    rng.range_f32(-4.0, 4.0),
                );
            }
            bvh.refit(&pos, &radius);
        }
        let mut scratch = QueryScratch::new();
        for i in 0..pos.len() {
            bvh.query_point(pos[i], i, &pos, &radius, &mut scratch, |_| {});
        }
        let refit_stats = scratch.take_stats();
        let fresh = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
        for i in 0..pos.len() {
            fresh.query_point(pos[i], i, &pos, &radius, &mut scratch, |_| {});
        }
        let fresh_stats = scratch.take_stats();
        // hits identical (correctness), cost strictly larger (degradation)
        assert_eq!(refit_stats.hits, fresh_stats.hits);
        assert!(
            refit_stats.aabb_tests > fresh_stats.aabb_tests,
            "refit={} fresh={}",
            refit_stats.aabb_tests,
            fresh_stats.aabb_tests
        );
    }

    #[test]
    fn exclude_max_keeps_self() {
        let pos = vec![Vec3::ZERO, Vec3::new(1.0, 0.0, 0.0)];
        let radius = vec![2.0f32, 2.0];
        let bvh = Bvh::build(&pos, &radius, BuildKind::Median);
        let mut scratch = QueryScratch::new();
        let got = bvh.query_point_collect(Vec3::ZERO, usize::MAX, &pos, &radius, &mut scratch);
        assert_eq!(got.len(), 2); // both spheres contain the origin
    }

    #[test]
    fn forced_stack_spill_matches_default() {
        // a tiny stack limit routes every push through the spill vector;
        // hit sets and visit order must be unchanged
        let (pos, radius) = scene(2000, 29, 6.0);
        for kind in [BuildKind::Median, BuildKind::BinnedSah, BuildKind::Lbvh] {
            let bvh = Bvh::build(&pos, &radius, kind);
            let mut plain = QueryScratch::new();
            let mut spilly = QueryScratch::with_stack_limit(1);
            for i in (0..pos.len()).step_by(11) {
                let a = bvh.query_point_collect(pos[i], i, &pos, &radius, &mut plain);
                let b = bvh.query_point_collect(pos[i], i, &pos, &radius, &mut spilly);
                assert_eq!(a, b, "kind={kind:?} i={i}");
            }
            assert_eq!(plain.take_stats(), spilly.take_stats(), "kind={kind:?}");
        }
    }

    #[test]
    fn batch_matches_per_point_queries() {
        let (pos, radius) = scene(700, 24, 7.0);
        for kind in [BuildKind::Median, BuildKind::BinnedSah, BuildKind::Lbvh] {
            let bvh = Bvh::build(&pos, &radius, kind);
            // per-point reference
            let mut scratch = QueryScratch::new();
            let serial: Vec<Vec<usize>> = (0..pos.len())
                .map(|i| bvh.query_point_collect(pos[i], i, &pos, &radius, &mut scratch))
                .collect();
            let serial_stats = scratch.take_stats();
            for threads in [1, 4] {
                let (chunks, stats) = bvh.query_batch(
                    pos.len(),
                    threads,
                    || (),
                    |_, scratch, range| {
                        range
                            .map(|i| {
                                bvh.query_point_collect(pos[i], i, &pos, &radius, scratch)
                            })
                            .collect::<Vec<_>>()
                    },
                );
                let batched: Vec<Vec<usize>> = chunks.into_iter().flatten().collect();
                assert_eq!(batched, serial, "kind={kind:?} threads={threads}");
                assert_eq!(stats, serial_stats, "kind={kind:?} threads={threads}");
            }
        }
    }

    #[test]
    fn cached_order_sweep_equals_self_sorting_sweep() {
        // query_batch_with_order fed the per-step Z-order cache must chunk
        // and sweep exactly like query_batch_ordered's own key + sort
        let (pos, radius) = scene(800, 31, 6.0);
        let bvh = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
        let body = |_: &mut (), scratch: &mut QueryScratch, ids: &[u32]| {
            ids.iter()
                .map(|&iu| {
                    let i = iu as usize;
                    (iu, bvh.query_point_collect(pos[i], i, &pos, &radius, scratch))
                })
                .collect::<Vec<_>>()
        };
        let (want, want_stats) = bvh.query_batch_ordered(&pos, 100.0, 3, || (), body);
        let mut cache = crate::frnn::zorder::ZOrderCache::new();
        cache.compute(&pos, 100.0, 3);
        let (got, got_stats) = bvh.query_batch_with_order(cache.order(), 3, || (), body);
        assert_eq!(got, want);
        assert_eq!(got_stats, want_stats);
    }

    #[test]
    fn ordered_batch_covers_all_queries_once_and_matches() {
        let (pos, radius) = scene(900, 25, 7.0);
        let bvh = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
        // per-point reference in particle order
        let mut scratch = QueryScratch::new();
        let want: Vec<Vec<usize>> = (0..pos.len())
            .map(|i| {
                let mut v = bvh.query_point_collect(pos[i], i, &pos, &radius, &mut scratch);
                v.sort_unstable();
                v
            })
            .collect();
        let want_stats = scratch.take_stats();
        for threads in [1, 3, 8] {
            let (chunks, stats) = bvh.query_batch_ordered(
                &pos,
                100.0,
                threads,
                || (),
                |_, scratch, ids| {
                    ids.iter()
                        .map(|&iu| {
                            let i = iu as usize;
                            let mut v =
                                bvh.query_point_collect(pos[i], i, &pos, &radius, scratch);
                            v.sort_unstable();
                            (iu, v)
                        })
                        .collect::<Vec<_>>()
                },
            );
            let mut got = vec![Vec::new(); pos.len()];
            let mut filled = vec![false; pos.len()];
            for (iu, v) in chunks.into_iter().flatten() {
                assert!(!filled[iu as usize], "query {iu} swept twice");
                filled[iu as usize] = true;
                got[iu as usize] = v;
            }
            assert!(filled.iter().all(|&f| f), "some query was never swept");
            for (i, g) in got.into_iter().enumerate() {
                assert_eq!(g, want[i], "threads={threads} i={i}");
            }
            // totals are order-independent, so stats match the plain sweep
            assert_eq!(stats, want_stats, "threads={threads}");
        }
    }
}
