//! `cargo bench --bench fig9_speedup_wall [-- --quick]`
//! Regenerates paper Fig. 9 (speedup vs CPU-CELL@64c, wall BC).
fn main() {
    let opts = orcs::benchsuite::common::BenchOpts::from_env().expect("bench options");
    orcs::benchsuite::fig9_10::run(&opts, orcs::core::config::Boundary::Wall).expect("fig9 bench");
}
