//! The simulation engine: one backend, one scenario, stepped to completion
//! with full metering.

use std::sync::Arc;

use anyhow::Result;

use crate::core::config::{ForcePath, SimConfig};
use crate::core::vec3::Vec3;
use crate::frnn::{ApproachKind, Backend, PhysicsKernels, RustKernels, StepCtx, WallPhases};
use crate::gradient::BvhAction;
use crate::physics::state::SimState;
use crate::resilience::checkpoint::EngineCheckpoint;
use crate::resilience::{
    EventKind, FaultInjector, FaultKind, OomPolicy, ResilienceConfig, ResilienceEvent, SimError,
    SimResult, Watchdog,
};
use crate::rtcore::power::{step_energy, StepEnergy};
use crate::rtcore::profile::{DeviceKind, EPYC64};
use crate::rtcore::{fleet, timing, HwProfile, OpCounts, PhaseTimes};
use crate::telemetry::wallclock::WallTimer;
use crate::telemetry::{Recorder, GLOBAL_LANE};

/// Engine configuration: scenario + execution bindings.
#[derive(Clone)]
pub struct EngineConfig {
    pub sim: SimConfig,
    pub approach: ApproachKind,
    /// BVH rebuild policy spec for RT backends (`gradient`, `avg`,
    /// `fixed-K`). Ignored by cell backends.
    pub policy: String,
    /// GPU profile pricing the GPU approaches (CPU-CELL is always priced on
    /// the EPYC host profile).
    pub hw: &'static HwProfile,
    pub threads: usize,
    /// Enforce device-memory limits (RT-REF neighbor list OOM, §4.2).
    pub check_oom: bool,
    /// Resilience knobs (faults, watchdog, checkpoints, OOM fallback).
    /// Default is inert — identical behavior to a pre-resilience engine.
    pub resilience: ResilienceConfig,
}

impl EngineConfig {
    pub fn new(sim: SimConfig, approach: ApproachKind) -> Self {
        EngineConfig {
            sim,
            approach,
            policy: "gradient".into(),
            hw: crate::rtcore::profile::DEFAULT_GPU,
            threads: crate::parallel::num_threads(),
            check_oom: true,
            resilience: ResilienceConfig::default(),
        }
    }

    /// The profile that prices this engine's op counts.
    pub fn pricing_profile(&self) -> &'static HwProfile {
        if self.approach == ApproachKind::CpuCell {
            &EPYC64
        } else {
            self.hw
        }
    }
}

/// Everything measured about one step.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    pub step: u64,
    pub counts: OpCounts,
    /// Simulated phase times on the pricing profile.
    pub sim_times: PhaseTimes,
    /// Total simulated step time, ms.
    pub sim_ms: f64,
    /// Simulated RT cost (BVH op + query), ms — the Fig. 8 quantity.
    pub rt_ms: f64,
    pub energy: StepEnergy,
    pub wall: WallPhases,
    pub bvh_action: Option<BvhAction>,
    pub interactions: u64,
    pub oom_bytes: Option<u64>,
}

/// Aggregate over a run.
#[derive(Clone, Debug, Default)]
pub struct RunSummary {
    pub approach: String,
    pub scenario: String,
    pub hw: String,
    pub steps: u64,
    /// Mean simulated step time, ms.
    pub avg_sim_ms: f64,
    pub total_sim_ms: f64,
    pub total_rt_ms: f64,
    pub total_energy_j: f64,
    pub total_interactions: u64,
    pub avg_power_w: f64,
    /// interactions per joule (Eq. 10).
    pub ee: f64,
    pub oom: bool,
    pub oom_bytes: u64,
    pub wall_total_s: f64,
    /// Resilience log for the run (fallbacks, retries, recoveries).
    pub events: Vec<ResilienceEvent>,
    /// Steps re-executed by checkpoint recovery.
    pub replayed_steps: u64,
    /// Per-step trace (kept when requested).
    pub records: Vec<StepRecord>,
}

/// A live simulation: state + backend + bindings.
pub struct Engine {
    pub cfg: EngineConfig,
    pub state: SimState,
    backend: Box<dyn Backend>,
    kernels: Arc<dyn PhysicsKernels>,
    injector: FaultInjector,
    watchdog: Watchdog,
    /// Injected VRAM squeeze, sticky once it fires.
    vram_budget: Option<u64>,
    /// Straggler factor for the next step (1.0 = none).
    slowdown: f64,
    checkpoint: Option<EngineCheckpoint>,
    events: Vec<ResilienceEvent>,
    replayed: u64,
    /// An injected divergence corrupts the state after the next step.
    divergence_armed: bool,
    /// Per-step telemetry: spans, metrics registry, flight recorder.
    telemetry: Recorder,
}

impl Engine {
    /// Build the engine; `kernels` binds the force/integration path (XLA or
    /// Rust). Fails fast when the backend does not support the scenario
    /// (e.g. ORCS-persé with variable radii).
    pub fn new(cfg: EngineConfig, kernels: Arc<dyn PhysicsKernels>) -> Result<Self> {
        let state = SimState::from_config(&cfg.sim);
        Self::with_state(cfg, kernels, state)
    }

    /// Build the engine over an existing state (snapshot runs: the
    /// OOM-fallback equivalence tests start a fresh backend from a
    /// mid-trajectory `SimState`).
    pub fn with_state(
        cfg: EngineConfig,
        kernels: Arc<dyn PhysicsKernels>,
        state: SimState,
    ) -> Result<Self> {
        let backend = cfg.approach.create(&cfg.policy)?;
        backend
            .supports(&state)
            .map_err(|e| anyhow::anyhow!("{} cannot run {}: {e}", backend.name(), cfg.sim.tag()))?;
        let injector = FaultInjector::new(&cfg.resilience.faults);
        // a step-0 checkpoint makes an early device loss recoverable
        let checkpoint = cfg
            .resilience
            .active()
            .then(|| EngineCheckpoint { step: state.step_count, state: state.clone() });
        Ok(Engine {
            cfg,
            state,
            backend,
            kernels,
            injector,
            watchdog: Watchdog::default(),
            vram_budget: None,
            slowdown: 1.0,
            checkpoint,
            events: Vec::new(),
            replayed: 0,
            divergence_armed: false,
            telemetry: Recorder::new(),
        })
    }

    /// Convenience: engine with the pure-Rust kernels.
    pub fn new_rust(cfg: EngineConfig) -> Result<Self> {
        let threads = cfg.threads;
        Self::new(cfg, Arc::new(RustKernels { threads }))
    }

    /// Build the kernels requested by the config's force path.
    pub fn kernels_for(path: ForcePath, threads: usize) -> Result<Arc<dyn PhysicsKernels>> {
        Ok(match path {
            ForcePath::Rust => Arc::new(RustKernels { threads }),
            ForcePath::Xla => Arc::new(crate::runtime::kernels::XlaKernels::load_default()?),
        })
    }

    /// Execute one raw step and meter it (no fault handling — the
    /// resilient path wraps this).
    pub fn step(&mut self) -> SimResult<StepRecord> {
        let hw = self.cfg.pricing_profile();
        let opened = self.telemetry.begin_step(self.state.step_count);
        self.telemetry.begin_attempt();
        let mut ctx = StepCtx {
            threads: self.cfg.threads,
            kernels: self.kernels.as_ref(),
            hw,
            check_oom: self.cfg.check_oom,
            vram_budget: self.vram_budget,
        };
        let r = self.backend.step(&mut self.state, &mut ctx)?;
        let sim_times = timing::simulate(&r.counts, hw);
        let energy = step_energy(&sim_times, &r.counts, hw);
        let backend_name = self.backend.name();
        self.telemetry.name_lane(GLOBAL_LANE, format!("{} ({backend_name})", hw.name));
        let base = self.telemetry.attempt_base_ms();
        self.telemetry.record_phases(
            GLOBAL_LANE,
            base,
            &sim_times,
            &r.counts,
            Some(&r.wall),
            &[("backend", backend_name), ("device", hw.name)],
        );
        let rec = StepRecord {
            step: self.state.step_count,
            counts: r.counts,
            sim_times,
            sim_ms: sim_times.total() * 1e3,
            rt_ms: sim_times.rt_cost() * 1e3,
            energy,
            wall: r.wall,
            bvh_action: r.bvh_action,
            interactions: r.counts.interactions,
            oom_bytes: r.oom_bytes,
        };
        if opened {
            self.telemetry.end_step(rec.sim_ms);
        }
        Ok(rec)
    }

    /// One step under the resilience policy: consume injected faults, walk
    /// the OOM degradation ladder, and retry watchdog-rejected steps from
    /// the pre-step snapshot with halved `dt` and a forced BVH rebuild.
    pub fn step_resilient(&mut self) -> SimResult<StepRecord> {
        let res = self.cfg.resilience.clone();
        let step = self.state.step_count;
        // Open the telemetry step before consuming faults so device-loss
        // and squeeze markers land inside the step that absorbed them.
        let opened = self.telemetry.begin_step(step);
        let mut transient = false;
        for f in self.injector.take(step) {
            match f {
                FaultKind::VramSqueeze { budget_bytes } => {
                    self.vram_budget = Some(budget_bytes);
                    let kind = EventKind::VramSqueeze { budget_bytes };
                    let ev = ResilienceEvent { step, kind };
                    self.telemetry.mark_event(&ev);
                    self.events.push(ev);
                }
                FaultKind::Straggler { shard, slowdown } => {
                    self.slowdown = slowdown;
                    let kind = EventKind::Straggler { shard, slowdown };
                    let ev = ResilienceEvent { step, kind };
                    self.telemetry.mark_event(&ev);
                    self.events.push(ev);
                }
                FaultKind::Transient => transient = true,
                FaultKind::Divergence => self.divergence_armed = true,
                FaultKind::DeviceLost { shard } => self.recover_from_device_loss(shard)?,
            }
        }

        let mut wasted_ms = 0.0;
        let mut wasted_j = 0.0;
        let mut attempt = 0u32;
        loop {
            let snapshot = res.watchdog.enabled.then(|| self.state.clone());
            let mut rec = self.step()?;

            // OOM degradation ladder: the failed attempt did not mutate the
            // state (RT-REF reports OOM before force/integrate), so the
            // step re-runs cleanly on the next rung.
            if let Some(required) = rec.oom_bytes {
                if res.on_oom == OomPolicy::Fallback {
                    if let Some(switch_ms) = self.fall_back(required)? {
                        wasted_ms += rec.sim_ms;
                        wasted_j += rec.energy.energy_j;
                        rec = self.step()?;
                        rec.sim_ms += switch_ms;
                    }
                }
            }

            if self.divergence_armed && rec.oom_bytes.is_none() && !self.state.vel.is_empty() {
                // injected divergence: blow up one velocity (finite, so only
                // the kinetic-energy bound can catch it)
                self.divergence_armed = false;
                // lint:allow(P-INDEX-LIT): guarded by !vel.is_empty() above
                self.state.vel[0] = self.state.vel[0] * 1e15 + Vec3::splat(1e15);
            }

            if res.watchdog.enabled && rec.oom_bytes.is_none() {
                if let Err(detail) = self.watchdog.check(&res.watchdog, &self.state) {
                    if attempt >= res.watchdog.max_retries {
                        return Err(SimError::NumericalDivergence { detail });
                    }
                    attempt += 1;
                    let Some(snap) = snapshot else {
                        return Err(SimError::fatal("watchdog retry without a pre-step snapshot"));
                    };
                    self.state = snap;
                    self.state.dt *= 0.5;
                    self.backend.invalidate_bvh();
                    wasted_ms += rec.sim_ms;
                    wasted_j += rec.energy.energy_j;
                    let ev = ResilienceEvent {
                        step,
                        kind: EventKind::WatchdogRetry { attempt, dt: self.state.dt, detail },
                    };
                    self.telemetry.mark_event(&ev);
                    self.events.push(ev);
                    continue;
                }
            }

            if transient {
                // the attempt failed spuriously mid-flight and re-ran: the
                // physics is the re-run's, the price includes the discard
                wasted_ms += rec.sim_ms;
                wasted_j += rec.energy.energy_j;
                let ev = ResilienceEvent { step, kind: EventKind::TransientRetry { attempt: 1 } };
                self.telemetry.mark_event(&ev);
                self.events.push(ev);
            }

            rec.sim_ms += wasted_ms;
            rec.energy.energy_j += wasted_j;
            if self.slowdown != 1.0 {
                rec.sim_ms *= self.slowdown;
                rec.energy.energy_j *= self.slowdown;
                self.slowdown = 1.0;
            }
            if res.checkpoint_every > 0
                && rec.oom_bytes.is_none()
                && self.state.step_count % res.checkpoint_every == 0
            {
                self.checkpoint = Some(EngineCheckpoint {
                    step: self.state.step_count,
                    state: self.state.clone(),
                });
                self.telemetry.mark(
                    GLOBAL_LANE,
                    "checkpoint",
                    format!("checkpoint @ step {}", self.state.step_count),
                );
            }
            if opened {
                self.telemetry.end_step(rec.sim_ms);
            }
            return Ok(rec);
        }
    }

    /// Step down the degradation ladder (RT-REF → ORCS-persé → CPU-CELL) to
    /// the first rung that supports the scene. Returns the priced switch
    /// time in ms, or `None` when no rung is left (the OOM stands).
    fn fall_back(&mut self, required_bytes: u64) -> SimResult<Option<f64>> {
        const LADDER: [ApproachKind; 3] =
            [ApproachKind::RtRef, ApproachKind::OrcsPerse, ApproachKind::CpuCell];
        let step = self.state.step_count;
        let old_hw = self.cfg.pricing_profile();
        let budget_bytes = self.vram_budget.map_or(old_hw.vram_bytes, |b| b.min(old_hw.vram_bytes));
        let pos = LADDER.iter().position(|a| *a == self.cfg.approach);
        let start = pos.map_or(LADDER.len(), |i| i + 1);
        for &next in LADDER.iter().skip(start) {
            let backend = next.create(&self.cfg.policy).map_err(SimError::fatal)?;
            if backend.supports(&self.state).is_err() {
                continue;
            }
            let from = self.cfg.approach.label();
            self.cfg.approach = next;
            self.backend = backend;
            let new_hw = self.cfg.pricing_profile();
            let switch_ms = fleet::switch_time(self.state.n() as u64, new_hw) * 1e3;
            let ev = ResilienceEvent {
                step,
                kind: EventKind::OomFallback {
                    from,
                    to: next.label(),
                    shard: None,
                    required_bytes,
                    budget_bytes,
                    switch_ms,
                },
            };
            self.telemetry.mark_event(&ev);
            self.events.push(ev);
            return Ok(Some(switch_ms));
        }
        let kind = EventKind::FallbackUnavailable { required_bytes };
        let ev = ResilienceEvent { step, kind };
        self.telemetry.mark_event(&ev);
        self.events.push(ev);
        Ok(None)
    }

    /// Handle an injected device loss: a replacement device re-stages from
    /// the last checkpoint with a fresh backend (empty BVH, fresh policy)
    /// and the trajectory replays from the step boundary.
    fn recover_from_device_loss(&mut self, shard: usize) -> SimResult<()> {
        let device = self.cfg.pricing_profile().name.to_string();
        let Some(cp) = self.checkpoint.as_ref() else {
            return Err(SimError::DeviceLost { shard, device });
        };
        let from_step = cp.step;
        let replayed = self.state.step_count.saturating_sub(from_step);
        let at = self.state.step_count;
        self.state = cp.state.clone();
        self.backend = self.cfg.approach.create(&self.cfg.policy).map_err(SimError::fatal)?;
        self.watchdog.reset();
        self.replayed += replayed;
        let ev = ResilienceEvent {
            step: at,
            kind: EventKind::DeviceLost { shard, device, survivors: 1 },
        };
        self.telemetry.mark_event(&ev);
        self.events.push(ev);
        let ev =
            ResilienceEvent { step: at, kind: EventKind::Recovery { from_step, replayed } };
        self.telemetry.mark_event(&ev);
        self.events.push(ev);
        Ok(())
    }

    /// Drain the resilience log (events accumulate across steps).
    pub fn take_events(&mut self) -> Vec<ResilienceEvent> {
        std::mem::take(&mut self.events)
    }

    /// Steps re-executed by checkpoint recovery so far.
    pub fn replayed_steps(&self) -> u64 {
        self.replayed
    }

    /// The telemetry recorder: per-step spans, metrics, flight recorder.
    pub fn telemetry(&self) -> &Recorder {
        &self.telemetry
    }

    pub fn telemetry_mut(&mut self) -> &mut Recorder {
        &mut self.telemetry
    }

    /// Run `steps` steps; aborts early on an unhandled OOM (like the
    /// paper's runs). With an active [`ResilienceConfig`] every step goes
    /// through the resilient path; a failed step surfaces its index,
    /// backend and device in the error context.
    pub fn run(&mut self, steps: usize, keep_trace: bool) -> Result<RunSummary> {
        let wall_start = WallTimer::start();
        let mut s = RunSummary {
            approach: self.backend.name().to_string(),
            scenario: self.cfg.sim.tag(),
            hw: self.cfg.pricing_profile().name.to_string(),
            ..Default::default()
        };
        let resilient = self.cfg.resilience.active();
        let target = self.state.step_count + steps as u64;
        let mut energy_time = 0.0;
        while self.state.step_count < target {
            let i = self.state.step_count;
            let backend_name = self.backend.name();
            let hw_name = self.cfg.pricing_profile().name;
            let r = if resilient { self.step_resilient() } else { self.step() };
            let rec = match r {
                Ok(rec) => rec,
                Err(e) => {
                    // Fault forensics: dump the flight recorder (including
                    // the partially-recorded failing step) before bailing.
                    let dump = self.telemetry.flight_dump();
                    if !dump.is_empty() {
                        eprintln!("{dump}");
                    }
                    self.telemetry.abandon_step();
                    return Err(anyhow::anyhow!(
                        "step {i} failed [{backend_name} on {hw_name}]: {e}"
                    ));
                }
            };
            s.steps += 1;
            s.total_sim_ms += rec.sim_ms;
            s.total_rt_ms += rec.rt_ms;
            s.total_energy_j += rec.energy.energy_j;
            s.total_interactions += rec.interactions;
            energy_time += rec.sim_ms;
            if keep_trace {
                s.records.push(rec);
            }
            if let Some(bytes) = rec.oom_bytes {
                s.oom = true;
                s.oom_bytes = bytes;
                break;
            }
        }
        if s.steps > 0 {
            s.avg_sim_ms = s.total_sim_ms / s.steps as f64;
        }
        if energy_time > 0.0 {
            s.avg_power_w = s.total_energy_j / (energy_time * 1e-3);
        }
        s.ee = crate::rtcore::power::energy_efficiency(s.total_interactions, s.total_energy_j);
        s.wall_total_s = wall_start.elapsed_s();
        s.events = self.events.clone();
        s.replayed_steps = self.replayed;
        debug_assert!(
            self.cfg.pricing_profile().kind == DeviceKind::Cpu
                || self.cfg.approach != ApproachKind::CpuCell
        );
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::{Boundary, ParticleDist, RadiusDist};

    fn small_cfg(approach: ApproachKind) -> EngineConfig {
        let sim = SimConfig {
            n: 300,
            box_l: 200.0,
            particle_dist: ParticleDist::Disordered,
            radius_dist: RadiusDist::Const(6.0),
            boundary: Boundary::Periodic,
            ..SimConfig::default()
        };
        EngineConfig { threads: 2, policy: "fixed-10".into(), ..EngineConfig::new(sim, approach) }
    }

    #[test]
    fn all_backends_run_and_meter() {
        for approach in ApproachKind::ALL {
            let mut e = Engine::new_rust(small_cfg(approach)).unwrap();
            let s = e.run(5, true).unwrap();
            assert_eq!(s.steps, 5, "{approach}");
            assert!(s.avg_sim_ms > 0.0, "{approach}");
            assert!(s.total_energy_j > 0.0, "{approach}");
            assert!(s.total_interactions > 0, "{approach}");
            assert_eq!(s.records.len(), 5);
            assert!(e.state.is_finite());
        }
    }

    #[test]
    fn cpu_cell_priced_on_epyc() {
        let cfg = small_cfg(ApproachKind::CpuCell);
        assert_eq!(cfg.pricing_profile().name, "CPU-EPYC64");
        let cfg = small_cfg(ApproachKind::RtRef);
        assert_eq!(cfg.pricing_profile().name, "RTXPRO");
    }

    #[test]
    fn perse_rejects_variable_radius_at_construction() {
        let mut cfg = small_cfg(ApproachKind::OrcsPerse);
        cfg.sim.radius_dist = RadiusDist::Uniform(1.0, 5.0);
        assert!(Engine::new_rust(cfg).is_err());
    }

    #[test]
    fn backends_agree_on_trajectories() {
        // RT-REF, ORCS-forces, ORCS-perse, GPU-CELL, CPU-CELL must produce
        // the same physics (same forces => same positions) step for step.
        let mut positions = Vec::new();
        for approach in ApproachKind::ALL {
            let mut e = Engine::new_rust(small_cfg(approach)).unwrap();
            e.run(3, false).unwrap();
            positions.push((approach, e.state.pos.clone()));
        }
        let (ref_name, ref_pos) = &positions[0];
        for (name, pos) in &positions[1..] {
            for i in 0..ref_pos.len() {
                let d = (pos[i] - ref_pos[i]).norm();
                assert!(d < 1e-2, "{name} vs {ref_name} diverged at {i}: {d}");
            }
        }
    }
}
