//! In-house property-testing driver (the offline vendor set has no
//! `proptest`). Deterministic: case `i` of a named check always uses the
//! same RNG stream, and failures report the case seed so they can be
//! replayed with `ORCS_PROP_SEED`.
//!
//! `ORCS_PROP_CASES` scales the case count globally (CI vs deep runs).

use crate::core::rng::Rng;

/// Base seed for a named property (env-overridable).
fn base_seed(name: &str) -> u64 {
    if let Ok(s) = std::env::var("ORCS_PROP_SEED") {
        if let Ok(v) = s.parse() {
            return v;
        }
    }
    // FNV-1a over the name: stable across runs
    let mut h = 0xcbf29ce484222325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn case_multiplier() -> f64 {
    std::env::var("ORCS_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0)
}

/// Run `cases` randomized checks of a property. The closure returns
/// `Err(msg)` to report a violation; the driver panics with the case index
/// and seed for replay.
pub fn prop_check<F>(name: &str, cases: usize, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let cases = ((cases as f64 * case_multiplier()).ceil() as usize).max(1);
    let base = base_seed(name);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property `{name}` failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Generators for common scene ingredients.
pub mod gen {
    use crate::core::config::{Boundary, ParticleDist, RadiusDist, SimConfig};
    use crate::core::rng::Rng;

    pub fn boundary(rng: &mut Rng) -> Boundary {
        if rng.f32() < 0.5 {
            Boundary::Wall
        } else {
            Boundary::Periodic
        }
    }

    pub fn particle_dist(rng: &mut Rng) -> ParticleDist {
        ParticleDist::ALL[rng.below(3)]
    }

    pub fn radius_dist(rng: &mut Rng, scale: f32) -> RadiusDist {
        match rng.below(3) {
            0 => RadiusDist::Const(rng.range_f32(0.05 * scale, 0.3 * scale)),
            1 => RadiusDist::Uniform(0.02 * scale, rng.range_f32(0.1 * scale, 0.4 * scale)),
            _ => RadiusDist::LogNormal {
                mu: 0.0,
                sigma: 1.0,
                lo: 0.02 * scale,
                hi: 0.4 * scale,
            },
        }
    }

    /// A random small scenario (n in [lo, hi], box 100) suitable for
    /// brute-force cross-checking.
    pub fn small_config(rng: &mut Rng, lo: usize, hi: usize) -> SimConfig {
        let box_l = 100.0;
        SimConfig {
            n: lo + rng.below(hi - lo + 1),
            box_l,
            particle_dist: particle_dist(rng),
            radius_dist: radius_dist(rng, box_l * 0.3),
            boundary: boundary(rng),
            seed: rng.next_u64(),
            ..SimConfig::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_check_runs_all_cases() {
        let mut count = 0;
        prop_check("counter", 17, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 17);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn prop_check_reports_failures() {
        prop_check("fails", 5, |rng| {
            if rng.f32() >= 0.0 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn generators_cover_space() {
        let mut rng = crate::core::rng::Rng::new(1);
        let mut walls = 0;
        for _ in 0..100 {
            if gen::boundary(&mut rng) == crate::core::config::Boundary::Wall {
                walls += 1;
            }
            let cfg = gen::small_config(&mut rng, 10, 50);
            assert!((10..=50).contains(&cfg.n));
        }
        assert!(walls > 20 && walls < 80);
    }
}
