//! Minimal f32 3-vector used throughout the simulation.
//!
//! f32 matches the precision of the paper's GPU implementation; the box is
//! 1000³ so f32 gives ~6e-5 absolute position resolution, far below the
//! smallest interaction radius (r = 1).

use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub, SubAssign};

/// A 3-component single-precision vector.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Vec3 {
    pub x: f32,
    pub y: f32,
    pub z: f32,
}

impl Vec3 {
    pub const ZERO: Vec3 = Vec3 { x: 0.0, y: 0.0, z: 0.0 };

    #[inline(always)]
    pub const fn new(x: f32, y: f32, z: f32) -> Self {
        Vec3 { x, y, z }
    }

    /// All components set to `v`.
    #[inline(always)]
    pub const fn splat(v: f32) -> Self {
        Vec3 { x: v, y: v, z: v }
    }

    #[inline(always)]
    pub fn dot(self, o: Vec3) -> f32 {
        self.x * o.x + self.y * o.y + self.z * o.z
    }

    /// Squared Euclidean norm.
    #[inline(always)]
    pub fn norm2(self) -> f32 {
        self.dot(self)
    }

    #[inline(always)]
    pub fn norm(self) -> f32 {
        self.norm2().sqrt()
    }

    /// Component-wise minimum.
    #[inline(always)]
    pub fn min(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x.min(o.x), self.y.min(o.y), self.z.min(o.z))
    }

    /// Component-wise maximum.
    #[inline(always)]
    pub fn max(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x.max(o.x), self.y.max(o.y), self.z.max(o.z))
    }

    /// Component-wise absolute value.
    #[inline(always)]
    pub fn abs(self) -> Vec3 {
        Vec3::new(self.x.abs(), self.y.abs(), self.z.abs())
    }

    /// Largest component.
    #[inline(always)]
    pub fn max_component(self) -> f32 {
        self.x.max(self.y).max(self.z)
    }

    /// Component accessor by axis index (0 = x, 1 = y, 2 = z).
    #[inline(always)]
    pub fn axis(self, a: usize) -> f32 {
        match a {
            0 => self.x,
            1 => self.y,
            _ => self.z,
        }
    }

    /// Minimum-image displacement for a periodic cubic box of side `box_l`:
    /// each component of `self` is wrapped into `[-box_l/2, box_l/2)`.
    #[inline(always)]
    pub fn min_image(self, box_l: f32) -> Vec3 {
        #[inline(always)]
        fn wrap(d: f32, l: f32) -> f32 {
            d - l * (d / l).round()
        }
        Vec3::new(wrap(self.x, box_l), wrap(self.y, box_l), wrap(self.z, box_l))
    }

    /// True if every component is finite.
    #[inline(always)]
    pub fn is_finite(self) -> bool {
        self.x.is_finite() && self.y.is_finite() && self.z.is_finite()
    }
}

impl Add for Vec3 {
    type Output = Vec3;
    #[inline(always)]
    fn add(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x + o.x, self.y + o.y, self.z + o.z)
    }
}

impl Sub for Vec3 {
    type Output = Vec3;
    #[inline(always)]
    fn sub(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x - o.x, self.y - o.y, self.z - o.z)
    }
}

impl Mul<f32> for Vec3 {
    type Output = Vec3;
    #[inline(always)]
    fn mul(self, s: f32) -> Vec3 {
        Vec3::new(self.x * s, self.y * s, self.z * s)
    }
}

impl Div<f32> for Vec3 {
    type Output = Vec3;
    #[inline(always)]
    fn div(self, s: f32) -> Vec3 {
        Vec3::new(self.x / s, self.y / s, self.z / s)
    }
}

impl Neg for Vec3 {
    type Output = Vec3;
    #[inline(always)]
    fn neg(self) -> Vec3 {
        Vec3::new(-self.x, -self.y, -self.z)
    }
}

impl AddAssign for Vec3 {
    #[inline(always)]
    fn add_assign(&mut self, o: Vec3) {
        *self = *self + o;
    }
}

impl SubAssign for Vec3 {
    #[inline(always)]
    fn sub_assign(&mut self, o: Vec3) {
        *self = *self - o;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let a = Vec3::new(1.0, 2.0, 3.0);
        let b = Vec3::new(4.0, 5.0, 6.0);
        assert_eq!(a + b, Vec3::new(5.0, 7.0, 9.0));
        assert_eq!(b - a, Vec3::splat(3.0));
        assert_eq!(a * 2.0, Vec3::new(2.0, 4.0, 6.0));
        assert_eq!(b / 2.0, Vec3::new(2.0, 2.5, 3.0));
        assert_eq!(-a, Vec3::new(-1.0, -2.0, -3.0));
        assert_eq!(a.dot(b), 32.0);
        assert_eq!(a.norm2(), 14.0);
    }

    #[test]
    fn min_max_axis() {
        let a = Vec3::new(1.0, 5.0, 3.0);
        let b = Vec3::new(4.0, 2.0, 6.0);
        assert_eq!(a.min(b), Vec3::new(1.0, 2.0, 3.0));
        assert_eq!(a.max(b), Vec3::new(4.0, 5.0, 6.0));
        assert_eq!(a.axis(0), 1.0);
        assert_eq!(a.axis(1), 5.0);
        assert_eq!(a.axis(2), 3.0);
        assert_eq!(a.max_component(), 5.0);
    }

    #[test]
    fn min_image_wraps_to_half_box() {
        let l = 100.0;
        // displacement of 90 across a 100-box is really -10
        let d = Vec3::new(90.0, -90.0, 30.0).min_image(l);
        assert!((d.x - (-10.0)).abs() < 1e-4);
        assert!((d.y - 10.0).abs() < 1e-4);
        assert!((d.z - 30.0).abs() < 1e-4);
    }

    #[test]
    fn min_image_idempotent_within_half() {
        let d = Vec3::new(10.0, -20.0, 49.0);
        assert_eq!(d.min_image(100.0), d);
    }
}
