//! The gradient optimizer in a real simulation: it must adapt its rebuild
//! budget to the dynamics and never lose badly to the reference policies —
//! the paper's Fig. 8 claims at test scale.

use std::sync::Arc;

use orcs::coordinator::{Engine, EngineConfig, RunSummary};
use orcs::core::config::{Boundary, ParticleDist, RadiusDist, SimConfig};
use orcs::frnn::{ApproachKind, RustKernels};
use orcs::gradient::BvhAction;

fn run_policy(cfg: &SimConfig, policy: &str, steps: usize) -> RunSummary {
    let ec = EngineConfig {
        policy: policy.into(),
        threads: 2,
        check_oom: false,
        ..EngineConfig::new(cfg.clone(), ApproachKind::RtRef)
    };
    let mut e = Engine::new(ec, Arc::new(RustKernels { threads: 2 })).unwrap();
    e.run(steps, true).unwrap()
}

fn dynamic_cluster() -> SimConfig {
    // collapsing cluster: strong dynamics early, relaxing later — the
    // adaptive case of Fig. 8
    SimConfig {
        n: 1200,
        box_l: 150.0,
        particle_dist: ParticleDist::Cluster,
        radius_dist: RadiusDist::Const(8.0),
        boundary: Boundary::Periodic,
        seed: 77,
        dt: 2e-3,
        ..SimConfig::default()
    }
}

#[test]
fn gradient_rebuilds_adaptively_not_on_schedule() {
    let s = run_policy(&dynamic_cluster(), "gradient", 120);
    let rebuild_steps: Vec<u64> = s
        .records
        .iter()
        .filter(|r| r.bvh_action == Some(BvhAction::Build))
        .map(|r| r.step)
        .collect();
    assert!(rebuild_steps.len() > 2, "gradient never rebuilt: {rebuild_steps:?}");
    // intervals must vary (adaptivity), unlike fixed-k
    let intervals: Vec<u64> = rebuild_steps.windows(2).map(|w| w[1] - w[0]).collect();
    let min = intervals.iter().min().copied().unwrap_or(0);
    let max = intervals.iter().max().copied().unwrap_or(0);
    assert!(max > min, "intervals constant ({intervals:?}) — not adapting");
}

#[test]
fn gradient_competitive_with_best_fixed_policy() {
    let cfg = dynamic_cluster();
    let steps = 120;
    let g = run_policy(&cfg, "gradient", steps);
    // fixed-200 never rebuilds within this horizon; fixed-5 rebuilds hard
    let f200 = run_policy(&cfg, "fixed-200", steps);
    let f5 = run_policy(&cfg, "fixed-5", steps);
    let avg = run_policy(&cfg, "avg", steps);
    let best_ref = f200.total_rt_ms.min(f5.total_rt_ms).min(avg.total_rt_ms);
    assert!(
        g.total_rt_ms <= best_ref * 1.25,
        "gradient {:.3} ms vs best reference {:.3} ms (f200 {:.3}, f5 {:.3}, avg {:.3})",
        g.total_rt_ms,
        best_ref,
        f200.total_rt_ms,
        f5.total_rt_ms,
        avg.total_rt_ms
    );
}

#[test]
fn gradient_beats_fixed_200_on_fast_dynamics() {
    // hot, fast-moving dense system degrades the BVH quickly: waiting 200
    // steps to rebuild must lose
    let mut cfg = dynamic_cluster();
    cfg.dt = 5e-3;
    cfg.n = 1500;
    let steps = 100;
    let g = run_policy(&cfg, "gradient", steps);
    let f200 = run_policy(&cfg, "fixed-200", steps);
    assert!(
        g.total_rt_ms < f200.total_rt_ms,
        "gradient {:.3} ms should beat fixed-200 {:.3} ms on fast dynamics",
        g.total_rt_ms,
        f200.total_rt_ms
    );
}

#[test]
fn query_cost_degrades_between_rebuilds() {
    // within one policy cycle the simulated traverse cost grows with
    // updates — the Δq the cost model integrates (Fig. 3)
    let s = run_policy(&dynamic_cluster(), "fixed-40", 41);
    let recs = &s.records;
    let first_cycle: Vec<&orcs::coordinator::StepRecord> =
        recs.iter().skip(1).take(35).collect(); // updates after the initial build
    let early: f64 = first_cycle[..5].iter().map(|r| r.sim_times.traverse).sum::<f64>() / 5.0;
    let late: f64 =
        first_cycle[first_cycle.len() - 5..].iter().map(|r| r.sim_times.traverse).sum::<f64>()
            / 5.0;
    assert!(
        late > early,
        "traverse cost should degrade: early {early:.3e} late {late:.3e}"
    );
}

#[test]
fn all_policies_preserve_physics() {
    // the BVH policy changes cost only, never trajectories
    let cfg = dynamic_cluster();
    let mut positions = Vec::new();
    for policy in ["gradient", "avg", "fixed-7"] {
        let ec = EngineConfig {
            policy: policy.into(),
            threads: 2,
            check_oom: false,
            ..EngineConfig::new(cfg.clone(), ApproachKind::RtRef)
        };
        let mut e = Engine::new(ec, Arc::new(RustKernels { threads: 2 })).unwrap();
        e.run(15, false).unwrap();
        positions.push(e.state.pos.clone());
    }
    for other in &positions[1..] {
        assert_eq!(&positions[0], other, "policies changed the physics");
    }
}
