//! Chunk/pad bucketing: map arbitrary `(n, per-particle list length)`
//! workloads onto the static artifact shapes `(CHUNK, K_BUCKETS)`.
//!
//! * particles are processed in `CHUNK`-sized blocks (tail zero-padded and
//!   masked out);
//! * each block's neighbor lists go into the smallest `K` bucket that fits
//!   the block's widest list;
//! * lists wider than the largest bucket are split into segments and the
//!   partial forces summed (forces are additive over neighbors).

use super::K_BUCKETS;

/// Smallest bucket with `bucket >= k`, or `None` if `k` exceeds the widest.
pub fn bucket_for(k: usize) -> Option<usize> {
    K_BUCKETS.iter().copied().find(|&b| b >= k)
}

/// Split a list width into (bucket, number of segments): segments of the
/// widest bucket plus a final bucket sized for the remainder.
///
/// Returns the per-segment plan as (segment_count_full, tail_bucket).
pub fn segment_plan(k: usize) -> (usize, Option<usize>) {
    let widest = *K_BUCKETS.last().unwrap();
    if k == 0 {
        return (0, Some(K_BUCKETS[0])); // one all-masked segment keeps shapes simple
    }
    if let Some(b) = bucket_for(k) {
        return (0, Some(b));
    }
    let full = k / widest;
    let rem = k % widest;
    if rem == 0 {
        (full, None)
    } else {
        (full, Some(bucket_for(rem).unwrap()))
    }
}

/// Number of chunks needed for `n` particles.
pub fn chunk_count(n: usize, chunk: usize) -> usize {
    n.div_ceil(chunk.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        assert_eq!(bucket_for(0), Some(16));
        assert_eq!(bucket_for(16), Some(16));
        assert_eq!(bucket_for(17), Some(64));
        assert_eq!(bucket_for(256), Some(256));
        assert_eq!(bucket_for(257), None);
    }

    #[test]
    fn segment_plans() {
        assert_eq!(segment_plan(0), (0, Some(16)));
        assert_eq!(segment_plan(10), (0, Some(16)));
        assert_eq!(segment_plan(200), (0, Some(256)));
        assert_eq!(segment_plan(256), (0, Some(256)));
        assert_eq!(segment_plan(300), (1, Some(64)));
        assert_eq!(segment_plan(512), (2, None));
        assert_eq!(segment_plan(513), (2, Some(16)));
        assert_eq!(segment_plan(1000), (3, Some(256)));
    }

    #[test]
    fn chunk_counts() {
        assert_eq!(chunk_count(0, 4096), 0);
        assert_eq!(chunk_count(1, 4096), 1);
        assert_eq!(chunk_count(4096, 4096), 1);
        assert_eq!(chunk_count(4097, 4096), 2);
    }
}
