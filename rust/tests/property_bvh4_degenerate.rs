//! Degenerate-geometry property tests for the BVH4 SoA path: coincident
//! particles, fewer primitives than the node width, zero radii, empty
//! scenes, refit-degraded trees queried through a forced traversal stack
//! spill, and the periodic large-radius (`r > box_l / 2`) ray regime.
//! Every case is anchored against the O(n²) oracle.

use orcs::bvh::traverse::QueryScratch;
use orcs::bvh::{BuildKind, Bvh, BVH4_WIDTH};
use orcs::core::config::Boundary;
use orcs::core::rng::Rng;
use orcs::core::vec3::Vec3;
use orcs::frnn::{brute, rt_common::launch_rays};
use orcs::testutil::prop_check;

fn brute(p: Vec3, exclude: usize, pos: &[Vec3], radius: &[f32]) -> Vec<usize> {
    (0..pos.len())
        .filter(|&j| j != exclude && (p - pos[j]).norm2() < radius[j] * radius[j])
        .collect()
}

fn build_kind(rng: &mut Rng) -> BuildKind {
    match rng.below(3) {
        0 => BuildKind::Median,
        1 => BuildKind::BinnedSah,
        _ => BuildKind::Lbvh,
    }
}

#[test]
fn prop_all_coincident_particles() {
    // every centroid identical: splits degenerate to forced half splits,
    // and every query point is inside every lane box
    prop_check("bvh4-coincident", 20, |rng| {
        let n = 1 + rng.below(60);
        let at = Vec3::new(
            rng.range_f32(0.0, 50.0),
            rng.range_f32(0.0, 50.0),
            rng.range_f32(0.0, 50.0),
        );
        let pos = vec![at; n];
        let radius: Vec<f32> = (0..n).map(|_| rng.range_f32(0.1, 5.0)).collect();
        let kind = build_kind(rng);
        let bvh = Bvh::build(&pos, &radius, kind);
        bvh.check_invariants(&pos, &radius).map_err(|e| e.to_string())?;
        let mut scratch = QueryScratch::new();
        for i in 0..n {
            let mut got = bvh.query_point_collect(pos[i], i, &pos, &radius, &mut scratch);
            got.sort_unstable();
            if got != brute(pos[i], i, &pos, &radius) {
                return Err(format!("{kind:?} coincident mismatch at {i} (n={n})"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fewer_prims_than_node_width() {
    // n < 4: the whole tree is a single node with one leaf lane
    prop_check("bvh4-tiny-n", 30, |rng| {
        let n = 1 + rng.below(BVH4_WIDTH - 1); // 1..=3
        let pos: Vec<Vec3> = (0..n)
            .map(|_| {
                Vec3::new(
                    rng.range_f32(0.0, 20.0),
                    rng.range_f32(0.0, 20.0),
                    rng.range_f32(0.0, 20.0),
                )
            })
            .collect();
        let radius: Vec<f32> = (0..n).map(|_| rng.range_f32(0.5, 10.0)).collect();
        let kind = build_kind(rng);
        let bvh = Bvh::build(&pos, &radius, kind);
        bvh.check_invariants(&pos, &radius).map_err(|e| e.to_string())?;
        if bvh.node_count() != 1 {
            return Err(format!("n={n} built {} nodes", bvh.node_count()));
        }
        let mut scratch = QueryScratch::new();
        // query from every particle and from an outside point
        for i in 0..n {
            let mut got = bvh.query_point_collect(pos[i], i, &pos, &radius, &mut scratch);
            got.sort_unstable();
            if got != brute(pos[i], i, &pos, &radius) {
                return Err(format!("{kind:?} tiny-n mismatch at {i}"));
            }
        }
        let far = Vec3::splat(1000.0);
        if !bvh.query_point_collect(far, usize::MAX, &pos, &radius, &mut scratch).is_empty() {
            return Err("far point found phantom neighbors".into());
        }
        Ok(())
    });
}

#[test]
fn prop_zero_radii_find_nothing() {
    // r = 0 spheres contain no point (strict inequality), even their own
    // center; the BVH must agree with the oracle everywhere
    prop_check("bvh4-zero-radius", 15, |rng| {
        let n = 5 + rng.below(100);
        let pos: Vec<Vec3> = (0..n)
            .map(|_| {
                Vec3::new(
                    rng.range_f32(0.0, 30.0),
                    rng.range_f32(0.0, 30.0),
                    rng.range_f32(0.0, 30.0),
                )
            })
            .collect();
        let radius = vec![0.0f32; n];
        let kind = build_kind(rng);
        let bvh = Bvh::build(&pos, &radius, kind);
        bvh.check_invariants(&pos, &radius).map_err(|e| e.to_string())?;
        let mut scratch = QueryScratch::new();
        for i in 0..n {
            let got = bvh.query_point_collect(pos[i], usize::MAX, &pos, &radius, &mut scratch);
            if !got.is_empty() {
                return Err(format!("zero radius produced hits {got:?} at {i}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_refit_degraded_tree_with_forced_stack_spill() {
    // long refit sequences inflate lane boxes (deep multi-lane descents);
    // a stack limit of 1 routes nearly every push through the heap spill —
    // results and stats must match the default scratch and the oracle
    prop_check("bvh4-spill-after-refits", 10, |rng| {
        let n = 200 + rng.below(600);
        let mut pos: Vec<Vec3> = (0..n)
            .map(|_| {
                Vec3::new(
                    rng.range_f32(0.0, 60.0),
                    rng.range_f32(0.0, 60.0),
                    rng.range_f32(0.0, 60.0),
                )
            })
            .collect();
        let radius: Vec<f32> = (0..n).map(|_| rng.range_f32(0.5, 8.0)).collect();
        let kind = build_kind(rng);
        let mut bvh = Bvh::build(&pos, &radius, kind);
        for _ in 0..6 {
            for p in pos.iter_mut() {
                *p += Vec3::new(
                    rng.range_f32(-4.0, 4.0),
                    rng.range_f32(-4.0, 4.0),
                    rng.range_f32(-4.0, 4.0),
                );
            }
            bvh.refit(&pos, &radius);
        }
        bvh.check_invariants(&pos, &radius).map_err(|e| e.to_string())?;
        let mut plain = QueryScratch::new();
        let mut spilly = QueryScratch::with_stack_limit(1);
        for i in 0..n {
            let a = bvh.query_point_collect(pos[i], i, &pos, &radius, &mut plain);
            let b = bvh.query_point_collect(pos[i], i, &pos, &radius, &mut spilly);
            if a != b {
                return Err(format!("{kind:?} spill diverged at {i}"));
            }
            let mut sorted = a;
            sorted.sort_unstable();
            if sorted != brute(pos[i], i, &pos, &radius) {
                return Err(format!("{kind:?} degraded-tree mismatch at {i}"));
            }
        }
        if plain.take_stats() != spilly.take_stats() {
            return Err("spill changed traversal stats".into());
        }
        Ok(())
    });
}

#[test]
fn prop_periodic_large_radius_matches_min_image_oracle() {
    // log-normal-tail regime: at least one search radius above box_l / 2.
    // The pre-fix ray set double-counted neighbors (primary + gamma both
    // hit) with non-min-image displacements and could miss neighbors
    // outright (one-shift-per-axis gammas are incomplete here); post-fix,
    // every particle's emissions must equal the brute min-image detection
    // set exactly once each, with min-image displacements.
    prop_check("periodic-large-radius-rays", 15, |rng| {
        let box_l = rng.range_f32(8.0, 40.0);
        let n = 2 + rng.below(20);
        let pos: Vec<Vec3> = (0..n)
            .map(|_| {
                Vec3::new(
                    rng.range_f32(0.0, box_l),
                    rng.range_f32(0.0, box_l),
                    rng.range_f32(0.0, box_l),
                )
            })
            .collect();
        let mut radius: Vec<f32> =
            (0..n).map(|_| rng.range_f32(0.1 * box_l, 1.2 * box_l)).collect();
        radius[0] = rng.range_f32(0.55 * box_l, 1.2 * box_l); // force the regime
        let trigger = radius.iter().fold(0.0f32, |a, &r| a.max(r));
        let kind = build_kind(rng);
        let bvh = Bvh::build(&pos, &radius, kind);
        let mut scratch = QueryScratch::new();
        for i in 0..n {
            let mut got: Vec<(usize, Vec3)> = Vec::new();
            launch_rays(
                &bvh,
                i,
                &pos,
                &radius,
                Boundary::Periodic,
                box_l,
                trigger,
                &mut scratch,
                |j, dx| got.push((j, dx)),
            );
            let ids: Vec<usize> = got.iter().map(|&(j, _)| j).collect();
            let mut uniq = ids.clone();
            uniq.sort_unstable();
            uniq.dedup();
            if uniq.len() != ids.len() {
                return Err(format!("{kind:?} particle {i}: duplicate emissions {ids:?}"));
            }
            let want =
                brute::detection_neighbors(i, &pos, &radius, Boundary::Periodic, box_l);
            if uniq != want {
                return Err(format!(
                    "{kind:?} particle {i}: ids {uniq:?} != oracle {want:?} \
                     (box_l={box_l}, trigger={trigger})"
                ));
            }
            for &(j, dx) in &got {
                let dmin = (pos[i] - pos[j]).min_image(box_l);
                if (dx - dmin).norm() > 1e-5 * box_l {
                    return Err(format!(
                        "{kind:?} pair ({i},{j}): dx {dx:?} is not min-image {dmin:?}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn empty_scene_queries_and_refits() {
    let bvh = Bvh::build(&[], &[], BuildKind::Lbvh);
    bvh.check_invariants(&[], &[]).unwrap();
    let mut scratch = QueryScratch::new();
    let got = bvh.query_point_collect(Vec3::ZERO, usize::MAX, &[], &[], &mut scratch);
    assert!(got.is_empty());
    assert_eq!(scratch.stats.rays, 1);
    assert_eq!(scratch.stats.aabb_tests, 0);
    let mut bvh = bvh;
    bvh.refit(&[], &[]);
    bvh.check_invariants(&[], &[]).unwrap();
}
