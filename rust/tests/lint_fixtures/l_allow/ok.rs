// Fixture: clean twin — a well-formed suppression with a reason, which
// cleanly absorbs the P-PANIC finding on the line below it.
pub fn demand(xs: &[u32]) -> u32 {
    // lint:allow(P-PANIC): fixture — the caller guarantees non-empty input
    *xs.first().expect("non-empty")
}
