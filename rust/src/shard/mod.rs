//! **Sharded domain decomposition** — the multi-device scaling layer.
//!
//! The coordinator's [`Engine`](crate::coordinator::Engine) runs one scene
//! on one BVH on one simulated device. This subsystem decomposes the
//! periodic box into an `S³` grid of subdomains ([`decomp::ShardGrid`]) and
//! steps them concurrently, the way RTNN partitions queries spatially and
//! RT-kNNS manages per-partition acceleration structures:
//!
//! * **per-shard ownership with migration** — particles belong to the shard
//!   under their position; integration migrates them across faces;
//! * **ghost/halo exchange** — each shard materializes the periodic images
//!   within `r_max` of its box as local ghost primitives
//!   ([`decomp::gather_ghosts`]), generalizing the single-domain 26-image
//!   sweep to shard faces, so periodic BC costs nothing beyond the halo;
//! * **a private BVH + rebuild policy per shard** — membership churn forces
//!   rebuilds while stable shards refit, so the gradient optimizer finally
//!   sees (and adapts to) heterogeneous dynamics;
//! * **deterministic shard-ordered merges** — per-owned neighbor lists are
//!   canonicalized (ascending global id, deduplicated) and merged into one
//!   global CSR, making forces and positions **bitwise identical** to the
//!   single-domain engine for any shard count and `ORCS_THREADS`;
//! * **first-class listless backends** — ORCS-forces and ORCS-persé run
//!   sharded ([`ShardedConfig::backend`]): the same canonical per-owned
//!   entries are folded in ascending-global-id order over shard-local
//!   views instead of being materialized as lists, preserving the bitwise
//!   contract with zero list bytes metered on any device;
//! * **heterogeneous fleet pricing** — each shard binds its own
//!   [`HwProfile`](crate::rtcore::HwProfile); step time aggregates as the
//!   max over devices, energy as the sum, and the RT-REF list allocation is
//!   metered **per shard** against each device's VRAM
//!   ([`crate::rtcore::fleet`]) — log-normal cluster scenes that OOM a
//!   single device complete once sharded.

pub mod decomp;
pub mod engine;

pub use decomp::{ShardGrid, ShardMember};
pub use engine::{
    ShardStepStat, ShardTotals, ShardedConfig, ShardedEngine, ShardedRunSummary,
    ShardedStepRecord,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::{Boundary, ParticleDist, RadiusDist, ShardSpec, SimConfig};
    use crate::rtcore::profile::{L40, RTXPRO, TITANRTX};

    fn small_cfg(s: usize, boundary: Boundary) -> ShardedConfig {
        let sim = SimConfig {
            n: 250,
            box_l: 120.0,
            particle_dist: ParticleDist::Disordered,
            radius_dist: RadiusDist::Uniform(2.0, 10.0),
            boundary,
            seed: 7,
            ..SimConfig::default()
        };
        ShardedConfig {
            threads: 2,
            policy: "fixed-6".into(),
            check_oom: false,
            ..ShardedConfig::new(sim, ShardSpec::new(s))
        }
    }

    #[test]
    fn sharded_engine_steps_and_meters() {
        for boundary in Boundary::ALL {
            for s in [1usize, 2] {
                let mut e = ShardedEngine::new_rust(small_cfg(s, boundary)).unwrap();
                let summary = e.run(4, true).unwrap();
                assert_eq!(summary.steps, 4, "{boundary} s={s}");
                assert_eq!(e.shard_count(), s * s * s);
                assert!(summary.avg_sim_ms > 0.0);
                assert!(summary.total_energy_j > 0.0);
                assert!(summary.total_interactions > 0);
                assert_eq!(summary.per_shard.len(), s * s * s);
                assert_eq!(summary.records.len(), 4);
                // every step's per-shard owned counts partition the scene
                for rec in &summary.records {
                    let owned: usize = rec.per_shard.iter().map(|p| p.owned).sum();
                    assert_eq!(owned, 250);
                }
                assert!(e.state.is_finite());
                assert_eq!(e.state.step_count, 4);
            }
        }
    }

    #[test]
    fn multi_shard_runs_exchange_ghosts() {
        let mut e = ShardedEngine::new_rust(small_cfg(2, Boundary::Periodic)).unwrap();
        let rec = e.step().unwrap();
        // halo width 10 on 60-wide subdomains: many boundary-band particles
        assert!(rec.ghost_entries > 0);
        // the aggregate step is gated by one shard
        assert!(rec.straggler < 8);
        assert!(rec.sim_ms >= rec.per_shard.iter().map(|p| p.sim_ms).fold(0.0, f64::max) - 1e-12);
    }

    #[test]
    fn heterogeneous_fleet_prices_straggler_and_sums_energy() {
        let mut cfg = small_cfg(2, Boundary::Periodic);
        cfg.fleet = vec![&TITANRTX, &L40];
        let mut e = ShardedEngine::new_rust(cfg).unwrap();
        assert_eq!(e.shard_hw(0).name, "TITANRTX");
        assert_eq!(e.shard_hw(1).name, "L40");
        assert_eq!(e.shard_hw(2).name, "TITANRTX"); // round-robin
        let rec = e.step().unwrap();
        let sum: f64 = rec.per_shard.iter().map(|p| p.energy_j).sum();
        assert!((rec.energy_j - sum).abs() < 1e-9 * sum.max(1.0));
        let summary = e.run(3, false).unwrap();
        assert_eq!(summary.fleet, "TITANRTX+L40");
    }

    #[test]
    fn per_shard_oom_fires_on_small_device() {
        // a dense scene whose fixed-slot list exceeds a 1 KB device
        static TINY: crate::rtcore::HwProfile = {
            let mut p = RTXPRO;
            p.vram_bytes = 1024;
            p
        };
        let mut cfg = small_cfg(1, Boundary::Wall);
        cfg.sim.radius_dist = RadiusDist::Const(50.0);
        cfg.sim.box_l = 40.0;
        cfg.check_oom = true;
        cfg.fleet = vec![&TINY];
        let mut e = ShardedEngine::new_rust(cfg).unwrap();
        let summary = e.run(3, false).unwrap();
        assert!(summary.oom, "expected per-shard OOM");
        assert!(summary.oom_bytes > 1024);
        assert_eq!(summary.steps, 1); // aborts on the OOM step
    }

    #[test]
    fn empty_and_singleton_scenes_are_legal() {
        for n in [0usize, 1] {
            let mut cfg = small_cfg(2, Boundary::Periodic);
            cfg.sim.n = n;
            let mut e = ShardedEngine::new_rust(cfg).unwrap();
            let summary = e.run(2, false).unwrap();
            assert_eq!(summary.steps, 2);
            assert_eq!(summary.total_interactions, 0);
            assert!(!summary.oom);
        }
    }
}
