"""L1 correctness: the Pallas LJ kernel against the pure-jnp oracle.

This is the CORE correctness signal of the compile path — hypothesis sweeps
shapes, masks, radii and box modes and asserts allclose against `ref.py`.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.lj import lj_forces_pallas
from compile.kernels.ref import integrate_ref, lj_forces_ref, lj_pair_terms, min_image
from compile.shapes import BLOCK_C, WALL_BOX


def make_case(seed, c, k, box_l, rad_lo, rad_hi, mask_p):
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0, min(box_l, 1000.0), (c, 3)).astype(np.float32)
    # neighbors near their particle so a fair share is inside the cutoff
    jitter = rng.normal(0, rad_hi, (c, k, 3)).astype(np.float32)
    nbr_pos = (pos[:, None, :] + jitter).astype(np.float32)
    if box_l < WALL_BOX:
        nbr_pos = np.mod(nbr_pos, box_l)
    rad = rng.uniform(rad_lo, rad_hi, (c,)).astype(np.float32)
    nbr_rad = rng.uniform(rad_lo, rad_hi, (c, k)).astype(np.float32)
    mask = (rng.uniform(size=(c, k)) < mask_p).astype(np.float32)
    scal = np.array([box_l, 1.0, 2.5, 1e4], np.float32)
    return pos, nbr_pos, rad, nbr_rad, mask, scal


def assert_kernel_matches_ref(args, rtol=1e-5, atol=1e-4):
    pos, nbr_pos, rad, nbr_rad, mask, scal = args
    f_k, pe_k = lj_forces_pallas(pos, nbr_pos, rad, nbr_rad, mask, scal)
    f_r, pe_r = lj_forces_ref(
        pos, nbr_pos, rad, nbr_rad, mask, scal[0], scal[1], scal[2], scal[3]
    )
    np.testing.assert_allclose(f_k, f_r, rtol=rtol, atol=atol)
    np.testing.assert_allclose(pe_k, pe_r, rtol=rtol, atol=atol)


@pytest.mark.parametrize("k", [16, 64, 256])
def test_kernel_matches_ref_buckets(k):
    assert_kernel_matches_ref(make_case(1, BLOCK_C * 2, k, 1000.0, 1.0, 20.0, 0.7))


@pytest.mark.parametrize("box_l", [100.0, 1000.0, WALL_BOX])
def test_kernel_matches_ref_box_modes(box_l):
    assert_kernel_matches_ref(make_case(2, BLOCK_C, 16, box_l, 1.0, 10.0, 0.5))


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    c_blocks=st.integers(1, 3),
    k=st.sampled_from([16, 64]),
    periodic=st.booleans(),
    rad_hi=st.floats(2.0, 160.0),
    mask_p=st.floats(0.0, 1.0),
)
def test_kernel_matches_ref_hypothesis(seed, c_blocks, k, periodic, rad_hi, mask_p):
    box_l = 1000.0 if periodic else WALL_BOX
    args = make_case(seed, BLOCK_C * c_blocks, k, box_l, 1.0, rad_hi, mask_p)
    assert_kernel_matches_ref(args)


def test_all_masked_yields_zero():
    pos, nbr_pos, rad, nbr_rad, mask, scal = make_case(3, BLOCK_C, 16, 1000.0, 1.0, 10.0, 1.0)
    mask[:] = 0.0
    f, pe = lj_forces_pallas(pos, nbr_pos, rad, nbr_rad, mask, scal)
    assert np.all(np.asarray(f) == 0.0)
    assert np.all(np.asarray(pe) == 0.0)


def test_overlap_guard_finite_and_capped():
    # neighbor exactly at the particle position: r2 = 0 -> excluded (self);
    # neighbor epsilon away: guarded by R2_MIN and the force cap
    pos, nbr_pos, rad, nbr_rad, mask, scal = make_case(4, BLOCK_C, 16, WALL_BOX, 1.0, 5.0, 1.0)
    nbr_pos[:, 0, :] = pos  # exact overlap
    nbr_pos[:, 1, :] = pos + 1e-5
    f, pe = lj_forces_pallas(pos, nbr_pos, rad, nbr_rad, mask, scal)
    f = np.asarray(f)
    assert np.all(np.isfinite(f))
    assert np.all(np.isfinite(np.asarray(pe)))
    # the capped near-overlap contribution cannot exceed K * f_max
    assert np.max(np.abs(f)) <= 16 * scal[3] + 1e-3


def test_force_cap_respected_per_pair():
    pos, nbr_pos, rad, nbr_rad, mask, scal = make_case(5, BLOCK_C, 16, WALL_BOX, 1.0, 5.0, 0.0)
    # single valid close neighbor per particle, tiny cap
    mask[:, 0] = 1.0
    nbr_pos[:, 0, :] = pos + np.array([0.02, 0, 0], np.float32)
    scal[3] = 0.5  # f_max
    f, _ = lj_forces_pallas(pos, nbr_pos, rad, nbr_rad, mask, scal)
    assert np.max(np.abs(np.asarray(f))) <= 0.5 + 1e-6


def test_min_image_helper():
    dx = jnp.array([90.0, -90.0, 30.0])
    w = min_image(dx, 100.0)
    np.testing.assert_allclose(np.asarray(w), [-10.0, 10.0, 30.0], atol=1e-5)
    # wall sentinel: no wrap
    w2 = min_image(dx, WALL_BOX)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(dx), atol=1e-5)


def test_pair_terms_lj_shape():
    # U(sigma) = 0, min at 2^(1/6) sigma with U = -eps
    sigma = jnp.float32(1.0)
    _, u_at_sigma = lj_pair_terms(jnp.float32(1.0), sigma, jnp.float32(1.0))
    assert abs(float(u_at_sigma)) < 1e-5
    rmin2 = jnp.float32(2.0 ** (1 / 3))
    s, u_min = lj_pair_terms(rmin2, sigma, jnp.float32(1.0))
    assert abs(float(u_min) + 1.0) < 1e-5
    assert abs(float(s)) < 1e-4


def test_integrate_ref_euler():
    pos = jnp.zeros((4, 3))
    vel = jnp.ones((4, 3))
    force = jnp.full((4, 3), 2.0)
    new_pos, new_vel = integrate_ref(pos, vel, force, 0.5, 1e4)
    np.testing.assert_allclose(np.asarray(new_vel), 2.0)   # 1 + 2*0.5
    np.testing.assert_allclose(np.asarray(new_pos), 1.0)   # 0 + 2*0.5
