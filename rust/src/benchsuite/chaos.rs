//! Chaos bench (`orcs bench-chaos`): recovery overhead vs injected fault
//! rate on a sharded heterogeneous fleet.
//!
//! For each fault rate a seeded [`FaultPlan`] (transients, stragglers, up
//! to two device losses) is injected into an otherwise identical S = 2 run
//! with checkpoints every 4 steps. Because recovery replays from step
//! boundaries and degradation never changes the canonical neighbor lists,
//! every faulted run must end **bitwise identical** to the fault-free
//! baseline — the bench asserts it per row. What faults *do* cost is
//! priced time: wasted attempts, switch re-staging, straggler-gated steps
//! and checkpoint replay, reported as overhead over the baseline.

use anyhow::Result;

use super::common::BenchOpts;
use crate::coordinator::metrics::fmt_ms;
use crate::coordinator::report::{results_dir, CsvWriter, TextTable};
use crate::core::config::{Boundary, ParticleDist, RadiusDist, ShardSpec, SimConfig};
use crate::resilience::{FaultPlan, OomPolicy, ResilienceConfig, WatchdogCfg};
use crate::rtcore::profile::{A40, L40, RTXPRO, TITANRTX};
use crate::shard::{ShardedConfig, ShardedEngine, ShardedRunSummary};

const N_DEFAULT: usize = 2_000;
const STEPS_DEFAULT: usize = 16;

/// Fault rates swept (probability a step draws a fault).
const RATES: [f64; 4] = [0.0, 0.05, 0.1, 0.2];

/// One chaos run: uniform-radius disordered gas, S = 2 over a four-device
/// fleet, full resilience stack, faults drawn at `rate`.
fn chaos_run(
    opts: &BenchOpts,
    n: usize,
    steps: usize,
    rate: f64,
) -> Result<(ShardedRunSummary, Vec<crate::core::vec3::Vec3>, u64)> {
    let sim = SimConfig {
        n,
        particle_dist: ParticleDist::Disordered,
        radius_dist: RadiusDist::Const(6.0),
        boundary: Boundary::Periodic,
        seed: opts.seed,
        ..SimConfig::default()
    };
    let spec = ShardSpec::new(2);
    let resilience = ResilienceConfig {
        on_oom: OomPolicy::Fallback,
        watchdog: WatchdogCfg { enabled: true, ..WatchdogCfg::default() },
        checkpoint_every: 4,
        faults: FaultPlan::seeded(opts.seed, steps as u64, rate, spec.count(), 2),
    };
    let cfg = ShardedConfig {
        policy: "gradient".into(),
        fleet: vec![&TITANRTX, &A40, &L40, &RTXPRO],
        threads: opts.threads,
        check_oom: true,
        resilience,
        ..ShardedConfig::new(sim, spec)
    };
    let mut engine = ShardedEngine::new(cfg, opts.kernels.clone())?;
    let summary = engine.run(steps, false)?;
    // the forensics payload a post-mortem would ship: the flight-recorder
    // dump of the last K steps, with every fault/recovery marker inline
    let recorder_bytes = engine.telemetry().flight_dump().len() as u64;
    Ok((summary, engine.state.pos.clone(), recorder_bytes))
}

fn bitwise_equal(a: &[crate::core::vec3::Vec3], b: &[crate::core::vec3::Vec3]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(p, q)| {
            p.x.to_bits() == q.x.to_bits()
                && p.y.to_bits() == q.y.to_bits()
                && p.z.to_bits() == q.z.to_bits()
        })
}

pub fn run(opts: &BenchOpts) -> Result<()> {
    let (n, steps) = opts.size(N_DEFAULT, STEPS_DEFAULT);
    println!("== Chaos: recovery overhead vs fault rate (n={n}, {steps} steps, S=2) ==\n");

    let mut csv = CsvWriter::create(
        &results_dir().join("chaos.csv"),
        &[
            "rate",
            "steps",
            "replayed",
            "events",
            "total_sim_ms",
            "overhead_pct",
            "bitwise_match",
            "recorder_bytes",
        ],
    )?;
    let mut table = TextTable::new(&[
        "rate", "steps", "replayed", "events", "total ms", "overhead", "bitwise", "recorder B",
    ]);

    let mut baseline: Option<(f64, Vec<crate::core::vec3::Vec3>)> = None;
    for rate in RATES {
        let (summary, pos, recorder_bytes) = chaos_run(opts, n, steps, rate)?;
        anyhow::ensure!(!summary.oom, "chaos run at rate {rate} aborted on OOM");
        let (base_ms, base_pos) = match &baseline {
            Some(b) => (b.0, b.1.as_slice()),
            None => {
                baseline = Some((summary.total_sim_ms, pos.clone()));
                (summary.total_sim_ms, pos.as_slice())
            }
        };
        let overhead = if base_ms > 0.0 {
            (summary.total_sim_ms - base_ms) / base_ms * 100.0
        } else {
            0.0
        };
        let bitwise = bitwise_equal(&pos, base_pos);
        anyhow::ensure!(
            bitwise,
            "rate {rate}: faulted-and-recovered trajectory diverged from the baseline"
        );
        table.row(vec![
            format!("{rate:.2}"),
            summary.steps.to_string(),
            summary.replayed_steps.to_string(),
            summary.events.len().to_string(),
            fmt_ms(summary.total_sim_ms),
            format!("{overhead:+.1}%"),
            bitwise.to_string(),
            recorder_bytes.to_string(),
        ]);
        csv.row(&[
            format!("{rate:.2}"),
            summary.steps.to_string(),
            summary.replayed_steps.to_string(),
            summary.events.len().to_string(),
            format!("{:.4}", summary.total_sim_ms),
            format!("{overhead:.2}"),
            bitwise.to_string(),
            recorder_bytes.to_string(),
        ])?;
    }
    println!("{}", table.render());
    println!("CSV: {}", results_dir().join("chaos.csv").display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frnn::RustKernels;
    use std::sync::Arc;

    fn opts() -> BenchOpts {
        BenchOpts {
            threads: 2,
            hw: crate::rtcore::profile::DEFAULT_GPU,
            kernels: Arc::new(RustKernels { threads: 2 }),
            quick: true,
            steps_override: None,
            n_override: None,
            seed: 0xC0FFEE,
        }
    }

    #[test]
    fn faulted_run_matches_baseline_bitwise() {
        let o = opts();
        let (clean, clean_pos, clean_rec) = chaos_run(&o, 400, 10, 0.0).unwrap();
        assert!(!clean.oom);
        assert_eq!(clean.steps, 10);
        assert_eq!(clean.replayed_steps, 0);
        assert!(clean_rec > 0, "the flight recorder always retains the tail");
        // a rate high enough that the seeded plan is guaranteed non-empty
        let (chaotic, chaotic_pos, chaotic_rec) = chaos_run(&o, 400, 10, 0.5).unwrap();
        assert!(!chaotic.oom);
        assert!(!chaotic.events.is_empty(), "0.5 rate over 10 steps must fire");
        assert!(bitwise_equal(&clean_pos, &chaotic_pos), "recovery must replay bitwise");
        assert!(chaotic.total_sim_ms >= clean.total_sim_ms, "faults cannot be free");
        assert!(chaotic_rec > 0, "faulted runs carry a forensics dump");
    }
}
