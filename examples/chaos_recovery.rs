//! Resilience-runtime walkthrough: a scripted fault schedule — transient,
//! straggler, device loss, VRAM squeeze, injected divergence — thrown at a
//! sharded run that must survive all of it and end with healthy physics.
//!
//! ```sh
//! cargo run --release --example chaos_recovery
//! ```
//!
//! What to watch in the output:
//!   step  2  a transient fault: the attempt is discarded, priced, re-run;
//!   step  3  shard 0 throttles 4x: the fleet step is straggler-gated;
//!   step  7  a device dies: the fleet shrinks from two devices to one,
//!            shards rebind, and the run replays from the checkpoint at
//!            step 6 (one step of replay);
//!   step  9  the VRAM budget collapses: shards degrade RT-REF ->
//!            ORCS-perse (listless, in-shader forces) and keep going;
//!   step 12  an injected divergence: the kinetic-energy watchdog rejects
//!            the step and retries from its snapshot at dt/2.

use std::sync::Arc;

use orcs::core::config::{Boundary, ParticleDist, RadiusDist, ShardSpec, SimConfig};
use orcs::frnn::RustKernels;
use orcs::resilience::{FaultPlan, OomPolicy, ResilienceConfig, WatchdogCfg};
use orcs::rtcore::profile::{L40, TITANRTX};
use orcs::shard::{ShardedConfig, ShardedEngine};

fn main() -> anyhow::Result<()> {
    let n = 1_200;
    let steps = 16;
    let sim = SimConfig {
        n,
        box_l: 300.0,
        particle_dist: ParticleDist::Disordered,
        radius_dist: RadiusDist::Const(6.0), // uniform: the listless rung is open
        boundary: Boundary::Periodic,
        seed: 42,
        ..SimConfig::default()
    };
    // squeeze to 64 KB: far below any fixed-slot list for n=1200, so every
    // shard must take the listless fallback at step 9
    let spec = "transient@2,slow@3:0:4.0,lost@7:1,squeeze@9:65536,nan@12";
    let faults = FaultPlan::parse(spec)
        .ok_or_else(|| anyhow::anyhow!("bad fault spec: {spec}"))?;
    let resilience = ResilienceConfig {
        on_oom: OomPolicy::Fallback,
        watchdog: WatchdogCfg { enabled: true, ..WatchdogCfg::default() },
        checkpoint_every: 3,
        faults,
    };
    let cfg = ShardedConfig {
        policy: "gradient".into(),
        fleet: vec![&TITANRTX, &L40],
        threads: orcs::parallel::num_threads(),
        check_oom: true,
        resilience,
        ..ShardedConfig::new(sim, ShardSpec::new(2))
    };

    println!("chaos recovery: n={n}, {steps} steps, S=2 over TITANRTX+L40");
    println!("fault schedule: {spec}\n");

    let threads = cfg.threads;
    let mut engine = ShardedEngine::new(cfg, Arc::new(RustKernels { threads }))?;
    let summary = engine.run(steps, false)?;

    for ev in &summary.events {
        println!("  {ev}");
    }
    println!();
    let listless: u64 = summary.per_shard.iter().map(|t| t.listless_steps).sum();
    println!(
        "done: {} steps ({} replayed by recovery) | {} resilience events | \
         {} listless shard-steps",
        summary.steps, summary.replayed_steps, summary.events.len(), listless
    );
    println!(
        "physics: KE {:.3} | finite={} | dt now {:.2e} (watchdog halves on retry)",
        engine.state.kinetic_energy(),
        engine.state.is_finite(),
        engine.state.dt
    );

    // fault forensics: the flight recorder retained the tail of the run —
    // the same timeline a failed run dumps automatically at the boundary
    let dump = engine.telemetry().flight_dump();
    println!("\nflight recorder (fault forensics timeline):");
    println!("{dump}");

    // the whole point: the run completed, recovered, and stayed healthy
    anyhow::ensure!(!summary.oom, "run aborted on OOM despite the fallback ladder");
    anyhow::ensure!(engine.state.is_finite(), "divergence survived the watchdog");
    anyhow::ensure!(engine.state.step_count == steps as u64, "run fell short");
    anyhow::ensure!(summary.replayed_steps > 0, "device loss never triggered recovery");
    anyhow::ensure!(listless > 0, "squeeze never forced the listless fallback");
    anyhow::ensure!(dump.contains("lost"), "the recorder must show the device loss");
    anyhow::ensure!(dump.contains("recovered"), "the recorder must show the recovery");
    println!("all resilience checks passed");
    Ok(())
}
