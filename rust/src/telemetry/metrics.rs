//! The telemetry metrics registry: counters, gauges and fixed-bucket
//! histograms keyed by `(family, labels)`, exported as Prometheus-style
//! exposition text and as JSON.
//!
//! Everything is `BTreeMap`-backed so rendering order is deterministic,
//! and every mutation is plain bookkeeping — recording metrics can never
//! perturb simulation results. Observed values are milliseconds of
//! *simulated* device time unless a family name says otherwise.

use std::collections::BTreeMap;

/// Histogram bucket upper bounds (ms of simulated time). An implicit
/// `+Inf` overflow bucket follows the last bound.
pub const MS_BUCKETS: &[f64] = &[0.001, 0.01, 0.1, 1.0, 10.0, 100.0, 1_000.0, 10_000.0];

/// One fixed-bucket histogram.
#[derive(Clone, Debug)]
pub struct Hist {
    /// Upper bounds, ascending; `buckets` has one extra overflow slot.
    pub bounds: Vec<f64>,
    /// Per-bucket observation counts (not cumulative).
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: f64,
}

impl Hist {
    fn new_ms() -> Hist {
        Hist {
            bounds: MS_BUCKETS.to_vec(),
            buckets: vec![0; MS_BUCKETS.len() + 1],
            count: 0,
            sum: 0.0,
        }
    }

    fn observe(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        let mut idx = self.bounds.len();
        for (i, b) in self.bounds.iter().enumerate() {
            if v <= *b {
                idx = i;
                break;
            }
        }
        if let Some(slot) = self.buckets.get_mut(idx) {
            *slot += 1;
        }
    }
}

/// The registry. Keys are `(family name, rendered label pairs)`; label
/// pairs are sorted by key at insert time so a family's series are
/// contiguous and canonical regardless of call-site label order.
#[derive(Clone, Debug, Default)]
pub struct Registry {
    counters: BTreeMap<(String, String), u64>,
    gauges: BTreeMap<(String, String), f64>,
    hists: BTreeMap<(String, String), Hist>,
}

/// Render label pairs as `k1="v1",k2="v2"` (sorted by key, no braces).
fn render_labels(labels: &[(&str, &str)]) -> String {
    let mut pairs: Vec<(&str, &str)> = labels.to_vec();
    pairs.sort_by(|a, b| a.0.cmp(b.0));
    let mut s = String::new();
    for (k, v) in pairs {
        if !s.is_empty() {
            s.push(',');
        }
        s.push_str(&format!("{k}=\"{v}\""));
    }
    s
}

fn series(name: &str, labels: &str) -> String {
    if labels.is_empty() {
        name.to_string()
    } else {
        format!("{name}{{{labels}}}")
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.hists.is_empty()
    }

    pub fn counter_add(&mut self, name: &str, labels: &[(&str, &str)], v: u64) {
        let key = (name.to_string(), render_labels(labels));
        *self.counters.entry(key).or_insert(0) += v;
    }

    pub fn gauge_set(&mut self, name: &str, labels: &[(&str, &str)], v: f64) {
        let key = (name.to_string(), render_labels(labels));
        self.gauges.insert(key, v);
    }

    pub fn hist_observe(&mut self, name: &str, labels: &[(&str, &str)], v: f64) {
        let key = (name.to_string(), render_labels(labels));
        self.hists.entry(key).or_insert_with(Hist::new_ms).observe(v);
    }

    /// Prometheus text exposition: `# TYPE` per family, one line per
    /// series; histograms expand to cumulative `_bucket`/`_sum`/`_count`.
    pub fn to_prometheus(&self) -> String {
        let mut s = String::new();
        let mut prev: Option<&str> = None;
        for ((name, labels), v) in &self.counters {
            if prev != Some(name.as_str()) {
                s.push_str(&format!("# TYPE {name} counter\n"));
                prev = Some(name.as_str());
            }
            s.push_str(&format!("{} {v}\n", series(name, labels)));
        }
        prev = None;
        for ((name, labels), v) in &self.gauges {
            if prev != Some(name.as_str()) {
                s.push_str(&format!("# TYPE {name} gauge\n"));
                prev = Some(name.as_str());
            }
            s.push_str(&format!("{} {v}\n", series(name, labels)));
        }
        prev = None;
        for ((name, labels), h) in &self.hists {
            if prev != Some(name.as_str()) {
                s.push_str(&format!("# TYPE {name} histogram\n"));
                prev = Some(name.as_str());
            }
            let le_series = |le: &str| {
                if labels.is_empty() {
                    format!("{name}_bucket{{le=\"{le}\"}}")
                } else {
                    format!("{name}_bucket{{{labels},le=\"{le}\"}}")
                }
            };
            let mut cum = 0u64;
            for (count, bound) in h.buckets.iter().zip(h.bounds.iter()) {
                cum += count;
                s.push_str(&format!("{} {cum}\n", le_series(&format!("{bound}"))));
            }
            cum += h.buckets.last().copied().unwrap_or(0);
            s.push_str(&format!("{} {cum}\n", le_series("+Inf")));
            s.push_str(&format!("{} {}\n", series(&format!("{name}_sum"), labels), h.sum));
            s.push_str(&format!("{} {}\n", series(&format!("{name}_count"), labels), h.count));
        }
        s
    }

    /// JSON export (hand-rolled — the vendor set has no serde). Series
    /// keys use the same `name{labels}` form as the Prometheus text.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n  \"counters\": {");
        let mut first = true;
        for ((name, labels), v) in &self.counters {
            if !first {
                s.push(',');
            }
            first = false;
            s.push_str(&format!("\n    \"{}\": {v}", json_escape(&series(name, labels))));
        }
        s.push_str("\n  },\n  \"gauges\": {");
        first = true;
        for ((name, labels), v) in &self.gauges {
            if !first {
                s.push(',');
            }
            first = false;
            s.push_str(&format!("\n    \"{}\": {v}", json_escape(&series(name, labels))));
        }
        s.push_str("\n  },\n  \"histograms\": {");
        first = true;
        for ((name, labels), h) in &self.hists {
            if !first {
                s.push(',');
            }
            first = false;
            let mut le = String::new();
            for b in &h.bounds {
                le.push_str(&format!("{b}, "));
            }
            le.push_str("\"+Inf\"");
            let counts: Vec<String> = h.buckets.iter().map(|c| format!("{c}")).collect();
            s.push_str(&format!(
                "\n    \"{}\": {{\"count\": {}, \"sum\": {}, \"le\": [{le}], \"buckets\": [{}]}}",
                json_escape(&series(name, labels)),
                h.count,
                h.sum,
                counts.join(", ")
            ));
        }
        s.push_str("\n  }\n}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_labels_canonicalize() {
        let mut r = Registry::new();
        r.counter_add("orcs_steps_total", &[], 1);
        r.counter_add("orcs_steps_total", &[], 2);
        r.counter_add("orcs_aabb_tests_total", &[("shard", "0"), ("device", "L40")], 10);
        // same series, label order flipped
        r.counter_add("orcs_aabb_tests_total", &[("device", "L40"), ("shard", "0")], 5);
        let text = r.to_prometheus();
        assert!(text.contains("orcs_steps_total 3"), "{text}");
        assert!(
            text.contains("orcs_aabb_tests_total{device=\"L40\",shard=\"0\"} 15"),
            "{text}"
        );
        assert!(text.contains("# TYPE orcs_steps_total counter"), "{text}");
    }

    #[test]
    fn histogram_buckets_are_cumulative_in_prometheus() {
        let mut r = Registry::new();
        r.hist_observe("orcs_phase_ms", &[("phase", "traverse")], 0.5);
        r.hist_observe("orcs_phase_ms", &[("phase", "traverse")], 5.0);
        r.hist_observe("orcs_phase_ms", &[("phase", "traverse")], 5e6); // overflow
        let text = r.to_prometheus();
        assert!(text.contains("# TYPE orcs_phase_ms histogram"), "{text}");
        assert!(text.contains("le=\"1\"} 1"), "{text}");
        assert!(text.contains("le=\"10\"} 2"), "{text}");
        assert!(text.contains("le=\"+Inf\"} 3"), "{text}");
        assert!(text.contains("orcs_phase_ms_count{phase=\"traverse\"} 3"), "{text}");
    }

    #[test]
    fn json_export_is_balanced_and_names_series() {
        let mut r = Registry::new();
        r.gauge_set("orcs_sim_clock_ms", &[], 12.5);
        r.hist_observe("orcs_phase_ms", &[("phase", "build")], 1.0);
        let js = r.to_json();
        assert!(js.contains("\"orcs_sim_clock_ms\": 12.5"), "{js}");
        assert!(js.contains("orcs_phase_ms{phase=\\\"build\\\"}"), "{js}");
        assert_eq!(js.matches('{').count(), js.matches('}').count(), "{js}");
    }
}
