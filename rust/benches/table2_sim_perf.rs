//! `cargo bench --bench table2_sim_perf [-- --quick]`
//! Regenerates paper Table 2 (avg ms/step per approach/scenario).
fn main() {
    let opts = orcs::benchsuite::common::BenchOpts::from_env().expect("bench options");
    orcs::benchsuite::table2::run(&opts).expect("table2 bench");
}
