//! The sharded stepping engine: per-shard BVHs and rebuild policies,
//! halo-exchanged ghost images, per-shard device pricing, and a canonical
//! global force merge that is bitwise identical to the single-domain run.
//!
//! # Execution model
//!
//! Each step:
//!
//! 1. **Ownership + migration** — every particle belongs to the shard whose
//!    subdomain contains its (wrapped) position; integration moves
//!    particles across faces, and the owner change is the migration the
//!    exchange phase prices.
//! 2. **Halo exchange** — each shard gathers its ghost images
//!    ([`decomp::gather_ghosts`]): all 27 periodic images within `r_max` of
//!    the shard box. Ghosts are *materialized* as local primitives, so
//!    shard-local traversal needs no gamma machinery.
//! 3. **Per-shard BVH** — each shard owns a [`BvhManager`] with an
//!    *independent policy instance*. A refit is only meaningful over an
//!    unchanged primitive set, so any membership churn (migration or halo
//!    turnover) forces a rebuild; stable (cold) shards refit on the
//!    policy's schedule while churning (hot) shards rebuild — the
//!    heterogeneous dynamics the gradient optimizer adapts to, per shard.
//! 4. **Discovery** — rays launch from *every* local primitive (owned and
//!    ghost). Owned rays fill their own lists; ghost rays contribute only
//!    cross-inserts into owned lists (the redundant-compute-instead-of-
//!    communicate convention of halo methods). Per-owned lists are then
//!    sorted ascending by global id and deduplicated — the canonical order.
//! 5. **Merge + physics** — per-shard lists land in one global CSR (each
//!    particle has exactly one owner, so the merge is conflict-free), and
//!    the *same* force/integration kernels as the single-domain engine run
//!    over it. Identical canonical lists + identical kernels ⇒ identical
//!    f32 operation sequences ⇒ **bitwise identical** forces and positions
//!    for any shard grid and any `ORCS_THREADS`.
//! 6. **Pricing** — per-shard op counts are priced on that shard's own
//!    [`HwProfile`]; the fleet step is `max` over devices (straggler) for
//!    time, `sum` for energy ([`crate::rtcore::fleet`]). `check_oom` meters
//!    the RT-REF fixed-slot list allocation **per shard** against each
//!    device's VRAM — the per-shard OOM relief that lets log-normal cluster
//!    scenes too wide for one device complete sharded.
//!
//! # Backends
//!
//! Every shard runs the configured RT backend ([`ShardedConfig::backend`]):
//! **RT-REF** keeps the fixed-slot neighbor list (and the per-shard OOM
//! ladder); the **listless** ORCS-forces and ORCS-persé never allocate one
//! and so cannot OOM. Shard-local discovery always yields the same
//! canonical per-owned lists (ascending global id, deduped), and each
//! backend then consumes them exactly as its single-domain twin would: the
//! list kernels globally (RT-REF and ORCS-forces), the canonical-order
//! payload gather per shard (persé and the RT-REF OOM rung — the same code
//! path), in-shader integration for persé. Identical canonical sets +
//! identical f32 operation sequences ⇒ every backend is **bitwise
//! identical** to its single-domain engine for any shard grid, any
//! `ORCS_THREADS`, and both boundary modes.

use std::sync::Arc;

use anyhow::Result;

use super::decomp::{self, ShardGrid, ShardMember, CENTER_SHIFT};
use crate::core::config::{ShardSpec, SimConfig};
use crate::core::vec3::Vec3;
use crate::frnn::orcs_forces::handles_pair;
use crate::frnn::rt_common::{canonical_force_sum, BvhManager};
use crate::frnn::zorder::ZOrderCache;
use crate::frnn::{ApproachKind, NeighborLists, PhysicsKernels, RustKernels};
use crate::gradient::BvhAction;
use crate::physics::{boundary, state::SimState};
use crate::resilience::checkpoint::{FleetCheckpoint, ShardCheckpoint};
use crate::resilience::{
    EventKind, FaultInjector, FaultKind, OomPolicy, ResilienceConfig, ResilienceEvent, SimError,
    SimResult, Watchdog,
};
use crate::rtcore::fleet::{self, ShardCost};
use crate::rtcore::power::step_energy;
use crate::rtcore::{timing, HwProfile, OpCounts};
use crate::telemetry::wallclock::WallTimer;
use crate::telemetry::{Phase, Recorder, Span, GLOBAL_LANE};

/// Sharded-engine configuration: scenario + decomposition + fleet bindings.
#[derive(Clone)]
pub struct ShardedConfig {
    pub sim: SimConfig,
    pub spec: ShardSpec,
    /// Per-shard BVH rebuild policy spec (`gradient`, `avg`, `fixed-K`);
    /// every shard gets its own policy instance.
    pub policy: String,
    /// Device profiles bound round-robin across the `s³` shards: one entry
    /// is a uniform fleet, several model a heterogeneous one (e.g.
    /// `TITANRTX` + `L40` in one run).
    pub fleet: Vec<&'static HwProfile>,
    pub threads: usize,
    /// Enforce the per-shard neighbor-list memory limit.
    pub check_oom: bool,
    /// Resilience knobs (faults, watchdog, checkpoints, OOM fallback).
    /// Default is inert — identical behavior to a pre-resilience engine.
    pub resilience: ResilienceConfig,
    /// The FRNN backend every shard runs: RT-REF (the list pipeline with
    /// the per-shard OOM story), ORCS-forces, or ORCS-persé (both listless
    /// — no neighbor list is ever allocated, so they cannot OOM). All three
    /// are bitwise identical to their single-domain counterparts.
    pub backend: ApproachKind,
}

impl ShardedConfig {
    pub fn new(sim: SimConfig, spec: ShardSpec) -> Self {
        ShardedConfig {
            sim,
            spec,
            policy: "gradient".into(),
            fleet: vec![crate::rtcore::profile::DEFAULT_GPU],
            threads: crate::parallel::num_threads(),
            check_oom: true,
            resilience: ResilienceConfig::default(),
            backend: ApproachKind::RtRef,
        }
    }
}

/// One shard's contribution to a step record.
#[derive(Clone, Copy, Debug)]
pub struct ShardStepStat {
    pub shard: usize,
    pub owned: usize,
    pub ghosts: usize,
    pub action: BvhAction,
    /// The action was forced by membership churn rather than chosen by the
    /// policy.
    pub forced_build: bool,
    /// Widest per-particle list this step (pre-dedup — the slots a real
    /// append stream occupies).
    pub k_max: usize,
    /// Fixed-slot list allocation on this shard's device (0 once listless).
    pub list_bytes: u64,
    /// The shard ran a listless path this step — a first-class ORCS
    /// backend or the RT-REF OOM rung (no neighbor list is materialized;
    /// forces accumulate in-shader).
    pub listless: bool,
    /// This shard's full step on its device (incl. exchange), ms.
    pub sim_ms: f64,
    pub rt_ms: f64,
    pub energy_j: f64,
}

/// Everything measured about one sharded step.
#[derive(Clone, Debug)]
pub struct ShardedStepRecord {
    pub step: u64,
    /// Aggregate step time: the straggler device, ms.
    pub sim_ms: f64,
    pub straggler: usize,
    /// Total energy across the fleet, J.
    pub energy_j: f64,
    pub interactions: u64,
    /// Particles whose owner shard changed this step.
    pub migrations: u64,
    /// Ghost entries exchanged this step (sum over shards).
    pub ghost_entries: u64,
    /// `(shard, required bytes)` when a shard's list allocation exceeds its
    /// device memory.
    pub oom: Option<(usize, u64)>,
    pub per_shard: Vec<ShardStepStat>,
}

/// Per-shard aggregate over a run.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardTotals {
    /// Name of the device profile this shard is bound to.
    pub hw: &'static str,
    pub builds: u64,
    pub updates: u64,
    pub forced_builds: u64,
    pub owned_sum: u64,
    pub ghosts_sum: u64,
    pub max_k_max: usize,
    pub max_list_bytes: u64,
    /// Steps this shard ran on the degraded listless path.
    pub listless_steps: u64,
    /// Sum of this shard's per-step device time, ms.
    pub total_sim_ms: f64,
}

impl ShardTotals {
    /// Updates per build — the policy's chosen ratio (hot shards low, cold
    /// shards high).
    pub fn update_ratio(&self) -> f64 {
        self.updates as f64 / (self.builds.max(1)) as f64
    }
}

/// Aggregate over a sharded run.
#[derive(Clone, Debug, Default)]
pub struct ShardedRunSummary {
    pub scenario: String,
    pub grid: String,
    pub fleet: String,
    pub steps: u64,
    pub avg_sim_ms: f64,
    pub total_sim_ms: f64,
    pub total_energy_j: f64,
    pub total_interactions: u64,
    /// Interactions per joule across the fleet (Eq. 10).
    pub ee: f64,
    pub migrations: u64,
    pub ghost_entries: u64,
    pub oom: bool,
    pub oom_shard: usize,
    pub oom_bytes: u64,
    pub wall_total_s: f64,
    /// Resilience log for the run (fallbacks, retries, recoveries).
    pub events: Vec<ResilienceEvent>,
    /// Steps re-executed by checkpoint recovery.
    pub replayed_steps: u64,
    pub per_shard: Vec<ShardTotals>,
    /// Per-step trace (kept when requested).
    pub records: Vec<ShardedStepRecord>,
}

/// A live shard: geometry + BVH lifecycle + running allocation width.
struct Shard {
    hw: &'static HwProfile,
    mgr: BvhManager,
    members_prev: Vec<ShardMember>,
    k_max_seen: usize,
    /// Shard-local Morton cache: one keying + radix sort per step over the
    /// local view (owned + ghosts), shared by the LBVH build and the query
    /// sweep — the single-domain Z-order coherence win, per shard.
    zcache: ZOrderCache,
}

/// The sharded simulation: global state + one engine-let per subdomain.
pub struct ShardedEngine {
    pub cfg: ShardedConfig,
    pub state: SimState,
    kernels: Arc<dyn PhysicsKernels>,
    grid: ShardGrid,
    shards: Vec<Shard>,
    owner: Vec<u32>,
    stepped: bool,
    /// Surviving fleet (device losses remove entries; shards rebind
    /// round-robin over what is left).
    devices: Vec<&'static HwProfile>,
    /// Per-shard degraded-to-listless flag (sticky once an OOM fallback
    /// fires; survives until a checkpoint restore resets it).
    listless: Vec<bool>,
    /// Per-shard straggler factor for the next step (1.0 = none).
    slowdowns: Vec<f64>,
    /// Injected VRAM squeeze, sticky once it fires (caps every device).
    vram_budget: Option<u64>,
    injector: FaultInjector,
    watchdog: Watchdog,
    checkpoint: Option<FleetCheckpoint>,
    events: Vec<ResilienceEvent>,
    replayed: u64,
    /// An injected divergence corrupts the state after the next step.
    divergence_armed: bool,
    /// The listless fallback requires a uniform radius (ORCS-persé rule).
    uniform_radius: bool,
    /// Per-step telemetry: one lane per shard, metrics, flight recorder.
    telemetry: Recorder,
}

impl ShardedEngine {
    pub fn new(cfg: ShardedConfig, kernels: Arc<dyn PhysicsKernels>) -> Result<Self> {
        anyhow::ensure!(!cfg.fleet.is_empty(), "fleet must bind at least one device");
        let state = SimState::from_config(&cfg.sim);
        let grid = ShardGrid::new(cfg.spec, state.box_l);
        let shards = (0..grid.count())
            .map(|s| -> Result<Shard> {
                let policy = crate::gradient::policy::parse_policy(&cfg.policy)
                    .ok_or_else(|| anyhow::anyhow!("unknown BVH policy: {}", cfg.policy))?;
                Ok(Shard {
                    hw: cfg.fleet[s % cfg.fleet.len()],
                    mgr: BvhManager::new(policy),
                    members_prev: Vec::new(),
                    k_max_seen: 0,
                    zcache: ZOrderCache::new(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let owner = vec![0; state.n()];
        let n_shards = grid.count();
        // lint:allow(P-INDEX-LIT): windows(2) yields exactly-2 slices
        let uniform_radius = state.radius.windows(2).all(|w| w[0] == w[1]);
        anyhow::ensure!(
            cfg.backend.is_rt(),
            "sharded runs support the RT backends only (rt-ref, orcs-forces, orcs-perse); \
             {} has no shard-local traversal",
            cfg.backend.label()
        );
        anyhow::ensure!(
            cfg.backend != ApproachKind::OrcsPerse || uniform_radius,
            "ORCS-persé requires a uniform radius across all particles"
        );
        let injector = FaultInjector::new(&cfg.resilience.faults);
        let devices = cfg.fleet.clone();
        let active = cfg.resilience.active();
        let mut e = ShardedEngine {
            cfg,
            state,
            kernels,
            grid,
            shards,
            owner,
            stepped: false,
            devices,
            listless: vec![false; n_shards],
            slowdowns: vec![1.0; n_shards],
            vram_budget: None,
            injector,
            watchdog: Watchdog::default(),
            checkpoint: None,
            events: Vec::new(),
            replayed: 0,
            divergence_armed: false,
            uniform_radius,
            telemetry: Recorder::new(),
        };
        // a step-0 checkpoint makes an early device loss recoverable
        if active {
            e.checkpoint = Some(e.take_checkpoint());
        }
        Ok(e)
    }

    /// Convenience: engine with the pure-Rust kernels.
    pub fn new_rust(cfg: ShardedConfig) -> Result<Self> {
        let threads = cfg.threads;
        Self::new(cfg, Arc::new(RustKernels { threads }))
    }

    pub fn shard_count(&self) -> usize {
        self.grid.count()
    }

    /// Current owner shard of particle `i` (valid after the first step).
    pub fn owner(&self, i: usize) -> usize {
        self.owner[i] as usize
    }

    /// The device profile bound to shard `s`.
    pub fn shard_hw(&self, s: usize) -> &'static HwProfile {
        self.shards[s].hw
    }

    /// Execute one step across all shards and meter it. Dispatches through
    /// the resilient path when any resilience knob is active.
    pub fn step(&mut self) -> SimResult<ShardedStepRecord> {
        if self.cfg.resilience.active() {
            self.step_resilient()
        } else {
            self.step_raw()
        }
    }

    /// One raw sharded step (no fault handling).
    fn step_raw(&mut self) -> SimResult<ShardedStepRecord> {
        let opened = self.telemetry.begin_step(self.state.step_count);
        self.telemetry.begin_attempt();
        let n = self.state.n();
        let threads = self.cfg.threads.max(1);
        let halo = self.state.r_max;
        let box_l = self.state.box_l;
        let boundary = self.state.boundary;
        let n_shards = self.grid.count();

        // --- Phase 1: ownership + migration ---------------------------
        let grid = self.grid;
        let pos_ref = &self.state.pos;
        let new_owner: Vec<u32> =
            // lint:allow(P-CAST-NARROW): shard count is tiny (grid dims)
            crate::parallel::parallel_map(n, threads, |i| grid.owner_of(pos_ref[i]) as u32);
        let mut migrations = 0u64;
        let mut mig_in = vec![0u64; n_shards];
        if self.stepped {
            for (i, &o) in new_owner.iter().enumerate() {
                if self.owner[i] != o {
                    migrations += 1;
                    mig_in[o as usize] += 1;
                }
            }
        }
        self.owner = new_owner;
        self.stepped = true;

        // Per-shard outputs for the global merge.
        struct ShardLists {
            owned_gids: Vec<u32>,
            /// Post-dedup lengths, parallel to `owned_gids`.
            lens: Vec<u32>,
            /// Compacted sorted+deduped items, segments in owned order.
            items: Vec<u32>,
        }
        let mut shard_lists: Vec<ShardLists> = Vec::with_capacity(n_shards);
        let mut per_shard: Vec<ShardStepStat> = Vec::with_capacity(n_shards);
        let mut costs: Vec<ShardCost> = Vec::with_capacity(n_shards);
        let mut oom: Option<(usize, u64)> = None;
        let mut total_ghosts = 0u64;
        let mut ghosts_buf: Vec<ShardMember> = Vec::new();
        let backend = self.cfg.backend;
        let dt = self.state.dt;

        // One GPU-CELL bucketing grid per step, shared by every shard's
        // halo gather — each gather then touches only the cells overlapping
        // its (shifted) halo slab instead of scanning all n × 27 images.
        let halo_cells = decomp::halo_grid(&self.state.pos, box_l, halo);

        // Listless physics results, deferred until after the shard loop so
        // every shard reads this step's input state (owners are disjoint, so
        // application order is irrelevant).
        let mut fallback_payloads: Vec<(u32, Vec3)> = Vec::new();
        let mut perse_moves: Vec<(u32, Vec3, Vec3, Vec3)> = Vec::new();
        // Canonical list entries / persé accumulations / forces handled
        // pairs, summed across shards (for the step's interaction count).
        let mut entries_total = 0u64;
        let mut accums_total = 0u64;
        let mut forces_pairs_total = 0u64;

        // One O(n) bucketing pass replaces a per-shard full-scene filter;
        // ids stay ascending within each bucket (the canonical owned order).
        let mut owned_by_shard: Vec<Vec<ShardMember>> = vec![Vec::new(); n_shards];
        for (i, &o) in self.owner.iter().enumerate() {
            owned_by_shard[o as usize].push(ShardMember { gid: i as u32, shift: CENTER_SHIFT });
        }

        for s in 0..n_shards {
            // --- Phase 2: membership + halo ---------------------------
            let mut members = std::mem::take(&mut owned_by_shard[s]);
            let owned_n = members.len();
            decomp::gather_ghosts(
                &self.grid,
                s,
                &self.state.pos,
                &self.owner,
                halo,
                boundary,
                &halo_cells,
                &mut ghosts_buf,
            );
            members.extend_from_slice(&ghosts_buf);
            let n_local = members.len();
            let ghosts = n_local - owned_n;
            total_ghosts += ghosts as u64;

            let local_pos: Vec<Vec3> = members
                .iter()
                .map(|m| self.state.pos[m.gid as usize] + decomp::shift_vec(m.shift, box_l))
                .collect();
            let local_radius: Vec<f32> =
                members.iter().map(|m| self.state.radius[m.gid as usize]).collect();
            let local_gid: Vec<u32> = members.iter().map(|m| m.gid).collect();

            // --- Phase 3: per-shard BVH under its own policy ----------
            let shard = &mut self.shards[s];
            let force_build = shard.members_prev != members;
            let mut counts = OpCounts::default();
            // Shard-local Morton order over the local view (owned + ghosts;
            // shifted ghost coordinates clamp into the grid), shared by the
            // LBVH build and the query sweep below.
            shard.zcache.compute(&local_pos, box_l, threads);
            let action = shard.mgr.prepare_with(
                &local_pos,
                &local_radius,
                &mut counts,
                threads,
                force_build,
                Some(shard.zcache.order()),
            );
            shard.members_prev = members;

            // --- Phase 4: discovery (owned + ghost rays) --------------
            struct ChunkOut {
                /// (owned-local index, neighbor gid) from the ray's own list.
                direct: Vec<(u32, u32)>,
                /// (owned-local index, inserted gid) — atomic appends.
                cross: Vec<(u32, u32)>,
            }
            let (chunks, stats) = {
                let bvh = shard.mgr.bvh();
                let (local_pos, local_radius, local_gid) = (&local_pos, &local_radius, &local_gid);
                // Swept in shard-local Morton order: coherent rays share
                // subtrees, so BVH4 node fetches stay cache-hot. The chunk
                // partition is thread-count invariant and the per-owned
                // lists are canonicalized below, so the sweep order drops
                // out of the physics entirely.
                let order = shard.zcache.order();
                bvh.query_batch_with_order(order, threads, || (), |_, scratch, ids| {
                    let mut out = ChunkOut { direct: Vec::new(), cross: Vec::new() };
                    for &au in ids {
                        let a = au as usize;
                        let ga = local_gid[a];
                        let ra = local_radius[a];
                        let pa = local_pos[a];
                        bvh.query_point(pa, a, local_pos, local_radius, scratch, |b| {
                            // never pair a particle with its own image
                            if local_gid[b] == ga {
                                return;
                            }
                            if a < owned_n {
                                out.direct.push((a as u32, local_gid[b]));
                            }
                            // the cross-insert of RT-REF's variable-radius
                            // rule: ray a found b, but b's ray cannot see a
                            if b < owned_n {
                                let d2 = (pa - local_pos[b]).norm2();
                                if d2 >= ra * ra {
                                    out.cross.push((b as u32, ga));
                                }
                            }
                        });
                    }
                    out
                })
            };
            crate::frnn::rt_common::fold_stats(&mut counts, &stats);

            // Count-then-fill over the owned lists (chunk order is
            // deterministic; the parallel scan is thread-count invariant).
            let mut lens_raw = vec![0u32; owned_n];
            let mut cross_inserts = 0u64;
            for c in &chunks {
                for &(a, _) in &c.direct {
                    lens_raw[a as usize] += 1;
                }
                for &(b, _) in &c.cross {
                    lens_raw[b as usize] += 1;
                    cross_inserts += 1;
                }
            }
            let offsets_raw = crate::parallel::exclusive_scan_u32(&lens_raw, threads);
            let raw_total = offsets_raw.last().copied().unwrap_or(0) as usize;
            let mut items = vec![0u32; raw_total];
            let mut cursor: Vec<u32> = offsets_raw[..owned_n].to_vec();
            for c in &chunks {
                for &(a, g) in &c.direct {
                    let a = a as usize;
                    items[cursor[a] as usize] = g;
                    cursor[a] += 1;
                }
            }
            for c in &chunks {
                for &(b, g) in &c.cross {
                    let b = b as usize;
                    items[cursor[b] as usize] = g;
                    cursor[b] += 1;
                }
            }
            // Canonicalize each owned list: ascending gid, deduplicated
            // (multiple images of one neighbor collapse to one entry, as in
            // the single-domain large-radius path), compacted in place.
            let mut lens = vec![0u32; owned_n];
            let mut k_max_raw = 0usize;
            let mut write = 0usize;
            let mut seg: Vec<u32> = Vec::new();
            for a in 0..owned_n {
                let lo = offsets_raw[a] as usize;
                let hi = offsets_raw[a + 1] as usize;
                k_max_raw = k_max_raw.max(hi - lo);
                seg.clear();
                seg.extend_from_slice(&items[lo..hi]);
                seg.sort_unstable();
                seg.dedup();
                lens[a] = seg.len() as u32; // lint:allow(P-CAST-NARROW): degree < 2^32
                items[write..write + seg.len()].copy_from_slice(&seg);
                write += seg.len();
            }
            items.truncate(write);
            let entries = write as u64;

            // --- Phase 5: per-backend metering + physics --------------
            let budget = self.vram_budget.map_or(shard.hw.vram_bytes, |b| {
                b.min(shard.hw.vram_bytes)
            });
            let mut switch_s = 0.0;
            if backend == ApproachKind::RtRef && !self.listless[s] {
                // RT-REF only: would the fixed-slot list allocation fit? If
                // not and the policy allows it, degrade this shard to the
                // listless ORCS-persé path *before* committing the
                // allocation. The first-class listless backends never enter
                // here — they have no list to OOM.
                let need = (owned_n as u64) * (shard.k_max_seen.max(k_max_raw) as u64) * 4;
                let fallback = self.cfg.resilience.on_oom == OomPolicy::Fallback;
                if self.cfg.check_oom && need > budget && fallback && self.uniform_radius {
                    self.listless[s] = true;
                    switch_s = fleet::switch_time(n_local as u64, shard.hw);
                    let ev = ResilienceEvent {
                        step: self.state.step_count,
                        kind: EventKind::OomFallback {
                            from: "RT-REF",
                            to: "ORCS-perse",
                            shard: Some(s),
                            required_bytes: need,
                            budget_bytes: budget,
                            switch_ms: switch_s * 1e3,
                        },
                    };
                    self.telemetry.mark_event(&ev);
                    self.events.push(ev);
                }
            }
            let is_forces = backend == ApproachKind::OrcsForces;
            let is_perse = backend == ApproachKind::OrcsPerse;
            // The OOM rung *is* the persé code path, minus the in-shader
            // integration (a mixed fleet still integrates globally).
            let is_fallback = backend == ApproachKind::RtRef && self.listless[s];
            let listless = is_forces || is_perse || is_fallback;
            let mut shard_oom = false;
            let list_bytes;
            let mut scatter_entries = 0u64;
            if is_forces {
                // ORCS-forces: every intersection scatters the pair force
                // into both endpoint accumulators — no list. Meter the
                // in-shader evals/atomics with the single-domain handler
                // rule (each pair handled by exactly one endpoint,
                // attributed to the handler's owner shard), and count the
                // entries whose source lives on another shard: those are
                // the ghost contributions the canonical-order scatter folds
                // back into this shard's owned accumulators.
                let offsets_c = crate::parallel::exclusive_scan_u32(&lens, threads);
                let st = &self.state;
                let owner_ref = &self.owner;
                let (items_ref, gid_ref) = (&items, &local_gid);
                let walk = crate::parallel::parallel_map(owned_n, threads, |a| {
                    let t = gid_ref[a] as usize;
                    let r_t = st.radius[t];
                    let seg = &items_ref[offsets_c[a] as usize..offsets_c[a + 1] as usize];
                    let (mut evals, mut pairs, mut xfer) = (0u64, 0u64, 0u64);
                    for &su in seg {
                        let src = su as usize;
                        let dx =
                            boundary::displacement(st.pos[t], st.pos[src], boundary, box_l);
                        let d2 = dx.norm2();
                        let r_s = st.radius[src];
                        let t_sees = d2 < r_s * r_s;
                        let mutual = t_sees && d2 < r_t * r_t;
                        if t_sees && handles_pair(t, r_t, src, r_s, mutual) {
                            evals += 1;
                            if st.params.pair_force(dx, r_t, r_s).is_some() {
                                pairs += 1; // "atomicAdd" × 2 on real hardware
                            }
                        }
                        if owner_ref[src] != s as u32 {
                            xfer += 1;
                        }
                    }
                    (evals, pairs, xfer)
                });
                let (mut evals, mut pairs) = (0u64, 0u64);
                for (e, p, x) in walk {
                    evals += e;
                    pairs += p;
                    scatter_entries += x;
                }
                counts.isect_force_evals += evals;
                counts.atomic_adds += 2 * pairs; // both endpoints, atomically
                counts.interactions += pairs;
                counts.integrate_particles += owned_n as u64;
                counts.kernel_launches += 1; // the one extra kernel: integration
                forces_pairs_total += pairs;
                list_bytes = 0;
            } else if listless {
                // ORCS-persé — first-class backend and the RT-REF OOM rung
                // run the same code: a per-owned canonical-order payload
                // gather over the shard's deduped lists, recomputing
                // min-image displacements from *global* state so the f32 sum
                // is byte-for-byte the single-domain row.
                let offsets_c = crate::parallel::exclusive_scan_u32(&lens, threads);
                let st = &self.state;
                let (items_ref, gid_ref) = (&items, &local_gid);
                let walk = crate::parallel::parallel_map(owned_n, threads, |a| {
                    let t = gid_ref[a] as usize;
                    let seg = &items_ref[offsets_c[a] as usize..offsets_c[a + 1] as usize];
                    let mut accums = 0u64;
                    let payload = canonical_force_sum(
                        &st.pos,
                        &st.radius,
                        &st.params,
                        boundary,
                        box_l,
                        t,
                        seg,
                        |_, _, in_range| {
                            if in_range {
                                accums += 1;
                            }
                        },
                    );
                    // in-shader integration of the ray's own particle (the
                    // fallback rung discards this and integrates globally)
                    let f = st.params.cap(payload);
                    let mut v = st.vel[t] + f * dt;
                    let mut p = st.pos[t] + v * dt;
                    boundary::apply(boundary, box_l, &mut p, &mut v);
                    (payload, p, v, accums)
                });
                let mut accums = 0u64;
                for (a, (payload, p, v, acc)) in walk.into_iter().enumerate() {
                    let g = local_gid[a];
                    accums += acc;
                    if is_perse {
                        perse_moves.push((g, payload, p, v));
                    } else {
                        fallback_payloads.push((g, payload));
                    }
                }
                counts.payload_accums += accums;
                counts.isect_force_evals += accums;
                counts.interactions += accums / 2;
                accums_total += accums;
                list_bytes = 0;
            } else {
                // RT-REF list pipeline: cross-inserts are the atomic list
                // appends; the fixed-slot allocation meters against this
                // shard's device.
                counts.atomic_adds += cross_inserts;
                counts.nbr_list_writes += raw_total as u64;
                shard.k_max_seen = shard.k_max_seen.max(k_max_raw);
                list_bytes = (owned_n as u64) * (shard.k_max_seen as u64) * 4;
                counts.nbr_list_bytes_peak = list_bytes;
                shard_oom = self.cfg.check_oom && list_bytes > budget;
                if shard_oom && oom.is_none() {
                    oom = Some((s, list_bytes));
                }
                if !shard_oom {
                    // this shard's slice of the force + integration kernels
                    counts.force_kernel_pairs += (owned_n as u64) * (k_max_raw as u64);
                    counts.integrate_particles += owned_n as u64;
                    counts.kernel_launches += 2;
                }
            }
            entries_total += entries;

            let gather_bytes = (ghosts as u64) * fleet::GHOST_ENTRY_BYTES;
            let mig_bytes = mig_in[s] * fleet::MIGRATION_BYTES;
            let scatter_bytes = scatter_entries * fleet::SCATTER_ENTRY_BYTES;
            let times = timing::simulate(&counts, shard.hw);
            let energy = step_energy(&times, &counts, shard.hw);
            // Interconnect pricing, itemized: halo ghosts in, migrations in
            // (plus any fallback-switch re-staging), canonical force
            // contributions folded back out to remote owners.
            let gather_s = fleet::exchange_time(gather_bytes, shard.hw);
            let mig_s = fleet::exchange_time(mig_bytes, shard.hw) + switch_s;
            let scatter_s = fleet::exchange_time(scatter_bytes, shard.hw);
            let exchange_s = gather_s + mig_s + scatter_s;
            let mut cost = ShardCost {
                times,
                energy,
                exchange_s,
                exchange_j: fleet::exchange_energy(exchange_s, shard.hw),
            };
            let slow = self.slowdowns[s];
            if slow != 1.0 {
                cost = cost.scaled(slow);
            }
            shard.mgr.observe(action, &counts, shard.hw);
            // Telemetry: this shard's lane, laid from the attempt base (all
            // shards step in parallel on their own devices). `cost` already
            // carries any straggler scaling, so spans show the priced times:
            // gather → exchange → compute phases → scatter.
            let lane = s as u32;
            let sname = s.to_string();
            self.telemetry.name_lane(lane, format!("shard {s} ({})", shard.hw.name));
            let labels = [("shard", sname.as_str()), ("device", shard.hw.name)];
            let mut from = self.telemetry.attempt_base_ms();
            if gather_s > 0.0 {
                from = self.telemetry.record_span(
                    Span {
                        lane,
                        phase: Phase::Gather,
                        t0_ms: from,
                        dur_ms: gather_s * slow * 1e3,
                        aabb_tests: 0,
                        isect_force_evals: 0,
                        bytes_moved: gather_bytes,
                        wall_ms: None,
                    },
                    &labels,
                );
            }
            if mig_s > 0.0 {
                from = self.telemetry.record_span(
                    Span {
                        lane,
                        phase: Phase::Exchange,
                        t0_ms: from,
                        dur_ms: mig_s * slow * 1e3,
                        aabb_tests: 0,
                        isect_force_evals: 0,
                        bytes_moved: mig_bytes,
                        wall_ms: None,
                    },
                    &labels,
                );
            }
            let end = self.telemetry.record_phases(lane, from, &cost.times, &counts, None, &labels);
            if scatter_s > 0.0 {
                self.telemetry.record_span(
                    Span {
                        lane,
                        phase: Phase::Scatter,
                        t0_ms: end,
                        dur_ms: scatter_s * slow * 1e3,
                        aabb_tests: 0,
                        isect_force_evals: 0,
                        bytes_moved: scatter_bytes,
                        wall_ms: None,
                    },
                    &labels,
                );
            }
            per_shard.push(ShardStepStat {
                shard: s,
                owned: owned_n,
                ghosts,
                action,
                forced_build: force_build && action == BvhAction::Build,
                k_max: k_max_raw,
                list_bytes,
                listless,
                sim_ms: cost.total_s() * 1e3,
                rt_ms: cost.times.rt_cost() * 1e3,
                energy_j: cost.energy.energy_j + cost.exchange_j,
            });
            costs.push(cost);
            // List mode and ORCS-forces feed the global merge (forces' rows
            // come out of the same canonical CSR the list kernel reads);
            // persé and the fallback rung never materialize their lists —
            // their owned rows arrive via the payload gathers above.
            shard_lists.push(if !listless || is_forces {
                ShardLists { owned_gids: local_gid[..owned_n].to_vec(), lens, items }
            } else {
                ShardLists {
                    owned_gids: local_gid[..owned_n].to_vec(),
                    lens: vec![0; owned_n],
                    items: Vec::new(),
                }
            });
        }

        let agg = fleet::aggregate(&costs);
        self.telemetry.name_lane(GLOBAL_LANE, "fleet".to_string());
        if let Some((shard, bytes)) = oom {
            self.telemetry.mark(
                GLOBAL_LANE,
                "oom",
                format!("shard {shard} neighbor list needs {bytes} B > device VRAM"),
            );
            if opened {
                self.telemetry.end_step(agg.sim_s * 1e3);
            }
            return Ok(ShardedStepRecord {
                step: self.state.step_count,
                sim_ms: agg.sim_s * 1e3,
                straggler: agg.straggler,
                energy_j: agg.energy_j,
                interactions: 0,
                migrations,
                ghost_entries: total_ghosts,
                oom: Some((shard, bytes)),
                per_shard,
            });
        }

        let interactions;
        if backend == ApproachKind::OrcsPerse {
            // --- Phase 6/7 (persé): no merge, no global kernels — every
            // particle was integrated in-shader on its owner shard. Apply
            // the double-buffered outputs; owners are disjoint, rays read
            // this step's inputs, so application order is irrelevant. The
            // uncapped payload is published as the step's force array,
            // exactly like the single-domain backend.
            let mut new_pos = self.state.pos.clone();
            let mut new_vel = self.state.vel.clone();
            let mut new_force = self.state.force.clone();
            for &(g, payload, p, v) in &perse_moves {
                let g = g as usize;
                new_force[g] = payload;
                new_pos[g] = p;
                new_vel[g] = v;
            }
            self.state.pos = new_pos;
            self.state.vel = new_vel;
            self.state.force = new_force;
            self.state.step_count += 1;
            // uniform radius: detection symmetric, each pair seen twice
            interactions = accums_total / 2;
            self.telemetry.mark(
                GLOBAL_LANE,
                "apply",
                format!("persé apply: {} in-shader integrated particles", perse_moves.len()),
            );
        } else {
            // --- Phase 6: shard-ordered merge into one canonical CSR --
            // Each particle has exactly one owner, so the merge is
            // conflict-free and the result is independent of shard iteration
            // order; lists are already in canonical ascending-gid order.
            let mut g_lens = vec![0u32; n];
            for sl in &shard_lists {
                for (k, &g) in sl.owned_gids.iter().enumerate() {
                    g_lens[g as usize] = sl.lens[k];
                }
            }
            let offsets = crate::parallel::exclusive_scan_u32(&g_lens, threads);
            let total = offsets.last().copied().unwrap_or(0) as usize;
            let mut g_items = vec![0u32; total];
            for sl in &shard_lists {
                let mut cur = 0usize;
                for (k, &g) in sl.owned_gids.iter().enumerate() {
                    let len = sl.lens[k] as usize;
                    let dst = offsets[g as usize] as usize;
                    g_items[dst..dst + len].copy_from_slice(&sl.items[cur..cur + len]);
                    cur += len;
                }
            }
            let nl = NeighborLists { offsets, items: g_items };

            // --- Phase 7: the same global kernels as the single-domain run.
            // Identical canonical lists + identical kernel code ⇒ identical
            // f32 operation sequences ⇒ bitwise-identical forces and
            // positions. (Per-device cost was attributed shard by shard.)
            let mut kernel_scratch = OpCounts::default();
            self.state.force = self
                .kernels
                .lj_forces(&self.state, &nl, &mut kernel_scratch)
                .map_err(SimError::fatal)?;
            // Fallback-rung shards never fed the merge; their owned rows
            // come from the shared canonical payload gather — byte-for-byte
            // the row the list kernel would have produced.
            for &(g, f) in &fallback_payloads {
                self.state.force[g as usize] = f;
            }
            self.kernels.integrate(&mut self.state, &mut kernel_scratch).map_err(SimError::fatal)?;
            interactions = if backend == ApproachKind::OrcsForces {
                forces_pairs_total
            } else {
                // entries from fallback-rung shards count too, exactly as
                // they did when their lists still reached the merge
                entries_total / 2
            };
            self.telemetry.mark(
                GLOBAL_LANE,
                "merge",
                format!("merge: {} canonical list entries", nl.total_entries()),
            );
        }
        if opened {
            self.telemetry.end_step(agg.sim_s * 1e3);
        }

        Ok(ShardedStepRecord {
            step: self.state.step_count,
            sim_ms: agg.sim_s * 1e3,
            straggler: agg.straggler,
            energy_j: agg.energy_j,
            interactions,
            migrations,
            ghost_entries: total_ghosts,
            oom: None,
            per_shard,
        })
    }

    /// One sharded step under the resilience policy: consume injected
    /// faults (device losses recover from the last checkpoint), retry
    /// watchdog-rejected steps from the pre-step snapshot with halved `dt`
    /// and forced per-shard BVH rebuilds.
    fn step_resilient(&mut self) -> SimResult<ShardedStepRecord> {
        let res = self.cfg.resilience.clone();
        let step = self.state.step_count;
        // Open the telemetry step before consuming faults so device-loss
        // and squeeze markers land inside the step that absorbed them.
        let opened = self.telemetry.begin_step(step);
        let mut transient = false;
        for f in self.injector.take(step) {
            match f {
                FaultKind::VramSqueeze { budget_bytes } => {
                    self.vram_budget = Some(budget_bytes);
                    let kind = EventKind::VramSqueeze { budget_bytes };
                    let ev = ResilienceEvent { step, kind };
                    self.telemetry.mark_event(&ev);
                    self.events.push(ev);
                }
                FaultKind::Straggler { shard, slowdown } => {
                    let s = shard % self.slowdowns.len();
                    self.slowdowns[s] = slowdown;
                    let kind = EventKind::Straggler { shard: s, slowdown };
                    let ev = ResilienceEvent { step, kind };
                    self.telemetry.mark_event(&ev);
                    self.events.push(ev);
                }
                FaultKind::Transient => transient = true,
                FaultKind::Divergence => self.divergence_armed = true,
                FaultKind::DeviceLost { shard } => self.lose_device(shard)?,
            }
        }

        let mut wasted_ms = 0.0;
        let mut wasted_j = 0.0;
        let mut attempt = 0u32;
        loop {
            let snapshot = res
                .watchdog
                .enabled
                .then(|| (self.state.clone(), self.owner.clone()));
            let mut rec = self.step_raw()?;

            if self.divergence_armed && rec.oom.is_none() && !self.state.vel.is_empty() {
                // injected divergence: blow up one velocity (finite, so only
                // the kinetic-energy bound can catch it)
                self.divergence_armed = false;
                // lint:allow(P-INDEX-LIT): guarded by !vel.is_empty() above
                self.state.vel[0] = self.state.vel[0] * 1e15 + Vec3::splat(1e15);
            }

            if res.watchdog.enabled && rec.oom.is_none() {
                if let Err(detail) = self.watchdog.check(&res.watchdog, &self.state) {
                    if attempt >= res.watchdog.max_retries {
                        return Err(SimError::NumericalDivergence { detail });
                    }
                    attempt += 1;
                    let Some((state, owner)) = snapshot else {
                        return Err(SimError::fatal("watchdog retry without a pre-step snapshot"));
                    };
                    self.state = state;
                    self.owner = owner;
                    self.state.dt *= 0.5;
                    for sh in &mut self.shards {
                        sh.mgr.invalidate();
                    }
                    wasted_ms += rec.sim_ms;
                    wasted_j += rec.energy_j;
                    let ev = ResilienceEvent {
                        step,
                        kind: EventKind::WatchdogRetry { attempt, dt: self.state.dt, detail },
                    };
                    self.telemetry.mark_event(&ev);
                    self.events.push(ev);
                    continue;
                }
            }

            if transient {
                // the attempt failed spuriously mid-flight and re-ran: the
                // physics is the re-run's, the price includes the discard
                wasted_ms += rec.sim_ms;
                wasted_j += rec.energy_j;
                let ev = ResilienceEvent { step, kind: EventKind::TransientRetry { attempt: 1 } };
                self.telemetry.mark_event(&ev);
                self.events.push(ev);
            }

            rec.sim_ms += wasted_ms;
            rec.energy_j += wasted_j;
            for s in &mut self.slowdowns {
                *s = 1.0;
            }
            if res.checkpoint_every > 0
                && rec.oom.is_none()
                && self.state.step_count % res.checkpoint_every == 0
            {
                self.checkpoint = Some(self.take_checkpoint());
                self.telemetry.mark(
                    GLOBAL_LANE,
                    "checkpoint",
                    format!("checkpoint @ step {}", self.state.step_count),
                );
            }
            if opened {
                self.telemetry.end_step(rec.sim_ms);
            }
            return Ok(rec);
        }
    }

    /// Snapshot everything a replacement fleet needs to resume: global
    /// state + ownership, plus each shard's policy instance and metering
    /// high-water marks.
    fn take_checkpoint(&self) -> FleetCheckpoint {
        FleetCheckpoint {
            step: self.state.step_count,
            state: self.state.clone(),
            owner: self.owner.clone(),
            stepped: self.stepped,
            shards: self
                .shards
                .iter()
                .enumerate()
                .map(|(i, sh)| ShardCheckpoint {
                    policy: sh.mgr.clone_policy(),
                    k_max_seen: sh.k_max_seen,
                    listless: self.listless[i],
                })
                .collect(),
        }
    }

    /// Restore from the retained checkpoint; every shard gets a fresh
    /// [`BvhManager`] (empty BVH ⇒ forced rebuild) seeded with the
    /// checkpointed policy state. Returns the number of steps to replay,
    /// or a fatal error when no checkpoint was retained.
    fn restore_checkpoint(&mut self) -> SimResult<u64> {
        let Some(cp) = self.checkpoint.as_ref() else {
            return Err(SimError::fatal("restore without a checkpoint"));
        };
        let replayed = self.state.step_count.saturating_sub(cp.step);
        self.state = cp.state.clone();
        self.owner = cp.owner.clone();
        self.stepped = cp.stepped;
        for i in 0..self.shards.len() {
            let scp = &cp.shards[i];
            self.shards[i].mgr = BvhManager::new(scp.policy.clone_box());
            self.shards[i].members_prev = Vec::new();
            self.shards[i].k_max_seen = scp.k_max_seen;
            self.listless[i] = scp.listless;
        }
        self.watchdog.reset();
        Ok(replayed)
    }

    /// Handle an injected device loss: drop the device from the fleet,
    /// rebind every shard round-robin over the survivors, and resume the
    /// whole fleet from the last checkpoint (the re-decomposition replays
    /// the trajectory from a step boundary, so physics stays bitwise
    /// identical to a fault-free run).
    fn lose_device(&mut self, shard: usize) -> SimResult<()> {
        let idx = shard % self.devices.len();
        let device = self.devices[idx].name.to_string();
        if self.devices.len() == 1 || self.checkpoint.is_none() {
            return Err(SimError::DeviceLost { shard, device });
        }
        self.devices.remove(idx);
        let at = self.state.step_count;
        let ev = ResilienceEvent {
            step: at,
            kind: EventKind::DeviceLost { shard, device, survivors: self.devices.len() },
        };
        self.telemetry.mark_event(&ev);
        self.events.push(ev);
        for (s, sh) in self.shards.iter_mut().enumerate() {
            sh.hw = self.devices[s % self.devices.len()];
        }
        let replayed = self.restore_checkpoint()?;
        self.replayed += replayed;
        let from_step = self.state.step_count;
        let ev =
            ResilienceEvent { step: at, kind: EventKind::Recovery { from_step, replayed } };
        self.telemetry.mark_event(&ev);
        self.events.push(ev);
        Ok(())
    }

    /// Drain the resilience log (events accumulate across steps).
    pub fn take_events(&mut self) -> Vec<ResilienceEvent> {
        std::mem::take(&mut self.events)
    }

    /// Steps re-executed by checkpoint recovery so far.
    pub fn replayed_steps(&self) -> u64 {
        self.replayed
    }

    /// The telemetry recorder: per-step spans, metrics, flight recorder.
    pub fn telemetry(&self) -> &Recorder {
        &self.telemetry
    }

    pub fn telemetry_mut(&mut self) -> &mut Recorder {
        &mut self.telemetry
    }

    /// Run `steps` steps; aborts early when any shard OOMs (the fleet
    /// cannot complete the step).
    pub fn run(&mut self, steps: usize, keep_trace: bool) -> Result<ShardedRunSummary> {
        let wall_start = WallTimer::start();
        let mut s = ShardedRunSummary {
            scenario: self.cfg.sim.tag(),
            grid: self.cfg.spec.to_string(),
            fleet: {
                let mut uniq: Vec<&str> = Vec::new();
                for sh in &self.shards {
                    if !uniq.contains(&sh.hw.name) {
                        uniq.push(sh.hw.name);
                    }
                }
                uniq.join("+")
            },
            per_shard: self
                .shards
                .iter()
                .map(|sh| ShardTotals { hw: sh.hw.name, ..Default::default() })
                .collect(),
            ..Default::default()
        };
        let target = self.state.step_count + steps as u64;
        while self.state.step_count < target {
            let i = self.state.step_count;
            let rec = match self.step() {
                Ok(rec) => rec,
                Err(e) => {
                    // Fault forensics: dump the flight recorder (including
                    // the partially-recorded failing step) before bailing.
                    let dump = self.telemetry.flight_dump();
                    if !dump.is_empty() {
                        eprintln!("{dump}");
                    }
                    self.telemetry.abandon_step();
                    return Err(anyhow::anyhow!(
                        "sharded step {i} failed [grid {}, fleet {}]: {e}",
                        s.grid,
                        s.fleet
                    ));
                }
            };
            s.steps += 1;
            s.total_sim_ms += rec.sim_ms;
            s.total_energy_j += rec.energy_j;
            s.total_interactions += rec.interactions;
            s.migrations += rec.migrations;
            s.ghost_entries += rec.ghost_entries;
            for st in &rec.per_shard {
                let t = &mut s.per_shard[st.shard];
                match st.action {
                    BvhAction::Build => t.builds += 1,
                    BvhAction::Update => t.updates += 1,
                }
                if st.forced_build {
                    t.forced_builds += 1;
                }
                if st.listless {
                    t.listless_steps += 1;
                }
                t.owned_sum += st.owned as u64;
                t.ghosts_sum += st.ghosts as u64;
                t.max_k_max = t.max_k_max.max(st.k_max);
                t.max_list_bytes = t.max_list_bytes.max(st.list_bytes);
                t.total_sim_ms += st.sim_ms;
            }
            let rec_oom = rec.oom;
            if keep_trace {
                s.records.push(rec);
            }
            if let Some((shard, bytes)) = rec_oom {
                s.oom = true;
                s.oom_shard = shard;
                s.oom_bytes = bytes;
                break;
            }
        }
        if s.steps > 0 {
            s.avg_sim_ms = s.total_sim_ms / s.steps as f64;
        }
        s.ee = crate::rtcore::power::energy_efficiency(s.total_interactions, s.total_energy_j);
        s.wall_total_s = wall_start.elapsed_s();
        s.events = self.events.clone();
        s.replayed_steps = self.replayed;
        Ok(s)
    }
}
