// Fixture: seeded D-FP-PARALLEL violation (unordered float accumulation
// inside a parallel_for_chunks closure).
pub fn sum_masses(masses: &[f32], threads: usize) -> f32 {
    let mut total: f32 = 0.0;
    crate::parallel::parallel_for_chunks(masses.len(), threads, |_, range| {
        for i in range {
            total += masses[i];
        }
    });
    total
}
