//! Fig. 13 — performance and energy-efficiency scaling across four GPU
//! generations (TITAN RTX → A40 → L40 → RTX Pro 6000 Blackwell).
//!
//! Shape targets: the A40→L40 jump is the strongest; Blackwell keeps
//! scaling performance but EE stays roughly flat (its 600 W envelope); the
//! RT-core approaches self-scale the most; RT-REF is absent (OOM) in the
//! Lattice-r160 and Cluster-LN columns at paper scale.

use anyhow::Result;

use super::common::{energy_cases, paper_scale_oom, BenchOpts};
use crate::coordinator::report::{results_dir, CsvWriter, TextTable};
use crate::core::config::Boundary;
use crate::frnn::ApproachKind;
use crate::rtcore::profile::GENERATIONS;

const N_DEFAULT: usize = 6_000;
const STEPS_DEFAULT: usize = 30;
/// Paper-scale n for the RT-REF OOM mirroring (see §4.3: Lattice r=160 at
/// n=1M needs ~25k neighbors/particle; Cluster-LN approaches k ~ n).
const N_PAPER: usize = 1_000_000;

const GPU_APPROACHES: [ApproachKind; 4] = [
    ApproachKind::GpuCell,
    ApproachKind::RtRef,
    ApproachKind::OrcsForces,
    ApproachKind::OrcsPerse,
];

pub fn run(opts: &BenchOpts) -> Result<()> {
    let (n, steps) = opts.size(N_DEFAULT, STEPS_DEFAULT);
    println!("== Fig. 13: scaling across GPU generations (n={n}, {steps} steps, periodic BC) ==\n");

    let mut csv = CsvWriter::create(
        &results_dir().join("fig13_scaling.csv"),
        &["case", "gpu", "approach", "avg_sim_ms", "perf_rel_titan", "ee_int_per_j",
          "ee_rel_titan", "oom_paper_scale"],
    )?;

    for case in energy_cases() {
        let mut perf_table = TextTable::new(&["approach", "TITANRTX", "A40", "L40", "RTXPRO"]);
        let mut ee_table = TextTable::new(&["approach", "TITANRTX", "A40", "L40", "RTXPRO"]);
        for approach in GPU_APPROACHES {
            let mut perf_fields = vec![approach.to_string()];
            let mut ee_fields = vec![approach.to_string()];
            let mut baseline: Option<(f64, f64)> = None; // (ms, ee) on Titan
            for hw in GENERATIONS {
                let mut o = BenchOpts {
                    threads: opts.threads,
                    hw,
                    kernels: opts.kernels.clone(),
                    quick: opts.quick,
                    steps_override: opts.steps_override,
                    n_override: opts.n_override,
                    seed: opts.seed,
                };
                o.hw = hw;
                let Some(s) =
                    o.run(&case, n, Boundary::Periodic, approach, "gradient", steps, true)?
                else {
                    perf_fields.push("-".into());
                    ee_fields.push("-".into());
                    continue;
                };
                let k_max_like = s
                    .records
                    .iter()
                    .map(|r| r.counts.nbr_list_bytes_peak / 4 / (n as u64).max(1))
                    .max()
                    .unwrap_or(0) as usize;
                let oom = s.oom
                    || (approach == ApproachKind::RtRef
                        && paper_scale_oom(k_max_like, n, N_PAPER, hw));
                if oom {
                    perf_fields.push("OOM".into());
                    ee_fields.push("OOM".into());
                    csv.row(&[
                        case.tag(),
                        hw.name.to_string(),
                        approach.to_string(),
                        format!("{:.4}", s.avg_sim_ms),
                        "".into(),
                        "".into(),
                        "".into(),
                        "true".into(),
                    ])?;
                    continue;
                }
                let (base_ms, base_ee) = *baseline.get_or_insert((s.avg_sim_ms, s.ee));
                let perf_rel = base_ms / s.avg_sim_ms.max(1e-12);
                let ee_rel = s.ee / base_ee.max(1e-12);
                perf_fields.push(format!("{perf_rel:.2}x"));
                ee_fields.push(format!("{ee_rel:.2}x"));
                csv.row(&[
                    case.tag(),
                    hw.name.to_string(),
                    approach.to_string(),
                    format!("{:.4}", s.avg_sim_ms),
                    format!("{perf_rel:.3}"),
                    format!("{:.1}", s.ee),
                    format!("{ee_rel:.3}"),
                    "false".into(),
                ])?;
            }
            perf_table.row(perf_fields);
            ee_table.row(ee_fields);
        }
        println!("--- {} — performance scaling (relative to first non-OOM gen) ---", case.tag());
        println!("{}", perf_table.render());
        println!("--- {} — energy-efficiency scaling ---", case.tag());
        println!("{}", ee_table.render());
    }
    println!("CSV: {}", results_dir().join("fig13_scaling.csv").display());
    Ok(())
}
