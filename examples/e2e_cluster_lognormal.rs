//! END-TO-END driver (DESIGN.md experiment E9): the full three-layer stack
//! on the paper's hardest workload — a particle cluster with log-normal
//! radii under periodic BC.
//!
//! Exercises every layer in one run:
//!   L1/L2  AOT Pallas/JAX HLO artifacts executed through PJRT (`make
//!          artifacts` first) — the RT-REF force kernel and the integration
//!          kernel on the hot path;
//!   L3     the Rust coordinator: gradient BVH policy, gamma-ray periodic
//!          BC, RT-REF and ORCS-forces pipelines, timing/power metering.
//!
//! Phase A runs RT-REF (neighbor list + XLA force kernel) and extrapolates
//! its list allocation to paper scale — where it ooms, exactly as Table 2
//! reports. Phase B runs ORCS-forces (no list, XLA integration kernel),
//! which handles the same physics in bounded memory; its per-step series is
//! the "loss curve" of this reproduction.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_cluster_lognormal
//! ```

use std::sync::Arc;

use orcs::benchsuite::common::paper_scale_oom;
use orcs::coordinator::{Engine, EngineConfig};
use orcs::core::config::{Boundary, ParticleDist, RadiusDist, SimConfig};
use orcs::frnn::ApproachKind;
use orcs::runtime::kernels::XlaKernels;

fn main() -> anyhow::Result<()> {
    // Phase A's neighbor lists are catastrophically wide by design (k_max
    // ~ n: that's the point), so a handful of steps suffices to measure
    // the allocation; phase B carries the long run.
    let n = 8_000;
    let steps_a = 5;
    let steps_b = 200;
    let sim = SimConfig {
        n,
        box_l: 1000.0,
        particle_dist: ParticleDist::Cluster,
        radius_dist: RadiusDist::LogNormal { mu: 1.0, sigma: 2.0, lo: 1.0, hi: 330.0 },
        boundary: Boundary::Periodic,
        seed: 31415,
        ..SimConfig::default()
    };

    println!("=== e2e: Cluster + LogNormal radii, periodic BC (n={n}) ===");
    println!("loading AOT artifacts (run `make artifacts` if this fails)...");
    let kernels = Arc::new(XlaKernels::load_default()?);
    println!("PJRT CPU executables compiled: lj_forces k∈{{16,64,256}}, integrate\n");

    // ---- Phase A: RT-REF with the XLA force kernel ----
    println!("[phase A] RT-REF: RT discovery -> neighbor list -> XLA force kernel");
    let ec = EngineConfig {
        policy: "gradient".into(),
        threads: orcs::parallel::num_threads(),
        check_oom: true,
        ..EngineConfig::new(sim.clone(), ApproachKind::RtRef)
    };
    let mut engine = Engine::new(ec, kernels.clone())?;
    let mut k_max_seen = 0usize;
    for s in 0..steps_a {
        let rec = engine.step()?;
        k_max_seen = k_max_seen
            .max((rec.counts.nbr_list_bytes_peak / 4 / n as u64) as usize);
        if s % 2 == 0 {
            println!(
                "  step {:>4}  sim {:>8.3} ms  k_max {:>6}  pairs {:>9}  launches {:>3}",
                rec.step, rec.sim_ms, k_max_seen, rec.counts.force_kernel_pairs,
                rec.counts.kernel_launches
            );
        }
        if let Some(bytes) = rec.oom_bytes {
            println!("  !! RT-REF OOM at bench scale: {bytes} bytes");
            break;
        }
    }
    let hw = orcs::rtcore::profile::DEFAULT_GPU;
    let ooms = paper_scale_oom(k_max_seen, n, 1_000_000, hw);
    println!(
        "  k_max={k_max_seen} at n={n}; extrapolated to the paper's n=1M: {}",
        if ooms {
            "neighbor list EXCEEDS device memory -> the paper's OOM cells"
        } else {
            "would fit (unexpected for this workload)"
        }
    );

    // ---- Phase B: ORCS-forces, no neighbor list ----
    println!("\n[phase B] ORCS-forces: in-shader scatter (no list) -> XLA integrate");
    let ec = EngineConfig {
        policy: "gradient".into(),
        threads: orcs::parallel::num_threads(),
        check_oom: true,
        ..EngineConfig::new(sim, ApproachKind::OrcsForces)
    };
    let mut engine = Engine::new(ec, kernels)?;
    println!("  step   sim-ms    rt-ms   power-W        KE  interactions  bvh");
    let mut summary_rows = 0;
    for s in 0..steps_b {
        let rec = engine.step()?;
        if s % 20 == 0 || s + 1 == steps_b {
            println!(
                "  {:>4} {:>8.3} {:>8.3} {:>9.0} {:>9.1} {:>13} {:>8}",
                rec.step,
                rec.sim_ms,
                rec.rt_ms,
                rec.energy.avg_power_w,
                engine.state.kinetic_energy(),
                rec.interactions,
                match rec.bvh_action {
                    Some(orcs::gradient::BvhAction::Build) => "rebuild",
                    Some(orcs::gradient::BvhAction::Update) => "update",
                    None => "-",
                }
            );
            summary_rows += 1;
        }
    }
    assert!(engine.state.is_finite(), "simulation diverged");
    assert!(summary_rows > 0);
    println!(
        "\ne2e OK: {} steps on the XLA hot path; ORCS-forces handled the workload RT-REF cannot hold at paper scale.",
        engine.state.step_count
    );
    Ok(())
}
