//! Quickstart: simulate a Lennard-Jones gas with the ORCS-forces pipeline
//! (RT-core FRNN without neighbor lists) and print the metered summary.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use orcs::coordinator::{Engine, EngineConfig};
use orcs::core::config::{Boundary, ParticleDist, RadiusDist, SimConfig};
use orcs::frnn::ApproachKind;

fn main() -> anyhow::Result<()> {
    // 1. Describe the scenario: 5k particles, uniform radius, periodic box.
    let sim = SimConfig {
        n: 5_000,
        box_l: 1000.0,
        particle_dist: ParticleDist::Disordered,
        radius_dist: RadiusDist::Const(20.0),
        boundary: Boundary::Periodic,
        ..SimConfig::default()
    };

    // 2. Bind it to a backend (ORCS-forces) with the gradient BVH policy,
    //    priced on the paper's Blackwell testbed GPU.
    let cfg = EngineConfig::new(sim, ApproachKind::OrcsForces);
    let mut engine = Engine::new_rust(cfg)?;

    // 3. Step the simulation; every step is fully metered.
    println!("step    sim-ms     rt-ms   power-W  interactions  bvh");
    for s in 0..50 {
        let rec = engine.step()?;
        if s % 5 == 0 {
            println!(
                "{:>4} {:>9.4} {:>9.4} {:>9.0} {:>13} {:>8}",
                rec.step,
                rec.sim_ms,
                rec.rt_ms,
                rec.energy.avg_power_w,
                rec.interactions,
                match rec.bvh_action {
                    Some(orcs::gradient::BvhAction::Build) => "rebuild",
                    Some(orcs::gradient::BvhAction::Update) => "update",
                    None => "-",
                }
            );
        }
    }

    // 4. Physics diagnostics come straight off the state.
    println!(
        "\nfinal: KE={:.3}  |p|={:.4}  finite={}  in-box={}",
        engine.state.kinetic_energy(),
        engine.state.total_momentum().norm(),
        engine.state.is_finite(),
        engine.state.all_in_box(),
    );
    Ok(())
}
