// Fixture: seeded L-ALLOW violation — the suppression below names an
// unknown rule, so it suppresses nothing and is itself flagged.
// lint:allow(NOT-A-RULE): bogus suppression
pub fn noop() {}
