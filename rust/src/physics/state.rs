//! Mutable simulation state shared by every backend (structure-of-arrays).

use crate::core::config::{Boundary, SimConfig};
use crate::core::distributions::{self, Scene};
use crate::core::vec3::Vec3;
use crate::physics::lj::LjParams;

/// Structure-of-arrays particle state plus the physics parameters.
#[derive(Clone, Debug)]
pub struct SimState {
    pub pos: Vec<Vec3>,
    pub vel: Vec<Vec3>,
    /// Per-particle force accumulator for the current step.
    pub force: Vec<Vec3>,
    /// Per-particle search radius (= interaction cutoff contribution).
    pub radius: Vec<f32>,
    /// Largest radius in the system (gamma-ray trigger distance, §3.3).
    pub r_max: f32,
    pub box_l: f32,
    pub boundary: Boundary,
    pub dt: f32,
    pub params: LjParams,
    /// Steps simulated so far.
    pub step_count: u64,
}

impl SimState {
    /// Build the initial state for a configuration (deterministic in seed).
    pub fn from_config(cfg: &SimConfig) -> Self {
        let Scene { pos, vel, radius, r_max, box_l } = distributions::scene(cfg);
        let n = pos.len();
        SimState {
            pos,
            vel,
            force: vec![Vec3::ZERO; n],
            radius,
            r_max,
            box_l,
            boundary: cfg.boundary,
            dt: cfg.dt,
            params: LjParams {
                epsilon: cfg.epsilon,
                sigma_factor: cfg.sigma_factor,
                f_max: cfg.f_max,
            },
            step_count: 0,
        }
    }

    pub fn n(&self) -> usize {
        self.pos.len()
    }

    /// Zero the force accumulators (start of a step).
    pub fn clear_forces(&mut self) {
        for f in &mut self.force {
            *f = Vec3::ZERO;
        }
    }

    /// Total momentum (diagnostic: conserved in periodic boxes with
    /// symmetric forces, up to f32 rounding and force caps).
    pub fn total_momentum(&self) -> Vec3 {
        self.vel.iter().fold(Vec3::ZERO, |a, &v| a + v)
    }

    /// Total kinetic energy (unit mass).
    pub fn kinetic_energy(&self) -> f64 {
        self.vel.iter().map(|v| 0.5 * v.norm2() as f64).sum()
    }

    /// True if every particle is inside the box (wall BC invariant).
    pub fn all_in_box(&self) -> bool {
        self.pos.iter().all(|p| {
            (0.0..=self.box_l).contains(&p.x)
                && (0.0..=self.box_l).contains(&p.y)
                && (0.0..=self.box_l).contains(&p.z)
        })
    }

    /// True if all positions and velocities are finite.
    pub fn is_finite(&self) -> bool {
        self.pos.iter().all(|p| p.is_finite()) && self.vel.iter().all(|v| v.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::{ParticleDist, RadiusDist};

    #[test]
    fn from_config_shapes() {
        let cfg = SimConfig { n: 64, ..SimConfig::default() };
        let s = SimState::from_config(&cfg);
        assert_eq!(s.n(), 64);
        assert_eq!(s.force.len(), 64);
        assert_eq!(s.radius.len(), 64);
        assert!(s.all_in_box());
        assert!(s.is_finite());
    }

    #[test]
    fn clear_forces_zeroes() {
        let cfg = SimConfig { n: 8, ..SimConfig::default() };
        let mut s = SimState::from_config(&cfg);
        s.force[3] = Vec3::splat(5.0);
        s.clear_forces();
        assert!(s.force.iter().all(|f| *f == Vec3::ZERO));
    }

    #[test]
    fn diagnostics_reasonable() {
        let cfg = SimConfig {
            n: 100,
            particle_dist: ParticleDist::Lattice,
            radius_dist: RadiusDist::Const(1.0),
            ..SimConfig::default()
        };
        let s = SimState::from_config(&cfg);
        assert!(s.kinetic_energy() > 0.0);
        // velocity kick is zero-mean, so total momentum is small
        assert!(s.total_momentum().norm() < 0.05 * 100.0);
    }
}
