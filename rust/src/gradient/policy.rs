//! BVH rebuild/update policies.
//!
//! Three policies from the paper's §4.1 benchmark:
//! * [`FixedKPolicy`] — rebuild every `k` steps (`fixed-200`);
//! * [`AvgPolicy`] — rebuild once the average step cost since the last
//!   rebuild exceeds the average cost of a rebuild step (`avg`);
//! * [`GradientPolicy`] — the paper's contribution: estimate `t_u`, `t_r`
//!   and `Δq` online and rebuild after `k_opt` updates (Eq. 8).
//!
//! The paper samples its timers with NVML; here the observations come from
//! the simulated RT clock ([`crate::rtcore::timing`]) so runs are exactly
//! reproducible (see DESIGN.md §Hardware-Adaptation).

use super::cost_model::{optimal_ku, CostParams};

/// What to do with the BVH before the next RT query.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BvhAction {
    Build,
    Update,
}

/// One step's timing observation fed back to the policy.
#[derive(Clone, Copy, Debug)]
pub struct StepObs {
    /// Action that was taken this step.
    pub action: BvhAction,
    /// Cost of the build *or* update operation (simulated ms).
    pub bvh_op_time: f64,
    /// Cost of the RT query phase this step (simulated ms).
    pub query_time: f64,
    /// Energy of the BVH operation (simulated millijoules; 0 when the
    /// caller does not meter energy). Used by [`GradientEePolicy`].
    pub bvh_op_energy: f64,
    /// Energy of the query phase (simulated millijoules).
    pub query_energy: f64,
}

/// A rebuild/update decision policy.
pub trait RebuildPolicy: Send {
    /// Decide the action for the upcoming step.
    fn decide(&mut self) -> BvhAction;
    /// Feed back the observed costs of the step just executed.
    fn observe(&mut self, obs: StepObs);
    fn name(&self) -> String;
    /// Current estimate of the update budget (diagnostic; NaN if n/a).
    fn current_k(&self) -> f64 {
        f64::NAN
    }
    /// Clone the policy with its full internal state (checkpoint support —
    /// restoring a shard must resume the optimizer where it left off).
    fn clone_box(&self) -> Box<dyn RebuildPolicy>;
}

// ---------------------------------------------------------------- fixed-k

/// Rebuild every `k` steps, update otherwise (`fixed-200` in the paper).
#[derive(Clone, Debug)]
pub struct FixedKPolicy {
    k: u64,
    since_build: u64,
    started: bool,
}

impl FixedKPolicy {
    pub fn new(k: u64) -> Self {
        FixedKPolicy { k: k.max(1), since_build: 0, started: false }
    }
}

impl RebuildPolicy for FixedKPolicy {
    fn decide(&mut self) -> BvhAction {
        if !self.started {
            self.started = true;
            return BvhAction::Build;
        }
        if self.since_build + 1 >= self.k {
            BvhAction::Build
        } else {
            BvhAction::Update
        }
    }

    fn observe(&mut self, obs: StepObs) {
        match obs.action {
            BvhAction::Build => self.since_build = 0,
            BvhAction::Update => self.since_build += 1,
        }
    }

    fn name(&self) -> String {
        format!("fixed-{}", self.k)
    }

    fn current_k(&self) -> f64 {
        self.k as f64
    }

    fn clone_box(&self) -> Box<dyn RebuildPolicy> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------- avg

/// Rebuild when the average per-step cost since the last rebuild surpasses
/// the average cost of a rebuild step (the `avg` baseline). Reacts slowly —
/// the running average drags behind sudden dynamics changes, which is
/// exactly the weakness Fig. 8 exposes.
#[derive(Clone, Debug, Default)]
pub struct AvgPolicy {
    started: bool,
    /// Mean cost of a rebuild step (build + query), running over all builds.
    rebuild_step_avg: f64,
    rebuild_steps: u64,
    /// Accumulated cost and count of steps since the last rebuild.
    since_cost: f64,
    since_steps: u64,
}

impl AvgPolicy {
    pub fn new() -> Self {
        Self::default()
    }
}

impl RebuildPolicy for AvgPolicy {
    fn decide(&mut self) -> BvhAction {
        if !self.started {
            self.started = true;
            return BvhAction::Build;
        }
        if self.since_steps == 0 {
            return BvhAction::Update;
        }
        let avg_since = self.since_cost / self.since_steps as f64;
        if self.rebuild_steps > 0 && avg_since > self.rebuild_step_avg {
            BvhAction::Build
        } else {
            BvhAction::Update
        }
    }

    fn observe(&mut self, obs: StepObs) {
        let step_cost = obs.bvh_op_time + obs.query_time;
        match obs.action {
            BvhAction::Build => {
                self.rebuild_steps += 1;
                let n = self.rebuild_steps as f64;
                self.rebuild_step_avg += (step_cost - self.rebuild_step_avg) / n;
                self.since_cost = 0.0;
                self.since_steps = 0;
            }
            BvhAction::Update => {
                self.since_cost += step_cost;
                self.since_steps += 1;
            }
        }
    }

    fn name(&self) -> String {
        "avg".into()
    }

    fn clone_box(&self) -> Box<dyn RebuildPolicy> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------- gradient

/// The paper's adaptive optimizer. Maintains EMA estimates of `t_u`, `t_r`
/// and the degradation slope `Δq`, and rebuilds once the number of updates
/// since the last rebuild reaches `k_opt` (Eq. 8).
#[derive(Clone, Debug)]
pub struct GradientPolicy {
    started: bool,
    /// EMA smoothing factor for the time estimates.
    alpha: f64,
    t_r: f64,
    t_u: f64,
    /// Query cost right after the last rebuild (the `t_q` anchor).
    q_fresh: f64,
    /// EMA of the degradation slope Δq.
    dq: f64,
    updates_since_build: u64,
    /// Previous step's query time, for slope sampling.
    last_query: f64,
    k_opt: f64,
    /// Minimum updates before trusting the Δq estimate.
    warmup: u64,
}

impl GradientPolicy {
    pub fn new() -> Self {
        GradientPolicy {
            started: false,
            alpha: 0.3,
            t_r: f64::NAN,
            t_u: f64::NAN,
            q_fresh: f64::NAN,
            dq: f64::NAN,
            updates_since_build: 0,
            last_query: f64::NAN,
            k_opt: 8.0, // optimistic initial budget, refined online
            warmup: 2,
        }
    }

    fn ema(current: f64, sample: f64, alpha: f64) -> f64 {
        if current.is_nan() {
            sample
        } else {
            current + alpha * (sample - current)
        }
    }

    /// Current parameter estimates (diagnostics / tests).
    pub fn estimates(&self) -> CostParams {
        CostParams {
            t_r: self.t_r,
            t_u: self.t_u,
            t_q: self.q_fresh,
            dq: self.dq,
        }
    }
}

impl Default for GradientPolicy {
    fn default() -> Self {
        Self::new()
    }
}

impl RebuildPolicy for GradientPolicy {
    fn decide(&mut self) -> BvhAction {
        if !self.started {
            self.started = true;
            return BvhAction::Build;
        }
        // Need at least one observed rebuild and update cost to decide.
        if self.t_r.is_nan() || self.t_u.is_nan() {
            return BvhAction::Update;
        }
        if self.updates_since_build >= self.warmup
            && (self.updates_since_build as f64) >= self.k_opt
        {
            BvhAction::Build
        } else {
            BvhAction::Update
        }
    }

    fn observe(&mut self, obs: StepObs) {
        match obs.action {
            BvhAction::Build => {
                self.t_r = Self::ema(self.t_r, obs.bvh_op_time, self.alpha);
                self.q_fresh = Self::ema(self.q_fresh, obs.query_time, self.alpha);
                self.updates_since_build = 0;
                self.last_query = obs.query_time;
            }
            BvhAction::Update => {
                self.t_u = Self::ema(self.t_u, obs.bvh_op_time, self.alpha);
                // Per-step degradation sample: rise of query cost since the
                // previous step. Clamp at 0 — noise can make it negative.
                if !self.last_query.is_nan() {
                    let slope = (obs.query_time - self.last_query).max(0.0);
                    self.dq = Self::ema(self.dq, slope, self.alpha);
                }
                self.last_query = obs.query_time;
                self.updates_since_build += 1;
            }
        }
        if !self.t_r.is_nan() && !self.t_u.is_nan() && !self.dq.is_nan() {
            self.k_opt = optimal_ku(&self.estimates());
        }
    }

    fn name(&self) -> String {
        "gradient".into()
    }

    fn current_k(&self) -> f64 {
        self.k_opt
    }

    fn clone_box(&self) -> Box<dyn RebuildPolicy> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------- gradient-ee

/// The paper's §5 future-work extension: run the gradient cost model on
/// *energy* instead of time — `t_r`, `t_u` and `Δq` become joules per step,
/// so `k_opt` minimizes the total energy of the BVH pipeline. The math is
/// identical (Eq. 5 integrates any additive per-step cost); only the
/// observable changes.
#[derive(Clone, Debug, Default)]
pub struct GradientEePolicy {
    inner: GradientPolicy,
}

impl GradientEePolicy {
    pub fn new() -> Self {
        GradientEePolicy { inner: GradientPolicy::new() }
    }
}

impl RebuildPolicy for GradientEePolicy {
    fn decide(&mut self) -> BvhAction {
        self.inner.decide()
    }

    fn observe(&mut self, obs: StepObs) {
        // Re-map the observation onto the energy axis; fall back to time
        // when the caller supplied no energy metering.
        let (op, q) = if obs.bvh_op_energy > 0.0 || obs.query_energy > 0.0 {
            (obs.bvh_op_energy, obs.query_energy)
        } else {
            (obs.bvh_op_time, obs.query_time)
        };
        self.inner.observe(StepObs { bvh_op_time: op, query_time: q, ..obs });
    }

    fn name(&self) -> String {
        "gradient-ee".into()
    }

    fn current_k(&self) -> f64 {
        self.inner.current_k()
    }

    fn clone_box(&self) -> Box<dyn RebuildPolicy> {
        Box::new(self.clone())
    }
}

/// Parse a policy spec: `gradient`, `gradient-ee`, `avg`, `fixed-200`, ...
pub fn parse_policy(s: &str) -> Option<Box<dyn RebuildPolicy>> {
    let s = s.to_ascii_lowercase();
    if s == "gradient" {
        return Some(Box::new(GradientPolicy::new()));
    }
    if s == "gradient-ee" {
        return Some(Box::new(GradientEePolicy::new()));
    }
    if s == "avg" {
        return Some(Box::new(AvgPolicy::new()));
    }
    if let Some(k) = s.strip_prefix("fixed-") {
        return k.parse().ok().map(|k| Box::new(FixedKPolicy::new(k)) as Box<dyn RebuildPolicy>);
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive a policy against a synthetic BVH cost simulator where updates
    /// cost `t_u`, rebuilds `t_r`, and query cost grows by `dq` per update.
    fn drive(policy: &mut dyn RebuildPolicy, steps: usize, t_r: f64, t_u: f64, dq: f64) -> (f64, Vec<usize>) {
        let t_q = 5.0;
        let mut degradation = 0.0;
        let mut total = 0.0;
        let mut rebuild_steps = Vec::new();
        for s in 0..steps {
            let action = policy.decide();
            let (op, q) = match action {
                BvhAction::Build => {
                    degradation = 0.0;
                    rebuild_steps.push(s);
                    (t_r, t_q)
                }
                BvhAction::Update => {
                    degradation += dq;
                    (t_u, t_q + degradation)
                }
            };
            total += op + q;
            policy.observe(StepObs {
                action,
                bvh_op_time: op,
                query_time: q,
                bvh_op_energy: 0.0,
                query_energy: 0.0,
            });
        }
        (total, rebuild_steps)
    }

    #[test]
    fn fixed_k_rebuilds_on_schedule() {
        let mut p = FixedKPolicy::new(10);
        let (_, rebuilds) = drive(&mut p, 50, 10.0, 1.0, 0.5);
        assert_eq!(rebuilds, vec![0, 10, 20, 30, 40]);
    }

    #[test]
    fn gradient_converges_to_kopt() {
        let (t_r, t_u, dq) = (20.0, 1.0, 0.5);
        let mut p = GradientPolicy::new();
        drive(&mut p, 500, t_r, t_u, dq);
        let k_true = optimal_ku(&CostParams { t_r, t_u, t_q: 5.0, dq });
        assert!(
            (p.current_k() - k_true).abs() < 0.25 * k_true + 1.0,
            "estimated k={} true k={}",
            p.current_k(),
            k_true
        );
    }

    #[test]
    fn gradient_beats_bad_fixed_k_on_fast_dynamics() {
        // fast dynamics: heavy degradation -> fixed-200 is terrible
        let (t_r, t_u, dq) = (20.0, 1.0, 2.0);
        let mut g = GradientPolicy::new();
        let (cost_g, _) = drive(&mut g, 1000, t_r, t_u, dq);
        let mut f = FixedKPolicy::new(200);
        let (cost_f, _) = drive(&mut f, 1000, t_r, t_u, dq);
        assert!(cost_g < cost_f * 0.5, "gradient={cost_g} fixed200={cost_f}");
    }

    #[test]
    fn gradient_beats_eager_fixed_k_on_slow_dynamics() {
        // slow dynamics: rebuilding every step wastes t_r
        let (t_r, t_u, dq) = (50.0, 0.5, 0.01);
        let mut g = GradientPolicy::new();
        let (cost_g, _) = drive(&mut g, 1000, t_r, t_u, dq);
        let mut f = FixedKPolicy::new(2);
        let (cost_f, _) = drive(&mut f, 1000, t_r, t_u, dq);
        assert!(cost_g < cost_f, "gradient={cost_g} fixed2={cost_f}");
    }

    #[test]
    fn gradient_adapts_to_regime_change() {
        // start slow, switch to fast dynamics; k estimate must drop
        let mut p = GradientPolicy::new();
        drive(&mut p, 400, 20.0, 1.0, 0.02);
        let k_slow = p.current_k();
        drive(&mut p, 400, 20.0, 1.0, 4.0);
        let k_fast = p.current_k();
        assert!(k_fast < k_slow * 0.5, "k_slow={k_slow} k_fast={k_fast}");
    }

    #[test]
    fn avg_policy_eventually_rebuilds() {
        let mut p = AvgPolicy::new();
        let (_, rebuilds) = drive(&mut p, 300, 10.0, 1.0, 1.0);
        assert!(rebuilds.len() > 2, "rebuilds={rebuilds:?}");
        assert_eq!(rebuilds[0], 0);
    }

    #[test]
    fn parse_policies() {
        assert_eq!(parse_policy("gradient").unwrap().name(), "gradient");
        assert_eq!(parse_policy("gradient-ee").unwrap().name(), "gradient-ee");
        assert_eq!(parse_policy("avg").unwrap().name(), "avg");
        assert_eq!(parse_policy("fixed-200").unwrap().name(), "fixed-200");
        assert!(parse_policy("nope").is_none());
    }

    #[test]
    fn gradient_ee_optimizes_energy_axis() {
        // Energy observations scaled differently from time: if rebuilds are
        // energy-cheap relative to updates' degradation energy, the EE
        // policy must rebuild more eagerly than the time policy.
        let mut drive_scaled = |p: &mut dyn RebuildPolicy, e_op: f64, e_q: f64| {
            let t_q = 5.0;
            let mut deg = 0.0;
            for _ in 0..300 {
                let action = p.decide();
                let (op, q) = match action {
                    BvhAction::Build => {
                        deg = 0.0;
                        (20.0, t_q)
                    }
                    BvhAction::Update => {
                        deg += 0.5;
                        (1.0, t_q + deg)
                    }
                };
                p.observe(StepObs {
                    action,
                    bvh_op_time: op,
                    query_time: q,
                    bvh_op_energy: op * e_op,
                    query_energy: q * e_q,
                });
            }
        };
        let mut time_p = GradientPolicy::new();
        let mut ee_p = GradientEePolicy::new();
        drive_scaled(&mut time_p, 0.0, 0.0);
        drive_scaled(&mut ee_p, 0.3, 3.0);
        // energy axis: rebuild 0.3x cheaper, degradation 3x dearer -> lower k
        assert!(
            ee_p.current_k() < time_p.current_k(),
            "ee k={} time k={}",
            ee_p.current_k(),
            time_p.current_k()
        );
    }

    #[test]
    fn gradient_ee_falls_back_to_time_without_energy() {
        let mut p = GradientEePolicy::new();
        drive(&mut p, 300, 20.0, 1.0, 0.5);
        let k_true = optimal_ku(&CostParams { t_r: 20.0, t_u: 1.0, t_q: 5.0, dq: 0.5 });
        assert!((p.current_k() - k_true).abs() < 0.3 * k_true + 1.0);
    }
}
