//! Checkpoint containers for the resilient engines.
//!
//! A checkpoint is an in-memory snapshot taken at a step boundary. Physics
//! is deliberately independent of BVH topology, rebuild-policy history and
//! fleet binding (the canonical-list invariant), so restoring `SimState` +
//! ownership and rebuilding fresh BVHs replays the trajectory **bitwise** —
//! the property `tests/property_resilience.rs` pins. Policy state and list
//! widths are snapshotted too, so metering resumes without a cold-start
//! artifact.

use crate::gradient::policy::RebuildPolicy;
use crate::physics::state::SimState;

/// Snapshot of a single-domain [`crate::coordinator::Engine`].
#[derive(Clone, Debug)]
pub struct EngineCheckpoint {
    /// `step_count` at the boundary the snapshot was taken.
    pub step: u64,
    pub state: SimState,
}

/// Per-shard slice of a fleet checkpoint.
pub struct ShardCheckpoint {
    /// The shard's rebuild-policy state (gradient optimizer history etc.).
    pub policy: Box<dyn RebuildPolicy>,
    /// Widest pre-dedup list seen (the fixed-slot allocation width).
    pub k_max_seen: usize,
    /// Whether the shard had already degraded to the listless pipeline.
    pub listless: bool,
}

impl Clone for ShardCheckpoint {
    fn clone(&self) -> Self {
        ShardCheckpoint {
            policy: self.policy.clone_box(),
            k_max_seen: self.k_max_seen,
            listless: self.listless,
        }
    }
}

impl std::fmt::Debug for ShardCheckpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardCheckpoint")
            .field("policy", &self.policy.name())
            .field("k_max_seen", &self.k_max_seen)
            .field("listless", &self.listless)
            .finish()
    }
}

/// Snapshot of a [`crate::shard::ShardedEngine`] at a step boundary.
#[derive(Clone, Debug)]
pub struct FleetCheckpoint {
    pub step: u64,
    pub state: SimState,
    /// Owner shard per particle.
    pub owner: Vec<u32>,
    /// Whether the engine had stepped at least once (migration baseline).
    pub stepped: bool,
    pub shards: Vec<ShardCheckpoint>,
}
