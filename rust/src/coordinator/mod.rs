//! The coordinator: owns the simulation loop, binds backends to physics
//! kernels and hardware profiles, meters every step (simulated time, real
//! wall time, energy) and renders reports.

pub mod engine;
pub mod metrics;
pub mod report;

pub use engine::{Engine, EngineConfig, RunSummary, StepRecord};
