//! The crate's **single blessed wall-clock site**.
//!
//! Wall time is *report-only*: it never feeds a physics decision, a
//! rebuild-policy input, or anything else that could make a traced run
//! diverge from an untraced one. Every backend and both engines meter
//! elapsed host time exclusively through [`WallTimer`], so the
//! `D-WALL-CLOCK` lint rule has exactly one allowed site (this file —
//! see the `[[allow]]` entry in `lint.toml`) and a raw clock anywhere
//! else in a determinism-scoped path is a CI failure.
//!
//! The simulated device time that drives *all* decisions comes from
//! [`crate::rtcore::timing`], not from here.

// lint:allow(D-WALL-CLOCK): the single blessed wall-clock site; report-only metering
use std::time::Instant;

/// An opaque wall-clock stopwatch. The underlying clock value never
/// escapes this module — callers only see elapsed seconds, and only for
/// reporting.
#[derive(Clone, Copy, Debug)]
pub struct WallTimer {
    // lint:allow(D-WALL-CLOCK): blessed site — the raw clock stays private to this module
    t0: Instant,
}

impl WallTimer {
    /// Start timing.
    pub fn start() -> WallTimer {
        // lint:allow(D-WALL-CLOCK): blessed site — capture for report-only metering
        WallTimer { t0: Instant::now() }
    }

    /// Seconds elapsed since [`WallTimer::start`]. Report-only: must not
    /// feed any decision that affects simulation results.
    pub fn elapsed_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elapsed_is_nonnegative_and_monotone() {
        let t = WallTimer::start();
        let a = t.elapsed_s();
        let b = t.elapsed_s();
        assert!(a >= 0.0);
        assert!(b >= a);
    }
}
