//! The simulation engine: one backend, one scenario, stepped to completion
//! with full metering.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::core::config::{ForcePath, SimConfig};
use crate::frnn::{ApproachKind, Backend, PhysicsKernels, RustKernels, StepCtx, WallPhases};
use crate::gradient::BvhAction;
use crate::physics::state::SimState;
use crate::rtcore::power::{step_energy, StepEnergy};
use crate::rtcore::profile::{DeviceKind, EPYC64};
use crate::rtcore::{timing, HwProfile, OpCounts, PhaseTimes};

/// Engine configuration: scenario + execution bindings.
#[derive(Clone)]
pub struct EngineConfig {
    pub sim: SimConfig,
    pub approach: ApproachKind,
    /// BVH rebuild policy spec for RT backends (`gradient`, `avg`,
    /// `fixed-K`). Ignored by cell backends.
    pub policy: String,
    /// GPU profile pricing the GPU approaches (CPU-CELL is always priced on
    /// the EPYC host profile).
    pub hw: &'static HwProfile,
    pub threads: usize,
    /// Enforce device-memory limits (RT-REF neighbor list OOM, §4.2).
    pub check_oom: bool,
}

impl EngineConfig {
    pub fn new(sim: SimConfig, approach: ApproachKind) -> Self {
        EngineConfig {
            sim,
            approach,
            policy: "gradient".into(),
            hw: crate::rtcore::profile::DEFAULT_GPU,
            threads: crate::parallel::num_threads(),
            check_oom: true,
        }
    }

    /// The profile that prices this engine's op counts.
    pub fn pricing_profile(&self) -> &'static HwProfile {
        if self.approach == ApproachKind::CpuCell {
            &EPYC64
        } else {
            self.hw
        }
    }
}

/// Everything measured about one step.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    pub step: u64,
    pub counts: OpCounts,
    /// Simulated phase times on the pricing profile.
    pub sim_times: PhaseTimes,
    /// Total simulated step time, ms.
    pub sim_ms: f64,
    /// Simulated RT cost (BVH op + query), ms — the Fig. 8 quantity.
    pub rt_ms: f64,
    pub energy: StepEnergy,
    pub wall: WallPhases,
    pub bvh_action: Option<BvhAction>,
    pub interactions: u64,
    pub oom_bytes: Option<u64>,
}

/// Aggregate over a run.
#[derive(Clone, Debug, Default)]
pub struct RunSummary {
    pub approach: String,
    pub scenario: String,
    pub hw: String,
    pub steps: u64,
    /// Mean simulated step time, ms.
    pub avg_sim_ms: f64,
    pub total_sim_ms: f64,
    pub total_rt_ms: f64,
    pub total_energy_j: f64,
    pub total_interactions: u64,
    pub avg_power_w: f64,
    /// interactions per joule (Eq. 10).
    pub ee: f64,
    pub oom: bool,
    pub oom_bytes: u64,
    pub wall_total_s: f64,
    /// Per-step trace (kept when requested).
    pub records: Vec<StepRecord>,
}

/// A live simulation: state + backend + bindings.
pub struct Engine {
    pub cfg: EngineConfig,
    pub state: SimState,
    backend: Box<dyn Backend>,
    kernels: Arc<dyn PhysicsKernels>,
}

impl Engine {
    /// Build the engine; `kernels` binds the force/integration path (XLA or
    /// Rust). Fails fast when the backend does not support the scenario
    /// (e.g. ORCS-persé with variable radii).
    pub fn new(cfg: EngineConfig, kernels: Arc<dyn PhysicsKernels>) -> Result<Self> {
        let state = SimState::from_config(&cfg.sim);
        let backend = cfg.approach.create(&cfg.policy)?;
        backend
            .supports(&state)
            .map_err(|e| anyhow::anyhow!("{} cannot run {}: {e}", backend.name(), cfg.sim.tag()))?;
        Ok(Engine { cfg, state, backend, kernels })
    }

    /// Convenience: engine with the pure-Rust kernels.
    pub fn new_rust(cfg: EngineConfig) -> Result<Self> {
        let threads = cfg.threads;
        Self::new(cfg, Arc::new(RustKernels { threads }))
    }

    /// Build the kernels requested by the config's force path.
    pub fn kernels_for(path: ForcePath, threads: usize) -> Result<Arc<dyn PhysicsKernels>> {
        Ok(match path {
            ForcePath::Rust => Arc::new(RustKernels { threads }),
            ForcePath::Xla => Arc::new(crate::runtime::kernels::XlaKernels::load_default()?),
        })
    }

    /// Execute one step and meter it.
    pub fn step(&mut self) -> Result<StepRecord> {
        let hw = self.cfg.pricing_profile();
        let mut ctx = StepCtx {
            threads: self.cfg.threads,
            kernels: self.kernels.as_ref(),
            hw,
            check_oom: self.cfg.check_oom,
        };
        let r = self.backend.step(&mut self.state, &mut ctx)?;
        let sim_times = timing::simulate(&r.counts, hw);
        let energy = step_energy(&sim_times, &r.counts, hw);
        Ok(StepRecord {
            step: self.state.step_count,
            counts: r.counts,
            sim_times,
            sim_ms: sim_times.total() * 1e3,
            rt_ms: sim_times.rt_cost() * 1e3,
            energy,
            wall: r.wall,
            bvh_action: r.bvh_action,
            interactions: r.counts.interactions,
            oom_bytes: r.oom_bytes,
        })
    }

    /// Run `steps` steps; aborts early on OOM (like the paper's runs).
    pub fn run(&mut self, steps: usize, keep_trace: bool) -> Result<RunSummary> {
        let wall_start = Instant::now();
        let mut s = RunSummary {
            approach: self.backend.name().to_string(),
            scenario: self.cfg.sim.tag(),
            hw: self.cfg.pricing_profile().name.to_string(),
            ..Default::default()
        };
        let mut energy_time = 0.0;
        for _ in 0..steps {
            let rec = self.step()?;
            s.steps += 1;
            s.total_sim_ms += rec.sim_ms;
            s.total_rt_ms += rec.rt_ms;
            s.total_energy_j += rec.energy.energy_j;
            s.total_interactions += rec.interactions;
            energy_time += rec.sim_ms;
            if keep_trace {
                s.records.push(rec);
            }
            if let Some(bytes) = rec.oom_bytes {
                s.oom = true;
                s.oom_bytes = bytes;
                break;
            }
        }
        if s.steps > 0 {
            s.avg_sim_ms = s.total_sim_ms / s.steps as f64;
        }
        if energy_time > 0.0 {
            s.avg_power_w = s.total_energy_j / (energy_time * 1e-3);
        }
        s.ee = crate::rtcore::power::energy_efficiency(s.total_interactions, s.total_energy_j);
        s.wall_total_s = wall_start.elapsed().as_secs_f64();
        debug_assert!(
            self.cfg.pricing_profile().kind == DeviceKind::Cpu
                || self.cfg.approach != ApproachKind::CpuCell
        );
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::{Boundary, ParticleDist, RadiusDist};

    fn small_cfg(approach: ApproachKind) -> EngineConfig {
        let sim = SimConfig {
            n: 300,
            box_l: 200.0,
            particle_dist: ParticleDist::Disordered,
            radius_dist: RadiusDist::Const(6.0),
            boundary: Boundary::Periodic,
            ..SimConfig::default()
        };
        EngineConfig { threads: 2, policy: "fixed-10".into(), ..EngineConfig::new(sim, approach) }
    }

    #[test]
    fn all_backends_run_and_meter() {
        for approach in ApproachKind::ALL {
            let mut e = Engine::new_rust(small_cfg(approach)).unwrap();
            let s = e.run(5, true).unwrap();
            assert_eq!(s.steps, 5, "{approach}");
            assert!(s.avg_sim_ms > 0.0, "{approach}");
            assert!(s.total_energy_j > 0.0, "{approach}");
            assert!(s.total_interactions > 0, "{approach}");
            assert_eq!(s.records.len(), 5);
            assert!(e.state.is_finite());
        }
    }

    #[test]
    fn cpu_cell_priced_on_epyc() {
        let cfg = small_cfg(ApproachKind::CpuCell);
        assert_eq!(cfg.pricing_profile().name, "CPU-EPYC64");
        let cfg = small_cfg(ApproachKind::RtRef);
        assert_eq!(cfg.pricing_profile().name, "RTXPRO");
    }

    #[test]
    fn perse_rejects_variable_radius_at_construction() {
        let mut cfg = small_cfg(ApproachKind::OrcsPerse);
        cfg.sim.radius_dist = RadiusDist::Uniform(1.0, 5.0);
        assert!(Engine::new_rust(cfg).is_err());
    }

    #[test]
    fn backends_agree_on_trajectories() {
        // RT-REF, ORCS-forces, ORCS-perse, GPU-CELL, CPU-CELL must produce
        // the same physics (same forces => same positions) step for step.
        let mut positions = Vec::new();
        for approach in ApproachKind::ALL {
            let mut e = Engine::new_rust(small_cfg(approach)).unwrap();
            e.run(3, false).unwrap();
            positions.push((approach, e.state.pos.clone()));
        }
        let (ref_name, ref_pos) = &positions[0];
        for (name, pos) in &positions[1..] {
            for i in 0..ref_pos.len() {
                let d = (pos[i] - ref_pos[i]).norm();
                assert!(d < 1e-2, "{name} vs {ref_name} diverged at {i}: {d}");
            }
        }
    }
}
