//! Figs. 9 & 10 — GPU acceleration over CPU-CELL@64c for an increasing
//! number of particles, under wall (Fig. 9) and periodic (Fig. 10) BC.
//!
//! `Speedup = <T_cpu-cell> / <T_gpu-approach>` (paper Eq. 9), simulated
//! times. Shape targets: ORCS-persé fastest at r=1 (~1.3x over RT-REF);
//! ORCS-forces fastest at log-normal radii (~1.6x wall / ~2x periodic over
//! RT-REF); CELL methods win at r=160; RT-REF OOMs on Cluster-LN.

use anyhow::Result;

use super::common::{paper_grid, BenchOpts};
use crate::coordinator::report::{results_dir, CsvWriter, TextTable};
use crate::core::config::Boundary;
use crate::frnn::ApproachKind;

/// Particle-count sweep (paper reaches 1M; see DESIGN.md on sizing).
const N_SWEEP_DEFAULT: [usize; 4] = [500, 1_000, 2_000, 4_000];
const STEPS_DEFAULT: usize = 10;

const GPU_APPROACHES: [ApproachKind; 4] = [
    ApproachKind::GpuCell,
    ApproachKind::RtRef,
    ApproachKind::OrcsForces,
    ApproachKind::OrcsPerse,
];

pub fn run(opts: &BenchOpts, boundary: Boundary) -> Result<()> {
    let fig = if boundary == Boundary::Wall { 9 } else { 10 };
    let (_, steps) = opts.size(8_000, STEPS_DEFAULT);
    let sweep: Vec<usize> = if opts.quick {
        vec![500, 1_000]
    } else if let Some(n) = opts.n_override {
        vec![n / 4, n / 2, n]
    } else {
        N_SWEEP_DEFAULT.to_vec()
    };
    println!("== Fig. {fig}: speedup vs CPU-CELL@64c ({boundary} BC, {steps} steps, n sweep {sweep:?}) ==\n");

    let mut csv = CsvWriter::create(
        &results_dir().join(format!("fig{fig}_speedup_{}.csv", boundary.to_string().to_lowercase())),
        &["case", "n", "approach", "avg_sim_ms", "cpu_ms", "speedup", "oom"],
    )?;

    for case in paper_grid() {
        let mut table = TextTable::new(&["n", "GPU-CELL", "RT-REF", "ORCS-forces", "ORCS-perse"]);
        for &n in &sweep {
            let cpu = opts
                .run(&case, n, boundary, ApproachKind::CpuCell, "gradient", steps, false)?
                .ok_or_else(|| anyhow::anyhow!("CPU-CELL rejected {} at n={n}", case.tag()))?;
            let mut fields = vec![n.to_string()];
            for approach in GPU_APPROACHES {
                let cell = match opts.run(&case, n, boundary, approach, "gradient", steps, false)? {
                    None => "-".into(),
                    Some(s) if s.oom => {
                        csv.row(&[
                            case.tag(),
                            n.to_string(),
                            approach.to_string(),
                            "".into(),
                            format!("{:.4}", cpu.avg_sim_ms),
                            "".into(),
                            "true".into(),
                        ])?;
                        "OOM".into()
                    }
                    Some(s) => {
                        let speedup = cpu.avg_sim_ms / s.avg_sim_ms.max(1e-12);
                        csv.row(&[
                            case.tag(),
                            n.to_string(),
                            approach.to_string(),
                            format!("{:.4}", s.avg_sim_ms),
                            format!("{:.4}", cpu.avg_sim_ms),
                            format!("{:.2}", speedup),
                            "false".into(),
                        ])?;
                        format!("{speedup:.1}x")
                    }
                };
                fields.push(cell);
            }
            table.row(fields);
        }
        println!("--- {} ---", case.tag());
        println!("{}", table.render());
    }
    println!(
        "CSV: {}",
        results_dir()
            .join(format!("fig{fig}_speedup_{}.csv", boundary.to_string().to_lowercase()))
            .display()
    );
    Ok(())
}
