//! Deterministic telemetry: per-step phase spans over *simulated* device
//! time, a labeled metrics registry, Chrome-trace export, and a bounded
//! flight recorder for fault forensics.
//!
//! The design rule that makes tracing free of determinism hazards: a
//! span only ever *reads* quantities the engines already computed — the
//! [`crate::rtcore::timing`] roofline times, [`crate::rtcore::OpCounts`]
//! deltas, and the modeled bytes moved — and recording mutates nothing
//! but the [`Recorder`] itself. Traced runs are therefore bitwise
//! identical to untraced runs (pinned by `tests/property_telemetry.rs`).
//! Host wall time is report-only and is captured exclusively through the
//! one blessed [`wallclock`] module (`D-WALL-CLOCK` lint contract); it
//! rides along as an optional span field that determinism comparisons
//! ignore.
//!
//! Three retention tiers:
//! * **metrics** — always on; counters/gauges/histograms in [`metrics`].
//! * **flight recorder** — always on; a ring of the last
//!   [`DEFAULT_FLIGHT_STEPS`] steps' spans + event marks, dumped by the
//!   engines alongside any `SimError` that surfaces at the run boundary.
//! * **full trace** — opt-in via [`Recorder::enable_trace`] (the
//!   `--trace-out` flag); retains every step for Chrome/Perfetto export
//!   through [`chrome::render`].

pub mod chrome;
pub mod metrics;
pub mod wallclock;

use std::collections::{BTreeMap, VecDeque};

use crate::frnn::WallPhases;
use crate::resilience::ResilienceEvent;
use crate::rtcore::timing::{phase_bytes, PhaseTimes};
use crate::rtcore::OpCounts;

pub use metrics::Registry;

/// Lane id for single-domain runs and fleet-global marks (merge,
/// checkpoints, resilience events). Shard lanes use the shard index.
pub const GLOBAL_LANE: u32 = u32::MAX;

/// Flight-recorder depth: how many trailing steps survive for forensics.
pub const DEFAULT_FLIGHT_STEPS: usize = 32;

/// The step-phase taxonomy. `Sort` covers z-order keying/binning, `Cell`
/// the cell-list pair sweep; checkpointing and the sharded list merge
/// are instant [`Mark`]s (they carry no simulated device time).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    Sort,
    /// Halo ghost collection (sharded only): the cell-bucketed gather's
    /// modeled device traffic, recorded before any migration exchange.
    Gather,
    Exchange,
    Build,
    Refit,
    Traverse,
    Cell,
    Force,
    Integrate,
    /// Canonical-order force fold-back (sharded ORCS-forces only): ghost
    /// rays' contributions returned to their owner shards.
    Scatter,
}

impl Phase {
    pub fn label(self) -> &'static str {
        match self {
            Phase::Sort => "sort",
            Phase::Gather => "gather",
            Phase::Exchange => "exchange",
            Phase::Build => "build",
            Phase::Refit => "refit",
            Phase::Traverse => "traverse",
            Phase::Cell => "cell",
            Phase::Force => "force",
            Phase::Integrate => "integrate",
            Phase::Scatter => "scatter",
        }
    }
}

/// One phase execution on one lane. Times are milliseconds of simulated
/// device time; `wall_ms` is the optional report-only host measurement
/// (excluded from determinism comparisons).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Span {
    pub lane: u32,
    pub phase: Phase,
    pub t0_ms: f64,
    pub dur_ms: f64,
    pub aabb_tests: u64,
    pub isect_force_evals: u64,
    pub bytes_moved: u64,
    pub wall_ms: Option<f64>,
}

/// An instant event on a lane: resilience events, checkpoints, merges.
#[derive(Clone, Debug)]
pub struct Mark {
    pub lane: u32,
    pub t_ms: f64,
    /// Short machine-readable category (metrics label, trace `cat`).
    pub tag: &'static str,
    /// The human one-liner (e.g. a `ResilienceEvent`'s display form).
    pub label: String,
}

/// Everything recorded for one engine step.
#[derive(Clone, Debug, Default)]
pub struct StepSpans {
    pub step: u64,
    pub t0_ms: f64,
    /// Full step duration on the simulated clock, including retry waste,
    /// fallback switches and straggler slowdown — always covers the
    /// extent of the contained spans.
    pub dur_ms: f64,
    pub spans: Vec<Span>,
    pub marks: Vec<Mark>,
}

/// Expand one `(PhaseTimes, OpCounts)` pair into sequential spans on
/// `lane` starting at `t0_ms`. Only phases with nonzero simulated time
/// are emitted; counters and modeled bytes are attributed to the phase
/// that generated them, and the optional backend wall measurements map
/// onto their nearest phase (approximate for the cell backends, whose
/// `search` wall covers the grid build).
pub fn phase_spans(
    lane: u32,
    t0_ms: f64,
    times: &PhaseTimes,
    counts: &OpCounts,
    wall: Option<&WallPhases>,
) -> Vec<Span> {
    let bytes = phase_bytes(counts);
    let has_grid = times.grid > 0.0;
    let has_trav = times.traverse > 0.0;
    let w = |pick: fn(&WallPhases) -> f64| wall.map(|w| pick(w) * 1e3);
    let w_sort = if has_grid { w(|w| w.search) } else { None };
    let w_trav = if has_trav { w(|w| w.search) } else { None };
    let w_cell = if has_grid || has_trav { w(|w| w.force) } else { w(|w| w.search + w.force) };
    let w_build = w(|w| w.bvh);
    let specs = [
        (Phase::Sort, times.grid, 0u64, 0u64, bytes.sort, w_sort),
        (Phase::Build, times.build, 0, 0, 0, w_build),
        (Phase::Refit, times.refit, 0, 0, 0, if times.build > 0.0 { None } else { w_build }),
        (
            Phase::Traverse,
            times.traverse,
            counts.aabb_tests,
            counts.isect_force_evals,
            bytes.traverse,
            w_trav,
        ),
        (Phase::Cell, times.cell, 0, counts.cell_force_evals, bytes.cell, w_cell),
        (Phase::Force, times.force_kernel, 0, 0, bytes.force_kernel, w(|w| w.force)),
        (Phase::Integrate, times.integrate, 0, 0, bytes.integrate, w(|w| w.integrate)),
    ];
    let mut out = Vec::new();
    let mut cursor = t0_ms;
    for (phase, dur_s, aabb, isect, moved, wall_ms) in specs {
        if dur_s <= 0.0 {
            continue;
        }
        let dur_ms = dur_s * 1e3;
        out.push(Span {
            lane,
            phase,
            t0_ms: cursor,
            dur_ms,
            aabb_tests: aabb,
            isect_force_evals: isect,
            bytes_moved: moved,
            wall_ms,
        });
        cursor += dur_ms;
    }
    out
}

/// The per-engine telemetry sink. One instance lives on each engine;
/// every method is plain bookkeeping over already-computed simulated
/// quantities, so recording can never perturb results.
///
/// Step protocol: the outermost step driver calls [`Recorder::begin_step`]
/// (which returns `false` — and changes nothing — when a step is already
/// open, so `step()` nested inside `step_resilient()` does not restart
/// it), attempts lay spans from [`Recorder::attempt_base_ms`], and the
/// opener finishes with [`Recorder::end_step`].
#[derive(Clone, Debug)]
pub struct Recorder {
    trace: bool,
    flight_len: usize,
    /// The simulated run clock: end of the last completed step.
    clock_ms: f64,
    /// Where the current attempt's lanes start.
    attempt_base: f64,
    /// High-water mark of recorded span ends within the open step.
    hi_ms: f64,
    cur: Option<StepSpans>,
    trace_steps: Vec<StepSpans>,
    flight: VecDeque<StepSpans>,
    lanes: BTreeMap<u32, String>,
    metrics: Registry,
}

impl Default for Recorder {
    fn default() -> Recorder {
        Recorder::new()
    }
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder {
            trace: false,
            flight_len: DEFAULT_FLIGHT_STEPS,
            clock_ms: 0.0,
            attempt_base: 0.0,
            hi_ms: 0.0,
            cur: None,
            trace_steps: Vec::new(),
            flight: VecDeque::new(),
            lanes: BTreeMap::new(),
            metrics: Registry::new(),
        }
    }

    /// Retain every step for Chrome export (default: flight ring only).
    pub fn enable_trace(&mut self) {
        self.trace = true;
    }

    pub fn trace_enabled(&self) -> bool {
        self.trace
    }

    /// Resize the flight ring (clamped to at least 1 step).
    pub fn set_flight_len(&mut self, len: usize) {
        self.flight_len = len.max(1);
        while self.flight.len() > self.flight_len {
            self.flight.pop_front();
        }
    }

    /// Name a lane for trace export and flight dumps (last write wins,
    /// so a mid-run backend fallback renames its lane).
    pub fn name_lane(&mut self, lane: u32, name: String) {
        self.lanes.insert(lane, name);
    }

    /// `(lane, name)` pairs, shard lanes first, global lane last.
    pub fn lanes(&self) -> Vec<(u32, String)> {
        self.lanes.iter().map(|(l, n)| (*l, n.clone())).collect()
    }

    /// Open a step. Returns `true` if this call opened it (the caller
    /// then owns the matching [`Recorder::end_step`]); `false` when a
    /// step is already open (nested driver).
    pub fn begin_step(&mut self, step: u64) -> bool {
        if self.cur.is_some() {
            return false;
        }
        self.attempt_base = self.clock_ms;
        self.hi_ms = self.clock_ms;
        self.cur = Some(StepSpans {
            step,
            t0_ms: self.clock_ms,
            dur_ms: 0.0,
            spans: Vec::new(),
            marks: Vec::new(),
        });
        true
    }

    pub fn step_open(&self) -> bool {
        self.cur.is_some()
    }

    /// Start a new attempt within the open step: lanes recorded next lay
    /// out from the current high-water mark, so discarded watchdog /
    /// transient attempts stay visible sequentially.
    pub fn begin_attempt(&mut self) {
        self.attempt_base = self.hi_ms;
    }

    pub fn attempt_base_ms(&self) -> f64 {
        self.attempt_base
    }

    /// Record one span (plus its metrics); returns the span's end time.
    pub fn record_span(&mut self, span: Span, labels: &[(&str, &str)]) -> f64 {
        let mut lab: Vec<(&str, &str)> = Vec::with_capacity(labels.len() + 1);
        lab.extend_from_slice(labels);
        lab.push(("phase", span.phase.label()));
        self.metrics.hist_observe("orcs_phase_ms", &lab, span.dur_ms);
        if span.aabb_tests > 0 {
            self.metrics.counter_add("orcs_aabb_tests_total", labels, span.aabb_tests);
        }
        if span.isect_force_evals > 0 {
            let n = span.isect_force_evals;
            self.metrics.counter_add("orcs_isect_force_evals_total", labels, n);
        }
        if span.bytes_moved > 0 {
            self.metrics.counter_add("orcs_bytes_moved_total", &lab, span.bytes_moved);
        }
        let end = span.t0_ms + span.dur_ms;
        if end > self.hi_ms {
            self.hi_ms = end;
        }
        if let Some(cur) = self.cur.as_mut() {
            cur.spans.push(span);
        }
        end
    }

    /// Expand a priced `(PhaseTimes, OpCounts)` pair into spans on
    /// `lane` starting at `base_ms`; returns the lane's end time.
    pub fn record_phases(
        &mut self,
        lane: u32,
        base_ms: f64,
        times: &PhaseTimes,
        counts: &OpCounts,
        wall: Option<&WallPhases>,
        labels: &[(&str, &str)],
    ) -> f64 {
        let mut end = base_ms;
        for span in phase_spans(lane, base_ms, times, counts, wall) {
            end = self.record_span(span, labels);
        }
        end
    }

    /// Record an instant mark at the step's current high-water time.
    pub fn mark(&mut self, lane: u32, tag: &'static str, label: String) {
        self.metrics.counter_add("orcs_marks_total", &[("tag", tag)], 1);
        let t_ms = self.hi_ms;
        if let Some(cur) = self.cur.as_mut() {
            cur.marks.push(Mark { lane, t_ms, tag, label });
        }
    }

    /// Mirror a resilience event as a global-lane mark + metrics count.
    pub fn mark_event(&mut self, ev: &ResilienceEvent) {
        let tag = ev.kind.tag();
        self.metrics.counter_add("orcs_events_total", &[("kind", tag)], 1);
        let t_ms = self.hi_ms;
        if let Some(cur) = self.cur.as_mut() {
            cur.marks.push(Mark { lane: GLOBAL_LANE, t_ms, tag, label: ev.to_string() });
        }
    }

    /// Close the open step: `dur_ms` is the engine's full priced step
    /// time (never less than the recorded span extent); advances the run
    /// clock and rotates the flight ring. No-op if no step is open.
    pub fn end_step(&mut self, dur_ms: f64) {
        let Some(mut cur) = self.cur.take() else {
            return;
        };
        cur.dur_ms = dur_ms.max(self.hi_ms - cur.t0_ms);
        self.clock_ms = cur.t0_ms + cur.dur_ms;
        self.metrics.counter_add("orcs_steps_total", &[], 1);
        self.metrics.gauge_set("orcs_sim_clock_ms", &[], self.clock_ms);
        if self.trace {
            self.trace_steps.push(cur.clone());
        }
        self.flight.push_back(cur);
        while self.flight.len() > self.flight_len {
            self.flight.pop_front();
        }
    }

    /// Push an errored step's partial record into the flight ring (after
    /// dumping) so a later step can open cleanly.
    pub fn abandon_step(&mut self) {
        let hi = self.hi_ms;
        if let Some(t0) = self.cur.as_ref().map(|c| c.t0_ms) {
            self.end_step(hi - t0);
        }
    }

    /// Full per-step trace (empty unless [`Recorder::enable_trace`]).
    pub fn steps(&self) -> &[StepSpans] {
        &self.trace_steps
    }

    /// The flight ring's current contents, oldest first (completed steps
    /// only; an open step is included by [`Recorder::flight_dump`]).
    pub fn flight_steps(&self) -> Vec<&StepSpans> {
        self.flight.iter().collect()
    }

    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    pub fn metrics_mut(&mut self) -> &mut Registry {
        &mut self.metrics
    }

    fn lane_name(&self, lane: u32) -> String {
        if let Some(n) = self.lanes.get(&lane) {
            return n.clone();
        }
        if lane == GLOBAL_LANE {
            "global".to_string()
        } else {
            format!("lane {lane}")
        }
    }

    /// Human-readable timeline of the flight ring (plus the currently
    /// open step, if an error left one behind) — the fault-forensics
    /// dump the engines emit alongside a surfaced `SimError`.
    pub fn flight_dump(&self) -> String {
        let steps: Vec<&StepSpans> = self.flight.iter().chain(self.cur.as_ref()).collect();
        if steps.is_empty() {
            return String::new();
        }
        let mut s = format!("flight recorder — last {} step(s):\n", steps.len());
        for st in steps {
            s.push_str(&format!(
                "  step {:>4} @ {:>10.3} ms (+{:.3} ms)\n",
                st.step, st.t0_ms, st.dur_ms
            ));
            let mut by_lane: BTreeMap<u32, Vec<&Span>> = BTreeMap::new();
            for sp in &st.spans {
                by_lane.entry(sp.lane).or_default().push(sp);
            }
            for (lane, spans) in &by_lane {
                let parts: Vec<String> = spans
                    .iter()
                    .map(|sp| format!("{} {:.3}", sp.phase.label(), sp.dur_ms))
                    .collect();
                s.push_str(&format!("    [{}] {} ms\n", self.lane_name(*lane), parts.join(" | ")));
            }
            for m in &st.marks {
                s.push_str(&format!("    ! {}\n", m.label));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn times() -> PhaseTimes {
        PhaseTimes {
            build: 1e-3,
            refit: 0.0,
            traverse: 2e-3,
            force_kernel: 5e-4,
            integrate: 1e-4,
            grid: 0.0,
            cell: 0.0,
        }
    }

    fn counts() -> OpCounts {
        OpCounts { aabb_tests: 100, sphere_tests: 40, nbr_list_writes: 10, ..Default::default() }
    }

    #[test]
    fn phase_spans_lay_out_sequentially_and_skip_zero_phases() {
        let spans = phase_spans(3, 10.0, &times(), &counts(), None);
        let labels: Vec<&str> = spans.iter().map(|s| s.phase.label()).collect();
        assert_eq!(labels, vec!["build", "traverse", "force", "integrate"]);
        let mut cursor = 10.0;
        for s in &spans {
            assert_eq!(s.lane, 3);
            assert_eq!(s.t0_ms, cursor, "{}", s.phase.label());
            assert!(s.wall_ms.is_none());
            cursor += s.dur_ms;
        }
        let trav = spans.iter().find(|s| s.phase == Phase::Traverse).expect("traverse span");
        assert_eq!(trav.aabb_tests, 100);
        assert!(trav.bytes_moved > 0);
    }

    #[test]
    fn wall_maps_to_build_and_traverse_for_rt_backends() {
        let wall = WallPhases { bvh: 1.0, search: 2.0, force: 3.0, integrate: 4.0 };
        let spans = phase_spans(0, 0.0, &times(), &counts(), Some(&wall));
        let get = |p: Phase| spans.iter().find(|s| s.phase == p).and_then(|s| s.wall_ms);
        assert_eq!(get(Phase::Build), Some(1.0e3));
        assert_eq!(get(Phase::Traverse), Some(2.0e3));
        assert_eq!(get(Phase::Force), Some(3.0e3));
        assert_eq!(get(Phase::Integrate), Some(4.0e3));
    }

    #[test]
    fn step_protocol_nests_and_advances_the_clock() {
        let mut r = Recorder::new();
        assert!(r.begin_step(0));
        assert!(!r.begin_step(0), "nested begin must not reopen");
        let base = r.attempt_base_ms();
        let end = r.record_phases(GLOBAL_LANE, base, &times(), &counts(), None, &[]);
        assert!(end > base);
        r.end_step(end - base);
        assert!(!r.step_open());
        assert!(r.begin_step(1));
        assert_eq!(r.attempt_base_ms(), end, "next step starts where the last ended");
        r.end_step(0.5);
        assert_eq!(r.flight_steps().len(), 2);
        assert!(r.steps().is_empty(), "trace retention is opt-in");
    }

    #[test]
    fn flight_ring_is_bounded_and_keeps_the_tail() {
        let mut r = Recorder::new();
        r.set_flight_len(4);
        for i in 0..10u64 {
            r.begin_step(i);
            r.end_step(1.0);
        }
        let steps: Vec<u64> = r.flight_steps().iter().map(|s| s.step).collect();
        assert_eq!(steps, vec![6, 7, 8, 9]);
    }

    #[test]
    fn flight_dump_includes_open_step_and_marks() {
        let mut r = Recorder::new();
        r.begin_step(7);
        let base = r.attempt_base_ms();
        r.record_phases(2, base, &times(), &counts(), None, &[("shard", "2")]);
        r.mark(GLOBAL_LANE, "checkpoint", "checkpoint @ step 7".to_string());
        let dump = r.flight_dump();
        assert!(dump.contains("step    7"), "{dump}");
        assert!(dump.contains("traverse"), "{dump}");
        assert!(dump.contains("! checkpoint @ step 7"), "{dump}");
        r.abandon_step();
        assert!(!r.step_open());
        assert_eq!(r.flight_steps().len(), 1);
    }

    #[test]
    fn trace_mode_retains_steps_for_export() {
        let mut r = Recorder::new();
        r.enable_trace();
        r.name_lane(GLOBAL_LANE, "RTXPRO (RT-REF)".to_string());
        for i in 0..3u64 {
            r.begin_step(i);
            let base = r.attempt_base_ms();
            let end = r.record_phases(GLOBAL_LANE, base, &times(), &counts(), None, &[]);
            r.end_step(end - base);
        }
        assert_eq!(r.steps().len(), 3);
        chrome::validate(r.steps()).expect("recorded trace must validate");
        let js = chrome::render(r.steps(), &r.lanes());
        chrome::validate_json(&js).expect("rendered trace must be balanced");
        assert!(!r.metrics().is_empty());
    }
}
