//! `cargo bench --bench fig10_speedup_periodic [-- --quick]`
//! Regenerates paper Fig. 10 (speedup vs CPU-CELL@64c, periodic BC).
fn main() {
    let opts = orcs::benchsuite::common::BenchOpts::from_env().expect("bench options");
    orcs::benchsuite::fig9_10::run(&opts, orcs::core::config::Boundary::Periodic)
        .expect("fig10 bench");
}
