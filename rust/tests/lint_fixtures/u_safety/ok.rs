// Fixture: clean twin — the unsafe block carries its SAFETY contract.
pub fn read_first(data: &[u8]) -> u8 {
    assert!(!data.is_empty());
    // SAFETY: asserted non-empty above, so the pointer read is in bounds.
    unsafe { *data.as_ptr() }
}
