//! Shard-invariance properties: the sharded engine must be a *transparent*
//! decomposition — for any shard grid and any thread count, forces and
//! positions are **bitwise identical** to the single-domain RT-REF engine,
//! under both boundary modes, across migrations and periodic wraps.
//!
//! Why bitwise equality is attainable at all: both engines canonicalize
//! every per-particle neighbor list to ascending global id (deduplicated),
//! and both evaluate forces/integration through the *same*
//! `PhysicsKernels` code over that CSR, so the f32 operation sequences
//! coincide exactly — not approximately.

use std::sync::Arc;

use orcs::coordinator::{Engine, EngineConfig};
use orcs::core::config::{Boundary, ParticleDist, RadiusDist, ShardSpec, SimConfig};
use orcs::core::vec3::Vec3;
use orcs::frnn::{ApproachKind, RustKernels};
use orcs::shard::{ShardedConfig, ShardedEngine};

fn scenario(n: usize, boundary: Boundary, radius: RadiusDist, box_l: f32, seed: u64) -> SimConfig {
    SimConfig {
        n,
        box_l,
        particle_dist: ParticleDist::Disordered,
        radius_dist: radius,
        boundary,
        seed,
        ..SimConfig::default()
    }
}

/// Positions + velocities of the single-domain RT-REF engine after `steps`.
fn single_domain(cfg: &SimConfig, threads: usize, steps: usize) -> (Vec<Vec3>, Vec<Vec3>) {
    let ec = EngineConfig {
        policy: "fixed-3".into(),
        threads,
        check_oom: false,
        ..EngineConfig::new(cfg.clone(), ApproachKind::RtRef)
    };
    let mut e = Engine::new(ec, Arc::new(RustKernels { threads })).unwrap();
    e.run(steps, false).unwrap();
    (e.state.pos, e.state.vel)
}

fn sharded(cfg: &SimConfig, s: usize, threads: usize, steps: usize) -> ShardedEngine {
    let sc = ShardedConfig {
        policy: "fixed-3".into(),
        threads,
        check_oom: false,
        ..ShardedConfig::new(cfg.clone(), ShardSpec::new(s))
    };
    let mut e = ShardedEngine::new(sc, Arc::new(RustKernels { threads })).unwrap();
    e.run(steps, false).unwrap();
    e
}

fn assert_bits_equal(got: &[Vec3], want: &[Vec3], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for i in 0..want.len() {
        // Vec3 PartialEq is exact f32 equality; compare bits so that a
        // hypothetical -0.0 vs +0.0 discrepancy is also caught.
        let (a, b) = (got[i], want[i]);
        assert_eq!(
            (a.x.to_bits(), a.y.to_bits(), a.z.to_bits()),
            (b.x.to_bits(), b.y.to_bits(), b.z.to_bits()),
            "{ctx}: particle {i} diverged: {a:?} vs {b:?}"
        );
    }
}

#[test]
fn sharded_is_bitwise_identical_to_single_domain() {
    // the acceptance property: S ∈ {1, 2, 3} grids reproduce the unsharded
    // trajectory bit for bit, under both boundary modes, with variable
    // radii (cross-inserts) and multi-step migration
    let steps = 4;
    for boundary in Boundary::ALL {
        for radius in [RadiusDist::Const(8.0), RadiusDist::Uniform(2.0, 14.0)] {
            let cfg = scenario(220, boundary, radius, 100.0, 99);
            let (want_pos, want_vel) = single_domain(&cfg, 2, steps);
            for s in [1usize, 2, 3] {
                let e = sharded(&cfg, s, 2, steps);
                let ctx = format!("{boundary:?}/{radius:?}/S={s}");
                assert_bits_equal(&e.state.pos, &want_pos, &ctx);
                assert_bits_equal(&e.state.vel, &want_vel, &ctx);
            }
        }
    }
}

#[test]
fn sharded_is_thread_count_invariant() {
    // the chunk partitions, scans and merges are thread-count independent,
    // so any ORCS_THREADS produces the same bits as the 1-thread reference
    let cfg = scenario(300, Boundary::Periodic, RadiusDist::Uniform(2.0, 12.0), 100.0, 5);
    let (want_pos, want_vel) = single_domain(&cfg, 1, 5);
    for threads in [1usize, 3, 8] {
        let e = sharded(&cfg, 2, threads, 5);
        let ctx = format!("threads={threads}");
        assert_bits_equal(&e.state.pos, &want_pos, &ctx);
        assert_bits_equal(&e.state.vel, &want_vel, &ctx);
    }
}

#[test]
fn sharded_matches_in_large_radius_periodic_regime() {
    // r_max > box_l / 2: the single-domain path switches to the 26-image
    // dedup sweep; the sharded halo materializes the same images as ghosts
    // (an owned particle can neighbor its own shard through a wrap)
    let cfg = scenario(60, Boundary::Periodic, RadiusDist::Const(25.0), 40.0, 17);
    let (want_pos, want_vel) = single_domain(&cfg, 2, 3);
    for s in [1usize, 2] {
        let e = sharded(&cfg, s, 2, 3);
        let ctx = format!("large-radius S={s}");
        assert_bits_equal(&e.state.pos, &want_pos, &ctx);
        assert_bits_equal(&e.state.vel, &want_vel, &ctx);
    }
}

#[test]
fn migration_across_a_periodic_wrap_stays_exact() {
    // a particle rides across the box boundary: its owner must wrap from
    // the last shard back to shard 0 while the trajectory stays bitwise
    // identical to the unsharded run
    let mut cfg = scenario(64, Boundary::Periodic, RadiusDist::Const(6.0), 80.0, 23);
    cfg.particle_dist = ParticleDist::Lattice;
    let steps = 6;
    let (want_pos, _) = single_domain(&cfg, 2, steps);

    let sc = ShardedConfig {
        policy: "fixed-3".into(),
        threads: 2,
        check_oom: false,
        ..ShardedConfig::new(cfg.clone(), ShardSpec::new(2))
    };
    let mut e = ShardedEngine::new(sc, Arc::new(RustKernels { threads: 2 })).unwrap();
    // plant a tracer just inside the +x face, moving outward fast enough to
    // wrap within a couple of steps (dt = 1e-3)
    let tracer = 0usize;
    e.state.pos[tracer] = Vec3::new(79.9995, 40.0, 40.0);
    e.state.vel[tracer] = Vec3::new(0.5, 0.0, 0.0);

    // mirror the same tampering into a fresh single-domain run
    let want = {
        let ec = EngineConfig {
            policy: "fixed-3".into(),
            threads: 2,
            check_oom: false,
            ..EngineConfig::new(cfg.clone(), ApproachKind::RtRef)
        };
        let mut se = Engine::new(ec, Arc::new(RustKernels { threads: 2 })).unwrap();
        se.state.pos[tracer] = Vec3::new(79.9995, 40.0, 40.0);
        se.state.vel[tracer] = Vec3::new(0.5, 0.0, 0.0);
        se.run(steps, false).unwrap();
        se.state.pos.clone()
    };
    assert_ne!(want, want_pos, "tampering must change the trajectory");

    let mut owners = Vec::new();
    let mut migrations = 0u64;
    for _ in 0..steps {
        let rec = e.step().unwrap();
        migrations += rec.migrations;
        owners.push(e.owner(tracer));
    }
    assert_bits_equal(&e.state.pos, &want, "periodic-wrap migration");
    // the tracer started in an x-high shard (odd index) and wrapped into an
    // x-low shard (even index)
    assert_eq!(owners[0] % 2, 1, "tracer should start x-high: {owners:?}");
    assert_eq!(owners.last().unwrap() % 2, 0, "tracer should wrap to x-low: {owners:?}");
    assert!(migrations > 0, "the wrap must be metered as a migration");
}

#[test]
fn prop_random_scenes_shard_transparently() {
    // randomized sweep over distributions, radii, boundaries and shard
    // grids: the decomposition must stay bitwise transparent everywhere
    orcs::testutil::prop_check("sharding_transparent", 8, |rng| {
        let cfg = orcs::testutil::gen::small_config(rng, 40, 120);
        let s = 1 + rng.below(3); // S in {1, 2, 3}
        let steps = 2;
        let (want_pos, want_vel) = single_domain(&cfg, 2, steps);
        let e = sharded(&cfg, s, 2, steps);
        for i in 0..want_pos.len() {
            if e.state.pos[i] != want_pos[i] || e.state.vel[i] != want_vel[i] {
                return Err(format!(
                    "S={s} diverged at particle {i} ({:?} vs {:?}) on {}",
                    e.state.pos[i],
                    want_pos[i],
                    cfg.tag()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn sharded_oom_fallback_is_bitwise_transparent() {
    // ISSUE satellite: a shard tripping `check_oom` under the fallback
    // policy degrades to the listless ORCS-persé path, and the run must be
    // bitwise identical to an uncapped run of the same decomposition — the
    // switch changes metering and memory, never the canonical lists
    use orcs::resilience::{EventKind, OomPolicy, ResilienceConfig};
    use orcs::rtcore::HwProfile;
    // 16 B: any shard that finds a single neighbor overflows immediately
    static TINY_LIST: HwProfile = {
        let mut p = orcs::rtcore::profile::TITANRTX;
        p.vram_bytes = 16;
        p
    };
    let cfg = scenario(220, Boundary::Periodic, RadiusDist::Const(8.0), 100.0, 99);
    let steps = 4;
    for s in [1usize, 2] {
        for threads in [1usize, 8] {
            let ctx = format!("fallback S={s} threads={threads}");
            // reference: same decomposition, no memory limit
            let free = {
                let sc = ShardedConfig {
                    policy: "fixed-3".into(),
                    threads,
                    check_oom: false,
                    fleet: vec![&TINY_LIST],
                    ..ShardedConfig::new(cfg.clone(), ShardSpec::new(s))
                };
                let mut e = ShardedEngine::new(sc, Arc::new(RustKernels { threads })).unwrap();
                e.run(steps, false).unwrap();
                e
            };
            let sc = ShardedConfig {
                policy: "fixed-3".into(),
                threads,
                check_oom: true,
                fleet: vec![&TINY_LIST],
                resilience: ResilienceConfig {
                    on_oom: OomPolicy::Fallback,
                    ..ResilienceConfig::default()
                },
                ..ShardedConfig::new(cfg.clone(), ShardSpec::new(s))
            };
            let mut e = ShardedEngine::new(sc, Arc::new(RustKernels { threads })).unwrap();
            let summary = e.run(steps, false).unwrap();
            assert!(!summary.oom, "{ctx}: fallback must absorb the OOM");
            assert_eq!(summary.steps, steps as u64, "{ctx}");
            assert!(
                summary.events.iter().any(|ev| matches!(ev.kind, EventKind::OomFallback { .. })),
                "{ctx}: no OomFallback event: {:?}",
                summary.events
            );
            let listless: u64 = summary.per_shard.iter().map(|t| t.listless_steps).sum();
            assert!(listless > 0, "{ctx}: no shard went listless");
            assert_bits_equal(&e.state.pos, &free.state.pos, &ctx);
            assert_bits_equal(&e.state.vel, &free.state.vel, &ctx);
            assert_bits_equal(&e.state.force, &free.state.force, &ctx);
        }
    }
}

#[test]
fn per_shard_oom_relief_on_lognormal_cluster() {
    // the ISSUE acceptance criterion: a log-normal cluster that OOMs the
    // single-domain RT-REF list completes once sharded with S >= 2
    use orcs::rtcore::HwProfile;
    static TINY: HwProfile = {
        let mut p = orcs::rtcore::profile::TITANRTX;
        p.vram_bytes = 700 * 1024; // 700 KB
        p
    };
    let cfg = SimConfig {
        n: 600,
        box_l: 1000.0,
        particle_dist: ParticleDist::Cluster,
        radius_dist: RadiusDist::LogNormal { mu: 1.0, sigma: 2.0, lo: 1.0, hi: 330.0 },
        boundary: Boundary::Periodic,
        seed: 31415,
        ..SimConfig::default()
    };
    let run = |s: usize| {
        let sc = ShardedConfig {
            policy: "gradient".into(),
            threads: 2,
            check_oom: true,
            fleet: vec![&TINY],
            ..ShardedConfig::new(cfg.clone(), ShardSpec::new(s))
        };
        let mut e = ShardedEngine::new(sc, Arc::new(RustKernels { threads: 2 })).unwrap();
        orcs::benchsuite::sharded::center_positions(&mut e.state);
        e.run(3, false).unwrap()
    };
    let single = run(1);
    assert!(single.oom, "single-domain must OOM: {} bytes", single.oom_bytes);
    assert!(single.oom_bytes > TINY.vram_bytes);
    let split = run(2);
    assert!(!split.oom, "S=2 must complete (max shard {} bytes)",
        split.per_shard.iter().map(|t| t.max_list_bytes).max().unwrap_or(0));
    assert_eq!(split.steps, 3);
}
