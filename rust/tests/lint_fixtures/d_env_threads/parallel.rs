// Fixture: clean twin — the same read is legal inside parallel.rs, the
// one blessed reader of the thread-count env var.
pub fn worker_count() -> usize {
    std::env::var("ORCS_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(1)
}
