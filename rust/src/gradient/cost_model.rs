//! The gradient cost model (paper Eqs. 5–8).
//!
//! With `k_u` consecutive updates between rebuilds, the total RT cost over a
//! simulation is modeled as the area under the saw-tooth curve of Fig. 3:
//!
//! ```text
//! T_sim = n_steps/(k_u+1) * [ k_u*(k_u*Δq)/2 + k_u*(t_u + t_q) + (t_r + t_q) ]
//! ```
//!
//! Setting dT/dk = 0 yields `Δq k² + 2Δq k + 2(t_u − t_r) = 0`, whose
//! positive root is the optimal number of consecutive updates:
//!
//! ```text
//! k_opt = −1 + sqrt(1 − 2 (t_u − t_r)/Δq)
//! ```

/// Cost-model parameters, all in the same time unit (we use simulated ms).
#[derive(Clone, Copy, Debug)]
pub struct CostParams {
    /// BVH full rebuild cost `t_r`.
    pub t_r: f64,
    /// BVH update (refit) cost `t_u`.
    pub t_u: f64,
    /// RT query cost with a fresh BVH `t_q`.
    pub t_q: f64,
    /// Average extra query cost per update step `Δq`.
    pub dq: f64,
}

/// Total simulation RT cost for a fixed update count `k_u` (Eq. 5).
pub fn simulation_cost(p: &CostParams, n_steps: f64, k_u: f64) -> f64 {
    let k = k_u.max(0.0);
    n_steps / (k + 1.0)
        * (k * (k * p.dq) / 2.0 + k * (p.t_u + p.t_q) + (p.t_r + p.t_q))
}

/// Closed-form optimal `k_u` (Eq. 8). Returns a large-but-finite value when
/// `Δq` is (numerically) zero — no degradation means "never rebuild".
pub fn optimal_ku(p: &CostParams) -> f64 {
    const DQ_FLOOR: f64 = 1e-12;
    const K_CAP: f64 = 1e6;
    let dq = p.dq.max(DQ_FLOOR);
    // t_u <= t_r in any sane system; clamp the discriminant defensively.
    let disc = 1.0 - 2.0 * (p.t_u - p.t_r) / dq;
    if disc <= 1.0 {
        // updates cost more than rebuilds: rebuild every step
        return 0.0;
    }
    (-1.0 + disc.sqrt()).min(K_CAP)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn numeric_argmin(p: &CostParams) -> f64 {
        // integer scan is what a discrete simulation can actually choose
        let mut best_k = 0.0;
        let mut best_c = f64::INFINITY;
        for k in 0..100_000 {
            let c = simulation_cost(p, 1000.0, k as f64);
            if c < best_c {
                best_c = c;
                best_k = k as f64;
            }
        }
        best_k
    }

    #[test]
    fn closed_form_matches_numeric_minimum() {
        for (t_r, t_u, dq) in [
            (10.0, 1.0, 0.5),
            (100.0, 5.0, 0.1),
            (50.0, 0.5, 2.0),
            (3.0, 0.1, 0.01),
        ] {
            let p = CostParams { t_r, t_u, t_q: 5.0, dq };
            let k_closed = optimal_ku(&p);
            let k_num = numeric_argmin(&p);
            assert!(
                (k_closed - k_num).abs() <= 1.0 + 0.02 * k_num,
                "t_r={t_r} t_u={t_u} dq={dq}: closed={k_closed} numeric={k_num}"
            );
        }
    }

    #[test]
    fn faster_dynamics_lower_k() {
        // larger Δq (stronger degradation per step) must shrink k_opt
        let slow = CostParams { t_r: 20.0, t_u: 1.0, t_q: 4.0, dq: 0.05 };
        let fast = CostParams { dq: 5.0, ..slow };
        assert!(optimal_ku(&fast) < optimal_ku(&slow));
    }

    #[test]
    fn cheap_rebuild_means_rebuild_always() {
        // t_u >= t_r -> updates pointless -> k = 0
        let p = CostParams { t_r: 1.0, t_u: 2.0, t_q: 4.0, dq: 0.5 };
        assert_eq!(optimal_ku(&p), 0.0);
    }

    #[test]
    fn zero_degradation_never_rebuilds() {
        let p = CostParams { t_r: 10.0, t_u: 0.1, t_q: 4.0, dq: 0.0 };
        assert!(optimal_ku(&p) >= 1e5);
    }

    #[test]
    fn cost_positive_and_k0_is_rebuild_every_step() {
        let p = CostParams { t_r: 10.0, t_u: 1.0, t_q: 5.0, dq: 0.2 };
        let c0 = simulation_cost(&p, 100.0, 0.0);
        // k=0: every step pays t_r + t_q
        assert!((c0 - 100.0 * (10.0 + 5.0)).abs() < 1e-9);
    }
}
