//! Micro-benchmarks of the hot paths (the §Perf profiling harness):
//! BVH build / refit / query (plain and Morton-ordered), cell sweep, radix
//! sort, and the XLA force kernel dispatch. Plain timing loops (no
//! criterion in the offline vendor set) with min/mean reporting over R
//! repetitions.
//!
//! `cargo bench --bench micro [-- --n N] [-- --json PATH]`
//!
//! `--json PATH` additionally writes the results as a machine-readable
//! table (used by CI to publish `BENCH_micro.json`).

use std::time::Instant;

use orcs::bvh::{BuildKind, Bvh};
use orcs::core::config::{Boundary, RadiusDist, SimConfig};
use orcs::core::rng::Rng;
use orcs::core::vec3::Vec3;
use orcs::frnn::cell_list::{cell_forces, Grid};
use orcs::frnn::gpu_cell::radix_sort_pairs;
use orcs::physics::state::SimState;

struct BenchRow {
    name: String,
    min_ms: f64,
    mean_ms: f64,
}

fn bench<F: FnMut()>(rows: &mut Vec<BenchRow>, name: &str, reps: usize, mut f: F) {
    // warmup
    f();
    let mut best = f64::INFINITY;
    let mut total = 0.0;
    for _ in 0..reps {
        let t = Instant::now();
        f();
        let dt = t.elapsed().as_secs_f64();
        best = best.min(dt);
        total += dt;
    }
    let min_ms = best * 1e3;
    let mean_ms = total / reps as f64 * 1e3;
    println!("{name:<52} min {min_ms:>10.3} ms   mean {mean_ms:>10.3} ms");
    rows.push(BenchRow { name: name.to_string(), min_ms, mean_ms });
}

fn arg_after(flag: &str) -> Option<String> {
    std::env::args().skip_while(|a| a != flag).nth(1)
}

fn write_json(
    path: &str,
    n: usize,
    threads: usize,
    aabb_tests_per_ray: f64,
    node_fetch_bytes_per_ray: f64,
    rows: &[BenchRow],
) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"n\": {n},\n"));
    s.push_str(&format!("  \"threads\": {threads},\n"));
    s.push_str(&format!("  \"node_bytes\": {},\n", std::mem::size_of::<orcs::bvh::Bvh4Node>()));
    s.push_str(&format!("  \"aabb_tests_per_ray\": {aabb_tests_per_ray:.4},\n"));
    s.push_str(&format!("  \"node_fetch_bytes_per_ray\": {node_fetch_bytes_per_ray:.4},\n"));
    s.push_str("  \"benches\": {\n");
    for (k, r) in rows.iter().enumerate() {
        let comma = if k + 1 == rows.len() { "" } else { "," };
        s.push_str(&format!(
            "    \"{}\": {{\"min_ms\": {:.4}, \"mean_ms\": {:.4}}}{comma}\n",
            r.name, r.min_ms, r.mean_ms
        ));
    }
    s.push_str("  }\n}\n");
    match std::fs::write(path, s) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

/// The pre-quantization 128-byte SoA node layout, rebuilt from the
/// quantized tree's dequantized lane boxes — the bench-local reference the
/// "quantized vs 128 B" rows compare against (the library itself only ships
/// the quantized layout).
struct FatNode {
    min_x: [f32; 4],
    min_y: [f32; 4],
    min_z: [f32; 4],
    max_x: [f32; 4],
    max_y: [f32; 4],
    max_z: [f32; 4],
    child: [u32; 4],
    count: [u32; 4],
}

fn fatten(bvh: &Bvh) -> Vec<FatNode> {
    bvh.nodes
        .iter()
        .map(|nd| {
            let mut f = FatNode {
                min_x: [f32::INFINITY; 4],
                min_y: [f32::INFINITY; 4],
                min_z: [f32::INFINITY; 4],
                max_x: [f32::NEG_INFINITY; 4],
                max_y: [f32::NEG_INFINITY; 4],
                max_z: [f32::NEG_INFINITY; 4],
                child: [u32::MAX; 4],
                count: [0; 4],
            };
            for lane in 0..4 {
                if !nd.lane_used(lane) {
                    continue;
                }
                let bb = nd.lane_aabb(lane);
                f.min_x[lane] = bb.lo.x;
                f.min_y[lane] = bb.lo.y;
                f.min_z[lane] = bb.lo.z;
                f.max_x[lane] = bb.hi.x;
                f.max_y[lane] = bb.hi.y;
                f.max_z[lane] = bb.hi.z;
                f.child[lane] = nd.child[lane];
                f.count[lane] = nd.count[lane] as u32;
            }
            f
        })
        .collect()
}

/// The old float-compare traversal over [`FatNode`]s (empty lanes carry
/// +inf/-inf bounds and fail automatically).
fn fat_query<F: FnMut(usize)>(
    nodes: &[FatNode],
    prim_order: &[u32],
    p: Vec3,
    exclude: usize,
    pos: &[Vec3],
    radius: &[f32],
    mut visit: F,
) {
    if nodes.is_empty() {
        return;
    }
    let mut stack = [0u32; 96];
    let mut sp = 0usize;
    let mut current = 0u32;
    loop {
        let node = &nodes[current as usize];
        let mut pending = [0u32; 4];
        let mut n_pending = 0usize;
        for lane in 0..4 {
            let inside = p.x >= node.min_x[lane]
                && p.y >= node.min_y[lane]
                && p.z >= node.min_z[lane]
                && p.x <= node.max_x[lane]
                && p.y <= node.max_y[lane]
                && p.z <= node.max_z[lane];
            if !inside {
                continue;
            }
            if node.count[lane] > 0 {
                let first = node.child[lane] as usize;
                for k in first..first + node.count[lane] as usize {
                    let j = prim_order[k] as usize;
                    if j != exclude {
                        let d2 = (p - pos[j]).norm2();
                        if d2 < radius[j] * radius[j] {
                            visit(j);
                        }
                    }
                }
            } else {
                pending[n_pending] = node.child[lane];
                n_pending += 1;
            }
        }
        for k in (0..n_pending).rev() {
            stack[sp] = pending[k];
            sp += 1;
        }
        if sp == 0 {
            break;
        }
        sp -= 1;
        current = stack[sp];
    }
}

fn main() {
    let n: usize = arg_after("--n").and_then(|v| v.parse().ok()).unwrap_or(50_000);
    let json_path = arg_after("--json");
    let reps = 5;
    let threads = orcs::parallel::num_threads();
    println!("== micro benches (n={n}, reps={reps}, ORCS_THREADS={threads}) ==");
    let mut rows: Vec<BenchRow> = Vec::new();
    let rows = &mut rows;

    let mut rng = Rng::new(42);
    let pos: Vec<Vec3> = (0..n)
        .map(|_| {
            Vec3::new(
                rng.range_f32(0.0, 1000.0),
                rng.range_f32(0.0, 1000.0),
                rng.range_f32(0.0, 1000.0),
            )
        })
        .collect();
    let radius: Vec<f32> = (0..n).map(|_| rng.range_f32(1.0, 20.0)).collect();

    bench(rows, "bvh build (binned SAH)", reps, || {
        let b = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
        std::hint::black_box(b.node_count());
    });
    bench(rows, "bvh build (median)", reps, || {
        let b = Bvh::build(&pos, &radius, BuildKind::Median);
        std::hint::black_box(b.node_count());
    });
    bench(rows, "bvh build (LBVH / morton)", reps, || {
        let b = Bvh::build(&pos, &radius, BuildKind::Lbvh);
        std::hint::black_box(b.node_count());
    });

    let mut bvh = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
    bench(rows, "bvh refit (1 thread)", reps, || {
        bvh.refit_with_threads(&pos, &radius, 1);
    });
    bench(rows, &format!("bvh refit ({threads} threads, level-parallel)"), reps, || {
        bvh.refit_with_threads(&pos, &radius, threads);
    });

    let bvh = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
    bench(rows, "bvh query x n (per-point, 1 thread)", reps, || {
        let mut scratch = orcs::bvh::traverse::QueryScratch::new();
        let mut acc = 0usize;
        for i in 0..n {
            bvh.query_point(pos[i], i, &pos, &radius, &mut scratch, |_| acc += 1);
        }
        std::hint::black_box((acc, scratch.stats.aabb_tests));
    });
    bench(rows, &format!("bvh query_batch x n ({threads} threads)"), reps, || {
        let (hits, stats) = bvh.query_batch(
            n,
            threads,
            || (),
            |_, scratch, range| {
                let mut acc = 0usize;
                for i in range {
                    bvh.query_point(pos[i], i, &pos, &radius, scratch, |_| acc += 1);
                }
                acc
            },
        );
        let acc: usize = hits.iter().sum();
        std::hint::black_box((acc, stats.aabb_tests));
    });
    let mut aabb_tests_per_ray = 0.0;
    bench(
        rows,
        &format!("bvh query_batch morton-ordered x n ({threads} threads)"),
        reps,
        || {
            let (hits, stats) = bvh.query_batch_ordered(
                &pos,
                1000.0,
                threads,
                || (),
                |_, scratch, ids| {
                    let mut acc = 0usize;
                    for &iu in ids {
                        let i = iu as usize;
                        bvh.query_point(pos[i], i, &pos, &radius, scratch, |_| acc += 1);
                    }
                    acc
                },
            );
            let acc: usize = hits.iter().sum();
            aabb_tests_per_ray = stats.aabb_tests as f64 / stats.rays.max(1) as f64;
            std::hint::black_box((acc, stats.aabb_tests));
        },
    );
    println!(
        "{:<52} {aabb_tests_per_ray:>14.2}   (1 unit = one 4-wide node test)",
        "aabb_tests / ray"
    );
    // the acceptance metric of the quantized layout: priced node-fetch
    // traffic per ray through the re-calibrated rtcore/timing meter
    let node_fetch_bytes_per_ray =
        aabb_tests_per_ray * orcs::rtcore::timing::BYTES_PER_NODE_FETCH;
    let fetch_128 = aabb_tests_per_ray * orcs::rtcore::timing::BYTES_PER_NODE_FETCH_UNCOMPRESSED;
    println!(
        "{:<52} {node_fetch_bytes_per_ray:>14.2}   ({} B/node; {fetch_128:.2} at 128 B, {:.2}x less)",
        "node-fetch bytes / ray (priced)",
        std::mem::size_of::<orcs::bvh::Bvh4Node>(),
        fetch_128 / node_fetch_bytes_per_ray
    );

    // --- quantized vs 128-byte nodes, SIMD vs scalar lanes ---
    assert_eq!(std::mem::size_of::<FatNode>(), 128);
    let fat = fatten(&bvh);
    bench(rows, "bvh query x n (128B f32 nodes, reference)", reps, || {
        let mut acc = 0usize;
        for i in 0..n {
            fat_query(&fat, &bvh.prim_order, pos[i], i, &pos, &radius, |_| acc += 1);
        }
        std::hint::black_box(acc);
    });
    let native = orcs::bvh::simd::detect_kernel();
    for (label, kern) in
        [("scalar lanes", orcs::bvh::simd::Kernel::Scalar), ("simd lanes", native)]
    {
        orcs::bvh::simd::set_kernel(kern);
        bench(rows, &format!("bvh query x n (quantized, {label} = {kern:?})"), reps, || {
            let mut scratch = orcs::bvh::traverse::QueryScratch::new();
            let mut acc = 0usize;
            for i in 0..n {
                bvh.query_point(pos[i], i, &pos, &radius, &mut scratch, |_| acc += 1);
            }
            std::hint::black_box((acc, scratch.stats.aabb_tests));
        });
    }
    orcs::bvh::simd::set_kernel(native);

    let cfg = SimConfig {
        n,
        boundary: Boundary::Periodic,
        radius_dist: RadiusDist::Const(10.0),
        ..SimConfig::default()
    };
    let state = SimState::from_config(&cfg);
    bench(rows, "cell grid build", reps, || {
        let g = Grid::build(&state.pos, state.box_l, state.r_max);
        std::hint::black_box(matches!(g, Grid::Dense(_)));
    });
    let grid = Grid::build(&state.pos, state.box_l, state.r_max);
    bench(rows, "cell sweep forces", reps, || {
        let (f, t, e, v) = cell_forces(&state, &grid, orcs::parallel::num_threads());
        std::hint::black_box((f.len(), t, e, v));
    });

    bench(rows, "radix sort (morton pairs, serial)", reps, || {
        let mut keys: Vec<u32> =
            pos.iter().map(|&p| orcs::frnn::gpu_cell::morton30(p, 1000.0)).collect();
        let mut vals: Vec<u32> = (0..n as u32).collect();
        radix_sort_pairs(&mut keys, &mut vals);
        std::hint::black_box(keys[0]);
    });
    bench(rows, &format!("radix sort (morton pairs, {threads} threads)"), reps, || {
        let mut keys: Vec<u32> =
            pos.iter().map(|&p| orcs::frnn::gpu_cell::morton30(p, 1000.0)).collect();
        let mut vals: Vec<u32> = (0..n as u32).collect();
        orcs::frnn::gpu_cell::radix_sort_pairs_mt(&mut keys, &mut vals, threads);
        std::hint::black_box(keys[0]);
    });
    bench(rows, "bvh build (binned SAH, 1 thread)", reps, || {
        let b = Bvh::build_with_threads(&pos, &radius, BuildKind::BinnedSah, 1);
        std::hint::black_box(b.node_count());
    });

    // XLA dispatch cost (needs artifacts; skipped when absent)
    match orcs::runtime::kernels::XlaKernels::load_default() {
        Ok(kernels) => {
            use orcs::frnn::{NeighborLists, PhysicsKernels};
            let small_cfg = SimConfig { n: 4096, ..cfg };
            let mut sstate = SimState::from_config(&small_cfg);
            let lists = NeighborLists::from_vecs(
                &(0..4096)
                    .map(|i| vec![((i + 1) % 4096) as u32; 16])
                    .collect::<Vec<_>>(),
            );
            let mut counts = orcs::rtcore::OpCounts::default();
            bench(rows, "xla lj_forces (1 chunk, k=16)", reps, || {
                let f = kernels.lj_forces(&sstate, &lists, &mut counts).unwrap();
                std::hint::black_box(f.len());
            });
            bench(rows, "xla integrate (1 chunk)", reps, || {
                kernels.integrate(&mut sstate, &mut counts).unwrap();
            });
        }
        Err(e) => println!("xla benches skipped: {e}"),
    }

    if let Some(path) = json_path {
        write_json(&path, n, threads, aabb_tests_per_ray, node_fetch_bytes_per_ray, rows);
    }
}
