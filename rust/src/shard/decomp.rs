//! Domain decomposition geometry: the `S³` shard grid, particle ownership,
//! and the periodic ghost-image halo.
//!
//! # Ghost images are the 26-image sweep, generalized to shard faces
//!
//! The single-domain periodic machinery (gamma rays, and the 26-image sweep
//! of the large-radius regime — see [`crate::frnn::rt_common::launch_rays`])
//! answers one question: *which shifted copies of the scene can interact
//! with a query point near a box face?* Sharding asks the identical
//! question per subdomain: which particles — including shifted images of
//! particles, possibly of particles the shard itself owns — lie within the
//! halo width of the shard's box? [`gather_ghosts`] enumerates the 27 image
//! shifts in `{-L, 0, +L}³` and keeps every `(particle, shift)` whose image
//! position is strictly within `halo` of the shard box. With the images
//! materialized as local ghost primitives, shard-local traversal needs *no*
//! gamma rays at all: periodic BC costs nothing beyond the halo itself,
//! exactly the paper's claim. For `S = 1` the shard box is the whole domain
//! and the ghost set degenerates to the classic 26 boundary images.
//!
//! The halo width is the gamma trigger distance (`r_max`, §3.3): a
//! neighbor `j` of an owned particle `i` satisfies `|d| < max(r_i, r_j) ≤
//! r_max`, and `dist(image, box) ≤ |d|`, so every image that can either be
//! discovered by an owned ray or must itself launch a discovering ray is
//! inside the halo.
//!
//! Since PR 9 the gather is **cell-bucketed**: one GPU-CELL counting-sort
//! grid over the scene ([`halo_grid`], built once per step) replaces the
//! `O(27·n)`-per-shard full scan — each shard only sweeps the buckets its
//! halo-expanded box overlaps, per image shift, which is what makes
//! `S³ ≫ 1` decompositions cheap. The ghost set is bitwise identical to
//! the old scan (kept as the `gather_ghosts_scan` test oracle).

use crate::core::config::{Boundary, ShardSpec};
use crate::core::vec3::Vec3;
use crate::frnn::cell_list::CellGrid;

/// Image-shift code `0..27`: each axis shifted by one of `{-L, 0, +L}`.
/// [`CENTER_SHIFT`] (13) is the identity — the code carried by owned
/// entries and by unshifted ghosts (wall BC, or a neighbor from an adjacent
/// shard with no wrap).
pub const CENTER_SHIFT: u8 = 13;

/// The shift vector of an image code.
#[inline]
pub fn shift_vec(code: u8, box_l: f32) -> Vec3 {
    let c = code as i32;
    Vec3::new(
        (c / 9 - 1) as f32 * box_l,
        ((c / 3) % 3 - 1) as f32 * box_l,
        (c % 3 - 1) as f32 * box_l,
    )
}

/// One local entry of a shard: an owned particle (`shift == CENTER_SHIFT`)
/// or a ghost image. The pair is the shard's *membership key*: as long as
/// the full key sequence is unchanged between steps, every local primitive
/// moves continuously and a BVH refit is meaningful; any churn forces a
/// rebuild (see [`crate::shard::ShardedEngine`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardMember {
    pub gid: u32,
    pub shift: u8,
}

/// The `S³` grid over the simulation box.
#[derive(Clone, Copy, Debug)]
pub struct ShardGrid {
    pub s: usize,
    pub box_l: f32,
    /// Subdomain side length, `box_l / s`.
    pub width: f32,
}

impl ShardGrid {
    pub fn new(spec: ShardSpec, box_l: f32) -> Self {
        let s = spec.s.max(1);
        ShardGrid { s, box_l, width: box_l / s as f32 }
    }

    pub fn count(&self) -> usize {
        self.s * self.s * self.s
    }

    /// Owning shard of a position. Coordinates are clamped into the grid,
    /// so wall-BC positions sitting exactly on `box_l` (legal under
    /// [`crate::physics::state::SimState::all_in_box`]) land in the last
    /// cell rather than out of range.
    #[inline]
    pub fn owner_of(&self, p: Vec3) -> usize {
        let cell = |x: f32| -> usize { ((x / self.width) as usize).min(self.s - 1) };
        cell(p.x) + self.s * (cell(p.y) + self.s * cell(p.z))
    }

    /// Axis-aligned bounds of shard `idx`.
    pub fn bounds(&self, idx: usize) -> (Vec3, Vec3) {
        debug_assert!(idx < self.count());
        let x = idx % self.s;
        let y = (idx / self.s) % self.s;
        let z = idx / (self.s * self.s);
        let lo = Vec3::new(x as f32, y as f32, z as f32) * self.width;
        (lo, lo + Vec3::splat(self.width))
    }
}

/// Squared distance from a point to the box `[lo, hi]` (0 inside).
#[inline]
pub fn dist2_point_box(p: Vec3, lo: Vec3, hi: Vec3) -> f32 {
    let dx = (lo.x - p.x).max(p.x - hi.x).max(0.0);
    let dy = (lo.y - p.y).max(p.y - hi.y).max(0.0);
    let dz = (lo.z - p.z).max(p.z - hi.z).max(0.0);
    dx * dx + dy * dy + dz * dz
}

/// Build the step's halo-bucketing grid: the GPU-CELL counting-sort grid
/// ([`CellGrid`]) over all in-box positions with halo-sized cells, built
/// **once per step** and shared by every shard's [`gather_ghosts`] call.
/// Buckets hold ascending particle ids (counting-sort order), so the
/// bucketed sweep plus a final `(gid, shift)` sort reproduces the scan
/// oracle's enumeration order exactly.
pub fn halo_grid(pos: &[Vec3], box_l: f32, halo: f32) -> CellGrid {
    CellGrid::build(pos, box_l, CellGrid::choose_dims(pos.len(), box_l, halo))
}

/// Collect the ghost members of shard `idx` into `out` (cleared first):
/// every `(particle, image shift)` whose shifted position lies strictly
/// within `halo` of the shard box and is not the shard's own owned entry.
/// Wall boundaries have no images (only the identity shift); periodic
/// boundaries sweep all 27 shifts, so an owned particle can reappear as its
/// own wrapped image — exactly the pairs the single-domain gamma rays
/// discover. Output order is ascending `(gid, shift)`, so it is
/// deterministic and usable as a membership key.
///
/// Instead of testing all `27·n` images per shard, the sweep walks only the
/// `cells` buckets overlapping the halo-expanded shard box *translated by
/// `-shift`* (positions are always in-box, so the query box moves, never
/// the particles — [`CellGrid`] cannot index negative coordinates). Cell
/// ranges are conservative (±1 cell for f32 rounding); the exact
/// [`dist2_point_box`] predicate — the same expression the scan oracle
/// evaluates — re-filters every candidate, so the ghost set is bitwise
/// identical to the full scan (pinned by `cell_bucketed_gather_matches_scan`
/// below).
#[allow(clippy::too_many_arguments)]
pub fn gather_ghosts(
    grid: &ShardGrid,
    idx: usize,
    pos: &[Vec3],
    owner: &[u32],
    halo: f32,
    boundary: Boundary,
    cells: &CellGrid,
    out: &mut Vec<ShardMember>,
) {
    out.clear();
    let (lo, hi) = grid.bounds(idx);
    let h2 = halo * halo;
    let codes: std::ops::Range<u8> = match boundary {
        Boundary::Wall => CENTER_SHIFT..CENTER_SHIFT + 1,
        Boundary::Periodic => 0..27,
    };
    let dims = cells.dims;
    let cell_w = cells.cell;
    let axis_cells = |q_lo: f32, q_hi: f32| -> Option<(usize, usize)> {
        // The grid covers [0, box_l]; a query interval entirely outside it
        // holds no particles.
        if q_hi < 0.0 || q_lo > grid.box_l {
            return None;
        }
        let c_lo = ((q_lo / cell_w).floor() as isize - 1).clamp(0, dims as isize - 1);
        let c_hi = ((q_hi / cell_w).floor() as isize + 1).clamp(0, dims as isize - 1);
        Some((c_lo as usize, c_hi as usize))
    };
    for code in codes {
        let shift = shift_vec(code, grid.box_l);
        let (Some((x0, x1)), Some((y0, y1)), Some((z0, z1))) = (
            axis_cells(lo.x - halo - shift.x, hi.x + halo - shift.x),
            axis_cells(lo.y - halo - shift.y, hi.y + halo - shift.y),
            axis_cells(lo.z - halo - shift.z, hi.z + halo - shift.z),
        ) else {
            continue;
        };
        for cz in z0..=z1 {
            for cy in y0..=y1 {
                for cx in x0..=x1 {
                    let c = (cz * dims + cy) * dims + cx;
                    let bucket =
                        &cells.items[cells.starts[c] as usize..cells.starts[c + 1] as usize];
                    for &iu in bucket {
                        let i = iu as usize;
                        if code == CENTER_SHIFT && owner[i] as usize == idx {
                            continue; // the owned entry, not a ghost
                        }
                        let q = pos[i] + shift;
                        if dist2_point_box(q, lo, hi) < h2 {
                            out.push(ShardMember { gid: iu, shift: code });
                        }
                    }
                }
            }
        }
    }
    out.sort_unstable_by_key(|m| (m.gid, m.shift));
}

/// The original `O(27·n)`-per-shard full-scan gather, kept as the oracle
/// the cell-bucketed path is pinned against.
#[cfg(test)]
pub fn gather_ghosts_scan(
    grid: &ShardGrid,
    idx: usize,
    pos: &[Vec3],
    owner: &[u32],
    halo: f32,
    boundary: Boundary,
    out: &mut Vec<ShardMember>,
) {
    out.clear();
    let (lo, hi) = grid.bounds(idx);
    let h2 = halo * halo;
    let codes: std::ops::Range<u8> = match boundary {
        Boundary::Wall => CENTER_SHIFT..CENTER_SHIFT + 1,
        Boundary::Periodic => 0..27,
    };
    for (i, &p) in pos.iter().enumerate() {
        for code in codes.clone() {
            if code == CENTER_SHIFT && owner[i] as usize == idx {
                continue; // the owned entry, not a ghost
            }
            let q = p + shift_vec(code, grid.box_l);
            if dist2_point_box(q, lo, hi) < h2 {
                out.push(ShardMember { gid: i as u32, shift: code });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::{Boundary, ShardSpec};

    #[test]
    fn owner_partitions_the_box() {
        let g = ShardGrid::new(ShardSpec::new(2), 100.0);
        assert_eq!(g.count(), 8);
        assert_eq!(g.owner_of(Vec3::new(10.0, 10.0, 10.0)), 0);
        assert_eq!(g.owner_of(Vec3::new(60.0, 10.0, 10.0)), 1);
        assert_eq!(g.owner_of(Vec3::new(10.0, 60.0, 10.0)), 2);
        assert_eq!(g.owner_of(Vec3::new(10.0, 10.0, 60.0)), 4);
        // the wall-BC corner case: exactly box_l stays in range
        assert_eq!(g.owner_of(Vec3::splat(100.0)), 7);
        // bounds round-trip
        for idx in 0..8 {
            let (lo, hi) = g.bounds(idx);
            let center = (lo + hi) * 0.5;
            assert_eq!(g.owner_of(center), idx, "idx={idx}");
        }
    }

    #[test]
    fn shift_codes_cover_the_27_images() {
        let mut seen = Vec::new();
        for code in 0u8..27 {
            let v = shift_vec(code, 1.0);
            assert!([-1.0, 0.0, 1.0].contains(&v.x));
            assert!([-1.0, 0.0, 1.0].contains(&v.y));
            assert!([-1.0, 0.0, 1.0].contains(&v.z));
            seen.push((v.x as i32, v.y as i32, v.z as i32));
        }
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 27);
        assert_eq!(shift_vec(CENTER_SHIFT, 123.0), Vec3::ZERO);
    }

    #[test]
    fn point_box_distance() {
        let lo = Vec3::ZERO;
        let hi = Vec3::splat(10.0);
        assert_eq!(dist2_point_box(Vec3::splat(5.0), lo, hi), 0.0);
        assert_eq!(dist2_point_box(Vec3::new(12.0, 5.0, 5.0), lo, hi), 4.0);
        assert_eq!(dist2_point_box(Vec3::new(-3.0, 5.0, 14.0), lo, hi), 25.0);
    }

    #[test]
    fn ghosts_cover_neighbor_faces_and_wrap() {
        // 2x2x2 grid over a 100 box; a particle just left of the x midplane
        // must be a ghost of the +x shard; one near x=0 must reach the
        // opposite shard *only* through its +L wrapped image under periodic
        let g = ShardGrid::new(ShardSpec::new(2), 100.0);
        let pos = vec![Vec3::new(49.0, 10.0, 10.0), Vec3::new(1.0, 10.0, 10.0)];
        let owner: Vec<u32> = pos.iter().map(|&p| g.owner_of(p) as u32).collect();
        assert_eq!(owner, vec![0, 0]);
        let mut out = Vec::new();
        let cells = halo_grid(&pos, 100.0, 5.0);
        // shard 1 = x in [50, 100)
        gather_ghosts(&g, 1, &pos, &owner, 5.0, Boundary::Wall, &cells, &mut out);
        assert_eq!(out, vec![ShardMember { gid: 0, shift: CENTER_SHIFT }]);
        gather_ghosts(&g, 1, &pos, &owner, 5.0, Boundary::Periodic, &cells, &mut out);
        // particle 0 via identity; particle 1 via its +L x-image (x=101,
        // within 5 of the shard's hi face at 100)
        assert_eq!(out.len(), 2);
        assert_eq!(out[0], ShardMember { gid: 0, shift: CENTER_SHIFT });
        assert_eq!(out[1].gid, 1);
        let shift = shift_vec(out[1].shift, 100.0);
        assert_eq!((shift.x, shift.y, shift.z), (100.0, 0.0, 0.0));
    }

    #[test]
    fn single_shard_periodic_ghosts_are_boundary_images() {
        // S=1: the shard is the whole box, so ghosts are exactly the
        // wrapped boundary images — the classic 26-image sweep
        let g = ShardGrid::new(ShardSpec::new(1), 10.0);
        let pos = vec![Vec3::new(0.5, 5.0, 5.0), Vec3::new(5.0, 5.0, 5.0)];
        let owner = vec![0u32, 0];
        let mut out = Vec::new();
        let cells = halo_grid(&pos, 10.0, 1.0);
        gather_ghosts(&g, 0, &pos, &owner, 1.0, Boundary::Periodic, &cells, &mut out);
        // particle 0 at x=0.5 reappears via the +L x-image at 10.5 (within
        // halo 1 of the box); the interior particle has no close image
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].gid, 0);
        assert_eq!(shift_vec(out[0].shift, 10.0).x, 10.0);
        // wall BC: no images at all
        gather_ghosts(&g, 0, &pos, &owner, 1.0, Boundary::Wall, &cells, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn cell_bucketed_gather_matches_scan() {
        // Randomized scenes: the bucketed gather's ghost set must be
        // bitwise identical — same (gid, shift) sequence — to the 27-shift
        // full-scan oracle, for every shard, both boundary modes, and halos
        // from a sliver up to wider than the whole box (degenerate case:
        // every cell range clamps to the full grid).
        crate::testutil::prop_check("bucketed_gather_equiv", 12, |rng| {
            let box_l = 20.0 + rng.f32() * 180.0;
            let n = 1 + rng.below(400) as usize;
            let s = 1 + rng.below(4) as usize;
            let halo = match rng.below(3) {
                0 => 0.02 * box_l,
                1 => 0.25 * box_l,
                _ => 1.1 * box_l,
            };
            let pos: Vec<Vec3> = (0..n)
                .map(|_| {
                    Vec3::new(
                        rng.f32() * box_l,
                        rng.f32() * box_l,
                        rng.f32() * box_l,
                    )
                })
                .collect();
            let g = ShardGrid::new(ShardSpec::new(s), box_l);
            let owner: Vec<u32> =
                pos.iter().map(|&p| g.owner_of(p) as u32).collect();
            let cells = halo_grid(&pos, box_l, halo);
            let (mut fast, mut slow) = (Vec::new(), Vec::new());
            for boundary in [Boundary::Wall, Boundary::Periodic] {
                for idx in 0..g.count() {
                    gather_ghosts(&g, idx, &pos, &owner, halo, boundary, &cells, &mut fast);
                    gather_ghosts_scan(&g, idx, &pos, &owner, halo, boundary, &mut slow);
                    if fast != slow {
                        return Err(format!(
                            "shard {idx} {boundary:?} s={s} halo={halo} n={n}: \
                             bucketed {} vs scan {} ghosts",
                            fast.len(),
                            slow.len()
                        ));
                    }
                }
            }
            Ok(())
        });
    }
}
