//! Boundary conditions: reflective walls and periodic wrap with
//! minimum-image displacement.

use crate::core::config::Boundary;
use crate::core::vec3::Vec3;

/// Displacement `p_i - p_j` respecting the boundary mode: minimum image for
/// periodic boxes, plain difference for walls.
#[inline(always)]
pub fn displacement(p_i: Vec3, p_j: Vec3, boundary: Boundary, box_l: f32) -> Vec3 {
    let d = p_i - p_j;
    match boundary {
        Boundary::Wall => d,
        Boundary::Periodic => d.min_image(box_l),
    }
}

/// Apply the boundary to one particle after integration. Returns the
/// corrected position and (for walls) flips the corresponding velocity
/// components.
#[inline]
pub fn apply(boundary: Boundary, box_l: f32, pos: &mut Vec3, vel: &mut Vec3) {
    match boundary {
        Boundary::Periodic => {
            pos.x = wrap(pos.x, box_l);
            pos.y = wrap(pos.y, box_l);
            pos.z = wrap(pos.z, box_l);
        }
        Boundary::Wall => {
            reflect(&mut pos.x, &mut vel.x, box_l);
            reflect(&mut pos.y, &mut vel.y, box_l);
            reflect(&mut pos.z, &mut vel.z, box_l);
        }
    }
}

/// Euclidean-mod wrap of a coordinate into `[0, l)`.
#[inline(always)]
pub fn wrap(x: f32, l: f32) -> f32 {
    let w = x - l * (x / l).floor();
    // floating point can land exactly on l
    if w >= l {
        0.0
    } else {
        w
    }
}

/// Reflect a coordinate off the walls at 0 and `l`, flipping velocity.
/// Handles multiple bounces (fast particles) by folding.
#[inline]
fn reflect(x: &mut f32, v: &mut f32, l: f32) {
    if *x >= 0.0 && *x <= l {
        return;
    }
    // Fold into the [0, 2l) sawtooth period.
    let period = 2.0 * l;
    let mut y = *x - period * (*x / period).floor();
    let mut flipped = false;
    if y > l {
        y = period - y;
        flipped = true;
    }
    *x = y.clamp(0.0, l);
    if flipped {
        *v = -*v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wrap_into_box() {
        assert_eq!(wrap(5.0, 10.0), 5.0);
        assert_eq!(wrap(15.0, 10.0), 5.0);
        assert_eq!(wrap(-3.0, 10.0), 7.0);
        assert!(wrap(10.0, 10.0) < 10.0);
        assert_eq!(wrap(0.0, 10.0), 0.0);
    }

    #[test]
    fn periodic_apply_wraps() {
        let mut p = Vec3::new(11.0, -1.0, 5.0);
        let mut v = Vec3::new(1.0, 1.0, 1.0);
        apply(Boundary::Periodic, 10.0, &mut p, &mut v);
        assert_eq!(p, Vec3::new(1.0, 9.0, 5.0));
        assert_eq!(v, Vec3::new(1.0, 1.0, 1.0)); // velocity untouched
    }

    #[test]
    fn wall_apply_reflects_and_flips() {
        let mut p = Vec3::new(11.0, -2.0, 5.0);
        let mut v = Vec3::new(3.0, -4.0, 5.0);
        apply(Boundary::Wall, 10.0, &mut p, &mut v);
        assert!((p.x - 9.0).abs() < 1e-5);
        assert!((p.y - 2.0).abs() < 1e-5);
        assert_eq!(p.z, 5.0);
        assert_eq!(v.x, -3.0);
        assert_eq!(v.y, 4.0);
        assert_eq!(v.z, 5.0);
    }

    #[test]
    fn wall_multiple_bounce_fold() {
        // x = 25 with l = 10: 25 -> fold period 20 -> 5, one flip
        let mut x = 25.0f32;
        let mut v = 1.0f32;
        reflect(&mut x, &mut v, 10.0);
        assert!((x - 5.0).abs() < 1e-5);
        // 25 = 2*10 + 5 -> within first half of next period -> no flip
        assert_eq!(v, 1.0);
        // x = -5: folds to 5 with flip
        let mut x2 = -5.0f32;
        let mut v2 = -2.0f32;
        reflect(&mut x2, &mut v2, 10.0);
        assert!((x2 - 5.0).abs() < 1e-5);
        assert_eq!(v2, 2.0);
    }

    #[test]
    fn displacement_min_image_only_when_periodic() {
        let a = Vec3::new(9.5, 0.0, 0.0);
        let b = Vec3::new(0.5, 0.0, 0.0);
        let dw = displacement(a, b, Boundary::Wall, 10.0);
        assert_eq!(dw.x, 9.0);
        let dp = displacement(a, b, Boundary::Periodic, 10.0);
        assert!((dp.x + 1.0).abs() < 1e-5);
    }
}
