//! Typed error taxonomy for step failures.
//!
//! Every way a simulation step can fail is classified into a [`SimError`]
//! variant, so the resilient layers (coordinator and sharded engine) can
//! decide *per class* whether to degrade, retry, recover from a checkpoint,
//! or abort — instead of bubbling an opaque `anyhow` string to the CLI.
//!
//! `SimError` implements `std::error::Error`, so `?` converts it into the
//! vendored `anyhow::Error` at the API boundary for free (via anyhow's
//! blanket `From<E: std::error::Error>` impl). Inside the engines the typed
//! form is preserved end to end.

use std::fmt;

/// A classified step failure.
#[derive(Clone, Debug)]
pub enum SimError {
    /// A fixed-slot allocation exceeded the device's (possibly squeezed)
    /// memory budget — the §4.2 RT-REF neighbor-list overflow.
    Oom {
        backend: &'static str,
        /// Shard index for sharded runs; `None` single-domain.
        shard: Option<usize>,
        required_bytes: u64,
        budget_bytes: u64,
    },
    /// A (simulated) device dropped out of the fleet mid-run.
    DeviceLost { shard: usize, device: String },
    /// The numerical watchdog exhausted its retry budget on a diverged
    /// trajectory (non-finite state or kinetic-energy blow-up).
    NumericalDivergence { detail: String },
    /// A spurious, retryable failure (simulated ECC hiccup, launch timeout).
    Transient { detail: String },
    /// Anything unclassifiable: configuration or kernel errors. Never
    /// retried.
    Fatal { detail: String },
}

/// `Result` specialized to the typed taxonomy.
pub type SimResult<T> = Result<T, SimError>;

impl SimError {
    /// Wrap an unclassifiable error (kernel failure, bad config) as fatal.
    pub fn fatal(e: impl fmt::Display) -> Self {
        SimError::Fatal { detail: e.to_string() }
    }

    /// Stable lowercase tag for reports and event lines.
    pub fn kind(&self) -> &'static str {
        match self {
            SimError::Oom { .. } => "oom",
            SimError::DeviceLost { .. } => "device-lost",
            SimError::NumericalDivergence { .. } => "divergence",
            SimError::Transient { .. } => "transient",
            SimError::Fatal { .. } => "fatal",
        }
    }

    /// Whether a resilient engine has a recovery path for this class
    /// (degradation ladder, checkpoint restore, or retry).
    pub fn is_recoverable(&self) -> bool {
        !matches!(self, SimError::Fatal { .. })
    }
}

impl fmt::Display for SimError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SimError::Oom { backend, shard, required_bytes, budget_bytes } => {
                match shard {
                    Some(s) => write!(f, "{backend} OOM on shard {s}")?,
                    None => write!(f, "{backend} OOM")?,
                }
                write!(f, ": needs {required_bytes} B, budget {budget_bytes} B")
            }
            SimError::DeviceLost { shard, device } => {
                write!(f, "device {device} (shard {shard}) lost")
            }
            SimError::NumericalDivergence { detail } => {
                write!(f, "numerical divergence: {detail}")
            }
            SimError::Transient { detail } => write!(f, "transient fault: {detail}"),
            SimError::Fatal { detail } => write!(f, "fatal: {detail}"),
        }
    }
}

impl std::error::Error for SimError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_and_recoverability() {
        let oom = SimError::Oom {
            backend: "RT-REF",
            shard: Some(3),
            required_bytes: 2048,
            budget_bytes: 1024,
        };
        assert_eq!(oom.kind(), "oom");
        assert!(oom.is_recoverable());
        assert!(oom.to_string().contains("shard 3"));
        assert!(oom.to_string().contains("2048"));

        let fatal = SimError::fatal("kernel exploded");
        assert_eq!(fatal.kind(), "fatal");
        assert!(!fatal.is_recoverable());
    }

    #[test]
    fn converts_into_anyhow_via_question_mark() {
        fn f() -> anyhow::Result<()> {
            Err::<(), _>(SimError::Transient { detail: "ecc".into() })?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(e.to_string().contains("transient fault"));
    }
}
