// Fixture: clean twin — integer accumulation is associative, and float
// partials would go through a chunk-ordered merge instead.
pub fn count_hits(flags: &[bool], threads: usize) -> u64 {
    let mut hits = 0u64;
    crate::parallel::parallel_for_chunks(flags.len(), threads, |_, range| {
        for i in range {
            if flags[i] {
                hits += 1;
            }
        }
    });
    hits
}
