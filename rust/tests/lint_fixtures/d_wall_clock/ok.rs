// Fixture: clean twin — logical step counter instead of a wall clock.
pub fn stamp_steps(counter: &mut u64) -> u64 {
    *counter += 1;
    *counter
}
