// Fixture: seeded U-SAFETY violation (undocumented unsafe block).
pub fn read_first(data: &[u8]) -> u8 {
    unsafe { *data.as_ptr() }
}
