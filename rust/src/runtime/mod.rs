//! PJRT runtime: loads the AOT-lowered HLO artifacts and executes them on
//! the request path.
//!
//! `make artifacts` runs Python exactly once; from then on the Rust binary
//! is self-contained: `HloModuleProto::from_text_file` → `client.compile`
//! (once per shape bucket, at startup) → `execute` per step. See
//! /opt/xla-example/load_hlo/ for the reference wiring and
//! python/compile/aot.py for why the interchange format is HLO *text*.

pub mod buckets;
pub mod kernels;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Static shapes shared with `python/compile/shapes.py` — change together.
pub const CHUNK: usize = 4096;
pub const K_BUCKETS: [usize; 3] = [16, 64, 256];
/// Box-length sentinel disabling minimum-image wrap (wall BC).
pub const WALL_BOX: f32 = 1e30;

/// A loaded, compiled PJRT executable with its input layout.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with literal inputs; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        // aot.py lowers with return_tuple=True
        Ok(out.to_tuple()?)
    }
}

/// The compiled artifact set.
pub struct XlaRuntime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    /// K-bucket → force executable.
    pub lj_forces: HashMap<usize, Executable>,
    pub integrate: Executable,
    /// Pure-jnp variant of the K=64 bucket (cross-check tests).
    pub lj_forces_ref: Option<Executable>,
    pub artifact_dir: PathBuf,
}

fn load_one(client: &xla::PjRtClient, dir: &Path, name: &str) -> Result<Executable> {
    let path = dir.join(name);
    let proto = xla::HloModuleProto::from_text_file(&path)
        .with_context(|| format!("parsing {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp).with_context(|| format!("compiling {name}"))?;
    Ok(Executable { exe, name: name.to_string() })
}

impl XlaRuntime {
    /// Load and compile every artifact in `dir` (built by `make artifacts`).
    pub fn load(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut lj_forces = HashMap::new();
        for k in K_BUCKETS {
            let name = format!("lj_forces_c{CHUNK}_k{k}.hlo.txt");
            lj_forces.insert(k, load_one(&client, dir, &name)?);
        }
        let integrate = load_one(&client, dir, &format!("integrate_c{CHUNK}.hlo.txt"))?;
        let lj_forces_ref =
            load_one(&client, dir, &format!("lj_forces_ref_c{CHUNK}_k64.hlo.txt")).ok();
        Ok(XlaRuntime {
            client,
            lj_forces,
            integrate,
            lj_forces_ref,
            artifact_dir: dir.to_path_buf(),
        })
    }

    /// Default artifact directory: `$ORCS_ARTIFACTS` or `./artifacts`,
    /// falling back to the crate-root copy for tests run elsewhere.
    pub fn default_dir() -> PathBuf {
        std::env::var("ORCS_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
            let local = PathBuf::from("artifacts");
            if local.join(format!("integrate_c{CHUNK}.hlo.txt")).exists() {
                local
            } else {
                PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
            }
        })
    }
}

/// f32 slice → PJRT literal of the given dims.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let expected: usize = dims.iter().product();
    anyhow::ensure!(data.len() == expected, "literal size {} != {:?}", data.len(), dims);
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        dims,
        bytes,
    )?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = literal_f32(&data, &[2, 3]).unwrap();
        let back = lit.to_vec::<f32>().unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn literal_size_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
    }

    #[test]
    fn constants_mirror_python() {
        // guard against drift with python/compile/shapes.py
        assert_eq!(CHUNK, 4096);
        assert_eq!(K_BUCKETS, [16, 64, 256]);
    }
}
