//! [`XlaKernels`] — the [`crate::frnn::PhysicsKernels`] implementation
//! backed by the PJRT executables. This is the paper-faithful
//! configuration: neighbor discovery happens in the (simulated) RT cores,
//! physics in separate AOT-compiled compute kernels, Python never in the
//! loop.
//!
//! Gated behind the `xla` cargo feature (see [`crate::runtime`]); the
//! stub's `load_default` returns `Err`, which every caller already treats
//! as "artifacts unavailable — use the Rust kernels".

#[cfg(feature = "xla")]
mod pjrt {
    use anyhow::Result;

    use crate::core::config::Boundary;
    use crate::core::vec3::Vec3;
    use crate::frnn::{NeighborLists, PhysicsKernels};
    use crate::physics::state::SimState;
    use crate::rtcore::OpCounts;
    use crate::runtime::buckets::segment_plan;
    use crate::runtime::{literal_f32, XlaRuntime, CHUNK, WALL_BOX};

    pub struct XlaKernels {
        pub rt: XlaRuntime,
    }

    // SAFETY: the PJRT client wrappers hold raw pointers without Send/Sync
    // markers, but every call site in this crate invokes the kernels from the
    // single coordinator thread (backends parallelize traversal, never kernel
    // execution). The PJRT CPU client itself is internally synchronized.
    unsafe impl Send for XlaKernels {}
    unsafe impl Sync for XlaKernels {}

    impl XlaKernels {
        pub fn load_default() -> Result<Self> {
            Ok(XlaKernels { rt: XlaRuntime::load(&XlaRuntime::default_dir())? })
        }

        /// Effective box length for the min-image term: the sentinel disables
        /// wrapping under wall BC.
        fn model_box(state: &SimState) -> f32 {
            match state.boundary {
                Boundary::Periodic => state.box_l,
                Boundary::Wall => WALL_BOX,
            }
        }

        /// Execute the force kernel for particles `[lo, lo+CHUNK)` (tail
        /// zero-padded) over one K-segment of their neighbor lists.
        #[allow(clippy::too_many_arguments)]
        fn run_force_chunk(
            &self,
            state: &SimState,
            lists: &NeighborLists,
            lo: usize,
            seg_start: usize,
            k_bucket: usize,
            forces: &mut [Vec3],
            counts: &mut OpCounts,
        ) -> Result<()> {
            let n = state.n();
            let hi = (lo + CHUNK).min(n);
            let c = CHUNK;

            let mut pos = vec![0f32; c * 3];
            let mut rad = vec![1f32; c];
            let mut nbr_pos = vec![0f32; c * k_bucket * 3];
            let mut nbr_rad = vec![1f32; c * k_bucket];
            let mut mask = vec![0f32; c * k_bucket];

            let mut real_pairs = 0u64;
            for i in lo..hi {
                let row = i - lo;
                let p = state.pos[i];
                pos[row * 3] = p.x;
                pos[row * 3 + 1] = p.y;
                pos[row * 3 + 2] = p.z;
                rad[row] = state.radius[i];
                let nbrs = lists.neighbors(i);
                let seg =
                    &nbrs[seg_start.min(nbrs.len())..(seg_start + k_bucket).min(nbrs.len())];
                for (slot, &j) in seg.iter().enumerate() {
                    let j = j as usize;
                    let q = state.pos[j];
                    let base = (row * k_bucket + slot) * 3;
                    nbr_pos[base] = q.x;
                    nbr_pos[base + 1] = q.y;
                    nbr_pos[base + 2] = q.z;
                    nbr_rad[row * k_bucket + slot] = state.radius[j];
                    mask[row * k_bucket + slot] = 1.0;
                    real_pairs += 1;
                }
            }
            if real_pairs == 0 {
                return Ok(());
            }

            let scal = [
                Self::model_box(state),
                state.params.epsilon,
                state.params.sigma_factor,
                state.params.f_max,
            ];
            let exe = self
                .rt
                .lj_forces
                .get(&k_bucket)
                .ok_or_else(|| anyhow::anyhow!("no artifact for K={k_bucket}"))?;
            let out = exe.run(&[
                literal_f32(&pos, &[c, 3])?,
                literal_f32(&nbr_pos, &[c, k_bucket, 3])?,
                literal_f32(&rad, &[c])?,
                literal_f32(&nbr_rad, &[c, k_bucket])?,
                literal_f32(&mask, &[c, k_bucket])?,
                literal_f32(&scal, &[4])?,
            ])?;
            // output tuple arity is fixed by the artifact ABI (aot.py)
            // lint:allow(P-INDEX-LIT): tuple arity pinned by the artifact ABI
            let f = out[0].to_vec::<f32>()?;
            for i in lo..hi {
                let row = i - lo;
                forces[i] += Vec3::new(f[row * 3], f[row * 3 + 1], f[row * 3 + 2]);
            }
            // force_kernel_pairs is charged by the caller on the fixed-slot
            // layout (see rt_ref.rs); here we only count launches.
            let _ = real_pairs;
            counts.kernel_launches += 1;
            Ok(())
        }
    }

    impl PhysicsKernels for XlaKernels {
        fn lj_forces(
            &self,
            state: &SimState,
            lists: &NeighborLists,
            counts: &mut OpCounts,
        ) -> Result<Vec<Vec3>> {
            let n = state.n();
            let mut forces = vec![Vec3::ZERO; n];
            // lint:allow(P-PANIC): K_BUCKETS is a non-empty const
            let widest = *crate::runtime::K_BUCKETS.last().unwrap();
            let mut lo = 0;
            while lo < n {
                let hi = (lo + CHUNK).min(n);
                // widest list in this chunk decides the segmentation
                let k_max =
                    (lo..hi).map(|i| lists.neighbors(i).len()).max().unwrap_or(0);
                let (full_segs, tail) = segment_plan(k_max);
                for s in 0..full_segs {
                    self.run_force_chunk(
                        state,
                        lists,
                        lo,
                        s * widest,
                        widest,
                        &mut forces,
                        counts,
                    )?;
                }
                if let Some(tb) = tail {
                    self.run_force_chunk(
                        state,
                        lists,
                        lo,
                        full_segs * widest,
                        tb,
                        &mut forces,
                        counts,
                    )?;
                }
                lo = hi;
            }
            Ok(forces)
        }

        fn integrate(&self, state: &mut SimState, counts: &mut OpCounts) -> Result<()> {
            let n = state.n();
            let c = CHUNK;
            let mut new_pos = vec![[0f32; 3]; n];
            let mut new_vel = vec![[0f32; 3]; n];
            let scal = [state.dt, state.params.f_max];
            let mut lo = 0;
            while lo < n {
                let hi = (lo + c).min(n);
                let mut pos = vec![0f32; c * 3];
                let mut vel = vec![0f32; c * 3];
                let mut force = vec![0f32; c * 3];
                for i in lo..hi {
                    let row = i - lo;
                    for (dst, v) in [
                        (&mut pos, state.pos[i]),
                        (&mut vel, state.vel[i]),
                        (&mut force, state.force[i]),
                    ] {
                        dst[row * 3] = v.x;
                        dst[row * 3 + 1] = v.y;
                        dst[row * 3 + 2] = v.z;
                    }
                }
                let out = self.rt.integrate.run(&[
                    literal_f32(&pos, &[c, 3])?,
                    literal_f32(&vel, &[c, 3])?,
                    literal_f32(&force, &[c, 3])?,
                    literal_f32(&scal, &[2])?,
                ])?;
                let np = out[0].to_vec::<f32>()?; // lint:allow(P-INDEX-LIT): tuple ABI
                let nv = out[1].to_vec::<f32>()?; // lint:allow(P-INDEX-LIT): tuple ABI
                for i in lo..hi {
                    let row = i - lo;
                    new_pos[i] = [np[row * 3], np[row * 3 + 1], np[row * 3 + 2]];
                    new_vel[i] = [nv[row * 3], nv[row * 3 + 1], nv[row * 3 + 2]];
                }
                counts.kernel_launches += 1;
                lo = hi;
            }
            // boundary handling stays on the coordinator (DESIGN.md §Three-layer)
            crate::physics::integrator::apply_integrated(state, &new_pos, &new_vel);
            counts.integrate_particles += n as u64;
            Ok(())
        }

        fn name(&self) -> &'static str {
            "xla"
        }
    }
}

#[cfg(feature = "xla")]
pub use pjrt::XlaKernels;

#[cfg(not(feature = "xla"))]
mod stub {
    use anyhow::Result;

    use crate::core::vec3::Vec3;
    use crate::frnn::{NeighborLists, PhysicsKernels};
    use crate::physics::state::SimState;
    use crate::rtcore::OpCounts;

    /// Feature-off stand-in: `load_default` always errors, so the kernel
    /// methods below are unreachable in practice (there is no other way to
    /// construct the type).
    pub struct XlaKernels {
        _private: (),
    }

    impl XlaKernels {
        pub fn load_default() -> Result<Self> {
            Err(anyhow::anyhow!(
                "XLA kernels unavailable: crate built without the `xla` cargo feature"
            ))
        }
    }

    impl PhysicsKernels for XlaKernels {
        fn lj_forces(
            &self,
            _state: &SimState,
            _lists: &NeighborLists,
            _counts: &mut OpCounts,
        ) -> Result<Vec<Vec3>> {
            Err(anyhow::anyhow!("xla feature disabled"))
        }

        fn integrate(&self, _state: &mut SimState, _counts: &mut OpCounts) -> Result<()> {
            Err(anyhow::anyhow!("xla feature disabled"))
        }

        fn name(&self) -> &'static str {
            "xla-stub"
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::XlaKernels;
