//! ORCS-persé (contribution #2, §3.2.1): the whole simulation step lives
//! inside the ray-tracing pipeline. Each ray carries a force-vector
//! *payload*; every intersection accumulates the pair force into the
//! payload, and when the ray completes, the thread integrates its own
//! particle and writes the new position — no neighbor list, no atomics, no
//! extra compute kernels. Restricted to scenes where all particles share
//! one radius (detection is then symmetric and every thread independently
//! sees all of its pairs; each pair is evaluated twice, once per endpoint).
//!
//! Positions are double-buffered: rays read the step's input positions
//! while integrated outputs land in a fresh buffer (real implementations
//! do the same to keep in-flight rays consistent).

use crate::core::vec3::Vec3;
use crate::frnn::rt_common::{fold_stats, launch_rays, BvhManager};
use crate::frnn::zorder::ZOrderCache;
use crate::frnn::{Backend, StepCtx, StepResult, WallPhases};
use crate::gradient::RebuildPolicy;
use crate::physics::{boundary, state::SimState};
use crate::resilience::{SimError, SimResult};
use crate::rtcore::OpCounts;
use crate::telemetry::wallclock::WallTimer;

pub struct OrcsPerse {
    mgr: BvhManager,
    /// Per-step Morton cache shared by LBVH builds and the query sweep.
    zcache: ZOrderCache,
}

impl OrcsPerse {
    pub fn new(policy: Box<dyn RebuildPolicy>) -> Self {
        OrcsPerse { mgr: BvhManager::new(policy), zcache: ZOrderCache::new() }
    }
}

impl Backend for OrcsPerse {
    fn name(&self) -> &'static str {
        "ORCS-perse"
    }

    fn supports(&self, state: &SimState) -> Result<(), String> {
        let r0 = state.radius.first().copied().unwrap_or(0.0);
        if state.radius.iter().any(|&r| r != r0) {
            return Err("ORCS-persé requires a uniform radius across all particles".into());
        }
        Ok(())
    }

    fn step(&mut self, state: &mut SimState, ctx: &mut StepCtx) -> SimResult<StepResult> {
        self.supports(state).map_err(SimError::fatal)?;
        let mut counts = OpCounts::default();
        let mut wall = WallPhases::default();

        // Phase 0: one Morton keying + sort per step (shared by build +
        // sweep); wall time charged to the search phase below.
        let t_sort = WallTimer::start();
        self.zcache.compute(&state.pos, state.box_l, ctx.threads);
        let sort_wall = t_sort.elapsed_s();
        debug_assert_eq!(self.zcache.order().len(), state.n());

        // Phase 1: BVH maintenance.
        let t0 = WallTimer::start();
        let action = self.mgr.prepare_with(
            &state.pos,
            &state.radius,
            &mut counts,
            ctx.threads,
            false,
            Some(self.zcache.order()),
        );
        wall.bvh = t0.elapsed_s();

        // Phase 2: the entire step inside the RT pipeline — batched sweep
        // in Morton order of the ray origins (coherent rays share subtrees,
        // keeping BVH4 node fetches cache-hot), one payload per ray thread,
        // in-shader integration. Each ray's hit set is canonicalized
        // (ascending global id, deduped) before the payload accumulates, so
        // the f32 sum is byte-for-byte `RustKernels::lj_forces`'s row for
        // the particle — discovery order, thread count and (in the sharded
        // engine) shard-local ghost layout all drop out of the result. Each
        // chunk returns its particles' payload + integrated (pos, vel)
        // keyed by particle id; slots are disjoint so the scatter back to
        // particle order is trivially deterministic.
        let t1 = WallTimer::start();
        let bvh = self.mgr.bvh();
        // uniform radius: gamma trigger is *the* radius (§3.3 fast case)
        let trigger = state.r_max;
        let dt = state.dt;
        let (boundary_mode, box_l) = (state.boundary, state.box_l);
        struct ChunkOut {
            /// Particle ids swept by this chunk (Morton order).
            ids: Vec<u32>,
            /// (payload, new_pos, new_vel) per particle, parallel to `ids`.
            moved: Vec<(Vec3, Vec3, Vec3)>,
            accums: u64,
        }
        let (chunks, stats) = bvh.query_batch_with_order(
            self.zcache.order(),
            ctx.threads,
            || (),
            |_, scratch, ids| {
                let mut out = ChunkOut {
                    ids: ids.to_vec(),
                    moved: Vec::with_capacity(ids.len()),
                    accums: 0,
                };
                let mut hits: Vec<u32> = Vec::new();
                for &iu in ids {
                    let i = iu as usize;
                    hits.clear();
                    launch_rays(
                        bvh,
                        i,
                        &state.pos,
                        &state.radius,
                        boundary_mode,
                        box_l,
                        trigger,
                        scratch,
                        |j, _dx| hits.push(j as u32),
                    );
                    hits.sort_unstable();
                    hits.dedup();
                    // ray payload: the canonical-order force accumulator
                    let accums = &mut out.accums;
                    let payload = crate::frnn::rt_common::canonical_force_sum(
                        &state.pos,
                        &state.radius,
                        &state.params,
                        boundary_mode,
                        box_l,
                        i,
                        &hits,
                        |_, _, in_range| {
                            if in_range {
                                *accums += 1;
                            }
                        },
                    );
                    // in-shader integration of p_i from the payload force
                    let f = state.params.cap(payload);
                    let mut v = state.vel[i] + f * dt;
                    let mut p = state.pos[i] + v * dt;
                    boundary::apply(boundary_mode, box_l, &mut p, &mut v);
                    out.moved.push((payload, p, v));
                }
                out
            },
        );

        // Double-buffered positions: rays read the step's inputs above,
        // integrated outputs land in fresh buffers here. The uncapped
        // payload is also published as the step's force array — exactly
        // what the list pipeline's force kernel would have stored — so
        // listless runs stay force-bitwise comparable, not just pos/vel.
        let mut accums = 0u64;
        let mut new_pos = state.pos.clone();
        let mut new_vel = state.vel.clone();
        let mut new_force = state.force.clone();
        for c in chunks {
            accums += c.accums;
            for (k, (payload, p, v)) in c.moved.into_iter().enumerate() {
                let i = c.ids[k] as usize;
                new_force[i] = payload;
                new_pos[i] = p;
                new_vel[i] = v;
            }
        }
        state.pos = new_pos;
        state.vel = new_vel;
        state.force = new_force;
        state.step_count += 1;
        fold_stats(&mut counts, &stats);
        counts.payload_accums += accums;
        counts.isect_force_evals += accums;
        // uniform radius: detection symmetric, each pair seen twice
        counts.interactions += accums / 2;
        wall.search = sort_wall + t1.elapsed_s();

        self.mgr.observe(action, &counts, ctx.hw);
        Ok(StepResult { counts, bvh_action: Some(action), oom_bytes: None, wall })
    }

    fn invalidate_bvh(&mut self) {
        self.mgr.invalidate();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::{Boundary, RadiusDist, SimConfig};
    use crate::frnn::{brute, RustKernels};
    use crate::gradient::FixedKPolicy;
    use crate::rtcore::profile::RTXPRO;

    #[test]
    fn rejects_variable_radius() {
        let cfg = SimConfig {
            n: 50,
            radius_dist: RadiusDist::Uniform(1.0, 5.0),
            ..SimConfig::default()
        };
        let mut state = SimState::from_config(&cfg);
        let kernels = RustKernels { threads: 1 };
        let mut ctx = StepCtx {
            threads: 1,
            kernels: &kernels,
            hw: &RTXPRO,
            check_oom: false,
            vram_budget: None,
        };
        let mut backend = OrcsPerse::new(Box::new(FixedKPolicy::new(4)));
        assert!(backend.supports(&state).is_err());
        assert!(backend.step(&mut state, &mut ctx).is_err());
    }

    #[test]
    fn matches_brute_force_both_boundaries() {
        for boundary in [Boundary::Wall, Boundary::Periodic] {
            let cfg = SimConfig {
                n: 240,
                boundary,
                radius_dist: RadiusDist::Const(8.0),
                box_l: 100.0,
                ..SimConfig::default()
            };
            let mut state = SimState::from_config(&cfg);
            let want = {
                let mut s2 = state.clone();
                s2.force = brute::forces(&s2);
                crate::physics::integrator::step(&mut s2);
                s2
            };
            let kernels = RustKernels { threads: 3 };
            let mut ctx = StepCtx {
                threads: 3,
                kernels: &kernels,
                hw: &RTXPRO,
                check_oom: false,
                vram_budget: None,
            };
            let mut backend = OrcsPerse::new(Box::new(FixedKPolicy::new(4)));
            let r = backend.step(&mut state, &mut ctx).unwrap();
            // no list, no atomics, no separate kernels
            assert_eq!(r.counts.nbr_list_writes, 0);
            assert_eq!(r.counts.atomic_adds, 0);
            assert_eq!(r.counts.kernel_launches, 0);
            assert!(r.counts.payload_accums > 0);
            for i in 0..state.n() {
                assert!(
                    (state.pos[i] - want.pos[i]).norm() < 1e-3,
                    "{boundary:?} particle {i}"
                );
            }
        }
    }

    #[test]
    fn multi_step_stays_finite_and_in_box() {
        let cfg = SimConfig {
            n: 150,
            boundary: Boundary::Wall,
            radius_dist: RadiusDist::Const(6.0),
            box_l: 100.0,
            ..SimConfig::default()
        };
        let mut state = SimState::from_config(&cfg);
        let kernels = RustKernels { threads: 2 };
        let mut ctx = StepCtx {
            threads: 2,
            kernels: &kernels,
            hw: &RTXPRO,
            check_oom: false,
            vram_budget: None,
        };
        let mut backend = OrcsPerse::new(Box::new(FixedKPolicy::new(8)));
        for _ in 0..20 {
            backend.step(&mut state, &mut ctx).unwrap();
        }
        assert_eq!(state.step_count, 20);
        assert!(state.is_finite());
        assert!(state.all_in_box());
    }
}
