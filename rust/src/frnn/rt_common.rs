//! Shared infrastructure for the three RT-core backends: BVH lifecycle
//! management under a rebuild policy, and the parallel ray-launch loop.

use crate::bvh::traverse::{QueryScratch, TraversalStats};
use crate::bvh::{BuildKind, Bvh};
use crate::core::config::Boundary;
use crate::core::vec3::Vec3;
use crate::gradient::{BvhAction, RebuildPolicy, StepObs};
use crate::physics::state::SimState;
use crate::rtcore::{timing, HwProfile, OpCounts};

/// Owns the BVH and applies the rebuild/update policy each step.
pub struct BvhManager {
    bvh: Option<Bvh>,
    pub policy: Box<dyn RebuildPolicy>,
    pub build_kind: BuildKind,
}

impl BvhManager {
    pub fn new(policy: Box<dyn RebuildPolicy>) -> Self {
        BvhManager { bvh: None, policy, build_kind: BuildKind::BinnedSah }
    }

    /// Apply the policy's decision: build or refit the BVH for the current
    /// particle state. Returns the action taken and fills the counters.
    pub fn prepare(
        &mut self,
        pos: &[Vec3],
        radius: &[f32],
        counts: &mut OpCounts,
    ) -> BvhAction {
        self.prepare_with(pos, radius, counts, crate::parallel::num_threads(), false, None)
    }

    /// [`BvhManager::prepare`] with three extension points:
    ///
    /// * `threads` caps the build/refit worker count (the backends pass the
    ///   step context's count so every phase honors the same setting);
    /// * `force_build` overrides the policy with a build — the sharded
    ///   engine forces one whenever a shard's membership (owned set + halo)
    ///   churned, since a refit is only meaningful over an unchanged
    ///   primitive set. The policy still observes the build afterwards, so
    ///   its cost estimates stay live.
    /// * `zorder` is the step's cached Morton permutation
    ///   ([`crate::frnn::zorder::ZOrderCache`]), reused by LBVH builds
    ///   instead of re-sorting.
    pub fn prepare_with(
        &mut self,
        pos: &[Vec3],
        radius: &[f32],
        counts: &mut OpCounts,
        threads: usize,
        force_build: bool,
        zorder: Option<&[u32]>,
    ) -> BvhAction {
        // Always consult the policy (its decide/observe cycle keeps
        // internal counters live), then override when forced.
        let decided = self.policy.decide();
        let mut action = if force_build { BvhAction::Build } else { decided };
        if action == BvhAction::Build || self.bvh.is_none() {
            action = BvhAction::Build; // nothing to refit before the first build
            self.bvh = Some(Bvh::build_with_threads_ordered(
                pos,
                radius,
                self.build_kind,
                threads,
                zorder,
            ));
            counts.bvh_built_prims += pos.len() as u64;
        } else if let Some(bvh) = self.bvh.as_mut() {
            bvh.refit_with_threads(pos, radius, threads);
            counts.bvh_refit_prims += pos.len() as u64;
        }
        action
    }

    /// Feed the policy the simulated costs of the executed step. The
    /// observation clock is the RT timing model — the reproducible
    /// substitute for the paper's NVML timers.
    pub fn observe(&mut self, action: BvhAction, counts: &OpCounts, hw: &HwProfile) {
        use crate::rtcore::power::{bvh_phase_power, BvhPhase};
        let t = timing::simulate(counts, hw);
        let op_power = bvh_phase_power(
            hw,
            if action == BvhAction::Build { BvhPhase::Build } else { BvhPhase::Refit },
        );
        let q_power = bvh_phase_power(hw, BvhPhase::Traverse);
        self.policy.observe(StepObs {
            action,
            bvh_op_time: (t.build + t.refit) * 1e3,
            query_time: t.traverse * 1e3,
            // millijoules (ms x W)
            bvh_op_energy: (t.build + t.refit) * 1e3 * op_power,
            query_energy: t.traverse * 1e3 * q_power,
        });
    }

    pub fn bvh(&self) -> &Bvh {
        // lint:allow(P-PANIC): accessor contract — callers invoke prepare() first
        self.bvh.as_ref().expect("BVH not built yet")
    }

    /// Drop the cached BVH so the next [`BvhManager::prepare_with`] builds
    /// from scratch regardless of the policy's decision (watchdog recovery
    /// forces a clean tree after restoring a snapshot).
    pub fn invalidate(&mut self) {
        self.bvh = None;
    }

    /// Snapshot the policy with its full internal state (checkpointing).
    pub fn clone_policy(&self) -> Box<dyn RebuildPolicy> {
        self.policy.clone_box()
    }
}

/// One particle's ray set: primary origin plus gamma origins (periodic BC).
/// Visits every discovered sphere exactly once; `visit(j, dx)` receives the
/// neighbor id and the displacement `origin - p_j` (which equals the
/// minimum-image displacement for gamma hits and, in the large-radius
/// periodic regime below, is explicitly minimum-imaged).
///
/// When a search radius exceeds `box_l / 2` (log-normal tails), the gamma
/// machinery breaks down in two ways. A primary ray and a gamma ray can
/// both hit the same sphere — `2 r_j > box_l` means both images of `j` are
/// within reach — and the primary displacement `p - p_j` need not be the
/// minimum image, so emitting both would double the pair's LJ contribution
/// (one of them with the wrong image). And with *variable* radii, the
/// one-shift-per-axis gamma origins are no longer complete: a particle in
/// the band where both walls are within the trigger gets only the `+L`
/// shift, yet a smaller sphere on the `-L` side can satisfy
/// `|d_min| < r_j <= |p - p_j|` and is then never discovered. In that
/// regime (`gamma_trigger > box_l / 2`, conservative since the trigger is
/// `r_max`) rays are launched from **all 26 non-zero image offsets** in
/// `{-L, 0, +L}³`, hits are deduplicated per neighbor, and each neighbor is
/// emitted once with the minimum-image displacement.
///
/// All per-ray state (traversal stack, gamma origins, dedup buffer, stats)
/// lives in the caller-owned [`QueryScratch`]: the hot loop performs no
/// heap allocations once the scratch is warm. Batched sweeps get a
/// per-worker scratch from [`Bvh::query_batch`] /
/// [`Bvh::query_batch_ordered`]; one-off callers create their own.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn launch_rays<F: FnMut(usize, Vec3)>(
    bvh: &Bvh,
    i: usize,
    pos: &[Vec3],
    radius: &[f32],
    boundary: Boundary,
    box_l: f32,
    gamma_trigger: f32,
    scratch: &mut QueryScratch,
    mut visit: F,
) {
    let p = pos[i];
    if boundary == Boundary::Periodic && gamma_trigger > 0.5 * box_l {
        // Large-radius periodic regime: dedup + min-image (see docs above).
        // Below the threshold a sphere is strictly smaller than the box
        // half-width, so at most one ray origin can be inside it and every
        // emitted displacement is already the minimum image — the fast
        // paths below stay exact.
        let mut hits = std::mem::take(&mut scratch.hit_ids);
        debug_assert!(hits.is_empty());
        bvh.query_point(p, i, pos, radius, scratch, |j| hits.push(j as u32));
        let mut gamma = std::mem::take(&mut scratch.gamma);
        gamma.clear();
        for sx in [-box_l, 0.0, box_l] {
            for sy in [-box_l, 0.0, box_l] {
                for sz in [-box_l, 0.0, box_l] {
                    if sx == 0.0 && sy == 0.0 && sz == 0.0 {
                        continue;
                    }
                    gamma.push(p + Vec3::new(sx, sy, sz));
                }
            }
        }
        for &o in &gamma {
            bvh.query_point(o, i, pos, radius, scratch, |j| hits.push(j as u32));
        }
        scratch.gamma = gamma;
        hits.sort_unstable();
        hits.dedup();
        for &ju in &hits {
            let j = ju as usize;
            visit(j, (p - pos[j]).min_image(box_l));
        }
        hits.clear();
        scratch.hit_ids = hits;
        return;
    }
    bvh.query_point(p, i, pos, radius, scratch, |j| {
        visit(j, p - pos[j]);
    });
    if boundary == Boundary::Periodic {
        // Detach the gamma buffer so the scratch can be reborrowed by the
        // gamma queries (pointer swap, no allocation).
        let mut gamma = std::mem::take(&mut scratch.gamma);
        crate::frnn::gamma::gamma_origins(p, gamma_trigger, box_l, &mut gamma);
        for &o in &gamma {
            bvh.query_point(o, i, pos, radius, scratch, |j| {
                visit(j, o - pos[j]);
            });
        }
        scratch.gamma = gamma;
    }
}

/// Fold traversal stats into the step counters.
pub fn fold_stats(counts: &mut OpCounts, stats: &TraversalStats) {
    counts.aabb_tests += stats.aabb_tests;
    counts.sphere_tests += stats.sphere_tests;
    counts.rays += stats.rays;
}

/// The gamma trigger distance for a scene (§3.3): the largest search radius
/// in the system.
pub fn gamma_trigger(state: &SimState) -> f32 {
    state.r_max
}

/// Canonical per-target CSR assembled from unordered `(target, source)`
/// candidate entries: count → exclusive scan → chunk-ordered fill, then each
/// segment is sorted ascending and deduplicated in place (dedup also
/// collapses gamma-ray double discoveries). `lens[t]` is the deduplicated
/// segment length; the entries live at `items[offsets[t]..][..lens[t]]`.
///
/// This is the listless backends' substitute for a stored neighbor list: the
/// structure exists only for the duration of the step so the canonical
/// (ascending-global-id) accumulation order is pinned, and is never metered
/// as a device allocation.
pub struct CanonicalCsr {
    pub offsets: Vec<u32>,
    pub lens: Vec<u32>,
    pub items: Vec<u32>,
}

impl CanonicalCsr {
    #[inline]
    pub fn sources(&self, t: usize) -> &[u32] {
        let off = self.offsets[t] as usize;
        &self.items[off..off + self.lens[t] as usize]
    }
}

pub fn canonical_csr(n: usize, threads: usize, chunks: &[Vec<(u32, u32)>]) -> CanonicalCsr {
    let mut raw_lens = vec![0u32; n];
    for c in chunks {
        for &(t, _) in c {
            raw_lens[t as usize] += 1;
        }
    }
    let offsets = crate::parallel::exclusive_scan_u32(&raw_lens, threads);
    let total = offsets[n] as usize;
    let mut items = vec![0u32; total];
    let mut cursor: Vec<u32> = offsets[..n].to_vec();
    for c in chunks {
        for &(t, s) in c {
            let dst = cursor[t as usize];
            items[dst as usize] = s;
            cursor[t as usize] = dst + 1;
        }
    }
    // Canonicalize each segment in place (segments are disjoint slices, so
    // the parallel sweep is race-free; per-target results are independent of
    // chunk assignment).
    let mut lens = vec![0u32; n];
    {
        let items_ptr = crate::parallel::SendPtr(items.as_mut_ptr());
        let lens_ptr = crate::parallel::SendPtr(lens.as_mut_ptr());
        let offsets_ref: &[u32] = &offsets;
        let raw_ref: &[u32] = &raw_lens;
        crate::parallel::parallel_for_chunks(n, threads, |_, range| {
            let (items_p, lens_p) = (items_ptr, lens_ptr);
            for t in range {
                let off = offsets_ref[t] as usize;
                let raw = raw_ref[t] as usize;
                // SAFETY: [off, off+raw) ranges are disjoint across targets
                // (exclusive scan of raw_lens) and lens[t] is written by
                // exactly one chunk.
                let seg = unsafe {
                    std::slice::from_raw_parts_mut(items_p.0.add(off), raw)
                };
                seg.sort_unstable();
                let mut w = 0usize;
                for r in 0..raw {
                    if r == 0 || seg[r] != seg[w - 1] {
                        seg[w] = seg[r];
                        w += 1;
                    }
                }
                unsafe { *lens_p.0.add(t) = w as u32 };
            }
        });
    }
    CanonicalCsr { offsets, lens, items }
}

/// Canonical-order pair-force gather for one target particle: sum the pair
/// forces over `sources` (ascending global id, deduplicated), recomputing
/// each displacement with [`crate::physics::boundary::displacement`] — this
/// is byte-for-byte the f32 accumulation `RustKernels::lj_forces` performs
/// for the particle, which is what makes every listless path (single-domain
/// ORCS, sharded ORCS, the OOM fallback rung) bitwise identical to the list
/// pipeline and to the brute min-image oracle. `visit(source, d2, in_range)`
/// fires per source so callers can meter the in-shader work without
/// perturbing the sum.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn canonical_force_sum(
    pos: &[Vec3],
    radius: &[f32],
    params: &crate::physics::lj::LjParams,
    boundary: Boundary,
    box_l: f32,
    target: usize,
    sources: &[u32],
    mut visit: impl FnMut(usize, f32, bool),
) -> Vec3 {
    let p_t = pos[target];
    let r_t = radius[target];
    let mut f = Vec3::ZERO;
    for &su in sources {
        let s = su as usize;
        let dx = crate::physics::boundary::displacement(p_t, pos[s], boundary, box_l);
        let fij = params.pair_force(dx, r_t, radius[s]);
        visit(s, dx.norm2(), fij.is_some());
        if let Some(fij) = fij {
            f += fij;
        }
    }
    f
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::{Boundary, RadiusDist, SimConfig};
    use crate::frnn::brute;
    use crate::gradient::FixedKPolicy;

    fn mk_state(n: usize, boundary: Boundary, radius: RadiusDist) -> SimState {
        let cfg = SimConfig {
            n,
            boundary,
            radius_dist: radius,
            box_l: 100.0,
            ..SimConfig::default()
        };
        let mut s = SimState::from_config(&cfg);
        // shrink box positions into [0,100)
        for p in s.pos.iter_mut() {
            p.x = p.x.rem_euclid(100.0);
            p.y = p.y.rem_euclid(100.0);
            p.z = p.z.rem_euclid(100.0);
        }
        s
    }

    #[test]
    fn rays_discover_interaction_set_periodic_uniform() {
        let state = mk_state(200, Boundary::Periodic, RadiusDist::Const(8.0));
        let mut mgr = BvhManager::new(Box::new(FixedKPolicy::new(5)));
        let mut counts = OpCounts::default();
        mgr.prepare(&state.pos, &state.radius, &mut counts);
        let mut scratch = QueryScratch::new();
        for i in 0..state.n() {
            let mut found = Vec::new();
            launch_rays(
                mgr.bvh(),
                i,
                &state.pos,
                &state.radius,
                state.boundary,
                state.box_l,
                gamma_trigger(&state),
                &mut scratch,
                |j, _| found.push(j),
            );
            found.sort_unstable();
            found.dedup();
            let want = brute::interaction_neighbors(
                i,
                &state.pos,
                &state.radius,
                state.boundary,
                state.box_l,
            );
            assert_eq!(found, want, "particle {i}");
        }
        assert!(scratch.stats.rays as usize >= state.n());
    }

    #[test]
    fn gamma_displacement_equals_min_image() {
        // particle at x=1, neighbor at x=99 in a 100-box with radius 5
        let mut state = mk_state(2, Boundary::Periodic, RadiusDist::Const(5.0));
        state.pos[0] = Vec3::new(1.0, 50.0, 50.0);
        state.pos[1] = Vec3::new(99.0, 50.0, 50.0);
        state.r_max = 5.0;
        let mut mgr = BvhManager::new(Box::new(FixedKPolicy::new(5)));
        let mut counts = OpCounts::default();
        mgr.prepare(&state.pos, &state.radius, &mut counts);
        let mut scratch = QueryScratch::new();
        let mut seen = Vec::new();
        launch_rays(
            mgr.bvh(),
            0,
            &state.pos,
            &state.radius,
            state.boundary,
            state.box_l,
            5.0,
            &mut scratch,
            |j, dx| seen.push((j, dx)),
        );
        assert_eq!(seen.len(), 1);
        let (j, dx) = seen[0];
        assert_eq!(j, 1);
        // min image of (1 - 99) across 100 is +2
        assert!((dx.x - 2.0).abs() < 1e-5, "dx={dx:?}");
    }

    #[test]
    fn periodic_large_radius_dedups_and_min_images() {
        // Regression for the r > box_l / 2 double-hit bug: particle 0 at
        // x=1 and particle 1 at x=9 in a 10-box with radius 9. The primary
        // ray hits sphere 1 directly (|p0 - p1| = 8 < 9, displacement -8 —
        // NOT the minimum image) and the gamma_x ray at x=11 hits the same
        // sphere (|11 - 9| = 2 < 9). Pre-fix, `visit` fired twice for j=1
        // (once with the wrong image); post-fix it fires exactly once with
        // the minimum-image displacement +2.
        let box_l = 10.0;
        let pos = vec![Vec3::new(1.0, 5.0, 5.0), Vec3::new(9.0, 5.0, 5.0)];
        let radius = vec![9.0f32, 9.0];
        let bvh = crate::bvh::Bvh::build(&pos, &radius, crate::bvh::BuildKind::BinnedSah);
        let mut scratch = QueryScratch::new();
        let mut seen = Vec::new();
        launch_rays(
            &bvh,
            0,
            &pos,
            &radius,
            Boundary::Periodic,
            box_l,
            9.0,
            &mut scratch,
            |j, dx| seen.push((j, dx)),
        );
        assert_eq!(seen.len(), 1, "duplicate periodic hits: {seen:?}");
        let (j, dx) = seen[0];
        assert_eq!(j, 1);
        assert!(
            (dx.x - 2.0).abs() < 1e-5 && dx.y.abs() < 1e-5 && dx.z.abs() < 1e-5,
            "displacement {dx:?} is not the minimum image"
        );
        // forces built from the ray set must now match the brute-force
        // min-image oracle in this regime
        let params = crate::physics::lj::LjParams::default();
        let want = brute::forces_raw(&pos, &radius, &params, Boundary::Periodic, box_l);
        let mut got = vec![Vec3::ZERO; 2];
        for i in 0..2 {
            launch_rays(
                &bvh,
                i,
                &pos,
                &radius,
                Boundary::Periodic,
                box_l,
                9.0,
                &mut scratch,
                |j, dx| {
                    if let Some(fij) = params.pair_force(dx, radius[i], radius[j]) {
                        got[i] += fij;
                    }
                },
            );
        }
        for i in 0..2 {
            assert!(
                (got[i] - want[i]).norm() <= 1e-4 * want[i].norm().max(1.0),
                "particle {i}: got {:?} want {:?}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn manager_policy_drives_rebuilds() {
        let state = mk_state(100, Boundary::Wall, RadiusDist::Const(4.0));
        let mut mgr = BvhManager::new(Box::new(FixedKPolicy::new(3)));
        let mut actions = Vec::new();
        for _ in 0..6 {
            let mut counts = OpCounts::default();
            let a = mgr.prepare(&state.pos, &state.radius, &mut counts);
            mgr.observe(a, &counts, &crate::rtcore::profile::RTXPRO);
            actions.push(a);
        }
        use BvhAction::*;
        assert_eq!(actions, vec![Build, Update, Update, Build, Update, Update]);
    }
}
