"""Pure-jnp oracle for the LJ neighbor-force computation.

This is the correctness reference the Pallas kernel is validated against
(pytest `test_kernel.py`), and the semantic mirror of the Rust oracle
`rust/src/physics/lj.rs` — all three implementations must agree.

Conventions (DESIGN.md §Physics):
  sigma_ij  = (r_i + r_j) / 2 / sigma_factor
  cutoff_ij = max(r_i, r_j)
  F_ij      = 24 eps (2 (sigma/r)^12 - (sigma/r)^6) / r^2 * dx,   dx = p_i - p_j
  per-pair force clamped component-wise to [-f_max, f_max]
  r^2 floored at R2_MIN (overlap guard), pairs outside cutoff contribute 0
"""

import jax.numpy as jnp

from ..shapes import R2_MIN


def min_image(dx, box_l):
    """Minimum-image displacement for a cubic box of side ``box_l``.

    Pass ``WALL_BOX`` (1e30) to make the wrap a no-op (wall BC).
    """
    return dx - box_l * jnp.round(dx / box_l)


def lj_pair_terms(r2, sigma, eps):
    """Force scalar s (F = s * dx) and potential energy for squared
    distance ``r2`` — *without* cutoff masking (caller masks)."""
    r2s = jnp.maximum(r2, R2_MIN)
    s2 = (sigma * sigma) / r2s
    s6 = s2 * s2 * s2
    force_scalar = 24.0 * eps * (2.0 * s6 * s6 - s6) / r2s
    potential = 4.0 * eps * (s6 * s6 - s6)
    return force_scalar, potential


def lj_forces_ref(pos, nbr_pos, rad, nbr_rad, mask, box_l, eps, sigma_factor, f_max):
    """Reference neighbor-force computation.

    Args:
      pos:      (C, 3)  particle positions.
      nbr_pos:  (C, K, 3) gathered neighbor positions.
      rad:      (C,)    particle search radii.
      nbr_rad:  (C, K)  neighbor radii.
      mask:     (C, K)  1.0 for valid slots, 0.0 for padding.
      box_l, eps, sigma_factor, f_max: scalars.

    Returns:
      force: (C, 3) summed per-particle force.
      pe:    (C,)   summed per-particle pair potential energy.
    """
    dx = min_image(pos[:, None, :] - nbr_pos, box_l)  # (C, K, 3)
    r2 = jnp.sum(dx * dx, axis=-1)  # (C, K)
    sigma = (rad[:, None] + nbr_rad) * 0.5 / sigma_factor
    cutoff = jnp.maximum(rad[:, None], nbr_rad)
    valid = (mask > 0.0) & (r2 < cutoff * cutoff) & (r2 > 0.0)
    s, pe = lj_pair_terms(r2, sigma, eps)
    fvec = jnp.clip(s[..., None] * dx, -f_max, f_max)
    fvec = jnp.where(valid[..., None], fvec, 0.0)
    pe = jnp.where(valid, pe, 0.0)
    return jnp.sum(fvec, axis=1), jnp.sum(pe, axis=1)


def integrate_ref(pos, vel, force, dt, f_max):
    """Symplectic-Euler update (boundary handling stays in Rust)."""
    f = jnp.clip(force, -f_max, f_max)
    new_vel = vel + f * dt
    new_pos = pos + new_vel * dt
    return new_pos, new_vel
