//! RT-REF — the base RT-core FRNN idea of prior work [10, 11, 12, 24]:
//! traversal fills a neighbor list, then a separate compute kernel
//! evaluates forces from the list and another one integrates.
//!
//! The fixed-slot GPU allocation is `n * k_max * 4` bytes; when a scene's
//! densest particle pushes `k_max` toward `n` (Cluster + log-normal radii),
//! the allocation exceeds device memory — the OOM cells of Table 2 and
//! Fig. 13. We track the same quantity and fail the same way.
//!
//! Variable-radius subtlety (paper Fig. 5): `i`'s ray only discovers `j`
//! when `|d| < r_j`. If additionally `|d| >= r_i`, `j`'s ray can *not*
//! discover `i`, so the detecting thread must also append itself to `j`'s
//! list — an atomic cross-insert on real hardware, counted as such.

use std::time::Instant;

use crate::bvh::traverse::TraversalStats;
use crate::frnn::rt_common::{fold_stats, gamma_trigger, launch_rays, BvhManager};
use crate::frnn::{Backend, NeighborLists, StepCtx, StepResult, WallPhases};
use crate::gradient::RebuildPolicy;
use crate::parallel;
use crate::physics::state::SimState;
use crate::rtcore::OpCounts;

pub struct RtRef {
    mgr: BvhManager,
    /// Running worst-case list width (real implementations size the fixed
    /// allocation from it and must re-allocate upward).
    k_max_seen: usize,
}

impl RtRef {
    pub fn new(policy: Box<dyn RebuildPolicy>) -> Self {
        RtRef { mgr: BvhManager::new(policy), k_max_seen: 0 }
    }

    pub fn policy_name(&self) -> String {
        self.mgr.policy.name()
    }
}

impl Backend for RtRef {
    fn name(&self) -> &'static str {
        "RT-REF"
    }

    fn step(&mut self, state: &mut SimState, ctx: &mut StepCtx) -> anyhow::Result<StepResult> {
        let mut counts = OpCounts::default();
        let mut wall = WallPhases::default();
        let n = state.n();

        // Phase 1: BVH maintenance under the rebuild policy.
        let t0 = Instant::now();
        let action = self.mgr.prepare(&state.pos, &state.radius, &mut counts);
        wall.bvh = t0.elapsed().as_secs_f64();

        // Phase 2: ray traversal filling per-particle neighbor lists.
        let t1 = Instant::now();
        let bvh = self.mgr.bvh();
        let trigger = gamma_trigger(state);
        struct ThreadOut {
            lists: Vec<(u32, Vec<u32>)>,
            cross: Vec<(u32, u32)>, // (dst list, inserted id)
            stats: TraversalStats,
        }
        let parts = parallel::parallel_reduce(
            n,
            ctx.threads,
            || ThreadOut { lists: Vec::new(), cross: Vec::new(), stats: TraversalStats::default() },
            |out, i| {
                let mut gamma_buf = Vec::new();
                let mut list = Vec::new();
                let r_i = state.radius[i];
                launch_rays(
                    bvh,
                    i,
                    &state.pos,
                    &state.radius,
                    state.boundary,
                    state.box_l,
                    trigger,
                    &mut gamma_buf,
                    &mut out.stats,
                    |j, dx| {
                        list.push(j as u32);
                        // cross-insert when j's ray cannot see i
                        let r2 = dx.norm2();
                        if r2 >= r_i * r_i {
                            out.cross.push((j as u32, i as u32));
                        }
                    },
                );
                out.lists.push((i as u32, list));
            },
        );

        let mut lists: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut stats = TraversalStats::default();
        let mut cross_inserts = 0u64;
        for part in parts {
            stats.add(&part.stats);
            for (i, l) in part.lists {
                lists[i as usize] = l;
            }
            for (dst, v) in part.cross {
                lists[dst as usize].push(v);
                cross_inserts += 1;
            }
        }
        fold_stats(&mut counts, &stats);
        let nl = NeighborLists::from_vecs(&lists);
        counts.nbr_list_writes += nl.total_entries() as u64;
        counts.atomic_adds += cross_inserts; // atomic appends on real hardware
        self.k_max_seen = self.k_max_seen.max(nl.k_max());
        let list_bytes = (n as u64) * (self.k_max_seen as u64) * 4;
        counts.nbr_list_bytes_peak = list_bytes;
        // every interacting pair ends up in both endpoint lists exactly once
        counts.interactions += nl.total_entries() as u64 / 2;
        wall.search = t1.elapsed().as_secs_f64();

        if ctx.check_oom && list_bytes > ctx.hw.vram_bytes {
            self.mgr.observe(action, &counts, ctx.hw);
            return Ok(StepResult {
                counts,
                bvh_action: Some(action),
                oom_bytes: Some(list_bytes),
                wall,
            });
        }

        // Phase 3: separate force kernel over the lists (XLA or Rust).
        // The paper's kernel reads the *fixed-slot* n x k_max allocation —
        // padding slots are fetched and masked like real ones — so the
        // simulated cost is priced on n * k_max, not on the CSR entry
        // count. This is what makes RT-REF lose to ORCS-forces on skewed
        // (log-normal) neighbor distributions (Table 2, Figs 9-10).
        let t2 = Instant::now();
        state.force = ctx.kernels.lj_forces(state, &nl, &mut counts)?;
        counts.force_kernel_pairs += (n as u64) * (nl.k_max() as u64);
        wall.force = t2.elapsed().as_secs_f64();

        // Phase 4: integration kernel.
        let t3 = Instant::now();
        ctx.kernels.integrate(state, &mut counts)?;
        wall.integrate = t3.elapsed().as_secs_f64();

        self.mgr.observe(action, &counts, ctx.hw);
        Ok(StepResult { counts, bvh_action: Some(action), oom_bytes: None, wall })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::{Boundary, RadiusDist, SimConfig};
    use crate::frnn::{brute, RustKernels};
    use crate::gradient::FixedKPolicy;
    use crate::rtcore::profile::RTXPRO;

    fn run_one(
        n: usize,
        boundary: Boundary,
        radius: RadiusDist,
    ) -> (SimState, SimState, StepResult) {
        let cfg = SimConfig {
            n,
            boundary,
            radius_dist: radius,
            box_l: 100.0,
            ..SimConfig::default()
        };
        let mut state = SimState::from_config(&cfg);
        let want = {
            let mut s2 = state.clone();
            s2.force = brute::forces(&s2);
            crate::physics::integrator::step(&mut s2);
            s2
        };
        let kernels = RustKernels { threads: 2 };
        let mut ctx = StepCtx { threads: 2, kernels: &kernels, hw: &RTXPRO, check_oom: false };
        let mut backend = RtRef::new(Box::new(FixedKPolicy::new(4)));
        let r = backend.step(&mut state, &mut ctx).unwrap();
        (state, want, r)
    }

    #[test]
    fn matches_brute_force_uniform_radius() {
        for boundary in [Boundary::Wall, Boundary::Periodic] {
            let (state, want, r) = run_one(250, boundary, RadiusDist::Const(8.0));
            assert!(r.counts.nbr_list_writes > 0);
            for i in 0..state.n() {
                assert!(
                    (state.pos[i] - want.pos[i]).norm() < 1e-3,
                    "{boundary:?} particle {i}"
                );
            }
        }
    }

    #[test]
    fn matches_brute_force_variable_radius() {
        for boundary in [Boundary::Wall, Boundary::Periodic] {
            let (state, want, r) = run_one(250, boundary, RadiusDist::Uniform(2.0, 14.0));
            // variable radius must trigger cross-inserts (asymmetric pairs)
            assert!(r.counts.atomic_adds > 0, "expected cross-inserts");
            for i in 0..state.n() {
                assert!(
                    (state.pos[i] - want.pos[i]).norm() < 1e-3,
                    "{boundary:?} particle {i}"
                );
            }
        }
    }

    #[test]
    fn oom_fires_when_list_exceeds_vram() {
        let cfg = SimConfig {
            n: 100,
            boundary: Boundary::Wall,
            radius_dist: RadiusDist::Const(50.0), // dense: k_max ~ n
            box_l: 20.0,                          // everything interacts
            ..SimConfig::default()
        };
        let mut state = SimState::from_config(&cfg);
        for p in state.pos.iter_mut() {
            p.x = p.x.rem_euclid(20.0);
            p.y = p.y.rem_euclid(20.0);
            p.z = p.z.rem_euclid(20.0);
        }
        // a tiny synthetic device: 1 KB of VRAM
        static TINY: crate::rtcore::HwProfile = {
            let mut p = crate::rtcore::profile::RTXPRO;
            p.vram_bytes = 1024;
            p
        };
        let kernels = RustKernels { threads: 1 };
        let mut ctx = StepCtx { threads: 1, kernels: &kernels, hw: &TINY, check_oom: true };
        let mut backend = RtRef::new(Box::new(FixedKPolicy::new(4)));
        let r = backend.step(&mut state, &mut ctx).unwrap();
        assert!(r.oom_bytes.is_some(), "expected OOM, got {:?}", r.counts.nbr_list_bytes_peak);
    }

    #[test]
    fn interactions_counted_once_per_pair() {
        let (_, _, r) = run_one(200, Boundary::Periodic, RadiusDist::Const(10.0));
        let cfg = SimConfig {
            n: 200,
            boundary: Boundary::Periodic,
            radius_dist: RadiusDist::Const(10.0),
            box_l: 100.0,
            ..SimConfig::default()
        };
        let state = SimState::from_config(&cfg);
        let want = brute::count_interactions(&state.pos, &state.radius, state.boundary, state.box_l);
        assert_eq!(r.counts.interactions, want);
    }
}
