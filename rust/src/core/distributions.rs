//! Scene generation: initial particle positions, velocities and search radii
//! for the paper's benchmark scenarios (§4.1, Fig. 7).

use super::config::{ParticleDist, RadiusDist, SimConfig};
use super::rng::Rng;
use super::vec3::Vec3;

/// A generated scene: positions, velocities and per-particle search radii
/// (structure-of-arrays, the layout every downstream system consumes).
#[derive(Clone, Debug)]
pub struct Scene {
    pub pos: Vec<Vec3>,
    pub vel: Vec<Vec3>,
    pub radius: Vec<f32>,
    /// Largest search radius in the system — the gamma-ray trigger distance
    /// for periodic BC with variable radii (§3.3).
    pub r_max: f32,
    pub box_l: f32,
}

impl Scene {
    pub fn len(&self) -> usize {
        self.pos.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pos.is_empty()
    }
}

/// Generate initial positions for `n` particles in a cubic box of side
/// `box_l` according to `dist`.
pub fn positions(dist: ParticleDist, n: usize, box_l: f32, rng: &mut Rng) -> Vec<Vec3> {
    match dist {
        ParticleDist::Lattice => lattice(n, box_l),
        ParticleDist::Disordered => (0..n)
            .map(|_| {
                Vec3::new(
                    rng.range_f32(0.0, box_l),
                    rng.range_f32(0.0, box_l),
                    rng.range_f32(0.0, box_l),
                )
            })
            .collect(),
        ParticleDist::Cluster => {
            // Paper: N(mu = rand, sigma = 25). One random cluster center,
            // normal spread of 25, clamped into the box.
            let mu = Vec3::new(
                rng.range_f32(0.2 * box_l, 0.8 * box_l),
                rng.range_f32(0.2 * box_l, 0.8 * box_l),
                rng.range_f32(0.2 * box_l, 0.8 * box_l),
            );
            (0..n)
                .map(|_| {
                    let p = Vec3::new(
                        mu.x + rng.normal_ms(0.0, 25.0) as f32,
                        mu.y + rng.normal_ms(0.0, 25.0) as f32,
                        mu.z + rng.normal_ms(0.0, 25.0) as f32,
                    );
                    clamp_into_box(p, box_l)
                })
                .collect()
        }
    }
}

/// Regular grid filling the box: ceil(n^(1/3)) points per side, row-major,
/// truncated to exactly `n`.
fn lattice(n: usize, box_l: f32) -> Vec<Vec3> {
    let side = (n as f64).cbrt().ceil() as usize;
    let side = side.max(1);
    let step = box_l / side as f32;
    let half = step * 0.5;
    let mut out = Vec::with_capacity(n);
    'outer: for k in 0..side {
        for j in 0..side {
            for i in 0..side {
                if out.len() == n {
                    break 'outer;
                }
                out.push(Vec3::new(
                    half + i as f32 * step,
                    half + j as f32 * step,
                    half + k as f32 * step,
                ));
            }
        }
    }
    out
}

#[inline]
fn clamp_into_box(p: Vec3, box_l: f32) -> Vec3 {
    let eps = 1e-3;
    Vec3::new(
        p.x.clamp(eps, box_l - eps),
        p.y.clamp(eps, box_l - eps),
        p.z.clamp(eps, box_l - eps),
    )
}

/// Sample per-particle search radii.
pub fn radii(dist: RadiusDist, n: usize, rng: &mut Rng) -> Vec<f32> {
    match dist {
        RadiusDist::Const(r) => vec![r; n],
        RadiusDist::Uniform(lo, hi) => (0..n).map(|_| rng.range_f32(lo, hi)).collect(),
        RadiusDist::LogNormal { mu, sigma, lo, hi } => (0..n)
            .map(|_| (rng.lognormal(mu, sigma) as f32).clamp(lo, hi))
            .collect(),
    }
}

/// Small random initial velocities (temperature seed) — the paper's systems
/// start near rest and acquire motion from LJ forces; a tiny kick breaks
/// lattice symmetry.
pub fn velocities(n: usize, scale: f32, rng: &mut Rng) -> Vec<Vec3> {
    (0..n)
        .map(|_| {
            Vec3::new(
                rng.normal_ms(0.0, scale as f64) as f32,
                rng.normal_ms(0.0, scale as f64) as f32,
                rng.normal_ms(0.0, scale as f64) as f32,
            )
        })
        .collect()
}

/// Build the full scene for a configuration.
pub fn scene(cfg: &SimConfig) -> Scene {
    let mut rng = Rng::new(cfg.seed);
    let pos = positions(cfg.particle_dist, cfg.n, cfg.box_l, &mut rng);
    let radius = radii(cfg.radius_dist, cfg.n, &mut rng);
    let vel = velocities(cfg.n, cfg.vel_scale, &mut rng);
    let r_max = radius.iter().fold(0.0f32, |a, &b| a.max(b));
    Scene { pos, vel, radius, r_max, box_l: cfg.box_l }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::{ParticleDist, RadiusDist};

    fn in_box(p: Vec3, l: f32) -> bool {
        (0.0..=l).contains(&p.x) && (0.0..=l).contains(&p.y) && (0.0..=l).contains(&p.z)
    }

    #[test]
    fn lattice_positions_in_box_and_distinct() {
        let ps = positions(ParticleDist::Lattice, 1000, 100.0, &mut Rng::new(1));
        assert_eq!(ps.len(), 1000);
        assert!(ps.iter().all(|&p| in_box(p, 100.0)));
        // grid of 10^3 -> spacing 10, first two differ by 10 in x
        assert!((ps[1].x - ps[0].x - 10.0).abs() < 1e-4);
    }

    #[test]
    fn lattice_non_cube_count() {
        let ps = positions(ParticleDist::Lattice, 37, 100.0, &mut Rng::new(1));
        assert_eq!(ps.len(), 37);
    }

    #[test]
    fn disordered_uniform_spread() {
        let ps = positions(ParticleDist::Disordered, 5000, 1000.0, &mut Rng::new(2));
        assert!(ps.iter().all(|&p| in_box(p, 1000.0)));
        let mean = ps.iter().fold(Vec3::ZERO, |a, &b| a + b) / 5000.0;
        assert!((mean.x - 500.0).abs() < 20.0);
        assert!((mean.y - 500.0).abs() < 20.0);
    }

    #[test]
    fn cluster_is_tight() {
        let ps = positions(ParticleDist::Cluster, 5000, 1000.0, &mut Rng::new(3));
        let mean = ps.iter().fold(Vec3::ZERO, |a, &b| a + b) / 5000.0;
        // std 25 -> nearly all particles within 100 of the center
        let far = ps.iter().filter(|&&p| (p - mean).norm() > 150.0).count();
        assert!(far < 10, "far={far}");
        assert!(ps.iter().all(|&p| in_box(p, 1000.0)));
    }

    #[test]
    fn radii_distributions() {
        let mut rng = Rng::new(4);
        let c = radii(RadiusDist::Const(160.0), 100, &mut rng);
        assert!(c.iter().all(|&r| r == 160.0));
        let u = radii(RadiusDist::Uniform(1.0, 160.0), 10_000, &mut rng);
        assert!(u.iter().all(|&r| (1.0..160.0).contains(&r)));
        let ln =
            radii(RadiusDist::LogNormal { mu: 1.0, sigma: 2.0, lo: 1.0, hi: 330.0 }, 10_000, &mut rng);
        assert!(ln.iter().all(|&r| (1.0..=330.0).contains(&r)));
        // log-normal: most particles small, a few large (paper §4.1)
        let small = ln.iter().filter(|&&r| r < 20.0).count();
        let large = ln.iter().filter(|&&r| r > 100.0).count();
        assert!(small > 7_000, "small={small}");
        assert!(large > 50, "large={large}");
    }

    #[test]
    fn scene_r_max_consistent() {
        let cfg = SimConfig {
            n: 500,
            radius_dist: RadiusDist::Uniform(1.0, 160.0),
            ..SimConfig::default()
        };
        let s = scene(&cfg);
        let m = s.radius.iter().cloned().fold(0.0f32, f32::max);
        assert_eq!(s.r_max, m);
        assert_eq!(s.len(), 500);
    }

    #[test]
    fn scene_deterministic_per_seed() {
        let cfg = SimConfig { n: 100, ..SimConfig::default() };
        let a = scene(&cfg);
        let b = scene(&cfg);
        assert_eq!(a.pos, b.pos);
        assert_eq!(a.radius, b.radius);
    }
}
