//! Hand-rolled CLI argument parsing (the offline vendor set has no `clap`).
//!
//! Grammar: `orcs <subcommand> [--flag value]... [--switch]...`

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::core::config::{Boundary, ForcePath, ParticleDist, RadiusDist, ShardSpec, SimConfig};
use crate::frnn::ApproachKind;
use crate::resilience::{FaultPlan, OomPolicy, ResilienceConfig, WatchdogCfg};
use crate::rtcore::profile;
use crate::rtcore::HwProfile;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    // BTreeMap so any future iteration over the flags is in sorted order
    // (D-HASH-ITER keeps hash order out of user-visible output)
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut it = args.into_iter().peekable();
        let subcommand = it.next().unwrap_or_else(|| "help".into());
        let mut out = Args { subcommand, ..Default::default() };
        while let Some(a) = it.next() {
            let Some(name) = a.strip_prefix("--") else {
                bail!("unexpected positional argument: {a}");
            };
            // --key=value or --key value or --switch
            if let Some((k, v)) = name.split_once('=') {
                out.flags.insert(k.to_string(), v.to_string());
            } else if let Some(v) = it.next_if(|n| !n.starts_with("--")) {
                out.flags.insert(name.to_string(), v);
            } else {
                out.switches.push(name.to_string());
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_f32(&self, key: &str, default: f32) -> Result<f32> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// Build a [`SimConfig`] from the common scenario flags.
    pub fn sim_config(&self) -> Result<SimConfig> {
        let mut cfg = SimConfig {
            n: self.get_usize("n", 10_000)?,
            box_l: self.get_f32("box", 1000.0)?,
            dt: self.get_f32("dt", 1e-3)?,
            ..SimConfig::default()
        };
        if let Some(d) = self.get("dist") {
            cfg.particle_dist = ParticleDist::parse(d)
                .ok_or_else(|| anyhow::anyhow!("bad --dist {d} (lattice|disordered|cluster)"))?;
        }
        if let Some(r) = self.get("radius") {
            cfg.radius_dist = RadiusDist::parse(r)
                .ok_or_else(|| anyhow::anyhow!("bad --radius {r} (r1|r160|u|ln|const:X|uniform:LO,HI|lognormal:MU,SIG,LO,HI)"))?;
        }
        if let Some(b) = self.get("bc") {
            cfg.boundary = Boundary::parse(b)
                .ok_or_else(|| anyhow::anyhow!("bad --bc {b} (wall|periodic)"))?;
        }
        if let Some(s) = self.get("seed") {
            cfg.seed = s.parse()?;
        }
        if let Some(fp) = self.get("force-path") {
            cfg.force_path = match fp {
                "xla" => ForcePath::Xla,
                "rust" => ForcePath::Rust,
                other => bail!("bad --force-path {other} (xla|rust)"),
            };
        }
        Ok(cfg)
    }

    pub fn approach(&self, default: ApproachKind) -> Result<ApproachKind> {
        match self.get("approach") {
            None => Ok(default),
            Some(a) => ApproachKind::parse(a)
                .ok_or_else(|| anyhow::anyhow!("bad --approach {a} (cpu-cell|gpu-cell|rt-ref|orcs-forces|orcs-perse)")),
        }
    }

    /// Sharded-engine backend (`--backend`, with `--approach` accepted as
    /// an alias): the FRNN backend every shard runs. Only the RT trio has a
    /// shard-local traversal; the engine itself validates that.
    pub fn backend(&self, default: ApproachKind) -> Result<ApproachKind> {
        match self.get("backend").or_else(|| self.get("approach")) {
            None => Ok(default),
            Some(a) => ApproachKind::parse(a).ok_or_else(|| {
                anyhow::anyhow!("bad --backend {a} (rt-ref|orcs-forces|orcs-perse)")
            }),
        }
    }

    pub fn hw(&self) -> Result<&'static HwProfile> {
        match self.get("hw") {
            None => Ok(profile::DEFAULT_GPU),
            Some(h) => profile::by_name(h)
                .ok_or_else(|| anyhow::anyhow!("bad --hw {h} (titanrtx|a40|l40|rtxpro)")),
        }
    }

    /// Sharded decomposition requested on the command line (`--shards S`).
    pub fn shards(&self) -> Result<Option<ShardSpec>> {
        match self.get("shards") {
            None => Ok(None),
            Some(v) => ShardSpec::parse(v)
                .map(Some)
                .ok_or_else(|| anyhow::anyhow!("bad --shards {v} (S or SxSxS, cubic)")),
        }
    }

    /// Heterogeneous device fleet (`--fleet titanrtx,l40`), bound
    /// round-robin across the shards.
    pub fn fleet(&self) -> Result<Option<Vec<&'static HwProfile>>> {
        match self.get("fleet") {
            None => Ok(None),
            Some(v) => crate::rtcore::fleet::parse_fleet(v)
                .map(Some)
                .ok_or_else(|| anyhow::anyhow!("bad --fleet {v} (titanrtx|a40|l40|rtxpro)")),
        }
    }

    /// Resilience knobs: `--faults SPEC`, `--checkpoint-every N`,
    /// `--on-oom abort|fallback`, `--watchdog`, `--max-retries N`.
    ///
    /// A `--faults` schedule implies the handlers that keep it survivable:
    /// the watchdog turns on, checkpoints default to every 4 steps, and the
    /// OOM policy defaults to `fallback` (all still overridable).
    pub fn resilience(&self, steps: u64, shards: usize) -> Result<ResilienceConfig> {
        let watchdog = WatchdogCfg {
            enabled: self.has("watchdog"),
            max_retries: self.get_usize("max-retries", 4)? as u32,
            ..WatchdogCfg::default()
        };
        let mut cfg = ResilienceConfig {
            checkpoint_every: self.get_usize("checkpoint-every", 0)? as u64,
            watchdog,
            ..ResilienceConfig::default()
        };
        if let Some(spec) = self.get("faults") {
            cfg.faults = FaultPlan::from_spec(spec, steps, shards).ok_or_else(|| {
                anyhow::anyhow!(
                    "bad --faults {spec} (rand:SEED:RATE or a list of transient@K, nan@K, \
                     lost@K:SHARD, squeeze@K:BYTES, slow@K:SHARD:FACTOR)"
                )
            })?;
            cfg.watchdog.enabled = true;
            cfg.on_oom = OomPolicy::Fallback;
            if cfg.checkpoint_every == 0 {
                cfg.checkpoint_every = 4;
            }
        }
        if let Some(p) = self.get("on-oom") {
            cfg.on_oom = OomPolicy::parse(p)
                .ok_or_else(|| anyhow::anyhow!("bad --on-oom {p} (abort|fallback)"))?;
        }
        Ok(cfg)
    }
}

pub const USAGE: &str = "\
orcs — RT-core FRNN particle simulation (paper reproduction)

USAGE:
  orcs simulate   [scenario flags] [--approach A] [--steps N]
                  [--policy gradient|gradient-ee|avg|fixed-K]
                  [--force-path xla|rust] [--hw GPU] [--trace out.csv]
                  [--shards S [--backend B] [--fleet GPU[,GPU...]]]
                  [telemetry flags]
  orcs trace      run with full tracing on, then emit the Chrome trace,
                  Prometheus/JSON metrics, and a phase-breakdown table
                  (same scenario/shard/resilience flags as simulate)
  orcs bench-fig8        regenerate Fig. 8 (BVH policies time series)
  orcs bench-table2      regenerate Table 2 (avg ms/step grid)
  orcs bench-fig9        regenerate Fig. 9 (speedup, wall BC)
  orcs bench-fig10       regenerate Fig. 10 (speedup, periodic BC)
  orcs bench-fig11       regenerate Fig. 11 (power time series)
  orcs bench-fig12       regenerate Fig. 12 (energy efficiency)
  orcs bench-fig13       regenerate Fig. 13 (GPU-generation scaling)
  orcs bench-sharded     sharded-scaling table (per-shard BVH policies,
                         OOM relief, heterogeneous fleet)
  orcs bench-chaos       recovery-overhead table vs injected fault rate
  orcs lint              determinism / panic-safety static analysis over
                         rust/src (see docs/LINTS.md)
  orcs inspect-artifacts print the loaded PJRT artifact set

Scenario flags:
  --n N                particle count             (default 10000)
  --dist D             lattice|disordered|cluster (default disordered)
  --radius R           r1|r160|u|ln|const:X|uniform:LO,HI|lognormal:MU,SIG,LO,HI
  --bc B               wall|periodic              (default periodic)
  --box L              box side                   (default 1000)
  --dt DT              time step                  (default 1e-3)
  --seed S             RNG seed
Sharding flags:
  --shards S           decompose into an SxSxS shard grid (sharded engine)
  --fleet L            comma-separated GPU list bound round-robin to shards
  --backend B          rt-ref|orcs-forces|orcs-perse — the backend every
                       shard runs (default rt-ref; listless backends never
                       allocate a neighbor list, so they cannot OOM)
Resilience flags:
  --faults SPEC        inject faults: rand:SEED:RATE, or a scripted list of
                       transient@K, nan@K, lost@K:SHARD, squeeze@K:BYTES,
                       slow@K:SHARD:FACTOR  (implies --watchdog, fallback
                       OOM policy, and a 4-step checkpoint cadence)
  --checkpoint-every N snapshot state every N steps (0 = off)
  --on-oom P           abort|fallback — walk the degradation ladder
                       RT-REF -> ORCS-perse -> CPU-CELL instead of aborting
  --watchdog           per-step finiteness + kinetic-energy-drift check;
                       diverged steps retry from the snapshot at dt/2
  --max-retries N      watchdog retry budget per step (default 4)
Telemetry flags (see docs/OBSERVABILITY.md):
  --trace-out F        write a chrome://tracing / Perfetto JSON trace to F
                       (also turns on span retention for orcs simulate;
                       orcs trace defaults to results/trace.json)
  --metrics-out F      write the metrics registry as JSON to F, plus the
                       Prometheus text exposition next to it as F.prom
                       (orcs trace defaults to results/metrics.json)
  --flight K           flight-recorder depth: keep the last K steps for
                       the on-error forensics dump (default 32)
Bench flags:
  --scale F            shrink paper sizes by F (default per-bench)
  --steps N            step count override
  --quick              tiny sizes for smoke runs
Lint flags:
  --root DIR           lint root (default rust/src, then src, then .)
  --config FILE        lint.toml path (default: repo-root lint.toml)
  --format F           human|json             (default human)
  --deny D             all|none|default|RULE[,RULE...]  exit 1 on deny
  --rules              print the rule table and exit
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_flags_and_switches() {
        let a = parse(&["simulate", "--n", "500", "--bc=wall", "--quick", "--policy", "avg"]);
        assert_eq!(a.subcommand, "simulate");
        assert_eq!(a.get("n"), Some("500"));
        assert_eq!(a.get("bc"), Some("wall"));
        assert_eq!(a.get("policy"), Some("avg"));
        assert!(a.has("quick"));
        assert!(!a.has("verbose"));
    }

    #[test]
    fn builds_sim_config() {
        let a = parse(&[
            "simulate", "--n", "123", "--dist", "cluster", "--radius", "ln", "--bc", "wall",
        ]);
        let cfg = a.sim_config().unwrap();
        assert_eq!(cfg.n, 123);
        assert_eq!(cfg.particle_dist, ParticleDist::Cluster);
        assert_eq!(cfg.boundary, Boundary::Wall);
        assert!(matches!(cfg.radius_dist, RadiusDist::LogNormal { .. }));
    }

    #[test]
    fn rejects_bad_values() {
        assert!(parse(&["x", "--dist", "blob"]).sim_config().is_err());
        assert!(parse(&["x", "--bc", "moebius"]).sim_config().is_err());
        assert!(parse(&["x"]).approach(ApproachKind::RtRef).is_ok());
        assert!(parse(&["x", "--approach", "zzz"]).approach(ApproachKind::RtRef).is_err());
        assert!(Args::parse(["x".to_string(), "oops".to_string()]).is_err());
    }

    #[test]
    fn hw_lookup() {
        assert_eq!(parse(&["x"]).hw().unwrap().name, "RTXPRO");
        assert_eq!(parse(&["x", "--hw", "l40"]).hw().unwrap().name, "L40");
        assert!(parse(&["x", "--hw", "h100"]).hw().is_err());
    }

    #[test]
    fn resilience_flags() {
        let r = parse(&["x"]).resilience(10, 1).unwrap();
        assert!(!r.active(), "no flags => inert config");
        let r = parse(&["x", "--watchdog", "--max-retries", "2", "--checkpoint-every", "5"])
            .resilience(10, 1)
            .unwrap();
        assert!(r.watchdog.enabled && r.watchdog.max_retries == 2);
        assert_eq!(r.checkpoint_every, 5);
        assert_eq!(r.on_oom, OomPolicy::Abort);
        // --faults implies survivable defaults
        let r = parse(&["x", "--faults", "lost@3:0,nan@5"]).resilience(10, 2).unwrap();
        assert_eq!(r.faults.faults.len(), 2);
        assert!(r.watchdog.enabled);
        assert_eq!(r.on_oom, OomPolicy::Fallback);
        assert_eq!(r.checkpoint_every, 4);
        // explicit overrides win
        let r = parse(&["x", "--faults", "transient@1", "--on-oom", "abort"])
            .resilience(10, 1)
            .unwrap();
        assert_eq!(r.on_oom, OomPolicy::Abort);
        assert!(parse(&["x", "--faults", "frob@2"]).resilience(10, 1).is_err());
        assert!(parse(&["x", "--on-oom", "explode"]).resilience(10, 1).is_err());
    }

    #[test]
    fn sharding_flags() {
        assert_eq!(parse(&["x"]).shards().unwrap(), None);
        assert_eq!(parse(&["x", "--shards", "2"]).shards().unwrap(), Some(ShardSpec::new(2)));
        assert!(parse(&["x", "--shards", "2x2x3"]).shards().is_err());
        let d = ApproachKind::RtRef;
        assert_eq!(parse(&["x"]).backend(d).unwrap(), ApproachKind::RtRef);
        assert_eq!(
            parse(&["x", "--backend", "orcs-perse"]).backend(d).unwrap(),
            ApproachKind::OrcsPerse
        );
        // --approach is accepted as an alias for sharded runs
        assert_eq!(
            parse(&["x", "--approach", "forces"]).backend(d).unwrap(),
            ApproachKind::OrcsForces
        );
        assert!(parse(&["x", "--backend", "zzz"]).backend(d).is_err());
        assert!(parse(&["x"]).fleet().unwrap().is_none());
        let f = parse(&["x", "--fleet", "titanrtx,l40"]).fleet().unwrap().unwrap();
        assert_eq!(f.len(), 2);
        assert!(parse(&["x", "--fleet", "h100"]).fleet().is_err());
    }
}
