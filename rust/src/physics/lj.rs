//! Truncated Lennard-Jones potential and force (paper Eqs. 2–4).
//!
//! Conventions (documented in DESIGN.md §Physics):
//! * `sigma_i = r_i / sigma_factor` with `sigma_factor = 2.5` (the classic
//!   `r_c = 2.5 sigma` cutoff choice), so a particle's *search radius* is its
//!   interaction cutoff;
//! * pairs mix with Lorentz–Berthelot: `sigma_ij = (sigma_i + sigma_j)/2`;
//! * a pair interacts iff `r < max(r_i, r_j)` — the detection set reachable
//!   by the RT scheme of paper Fig. 5;
//! * force magnitude capped at `f_max` and `r^2` floored to keep dense
//!   clusters numerically stable (standard MD practice).

use crate::core::vec3::Vec3;

/// Minimum r² used in force/potential evaluation (overlap guard).
pub const R2_MIN: f32 = 1e-4;

/// Interaction parameters shared by every backend.
#[derive(Clone, Copy, Debug)]
pub struct LjParams {
    pub epsilon: f32,
    /// `sigma_i = radius_i / sigma_factor`.
    pub sigma_factor: f32,
    /// Per-component force cap.
    pub f_max: f32,
}

impl Default for LjParams {
    fn default() -> Self {
        LjParams { epsilon: 1.0, sigma_factor: 2.5, f_max: 1e4 }
    }
}

impl LjParams {
    /// Pair sigma from the two search radii (Lorentz–Berthelot on
    /// `sigma_i = r_i / sigma_factor`).
    #[inline(always)]
    pub fn sigma_pair(&self, r_i: f32, r_j: f32) -> f32 {
        (r_i + r_j) * 0.5 / self.sigma_factor
    }

    /// Interaction cutoff for a pair: `max(r_i, r_j)` (see module docs).
    #[inline(always)]
    pub fn cutoff_pair(&self, r_i: f32, r_j: f32) -> f32 {
        r_i.max(r_j)
    }

    /// Scalar multiplier `s` such that `F_ij = s * dx` where `dx = p_i - p_j`
    /// (force acting on particle i). Positive s = repulsion.
    ///
    /// `F(r) = 24 eps [ 2 (sigma/r)^12 - (sigma/r)^6 ] / r^2 * dx`
    #[inline(always)]
    pub fn force_scalar(&self, r2: f32, sigma: f32) -> f32 {
        let r2 = r2.max(R2_MIN);
        let s2 = (sigma * sigma) / r2;
        let s6 = s2 * s2 * s2;
        24.0 * self.epsilon * (2.0 * s6 * s6 - s6) / r2
    }

    /// Truncated LJ potential energy of a pair at squared distance `r2`.
    #[inline(always)]
    pub fn potential(&self, r2: f32, sigma: f32) -> f32 {
        let r2 = r2.max(R2_MIN);
        let s2 = (sigma * sigma) / r2;
        let s6 = s2 * s2 * s2;
        4.0 * self.epsilon * (s6 * s6 - s6)
    }

    /// Full pair force on particle i given displacement `dx = p_i - p_j`
    /// (already minimum-imaged by the caller when periodic) and the two
    /// search radii. Returns `None` outside the cutoff.
    #[inline(always)]
    pub fn pair_force(&self, dx: Vec3, r_i: f32, r_j: f32) -> Option<Vec3> {
        let rc = self.cutoff_pair(r_i, r_j);
        let r2 = dx.norm2();
        if r2 >= rc * rc || r2 == 0.0 {
            return None;
        }
        let s = self.force_scalar(r2, self.sigma_pair(r_i, r_j));
        Some(self.cap(dx * s))
    }

    /// Clamp each force component to `[-f_max, f_max]`.
    #[inline(always)]
    pub fn cap(&self, f: Vec3) -> Vec3 {
        Vec3::new(
            f.x.clamp(-self.f_max, self.f_max),
            f.y.clamp(-self.f_max, self.f_max),
            f.z.clamp(-self.f_max, self.f_max),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const P: LjParams = LjParams { epsilon: 1.0, sigma_factor: 2.5, f_max: 1e12 };

    #[test]
    fn potential_zero_at_sigma_min_at_pow2_1_6() {
        let sigma = 1.0f32;
        // U(sigma) = 0
        assert!(P.potential(sigma * sigma, sigma).abs() < 1e-6);
        // minimum at r = 2^(1/6) sigma, U = -eps
        let rmin = 2f32.powf(1.0 / 6.0) * sigma;
        assert!((P.potential(rmin * rmin, sigma) + 1.0).abs() < 1e-5);
        // force zero at the minimum
        assert!(P.force_scalar(rmin * rmin, sigma).abs() < 1e-4);
    }

    #[test]
    fn force_sign_repulsive_inside_attractive_outside() {
        let sigma = 1.0f32;
        let rmin = 2f32.powf(1.0 / 6.0) * sigma;
        // closer than the minimum: repulsive (positive scalar pushes i away from j)
        assert!(P.force_scalar(0.9 * 0.9, sigma) > 0.0);
        // beyond the minimum: attractive
        assert!(P.force_scalar((rmin * 1.5).powi(2), sigma) < 0.0);
    }

    #[test]
    fn force_is_negative_gradient_of_potential() {
        // numeric dU/dr vs analytic F at several r
        let sigma = 0.8f32;
        for &r in &[0.75f32, 0.9, 1.0, 1.3, 1.8] {
            let h = 1e-3f32;
            let up = P.potential((r + h) * (r + h), sigma);
            let um = P.potential((r - h) * (r - h), sigma);
            let dudr = (up - um) / (2.0 * h);
            // F_vec = s * dx, radial magnitude = s * r, and F_r = -dU/dr
            let s = P.force_scalar(r * r, sigma);
            let f_r = s * r;
            assert!(
                (f_r + dudr).abs() < 2e-2 * (1.0 + dudr.abs()),
                "r={r}: f_r={f_r} -dU/dr={:.5}",
                -dudr
            );
        }
    }

    #[test]
    fn pair_force_cutoff_and_symmetry() {
        let dx = Vec3::new(3.0, 0.0, 0.0);
        // cutoff is max(r_i, r_j): with radii (1, 2), r=3 is outside
        assert!(P.pair_force(dx, 1.0, 2.0).is_none());
        // with radii (1, 4) it is inside
        let f = P.pair_force(dx, 1.0, 4.0).unwrap();
        // Newton's third law: swapping i/j flips dx and the force
        let f_ji = P.pair_force(-dx, 4.0, 1.0).unwrap();
        assert!((f + f_ji).norm() < 1e-6 * f.norm().max(1.0));
    }

    #[test]
    fn cap_limits_components() {
        let p = LjParams { f_max: 10.0, ..P };
        let f = p.cap(Vec3::new(100.0, -100.0, 5.0));
        assert_eq!(f, Vec3::new(10.0, -10.0, 5.0));
    }

    #[test]
    fn overlap_guard_is_finite() {
        let f = P.force_scalar(0.0, 1.0);
        assert!(f.is_finite());
        let u = P.potential(0.0, 1.0);
        assert!(u.is_finite());
    }
}
