//! PJRT runtime: loads the AOT-lowered HLO artifacts and executes them on
//! the request path.
//!
//! `make artifacts` runs Python exactly once; from then on the Rust binary
//! is self-contained: `HloModuleProto::from_text_file` → `client.compile`
//! (once per shape bucket, at startup) → `execute` per step. See
//! /opt/xla-example/load_hlo/ for the reference wiring and
//! python/compile/aot.py for why the interchange format is HLO *text*.
//!
//! The PJRT bindings (`xla` crate) are not part of the offline vendor set,
//! so the whole runtime is gated behind the `xla` cargo feature. Without
//! it this module compiles a stub whose loaders return `Err`, and every
//! caller (CLI, benches, integration tests) falls back to the pure-Rust
//! kernels exactly as it does when the artifacts are missing.

pub mod buckets;
pub mod kernels;

use std::path::PathBuf;

/// Static shapes shared with `python/compile/shapes.py` — change together.
pub const CHUNK: usize = 4096;
pub const K_BUCKETS: [usize; 3] = [16, 64, 256];
/// Box-length sentinel disabling minimum-image wrap (wall BC).
pub const WALL_BOX: f32 = 1e30;

/// Default artifact directory: `$ORCS_ARTIFACTS` or `./artifacts`,
/// falling back to the crate-root copy for tests run elsewhere.
fn default_artifact_dir() -> PathBuf {
    std::env::var("ORCS_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
        let local = PathBuf::from("artifacts");
        if local.join(format!("integrate_c{CHUNK}.hlo.txt")).exists() {
            local
        } else {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        }
    })
}

#[cfg(feature = "xla")]
mod pjrt {
    use std::collections::BTreeMap;
    use std::path::{Path, PathBuf};

    use anyhow::{Context, Result};

    use super::{default_artifact_dir, CHUNK, K_BUCKETS};

    /// A loaded, compiled PJRT executable with its input layout.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        pub name: String,
    }

    impl Executable {
        /// Execute with literal inputs; returns the decomposed output tuple.
        pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let result = self
                .exe
                .execute::<xla::Literal>(inputs)
                .with_context(|| format!("executing {}", self.name))?;
            let out = result[0][0]
                .to_literal_sync()
                .with_context(|| format!("fetching result of {}", self.name))?;
            // aot.py lowers with return_tuple=True
            Ok(out.to_tuple()?)
        }
    }

    /// The compiled artifact set.
    pub struct XlaRuntime {
        #[allow(dead_code)]
        client: xla::PjRtClient,
        /// K-bucket → force executable, iterable in ascending-K order.
        pub lj_forces: BTreeMap<usize, Executable>,
        pub integrate: Executable,
        /// Pure-jnp variant of the K=64 bucket (cross-check tests).
        pub lj_forces_ref: Option<Executable>,
        pub artifact_dir: PathBuf,
    }

    fn load_one(client: &xla::PjRtClient, dir: &Path, name: &str) -> Result<Executable> {
        let path = dir.join(name);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        Ok(Executable { exe, name: name.to_string() })
    }

    impl XlaRuntime {
        /// Load and compile every artifact in `dir` (built by `make artifacts`).
        pub fn load(dir: &Path) -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let mut lj_forces = BTreeMap::new();
            for k in K_BUCKETS {
                let name = format!("lj_forces_c{CHUNK}_k{k}.hlo.txt");
                lj_forces.insert(k, load_one(&client, dir, &name)?);
            }
            let integrate = load_one(&client, dir, &format!("integrate_c{CHUNK}.hlo.txt"))?;
            let lj_forces_ref =
                load_one(&client, dir, &format!("lj_forces_ref_c{CHUNK}_k64.hlo.txt")).ok();
            Ok(XlaRuntime {
                client,
                lj_forces,
                integrate,
                lj_forces_ref,
                artifact_dir: dir.to_path_buf(),
            })
        }

        /// See [`super::default_artifact_dir`].
        pub fn default_dir() -> PathBuf {
            default_artifact_dir()
        }
    }

    /// f32 slice → PJRT literal of the given dims.
    pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
        let expected: usize = dims.iter().product();
        anyhow::ensure!(data.len() == expected, "literal size {} != {:?}", data.len(), dims);
        // SAFETY: reinterpreting an f32 slice as its raw bytes — the pointer
        // is valid for `len * 4` bytes, u8 has no alignment requirement, and
        // the lifetime is bounded by `data`'s borrow.
        let bytes =
            unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            dims,
            bytes,
        )?)
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn literal_roundtrip() {
            let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
            let lit = literal_f32(&data, &[2, 3]).unwrap();
            let back = lit.to_vec::<f32>().unwrap();
            assert_eq!(back, data);
        }

        #[test]
        fn literal_size_mismatch_rejected() {
            assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        }
    }
}

#[cfg(feature = "xla")]
pub use pjrt::{literal_f32, Executable, XlaRuntime};

#[cfg(not(feature = "xla"))]
mod stub {
    use std::collections::BTreeMap;
    use std::path::{Path, PathBuf};

    use anyhow::{bail, Result};

    /// Shape-compatible stand-in so callers (e.g. `orcs
    /// inspect-artifacts`) compile without the `xla` feature; never
    /// constructed because [`XlaRuntime::load`] always errors.
    pub struct Executable {
        pub name: String,
    }

    /// Stub runtime: [`XlaRuntime::load`] reports the missing feature.
    pub struct XlaRuntime {
        pub lj_forces: BTreeMap<usize, Executable>,
        pub integrate: Executable,
        pub lj_forces_ref: Option<Executable>,
        pub artifact_dir: PathBuf,
    }

    impl XlaRuntime {
        pub fn load(dir: &Path) -> Result<Self> {
            bail!(
                "PJRT runtime unavailable: built without the `xla` cargo feature \
                 (the offline vendor set has no PJRT bindings); artifact dir was {}",
                dir.display()
            )
        }

        /// See [`super::default_artifact_dir`].
        pub fn default_dir() -> PathBuf {
            super::default_artifact_dir()
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::{Executable, XlaRuntime};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_mirror_python() {
        // guard against drift with python/compile/shapes.py
        assert_eq!(CHUNK, 4096);
        assert_eq!(K_BUCKETS, [16, 64, 256]);
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_runtime_reports_missing_feature() {
        let err = XlaRuntime::load(&default_artifact_dir()).unwrap_err();
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
