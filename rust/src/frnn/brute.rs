//! All-pairs brute-force oracle: `O(n²)` neighbor finding and force
//! evaluation. The ground truth every backend is validated against in the
//! integration and property tests.

use crate::core::config::Boundary;
use crate::core::vec3::Vec3;
use crate::physics::boundary::displacement;
use crate::physics::lj::LjParams;
use crate::physics::state::SimState;

/// Interaction neighbor set of particle `i`: all `j != i` with
/// `|d_ij| < max(r_i, r_j)` (minimum-imaged when periodic). Sorted.
pub fn interaction_neighbors(
    i: usize,
    pos: &[Vec3],
    radius: &[f32],
    boundary: Boundary,
    box_l: f32,
) -> Vec<usize> {
    let mut out = Vec::new();
    for j in 0..pos.len() {
        if j == i {
            continue;
        }
        let d = displacement(pos[i], pos[j], boundary, box_l);
        let rc = radius[i].max(radius[j]);
        if d.norm2() < rc * rc {
            out.push(j);
        }
    }
    out
}

/// Detection neighbor set: all `j != i` whose *sphere contains* `p_i`
/// (`|d_ij| < r_j`) — what particle i's ray alone can discover (Fig. 5).
pub fn detection_neighbors(
    i: usize,
    pos: &[Vec3],
    radius: &[f32],
    boundary: Boundary,
    box_l: f32,
) -> Vec<usize> {
    let mut out = Vec::new();
    for j in 0..pos.len() {
        if j == i {
            continue;
        }
        let d = displacement(pos[i], pos[j], boundary, box_l);
        if d.norm2() < radius[j] * radius[j] {
            out.push(j);
        }
    }
    out
}

/// Brute-force per-particle LJ forces over the interaction sets.
pub fn forces(state: &SimState) -> Vec<Vec3> {
    let n = state.n();
    let mut f = vec![Vec3::ZERO; n];
    for i in 0..n {
        for j in (i + 1)..n {
            let d = displacement(state.pos[i], state.pos[j], state.boundary, state.box_l);
            if let Some(fij) = state.params.pair_force(d, state.radius[i], state.radius[j]) {
                f[i] += fij;
                f[j] -= fij;
            }
        }
    }
    f
}

/// Count unordered interacting pairs (the paper's per-step `I`).
pub fn count_interactions(
    pos: &[Vec3],
    radius: &[f32],
    boundary: Boundary,
    box_l: f32,
) -> u64 {
    let n = pos.len();
    let mut c = 0u64;
    for i in 0..n {
        for j in (i + 1)..n {
            let d = displacement(pos[i], pos[j], boundary, box_l);
            let rc = radius[i].max(radius[j]);
            if d.norm2() < rc * rc {
                c += 1;
            }
        }
    }
    c
}

/// Total potential energy (diagnostic for integration tests).
pub fn potential_energy(state: &SimState) -> f64 {
    let n = state.n();
    let mut u = 0.0f64;
    for i in 0..n {
        for j in (i + 1)..n {
            let d = displacement(state.pos[i], state.pos[j], state.boundary, state.box_l);
            let rc = state.params.cutoff_pair(state.radius[i], state.radius[j]);
            let r2 = d.norm2();
            if r2 < rc * rc {
                let sigma = state.params.sigma_pair(state.radius[i], state.radius[j]);
                u += state.params.potential(r2, sigma) as f64;
            }
        }
    }
    u
}

/// Convenience used by tests: forces computed for arbitrary arrays.
pub fn forces_raw(
    pos: &[Vec3],
    radius: &[f32],
    params: &LjParams,
    boundary: Boundary,
    box_l: f32,
) -> Vec<Vec3> {
    let n = pos.len();
    let mut f = vec![Vec3::ZERO; n];
    for i in 0..n {
        for j in (i + 1)..n {
            let d = displacement(pos[i], pos[j], boundary, box_l);
            if let Some(fij) = params.pair_force(d, radius[i], radius[j]) {
                f[i] += fij;
                f[j] -= fij;
            }
        }
    }
    f
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::SimConfig;

    #[test]
    fn interaction_set_symmetric() {
        let pos = vec![
            Vec3::new(0.0, 0.0, 0.0),
            Vec3::new(3.0, 0.0, 0.0),
            Vec3::new(50.0, 0.0, 0.0),
        ];
        let radius = vec![1.0f32, 5.0, 1.0];
        // pair (0,1): dist 3 < max(1,5) -> interact both ways
        let n0 = interaction_neighbors(0, &pos, &radius, Boundary::Wall, 100.0);
        let n1 = interaction_neighbors(1, &pos, &radius, Boundary::Wall, 100.0);
        assert_eq!(n0, vec![1]);
        assert_eq!(n1, vec![0]);
        // detection is asymmetric: 0 sees 1 (inside 1's sphere), 1 does not see 0
        let d0 = detection_neighbors(0, &pos, &radius, Boundary::Wall, 100.0);
        let d1 = detection_neighbors(1, &pos, &radius, Boundary::Wall, 100.0);
        assert_eq!(d0, vec![1]);
        assert!(d1.is_empty());
    }

    #[test]
    fn periodic_wraps_neighbors() {
        let pos = vec![Vec3::new(0.5, 5.0, 5.0), Vec3::new(9.5, 5.0, 5.0)];
        let radius = vec![2.0f32, 2.0];
        let nw = interaction_neighbors(0, &pos, &radius, Boundary::Wall, 10.0);
        assert!(nw.is_empty());
        let np = interaction_neighbors(0, &pos, &radius, Boundary::Periodic, 10.0);
        assert_eq!(np, vec![1]);
    }

    #[test]
    fn forces_conserve_momentum() {
        let cfg = SimConfig { n: 50, ..SimConfig::default() };
        let mut state = SimState::from_config(&cfg);
        // dense cluster to guarantee interactions
        for (k, p) in state.pos.iter_mut().enumerate() {
            let k = k as f32;
            *p = Vec3::new(500.0 + (k % 5.0) * 0.8, 500.0 + (k / 7.0) * 0.6, 500.0);
        }
        state.radius.iter_mut().for_each(|r| *r = 3.0);
        let f = forces(&state);
        let sum = f.iter().fold(Vec3::ZERO, |a, &b| a + b);
        let scale: f32 = f.iter().map(|v| v.norm()).sum::<f32>().max(1.0);
        assert!(sum.norm() < 1e-3 * scale, "net force {sum:?} vs scale {scale}");
    }

    #[test]
    fn interaction_count_matches_sets() {
        let cfg = SimConfig { n: 40, ..SimConfig::default() };
        let mut state = SimState::from_config(&cfg);
        state.radius.iter_mut().for_each(|r| *r = 40.0);
        let total: usize = (0..state.n())
            .map(|i| {
                interaction_neighbors(i, &state.pos, &state.radius, state.boundary, state.box_l)
                    .len()
            })
            .sum();
        let pairs = count_interactions(&state.pos, &state.radius, state.boundary, state.box_l);
        assert_eq!(total as u64, 2 * pairs);
    }
}
