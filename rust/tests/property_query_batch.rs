//! Property tests for the batched traversal engine: `Bvh::query_batch`
//! and the Morton-ordered `Bvh::query_batch_ordered` (with gamma rays
//! under periodic BC) must return **bit-identical** neighbor streams and
//! traversal stats to the per-point `query_point` / `launch_rays` path —
//! across all three `BuildKind`s, after arbitrary refit sequences, and for
//! any worker count — and the level-parallel refit must equal the serial
//! sweep node-for-node.

use orcs::bvh::traverse::QueryScratch;
use orcs::bvh::{BuildKind, Bvh};
use orcs::core::config::Boundary;
use orcs::core::rng::Rng;
use orcs::core::vec3::Vec3;
use orcs::frnn::rt_common::launch_rays;
use orcs::testutil::prop_check;

fn random_scene(rng: &mut Rng, n: usize, box_l: f32, r_max: f32) -> (Vec<Vec3>, Vec<f32>) {
    let pos = (0..n)
        .map(|_| {
            Vec3::new(
                rng.range_f32(0.0, box_l),
                rng.range_f32(0.0, box_l),
                rng.range_f32(0.0, box_l),
            )
        })
        .collect();
    let radius = (0..n).map(|_| rng.range_f32(0.3, r_max)).collect();
    (pos, radius)
}

fn build_kind(rng: &mut Rng) -> BuildKind {
    match rng.below(3) {
        0 => BuildKind::Median,
        1 => BuildKind::BinnedSah,
        _ => BuildKind::Lbvh,
    }
}

/// Per-particle `(neighbor, displacement)` streams via the per-point path.
#[allow(clippy::too_many_arguments, clippy::type_complexity)]
fn per_point_lists(
    bvh: &Bvh,
    pos: &[Vec3],
    radius: &[f32],
    boundary: Boundary,
    box_l: f32,
    trigger: f32,
) -> (Vec<Vec<(u32, Vec3)>>, orcs::bvh::traverse::TraversalStats) {
    let mut scratch = QueryScratch::new();
    let lists = (0..pos.len())
        .map(|i| {
            let mut list = Vec::new();
            launch_rays(bvh, i, pos, radius, boundary, box_l, trigger, &mut scratch, |j, dx| {
                list.push((j as u32, dx));
            });
            list
        })
        .collect();
    (lists, scratch.take_stats())
}

#[test]
fn prop_query_batch_bit_identical_to_per_point() {
    prop_check("query-batch-vs-per-point", 20, |rng| {
        let n = 30 + rng.below(250);
        let box_l = 70.0;
        let (mut pos, radius) = random_scene(rng, n, box_l, 12.0);
        let kind = build_kind(rng);
        let boundary =
            if rng.f32() < 0.5 { Boundary::Wall } else { Boundary::Periodic };
        let trigger = radius.iter().fold(0.0f32, |a, &r| a.max(r));

        let mut bvh = Bvh::build(&pos, &radius, kind);
        // several refit rounds so stale-loose bounds are exercised too
        let refits = rng.below(4);
        for _ in 0..refits {
            for p in pos.iter_mut() {
                *p += Vec3::new(
                    rng.range_f32(-2.0, 2.0),
                    rng.range_f32(-2.0, 2.0),
                    rng.range_f32(-2.0, 2.0),
                );
            }
            bvh.refit(&pos, &radius);
        }

        let (want, want_stats) =
            per_point_lists(&bvh, &pos, &radius, boundary, box_l, trigger);

        for threads in [1usize, 2, 5] {
            let (chunks, stats) = bvh.query_batch(
                n,
                threads,
                || (),
                |_, scratch, range| {
                    range
                        .map(|i| {
                            let mut list = Vec::new();
                            launch_rays(
                                &bvh,
                                i,
                                &pos,
                                &radius,
                                boundary,
                                box_l,
                                trigger,
                                scratch,
                                |j, dx| list.push((j as u32, dx)),
                            );
                            list
                        })
                        .collect::<Vec<_>>()
                },
            );
            let got: Vec<Vec<(u32, Vec3)>> = chunks.into_iter().flatten().collect();
            if got != want {
                return Err(format!(
                    "{kind:?}/{boundary:?}/refits={refits}/threads={threads}: \
                     batched neighbor streams differ from per-point"
                ));
            }
            if stats != want_stats {
                return Err(format!(
                    "{kind:?}/{boundary:?}/threads={threads}: stats {stats:?} != {want_stats:?}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_query_batch_ordered_bit_identical_to_per_point() {
    // the Morton-ordered sweep must produce, per particle, exactly the
    // per-point neighbor stream (ids and displacements bit-identical) for
    // every thread count, with order-independent stats totals
    prop_check("query-batch-ordered-vs-per-point", 20, |rng| {
        let n = 30 + rng.below(250);
        let box_l = 70.0;
        let (mut pos, radius) = random_scene(rng, n, box_l, 12.0);
        let kind = build_kind(rng);
        let boundary =
            if rng.f32() < 0.5 { Boundary::Wall } else { Boundary::Periodic };
        let trigger = radius.iter().fold(0.0f32, |a, &r| a.max(r));

        let mut bvh = Bvh::build(&pos, &radius, kind);
        let refits = rng.below(4);
        for _ in 0..refits {
            for p in pos.iter_mut() {
                *p += Vec3::new(
                    rng.range_f32(-2.0, 2.0),
                    rng.range_f32(-2.0, 2.0),
                    rng.range_f32(-2.0, 2.0),
                );
            }
            bvh.refit(&pos, &radius);
        }

        let (want, want_stats) =
            per_point_lists(&bvh, &pos, &radius, boundary, box_l, trigger);

        for threads in [1usize, 2, 5] {
            let (chunks, stats) = bvh.query_batch_ordered(
                &pos,
                box_l,
                threads,
                || (),
                |_, scratch, ids| {
                    ids.iter()
                        .map(|&iu| {
                            let i = iu as usize;
                            let mut list = Vec::new();
                            launch_rays(
                                &bvh,
                                i,
                                &pos,
                                &radius,
                                boundary,
                                box_l,
                                trigger,
                                scratch,
                                |j, dx| list.push((j as u32, dx)),
                            );
                            (iu, list)
                        })
                        .collect::<Vec<_>>()
                },
            );
            // scatter back to particle order; every particle exactly once
            let mut got = vec![Vec::new(); n];
            let mut filled = vec![false; n];
            for (iu, list) in chunks.into_iter().flatten() {
                if filled[iu as usize] {
                    return Err(format!(
                        "{kind:?}/{boundary:?}/threads={threads}: particle {iu} swept twice"
                    ));
                }
                filled[iu as usize] = true;
                got[iu as usize] = list;
            }
            for (i, g) in got.into_iter().enumerate() {
                if !filled[i] {
                    return Err(format!(
                        "{kind:?}/{boundary:?}/threads={threads}: particle {i} missed"
                    ));
                }
                if g != want[i] {
                    return Err(format!(
                        "{kind:?}/{boundary:?}/refits={refits}/threads={threads}: \
                         ordered stream differs from per-point at particle {i}"
                    ));
                }
            }
            if stats != want_stats {
                return Err(format!(
                    "{kind:?}/{boundary:?}/threads={threads}: stats {stats:?} != {want_stats:?}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_refit_equals_serial_node_for_node() {
    // the level-partitioned refit must produce bit-identical lane boxes to
    // the serial bottom-up sweep, for every build kind and thread count
    prop_check("parallel-refit-vs-serial", 8, |rng| {
        let n = 3000 + rng.below(4000);
        let (mut pos, radius) = random_scene(rng, n, 90.0, 6.0);
        let kind = build_kind(rng);
        let base = Bvh::build_with_threads(&pos, &radius, kind, 1);
        let mut serial = base.clone();
        let mut par = base;
        let threads = 2 + rng.below(7);
        for round in 0..3 {
            for p in pos.iter_mut() {
                *p += Vec3::new(
                    rng.range_f32(-2.0, 2.0),
                    rng.range_f32(-2.0, 2.0),
                    rng.range_f32(-2.0, 2.0),
                );
            }
            serial.refit_with_threads(&pos, &radius, 1);
            par.refit_with_threads(&pos, &radius, threads);
            if serial.nodes != par.nodes {
                return Err(format!(
                    "{kind:?} threads={threads}: refit diverged at round {round}"
                ));
            }
        }
        par.check_invariants(&pos, &radius).map_err(|e| e.to_string())?;
        Ok(())
    });
}

#[test]
fn prop_query_batch_matches_brute_detection_sets() {
    // anchor the batched path against the O(n^2) oracle, dedup'd across
    // primary + gamma rays
    prop_check("query-batch-vs-brute", 15, |rng| {
        let n = 30 + rng.below(150);
        let box_l = 60.0;
        let (pos, radius) = random_scene(rng, n, box_l, 10.0);
        let kind = build_kind(rng);
        let boundary =
            if rng.f32() < 0.5 { Boundary::Wall } else { Boundary::Periodic };
        let trigger = radius.iter().fold(0.0f32, |a, &r| a.max(r));
        let bvh = Bvh::build(&pos, &radius, kind);

        let (chunks, _) = bvh.query_batch(
            n,
            3,
            || (),
            |_, scratch, range| {
                range
                    .map(|i| {
                        let mut list = Vec::new();
                        launch_rays(
                            &bvh,
                            i,
                            &pos,
                            &radius,
                            boundary,
                            box_l,
                            trigger,
                            scratch,
                            |j, _| list.push(j),
                        );
                        list.sort_unstable();
                        list.dedup();
                        list
                    })
                    .collect::<Vec<_>>()
            },
        );
        let got: Vec<Vec<usize>> = chunks.into_iter().flatten().collect();
        for i in 0..n {
            let want = orcs::frnn::brute::detection_neighbors(
                i, &pos, &radius, boundary, box_l,
            );
            if got[i] != want {
                return Err(format!("{kind:?}/{boundary:?} particle {i} set mismatch"));
            }
        }
        Ok(())
    });
}
