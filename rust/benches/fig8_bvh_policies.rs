//! `cargo bench --bench fig8_bvh_policies [-- --quick | --n N --steps S]`
//! Regenerates paper Fig. 8 (BVH rebuild/update schemes).
fn main() {
    let opts = orcs::benchsuite::common::BenchOpts::from_env().expect("bench options");
    orcs::benchsuite::fig8::run(&opts).expect("fig8 bench");
}
