//! Resilience-runtime acceptance properties (ISSUE):
//!
//!  (a) a faulted-and-recovered run is **bitwise identical** to a fault-free
//!      run at every step boundary — device loss replays from the last
//!      checkpoint through the same deterministic kernels;
//!  (b) an RT-REF run that trips `check_oom` and falls back mid-run produces
//!      forces bitwise identical to a pure ORCS-persé run started from the
//!      same snapshot — the degradation ladder changes pricing, not physics;
//!  (c) the numerical watchdog converges on an injected divergence: restore
//!      the pre-step snapshot, halve `dt`, force a BVH rebuild, finish
//!      finite.
//!
//! All properties are exercised for thread counts {1, 8} and, where the
//! sharded engine is involved, shard grids S ∈ {1, 2}.

use std::sync::Arc;

use orcs::coordinator::{Engine, EngineConfig};
use orcs::core::config::{Boundary, ParticleDist, RadiusDist, ShardSpec, SimConfig};
use orcs::core::vec3::Vec3;
use orcs::frnn::{ApproachKind, RustKernels};
use orcs::resilience::{EventKind, FaultPlan, OomPolicy, ResilienceConfig, WatchdogCfg};
use orcs::shard::{ShardedConfig, ShardedEngine};

fn scenario(n: usize, seed: u64) -> SimConfig {
    SimConfig {
        n,
        box_l: 100.0,
        particle_dist: ParticleDist::Disordered,
        // uniform radius: every rung of the degradation ladder is open
        radius_dist: RadiusDist::Const(8.0),
        boundary: Boundary::Periodic,
        seed,
        ..SimConfig::default()
    }
}

fn assert_bits_equal(got: &[Vec3], want: &[Vec3], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for i in 0..want.len() {
        let (a, b) = (got[i], want[i]);
        assert_eq!(
            (a.x.to_bits(), a.y.to_bits(), a.z.to_bits()),
            (b.x.to_bits(), b.y.to_bits(), b.z.to_bits()),
            "{ctx}: particle {i} diverged: {a:?} vs {b:?}"
        );
    }
}

fn engine(cfg: &SimConfig, threads: usize, res: ResilienceConfig) -> Engine {
    let ec = EngineConfig {
        policy: "fixed-3".into(),
        threads,
        resilience: res,
        ..EngineConfig::new(cfg.clone(), ApproachKind::RtRef)
    };
    Engine::new(ec, Arc::new(RustKernels { threads })).unwrap()
}

fn sharded(cfg: &SimConfig, s: usize, threads: usize, res: ResilienceConfig) -> ShardedEngine {
    let sc = ShardedConfig {
        policy: "fixed-3".into(),
        threads,
        fleet: vec![&orcs::rtcore::profile::TITANRTX, &orcs::rtcore::profile::L40],
        resilience: res,
        ..ShardedConfig::new(cfg.clone(), ShardSpec::new(s))
    };
    ShardedEngine::new(sc, Arc::new(RustKernels { threads })).unwrap()
}

fn plan(spec: &str) -> FaultPlan {
    FaultPlan::parse(spec).unwrap_or_else(|| panic!("bad fault spec {spec}"))
}

// ---- property (a): checkpointed recovery is bitwise transparent ---------

#[test]
fn resilience_engine_device_loss_recovery_is_bitwise_identical() {
    let cfg = scenario(300, 7);
    let steps = 8;
    for threads in [1usize, 8] {
        let ctx = format!("engine recovery threads={threads}");
        let mut clean = engine(&cfg, threads, ResilienceConfig::default());
        clean.run(steps, false).unwrap();

        // loss entering step 5, checkpoints at 0/2/4/... -> replay 1 step
        let res = ResilienceConfig {
            checkpoint_every: 2,
            faults: plan("lost@5:0"),
            ..ResilienceConfig::default()
        };
        let mut faulted = engine(&cfg, threads, res);
        let s = faulted.run(steps, false).unwrap();
        assert_eq!(s.replayed_steps, 1, "{ctx}: replay from the checkpoint at 4");
        assert_eq!(s.steps, steps as u64 + s.replayed_steps, "{ctx}: replayed steps re-priced");
        assert!(
            s.events.iter().any(|e| matches!(e.kind, EventKind::DeviceLost { .. })),
            "{ctx}: no DeviceLost event: {:?}",
            s.events
        );
        assert!(
            s.events.iter().any(|e| matches!(e.kind, EventKind::Recovery { replayed: 1, .. })),
            "{ctx}: no Recovery event: {:?}",
            s.events
        );
        assert_eq!(faulted.state.step_count, steps as u64, "{ctx}");
        assert_bits_equal(&faulted.state.pos, &clean.state.pos, &ctx);
        assert_bits_equal(&faulted.state.vel, &clean.state.vel, &ctx);
        assert_bits_equal(&faulted.state.force, &clean.state.force, &ctx);
    }
}

#[test]
fn resilience_sharded_device_loss_recovery_is_bitwise_identical() {
    let cfg = scenario(220, 99);
    let steps = 10;
    for s in [1usize, 2] {
        for threads in [1usize, 8] {
            let ctx = format!("sharded recovery S={s} threads={threads}");
            let mut clean = sharded(&cfg, s, threads, ResilienceConfig::default());
            clean.run(steps, false).unwrap();

            // device 0 dies entering step 7; checkpoints at 0/3/6 -> the
            // surviving device absorbs every shard and replays one step
            let res = ResilienceConfig {
                checkpoint_every: 3,
                faults: plan("lost@7:0"),
                ..ResilienceConfig::default()
            };
            let mut faulted = sharded(&cfg, s, threads, res);
            let sum = faulted.run(steps, false).unwrap();
            assert!(!sum.oom, "{ctx}");
            assert_eq!(sum.replayed_steps, 1, "{ctx}: replay from the checkpoint at 6");
            assert!(
                sum.events
                    .iter()
                    .any(|e| matches!(e.kind, EventKind::DeviceLost { survivors: 1, .. })),
                "{ctx}: no DeviceLost event: {:?}",
                sum.events
            );
            assert!(
                sum.events.iter().any(|e| matches!(e.kind, EventKind::Recovery { .. })),
                "{ctx}: no Recovery event: {:?}",
                sum.events
            );
            assert_eq!(faulted.state.step_count, steps as u64, "{ctx}");
            assert_bits_equal(&faulted.state.pos, &clean.state.pos, &ctx);
            assert_bits_equal(&faulted.state.vel, &clean.state.vel, &ctx);
            assert_bits_equal(&faulted.state.force, &clean.state.force, &ctx);
        }
    }
}

// ---- property (b): OOM fallback == native ORCS-persé from the snapshot --

#[test]
fn resilience_oom_fallback_matches_native_perse_from_snapshot() {
    let cfg = scenario(300, 7);
    for threads in [1usize, 8] {
        let ctx = format!("oom fallback threads={threads}");
        // phase 1: a clean RT-REF prefix; its state is the shared snapshot
        let mut pre = engine(&cfg, threads, ResilienceConfig::default());
        pre.run(3, false).unwrap();
        let snapshot = pre.state.clone();

        // reference: a pure ORCS-persé engine started from that snapshot
        let pc = EngineConfig {
            policy: "fixed-3".into(),
            threads,
            ..EngineConfig::new(cfg.clone(), ApproachKind::OrcsPerse)
        };
        let mut native =
            Engine::with_state(pc, Arc::new(RustKernels { threads }), snapshot.clone()).unwrap();
        native.run(3, false).unwrap();

        // the faulted run: a VRAM squeeze entering step 3 makes the RT-REF
        // fixed-slot list unpayable, so the ladder switches to ORCS-persé
        // mid-run and the remaining steps execute listless
        let res = ResilienceConfig {
            on_oom: OomPolicy::Fallback,
            faults: plan("squeeze@3:16"),
            ..ResilienceConfig::default()
        };
        let mut fb = engine(&cfg, threads, res);
        let sum = fb.run(6, false).unwrap();
        assert!(!sum.oom, "{ctx}: the fallback must absorb the OOM");
        assert_eq!(fb.cfg.approach, ApproachKind::OrcsPerse, "{ctx}: ladder landed on persé");
        assert!(
            sum.events.iter().any(|e| matches!(
                e.kind,
                EventKind::OomFallback { from: "RT-REF", to: "ORCS-perse", .. }
            )),
            "{ctx}: no RT-REF -> ORCS-perse fallback event: {:?}",
            sum.events
        );
        assert_eq!(fb.state.step_count, 6, "{ctx}");
        assert_bits_equal(&fb.state.pos, &native.state.pos, &ctx);
        assert_bits_equal(&fb.state.vel, &native.state.vel, &ctx);
        assert_bits_equal(&fb.state.force, &native.state.force, &ctx);
    }
}

// ---- property (c): the watchdog converges on injected divergence --------

#[test]
fn resilience_engine_watchdog_converges_on_injected_divergence() {
    let cfg = scenario(300, 11);
    let dt0 = cfg.dt;
    for threads in [1usize, 8] {
        let ctx = format!("engine watchdog threads={threads}");
        let res = ResilienceConfig {
            watchdog: WatchdogCfg { enabled: true, ..WatchdogCfg::default() },
            faults: plan("nan@3"),
            ..ResilienceConfig::default()
        };
        let mut e = engine(&cfg, threads, res);
        let s = e.run(6, false).unwrap();
        assert_eq!(s.steps, 6, "{ctx}");
        assert!(e.state.is_finite(), "{ctx}: divergence survived");
        assert!(e.state.dt < dt0, "{ctx}: dt must be halved ({} vs {dt0})", e.state.dt);
        assert!(
            s.events.iter().any(|e| matches!(e.kind, EventKind::WatchdogRetry { .. })),
            "{ctx}: no WatchdogRetry event: {:?}",
            s.events
        );
    }
}

#[test]
fn resilience_sharded_watchdog_converges_on_injected_divergence() {
    let cfg = scenario(220, 13);
    let dt0 = cfg.dt;
    for s in [1usize, 2] {
        let ctx = format!("sharded watchdog S={s}");
        let res = ResilienceConfig {
            watchdog: WatchdogCfg { enabled: true, ..WatchdogCfg::default() },
            faults: plan("nan@3"),
            ..ResilienceConfig::default()
        };
        let mut e = sharded(&cfg, s, 2, res);
        let sum = e.run(6, false).unwrap();
        assert!(!sum.oom, "{ctx}");
        assert!(e.state.is_finite(), "{ctx}: divergence survived");
        assert!(e.state.dt < dt0, "{ctx}: dt must be halved ({} vs {dt0})", e.state.dt);
        assert!(
            sum.events.iter().any(|e| matches!(e.kind, EventKind::WatchdogRetry { .. })),
            "{ctx}: no WatchdogRetry event: {:?}",
            sum.events
        );
        assert_eq!(e.state.step_count, 6, "{ctx}: the run must still finish");
    }
}

// ---- seeded chaos schedules terminate and stay comparable ---------------

#[test]
fn resilience_seeded_fault_schedule_completes_without_abort() {
    // the ISSUE smoke criterion: `FaultPlan::seeded` schedules (transients,
    // stragglers, bounded device losses — never divergence) complete, and
    // stay bitwise identical to the fault-free trajectory
    let cfg = scenario(220, 21);
    let steps = 12;
    let mut clean = sharded(&cfg, 2, 2, ResilienceConfig::default());
    clean.run(steps, false).unwrap();
    for seed in [1u64, 2, 3] {
        let ctx = format!("seeded chaos seed={seed}");
        let res = ResilienceConfig {
            on_oom: OomPolicy::Fallback,
            checkpoint_every: 4,
            faults: FaultPlan::seeded(seed, steps as u64, 0.4, 8, 1),
            ..ResilienceConfig::default()
        };
        let mut e = sharded(&cfg, 2, 2, res);
        let sum = e.run(steps, false).unwrap();
        assert!(!sum.oom, "{ctx}");
        assert_eq!(e.state.step_count, steps as u64, "{ctx}");
        assert!(e.state.is_finite(), "{ctx}");
        assert_bits_equal(&e.state.pos, &clean.state.pos, &ctx);
        assert_bits_equal(&e.state.vel, &clean.state.vel, &ctx);
    }
}
