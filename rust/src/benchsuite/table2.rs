//! Table 2 — average time (ms) per step for the five approaches over the
//! full scenario grid, wall + periodic BC, small + large n.
//!
//! Rows mirror the paper exactly: per (distribution, radius, BC, n) the
//! fastest approach is flagged, ORCS-persé prints `-` for variable radii,
//! and RT-REF prints `OOM` where its fixed-slot neighbor list would exceed
//! device memory *at paper scale* (extrapolated from the measured k_max —
//! see `common::paper_scale_oom`) or at bench scale.

use anyhow::Result;

use super::common::{paper_grid, paper_scale_oom, BenchOpts};
use crate::coordinator::metrics::fmt_ms;
use crate::coordinator::report::{results_dir, CsvWriter, TextTable};
use crate::core::config::Boundary;
use crate::frnn::ApproachKind;

/// Paper: n in {50k, 1M}. Bench defaults (simulated times are
/// size-faithful; see DESIGN.md).
const N_SMALL: usize = 1_500;
const N_LARGE: usize = 6_000;
/// Paper-scale sizes used for the OOM extrapolation.
const N_PAPER_SMALL: usize = 50_000;
const N_PAPER_LARGE: usize = 1_000_000;
const STEPS_DEFAULT: usize = 20;

pub fn run(opts: &BenchOpts) -> Result<()> {
    let (n_small, steps) = opts.size(N_SMALL, STEPS_DEFAULT);
    let (n_large, _) = opts.size(N_LARGE, STEPS_DEFAULT);
    println!("== Table 2: avg simulated ms/step (n_small={n_small}, n_large={n_large}, {steps} steps) ==");
    println!("   paper: n in {{50k, 1M}}; OOM cells extrapolated to paper scale\n");

    let mut csv = CsvWriter::create(
        &results_dir().join("table2_sim_perf.csv"),
        &["dist", "radius", "bc", "n", "approach", "avg_sim_ms", "oom", "k_max_like", "wall_s"],
    )?;

    for case in paper_grid() {
        let mut table = TextTable::new(&[
            "approach",
            "Wall/small",
            "Wall/large",
            "Periodic/small",
            "Periodic/large",
        ]);
        // column-wise bests for the teal highlight equivalent (asterisk)
        let mut cells: Vec<Vec<Option<(f64, bool)>>> = Vec::new();

        for approach in ApproachKind::ALL {
            let mut row_cells = Vec::new();
            for (boundary, n, n_paper) in [
                (Boundary::Wall, n_small, N_PAPER_SMALL),
                (Boundary::Wall, n_large, N_PAPER_LARGE),
                (Boundary::Periodic, n_small, N_PAPER_SMALL),
                (Boundary::Periodic, n_large, N_PAPER_LARGE),
            ] {
                let summary =
                    opts.run(&case, n, boundary, approach, "gradient", steps, true)?;
                let cell = match summary {
                    None => None, // unsupported (perse x variable radius)
                    Some(s) => {
                        // extrapolated OOM for RT-REF from measured k_max
                        let k_max_like = s
                            .records
                            .iter()
                            .map(|r| r.counts.nbr_list_bytes_peak / 4 / (n as u64).max(1))
                            .max()
                            .unwrap_or(0) as usize;
                        let oom = s.oom
                            || (approach == ApproachKind::RtRef
                                && paper_scale_oom(k_max_like, n, n_paper, opts.hw));
                        csv.row(&[
                            case.dist.to_string(),
                            case.radius.to_string(),
                            boundary.to_string(),
                            n.to_string(),
                            approach.to_string(),
                            format!("{:.4}", s.avg_sim_ms),
                            oom.to_string(),
                            k_max_like.to_string(),
                            format!("{:.2}", s.wall_total_s),
                        ])?;
                        Some((s.avg_sim_ms, oom))
                    }
                };
                row_cells.push(cell);
            }
            cells.push(row_cells);
        }

        // render with best-of-column markers (the paper's teal cells)
        let bests: Vec<f64> = (0..4)
            .map(|col| {
                cells
                    .iter()
                    .filter_map(|row| row[col])
                    .filter(|(_, oom)| !oom)
                    .map(|(ms, _)| ms)
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();
        for (ai, approach) in ApproachKind::ALL.iter().enumerate() {
            let mut fields = vec![approach.to_string()];
            for col in 0..4 {
                fields.push(match cells[ai][col] {
                    None => "-".into(),
                    Some((_, true)) => "OOM".into(),
                    Some((ms, false)) => {
                        if (ms - bests[col]).abs() < 1e-12 {
                            format!("*{}", fmt_ms(ms))
                        } else {
                            fmt_ms(ms)
                        }
                    }
                });
            }
            table.row(fields);
        }
        println!("--- {} ---", case.tag());
        println!("{}", table.render());
    }
    println!("(* = fastest per column, as the paper's teal cells)");
    println!("CSV: {}", results_dir().join("table2_sim_perf.csv").display());
    Ok(())
}
