//! `orcs lint` — a dependency-free static-analysis pass for the repo's
//! two load-bearing contracts:
//!
//! * **Determinism**: results are bitwise identical across `ORCS_THREADS`
//!   and shard counts. The D-* rules hunt the usual leaks (hash-order
//!   iteration, stray thread-count reads, wall clocks in decision paths,
//!   unordered float accumulation).
//! * **Panic safety**: no panic escapes `Backend::step` or the engines'
//!   `run()` (the `SimError` contract). The P-* rules hunt panicking
//!   constructs and silent truncation; U-SAFETY keeps `unsafe` documented.
//!
//! Findings can be suppressed inline (a `lint:allow(RULE-ID): reason`
//! comment on the same line or the line directly above) or via the
//! checked-in `lint.toml` allowlist. Rule IDs, rationale, and the known
//! heuristic limits are documented in `docs/LINTS.md`.

pub mod config;
pub mod lexer;
pub mod rules;

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

pub use config::{AllowEntry, LintConfig};
pub use rules::{Finding, RuleInfo, Severity, RULES};

use rules::FileSrc;

/// How `--deny` remaps severities before the exit-code decision.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DenyMode {
    /// Per-rule defaults from the rule table.
    Default,
    /// Everything denies (the CI gate).
    All,
    /// Everything warns (reporting only; the gate always passes).
    None,
    /// The listed rules deny; the rest keep their defaults.
    Rules(Vec<String>),
}

impl DenyMode {
    pub fn parse(s: &str) -> Result<DenyMode> {
        match s {
            "default" => Ok(DenyMode::Default),
            "all" => Ok(DenyMode::All),
            "none" | "warn" => Ok(DenyMode::None),
            list => {
                let ids: Vec<String> = list.split(',').map(|x| x.trim().to_string()).collect();
                for id in &ids {
                    if !rules::is_known_rule(id) {
                        bail!(
                            "--deny: unknown rule {id} (expected all|none|default or ids from: {})",
                            rules::rule_ids().join(", ")
                        );
                    }
                }
                Ok(DenyMode::Rules(ids))
            }
        }
    }

    fn apply(&self, rule: &str) -> Severity {
        match self {
            DenyMode::Default => rules::default_severity(rule),
            DenyMode::All => Severity::Deny,
            DenyMode::None => Severity::Warn,
            DenyMode::Rules(ids) => {
                if ids.iter().any(|i| i == rule) {
                    Severity::Deny
                } else {
                    rules::default_severity(rule)
                }
            }
        }
    }
}

/// The result of one lint run.
#[derive(Debug)]
pub struct LintReport {
    /// Surviving findings, sorted by (path, line, col, rule).
    pub findings: Vec<Finding>,
    /// Findings removed by inline or config suppressions.
    pub suppressed: usize,
    /// Number of files scanned.
    pub files: usize,
}

impl LintReport {
    pub fn deny_count(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Deny).count()
    }

    pub fn warn_count(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Warn).count()
    }
}

/// Lint in-memory sources: `(relative-path, content)` pairs. This is the
/// pure core — `lint_root` is a thin filesystem shim over it.
pub fn lint_sources(sources: &[(String, String)], cfg: &LintConfig, deny: &DenyMode) -> LintReport {
    let files: Vec<FileSrc> =
        sources.iter().map(|(rel, text)| FileSrc::new(rel.clone(), text)).collect();
    let raw = rules::scan(&files, cfg);

    // inline suppressions + their own hygiene findings, per file
    let mut inline: BTreeMap<&str, BTreeMap<u32, BTreeSet<String>>> = BTreeMap::new();
    let mut findings: Vec<Finding> = Vec::new();
    for f in &files {
        let (allows, mut bad) = parse_suppressions(f);
        inline.insert(f.rel.as_str(), allows);
        findings.append(&mut bad);
    }

    let mut suppressed = 0usize;
    for finding in raw {
        let by_inline = inline
            .get(finding.path.as_str())
            .and_then(|m| m.get(&finding.line))
            .map(|ids| ids.contains(finding.rule) || ids.contains("*"))
            .unwrap_or(false);
        if by_inline || cfg.allowed(finding.rule, &finding.path) {
            suppressed += 1;
        } else {
            findings.push(finding);
        }
    }

    for f in &mut findings {
        f.severity = deny.apply(f.rule);
    }
    findings.sort_by(|a, b| (&a.path, a.line, a.col, a.rule).cmp(&(&b.path, b.line, b.col, b.rule)));
    LintReport { findings, suppressed, files: files.len() }
}

/// Lint every `.rs` file under `root` (recursively, sorted order).
pub fn lint_root(root: &Path, cfg: &LintConfig, deny: &DenyMode) -> Result<LintReport> {
    let mut paths = Vec::new();
    collect_rs(root, &mut paths)
        .with_context(|| format!("walking lint root {}", root.display()))?;
    let mut sources = Vec::new();
    for p in &paths {
        let rel = p
            .strip_prefix(root)
            .unwrap_or(p)
            .to_string_lossy()
            .replace('\\', "/");
        let text =
            std::fs::read_to_string(p).with_context(|| format!("reading {}", p.display()))?;
        sources.push((rel, text));
    }
    Ok(lint_sources(&sources, cfg, deny))
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries = Vec::new();
    for e in std::fs::read_dir(dir)? {
        entries.push(e?.path());
    }
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Parse `lint:allow(RULE[, RULE]): reason` comments. Returns the
/// line→rules map (line = the line the allow covers) plus L-ALLOW
/// findings for malformed or unknown-rule suppressions.
fn parse_suppressions(f: &FileSrc) -> (BTreeMap<u32, BTreeSet<String>>, Vec<Finding>) {
    let mut allows: BTreeMap<u32, BTreeSet<String>> = BTreeMap::new();
    let mut bad = Vec::new();
    let mut flag = |tok: &lexer::Token, msg: String| {
        bad.push(Finding {
            rule: "L-ALLOW",
            severity: rules::default_severity("L-ALLOW"),
            path: f.rel.clone(),
            line: tok.line,
            col: tok.col,
            message: msg,
        });
    };
    for c in &f.comments {
        let body = c.text.trim_start_matches(['/', '!', '*']).trim();
        let Some(rest) = body.strip_prefix("lint:allow(") else {
            continue;
        };
        let Some(close) = rest.find(')') else {
            flag(c, "lint:allow missing closing `)`".to_string());
            continue;
        };
        let after = rest[close + 1..].trim().trim_end_matches("*/").trim();
        let reason = after.strip_prefix(':').map(str::trim).unwrap_or("");
        if reason.is_empty() {
            flag(c, "lint:allow needs a `: reason` after the rule list".to_string());
            continue;
        }
        // a full-line comment covers the next line; a trailing comment
        // covers its own line
        let own_line = f
            .lines
            .get(c.line as usize - 1)
            .map(|l| {
                let t = l.trim_start();
                t.starts_with("//") || t.starts_with("/*")
            })
            .unwrap_or(false);
        let target = if own_line { c.line + 1 } else { c.line };
        for id in rest[..close].split(',') {
            let id = id.trim();
            if id != "*" && !rules::is_known_rule(id) {
                flag(c, format!("lint:allow names unknown rule {id}"));
            } else {
                allows.entry(target).or_default().insert(id.to_string());
            }
        }
    }
    (allows, bad)
}

/// Render a human-readable report.
pub fn render_human(report: &LintReport) -> String {
    let mut s = String::new();
    for f in &report.findings {
        s.push_str(&format!(
            "{}:{}:{} [{}] {}: {}\n",
            f.path,
            f.line,
            f.col,
            f.severity.as_str(),
            f.rule,
            f.message
        ));
    }
    if report.findings.is_empty() {
        s.push_str(&format!(
            "lint: clean — {} files scanned, {} finding(s) suppressed\n",
            report.files, report.suppressed
        ));
    } else {
        s.push_str(&format!(
            "lint: {} finding(s) ({} deny, {} warn), {} suppressed, {} files scanned\n",
            report.findings.len(),
            report.deny_count(),
            report.warn_count(),
            report.suppressed,
            report.files
        ));
    }
    s
}

/// Render the report as JSON (hand-rolled — the vendor set has no serde).
pub fn render_json(report: &LintReport) -> String {
    let mut s = String::from("{\n");
    s.push_str(&format!(
        "  \"files\": {},\n  \"suppressed\": {},\n  \"deny\": {},\n  \"warn\": {},\n",
        report.files,
        report.suppressed,
        report.deny_count(),
        report.warn_count()
    ));
    s.push_str("  \"findings\": [");
    for (k, f) in report.findings.iter().enumerate() {
        if k > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "\n    {{\"rule\": \"{}\", \"severity\": \"{}\", \"path\": \"{}\", \"line\": {}, \
             \"col\": {}, \"message\": \"{}\"}}",
            json_escape(f.rule),
            f.severity.as_str(),
            json_escape(&f.path),
            f.line,
            f.col,
            json_escape(&f.message)
        ));
    }
    if !report.findings.is_empty() {
        s.push_str("\n  ");
    }
    s.push_str("]\n}\n");
    s
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// `orcs lint [--root DIR] [--config FILE] [--format human|json]
/// [--deny all|none|default|ID,...] [--rules]` — returns `Err` (exit 1)
/// when any deny-severity finding survives suppression.
pub fn run_cli(args: &crate::cli::Args) -> Result<()> {
    if args.has("rules") {
        for r in RULES {
            println!("{:<14} {:<5} {}", r.id, r.default_severity.as_str(), r.summary);
        }
        return Ok(());
    }
    let root = match args.get("root") {
        Some(r) => PathBuf::from(r),
        None => ["rust/src", "src"]
            .into_iter()
            .map(PathBuf::from)
            .find(|p| p.is_dir())
            .unwrap_or_else(|| PathBuf::from(".")),
    };
    let cfg = match args.get("config") {
        Some(c) => LintConfig::load(Path::new(c))?,
        None => {
            let candidates = [PathBuf::from("lint.toml"), root.join("../../lint.toml")];
            match candidates.iter().find(|p| p.is_file()) {
                Some(p) => LintConfig::load(p)?,
                None => LintConfig::default(),
            }
        }
    };
    let deny = DenyMode::parse(args.get_or("deny", "default"))?;
    let report = lint_root(&root, &cfg, &deny)?;
    match args.get_or("format", "human") {
        "human" => print!("{}", render_human(&report)),
        "json" => print!("{}", render_json(&report)),
        other => bail!("bad --format {other} (human|json)"),
    }
    if report.deny_count() > 0 {
        bail!("lint: {} deny finding(s) in {}", report.deny_count(), root.display());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn everywhere() -> LintConfig {
        let all = vec![".".to_string()];
        LintConfig { step_path: all.clone(), det_path: all.clone(), csr_path: all, allow: vec![] }
    }

    fn lint_one(src: &str) -> LintReport {
        lint_sources(&[("t.rs".to_string(), src.to_string())], &everywhere(), &DenyMode::All)
    }

    fn rules_of(r: &LintReport) -> Vec<&'static str> {
        r.findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn trailing_and_preceding_suppressions() {
        let hit = lint_one("fn f(xs: &[u32]) -> u32 {\n    *xs.first().unwrap()\n}\n");
        assert_eq!(rules_of(&hit), vec!["P-PANIC"]);
        let trailing = lint_one(
            "fn f(xs: &[u32]) -> u32 {\n    *xs.first().unwrap() // lint:allow(P-PANIC): caller \
             checks\n}\n",
        );
        assert!(trailing.findings.is_empty(), "{:?}", trailing.findings);
        assert_eq!(trailing.suppressed, 1);
        let above = lint_one(
            "fn f(xs: &[u32]) -> u32 {\n    // lint:allow(P-PANIC): caller checks\n    \
             *xs.first().unwrap()\n}\n",
        );
        assert!(above.findings.is_empty(), "{:?}", above.findings);
    }

    #[test]
    fn malformed_suppressions_are_l_allow() {
        let unknown = lint_one("// lint:allow(NOT-A-RULE): whatever\nfn f() {}\n");
        assert_eq!(rules_of(&unknown), vec!["L-ALLOW"]);
        let no_reason = lint_one("// lint:allow(P-PANIC)\nfn f() {}\n");
        assert_eq!(rules_of(&no_reason), vec!["L-ALLOW"]);
        // doc prose mentioning the syntax mid-sentence is not a suppression
        let prose = lint_one("// suppress with lint:allow(P-PANIC): reason\nfn f() {}\n");
        assert!(prose.findings.is_empty(), "{:?}", prose.findings);
    }

    #[test]
    fn deny_modes() {
        assert_eq!(DenyMode::parse("all").unwrap(), DenyMode::All);
        assert_eq!(DenyMode::parse("none").unwrap(), DenyMode::None);
        assert!(DenyMode::parse("P-PANIC,U-SAFETY").is_ok());
        assert!(DenyMode::parse("P-TYPO").is_err());
        // P-INDEX-LIT warns by default, denies under --deny all
        let src = "fn f(v: &[u32]) -> u32 {\n    v[0]\n}\n";
        let dflt =
            lint_sources(&[("t.rs".into(), src.into())], &everywhere(), &DenyMode::Default);
        assert_eq!(dflt.deny_count(), 0);
        assert_eq!(dflt.warn_count(), 1);
        let all = lint_sources(&[("t.rs".into(), src.into())], &everywhere(), &DenyMode::All);
        assert_eq!(all.deny_count(), 1);
    }

    #[test]
    fn test_modules_are_exempt_except_u_safety() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        \
                   Some(1).unwrap();\n    }\n}\n";
        let r = lint_one(src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn config_allowlist_suppresses_by_path() {
        let mut cfg = everywhere();
        cfg.allow.push(AllowEntry {
            rule: "P-PANIC".into(),
            path: "t.rs".into(),
            reason: "test".into(),
        });
        let r = lint_sources(
            &[("t.rs".into(), "fn f() {\n    None::<u32>.unwrap();\n}\n".into())],
            &cfg,
            &DenyMode::All,
        );
        assert!(r.findings.is_empty(), "{:?}", r.findings);
        assert_eq!(r.suppressed, 1);
    }

    #[test]
    fn json_output_is_well_formed_enough() {
        let r = lint_one("fn f() {\n    None::<u32>.unwrap();\n}\n");
        let js = render_json(&r);
        assert!(js.contains("\"rule\": \"P-PANIC\""));
        assert!(js.contains("\"deny\": 1"));
        let clean = lint_one("fn f() {}\n");
        assert!(render_json(&clean).contains("\"findings\": []"));
    }
}
