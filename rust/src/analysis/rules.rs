//! The lint rules: determinism (D-*), panic-safety (P-*), unsafe hygiene
//! (U-*), and suppression hygiene (L-*).
//!
//! Every rule is a token-level heuristic, not a semantic analysis — the
//! engine has no type information. Each rule's detection pattern and its
//! documented blind spots live in `docs/LINTS.md`; the fixture corpus in
//! `rust/tests/lint_fixtures/` pins both the positive and the negative
//! behavior of every pattern below.

use std::collections::{BTreeMap, BTreeSet};

use super::config::LintConfig;
use super::lexer::{tokenize, TokKind, Token};

/// Finding severity. `Deny` findings fail the CI gate; `Warn` findings
/// are reported but do not affect the exit code (until `--deny` says so).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warn,
    Deny,
}

impl Severity {
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Warn => "warn",
            Severity::Deny => "deny",
        }
    }
}

/// One lint finding, pinned to a source span.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub severity: Severity,
    pub path: String,
    pub line: u32,
    pub col: u32,
    pub message: String,
}

/// Static rule metadata (drives `--rules`, validation, docs).
pub struct RuleInfo {
    pub id: &'static str,
    pub default_severity: Severity,
    pub summary: &'static str,
}

/// The rule table. IDs are stable; `docs/LINTS.md` is the narrative.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "D-HASH-ITER",
        default_severity: Severity::Deny,
        summary: "HashMap/HashSet iteration or drain leaks hash order into results",
    },
    RuleInfo {
        id: "D-ENV-THREADS",
        default_severity: Severity::Deny,
        summary: "thread-count env var read outside parallel.rs bypasses the one blessed site",
    },
    RuleInfo {
        id: "D-WALL-CLOCK",
        default_severity: Severity::Deny,
        summary: "Instant/SystemTime/thread-id in a determinism path",
    },
    RuleInfo {
        id: "D-FP-PARALLEL",
        default_severity: Severity::Deny,
        summary: "float accumulation inside a parallel_* closure without a chunk-ordered merge",
    },
    RuleInfo {
        id: "P-PANIC",
        default_severity: Severity::Deny,
        summary: "unwrap/expect/panic! reachable from Backend::step (the SimError contract)",
    },
    RuleInfo {
        id: "P-INDEX-LIT",
        default_severity: Severity::Warn,
        summary: "direct literal slice index in a step path can panic on empty input",
    },
    RuleInfo {
        id: "P-CAST-NARROW",
        default_severity: Severity::Warn,
        summary: "lossy `as` narrowing in CSR offset/merge code truncates silently",
    },
    RuleInfo {
        id: "U-SAFETY",
        default_severity: Severity::Deny,
        summary: "unsafe block/fn without an immediately preceding SAFETY comment",
    },
    RuleInfo {
        id: "L-ALLOW",
        default_severity: Severity::Deny,
        summary: "malformed lint:allow suppression (unknown rule or missing reason)",
    },
];

pub fn is_known_rule(id: &str) -> bool {
    RULES.iter().any(|r| r.id == id)
}

pub fn rule_ids() -> Vec<&'static str> {
    RULES.iter().map(|r| r.id).collect()
}

pub fn default_severity(id: &str) -> Severity {
    RULES
        .iter()
        .find(|r| r.id == id)
        .map(|r| r.default_severity)
        .unwrap_or(Severity::Deny)
}

/// One tokenized source file plus the line-oriented views the rules need.
pub(crate) struct FileSrc {
    pub rel: String,
    pub lines: Vec<String>,
    /// Non-comment tokens, in order.
    pub code: Vec<Token>,
    /// Comment tokens only (suppressions, SAFETY detection).
    pub comments: Vec<Token>,
    /// Inclusive line spans of `#[cfg(test)]` items.
    pub test_spans: Vec<(u32, u32)>,
}

impl FileSrc {
    pub fn new(rel: String, content: &str) -> FileSrc {
        let all = tokenize(content);
        let mut code = Vec::new();
        let mut comments = Vec::new();
        for t in all {
            if t.kind == TokKind::Comment {
                comments.push(t);
            } else {
                code.push(t);
            }
        }
        let lines = content.lines().map(|l| l.to_string()).collect();
        let test_spans = find_test_spans(&code);
        FileSrc { rel, lines, code, comments, test_spans }
    }

    pub fn in_test(&self, line: u32) -> bool {
        self.test_spans.iter().any(|&(lo, hi)| lo <= line && line <= hi)
    }
}

/// Locate `#[cfg(test)]` items and return their inclusive line spans: the
/// attribute sequence `# [ cfg ( test ) ]`, any further attributes, then
/// the item's brace-matched body.
fn find_test_spans(code: &[Token]) -> Vec<(u32, u32)> {
    let txt = |k: usize| code.get(k).map(|t| t.text.as_str()).unwrap_or("");
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i < code.len() {
        let hit = txt(i) == "#"
            && txt(i + 1) == "["
            && txt(i + 2) == "cfg"
            && txt(i + 3) == "("
            && txt(i + 4) == "test"
            && txt(i + 5) == ")"
            && txt(i + 6) == "]";
        if !hit {
            i += 1;
            continue;
        }
        let start_line = code[i].line;
        let mut k = i + 7;
        // skip any further attributes on the same item
        while txt(k) == "#" && txt(k + 1) == "[" {
            let mut depth = 0i32;
            k += 1;
            while k < code.len() {
                match txt(k) {
                    "[" => depth += 1,
                    "]" => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                k += 1;
            }
            k += 1;
        }
        // find the item body: first `{` before any item-terminating `;`
        while k < code.len() && txt(k) != "{" && txt(k) != ";" {
            k += 1;
        }
        if txt(k) == "{" {
            let mut depth = 0i32;
            while k < code.len() {
                match txt(k) {
                    "{" => depth += 1,
                    "}" => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                k += 1;
            }
            let end_line = code.get(k).map(|t| t.line).unwrap_or(u32::MAX);
            spans.push((start_line, end_line));
        } else {
            spans.push((start_line, code.get(k).map(|t| t.line).unwrap_or(start_line)));
        }
        i = k.max(i + 7);
    }
    spans
}

/// Hash-typed binding/field names collected across the whole crate, so
/// `for k in self.index { ... }` is caught even when the `HashMap` type
/// annotation lives in another file.
pub(crate) fn collect_hash_names(files: &[FileSrc]) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for f in files {
        let code = &f.code;
        for (k, t) in code.iter().enumerate() {
            if t.kind != TokKind::Ident || (t.text != "HashMap" && t.text != "HashSet") {
                continue;
            }
            // pattern a: `name : [& mut path::]* HashMap<...>` (binding,
            // field, or parameter type annotation)
            let mut j = k;
            while j > 0 {
                let prev = &code[j - 1];
                let skip = prev.text == "::"
                    || prev.text == "&"
                    || prev.text == "mut"
                    || prev.kind == TokKind::Lifetime
                    || (prev.kind == TokKind::Ident && j >= 2 && code[j - 2].text == "::");
                if skip {
                    j -= 1;
                } else {
                    break;
                }
            }
            if j >= 2 && code[j - 1].text == ":" && code[j - 2].kind == TokKind::Ident {
                names.insert(code[j - 2].text.clone());
            }
            // pattern b: `let [mut] name = HashMap::new()` (inferred type)
            if j >= 2 && code[j - 1].text == "=" && code[j - 2].kind == TokKind::Ident {
                names.insert(code[j - 2].text.clone());
            }
        }
    }
    names
}

/// Float-typed binding names within one file (for D-FP-PARALLEL).
fn collect_float_names(f: &FileSrc) -> BTreeSet<String> {
    let code = &f.code;
    let mut names = BTreeSet::new();
    for (k, t) in code.iter().enumerate() {
        if t.kind != TokKind::Ident || (t.text != "f32" && t.text != "f64") {
            continue;
        }
        // `name : [& mut]* f32` — direct scalar annotations only
        let mut j = k;
        while j > 0 && (code[j - 1].text == "&" || code[j - 1].text == "mut") {
            j -= 1;
        }
        if j >= 2 && code[j - 1].text == ":" && code[j - 2].kind == TokKind::Ident {
            names.insert(code[j - 2].text.clone());
        }
    }
    // `let [mut] name = 1.0` / `= 0.5f32` — float-literal initializers
    for (k, t) in code.iter().enumerate() {
        if t.kind == TokKind::Num
            && is_float_literal(&t.text)
            && k >= 2
            && code[k - 1].text == "="
            && code[k - 2].kind == TokKind::Ident
        {
            names.insert(code[k - 2].text.clone());
        }
    }
    names
}

fn is_float_literal(text: &str) -> bool {
    text.contains('.') || text.ends_with("f32") || text.ends_with("f64")
}

/// Run every rule over `files` (whole-crate view) and return raw findings
/// (suppressions not yet applied), sorted by (path, line, col, rule).
pub(crate) fn scan(files: &[FileSrc], cfg: &LintConfig) -> Vec<Finding> {
    let hash_names = collect_hash_names(files);
    let mut out = Vec::new();
    for f in files {
        d_hash_iter(f, &hash_names, &mut out);
        d_env_threads(f, &mut out);
        d_wall_clock(f, cfg, &mut out);
        d_fp_parallel(f, &mut out);
        p_panic(f, cfg, &mut out);
        p_index_lit(f, cfg, &mut out);
        p_cast_narrow(f, cfg, &mut out);
        u_safety(f, &mut out);
    }
    dedupe_sort(out)
}

/// Sort and collapse duplicate (rule, path, line) findings — several
/// token patterns can hit the same construct (e.g. `for k in m.iter()`).
fn dedupe_sort(mut findings: Vec<Finding>) -> Vec<Finding> {
    findings.sort_by(|a, b| {
        (&a.path, a.line, a.col, a.rule).cmp(&(&b.path, b.line, b.col, b.rule))
    });
    findings.dedup_by(|a, b| a.rule == b.rule && a.path == b.path && a.line == b.line);
    findings
}

fn finding(rule: &'static str, f: &FileSrc, t: &Token, message: String) -> Finding {
    Finding {
        rule,
        severity: default_severity(rule),
        path: f.rel.clone(),
        line: t.line,
        col: t.col,
        message,
    }
}

const HASH_ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "retain",
    "into_iter",
    "into_keys",
    "into_values",
];

/// D-HASH-ITER: iteration over a `HashMap`/`HashSet` observes hash order.
fn d_hash_iter(f: &FileSrc, hash_names: &BTreeSet<String>, out: &mut Vec<Finding>) {
    let code = &f.code;
    let is_hashy = |t: &Token| {
        t.kind == TokKind::Ident
            && (t.text == "HashMap" || t.text == "HashSet" || hash_names.contains(&t.text))
    };
    for (k, t) in code.iter().enumerate() {
        if f.in_test(t.line) {
            continue;
        }
        // `recv.iter()` — receiver ident directly before the dot
        if t.kind == TokKind::Ident
            && HASH_ITER_METHODS.contains(&t.text.as_str())
            && k >= 2
            && code[k - 1].text == "."
            && code.get(k + 1).map(|n| n.text == "(").unwrap_or(false)
            && is_hashy(&code[k - 2])
        {
            out.push(finding(
                "D-HASH-ITER",
                f,
                t,
                format!("`{}.{}()` observes nondeterministic hash order", code[k - 2].text, t.text),
            ));
        }
        // `for pat in <expr with hash binding> {`
        if t.kind == TokKind::Ident && t.text == "for" {
            let mut j = k + 1;
            while j < code.len() && code[j].text != "{" && code[j].text != ";" {
                if is_hashy(&code[j]) {
                    out.push(finding(
                        "D-HASH-ITER",
                        f,
                        &code[j],
                        format!("for-loop over hash collection `{}`", code[j].text),
                    ));
                    break;
                }
                j += 1;
            }
        }
    }
}

/// The env-var name D-ENV-THREADS hunts for. Kept in one const so the
/// rule's own source carries a single suppressed occurrence of it.
// lint:allow(D-ENV-THREADS): the rule's own needle
const ENV_NEEDLE: &str = "ORCS_THREADS";

/// D-ENV-THREADS: the thread-count env var has exactly one blessed
/// reader (`parallel::num_threads`); any other mention in code is a leak.
fn d_env_threads(f: &FileSrc, out: &mut Vec<Finding>) {
    if f.rel == "parallel.rs" || f.rel.ends_with("/parallel.rs") {
        return;
    }
    for t in &f.code {
        if t.kind == TokKind::Str && t.text.contains(ENV_NEEDLE) && !f.in_test(t.line) {
            out.push(finding(
                "D-ENV-THREADS",
                f,
                t,
                format!("{ENV_NEEDLE} must only be read by parallel::num_threads()"),
            ));
        }
    }
}

/// D-WALL-CLOCK: wall-clock and thread-identity sources in det paths.
fn d_wall_clock(f: &FileSrc, cfg: &LintConfig, out: &mut Vec<Finding>) {
    if !LintConfig::in_scope(&f.rel, &cfg.det_path) {
        return;
    }
    let code = &f.code;
    for (k, t) in code.iter().enumerate() {
        if t.kind != TokKind::Ident || f.in_test(t.line) {
            continue;
        }
        if t.text == "Instant" || t.text == "SystemTime" {
            out.push(finding(
                "D-WALL-CLOCK",
                f,
                t,
                format!("`{}` in a determinism path (use the simulated timing model)", t.text),
            ));
        }
        if t.text == "thread"
            && code.get(k + 1).map(|n| n.text == "::").unwrap_or(false)
            && code.get(k + 2).map(|n| n.text == "current").unwrap_or(false)
        {
            out.push(finding(
                "D-WALL-CLOCK",
                f,
                t,
                "thread identity in a determinism path".to_string(),
            ));
        }
    }
}

const PARALLEL_ENTRYPOINTS: &[&str] =
    &["parallel_for_chunks", "parallel_for_chunks_grained", "parallel_for_dynamic"];

/// D-FP-PARALLEL: `+=`/`-=` on float state inside a closure passed to an
/// unordered `parallel_*` entry point. Float accumulation must go through
/// a chunk-ordered merge (`parallel_chunk_map` + ordered fold) instead.
fn d_fp_parallel(f: &FileSrc, out: &mut Vec<Finding>) {
    if f.rel == "parallel.rs" || f.rel.ends_with("/parallel.rs") {
        return; // the library's own internals are the ordered-merge machinery
    }
    let float_names = collect_float_names(f);
    let code = &f.code;
    for (k, t) in code.iter().enumerate() {
        if t.kind != TokKind::Ident
            || !PARALLEL_ENTRYPOINTS.contains(&t.text.as_str())
            || !code.get(k + 1).map(|n| n.text == "(").unwrap_or(false)
            || f.in_test(t.line)
        {
            continue;
        }
        // span of the call's argument list
        let mut depth = 0i32;
        let mut end = k + 1;
        for (j, tj) in code.iter().enumerate().skip(k + 1) {
            match tj.text.as_str() {
                "(" => depth += 1,
                ")" => {
                    depth -= 1;
                    if depth == 0 {
                        end = j;
                        break;
                    }
                }
                _ => {}
            }
        }
        for (j, tj) in code.iter().enumerate().take(end).skip(k + 1) {
            if tj.text != "+=" && tj.text != "-=" {
                continue;
            }
            // the accumulation statement: previous stmt boundary → next `;`
            let stmt_start = (0..j)
                .rev()
                .find(|&s| matches!(code[s].text.as_str(), ";" | "{" | "}"))
                .map(|s| s + 1)
                .unwrap_or(0);
            let stmt_end = (j..end).find(|&s| code[s].text == ";").unwrap_or(end);
            let is_float = code[stmt_start..stmt_end].iter().enumerate().any(|(off, s)| {
                let idx = stmt_start + off;
                (s.kind == TokKind::Num && is_float_literal(&s.text))
                    || (s.kind == TokKind::Ident && float_names.contains(&s.text))
                    || (s.kind == TokKind::Ident
                        && s.text == "as"
                        && code
                            .get(idx + 1)
                            .map(|n| n.text == "f32" || n.text == "f64")
                            .unwrap_or(false))
            });
            if is_float {
                out.push(finding(
                    "D-FP-PARALLEL",
                    f,
                    tj,
                    format!(
                        "float accumulation inside `{}` closure; route partials through a \
                         chunk-ordered merge",
                        t.text
                    ),
                ));
            }
        }
    }
}

const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

/// P-PANIC: panicking constructs in code reachable from `Backend::step`.
fn p_panic(f: &FileSrc, cfg: &LintConfig, out: &mut Vec<Finding>) {
    if !LintConfig::in_scope(&f.rel, &cfg.step_path) {
        return;
    }
    let code = &f.code;
    for (k, t) in code.iter().enumerate() {
        if t.kind != TokKind::Ident || f.in_test(t.line) {
            continue;
        }
        let method_call = k >= 1
            && code[k - 1].text == "."
            && code.get(k + 1).map(|n| n.text == "(").unwrap_or(false);
        if (t.text == "unwrap" || t.text == "expect") && method_call {
            out.push(finding(
                "P-PANIC",
                f,
                t,
                format!(".{}() in a step path; return SimError instead", t.text),
            ));
        }
        if PANIC_MACROS.contains(&t.text.as_str())
            && code.get(k + 1).map(|n| n.text == "!").unwrap_or(false)
        {
            out.push(finding(
                "P-PANIC",
                f,
                t,
                format!("{}! in a step path; return SimError instead", t.text),
            ));
        }
    }
}

/// P-INDEX-LIT: `expr[0]`-style literal indexing in step paths.
fn p_index_lit(f: &FileSrc, cfg: &LintConfig, out: &mut Vec<Finding>) {
    if !LintConfig::in_scope(&f.rel, &cfg.step_path) {
        return;
    }
    let code = &f.code;
    for (k, t) in code.iter().enumerate() {
        if t.text != "[" || k == 0 || f.in_test(t.line) {
            continue;
        }
        let prev = &code[k - 1];
        let indexable = (prev.kind == TokKind::Ident && prev.text != "mut")
            || prev.text == ")"
            || prev.text == "]";
        let lit_index = code.get(k + 1).map(|n| n.kind == TokKind::Num).unwrap_or(false)
            && code.get(k + 2).map(|n| n.text == "]").unwrap_or(false);
        if indexable && lit_index {
            out.push(finding(
                "P-INDEX-LIT",
                f,
                t,
                format!(
                    "literal index `[{}]` in a step path; prefer get()/first()",
                    code[k + 1].text
                ),
            ));
        }
    }
}

const NARROW_TARGETS: &[&str] = &["u8", "u16", "u32", "i8", "i16", "i32"];

/// P-CAST-NARROW: `(...) as u32`-style narrowing in CSR offset/merge code.
fn p_cast_narrow(f: &FileSrc, cfg: &LintConfig, out: &mut Vec<Finding>) {
    if !LintConfig::in_scope(&f.rel, &cfg.csr_path) {
        return;
    }
    let code = &f.code;
    for (k, t) in code.iter().enumerate() {
        if t.kind != TokKind::Ident || t.text != "as" || k == 0 || f.in_test(t.line) {
            continue;
        }
        let after_call = code[k - 1].text == ")";
        let target = code.get(k + 1).map(|n| n.text.clone()).unwrap_or_default();
        if after_call && NARROW_TARGETS.contains(&target.as_str()) {
            out.push(finding(
                "P-CAST-NARROW",
                f,
                t,
                format!("`as {target}` may truncate a CSR offset; justify or use try_from"),
            ));
        }
    }
}

/// U-SAFETY: every line containing `unsafe` must be covered by a SAFETY
/// comment — on the same line, or directly above it (walking up through
/// comment runs, attributes, statement continuations, and earlier lines
/// of the same unsafe construct). Applies everywhere, tests included.
fn u_safety(f: &FileSrc, out: &mut Vec<Finding>) {
    let mut seen = BTreeSet::new();
    for t in &f.code {
        if t.kind != TokKind::Ident || t.text != "unsafe" || !seen.insert(t.line) {
            continue;
        }
        if !safety_covered(&f.lines, t.line) {
            out.push(finding(
                "U-SAFETY",
                f,
                t,
                "unsafe without an immediately preceding `// SAFETY:` comment".to_string(),
            ));
        }
    }
}

fn is_safety_text(line: &str) -> bool {
    line.contains("SAFETY:") || line.contains("# Safety")
}

fn safety_covered(lines: &[String], unsafe_line: u32) -> bool {
    let idx = (unsafe_line as usize).saturating_sub(1);
    if lines.get(idx).map(|l| is_safety_text(l)).unwrap_or(false) {
        return true;
    }
    let mut k = idx;
    while k > 0 {
        k -= 1;
        let t = lines[k].trim();
        if t.is_empty() {
            return false;
        }
        if t.starts_with("//") || t.starts_with("/*") || t.starts_with('*') {
            if is_safety_text(t) {
                return true;
            }
            continue; // comment run — keep walking up
        }
        if t.starts_with("#[") || t.starts_with("#!") {
            continue; // attribute between the comment and the item
        }
        if t.contains("unsafe") {
            continue; // an earlier line of the same unsafe construct
        }
        if !(t.ends_with(';') || t.ends_with('{') || t.ends_with('}')) {
            continue; // statement continuation, e.g. `let sub =`
        }
        return false; // unrelated complete statement — not covered
    }
    false
}
