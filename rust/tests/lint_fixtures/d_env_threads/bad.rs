// Fixture: seeded D-ENV-THREADS violation (env read outside parallel.rs).
pub fn worker_count() -> usize {
    std::env::var("ORCS_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(1)
}
