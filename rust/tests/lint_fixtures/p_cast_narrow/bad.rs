// Fixture: seeded P-CAST-NARROW violation (silent truncation of a CSR
// offset computation).
pub fn total_bytes(lens: &[u32]) -> u32 {
    (lens.len() * 4) as u32
}
