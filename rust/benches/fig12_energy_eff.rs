//! `cargo bench --bench fig12_energy_eff [-- --quick]`
//! Alias of fig11_power: one run feeds both figures (see fig11_12.rs).
fn main() {
    let opts = orcs::benchsuite::common::BenchOpts::from_env().expect("bench options");
    orcs::benchsuite::fig11_12::run(&opts).expect("fig12 bench");
}
