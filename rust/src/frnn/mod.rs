//! FRNN simulation backends: the paper's five approaches plus shared
//! infrastructure.
//!
//! | Backend | Paper §4.2 name | Pipeline |
//! |---|---|---|
//! | [`cell_list::CpuCell`] | CPU-CELL@64c | parallel cell-list sweep on the host |
//! | [`gpu_cell::GpuCell`]  | GPU-CELL | z-order radix sort + grid + sweep (GPU model) |
//! | [`rt_ref::RtRef`]      | RT-REF | RT traversal → neighbor list → force kernel |
//! | [`orcs_forces::OrcsForces`] | ORCS-forces | in-shader symmetric force scatter |
//! | [`orcs_perse::OrcsPerse`]   | ORCS-persé | payload accumulation, whole step in RT |
//!
//! Backends fill [`OpCounts`] (priced by [`crate::rtcore::timing`]) and use
//! the [`PhysicsKernels`] abstraction for the "separate compute kernel"
//! stages, which the coordinator binds to either the PJRT/XLA runtime or
//! the pure-Rust oracle.

pub mod brute;
pub mod cell_list;
pub mod gamma;
pub mod gpu_cell;
pub mod orcs_forces;
pub mod orcs_perse;
pub mod rt_common;
pub mod rt_ref;
pub mod zorder;

use crate::core::vec3::Vec3;
use crate::gradient::BvhAction;
use crate::physics::state::SimState;
use crate::resilience::SimResult;
use crate::rtcore::{HwProfile, OpCounts};

/// Compressed sparse-row neighbor lists: neighbors of particle `i` are
/// `items[offsets[i]..offsets[i+1]]`.
#[derive(Clone, Debug, Default)]
pub struct NeighborLists {
    pub offsets: Vec<u32>,
    pub items: Vec<u32>,
}

impl NeighborLists {
    pub fn from_vecs(lists: &[Vec<u32>]) -> Self {
        let mut offsets = Vec::with_capacity(lists.len() + 1);
        let total: usize = lists.iter().map(|l| l.len()).sum();
        let mut items = Vec::with_capacity(total);
        offsets.push(0u32);
        for l in lists {
            items.extend_from_slice(l);
            offsets.push(items.len() as u32);
        }
        NeighborLists { offsets, items }
    }

    pub fn n(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    pub fn neighbors(&self, i: usize) -> &[u32] {
        &self.items[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }

    pub fn total_entries(&self) -> usize {
        self.items.len()
    }

    /// Longest per-particle list (the paper's `k_max`, which sizes the
    /// fixed-slot GPU allocation `n * k_max * 4` bytes).
    pub fn k_max(&self) -> usize {
        (0..self.n()).map(|i| self.neighbors(i).len()).max().unwrap_or(0)
    }

    /// Sort every per-particle segment ascending by neighbor id — the
    /// *canonical* list order. Downstream force kernels sum contributions in
    /// list order, so canonical ordering makes the f32 accumulation
    /// independent of discovery order; it is what lets the sharded engine
    /// ([`crate::shard`]) reproduce the single-domain forces bit for bit
    /// (and it matches the ascending-id order of the brute-force oracle).
    /// Segments are disjoint, so they sort in parallel.
    pub fn sort_segments(&mut self, threads: usize) {
        let n = self.n();
        let offsets = &self.offsets;
        let items_ptr = crate::parallel::SendPtr(self.items.as_mut_ptr());
        crate::parallel::parallel_for_chunks_grained(n, threads, 512, |_, range| {
            for i in range {
                let lo = offsets[i] as usize;
                let hi = offsets[i + 1] as usize;
                // SAFETY: CSR segments are disjoint; each one is sorted by
                // exactly one worker.
                let seg =
                    unsafe { std::slice::from_raw_parts_mut(items_ptr.0.add(lo), hi - lo) };
                seg.sort_unstable();
            }
        });
    }
}

/// The "separate GPU compute kernel" stages of the pipelines. Bound to the
/// PJRT/XLA runtime ([`crate::runtime::XlaKernels`]) or the pure-Rust
/// reference ([`RustKernels`]).
pub trait PhysicsKernels: Send + Sync {
    /// Gather-style LJ force evaluation over neighbor lists; returns the
    /// per-particle total force. Displacements are minimum-imaged when the
    /// state is periodic.
    fn lj_forces(
        &self,
        state: &SimState,
        lists: &NeighborLists,
        counts: &mut OpCounts,
    ) -> anyhow::Result<Vec<Vec3>>;

    /// Advance positions/velocities one step from `state.force`, applying
    /// boundary conditions.
    fn integrate(&self, state: &mut SimState, counts: &mut OpCounts) -> anyhow::Result<()>;

    fn name(&self) -> &'static str;
}

/// Pure-Rust reference kernels (also the test oracle for the XLA path).
pub struct RustKernels {
    pub threads: usize,
}

impl PhysicsKernels for RustKernels {
    fn lj_forces(
        &self,
        state: &SimState,
        lists: &NeighborLists,
        counts: &mut OpCounts,
    ) -> anyhow::Result<Vec<Vec3>> {
        let n = state.n();
        let forces = crate::parallel::parallel_map(n, self.threads, |i| {
            let mut f = Vec3::ZERO;
            for &j in lists.neighbors(i) {
                let j = j as usize;
                let dx = crate::physics::boundary::displacement(
                    state.pos[i],
                    state.pos[j],
                    state.boundary,
                    state.box_l,
                );
                if let Some(fij) =
                    state.params.pair_force(dx, state.radius[i], state.radius[j])
                {
                    f += fij;
                }
            }
            f
        });
        // force_kernel_pairs is charged by the *caller* (RT-REF prices the
        // fixed-slot n x k_max layout of the paper, not the CSR entries)
        counts.kernel_launches += 1;
        Ok(forces)
    }

    fn integrate(&self, state: &mut SimState, counts: &mut OpCounts) -> anyhow::Result<()> {
        crate::physics::integrator::step(state);
        counts.integrate_particles += state.n() as u64;
        counts.kernel_launches += 1;
        Ok(())
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

/// Wall-clock seconds per pipeline phase (real, measured).
#[derive(Clone, Copy, Debug, Default)]
pub struct WallPhases {
    pub bvh: f64,
    pub search: f64,
    pub force: f64,
    pub integrate: f64,
}

impl WallPhases {
    pub fn total(&self) -> f64 {
        self.bvh + self.search + self.force + self.integrate
    }
}

/// Result of one backend step.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepResult {
    pub counts: OpCounts,
    /// BVH action taken, for RT backends.
    pub bvh_action: Option<BvhAction>,
    /// Set when the step would exceed device memory (required bytes).
    pub oom_bytes: Option<u64>,
    pub wall: WallPhases,
}

/// Per-step execution context handed to backends by the coordinator.
pub struct StepCtx<'a> {
    pub threads: usize,
    pub kernels: &'a dyn PhysicsKernels,
    /// Hardware profile used to price this backend's ops (GPU for the RT
    /// and GPU-CELL backends, EPYC for CPU-CELL) — feeds the BVH policy's
    /// simulated clock and the OOM check.
    pub hw: &'static HwProfile,
    /// Enforce the device-memory limit (RT-REF neighbor list, §4.2).
    pub check_oom: bool,
    /// Injected VRAM-budget squeeze (resilience harness): when set, the
    /// usable device memory is `min(hw.vram_bytes, budget)`.
    pub vram_budget: Option<u64>,
}

impl StepCtx<'_> {
    /// Usable device memory after any injected squeeze.
    pub fn effective_vram(&self) -> u64 {
        self.vram_budget.map_or(self.hw.vram_bytes, |b| b.min(self.hw.vram_bytes))
    }
}

/// A full FRNN simulation backend.
pub trait Backend: Send {
    fn name(&self) -> &'static str;

    /// Check whether this backend supports the scenario (e.g. ORCS-persé
    /// requires a uniform radius).
    fn supports(&self, state: &SimState) -> Result<(), String> {
        let _ = state;
        Ok(())
    }

    /// Execute one simulation step: find neighbors, compute forces,
    /// advance particles; fill counters and wall times. Failures are
    /// classified through the [`crate::resilience::SimError`] taxonomy so
    /// the resilient engines can degrade, retry or recover.
    fn step(&mut self, state: &mut SimState, ctx: &mut StepCtx) -> SimResult<StepResult>;

    /// Drop any cached acceleration structure so the next step rebuilds
    /// from scratch (watchdog recovery). No-op for cell backends.
    fn invalidate_bvh(&mut self) {}
}

/// Backend identifiers (CLI + bench matrix).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ApproachKind {
    CpuCell,
    GpuCell,
    RtRef,
    OrcsForces,
    OrcsPerse,
}

impl ApproachKind {
    pub const ALL: [ApproachKind; 5] = [
        ApproachKind::CpuCell,
        ApproachKind::GpuCell,
        ApproachKind::RtRef,
        ApproachKind::OrcsForces,
        ApproachKind::OrcsPerse,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            ApproachKind::CpuCell => "CPU-CELL@64c",
            ApproachKind::GpuCell => "GPU-CELL",
            ApproachKind::RtRef => "RT-REF",
            ApproachKind::OrcsForces => "ORCS-forces",
            ApproachKind::OrcsPerse => "ORCS-perse",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "cpu-cell" | "cpucell" | "cpu" => Some(Self::CpuCell),
            "gpu-cell" | "gpucell" => Some(Self::GpuCell),
            "rt-ref" | "rtref" => Some(Self::RtRef),
            "orcs-forces" | "forces" => Some(Self::OrcsForces),
            "orcs-perse" | "perse" => Some(Self::OrcsPerse),
            _ => None,
        }
    }

    /// True for backends that maintain a BVH (and therefore take a rebuild
    /// policy).
    pub fn is_rt(&self) -> bool {
        matches!(self, Self::RtRef | Self::OrcsForces | Self::OrcsPerse)
    }

    /// Instantiate the backend. `policy_spec` selects the BVH rebuild
    /// policy for RT backends (`gradient`, `avg`, `fixed-K`).
    pub fn create(&self, policy_spec: &str) -> anyhow::Result<Box<dyn Backend>> {
        let policy = || {
            crate::gradient::policy::parse_policy(policy_spec)
                .ok_or_else(|| anyhow::anyhow!("unknown BVH policy: {policy_spec}"))
        };
        Ok(match self {
            ApproachKind::CpuCell => Box::new(cell_list::CpuCell::new()),
            ApproachKind::GpuCell => Box::new(gpu_cell::GpuCell::new()),
            ApproachKind::RtRef => Box::new(rt_ref::RtRef::new(policy()?)),
            ApproachKind::OrcsForces => Box::new(orcs_forces::OrcsForces::new(policy()?)),
            ApproachKind::OrcsPerse => Box::new(orcs_perse::OrcsPerse::new(policy()?)),
        })
    }
}

impl std::fmt::Display for ApproachKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csr_roundtrip() {
        let lists = vec![vec![1u32, 2], vec![], vec![0, 1, 3], vec![2]];
        let nl = NeighborLists::from_vecs(&lists);
        assert_eq!(nl.n(), 4);
        assert_eq!(nl.neighbors(0), &[1, 2]);
        assert_eq!(nl.neighbors(1), &[] as &[u32]);
        assert_eq!(nl.neighbors(2), &[0, 1, 3]);
        assert_eq!(nl.total_entries(), 6);
        assert_eq!(nl.k_max(), 3);
    }

    #[test]
    fn sort_segments_canonicalizes_each_list() {
        let lists = vec![vec![9u32, 1, 4], vec![], vec![7, 0], vec![3]];
        let mut nl = NeighborLists::from_vecs(&lists);
        for threads in [1, 4] {
            let mut s = nl.clone();
            s.sort_segments(threads);
            assert_eq!(s.neighbors(0), &[1, 4, 9]);
            assert_eq!(s.neighbors(1), &[] as &[u32]);
            assert_eq!(s.neighbors(2), &[0, 7]);
            assert_eq!(s.neighbors(3), &[3]);
            assert_eq!(s.offsets, nl.offsets, "offsets untouched");
        }
        nl.sort_segments(2);
        assert_eq!(nl.k_max(), 3);
    }

    #[test]
    fn approach_parse_and_labels() {
        assert_eq!(ApproachKind::parse("rt-ref"), Some(ApproachKind::RtRef));
        assert_eq!(ApproachKind::parse("perse"), Some(ApproachKind::OrcsPerse));
        assert!(ApproachKind::parse("nope").is_none());
        assert!(ApproachKind::RtRef.is_rt());
        assert!(!ApproachKind::CpuCell.is_rt());
        assert_eq!(ApproachKind::ALL.len(), 5);
    }
}
