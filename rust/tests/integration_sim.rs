//! Multi-step simulation invariants: stability, boundary containment,
//! momentum behavior, interaction accounting and cross-backend trajectory
//! agreement over longer horizons.

use std::sync::Arc;

use orcs::coordinator::{Engine, EngineConfig};
use orcs::core::config::{Boundary, ParticleDist, RadiusDist, SimConfig};
use orcs::frnn::{ApproachKind, RustKernels};

fn engine(cfg: &SimConfig, approach: ApproachKind, policy: &str) -> Engine {
    let ec = EngineConfig {
        policy: policy.into(),
        threads: 2,
        check_oom: false,
        ..EngineConfig::new(cfg.clone(), approach)
    };
    Engine::new(ec, Arc::new(RustKernels { threads: 2 })).unwrap()
}

fn dense_cfg(boundary: Boundary) -> SimConfig {
    SimConfig {
        n: 300,
        box_l: 60.0,
        particle_dist: ParticleDist::Cluster,
        radius_dist: RadiusDist::Const(5.0),
        boundary,
        seed: 11,
        ..SimConfig::default()
    }
}

#[test]
fn long_run_stays_finite_and_contained() {
    for boundary in Boundary::ALL {
        for approach in [ApproachKind::OrcsForces, ApproachKind::CpuCell] {
            let mut e = engine(&dense_cfg(boundary), approach, "gradient");
            e.run(60, false).unwrap();
            assert!(e.state.is_finite(), "{approach} {boundary}");
            assert!(e.state.all_in_box(), "{approach} {boundary}");
            assert_eq!(e.state.step_count, 60);
        }
    }
}

#[test]
fn momentum_drift_bounded_in_periodic_box() {
    // Pair forces are exactly antisymmetric, so momentum is conserved as
    // long as the *total-force* cap in the integrator never engages (the
    // cap is per-particle and breaks symmetry by design — a stability
    // valve). Use a moderate gas where forces stay far below f_max.
    let cfg = SimConfig {
        n: 400,
        box_l: 120.0,
        particle_dist: ParticleDist::Disordered,
        radius_dist: RadiusDist::Const(8.0),
        boundary: Boundary::Periodic,
        seed: 13,
        f_max: 1e9, // effectively uncapped
        ..SimConfig::default()
    };
    let mut e = engine(&cfg, ApproachKind::OrcsForces, "fixed-10");
    let p0 = e.state.total_momentum();
    e.run(40, false).unwrap();
    let p1 = e.state.total_momentum();
    let drift = (p1 - p0).norm();
    assert!(drift < 1.0, "momentum drift {drift}");
}

#[test]
fn interactions_grow_when_cluster_collapses_then_relax() {
    // a dense LJ cluster first interacts intensely, then the repulsion term
    // spreads it out (paper §3: "the system stabilizes thanks to the
    // repulsion term")
    let mut e = engine(&dense_cfg(Boundary::Wall), ApproachKind::OrcsForces, "gradient");
    let first = e.step().unwrap().interactions;
    e.run(80, false).unwrap();
    let last = e.step().unwrap().interactions;
    assert!(first > 0);
    assert!(
        last <= first,
        "interactions should not grow after relaxation: first={first} last={last}"
    );
}

#[test]
fn deterministic_across_identical_runs() {
    let cfg = dense_cfg(Boundary::Periodic);
    let run = |threads: usize| {
        let ec = EngineConfig {
            policy: "gradient".into(),
            threads,
            check_oom: false,
            ..EngineConfig::new(cfg.clone(), ApproachKind::RtRef)
        };
        let mut e = Engine::new(ec, Arc::new(RustKernels { threads })).unwrap();
        e.run(10, false).unwrap();
        e.state.pos.clone()
    };
    let a = run(2);
    let b = run(2);
    assert_eq!(a, b, "same thread count must be bitwise deterministic");
}

#[test]
fn simulated_times_track_interaction_load() {
    // r=1 (nearly no interactions) must be much cheaper than r=10 (dense).
    // n must be large enough that per-step work dominates the fixed
    // kernel-launch overhead in the GPU timing model (as in the paper,
    // which runs 50k-1M particles for exactly this reason).
    let base = SimConfig {
        n: 12_000,
        box_l: 60.0,
        particle_dist: ParticleDist::Disordered,
        boundary: Boundary::Periodic,
        seed: 17,
        ..SimConfig::default()
    };
    let dense_cfg = SimConfig { radius_dist: RadiusDist::Const(10.0), ..base.clone() };
    let cheap_cfg = SimConfig { radius_dist: RadiusDist::Const(1.0), ..base };
    let mut dense = engine(&dense_cfg, ApproachKind::RtRef, "gradient");
    let mut cheap = engine(&cheap_cfg, ApproachKind::RtRef, "gradient");
    let sd = dense.run(5, false).unwrap();
    let sc = cheap.run(5, false).unwrap();
    assert!(
        sd.avg_sim_ms > 2.0 * sc.avg_sim_ms,
        "dense {} vs cheap {}",
        sd.avg_sim_ms,
        sc.avg_sim_ms
    );
}

#[test]
fn wall_vs_periodic_differ_near_boundaries() {
    // the same initial scene must evolve differently under the two BCs when
    // particles sit near the walls
    let mut cfg = SimConfig {
        n: 200,
        box_l: 50.0,
        particle_dist: ParticleDist::Disordered,
        radius_dist: RadiusDist::Const(8.0),
        seed: 5,
        ..SimConfig::default()
    };
    cfg.boundary = Boundary::Wall;
    let mut ew = engine(&cfg, ApproachKind::OrcsForces, "fixed-5");
    cfg.boundary = Boundary::Periodic;
    let mut ep = engine(&cfg, ApproachKind::OrcsForces, "fixed-5");
    ew.run(10, false).unwrap();
    ep.run(10, false).unwrap();
    let diff = (0..200)
        .map(|i| (ew.state.pos[i] - ep.state.pos[i]).norm())
        .fold(0.0f32, f32::max);
    assert!(diff > 1e-4, "BC modes produced identical trajectories");
}
