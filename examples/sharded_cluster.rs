//! Sharded domain decomposition end to end — the paper's log-normal
//! cluster memory story, continued past one device.
//!
//! Phase A reproduces the single-device failure: a Cluster + log-normal
//! scene whose RT-REF fixed-slot neighbor allocation (`n · k_max · 4` with
//! `k_max → n`) exceeds a small device's memory — `check_oom` aborts, the
//! paper's OOM cells. Phase B runs the *same scene* through the sharded
//! engine on a 2×2×2 grid of the same small device: ownership divides the
//! cluster across eight subdomains, each device meters only its own owned
//! lists, and the run completes. Phase C shows the per-shard gradient
//! policies diverging on a hot/cold workload, and phase D steps a
//! heterogeneous TITAN RTX + L40 fleet (step time = straggler device,
//! energy = fleet sum).
//!
//! ```sh
//! cargo run --release --example sharded_cluster
//! ```

use std::sync::Arc;

use orcs::benchsuite::common::BenchOpts;
use orcs::benchsuite::sharded::{center_positions, hot_cold_engine, SMALL_VRAM};
use orcs::core::config::{Boundary, ParticleDist, RadiusDist, ShardSpec, SimConfig};
use orcs::frnn::RustKernels;
use orcs::rtcore::profile::{L40, TITANRTX};
use orcs::rtcore::HwProfile;
use orcs::shard::{ShardedConfig, ShardedEngine};

fn cluster_engine(
    n: usize,
    spec: ShardSpec,
    fleet: Vec<&'static HwProfile>,
) -> anyhow::Result<ShardedEngine> {
    let sim = SimConfig {
        n,
        box_l: 1000.0,
        particle_dist: ParticleDist::Cluster,
        radius_dist: RadiusDist::LogNormal { mu: 1.0, sigma: 2.0, lo: 1.0, hi: 330.0 },
        boundary: Boundary::Periodic,
        seed: 31415,
        ..SimConfig::default()
    };
    let threads = orcs::parallel::num_threads();
    let cfg = ShardedConfig {
        policy: "gradient".into(),
        fleet,
        threads,
        check_oom: true,
        ..ShardedConfig::new(sim, spec)
    };
    let mut engine = ShardedEngine::new(cfg, Arc::new(RustKernels { threads }))?;
    // put the dense core on the box center so the 2x2x2 grid splits it
    center_positions(&mut engine.state);
    Ok(engine)
}

fn main() -> anyhow::Result<()> {
    let n = 1_500;
    println!("=== sharded: Cluster + LogNormal radii, periodic BC (n={n}) ===\n");

    // ---- Phase A: one device, one domain -> OOM ----
    println!("[phase A] single domain on {} ({} B VRAM)", SMALL_VRAM.name, SMALL_VRAM.vram_bytes);
    let mut single = cluster_engine(n, ShardSpec::new(1), vec![&SMALL_VRAM])?;
    let a = single.run(4, false)?;
    assert!(a.oom, "expected the single-domain fixed-slot list to exceed VRAM");
    println!(
        "  OOM on step {}: list would need {} bytes ({}x the device)\n",
        a.steps, a.oom_bytes, a.oom_bytes / SMALL_VRAM.vram_bytes.max(1),
    );

    // ---- Phase B: the same scene, 2x2x2 sharded, same small device ----
    println!("[phase B] 2x2x2 shards, one {} per shard", SMALL_VRAM.name);
    let mut sharded = cluster_engine(n, ShardSpec::new(2), vec![&SMALL_VRAM])?;
    let b = sharded.run(30, false)?;
    assert!(!b.oom, "sharded run must fit per-device");
    assert_eq!(b.steps, 30);
    assert!(sharded.state.is_finite());
    let max_bytes = b.per_shard.iter().map(|t| t.max_list_bytes).max().unwrap_or(0);
    println!(
        "  completed {} steps | avg step {:.4} ms | EE {:.1} int/J",
        b.steps, b.avg_sim_ms, b.ee
    );
    println!(
        "  max per-shard list {} bytes (vs {} single-domain): the paper's\n  \"would otherwise not fit in memory\" scenes complete sharded",
        max_bytes, a.oom_bytes,
    );
    println!("  shard | owned | ghosts | builds | updates | k_max");
    for (k, t) in b.per_shard.iter().enumerate() {
        println!(
            "  {:>5} | {:>5.0} | {:>6.0} | {:>6} | {:>7} | {:>6}",
            k,
            t.owned_sum as f64 / b.steps as f64,
            t.ghosts_sum as f64 / b.steps as f64,
            t.builds,
            t.updates,
            t.max_k_max,
        );
    }

    // ---- Phase C: per-shard gradient policies on a hot/cold workload ----
    println!("\n[phase C] hot/cold slab: per-shard gradient update/rebuild ratios");
    let threads = orcs::parallel::num_threads();
    let opts = BenchOpts {
        threads,
        hw: orcs::rtcore::profile::DEFAULT_GPU,
        kernels: Arc::new(RustKernels { threads }),
        quick: false,
        steps_override: None,
        n_override: None,
        seed: 0xC0FFEE,
    };
    let mut hc = hot_cold_engine(&opts, 3_000)?;
    let c = hc.run(12, false)?;
    for (k, t) in c.per_shard.iter().enumerate() {
        println!(
            "  shard {k} ({}) : {} builds ({} forced), {} updates -> {:.2} upd/build",
            if k % 2 == 1 { "hot " } else { "cold" },
            t.builds,
            t.forced_builds,
            t.updates,
            t.update_ratio(),
        );
    }
    let cold_updates: u64 = c
        .per_shard
        .iter()
        .enumerate()
        .filter(|(k, _)| k % 2 == 0)
        .map(|(_, t)| t.updates)
        .sum();
    assert!(cold_updates > 0, "cold shards must refit");

    // ---- Phase D: heterogeneous fleet ----
    println!("\n[phase D] heterogeneous fleet: TITANRTX + L40 round-robin on 2x2x2");
    let mut fleet = cluster_engine(n, ShardSpec::new(2), vec![&TITANRTX, &L40])?;
    let mut straggles = [0u64; 8];
    for _ in 0..8 {
        let rec = fleet.step()?;
        straggles[rec.straggler] += 1;
    }
    let d = fleet.run(4, false)?;
    println!(
        "  fleet {} | avg step {:.4} ms (straggler-gated) | {:.3} J total",
        d.fleet, d.avg_sim_ms, d.total_energy_j,
    );
    for (k, hits) in straggles.iter().enumerate() {
        if *hits > 0 {
            println!("  shard {k} ({}) gated {hits} of 8 steps", fleet.shard_hw(k).name);
        }
    }
    assert!(fleet.state.is_finite());

    println!("\nsharded e2e OK: OOM relief, per-shard policies and fleet pricing all exercised.");
    Ok(())
}
