//! Report rendering: CSV traces and aligned text tables for the bench
//! suite (the offline vendor set has no serde, so emission is by hand).

use std::fs;
use std::io::Write;
use std::path::Path;

use anyhow::Result;

/// Minimal CSV writer (quotes fields containing separators).
pub struct CsvWriter {
    out: fs::File,
    cols: usize,
}

impl CsvWriter {
    pub fn create(path: &Path, header: &[&str]) -> Result<Self> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut out = fs::File::create(path)?;
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, cols: header.len() })
    }

    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        anyhow::ensure!(fields.len() == self.cols, "row width mismatch");
        let line: Vec<String> = fields
            .iter()
            .map(|f| {
                if f.contains(',') || f.contains('"') {
                    format!("\"{}\"", f.replace('"', "\"\""))
                } else {
                    f.clone()
                }
            })
            .collect();
        writeln!(self.out, "{}", line.join(","))?;
        Ok(())
    }
}

/// Aligned plain-text table (paper-style rows printed by the benches).
#[derive(Default)]
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(header: &[&str]) -> Self {
        TextTable { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, fields: Vec<String>) {
        self.rows.push(fields);
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (c, f) in row.iter().enumerate().take(ncols) {
                widths[c] = widths[c].max(f.len());
            }
        }
        let mut s = String::new();
        let fmt_row = |fields: &[String], widths: &[usize]| -> String {
            fields
                .iter()
                .enumerate()
                .map(|(c, f)| format!("{:>w$}", f, w = widths.get(c).copied().unwrap_or(8)))
                .collect::<Vec<_>>()
                .join("  ")
        };
        s.push_str(&fmt_row(&self.header, &widths));
        s.push('\n');
        s.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        s.push('\n');
        for row in &self.rows {
            s.push_str(&fmt_row(row, &widths));
            s.push('\n');
        }
        s
    }
}

/// Results directory (env override `ORCS_RESULTS`).
pub fn results_dir() -> std::path::PathBuf {
    std::env::var("ORCS_RESULTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("results"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_writes_and_quotes() {
        let dir = std::env::temp_dir().join("orcs_test_csv");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.row(&["1".into(), "x,y".into()]).unwrap();
        drop(w);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,\"x,y\"\n");
        assert!(CsvWriter::create(&path, &["a"]).unwrap().row(&["1".into(), "2".into()]).is_err());
    }

    #[test]
    fn table_aligns() {
        let mut t = TextTable::new(&["name", "val"]);
        t.row(vec!["x".into(), "1".into()]);
        t.row(vec!["longer".into(), "22".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[3].ends_with("22"));
    }
}
