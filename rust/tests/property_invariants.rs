//! Randomized property tests over the core invariants (in-house driver —
//! see `orcs::testutil`): BVH completeness/correctness across refits,
//! neighbor-set equality between every discovery mechanism and brute force,
//! force symmetry, gamma-ray minimality, bucket-plan coverage, and the
//! gradient cost model's optimality.

use orcs::bvh::traverse::QueryScratch;
use orcs::bvh::{BuildKind, Bvh};
use orcs::core::config::Boundary;
use orcs::core::rng::Rng;
use orcs::core::vec3::Vec3;
use orcs::frnn::{brute, gamma};
use orcs::gradient::{optimal_ku, simulation_cost, CostParams};
use orcs::physics::state::SimState;
use orcs::testutil::{gen, prop_check};

fn random_scene(rng: &mut Rng, n: usize, box_l: f32, r_max: f32) -> (Vec<Vec3>, Vec<f32>) {
    let pos = (0..n)
        .map(|_| {
            Vec3::new(
                rng.range_f32(0.0, box_l),
                rng.range_f32(0.0, box_l),
                rng.range_f32(0.0, box_l),
            )
        })
        .collect();
    let radius = (0..n).map(|_| rng.range_f32(0.2, r_max)).collect();
    (pos, radius)
}

#[test]
fn prop_bvh_queries_equal_brute_force_after_any_refit_sequence() {
    prop_check("bvh-query-vs-brute", 25, |rng| {
        let n = 20 + rng.below(200);
        let (mut pos, radius) = random_scene(rng, n, 80.0, 10.0);
        let kind = if rng.f32() < 0.5 { BuildKind::Median } else { BuildKind::BinnedSah };
        let mut bvh = Bvh::build(&pos, &radius, kind);
        let refits = rng.below(6);
        for _ in 0..refits {
            for p in pos.iter_mut() {
                *p += Vec3::new(
                    rng.range_f32(-3.0, 3.0),
                    rng.range_f32(-3.0, 3.0),
                    rng.range_f32(-3.0, 3.0),
                );
            }
            bvh.refit(&pos, &radius);
        }
        bvh.check_invariants(&pos, &radius).map_err(|e| e.to_string())?;
        let mut scratch = QueryScratch::new();
        for i in 0..n {
            let mut got = bvh.query_point_collect(pos[i], i, &pos, &radius, &mut scratch);
            got.sort_unstable();
            let want = brute::detection_neighbors(i, &pos, &radius, Boundary::Wall, 80.0);
            if got != want {
                return Err(format!("query mismatch at {i}: {got:?} vs {want:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_gamma_rays_complete_and_minimal() {
    prop_check("gamma-completeness", 30, |rng| {
        let box_l = 60.0;
        let trigger = rng.range_f32(1.0, 25.0);
        let p = Vec3::new(
            rng.range_f32(0.0, box_l),
            rng.range_f32(0.0, box_l),
            rng.range_f32(0.0, box_l),
        );
        let mut origins = Vec::new();
        gamma::gamma_origins(p, trigger, box_l, &mut origins);
        // count = 2^(active axes) - 1
        let active = [p.x, p.y, p.z]
            .iter()
            .filter(|&&x| x < trigger || x > box_l - trigger)
            .count();
        if origins.len() != (1usize << active) - 1 {
            return Err(format!("count {} for {active} active axes", origins.len()));
        }
        // every origin is the particle shifted by a +-box combination and
        // lies outside the box on the shifted axes
        for o in &origins {
            let d = *o - p;
            for c in [d.x, d.y, d.z] {
                if !(c == 0.0 || (c - box_l).abs() < 1e-3 || (c + box_l).abs() < 1e-3) {
                    return Err(format!("bad shift component {c}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_forces_antisymmetric_under_min_image() {
    prop_check("force-antisymmetry", 40, |rng| {
        let cfg = gen::small_config(rng, 20, 80);
        let state = SimState::from_config(&cfg);
        for _ in 0..30 {
            let i = rng.below(state.n());
            let j = rng.below(state.n());
            if i == j {
                continue;
            }
            let d_ij = orcs::physics::boundary::displacement(
                state.pos[i],
                state.pos[j],
                state.boundary,
                state.box_l,
            );
            let f_ij = state.params.pair_force(d_ij, state.radius[i], state.radius[j]);
            let d_ji = orcs::physics::boundary::displacement(
                state.pos[j],
                state.pos[i],
                state.boundary,
                state.box_l,
            );
            let f_ji = state.params.pair_force(d_ji, state.radius[j], state.radius[i]);
            match (f_ij, f_ji) {
                (None, None) => {}
                (Some(a), Some(b)) => {
                    if (a + b).norm() > 1e-3 * a.norm().max(1.0) {
                        return Err(format!("f_ij {a:?} != -f_ji {b:?}"));
                    }
                }
                _ => return Err("cutoff asymmetry between i->j and j->i".into()),
            }
        }
        Ok(())
    });
}

#[test]
fn prop_detection_union_covers_interaction_set() {
    // i's detections ∪ {j : j detects i} must equal the interaction set —
    // the identity that makes ORCS-forces' handler rule complete (Fig. 5)
    prop_check("detection-covers-interaction", 25, |rng| {
        let cfg = gen::small_config(rng, 20, 100);
        let state = SimState::from_config(&cfg);
        for i in 0..state.n() {
            let mut union = brute::detection_neighbors(
                i,
                &state.pos,
                &state.radius,
                state.boundary,
                state.box_l,
            );
            for j in 0..state.n() {
                if j != i {
                    let dj = brute::detection_neighbors(
                        j,
                        &state.pos,
                        &state.radius,
                        state.boundary,
                        state.box_l,
                    );
                    if dj.contains(&i) {
                        union.push(j);
                    }
                }
            }
            union.sort_unstable();
            union.dedup();
            let want = brute::interaction_neighbors(
                i,
                &state.pos,
                &state.radius,
                state.boundary,
                state.box_l,
            );
            if union != want {
                return Err(format!("coverage gap at {i}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_gradient_kopt_minimizes_cost_model() {
    prop_check("kopt-optimality", 200, |rng| {
        let p = CostParams {
            t_r: rng.range_f32(1.0, 200.0) as f64,
            t_u: rng.range_f32(0.01, 0.9) as f64,
            t_q: rng.range_f32(0.1, 50.0) as f64,
            dq: rng.range_f32(1e-4, 10.0) as f64,
        };
        let k = optimal_ku(&p);
        // the cost curve is unimodal in k, so the discrete argmin must be
        // floor(k*) or ceil(k*); no other integer may beat both
        let floor = k.floor().max(0.0);
        let ceil = k.ceil();
        let best =
            simulation_cost(&p, 1000.0, floor).min(simulation_cost(&p, 1000.0, ceil));
        for delta in -3i64..=3 {
            let kk = (floor + delta as f64).max(0.0);
            if kk == floor || kk == ceil {
                continue;
            }
            let ck = simulation_cost(&p, 1000.0, kk);
            if ck < best * (1.0 - 1e-9) {
                return Err(format!(
                    "k*={k:.3}: cost({kk})={ck:.4} < best-of-floor/ceil={best:.4} for {p:?}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_bucket_plans_cover_exactly() {
    prop_check("bucket-coverage", 300, |rng| {
        let k = rng.below(2000);
        let (full, tail) = orcs::runtime::buckets::segment_plan(k);
        let widest = 256;
        let covered = full * widest + tail.unwrap_or(0);
        if k == 0 {
            return if covered >= 16 { Ok(()) } else { Err("zero plan".into()) };
        }
        if covered < k {
            return Err(format!("k={k} covered only {covered}"));
        }
        if covered >= k + widest {
            return Err(format!("k={k} over-covered {covered}"));
        }
        Ok(())
    });
}

#[test]
fn prop_wall_reflection_conserves_speed() {
    prop_check("reflection-speed", 100, |rng| {
        let box_l = 50.0;
        let mut pos = Vec3::new(
            rng.range_f32(-20.0, 70.0),
            rng.range_f32(-20.0, 70.0),
            rng.range_f32(-20.0, 70.0),
        );
        let mut vel = Vec3::new(
            rng.range_f32(-5.0, 5.0),
            rng.range_f32(-5.0, 5.0),
            rng.range_f32(-5.0, 5.0),
        );
        let speed = vel.norm();
        orcs::physics::boundary::apply(Boundary::Wall, box_l, &mut pos, &mut vel);
        if (vel.norm() - speed).abs() > 1e-4 {
            return Err("reflection changed speed".into());
        }
        for c in [pos.x, pos.y, pos.z] {
            if !(0.0..=box_l).contains(&c) {
                return Err(format!("position {c} escaped the box"));
            }
        }
        Ok(())
    });
}
