// Fixture: seeded P-PANIC violation (unwrap in a step path).
pub fn first(xs: &[u32]) -> u32 {
    *xs.first().unwrap()
}
