//! Benchmark suite: one module per table/figure of the paper's evaluation.
//! Each regenerates the paper artifact's rows/series (simulated GPU times
//! from the rtcore model + real wall-clock), prints them, and writes CSVs
//! into `results/`.

pub mod chaos;
pub mod common;
pub mod fig11_12;
pub mod fig13;
pub mod fig8;
pub mod fig9_10;
pub mod sharded;
pub mod table2;
