// Fixture: clean twin — total function, no panicking construct.
pub fn first(xs: &[u32]) -> u32 {
    xs.first().copied().unwrap_or(0)
}
