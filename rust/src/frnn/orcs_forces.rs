//! ORCS-forces (contribution #2, §3.2.2): no neighbor list — every
//! intersection shader computes the pair force immediately and scatters it
//! into **both** endpoint force accumulators in global memory, atomically.
//! A separate kernel then integrates.
//!
//! Pair-handling rule (exactly once per pair):
//! * uniform radius: both rays detect the pair; the *smaller particle id*
//!   handles it;
//! * variable radius: detection can be one-sided (Fig. 5) — the thread with
//!   the smallest search radius is guaranteed to detect (it sits inside the
//!   larger sphere) and handles the pair; ties broken by id.
//!
//! On real hardware the scatter is `atomicAdd`; we reproduce it race-free
//! with per-thread force buffers + a deterministic reduction, while
//! *counting* the atomics for the timing model (DESIGN.md
//! §Hardware-Adaptation).

use crate::core::vec3::Vec3;
use crate::frnn::rt_common::{fold_stats, gamma_trigger, launch_rays, BvhManager};
use crate::frnn::zorder::ZOrderCache;
use crate::frnn::{Backend, StepCtx, StepResult, WallPhases};
use crate::gradient::RebuildPolicy;
use crate::physics::state::SimState;
use crate::resilience::{SimError, SimResult};
use crate::rtcore::OpCounts;
use crate::telemetry::wallclock::WallTimer;

pub struct OrcsForces {
    mgr: BvhManager,
    /// Per-step Morton cache shared by LBVH builds and the query sweep.
    zcache: ZOrderCache,
}

impl OrcsForces {
    pub fn new(policy: Box<dyn RebuildPolicy>) -> Self {
        OrcsForces { mgr: BvhManager::new(policy), zcache: ZOrderCache::new() }
    }
}

/// Does ray thread `i` handle the pair `(i, j)`? See module docs.
#[inline(always)]
pub fn handles_pair(i: usize, r_i: f32, j: usize, r_j: f32, mutual: bool) -> bool {
    if !mutual {
        return true; // only i detected the pair
    }
    // both detect: lexicographically smaller (radius, id) handles
    (r_i, i) < (r_j, j)
}

impl Backend for OrcsForces {
    fn name(&self) -> &'static str {
        "ORCS-forces"
    }

    fn step(&mut self, state: &mut SimState, ctx: &mut StepCtx) -> SimResult<StepResult> {
        let mut counts = OpCounts::default();
        let mut wall = WallPhases::default();
        let n = state.n();

        // Phase 0: one Morton keying + sort per step (shared by build +
        // sweep); wall time charged to the search phase below.
        let t_sort = WallTimer::start();
        self.zcache.compute(&state.pos, state.box_l, ctx.threads);
        let sort_wall = t_sort.elapsed_s();
        debug_assert_eq!(self.zcache.order().len(), n);

        // Phase 1: BVH maintenance.
        let t0 = WallTimer::start();
        let action = self.mgr.prepare_with(
            &state.pos,
            &state.radius,
            &mut counts,
            ctx.threads,
            false,
            Some(self.zcache.order()),
        );
        wall.bvh = t0.elapsed_s();

        // Phase 2: batched traversal with in-shader force scatter, swept in
        // Morton order of the ray origins (coherent rays share subtrees, so
        // BVH4 node fetches stay cache-hot — and the scatter buffer is
        // touched in spatially-local runs too). Each worker scatters into a
        // dense thread-local buffer (epoch-stamped so it re-zeroes lazily)
        // and flushes the touched entries as a sparse per-chunk delta list;
        // the deltas are applied in chunk order and the Morton permutation
        // is thread-count independent, so the reduction is bitwise
        // deterministic regardless of which worker ran which chunk — the
        // race-free substitute for the GPU's atomicAdd (DESIGN.md
        // §Hardware-Adaptation).
        let t1 = WallTimer::start();
        let bvh = self.mgr.bvh();
        let trigger = gamma_trigger(state);
        struct Scatter {
            buf: Vec<Vec3>,
            stamp: Vec<u32>,
            epoch: u32,
            touched: Vec<u32>,
        }
        struct ChunkOut {
            deltas: Vec<(u32, Vec3)>,
            pairs: u64,
            evals: u64,
        }
        let (chunks, stats) = bvh.query_batch_with_order(
            self.zcache.order(),
            ctx.threads,
            || Scatter {
                buf: vec![Vec3::ZERO; n],
                stamp: vec![0u32; n],
                epoch: 0,
                touched: Vec::new(),
            },
            |sc, scratch, ids| {
                sc.epoch += 1;
                sc.touched.clear();
                let mut pairs = 0u64;
                let mut evals = 0u64;
                for &iu in ids {
                    let i = iu as usize;
                    let r_i = state.radius[i];
                    let (buf, stamp, touched) =
                        (&mut sc.buf, &mut sc.stamp, &mut sc.touched);
                    let epoch = sc.epoch;
                    let mut add = |idx: usize, f: Vec3| {
                        if stamp[idx] != epoch {
                            stamp[idx] = epoch;
                            touched.push(idx as u32);
                        }
                        buf[idx] += f;
                    };
                    launch_rays(
                        bvh,
                        i,
                        &state.pos,
                        &state.radius,
                        state.boundary,
                        state.box_l,
                        trigger,
                        scratch,
                        |j, dx| {
                            let r_j = state.radius[j];
                            let mutual = dx.norm2() < r_i * r_i;
                            if !handles_pair(i, r_i, j, r_j, mutual) {
                                return;
                            }
                            evals += 1;
                            if let Some(fij) = state.params.pair_force(dx, r_i, r_j) {
                                add(i, fij);
                                add(j, -fij); // "atomicAdd" on real hardware
                                pairs += 1;
                            }
                        },
                    );
                }
                // Flush touched entries (zeroing them for the next chunk).
                let mut deltas = Vec::with_capacity(sc.touched.len());
                for &idx in &sc.touched {
                    let idx = idx as usize;
                    deltas.push((idx as u32, sc.buf[idx]));
                    sc.buf[idx] = Vec3::ZERO;
                }
                ChunkOut { deltas, pairs, evals }
            },
        );

        // Chunk-ordered deterministic reduction.
        let mut force = vec![Vec3::ZERO; n];
        let mut pairs = 0u64;
        let mut evals = 0u64;
        for c in chunks {
            for (idx, f) in c.deltas {
                force[idx as usize] += f;
            }
            pairs += c.pairs;
            evals += c.evals;
        }
        state.force = force;
        fold_stats(&mut counts, &stats);
        counts.isect_force_evals += evals;
        counts.atomic_adds += 2 * pairs; // both endpoints, atomically
        counts.interactions += pairs;
        wall.search = sort_wall + t1.elapsed_s();

        // Phase 3: the one extra compute kernel — integration.
        let t2 = WallTimer::start();
        ctx.kernels.integrate(state, &mut counts).map_err(SimError::fatal)?;
        wall.integrate = t2.elapsed_s();

        self.mgr.observe(action, &counts, ctx.hw);
        Ok(StepResult { counts, bvh_action: Some(action), oom_bytes: None, wall })
    }

    fn invalidate_bvh(&mut self) {
        self.mgr.invalidate();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::{Boundary, RadiusDist, SimConfig};
    use crate::frnn::{brute, RustKernels};
    use crate::gradient::FixedKPolicy;
    use crate::rtcore::profile::RTXPRO;

    #[test]
    fn handler_rule_exactly_once() {
        // mutual detection, distinct radii: smaller radius handles
        assert!(handles_pair(5, 1.0, 9, 2.0, true));
        assert!(!handles_pair(9, 2.0, 5, 1.0, true));
        // mutual, equal radii: smaller id handles
        assert!(handles_pair(3, 1.0, 7, 1.0, true));
        assert!(!handles_pair(7, 1.0, 3, 1.0, true));
        // one-sided detection: the detector always handles
        assert!(handles_pair(9, 1.0, 5, 8.0, false));
    }

    fn check_matches_brute(n: usize, boundary: Boundary, radius: RadiusDist) {
        let cfg = SimConfig {
            n,
            boundary,
            radius_dist: radius,
            box_l: 100.0,
            ..SimConfig::default()
        };
        let mut state = SimState::from_config(&cfg);
        let want = {
            let mut s2 = state.clone();
            s2.force = brute::forces(&s2);
            crate::physics::integrator::step(&mut s2);
            s2
        };
        let kernels = RustKernels { threads: 3 };
        let mut ctx = StepCtx {
            threads: 3,
            kernels: &kernels,
            hw: &RTXPRO,
            check_oom: false,
            vram_budget: None,
        };
        let mut backend = OrcsForces::new(Box::new(FixedKPolicy::new(4)));
        let r = backend.step(&mut state, &mut ctx).unwrap();
        assert!(r.counts.atomic_adds == 2 * r.counts.interactions);
        assert!(r.counts.nbr_list_writes == 0, "ORCS must not build lists");
        for i in 0..state.n() {
            assert!(
                (state.pos[i] - want.pos[i]).norm() < 1e-3,
                "{boundary:?} {radius:?} particle {i}"
            );
        }
    }

    #[test]
    fn matches_brute_force_all_modes() {
        for boundary in [Boundary::Wall, Boundary::Periodic] {
            for radius in [RadiusDist::Const(8.0), RadiusDist::Uniform(2.0, 14.0)] {
                check_matches_brute(220, boundary, radius);
            }
        }
    }

    #[test]
    fn interaction_count_exact() {
        let cfg = SimConfig {
            n: 180,
            boundary: Boundary::Periodic,
            radius_dist: RadiusDist::Uniform(2.0, 12.0),
            box_l: 100.0,
            ..SimConfig::default()
        };
        let mut state = SimState::from_config(&cfg);
        let want =
            brute::count_interactions(&state.pos, &state.radius, state.boundary, state.box_l);
        let kernels = RustKernels { threads: 2 };
        let mut ctx = StepCtx {
            threads: 2,
            kernels: &kernels,
            hw: &RTXPRO,
            check_oom: false,
            vram_budget: None,
        };
        let mut backend = OrcsForces::new(Box::new(FixedKPolicy::new(4)));
        let r = backend.step(&mut state, &mut ctx).unwrap();
        // pairs outside the LJ force cutoff but inside the search radius
        // still count as interactions (they were evaluated)
        assert_eq!(r.counts.interactions, want);
    }
}
