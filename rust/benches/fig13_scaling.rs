//! `cargo bench --bench fig13_scaling [-- --quick]`
//! Regenerates paper Fig. 13 (perf + EE scaling across GPU generations).
fn main() {
    let opts = orcs::benchsuite::common::BenchOpts::from_env().expect("bench options");
    orcs::benchsuite::fig13::run(&opts).expect("fig13 bench");
}
