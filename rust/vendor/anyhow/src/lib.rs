//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The offline vendor set this repository builds against has no registry
//! crates, so this path dependency provides the small slice of `anyhow`'s
//! API the codebase actually uses: [`Error`], [`Result`], the `anyhow!`,
//! `bail!` and `ensure!` macros, and the [`Context`] extension trait. The
//! crate is API-compatible with real `anyhow` for these uses, so swapping
//! in the upstream crate later is a manifest-only change.
//!
//! Differences from upstream: errors are flattened to a message string at
//! construction (no backtraces, no downcasting) — sufficient for a CLI
//! whose only consumer of errors is `eprintln!("{e:#}")`.

use std::fmt;

/// A flattened, message-carrying error type.
///
/// Deliberately does **not** implement `std::error::Error`: that is what
/// makes the blanket `From<E: std::error::Error>` impl below coherent
/// (the same trick upstream `anyhow` uses), which in turn makes `?` work
/// on any standard error type inside a `Result<T, Error>` function.
pub struct Error {
    msg: String,
}

/// `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    fn wrap(context: impl fmt::Display, cause: &Error) -> Error {
        Error { msg: format!("{context}: {}", cause.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // Flatten the source chain into one line, as `{:#}` would print.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// Extension trait adding `.context()` / `.with_context()` to `Result`
/// and `Option`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::wrap(context, &Error::from(e)))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::wrap(f(), &Error::from(e)))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_port(s: &str) -> Result<u16> {
        let p: u16 = s.parse()?; // ParseIntError -> Error via blanket From
        ensure!(p > 1024, "port {p} is privileged");
        Ok(p)
    }

    #[test]
    fn question_mark_and_ensure() {
        assert!(parse_port("8080").is_ok());
        assert!(parse_port("80").unwrap_err().to_string().contains("privileged"));
        assert!(parse_port("not-a-number").is_err());
    }

    #[test]
    fn macros_format() {
        let n = 3;
        let e = anyhow!("bad value {n}");
        assert_eq!(e.to_string(), "bad value 3");
        let e = anyhow!("bad value {}", 4);
        assert_eq!(e.to_string(), "bad value 4");
        let e = anyhow!(String::from("owned"));
        assert_eq!(e.to_string(), "owned");
    }

    #[test]
    fn bail_returns_early() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(1).unwrap(), 1);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"));
        let e = r.context("reading config").unwrap_err();
        assert!(e.to_string().starts_with("reading config: "), "{e}");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "field")).unwrap_err();
        assert_eq!(e.to_string(), "missing field");
    }
}
