//! Figs. 11 & 12 — power-consumption time series and energy efficiency
//! (interactions per joule) for the three representative cases of §4.3,
//! wall + periodic BC, all five approaches.
//!
//! One set of runs feeds both figures. Shape targets (Fig. 11/12): RT-REF
//! draws the most power in Lattice (≈400 W on the 600 W part), CPU-CELL a
//! stable ≈250 W; ORCS variants sit between; at log-normal Cluster,
//! ORCS-forces is the most energy-efficient by a wide margin; CPU remains
//! competitive in EE despite being slowest.

use anyhow::Result;

use super::common::{energy_cases, BenchOpts};
use crate::coordinator::metrics::fmt_si;
use crate::coordinator::report::{results_dir, CsvWriter, TextTable};
use crate::core::config::Boundary;
use crate::frnn::ApproachKind;

const N_DEFAULT: usize = 6_000;
const STEPS_DEFAULT: usize = 80;

pub fn run(opts: &BenchOpts) -> Result<()> {
    let (n, steps) = opts.size(N_DEFAULT, STEPS_DEFAULT);
    println!("== Figs. 11 & 12: power time series + energy efficiency (n={n}, {steps} steps) ==\n");

    let mut power_csv = CsvWriter::create(
        &results_dir().join("fig11_power.csv"),
        &["case", "bc", "approach", "step", "t_cum_ms", "power_w"],
    )?;
    let mut ee_csv = CsvWriter::create(
        &results_dir().join("fig12_energy_eff.csv"),
        &["case", "bc", "approach", "interactions", "energy_j", "ee_int_per_j", "oom"],
    )?;

    for boundary in [Boundary::Wall, Boundary::Periodic] {
        for case in energy_cases() {
            let mut table =
                TextTable::new(&["approach", "avg power (W)", "energy (J)", "EE (int/J)", "time (ms)"]);
            for approach in ApproachKind::ALL {
                let Some(s) =
                    opts.run(&case, n, boundary, approach, "gradient", steps, true)?
                else {
                    table.row(vec![approach.to_string(), "-".into(), "-".into(), "-".into(), "-".into()]);
                    continue;
                };
                let mut t_cum = 0.0;
                for rec in &s.records {
                    t_cum += rec.sim_ms;
                    power_csv.row(&[
                        case.tag(),
                        boundary.to_string(),
                        approach.to_string(),
                        rec.step.to_string(),
                        format!("{:.3}", t_cum),
                        format!("{:.1}", rec.energy.avg_power_w),
                    ])?;
                }
                ee_csv.row(&[
                    case.tag(),
                    boundary.to_string(),
                    approach.to_string(),
                    s.total_interactions.to_string(),
                    format!("{:.4}", s.total_energy_j),
                    format!("{:.1}", s.ee),
                    s.oom.to_string(),
                ])?;
                table.row(vec![
                    format!("{}{}", approach, if s.oom { " (OOM)" } else { "" }),
                    format!("{:.0}", s.avg_power_w),
                    format!("{:.3}", s.total_energy_j),
                    fmt_si(s.ee),
                    format!("{:.2}", s.total_sim_ms),
                ]);
            }
            println!("--- {} / {} BC ---", case.tag(), boundary);
            println!("{}", table.render());
        }
    }
    println!("CSV: {} and {}",
        results_dir().join("fig11_power.csv").display(),
        results_dir().join("fig12_energy_eff.csv").display());
    Ok(())
}
