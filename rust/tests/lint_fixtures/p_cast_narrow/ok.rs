// Fixture: clean twin — checked conversion surfaces overflow.
pub fn total_bytes(lens: &[u32]) -> Option<u32> {
    u32::try_from(lens.len() * 4).ok()
}
