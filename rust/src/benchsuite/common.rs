//! Shared benchmark infrastructure: the paper's scenario matrix, size
//! scaling, engine plumbing and the OOM extrapolation to paper scale.
//!
//! Sizes: the paper runs n = 140k–1M for hundreds–thousands of steps on a
//! 600 W GPU; the reproduced numbers come from the simulated-time model, so
//! the benches default to smaller n (the model is size-faithful: op counts
//! are measured, not extrapolated) with `--scale`/`--steps` overrides to
//! approach paper sizes when wall-clock budget allows (DESIGN.md
//! §Hardware-substitution).

use std::sync::Arc;

use anyhow::Result;

use crate::cli::Args;
use crate::coordinator::{Engine, EngineConfig, RunSummary};
use crate::core::config::{Boundary, ForcePath, ParticleDist, RadiusDist, SimConfig};
use crate::frnn::{ApproachKind, PhysicsKernels, RustKernels};
use crate::rtcore::HwProfile;

/// One (particle distribution, radius distribution) cell of the paper's
/// 3x4 evaluation grid (§4.1).
#[derive(Clone, Copy, Debug)]
pub struct Case {
    pub dist: ParticleDist,
    pub radius: RadiusDist,
}

impl Case {
    pub fn tag(&self) -> String {
        format!("{}/{}", self.dist, self.radius)
    }
}

/// The full 3x4 grid.
pub fn paper_grid() -> Vec<Case> {
    let mut out = Vec::new();
    for dist in ParticleDist::ALL {
        for radius in RadiusDist::paper_set() {
            out.push(Case { dist, radius });
        }
    }
    out
}

/// The three representative cases of §4.3 (Figs 11–13).
pub fn energy_cases() -> Vec<Case> {
    vec![
        Case { dist: ParticleDist::Lattice, radius: RadiusDist::Const(160.0) },
        Case { dist: ParticleDist::Disordered, radius: RadiusDist::Const(1.0) },
        Case {
            dist: ParticleDist::Cluster,
            radius: RadiusDist::LogNormal { mu: 1.0, sigma: 2.0, lo: 1.0, hi: 330.0 },
        },
    ]
}

/// Execution options shared by the bench binaries.
pub struct BenchOpts {
    pub threads: usize,
    pub hw: &'static HwProfile,
    pub kernels: Arc<dyn PhysicsKernels>,
    pub quick: bool,
    pub steps_override: Option<usize>,
    pub n_override: Option<usize>,
    pub seed: u64,
}

impl BenchOpts {
    /// Parse from bench-binary argv (skipping cargo's injected `--bench`).
    pub fn from_env() -> Result<BenchOpts> {
        let argv: Vec<String> = std::env::args()
            .skip(1)
            .filter(|a| a != "--bench" && !a.ends_with(".rs"))
            .collect();
        let args = Args::parse(std::iter::once("bench".to_string()).chain(argv))?;
        Self::from_args(&args)
    }

    pub fn from_args(args: &Args) -> Result<BenchOpts> {
        let threads = crate::parallel::num_threads();
        let force_path = match args.get_or("force-path", "rust") {
            "xla" => ForcePath::Xla,
            _ => ForcePath::Rust,
        };
        let kernels: Arc<dyn PhysicsKernels> = match force_path {
            ForcePath::Rust => Arc::new(RustKernels { threads }),
            ForcePath::Xla => Arc::new(crate::runtime::kernels::XlaKernels::load_default()?),
        };
        Ok(BenchOpts {
            threads,
            hw: args.hw()?,
            kernels,
            quick: args.has("quick") || std::env::var("ORCS_QUICK").is_ok(),
            steps_override: args.get("steps").map(|s| s.parse()).transpose()?,
            n_override: args.get("n").map(|s| s.parse()).transpose()?,
            seed: args.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(0xC0FFEE),
        })
    }

    /// Pick (n, steps): default unless overridden; `--quick` shrinks both.
    pub fn size(&self, n_default: usize, steps_default: usize) -> (usize, usize) {
        let mut n = self.n_override.unwrap_or(n_default);
        let mut steps = self.steps_override.unwrap_or(steps_default);
        if self.quick {
            n = (n / 8).max(256);
            steps = (steps / 8).max(4);
        }
        (n, steps)
    }

    pub fn sim_config(&self, case: &Case, n: usize, boundary: Boundary) -> SimConfig {
        SimConfig {
            n,
            particle_dist: case.dist,
            radius_dist: case.radius,
            boundary,
            seed: self.seed,
            ..SimConfig::default()
        }
    }

    /// Build and run one engine; returns `None` when the backend does not
    /// support the scenario (ORCS-persé × variable radius — the paper's
    /// `-` cells).
    pub fn run(
        &self,
        case: &Case,
        n: usize,
        boundary: Boundary,
        approach: ApproachKind,
        policy: &str,
        steps: usize,
        keep_trace: bool,
    ) -> Result<Option<RunSummary>> {
        self.run_with(case, n, boundary, approach, policy, steps, keep_trace, |_| {})
    }

    /// [`Self::run`] with a scenario-tweaking hook (dt, temperature, ...).
    #[allow(clippy::too_many_arguments)]
    pub fn run_with(
        &self,
        case: &Case,
        n: usize,
        boundary: Boundary,
        approach: ApproachKind,
        policy: &str,
        steps: usize,
        keep_trace: bool,
        tweak: impl FnOnce(&mut SimConfig),
    ) -> Result<Option<RunSummary>> {
        let mut sim = self.sim_config(case, n, boundary);
        tweak(&mut sim);
        let cfg = EngineConfig {
            policy: policy.to_string(),
            hw: self.hw,
            threads: self.threads,
            check_oom: true,
            ..EngineConfig::new(sim, approach)
        };
        match Engine::new(cfg, self.kernels.clone()) {
            Ok(mut engine) => Ok(Some(engine.run(steps, keep_trace)?)),
            Err(_) => Ok(None), // unsupported combination
        }
    }
}

/// Extrapolate whether RT-REF's neighbor list would exceed device memory at
/// *paper* scale (n_paper) from a bench-scale measurement: with box and
/// radii fixed, per-particle neighbor counts grow linearly in n, so
/// `bytes(paper) ≈ n_paper * k_max_bench * (n_paper / n_bench) * 4`.
pub fn paper_scale_oom(
    k_max_bench: usize,
    n_bench: usize,
    n_paper: usize,
    hw: &HwProfile,
) -> bool {
    if n_bench == 0 || k_max_bench == 0 {
        return false;
    }
    let k_paper = (k_max_bench as f64) * (n_paper as f64 / n_bench as f64);
    let bytes = n_paper as f64 * k_paper.min(n_paper as f64) * 4.0;
    bytes > hw.vram_bytes as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtcore::profile::{RTXPRO, TITANRTX};

    #[test]
    fn grid_is_three_by_four() {
        let g = paper_grid();
        assert_eq!(g.len(), 12);
        assert_eq!(energy_cases().len(), 3);
    }

    #[test]
    fn oom_extrapolation_matches_paper_cases() {
        // Lattice r=160 at 1M: k ~ 17k/particle -> ~68 GB -> OOM on 24 GB
        // Turing, fits nowhere near on Titan but borderline on 96 GB.
        // bench-scale stand-in: n=10k with k_max ~ 171
        assert!(paper_scale_oom(171, 10_000, 1_000_000, &TITANRTX));
        // r=1: k_max ~ 1 even at 1M -> no OOM anywhere
        assert!(!paper_scale_oom(1, 10_000, 1_000_000, &RTXPRO));
        // cluster LN: k_max ~ n at any scale -> catastrophic at 1M
        assert!(paper_scale_oom(10_000, 10_000, 1_000_000, &RTXPRO));
    }

    #[test]
    fn size_scaling() {
        let opts = BenchOpts {
            threads: 1,
            hw: &RTXPRO,
            kernels: Arc::new(RustKernels { threads: 1 }),
            quick: true,
            steps_override: None,
            n_override: None,
            seed: 1,
        };
        let (n, steps) = opts.size(8000, 80);
        assert_eq!((n, steps), (1000, 10));
    }
}
