// Fixture: clean twin — ordered iteration and point lookups only.
use std::collections::{BTreeMap, HashMap};

pub fn sum_sorted(tree: &BTreeMap<u64, u32>) -> u64 {
    let mut total = 0u64;
    for (_k, v) in tree.iter() {
        total += *v as u64;
    }
    total
}

pub fn lookup(index: &HashMap<u64, u32>, key: u64) -> Option<u32> {
    index.get(&key).copied()
}
