//! ORCS-forces (contribution #2, §3.2.2): no neighbor list — every
//! intersection shader computes the pair force immediately and scatters it
//! into **both** endpoint force accumulators in global memory, atomically.
//! A separate kernel then integrates.
//!
//! Pair-handling rule (exactly once per pair):
//! * uniform radius: both rays detect the pair; the *smaller particle id*
//!   handles it;
//! * variable radius: detection can be one-sided (Fig. 5) — the thread with
//!   the smallest search radius is guaranteed to detect (it sits inside the
//!   larger sphere) and handles the pair; ties broken by id.
//!
//! On real hardware the scatter is `atomicAdd`; we reproduce it race-free
//! by routing every discovered pair into a transient canonical CSR and
//! summing each particle's contributions in **ascending global id** order
//! (`rt_common::canonical_force_sum`), while *counting* the atomics for the
//! timing model (DESIGN.md §Hardware-Adaptation). The canonical order makes
//! the listless force array byte-for-byte equal to the list pipeline's —
//! the invariant that lets the sharded engine run this backend
//! transparently.

use crate::frnn::rt_common::{fold_stats, gamma_trigger, launch_rays, BvhManager};
use crate::frnn::zorder::ZOrderCache;
use crate::frnn::{Backend, StepCtx, StepResult, WallPhases};
use crate::gradient::RebuildPolicy;
use crate::physics::state::SimState;
use crate::resilience::{SimError, SimResult};
use crate::rtcore::OpCounts;
use crate::telemetry::wallclock::WallTimer;

pub struct OrcsForces {
    mgr: BvhManager,
    /// Per-step Morton cache shared by LBVH builds and the query sweep.
    zcache: ZOrderCache,
}

impl OrcsForces {
    pub fn new(policy: Box<dyn RebuildPolicy>) -> Self {
        OrcsForces { mgr: BvhManager::new(policy), zcache: ZOrderCache::new() }
    }
}

/// Does ray thread `i` handle the pair `(i, j)`? See module docs.
#[inline(always)]
pub fn handles_pair(i: usize, r_i: f32, j: usize, r_j: f32, mutual: bool) -> bool {
    if !mutual {
        return true; // only i detected the pair
    }
    // both detect: lexicographically smaller (radius, id) handles
    (r_i, i) < (r_j, j)
}

impl Backend for OrcsForces {
    fn name(&self) -> &'static str {
        "ORCS-forces"
    }

    fn step(&mut self, state: &mut SimState, ctx: &mut StepCtx) -> SimResult<StepResult> {
        let mut counts = OpCounts::default();
        let mut wall = WallPhases::default();
        let n = state.n();

        // Phase 0: one Morton keying + sort per step (shared by build +
        // sweep); wall time charged to the search phase below.
        let t_sort = WallTimer::start();
        self.zcache.compute(&state.pos, state.box_l, ctx.threads);
        let sort_wall = t_sort.elapsed_s();
        debug_assert_eq!(self.zcache.order().len(), n);

        // Phase 1: BVH maintenance.
        let t0 = WallTimer::start();
        let action = self.mgr.prepare_with(
            &state.pos,
            &state.radius,
            &mut counts,
            ctx.threads,
            false,
            Some(self.zcache.order()),
        );
        wall.bvh = t0.elapsed_s();

        // Phase 2: batched traversal, swept in Morton order of the ray
        // origins (coherent rays share subtrees, so BVH4 node fetches stay
        // cache-hot). Discovery emits each visited pair toward *both*
        // endpoints — the in-shader symmetric scatter's footprint — into a
        // transient canonical CSR (ascending global id per target, deduped).
        // On real hardware the scatter is an unordered `atomicAdd`; the
        // canonical-order gather below is its race-free reproduction, and
        // because the accumulation order per target is pinned to ascending
        // id it is byte-for-byte the sum `RustKernels::lj_forces` (and the
        // brute min-image oracle) produces — the invariant the sharded
        // engine's transparency contract rides on.
        let t1 = WallTimer::start();
        let bvh = self.mgr.bvh();
        let trigger = gamma_trigger(state);
        let (chunks, stats) = bvh.query_batch_with_order(
            self.zcache.order(),
            ctx.threads,
            || (),
            |_, scratch, ids| {
                let mut entries: Vec<(u32, u32)> = Vec::new();
                for &iu in ids {
                    let i = iu as usize;
                    launch_rays(
                        bvh,
                        i,
                        &state.pos,
                        &state.radius,
                        state.boundary,
                        state.box_l,
                        trigger,
                        scratch,
                        |j, _dx| {
                            entries.push((iu, j as u32));
                            entries.push((j as u32, iu)); // scatter to the other endpoint
                        },
                    );
                }
                entries
            },
        );
        fold_stats(&mut counts, &stats);

        let csr = crate::frnn::rt_common::canonical_csr(n, ctx.threads, &chunks);

        // Canonical-order force gather + in-shader metering. Each pair is
        // *handled* by exactly one endpoint thread (see `handles_pair`); the
        // handler recomputation below reconstructs, per canonical entry,
        // whether this target's ray was the handler — so the metered
        // evals/atomics match the GPU scatter even though the deterministic
        // reproduction sums per target.
        let per_target = crate::parallel::parallel_map(n, ctx.threads, |t| {
            let r_t = state.radius[t];
            let mut evals = 0u64;
            let mut pairs = 0u64;
            let f = crate::frnn::rt_common::canonical_force_sum(
                &state.pos,
                &state.radius,
                &state.params,
                state.boundary,
                state.box_l,
                t,
                csr.sources(t),
                |s, d2, in_range| {
                    let r_s = state.radius[s];
                    let t_sees = d2 < r_s * r_s;
                    let mutual = t_sees && d2 < r_t * r_t;
                    if t_sees && handles_pair(t, r_t, s, r_s, mutual) {
                        evals += 1;
                        if in_range {
                            pairs += 1; // "atomicAdd" × 2 on real hardware
                        }
                    }
                },
            );
            (f, evals, pairs)
        });
        let mut pairs = 0u64;
        let mut evals = 0u64;
        let mut force = Vec::with_capacity(n);
        for (f, e, p) in per_target {
            force.push(f);
            evals += e;
            pairs += p;
        }
        state.force = force;
        counts.isect_force_evals += evals;
        counts.atomic_adds += 2 * pairs; // both endpoints, atomically
        counts.interactions += pairs;
        wall.search = sort_wall + t1.elapsed_s();

        // Phase 3: the one extra compute kernel — integration.
        let t2 = WallTimer::start();
        ctx.kernels.integrate(state, &mut counts).map_err(SimError::fatal)?;
        wall.integrate = t2.elapsed_s();

        self.mgr.observe(action, &counts, ctx.hw);
        Ok(StepResult { counts, bvh_action: Some(action), oom_bytes: None, wall })
    }

    fn invalidate_bvh(&mut self) {
        self.mgr.invalidate();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::{Boundary, RadiusDist, SimConfig};
    use crate::frnn::{brute, RustKernels};
    use crate::gradient::FixedKPolicy;
    use crate::rtcore::profile::RTXPRO;

    #[test]
    fn handler_rule_exactly_once() {
        // mutual detection, distinct radii: smaller radius handles
        assert!(handles_pair(5, 1.0, 9, 2.0, true));
        assert!(!handles_pair(9, 2.0, 5, 1.0, true));
        // mutual, equal radii: smaller id handles
        assert!(handles_pair(3, 1.0, 7, 1.0, true));
        assert!(!handles_pair(7, 1.0, 3, 1.0, true));
        // one-sided detection: the detector always handles
        assert!(handles_pair(9, 1.0, 5, 8.0, false));
    }

    fn check_matches_brute(n: usize, boundary: Boundary, radius: RadiusDist) {
        let cfg = SimConfig {
            n,
            boundary,
            radius_dist: radius,
            box_l: 100.0,
            ..SimConfig::default()
        };
        let mut state = SimState::from_config(&cfg);
        let want = {
            let mut s2 = state.clone();
            s2.force = brute::forces(&s2);
            crate::physics::integrator::step(&mut s2);
            s2
        };
        let kernels = RustKernels { threads: 3 };
        let mut ctx = StepCtx {
            threads: 3,
            kernels: &kernels,
            hw: &RTXPRO,
            check_oom: false,
            vram_budget: None,
        };
        let mut backend = OrcsForces::new(Box::new(FixedKPolicy::new(4)));
        let r = backend.step(&mut state, &mut ctx).unwrap();
        assert!(r.counts.atomic_adds == 2 * r.counts.interactions);
        assert!(r.counts.nbr_list_writes == 0, "ORCS must not build lists");
        for i in 0..state.n() {
            assert!(
                (state.pos[i] - want.pos[i]).norm() < 1e-3,
                "{boundary:?} {radius:?} particle {i}"
            );
        }
    }

    #[test]
    fn matches_brute_force_all_modes() {
        for boundary in [Boundary::Wall, Boundary::Periodic] {
            for radius in [RadiusDist::Const(8.0), RadiusDist::Uniform(2.0, 14.0)] {
                check_matches_brute(220, boundary, radius);
            }
        }
    }

    #[test]
    fn interaction_count_exact() {
        let cfg = SimConfig {
            n: 180,
            boundary: Boundary::Periodic,
            radius_dist: RadiusDist::Uniform(2.0, 12.0),
            box_l: 100.0,
            ..SimConfig::default()
        };
        let mut state = SimState::from_config(&cfg);
        let want =
            brute::count_interactions(&state.pos, &state.radius, state.boundary, state.box_l);
        let kernels = RustKernels { threads: 2 };
        let mut ctx = StepCtx {
            threads: 2,
            kernels: &kernels,
            hw: &RTXPRO,
            check_oom: false,
            vram_budget: None,
        };
        let mut backend = OrcsForces::new(Box::new(FixedKPolicy::new(4)));
        let r = backend.step(&mut state, &mut ctx).unwrap();
        // pairs outside the LJ force cutoff but inside the search radius
        // still count as interactions (they were evaluated)
        assert_eq!(r.counts.interactions, want);
    }
}
