//! BVH traversal with exact operation counters — the simulated RT-core
//! query.
//!
//! The paper's FRNN scheme launches an *infinitesimal ray* at each particle
//! position and collects sphere intersections (Fig. 1): geometrically this is
//! a point query — `p_i` hits sphere `j` iff `|p_i - p_j| < r_j`. Traversal
//! visits every node whose AABB contains the query point and tests spheres
//! at the leaves. Counters mirror what RT silicon does per ray: box tests
//! (RT-core units) and intersection-shader invocations (SM units).

use super::Bvh;
use crate::core::vec3::Vec3;

/// Per-query (or accumulated) traversal statistics. These feed
/// [`crate::rtcore::timing`] to produce simulated GPU time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraversalStats {
    /// Ray–AABB tests executed (RT-core box units).
    pub aabb_tests: u64,
    /// Sphere (primitive) tests — intersection-shader invocations.
    pub sphere_tests: u64,
    /// Intersections found (hits = discovered neighbor candidates).
    pub hits: u64,
    /// Rays launched (primary + gamma).
    pub rays: u64,
}

impl TraversalStats {
    pub fn add(&mut self, o: &TraversalStats) {
        self.aabb_tests += o.aabb_tests;
        self.sphere_tests += o.sphere_tests;
        self.hits += o.hits;
        self.rays += o.rays;
    }
}

impl Bvh {
    /// Query all spheres containing point `p`, excluding primitive
    /// `exclude` (a particle never neighbors itself; pass `usize::MAX` to
    /// keep all). Calls `visit(j)` for every hit and updates `stats`.
    ///
    /// `pos`/`radius` are the *current* particle arrays: the BVH prunes by
    /// node bounds (possibly stale-loose after refits — exactly like RT
    /// hardware), but the sphere test itself is exact.
    #[inline]
    pub fn query_point<F: FnMut(usize)>(
        &self,
        p: Vec3,
        exclude: usize,
        pos: &[Vec3],
        radius: &[f32],
        stats: &mut TraversalStats,
        mut visit: F,
    ) {
        stats.rays += 1;
        // Manual stack; depth bounded by tree height (can grow after many
        // degenerate refits, so use a SmallVec-like spill pattern).
        let mut stack = [0u32; 96];
        let mut sp = 0usize;
        let mut spill: Vec<u32> = Vec::new();

        let mut current = 0u32;
        loop {
            // SAFETY: `current` is always a node index produced by the
            // builder (root 0, children `left_first`/`left_first+1` which
            // `check_invariants` proves in-bounds); prim_order indices are
            // a permutation of 0..n_prims. Skipping the bounds checks is
            // worth ~8% on this hottest loop (EXPERIMENTS.md §Perf #6).
            let node = unsafe { self.nodes.get_unchecked(current as usize) };
            stats.aabb_tests += 1;
            if node.aabb.contains(p) {
                if node.is_leaf() {
                    let first = node.left_first as usize;
                    for k in first..first + node.count as usize {
                        let j = unsafe { *self.prim_order.get_unchecked(k) } as usize;
                        stats.sphere_tests += 1;
                        if j != exclude {
                            let d2 = (p - *unsafe { pos.get_unchecked(j) }).norm2();
                            let r = unsafe { *radius.get_unchecked(j) };
                            if d2 < r * r {
                                stats.hits += 1;
                                visit(j);
                            }
                        }
                    }
                } else {
                    // push right, descend left
                    let l = node.left_first;
                    if sp < stack.len() {
                        stack[sp] = l + 1;
                        sp += 1;
                    } else {
                        spill.push(l + 1);
                    }
                    current = l;
                    continue;
                }
            }
            // pop
            if let Some(next) = spill.pop() {
                current = next;
            } else if sp > 0 {
                sp -= 1;
                current = stack[sp];
            } else {
                break;
            }
        }
    }

    /// Collect hit indices into a vector (convenience for tests and the
    /// neighbor-list pipeline).
    pub fn query_point_collect(
        &self,
        p: Vec3,
        exclude: usize,
        pos: &[Vec3],
        radius: &[f32],
        stats: &mut TraversalStats,
    ) -> Vec<usize> {
        let mut out = Vec::new();
        self.query_point(p, exclude, pos, radius, stats, |j| out.push(j));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bvh::BuildKind;
    use crate::core::rng::Rng;

    fn scene(n: usize, seed: u64, rmax: f32) -> (Vec<Vec3>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (
            (0..n)
                .map(|_| {
                    Vec3::new(
                        rng.range_f32(0.0, 100.0),
                        rng.range_f32(0.0, 100.0),
                        rng.range_f32(0.0, 100.0),
                    )
                })
                .collect(),
            (0..n).map(|_| rng.range_f32(0.5, rmax)).collect(),
        )
    }

    fn brute(p: Vec3, exclude: usize, pos: &[Vec3], radius: &[f32]) -> Vec<usize> {
        let mut v: Vec<usize> = (0..pos.len())
            .filter(|&j| j != exclude && (p - pos[j]).norm2() < radius[j] * radius[j])
            .collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn query_matches_brute_force() {
        let (pos, radius) = scene(400, 21, 8.0);
        for kind in [BuildKind::Median, BuildKind::BinnedSah] {
            let bvh = Bvh::build(&pos, &radius, kind);
            let mut stats = TraversalStats::default();
            for i in 0..pos.len() {
                let mut got = bvh.query_point_collect(pos[i], i, &pos, &radius, &mut stats);
                got.sort_unstable();
                assert_eq!(got, brute(pos[i], i, &pos, &radius), "i={i} kind={kind:?}");
            }
            assert_eq!(stats.rays, 400);
            assert!(stats.aabb_tests > 0 && stats.sphere_tests > 0);
        }
    }

    #[test]
    fn query_correct_after_refits() {
        let (mut pos, radius) = scene(300, 22, 6.0);
        let mut bvh = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
        let mut rng = Rng::new(5);
        for _ in 0..4 {
            for p in pos.iter_mut() {
                *p += Vec3::new(
                    rng.range_f32(-3.0, 3.0),
                    rng.range_f32(-3.0, 3.0),
                    rng.range_f32(-3.0, 3.0),
                );
            }
            bvh.refit(&pos, &radius);
            let mut stats = TraversalStats::default();
            for i in (0..pos.len()).step_by(7) {
                let mut got = bvh.query_point_collect(pos[i], i, &pos, &radius, &mut stats);
                got.sort_unstable();
                assert_eq!(got, brute(pos[i], i, &pos, &radius));
            }
        }
    }

    #[test]
    fn refit_degradation_increases_traversal_cost() {
        // the phenomenon gradient exploits: after motion + refit, queries
        // touch more nodes than after a rebuild of the same configuration
        let (mut pos, radius) = scene(2000, 23, 3.0);
        let mut bvh = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
        let mut rng = Rng::new(6);
        for _ in 0..10 {
            for p in pos.iter_mut() {
                *p += Vec3::new(
                    rng.range_f32(-4.0, 4.0),
                    rng.range_f32(-4.0, 4.0),
                    rng.range_f32(-4.0, 4.0),
                );
            }
            bvh.refit(&pos, &radius);
        }
        let mut refit_stats = TraversalStats::default();
        for i in 0..pos.len() {
            bvh.query_point(pos[i], i, &pos, &radius, &mut refit_stats, |_| {});
        }
        let fresh = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
        let mut fresh_stats = TraversalStats::default();
        for i in 0..pos.len() {
            fresh.query_point(pos[i], i, &pos, &radius, &mut fresh_stats, |_| {});
        }
        // hits identical (correctness), cost strictly larger (degradation)
        assert_eq!(refit_stats.hits, fresh_stats.hits);
        assert!(
            refit_stats.aabb_tests > fresh_stats.aabb_tests,
            "refit={} fresh={}",
            refit_stats.aabb_tests,
            fresh_stats.aabb_tests
        );
    }

    #[test]
    fn exclude_max_keeps_self() {
        let pos = vec![Vec3::ZERO, Vec3::new(1.0, 0.0, 0.0)];
        let radius = vec![2.0f32, 2.0];
        let bvh = Bvh::build(&pos, &radius, BuildKind::Median);
        let mut stats = TraversalStats::default();
        let got = bvh.query_point_collect(Vec3::ZERO, usize::MAX, &pos, &radius, &mut stats);
        assert_eq!(got.len(), 2); // both spheres contain the origin
    }
}
