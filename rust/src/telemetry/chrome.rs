//! Chrome `chrome://tracing` / Perfetto JSON export plus the structural
//! validators the CI smoke leg relies on.
//!
//! Layout: one lane (`tid`) per shard/device plus one global lane; each
//! step emits an umbrella `step N` slice per active lane with the phase
//! slices (build → refit → traverse → …) nested inside, and resilience
//! events render as instant markers. Timestamps are microseconds of
//! *simulated* device time, so traces are bitwise reproducible.

use std::collections::BTreeMap;

use super::{StepSpans, GLOBAL_LANE};

/// Tolerance for span-boundary comparisons: spans are laid out by exact
/// f64 cursor accumulation, so anything beyond a ulp-scale slack is a
/// real overlap.
const EPS_MS: f64 = 1e-9;

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Lane id → Chrome thread id: the global lane is tid 0, shard `s` is
/// tid `s + 1`.
fn tid(lane: u32) -> u64 {
    if lane == GLOBAL_LANE {
        0
    } else {
        u64::from(lane) + 1
    }
}

/// Render the recorded steps as a Chrome-trace JSON document.
///
/// `lanes` names the threads (from [`super::Recorder::lanes`]); lanes
/// that recorded spans but were never named still render, just unnamed.
pub fn render(steps: &[StepSpans], lanes: &[(u32, String)]) -> String {
    let mut events: Vec<String> = Vec::new();
    for (lane, name) in lanes {
        events.push(format!(
            "{{\"ph\":\"M\",\"pid\":0,\"tid\":{},\"name\":\"thread_name\",\
             \"args\":{{\"name\":\"{}\"}}}}",
            tid(*lane),
            esc(name)
        ));
    }
    for st in steps {
        // one umbrella slice per lane that was active this step; the
        // phase slices nest inside it
        let mut active: BTreeMap<u32, ()> = BTreeMap::new();
        for sp in &st.spans {
            active.entry(sp.lane).or_insert(());
        }
        for lane in active.keys() {
            events.push(format!(
                "{{\"ph\":\"X\",\"pid\":0,\"tid\":{},\"ts\":{:.3},\"dur\":{:.3},\
                 \"name\":\"step {}\",\"cat\":\"step\"}}",
                tid(*lane),
                st.t0_ms * 1e3,
                st.dur_ms * 1e3,
                st.step
            ));
        }
        for sp in &st.spans {
            let mut args = format!(
                "\"step\":{},\"aabb_tests\":{},\"isect_force_evals\":{},\"bytes_moved\":{}",
                st.step, sp.aabb_tests, sp.isect_force_evals, sp.bytes_moved
            );
            if let Some(w) = sp.wall_ms {
                args.push_str(&format!(",\"wall_ms\":{w}"));
            }
            events.push(format!(
                "{{\"ph\":\"X\",\"pid\":0,\"tid\":{},\"ts\":{:.3},\"dur\":{:.3},\
                 \"name\":\"{}\",\"cat\":\"phase\",\"args\":{{{args}}}}}",
                tid(sp.lane),
                sp.t0_ms * 1e3,
                sp.dur_ms * 1e3,
                sp.phase.label()
            ));
        }
        for m in &st.marks {
            events.push(format!(
                "{{\"ph\":\"i\",\"pid\":0,\"tid\":{},\"ts\":{:.3},\"s\":\"g\",\
                 \"name\":\"{}\",\"cat\":\"{}\"}}",
                tid(m.lane),
                m.t_ms * 1e3,
                esc(&m.label),
                esc(m.tag)
            ));
        }
    }
    format!("{{\"traceEvents\":[{}]}}\n", events.join(","))
}

/// Structural validation of a recorded trace: step starts are monotone,
/// durations are nonnegative, no span starts before its step, and spans
/// on one lane never overlap (within float slack) — the "monotone span
/// nesting" invariant the CI smoke leg asserts.
pub fn validate(steps: &[StepSpans]) -> Result<(), String> {
    let mut prev_t0 = f64::NEG_INFINITY;
    let mut lane_end: BTreeMap<u32, f64> = BTreeMap::new();
    for st in steps {
        if !st.t0_ms.is_finite() || st.t0_ms < prev_t0 {
            return Err(format!("step {}: start {} precedes {}", st.step, st.t0_ms, prev_t0));
        }
        prev_t0 = st.t0_ms;
        if st.dur_ms.is_nan() || st.dur_ms < 0.0 {
            return Err(format!("step {}: negative or NaN duration {}", st.step, st.dur_ms));
        }
        for sp in &st.spans {
            if sp.dur_ms.is_nan() || sp.dur_ms < 0.0 {
                return Err(format!(
                    "step {} lane {} {}: bad span duration {}",
                    st.step,
                    sp.lane,
                    sp.phase.label(),
                    sp.dur_ms
                ));
            }
            if sp.t0_ms + EPS_MS < st.t0_ms {
                return Err(format!(
                    "step {} lane {} {}: span starts before its step",
                    st.step,
                    sp.lane,
                    sp.phase.label()
                ));
            }
            let end = lane_end.entry(sp.lane).or_insert(f64::NEG_INFINITY);
            if sp.t0_ms + EPS_MS < *end {
                return Err(format!(
                    "step {} lane {} {}: span overlaps its predecessor",
                    st.step,
                    sp.lane,
                    sp.phase.label()
                ));
            }
            let e = sp.t0_ms + sp.dur_ms;
            if e > *end {
                *end = e;
            }
        }
    }
    Ok(())
}

/// String-aware well-formedness check of the rendered JSON text: brace
/// and bracket balance outside string literals, non-empty, object root.
pub fn validate_json(s: &str) -> Result<(), String> {
    let t = s.trim();
    if !t.starts_with('{') || !t.ends_with('}') {
        return Err("trace JSON root must be an object".to_string());
    }
    let mut braces = 0i64;
    let mut brackets = 0i64;
    let mut in_str = false;
    let mut escaped = false;
    for c in t.chars() {
        if in_str {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_str = false;
            }
            continue;
        }
        match c {
            '"' => in_str = true,
            '{' => braces += 1,
            '}' => braces -= 1,
            '[' => brackets += 1,
            ']' => brackets -= 1,
            _ => {}
        }
        if braces < 0 || brackets < 0 {
            return Err("unbalanced closing brace/bracket in trace JSON".to_string());
        }
    }
    if in_str {
        return Err("unterminated string in trace JSON".to_string());
    }
    if braces != 0 || brackets != 0 {
        return Err(format!("unbalanced trace JSON ({braces} braces, {brackets} brackets open)"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::{Mark, Phase, Span};
    use super::*;

    fn span(lane: u32, phase: Phase, t0: f64, dur: f64) -> Span {
        Span {
            lane,
            phase,
            t0_ms: t0,
            dur_ms: dur,
            aabb_tests: 7,
            isect_force_evals: 0,
            bytes_moved: 64,
            wall_ms: Some(0.25),
        }
    }

    fn step(n: u64, t0: f64, dur: f64, spans: Vec<Span>) -> StepSpans {
        StepSpans {
            step: n,
            t0_ms: t0,
            dur_ms: dur,
            spans,
            marks: vec![Mark {
                lane: GLOBAL_LANE,
                t_ms: t0,
                tag: "checkpoint",
                label: "checkpoint \"quoted\"".to_string(),
            }],
        }
    }

    #[test]
    fn render_emits_lanes_slices_and_markers() {
        let steps = vec![step(
            0,
            0.0,
            2.0,
            vec![span(0, Phase::Build, 0.0, 1.0), span(0, Phase::Traverse, 1.0, 1.0)],
        )];
        let lanes = vec![(0u32, "shard 0 (L40)".to_string()), (GLOBAL_LANE, "fleet".to_string())];
        let js = render(&steps, &lanes);
        assert!(js.contains("\"traceEvents\""), "{js}");
        assert!(js.contains("thread_name"), "{js}");
        assert!(js.contains("\"name\":\"build\""), "{js}");
        assert!(js.contains("\"cat\":\"step\""), "{js}");
        assert!(js.contains("\"ph\":\"i\""), "{js}");
        assert!(js.contains("\"wall_ms\":0.25"), "{js}");
        validate_json(&js).unwrap();
    }

    #[test]
    fn validate_accepts_sequential_and_rejects_overlap() {
        let a = span(0, Phase::Build, 0.0, 1.0);
        let good = vec![
            step(0, 0.0, 2.0, vec![a, span(0, Phase::Force, 1.0, 0.5)]),
            step(1, 2.0, 1.0, vec![span(0, Phase::Refit, 2.0, 0.5)]),
        ];
        validate(&good).unwrap();
        let overlap = vec![step(0, 0.0, 2.0, vec![a, span(0, Phase::Force, 0.5, 1.0)])];
        assert!(validate(&overlap).is_err());
        let backwards = vec![step(1, 5.0, 1.0, vec![]), step(2, 4.0, 1.0, vec![])];
        assert!(validate(&backwards).is_err());
        let negdur = vec![step(0, 0.0, 1.0, vec![span(0, Phase::Sort, 0.0, -1.0)])];
        assert!(validate(&negdur).is_err());
    }

    #[test]
    fn validate_json_catches_truncation_and_respects_strings() {
        let ok = "{\"a\":[{\"s\":\"br{ack]et \\\" soup\"}]}";
        validate_json(ok).unwrap();
        assert!(validate_json("{\"a\":[1,2}").is_err());
        assert!(validate_json("[1,2]").is_err());
        assert!(validate_json("{\"a\":\"unterminated}").is_err());
    }
}
