//! Contribution #1 — **gradient**: the adaptive real-time BVH
//! update/rebuild ratio optimizer, plus the reference policies it is
//! evaluated against (paper §3.1, §4.1 / Fig. 8).

pub mod cost_model;
pub mod policy;

pub use cost_model::{optimal_ku, simulation_cost, CostParams};
pub use policy::{AvgPolicy, BvhAction, FixedKPolicy, GradientPolicy, RebuildPolicy, StepObs};
