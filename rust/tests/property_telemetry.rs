//! Telemetry acceptance properties (ISSUE):
//!
//!  (a) a traced run is **bitwise identical** to an untraced run — the
//!      recorder only reads quantities the engines already computed, so
//!      flipping `--trace-out` can never perturb physics;
//!  (b) identical runs emit identical span trees *modulo wall-clock*:
//!      every simulated-time field of every span is bitwise stable across
//!      worker thread counts, while `wall_ms` is excluded from the
//!      comparison (it is the one report-only nondeterministic field);
//!  (c) the flight recorder is a bounded ring that keeps the tail of the
//!      run, and a faulted run's dump carries the loss/recovery forensics.
//!
//! Properties are exercised for thread counts {1, 8} and, where the
//! sharded engine is involved, shard grids S ∈ {1, 2} across all three RT
//! backends (RT-REF, ORCS-forces, ORCS-persé).

use std::sync::Arc;

use orcs::coordinator::{Engine, EngineConfig};
use orcs::core::config::{Boundary, ParticleDist, RadiusDist, ShardSpec, SimConfig};
use orcs::core::vec3::Vec3;
use orcs::frnn::{ApproachKind, RustKernels};
use orcs::resilience::{FaultPlan, ResilienceConfig};
use orcs::telemetry::{chrome, StepSpans};

fn scenario(n: usize, seed: u64) -> SimConfig {
    SimConfig {
        n,
        box_l: 100.0,
        particle_dist: ParticleDist::Disordered,
        radius_dist: RadiusDist::Const(8.0),
        boundary: Boundary::Periodic,
        seed,
        ..SimConfig::default()
    }
}

fn assert_bits_equal(got: &[Vec3], want: &[Vec3], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for i in 0..want.len() {
        let (a, b) = (got[i], want[i]);
        assert_eq!(
            (a.x.to_bits(), a.y.to_bits(), a.z.to_bits()),
            (b.x.to_bits(), b.y.to_bits(), b.z.to_bits()),
            "{ctx}: particle {i} diverged: {a:?} vs {b:?}"
        );
    }
}

fn engine(cfg: &SimConfig, threads: usize) -> Engine {
    let ec = EngineConfig {
        policy: "fixed-3".into(),
        threads,
        ..EngineConfig::new(cfg.clone(), ApproachKind::RtRef)
    };
    Engine::new(ec, Arc::new(RustKernels { threads })).unwrap()
}

fn sharded(
    cfg: &SimConfig,
    backend: ApproachKind,
    s: usize,
    threads: usize,
    res: ResilienceConfig,
) -> orcs::shard::ShardedEngine {
    let sc = orcs::shard::ShardedConfig {
        policy: "fixed-3".into(),
        threads,
        fleet: vec![&orcs::rtcore::profile::TITANRTX, &orcs::rtcore::profile::L40],
        resilience: res,
        backend,
        ..orcs::shard::ShardedConfig::new(cfg.clone(), ShardSpec::new(s))
    };
    orcs::shard::ShardedEngine::new(sc, Arc::new(RustKernels { threads })).unwrap()
}

const SHARDED_BACKENDS: [ApproachKind; 3] =
    [ApproachKind::RtRef, ApproachKind::OrcsForces, ApproachKind::OrcsPerse];

/// Everything that must be deterministic about a span tree: step ids and,
/// per span, lane/phase plus the bit patterns of the simulated times and
/// the op counters. `wall_ms` is deliberately absent.
type SpanKey = (u64, u32, &'static str, u64, u64, u64, u64, u64);

fn span_keys(steps: &[StepSpans]) -> Vec<SpanKey> {
    let mut out = Vec::new();
    for st in steps {
        for sp in &st.spans {
            out.push((
                st.step,
                sp.lane,
                sp.phase.label(),
                sp.t0_ms.to_bits(),
                sp.dur_ms.to_bits(),
                sp.aabb_tests,
                sp.isect_force_evals,
                sp.bytes_moved,
            ));
        }
    }
    out
}

fn mark_labels(steps: &[StepSpans]) -> Vec<(u64, String)> {
    steps
        .iter()
        .flat_map(|st| st.marks.iter().map(move |m| (st.step, m.label.clone())))
        .collect()
}

// ---- property (a): tracing never perturbs the trajectory ----------------

#[test]
fn telemetry_traced_engine_run_is_bitwise_identical_to_untraced() {
    let cfg = scenario(300, 7);
    let steps = 6;
    for threads in [1usize, 8] {
        let ctx = format!("engine traced-vs-untraced threads={threads}");
        let mut plain = engine(&cfg, threads);
        plain.run(steps, false).unwrap();

        let mut traced = engine(&cfg, threads);
        traced.telemetry_mut().enable_trace();
        traced.run(steps, false).unwrap();
        assert_eq!(traced.telemetry().steps().len(), steps, "{ctx}: retained steps");
        assert_bits_equal(&traced.state.pos, &plain.state.pos, &ctx);
        assert_bits_equal(&traced.state.vel, &plain.state.vel, &ctx);
        assert_bits_equal(&traced.state.force, &plain.state.force, &ctx);
    }
}

#[test]
fn telemetry_traced_sharded_run_is_bitwise_identical_to_untraced() {
    let cfg = scenario(220, 99);
    let steps = 6;
    for backend in SHARDED_BACKENDS {
        for s in [1usize, 2] {
            for threads in [1usize, 8] {
                let ctx = format!(
                    "sharded traced-vs-untraced {} S={s} threads={threads}",
                    backend.label()
                );
                let mut plain = sharded(&cfg, backend, s, threads, ResilienceConfig::default());
                plain.run(steps, false).unwrap();

                let mut traced = sharded(&cfg, backend, s, threads, ResilienceConfig::default());
                traced.telemetry_mut().enable_trace();
                traced.run(steps, false).unwrap();
                assert_eq!(traced.telemetry().steps().len(), steps, "{ctx}: retained steps");
                assert_bits_equal(&traced.state.pos, &plain.state.pos, &ctx);
                assert_bits_equal(&traced.state.vel, &plain.state.vel, &ctx);
            }
        }
    }
}

// ---- property (b): span trees are bitwise stable modulo wall-clock ------

#[test]
fn telemetry_span_tree_is_identical_across_thread_counts_modulo_wall() {
    let cfg = scenario(300, 7);
    let steps = 5;
    let run = |threads: usize| {
        let mut e = engine(&cfg, threads);
        e.telemetry_mut().enable_trace();
        e.run(steps, false).unwrap();
        e
    };
    let a = run(1);
    let b = run(8);
    let (ka, kb) = (span_keys(a.telemetry().steps()), span_keys(b.telemetry().steps()));
    assert!(!ka.is_empty(), "the traced run must have recorded spans");
    assert_eq!(ka, kb, "span trees must agree bitwise across thread counts");
    assert_eq!(mark_labels(a.telemetry().steps()), mark_labels(b.telemetry().steps()));
    // the one field the comparison excludes really is being captured: the
    // backends meter host wall time through the blessed wallclock module
    let has_wall = a
        .telemetry()
        .steps()
        .iter()
        .flat_map(|st| st.spans.iter())
        .any(|sp| sp.wall_ms.is_some());
    assert!(has_wall, "single-domain spans must carry report-only wall_ms");
}

#[test]
fn telemetry_sharded_span_tree_is_identical_across_thread_counts() {
    let cfg = scenario(220, 99);
    let steps = 5;
    for backend in SHARDED_BACKENDS {
        for s in [1usize, 2] {
            let ctx = format!("sharded span tree {} S={s}", backend.label());
            let run = |threads: usize| {
                let mut e = sharded(&cfg, backend, s, threads, ResilienceConfig::default());
                e.telemetry_mut().enable_trace();
                e.run(steps, false).unwrap();
                e
            };
            let a = run(1);
            let b = run(8);
            let (ka, kb) = (span_keys(a.telemetry().steps()), span_keys(b.telemetry().steps()));
            assert!(!ka.is_empty(), "{ctx}: spans recorded");
            assert_eq!(ka, kb, "{ctx}: bitwise-stable across thread counts");
            assert_eq!(mark_labels(a.telemetry().steps()), mark_labels(b.telemetry().steps()));
            // the sharded trace must survive Chrome export end to end
            chrome::validate(a.telemetry().steps()).expect("trace must validate");
            let js = chrome::render(a.telemetry().steps(), &a.telemetry().lanes());
            chrome::validate_json(&js).expect("rendered JSON must be balanced");
        }
    }
}

#[test]
fn telemetry_sharded_runs_record_gather_and_scatter_spans() {
    // the halo exchange decomposes into phases the trace can attribute:
    // every multi-shard run prices a `gather` span per shard with ghosts,
    // and the listless ORCS-forces backend adds a `scatter` span on shards
    // that fold cross-shard force contributions back to remote owners
    let cfg = scenario(220, 99);
    let phases = |e: &orcs::shard::ShardedEngine, label: &str| -> usize {
        e.telemetry()
            .steps()
            .iter()
            .flat_map(|st| st.spans.iter())
            .filter(|sp| sp.phase.label() == label)
            .count()
    };
    for backend in SHARDED_BACKENDS {
        let ctx = format!("spans {}", backend.label());
        let mut e = sharded(&cfg, backend, 2, 2, ResilienceConfig::default());
        e.telemetry_mut().enable_trace();
        e.run(4, false).unwrap();
        assert!(phases(&e, "gather") > 0, "{ctx}: no gather span in a multi-shard run");
        if backend == ApproachKind::OrcsForces {
            assert!(phases(&e, "scatter") > 0, "{ctx}: no scatter span at S=2");
        }
        // a single shard owns every source: nothing to gather or fold back
        let mut solo = sharded(&cfg, backend, 1, 2, ResilienceConfig::default());
        solo.telemetry_mut().enable_trace();
        solo.run(4, false).unwrap();
        assert_eq!(phases(&solo, "scatter"), 0, "{ctx}: scatter span on a single shard");
    }
}

// ---- property (c): the flight recorder is bounded and forensic ----------

#[test]
fn telemetry_flight_ring_keeps_the_default_tail() {
    let cfg = scenario(120, 3);
    let mut e = engine(&cfg, 2);
    e.run(40, false).unwrap();
    let steps: Vec<u64> = e.telemetry().flight_steps().iter().map(|s| s.step).collect();
    assert_eq!(steps.len(), 32, "default flight depth");
    assert_eq!(steps[0], 8, "the ring keeps the tail, dropping the head");
    assert_eq!(*steps.last().unwrap(), 39);
}

#[test]
fn telemetry_faulted_run_dump_carries_loss_and_recovery_forensics() {
    let cfg = scenario(220, 13);
    let res = ResilienceConfig {
        checkpoint_every: 2,
        faults: FaultPlan::parse("lost@5:1").unwrap(),
        ..ResilienceConfig::default()
    };
    let mut e = sharded(&cfg, ApproachKind::RtRef, 2, 2, res);
    let sum = e.run(8, false).unwrap();
    assert!(sum.replayed_steps > 0, "the loss must have triggered recovery");
    let dump = e.telemetry().flight_dump();
    assert!(dump.contains("lost"), "dump must show the device loss:\n{dump}");
    assert!(dump.contains("recovered"), "dump must show the recovery:\n{dump}");
    assert!(dump.contains("checkpoint"), "dump must show checkpoints:\n{dump}");
}
