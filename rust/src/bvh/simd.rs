//! Explicit SIMD kernels for the 4-lane quantized point-in-box test.
//!
//! The quantized SoA node ([`Bvh4Node`]) stores each axis's lane bounds as
//! `[u8; 4]` arrays, so the traversal hot loop's lane test is four
//! independent integer interval checks — exactly one 128-bit vector op per
//! compare once the bytes are widened. This module provides that test three
//! ways:
//!
//! * [`lane_mask_scalar`] — the portable reference: plain integer compares,
//!   auto-vectorized at best;
//! * `SSE2` (x86_64) and `NEON` (aarch64) kernels via `core::arch`
//!   intrinsics.
//!
//! All kernels compute the *same pure integer function* of
//! `(node bounds, quantized query point)` — no floating point, no rounding
//! modes — so their lane masks are **bit-identical by construction**; the
//! property suite (`tests/property_quantized.rs`) and the unit tests below
//! assert it lane-for-lane over edge-pattern nodes. The query point is
//! quantized *once, in scalar code* ([`Bvh4Node::quantize_query`], which
//! clamps in f32 before the cast precisely so no saturation behavior
//! difference between scalar `as` and vector conversions can ever be
//! observed) and shared by every kernel.
//!
//! # The test
//!
//! A lane passes iff, per axis, `qp + 1 >= qmin && qp - 1 <= qmax` — the ±1
//! slack absorbs the one unit the float quantization of the query point can
//! be off by, keeping the test conservative (may widen, never misses; see
//! `quantize_query`). Empty lanes carry inverted sentinel bounds
//! (`qmin = 255 > qmax = 0`), which no `qp` in the clamped `[-1, 256]`
//! range can satisfy on *both* sides of an axis, so they fail with no
//! special-casing.
//!
//! # Selection
//!
//! The kernel is picked once per process ([`active_kernel`], cached in an
//! atomic): runtime feature detection chooses the widest supported kernel,
//! and the `ORCS_SIMD=scalar` escape hatch (read through the blessed env
//! site [`crate::parallel::simd_force_scalar`]) forces the fallback — the
//! CI matrix runs a leg with it set so the scalar path stays exercised.
//! [`set_kernel`] overrides the cache for benches and differential tests.

use super::{Bvh4Node, BVH4_WIDTH};
use std::sync::atomic::{AtomicU8, Ordering};

/// A lane-test kernel. Variants only exist on architectures where the
/// corresponding intrinsics do, so constructing one is always safe:
/// `Sse2` requires SSE2, which is baseline for the x86_64 ABI, and `Neon`
/// is baseline for aarch64.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Portable integer compares — the reference all kernels must match.
    Scalar,
    /// `core::arch::x86_64` 128-bit integer SIMD.
    #[cfg(target_arch = "x86_64")]
    Sse2,
    /// `core::arch::aarch64` Advanced SIMD.
    #[cfg(target_arch = "aarch64")]
    Neon,
}

/// Cached selection: 0 = undecided, then `encode(kernel)`.
static KERNEL: AtomicU8 = AtomicU8::new(0);

fn encode(k: Kernel) -> u8 {
    match k {
        Kernel::Scalar => 1,
        #[cfg(target_arch = "x86_64")]
        Kernel::Sse2 => 2,
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => 3,
    }
}

/// Detect the widest kernel supported at runtime, honoring the
/// `ORCS_SIMD=scalar` escape hatch. Pure detection — does not touch the
/// cached selection.
pub fn detect_kernel() -> Kernel {
    if crate::parallel::simd_force_scalar() {
        return Kernel::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        // SSE2 is ABI-baseline on x86_64; the check is defense in depth.
        if is_x86_feature_detected!("sse2") {
            return Kernel::Sse2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return Kernel::Neon;
    }
    #[cfg_attr(target_arch = "aarch64", allow(unreachable_code))]
    Kernel::Scalar
}

/// The kernel the traversal hot loop uses: detected on first call, then a
/// single relaxed atomic load.
#[inline]
pub fn active_kernel() -> Kernel {
    match KERNEL.load(Ordering::Relaxed) {
        1 => Kernel::Scalar,
        #[cfg(target_arch = "x86_64")]
        2 => Kernel::Sse2,
        #[cfg(target_arch = "aarch64")]
        3 => Kernel::Neon,
        _ => {
            let k = detect_kernel();
            KERNEL.store(encode(k), Ordering::Relaxed);
            k
        }
    }
}

/// Override the cached selection (benches and differential tests; results
/// are bit-identical whichever kernel is active, so this is a perf knob,
/// never a correctness one).
pub fn set_kernel(k: Kernel) {
    KERNEL.store(encode(k), Ordering::Relaxed);
}

/// Lane mask of `node` for quantized query point `qp` (bit `l` set = lane
/// `l` passes), using the process-wide active kernel.
#[inline(always)]
pub fn lane_mask(node: &Bvh4Node, qp: [i32; 3]) -> u32 {
    lane_mask_with(active_kernel(), node, qp)
}

/// [`lane_mask`] with an explicit kernel.
#[inline(always)]
pub fn lane_mask_with(kern: Kernel, node: &Bvh4Node, qp: [i32; 3]) -> u32 {
    match kern {
        Kernel::Scalar => lane_mask_scalar(node, qp),
        // SAFETY: the Sse2 variant only exists on x86_64, where SSE2 is
        // ABI-baseline (and detect_kernel re-verified it at selection).
        #[cfg(target_arch = "x86_64")]
        Kernel::Sse2 => unsafe { lane_mask_sse2(node, qp) },
        // SAFETY: NEON is baseline on aarch64.
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => unsafe { lane_mask_neon(node, qp) },
    }
}

/// Portable reference kernel: the pure integer function every SIMD kernel
/// must reproduce bit-for-bit. `qp` comes from
/// [`Bvh4Node::quantize_query`], clamped to `[-1, 256]`, so the ±1 slack
/// arithmetic cannot overflow.
pub fn lane_mask_scalar(node: &Bvh4Node, qp: [i32; 3]) -> u32 {
    let [qx, qy, qz] = qp;
    let mut mask = 0u32;
    for lane in 0..BVH4_WIDTH {
        let pass = qx + 1 >= node.qmin_x[lane] as i32
            && qx - 1 <= node.qmax_x[lane] as i32
            && qy + 1 >= node.qmin_y[lane] as i32
            && qy - 1 <= node.qmax_y[lane] as i32
            && qz + 1 >= node.qmin_z[lane] as i32
            && qz - 1 <= node.qmax_z[lane] as i32;
        mask |= (pass as u32) << lane;
    }
    mask
}

/// SSE2 kernel: per axis, widen the four `u8` bounds to `i32x4`, form
/// `miss = (qmin > qp+1) | (qp-1 > qmax)` with `_mm_cmpgt_epi32`, OR the
/// three axes, and movemask-invert into the pass mask. Identical integer
/// arithmetic to [`lane_mask_scalar`], so identical results.
///
/// # Safety
/// Requires SSE2 (ABI-baseline on x86_64; the dispatcher only selects this
/// after runtime detection). All operations are value-only vector ops —
/// the only memory read is the safe `[u8; 4]` field copies.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn lane_mask_sse2(node: &Bvh4Node, qp: [i32; 3]) -> u32 {
    use core::arch::x86_64::{
        __m128i, _mm_castsi128_ps, _mm_cmpgt_epi32, _mm_cvtsi32_si128, _mm_movemask_ps,
        _mm_or_si128, _mm_set1_epi32, _mm_setzero_si128, _mm_unpacklo_epi16, _mm_unpacklo_epi8,
    };

    /// Zero-extend a `[u8; 4]` lane array into `i32x4`.
    ///
    /// # Safety
    /// Value-only vector ops on a 32-bit scalar moved into a register; no
    /// memory access. Caller provides SSE2 (enforced by the outer kernel's
    /// target_feature).
    #[inline(always)]
    unsafe fn widen(b: [u8; 4]) -> __m128i {
        // SAFETY: value-only intrinsics, SSE2 guaranteed by the caller.
        unsafe {
            let v = _mm_cvtsi32_si128(i32::from_ne_bytes(b));
            let z = _mm_setzero_si128();
            _mm_unpacklo_epi16(_mm_unpacklo_epi8(v, z), z)
        }
    }

    let [qx, qy, qz] = qp;
    // SAFETY: value-only SSE2 intrinsics; see the function-level contract.
    unsafe {
        let mut miss = _mm_setzero_si128();
        for (qmin, qmax, q) in [
            (node.qmin_x, node.qmax_x, qx),
            (node.qmin_y, node.qmax_y, qy),
            (node.qmin_z, node.qmax_z, qz),
        ] {
            let lo = widen(qmin);
            let hi = widen(qmax);
            miss = _mm_or_si128(miss, _mm_cmpgt_epi32(lo, _mm_set1_epi32(q + 1)));
            miss = _mm_or_si128(miss, _mm_cmpgt_epi32(_mm_set1_epi32(q - 1), hi));
        }
        // cmp results are all-ones per missing lane -> sign bits -> bitmask
        let miss_bits = _mm_movemask_ps(_mm_castsi128_ps(miss)) as u32;
        !miss_bits & 0xF
    }
}

/// NEON kernel: same structure as the SSE2 one — widen `u8x4` to `i32x4`,
/// OR per-axis `(qmin > qp+1) | (qp-1 > qmax)` misses, invert. Identical
/// integer arithmetic to [`lane_mask_scalar`], so identical results.
///
/// # Safety
/// Requires NEON, which is baseline on aarch64. Value-only vector ops; the
/// only memory read is the safe `[u8; 4]` field copies.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn lane_mask_neon(node: &Bvh4Node, qp: [i32; 3]) -> u32 {
    use core::arch::aarch64::{
        int32x4_t, vcgtq_s32, vcreate_u8, vdupq_n_s32, vdupq_n_u32, vget_low_u16,
        vgetq_lane_u32, vmovl_u16, vmovl_u8, vorrq_u32, vreinterpretq_s32_u32,
    };

    /// Zero-extend a `[u8; 4]` lane array into `i32x4`.
    ///
    /// # Safety
    /// Value-only NEON intrinsics (baseline on aarch64); no memory access.
    #[inline(always)]
    unsafe fn widen(b: [u8; 4]) -> int32x4_t {
        // SAFETY: value-only intrinsics, NEON is aarch64 baseline.
        unsafe {
            let v8 = vcreate_u8(u32::from_ne_bytes(b) as u64);
            vreinterpretq_s32_u32(vmovl_u16(vget_low_u16(vmovl_u8(v8))))
        }
    }

    let [qx, qy, qz] = qp;
    // SAFETY: value-only NEON intrinsics; see the function-level contract.
    unsafe {
        let mut miss = vdupq_n_u32(0);
        for (qmin, qmax, q) in [
            (node.qmin_x, node.qmax_x, qx),
            (node.qmin_y, node.qmax_y, qy),
            (node.qmin_z, node.qmax_z, qz),
        ] {
            let lo = widen(qmin);
            let hi = widen(qmax);
            miss = vorrq_u32(miss, vcgtq_s32(lo, vdupq_n_s32(q + 1)));
            miss = vorrq_u32(miss, vcgtq_s32(vdupq_n_s32(q - 1), hi));
        }
        let m0 = vgetq_lane_u32::<0>(miss) & 1;
        let m1 = vgetq_lane_u32::<1>(miss) & 1;
        let m2 = vgetq_lane_u32::<2>(miss) & 1;
        let m3 = vgetq_lane_u32::<3>(miss) & 1;
        !(m0 | (m1 << 1) | (m2 << 2) | (m3 << 3)) & 0xF
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::aabb::Aabb;
    use crate::core::rng::Rng;
    use crate::core::vec3::Vec3;

    /// Every kernel available on this architecture (always includes the
    /// scalar reference).
    fn all_kernels() -> Vec<Kernel> {
        let mut ks = vec![Kernel::Scalar];
        #[cfg(target_arch = "x86_64")]
        if is_x86_feature_detected!("sse2") {
            ks.push(Kernel::Sse2);
        }
        #[cfg(target_arch = "aarch64")]
        ks.push(Kernel::Neon);
        ks
    }

    fn random_node(rng: &mut Rng) -> Bvh4Node {
        let mut lanes = Vec::new();
        let k = 1 + rng.below(BVH4_WIDTH);
        for lane in 0..k {
            let lo = Vec3::new(
                rng.range_f32(-100.0, 100.0),
                rng.range_f32(-100.0, 100.0),
                rng.range_f32(-100.0, 100.0),
            );
            let ext = Vec3::new(
                rng.range_f32(0.0, 40.0),
                rng.range_f32(0.0, 40.0),
                rng.range_f32(0.0, 40.0),
            );
            lanes.push((Aabb::new(lo, lo + ext), lane as u32, 0u32));
        }
        Bvh4Node::pack(&lanes)
    }

    #[test]
    fn kernels_agree_on_random_nodes_exhaustive_grid() {
        // every kernel, every lane pattern, the full clamped qp range on
        // each axis (crossed with two fixed values on the others)
        let mut rng = Rng::new(97);
        for case in 0..100 {
            let node = random_node(&mut rng);
            for qx in -1..=256 {
                for &(qy, qz) in &[(0, 128), (-1, 256), (255, 1)] {
                    let qp = [qx, qy, qz];
                    let want = lane_mask_scalar(&node, qp);
                    for &k in &all_kernels() {
                        assert_eq!(
                            lane_mask_with(k, &node, qp),
                            want,
                            "case={case} kernel={k:?} qp={qp:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn empty_lanes_never_pass() {
        // the EMPTY node (all lanes sentinel) fails for every qp, under
        // every kernel — including the clamp endpoints
        let node = Bvh4Node::EMPTY;
        for qx in -1..=256 {
            for qy in [-1, 0, 1, 128, 255, 256] {
                for qz in [-1, 0, 1, 128, 255, 256] {
                    for &k in &all_kernels() {
                        assert_eq!(lane_mask_with(k, &node, [qx, qy, qz]), 0, "kernel={k:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn infinite_positions_clamp_into_valid_masks() {
        // ±inf query coordinates clamp to the qp endpoints (quantize_query);
        // all kernels must agree there too (the NaN-free guarantee is the
        // caller's: the watchdog rejects non-finite states)
        let mut rng = Rng::new(98);
        for _ in 0..50 {
            let node = random_node(&mut rng);
            for p in [
                Vec3::splat(f32::INFINITY),
                Vec3::splat(f32::NEG_INFINITY),
                Vec3::new(f32::INFINITY, 0.0, f32::NEG_INFINITY),
            ] {
                let qp = node.quantize_query(p);
                for a in qp {
                    assert!((-1..=256).contains(&a), "qp axis out of clamp range");
                }
                let want = lane_mask_scalar(&node, qp);
                for &k in &all_kernels() {
                    assert_eq!(lane_mask_with(k, &node, qp), want, "kernel={k:?} p={p:?}");
                }
            }
        }
    }

    #[test]
    fn points_inside_lane_boxes_always_pass() {
        // conservative contract at the kernel level: a point inside a
        // dequantized lane box passes that lane under every kernel
        let mut rng = Rng::new(99);
        for _ in 0..200 {
            let node = random_node(&mut rng);
            for lane in 0..BVH4_WIDTH {
                if !node.lane_used(lane) {
                    continue;
                }
                let bb = node.lane_aabb(lane);
                let p = Vec3::new(
                    bb.lo.x + (bb.hi.x - bb.lo.x) * rng.f32(),
                    bb.lo.y + (bb.hi.y - bb.lo.y) * rng.f32(),
                    bb.lo.z + (bb.hi.z - bb.lo.z) * rng.f32(),
                );
                let qp = node.quantize_query(p);
                for &k in &all_kernels() {
                    assert_eq!(
                        lane_mask_with(k, &node, qp) >> lane & 1,
                        1,
                        "kernel={k:?} lane={lane} p={p:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn detection_is_cached_and_overridable() {
        let first = active_kernel();
        assert_eq!(active_kernel(), first);
        set_kernel(Kernel::Scalar);
        assert_eq!(active_kernel(), Kernel::Scalar);
        set_kernel(first);
        assert_eq!(active_kernel(), first);
    }
}
