//! Micro-benchmarks of the hot paths (the §Perf profiling harness):
//! BVH build / refit / query, cell sweep, radix sort, and the XLA force
//! kernel dispatch. Plain timing loops (no criterion in the offline vendor
//! set) with min/mean reporting over R repetitions.
//!
//! `cargo bench --bench micro [-- --n N]`

use std::time::Instant;

use orcs::bvh::{BuildKind, Bvh};
use orcs::core::config::{Boundary, RadiusDist, SimConfig};
use orcs::core::rng::Rng;
use orcs::core::vec3::Vec3;
use orcs::frnn::cell_list::{cell_forces, Grid};
use orcs::frnn::gpu_cell::radix_sort_pairs;
use orcs::physics::state::SimState;

fn bench<F: FnMut()>(name: &str, reps: usize, mut f: F) {
    // warmup
    f();
    let mut best = f64::INFINITY;
    let mut total = 0.0;
    for _ in 0..reps {
        let t = Instant::now();
        f();
        let dt = t.elapsed().as_secs_f64();
        best = best.min(dt);
        total += dt;
    }
    println!(
        "{name:<44} min {:>10.3} ms   mean {:>10.3} ms",
        best * 1e3,
        total / reps as f64 * 1e3
    );
}

fn main() {
    let n: usize = std::env::args()
        .skip_while(|a| a != "--n")
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(50_000);
    let reps = 5;
    println!("== micro benches (n={n}, reps={reps}) ==");

    let mut rng = Rng::new(42);
    let pos: Vec<Vec3> = (0..n)
        .map(|_| {
            Vec3::new(
                rng.range_f32(0.0, 1000.0),
                rng.range_f32(0.0, 1000.0),
                rng.range_f32(0.0, 1000.0),
            )
        })
        .collect();
    let radius: Vec<f32> = (0..n).map(|_| rng.range_f32(1.0, 20.0)).collect();

    bench("bvh build (binned SAH)", reps, || {
        let b = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
        std::hint::black_box(b.node_count());
    });
    bench("bvh build (median)", reps, || {
        let b = Bvh::build(&pos, &radius, BuildKind::Median);
        std::hint::black_box(b.node_count());
    });
    bench("bvh build (LBVH / morton)", reps, || {
        let b = Bvh::build(&pos, &radius, BuildKind::Lbvh);
        std::hint::black_box(b.node_count());
    });

    let mut bvh = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
    bench("bvh refit", reps, || {
        bvh.refit(&pos, &radius);
    });

    let bvh = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
    bench("bvh query x n (per-point, 1 thread)", reps, || {
        let mut scratch = orcs::bvh::traverse::QueryScratch::new();
        let mut acc = 0usize;
        for i in 0..n {
            bvh.query_point(pos[i], i, &pos, &radius, &mut scratch, |_| acc += 1);
        }
        std::hint::black_box((acc, scratch.stats.aabb_tests));
    });
    let threads = orcs::parallel::num_threads();
    bench(&format!("bvh query_batch x n ({threads} threads)"), reps, || {
        let (hits, stats) = bvh.query_batch(
            n,
            threads,
            || (),
            |_, scratch, range| {
                let mut acc = 0usize;
                for i in range {
                    bvh.query_point(pos[i], i, &pos, &radius, scratch, |_| acc += 1);
                }
                acc
            },
        );
        let acc: usize = hits.iter().sum();
        std::hint::black_box((acc, stats.aabb_tests));
    });

    let cfg = SimConfig {
        n,
        boundary: Boundary::Periodic,
        radius_dist: RadiusDist::Const(10.0),
        ..SimConfig::default()
    };
    let state = SimState::from_config(&cfg);
    bench("cell grid build", reps, || {
        let g = Grid::build(&state.pos, state.box_l, state.r_max);
        std::hint::black_box(matches!(g, Grid::Dense(_)));
    });
    let grid = Grid::build(&state.pos, state.box_l, state.r_max);
    bench("cell sweep forces", reps, || {
        let (f, t, e, v) = cell_forces(&state, &grid, orcs::parallel::num_threads());
        std::hint::black_box((f.len(), t, e, v));
    });

    bench("radix sort (morton pairs, serial)", reps, || {
        let mut keys: Vec<u32> =
            pos.iter().map(|&p| orcs::frnn::gpu_cell::morton30(p, 1000.0)).collect();
        let mut vals: Vec<u32> = (0..n as u32).collect();
        radix_sort_pairs(&mut keys, &mut vals);
        std::hint::black_box(keys[0]);
    });
    bench(&format!("radix sort (morton pairs, {threads} threads)"), reps, || {
        let mut keys: Vec<u32> =
            pos.iter().map(|&p| orcs::frnn::gpu_cell::morton30(p, 1000.0)).collect();
        let mut vals: Vec<u32> = (0..n as u32).collect();
        orcs::frnn::gpu_cell::radix_sort_pairs_mt(&mut keys, &mut vals, threads);
        std::hint::black_box(keys[0]);
    });
    bench("bvh build (binned SAH, 1 thread)", reps, || {
        let b = Bvh::build_with_threads(&pos, &radius, BuildKind::BinnedSah, 1);
        std::hint::black_box(b.node_count());
    });

    // XLA dispatch cost (needs artifacts; skipped when absent)
    match orcs::runtime::kernels::XlaKernels::load_default() {
        Ok(kernels) => {
            use orcs::frnn::{NeighborLists, PhysicsKernels};
            let small_cfg = SimConfig { n: 4096, ..cfg };
            let mut sstate = SimState::from_config(&small_cfg);
            let lists = NeighborLists::from_vecs(
                &(0..4096)
                    .map(|i| vec![((i + 1) % 4096) as u32; 16])
                    .collect::<Vec<_>>(),
            );
            let mut counts = orcs::rtcore::OpCounts::default();
            bench("xla lj_forces (1 chunk, k=16)", reps, || {
                let f = kernels.lj_forces(&sstate, &lists, &mut counts).unwrap();
                std::hint::black_box(f.len());
            });
            bench("xla integrate (1 chunk)", reps, || {
                kernels.integrate(&mut sstate, &mut counts).unwrap();
            });
        }
        Err(e) => println!("xla benches skipped: {e}"),
    }
}
