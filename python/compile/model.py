"""L2 — the JAX compute graphs the Rust coordinator executes via PJRT.

Two graphs, both lowered by `aot.py` to HLO text:

* ``lj_forces_graph`` — the RT-REF "separate force kernel": per-particle LJ
  force + potential energy from host-gathered neighbor slots. Wraps the L1
  Pallas kernel (`kernels/lj.py`).
* ``integrate_graph`` — the "apply forces" kernel: symplectic-Euler update
  (boundary handling remains in Rust, see DESIGN.md §Three-layer).

Python never runs at simulation time: these functions exist only to be
lowered once by ``make artifacts``.
"""

import jax.numpy as jnp

from .kernels import lj as lj_kernel
from .kernels import ref


def lj_forces_graph(pos, nbr_pos, rad, nbr_rad, mask, scal):
    """Neighbor-force graph (C, K static). scal = (box_l, eps, sigma_factor,
    f_max). Returns (force (C,3), pe (C,))."""
    force, pe = lj_kernel.lj_forces_pallas(pos, nbr_pos, rad, nbr_rad, mask, scal)
    return force, pe


def lj_forces_graph_ref(pos, nbr_pos, rad, nbr_rad, mask, scal):
    """Same computation through the pure-jnp oracle (compiled for the
    runtime cross-check test; not used on the hot path)."""
    return ref.lj_forces_ref(
        pos, nbr_pos, rad, nbr_rad, mask, scal[0], scal[1], scal[2], scal[3]
    )


def integrate_graph(pos, vel, force, scal):
    """Integration kernel. scal = (dt, f_max). Returns (new_pos, new_vel)."""
    dt = scal[0]
    f_max = scal[1]
    f = jnp.clip(force, -f_max, f_max)
    new_vel = vel + f * dt
    new_pos = pos + new_vel * dt
    return new_pos, new_vel
