"""L1 — the Pallas LJ neighbor-force kernel.

The paper's hot spot is the per-neighbor force evaluation (its CUDA force
kernel / intersection shaders). Here it is a Pallas kernel tiled
(BLOCK_C particles) x (K neighbor slots): the BlockSpec expresses the
HBM -> VMEM schedule that the paper's CUDA implementation expresses with
threadblocks (DESIGN.md §Hardware-Adaptation). LJ is element-wise over the
(C, K) pair lattice, so the kernel is VPU-shaped (no MXU): K is padded to
lane multiples by construction (K in {16, 64, 256}).

Lowered with ``interpret=True`` — mandatory for CPU-PJRT execution: a real
TPU lowering emits a Mosaic custom-call the CPU plugin cannot run. The
interpret path produces plain HLO that the Rust runtime compiles and runs.

Scalar parameters (box_l, eps, sigma_factor, f_max) arrive as (1,)-shaped
operands so one compiled artifact serves every scenario configuration.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..shapes import BLOCK_C, R2_MIN


def _lj_kernel(pos_ref, nbr_pos_ref, rad_ref, nbr_rad_ref, mask_ref,
               scal_ref, force_ref, pe_ref):
    """One grid step: forces for a BLOCK_C-particle tile against all K slots.

    scal_ref packs (box_l, eps, sigma_factor, f_max) as a (4,) vector.
    """
    box_l = scal_ref[0]
    eps = scal_ref[1]
    sigma_factor = scal_ref[2]
    f_max = scal_ref[3]

    pos = pos_ref[...]            # (BC, 3)
    nbr_pos = nbr_pos_ref[...]    # (BC, K, 3)
    rad = rad_ref[...]            # (BC,)
    nbr_rad = nbr_rad_ref[...]    # (BC, K)
    mask = mask_ref[...]          # (BC, K)

    dx = pos[:, None, :] - nbr_pos                   # (BC, K, 3)
    dx = dx - box_l * jnp.round(dx / box_l)          # minimum image
    r2 = jnp.sum(dx * dx, axis=-1)                   # (BC, K)

    sigma = (rad[:, None] + nbr_rad) * 0.5 / sigma_factor
    cutoff = jnp.maximum(rad[:, None], nbr_rad)
    valid = (mask > 0.0) & (r2 < cutoff * cutoff) & (r2 > 0.0)

    r2s = jnp.maximum(r2, R2_MIN)
    s2 = (sigma * sigma) / r2s
    s6 = s2 * s2 * s2
    force_scalar = 24.0 * eps * (2.0 * s6 * s6 - s6) / r2s
    pe = 4.0 * eps * (s6 * s6 - s6)

    fvec = jnp.clip(force_scalar[..., None] * dx, -f_max, f_max)
    fvec = jnp.where(valid[..., None], fvec, 0.0)
    pe = jnp.where(valid, pe, 0.0)

    force_ref[...] = jnp.sum(fvec, axis=1)           # (BC, 3)
    pe_ref[...] = jnp.sum(pe, axis=1)                # (BC,)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lj_forces_pallas(pos, nbr_pos, rad, nbr_rad, mask, scal, *, interpret=True):
    """Pallas neighbor-force evaluation.

    Args:
      pos:     (C, 3) f32, C a multiple of BLOCK_C.
      nbr_pos: (C, K, 3) f32 gathered neighbor positions.
      rad:     (C,) f32.
      nbr_rad: (C, K) f32.
      mask:    (C, K) f32 (1 = valid slot).
      scal:    (4,) f32 = (box_l, eps, sigma_factor, f_max).

    Returns:
      force (C, 3) f32, pe (C,) f32.
    """
    c, k = mask.shape
    assert c % BLOCK_C == 0, f"C={c} must be a multiple of {BLOCK_C}"
    grid = (c // BLOCK_C,)
    return pl.pallas_call(
        _lj_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_C, 3), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_C, k, 3), lambda i: (i, 0, 0)),
            pl.BlockSpec((BLOCK_C,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_C, k), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_C, k), lambda i: (i, 0)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_C, 3), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_C,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, 3), jnp.float32),
            jax.ShapeDtypeStruct((c,), jnp.float32),
        ],
        interpret=interpret,
    )(pos, nbr_pos, rad, nbr_rad, mask, scal)
