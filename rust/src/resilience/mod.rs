//! Resilience runtime: typed failures, fault injection, graceful
//! degradation and checkpointed recovery.
//!
//! The paper's §4.2 OOM analysis tells us *when* an RT-REF run dies; this
//! module is what keeps the simulation alive when it does. Four pieces:
//!
//! - [`error`] — the [`SimError`] taxonomy every step failure is
//!   classified into.
//! - [`inject`] — deterministic seeded fault schedules (device loss,
//!   transient faults, VRAM squeezes, stragglers, divergence) consumed by
//!   the engines.
//! - [`watchdog`] — the per-step numerical divergence detector.
//! - [`checkpoint`] — step-boundary snapshots that make `DeviceLost`
//!   recoverable with a bitwise-identical replay.
//!
//! The degradation ladder on OOM is RT-REF → ORCS-persé (listless, uniform
//! radius only) → CPU-CELL; each rung is metered as a priced backend
//! switch and reported as a one-line [`ResilienceEvent`].
//!
//! The default [`ResilienceConfig`] is inert: no faults, no checkpoints,
//! watchdog off, `on_oom = Abort`. Every pre-existing run is byte-for-byte
//! unaffected unless a knob is turned.

pub mod checkpoint;
pub mod error;
pub mod inject;
pub mod watchdog;

pub use error::{SimError, SimResult};
pub use inject::{Fault, FaultInjector, FaultKind, FaultPlan};
pub use watchdog::{Watchdog, WatchdogCfg};

use std::fmt;

/// What to do when `check_oom` trips.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OomPolicy {
    /// Surface the OOM and stop the run (the paper's behavior).
    #[default]
    Abort,
    /// Step down the degradation ladder (RT-REF → ORCS-persé → CPU-CELL)
    /// and keep going, pricing the switch.
    Fallback,
}

impl OomPolicy {
    pub fn parse(s: &str) -> Option<OomPolicy> {
        match s {
            "abort" => Some(OomPolicy::Abort),
            "fallback" => Some(OomPolicy::Fallback),
            _ => None,
        }
    }
}

/// Resilience knobs shared by the coordinator and sharded engines.
#[derive(Clone, Debug, Default)]
pub struct ResilienceConfig {
    pub on_oom: OomPolicy,
    pub watchdog: WatchdogCfg,
    /// Snapshot the run every N steps (0 = no checkpoints).
    pub checkpoint_every: u64,
    /// Injected fault schedule (empty = none).
    pub faults: FaultPlan,
}

impl ResilienceConfig {
    /// Whether any knob is turned — the engines take the zero-overhead raw
    /// path when this is false.
    pub fn active(&self) -> bool {
        self.on_oom == OomPolicy::Fallback
            || self.watchdog.enabled
            || self.checkpoint_every > 0
            || !self.faults.is_empty()
    }
}

/// One line in the resilience log: something happened at `step`.
#[derive(Clone, Debug)]
pub struct ResilienceEvent {
    pub step: u64,
    pub kind: EventKind,
}

/// What happened.
#[derive(Clone, Debug)]
pub enum EventKind {
    /// A backend (or one shard) stepped down the degradation ladder.
    OomFallback {
        from: &'static str,
        to: &'static str,
        /// Affected shard for sharded runs; `None` single-domain.
        shard: Option<usize>,
        required_bytes: u64,
        budget_bytes: u64,
        /// Priced state re-upload for the switch, ms.
        switch_ms: f64,
    },
    /// OOM under `Fallback` but no ladder rung supports the scene.
    FallbackUnavailable { required_bytes: u64 },
    /// The watchdog rejected a step; retrying with halved `dt`.
    WatchdogRetry { attempt: u32, dt: f32, detail: String },
    /// A transient fault discarded one attempt; the re-run succeeded.
    TransientRetry { attempt: u32 },
    /// Injected VRAM squeeze now in effect.
    VramSqueeze { budget_bytes: u64 },
    /// Injected straggler slowdown for this step.
    Straggler { shard: usize, slowdown: f64 },
    /// A device died; `survivors` remain in the fleet.
    DeviceLost { shard: usize, device: String, survivors: usize },
    /// Recovery restored the last checkpoint and is replaying.
    Recovery { from_step: u64, replayed: u64 },
}

impl EventKind {
    /// Short machine-readable tag: telemetry metrics label
    /// (`orcs_events_total{kind=...}`) and trace-marker category.
    pub fn tag(&self) -> &'static str {
        match self {
            EventKind::OomFallback { .. } => "oom_fallback",
            EventKind::FallbackUnavailable { .. } => "fallback_unavailable",
            EventKind::WatchdogRetry { .. } => "watchdog_retry",
            EventKind::TransientRetry { .. } => "transient_retry",
            EventKind::VramSqueeze { .. } => "vram_squeeze",
            EventKind::Straggler { .. } => "straggler",
            EventKind::DeviceLost { .. } => "device_lost",
            EventKind::Recovery { .. } => "recovery",
        }
    }
}

impl fmt::Display for ResilienceEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[step {:>4}] ", self.step)?;
        match &self.kind {
            EventKind::OomFallback { from, to, shard, required_bytes, budget_bytes, switch_ms } => {
                if let Some(s) = shard {
                    write!(f, "shard {s}: ")?;
                }
                write!(
                    f,
                    "OOM ({required_bytes} B > {budget_bytes} B): \
                     fell back {from} -> {to} (+{switch_ms:.3} ms switch)"
                )
            }
            EventKind::FallbackUnavailable { required_bytes } => {
                write!(f, "OOM ({required_bytes} B) but no fallback rung supports this scene")
            }
            EventKind::WatchdogRetry { attempt, dt, detail } => {
                write!(f, "watchdog: {detail}; retry {attempt} with dt={dt:.3e} + BVH rebuild")
            }
            EventKind::TransientRetry { attempt } => {
                write!(f, "transient fault: attempt {attempt} discarded, re-run ok")
            }
            EventKind::VramSqueeze { budget_bytes } => {
                write!(f, "VRAM budget squeezed to {budget_bytes} B")
            }
            EventKind::Straggler { shard, slowdown } => {
                write!(f, "shard {shard} straggling {slowdown:.2}x this step")
            }
            EventKind::DeviceLost { shard, device, survivors } => {
                write!(f, "device {device} (shard {shard}) lost; {survivors} survivors")
            }
            EventKind::Recovery { from_step, replayed } => {
                write!(f, "recovered from checkpoint at step {from_step} (replaying {replayed})")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_inert() {
        let cfg = ResilienceConfig::default();
        assert_eq!(cfg.on_oom, OomPolicy::Abort);
        assert!(!cfg.watchdog.enabled);
        assert_eq!(cfg.checkpoint_every, 0);
        assert!(cfg.faults.is_empty());
        assert!(!cfg.active());
    }

    #[test]
    fn any_knob_activates() {
        let mut cfg = ResilienceConfig { on_oom: OomPolicy::Fallback, ..Default::default() };
        assert!(cfg.active());
        cfg = ResilienceConfig { checkpoint_every: 5, ..Default::default() };
        assert!(cfg.active());
        cfg.checkpoint_every = 0;
        cfg.watchdog.enabled = true;
        assert!(cfg.active());
    }

    #[test]
    fn oom_policy_parses() {
        assert_eq!(OomPolicy::parse("abort"), Some(OomPolicy::Abort));
        assert_eq!(OomPolicy::parse("fallback"), Some(OomPolicy::Fallback));
        assert_eq!(OomPolicy::parse("panic"), None);
    }

    #[test]
    fn events_render_one_line() {
        let e = ResilienceEvent {
            step: 6,
            kind: EventKind::DeviceLost { shard: 1, device: "L40".into(), survivors: 3 },
        };
        let line = e.to_string();
        assert!(line.contains("step"), "{line}");
        assert!(line.contains("L40"), "{line}");
        assert!(!line.contains('\n'));
    }
}
