//! Heterogeneous device-fleet pricing for the sharded engine
//! ([`crate::shard`]): each shard binds its own [`HwProfile`], shards step
//! concurrently, and a step's aggregate cost follows the multi-device
//! execution model — simulated time is the **max** over devices (the
//! straggler gates the step barrier), energy is the **sum** (every board
//! burns its own joules), and the halo/migration exchange is priced on an
//! interconnect modeled as a fraction of local memory bandwidth.

use super::power::StepEnergy;
use super::profile::{self, HwProfile};
use super::timing::PhaseTimes;

/// Bytes shipped per ghost entry during the halo exchange: position (12 B)
/// + radius (4 B) + global id (4 B).
pub const GHOST_ENTRY_BYTES: u64 = 20;

/// Bytes shipped per migrated particle: position + velocity (24 B) +
/// radius + global id (8 B).
pub const MIGRATION_BYTES: u64 = 32;

/// Bytes folded back per cross-shard force contribution when a listless
/// backend's canonical-order scatter lands in a remote owner's
/// accumulator: force vector (12 B) + global id (4 B).
pub const SCATTER_ENTRY_BYTES: u64 = 16;

/// Effective device-to-device interconnect bandwidth as a fraction of the
/// receiving device's memory bandwidth (NVLink-class links sustain roughly
/// a quarter of HBM).
pub const EXCHANGE_BW_FRACTION: f64 = 0.25;

/// Bytes re-staged per particle when a backend switch (degradation ladder)
/// re-uploads the simulation state: position + velocity (24 B) + radius +
/// global id (8 B) — same layout as a migration.
pub const STATE_ENTRY_BYTES: u64 = 32;

/// Simulated seconds to re-stage `n` particles for a fallback backend
/// switch on `hw` (priced like an exchange over the interconnect).
pub fn switch_time(n: u64, hw: &HwProfile) -> f64 {
    exchange_time(n * STATE_ENTRY_BYTES, hw)
}

/// Activity factor of the exchange phase (DMA engines + memory, no SMs).
const EXCHANGE_ACTIVITY: f64 = 0.20;

/// Simulated seconds to move `bytes` over the interconnect into `hw`.
pub fn exchange_time(bytes: u64, hw: &HwProfile) -> f64 {
    if bytes == 0 {
        return 0.0;
    }
    bytes as f64 / (EXCHANGE_BW_FRACTION * hw.mem_bw) + hw.launch_overhead_s
}

/// Energy of an exchange phase lasting `t` seconds on `hw`.
pub fn exchange_energy(t: f64, hw: &HwProfile) -> f64 {
    if t <= 0.0 {
        return 0.0;
    }
    t * (hw.idle_w + EXCHANGE_ACTIVITY * (hw.peak_w - hw.idle_w))
}

/// Parse a fleet spec: comma-separated profile names (`titanrtx,l40`).
/// Shards bind to the list round-robin, so a single name is a uniform
/// fleet and a shorter-than-shard-count list tiles.
pub fn parse_fleet(spec: &str) -> Option<Vec<&'static HwProfile>> {
    let mut out = Vec::new();
    for name in spec.split(',') {
        let name = name.trim();
        if name.is_empty() {
            continue;
        }
        out.push(profile::by_name(name)?);
    }
    if out.is_empty() {
        None
    } else {
        Some(out)
    }
}

/// One shard's priced step on its own device.
#[derive(Clone, Copy, Debug)]
pub struct ShardCost {
    pub times: PhaseTimes,
    pub energy: StepEnergy,
    /// Halo + migration exchange, seconds.
    pub exchange_s: f64,
    /// Exchange energy, joules.
    pub exchange_j: f64,
}

impl ShardCost {
    /// The shard's full step time on its device, including the exchange.
    pub fn total_s(&self) -> f64 {
        self.times.total() + self.exchange_s
    }

    /// Every component scaled by `f` — prices an injected straggler
    /// slowdown (time stretches; energy grows with the longer active
    /// window).
    pub fn scaled(&self, f: f64) -> ShardCost {
        ShardCost {
            times: self.times.scaled(f),
            energy: StepEnergy {
                avg_power_w: self.energy.avg_power_w,
                energy_j: self.energy.energy_j * f,
            },
            exchange_s: self.exchange_s * f,
            exchange_j: self.exchange_j * f,
        }
    }
}

/// A step aggregated across the fleet.
#[derive(Clone, Copy, Debug, Default)]
pub struct FleetStep {
    /// Step time = the slowest device's time (devices run concurrently).
    pub sim_s: f64,
    /// Index of the shard that gated the step.
    pub straggler: usize,
    /// Total energy = sum over every device.
    pub energy_j: f64,
}

/// Aggregate per-shard costs into the fleet step (max time, summed energy).
pub fn aggregate(costs: &[ShardCost]) -> FleetStep {
    let mut agg = FleetStep::default();
    for (s, c) in costs.iter().enumerate() {
        let t = c.total_s();
        if t > agg.sim_s {
            agg.sim_s = t;
            agg.straggler = s;
        }
        agg.energy_j += c.energy.energy_j + c.exchange_j;
    }
    agg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtcore::profile::{L40, RTXPRO, TITANRTX};

    fn cost(traverse: f64, energy_j: f64) -> ShardCost {
        ShardCost {
            times: PhaseTimes { traverse, ..Default::default() },
            energy: StepEnergy { avg_power_w: 0.0, energy_j },
            exchange_s: 0.0,
            exchange_j: 0.0,
        }
    }

    #[test]
    fn aggregate_is_max_time_sum_energy() {
        let agg = aggregate(&[cost(1.0, 5.0), cost(3.0, 7.0), cost(2.0, 1.0)]);
        assert_eq!(agg.straggler, 1);
        assert!((agg.sim_s - 3.0).abs() < 1e-12);
        assert!((agg.energy_j - 13.0).abs() < 1e-12);
    }

    #[test]
    fn exchange_priced_on_interconnect() {
        let t = exchange_time(1_000_000, &RTXPRO);
        // 1 MB at a quarter of 1.792 TB/s plus launch overhead
        let want = 1e6 / (0.25 * RTXPRO.mem_bw) + RTXPRO.launch_overhead_s;
        assert!((t - want).abs() < 1e-15);
        assert_eq!(exchange_time(0, &RTXPRO), 0.0);
        // exchange with the straggler: a slower link makes a longer phase
        assert!(exchange_time(1 << 20, &TITANRTX) > exchange_time(1 << 20, &RTXPRO));
        let e = exchange_energy(t, &RTXPRO);
        assert!(e > 0.0 && e < t * RTXPRO.peak_w);
        assert_eq!(exchange_energy(0.0, &RTXPRO), 0.0);
    }

    #[test]
    fn switch_and_slowdown_pricing() {
        // a backend switch re-stages 32 B per particle over the interconnect
        let t = switch_time(1000, &RTXPRO);
        assert!((t - exchange_time(32_000, &RTXPRO)).abs() < 1e-15);
        let c = cost(2.0, 6.0);
        let s = c.scaled(1.5);
        assert!((s.total_s() - 3.0).abs() < 1e-12);
        assert!((s.energy.energy_j - 9.0).abs() < 1e-12);
    }

    #[test]
    fn fleet_spec_parses_round_robin_lists() {
        let f = parse_fleet("titanrtx,l40").unwrap();
        assert_eq!(f.len(), 2);
        assert_eq!(f[0].name, TITANRTX.name);
        assert_eq!(f[1].name, L40.name);
        assert_eq!(parse_fleet("l40").unwrap().len(), 1);
        assert!(parse_fleet("h100").is_none());
        assert!(parse_fleet("").is_none());
    }
}
