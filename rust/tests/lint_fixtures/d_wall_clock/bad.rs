// Fixture: seeded D-WALL-CLOCK violation (wall clock in a det path).
pub fn stamp_nanos() -> u128 {
    let now = std::time::Instant::now();
    now.elapsed().as_nanos()
}
