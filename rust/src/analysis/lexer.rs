//! A hand-rolled Rust tokenizer — just enough lexical structure for the
//! lint rules in [`crate::analysis::rules`].
//!
//! The goal is *not* a conforming Rust lexer; it is a dependency-free
//! scanner that never confuses the four contexts the rules care about:
//! code, `//`/`/* */` comments, string/char literals, and lifetimes.
//! Everything the rules match (identifiers, punctuation, literal kinds)
//! is classified conservatively; anything unrecognized degrades to a
//! one-byte `Punct` token rather than an error, so a novel construct can
//! never abort the lint pass.

/// Lexical class of a [`Token`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `unsafe`, `HashMap`, `as`, ...).
    Ident,
    /// Numeric literal, suffix included (`0x1F`, `1e-5`, `3.0f32`, `10usize`).
    Num,
    /// String literal: `"..."`, `r"..."`, `r#"..."#`, `b"..."` — quotes kept.
    Str,
    /// Char or byte-char literal (`'x'`, `'\n'`, `b'\0'`).
    Char,
    /// Lifetime (`'a`, `'static`, `'_`).
    Lifetime,
    /// Operator / delimiter, multi-byte ops pre-joined (`::`, `+=`, `..=`).
    Punct,
    /// Line or block comment, delimiters kept.
    Comment,
}

/// One token with its source position (1-based line, 1-based byte column).
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
    pub col: u32,
}

/// Multi-byte operators, longest first so maximal munch works.
const MULTI_OPS: &[&str] = &[
    "<<=", ">>=", "..=", "...", "::", "->", "=>", "==", "!=", "<=", ">=", "&&", "||", "+=", "-=",
    "*=", "/=", "%=", "^=", "&=", "|=", "<<", ">>", "..",
];

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_' || b >= 0x80
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_' || b >= 0x80
}

/// Tokenize `src` into a flat stream, comments included.
pub fn tokenize(src: &str) -> Vec<Token> {
    Lexer { src: src.as_bytes(), i: 0, line: 1, line_start: 0, out: Vec::new() }.run(src)
}

struct Lexer<'a> {
    src: &'a [u8],
    i: usize,
    line: u32,
    line_start: usize,
    out: Vec<Token>,
}

impl<'a> Lexer<'a> {
    fn at(&self, k: usize) -> u8 {
        self.src.get(k).copied().unwrap_or(0)
    }

    fn push(&mut self, kind: TokKind, start_line: u32, start_col: u32, text: &str) {
        self.out.push(Token { kind, text: text.to_string(), line: start_line, col: start_col });
    }

    fn col(&self, at: usize) -> u32 {
        (at - self.line_start + 1) as u32
    }

    fn newline(&mut self, at: usize) {
        self.line += 1;
        self.line_start = at + 1;
    }

    /// Advance past a `"..."` body starting *after* the opening quote,
    /// honoring `\` escapes and tracking newlines. Leaves `self.i` after
    /// the closing quote (or at EOF).
    fn skip_str_body(&mut self) {
        while self.i < self.src.len() {
            match self.src[self.i] {
                b'\\' => {
                    if self.at(self.i + 1) == b'\n' {
                        self.newline(self.i + 1); // escaped line continuation
                    }
                    self.i = (self.i + 2).min(self.src.len());
                }
                b'"' => {
                    self.i += 1;
                    return;
                }
                b'\n' => {
                    self.newline(self.i);
                    self.i += 1;
                }
                _ => self.i += 1,
            }
        }
    }

    /// Raw string starting at `r` / `rb` / `br`: `r#*"..."#*`. Returns
    /// false if this is not actually a raw-string head (an `r` must be in
    /// the prefix; plain `b"..."` keeps its escape handling elsewhere).
    fn try_raw_str(&mut self, full: &str, start: usize) -> bool {
        let mut k = self.i;
        let mut saw_r = false;
        while self.at(k) == b'r' || self.at(k) == b'b' {
            saw_r |= self.at(k) == b'r';
            k += 1;
        }
        if !saw_r {
            return false;
        }
        let mut hashes = 0usize;
        while self.at(k) == b'#' {
            hashes += 1;
            k += 1;
        }
        if self.at(k) != b'"' {
            return false;
        }
        k += 1;
        let start_line = self.line;
        let start_col = self.col(start);
        loop {
            match self.at(k) {
                0 => break,
                b'\n' => {
                    self.newline(k);
                    k += 1;
                }
                b'"' => {
                    let mut h = 0usize;
                    while h < hashes && self.at(k + 1 + h) == b'#' {
                        h += 1;
                    }
                    k += 1 + h;
                    if h == hashes {
                        break;
                    }
                }
                _ => k += 1,
            }
        }
        let text = &full[start..k.min(full.len())];
        self.push(TokKind::Str, start_line, start_col, text);
        self.i = k;
        true
    }

    fn run(mut self, full: &'a str) -> Vec<Token> {
        while self.i < self.src.len() {
            let b = self.src[self.i];
            let start = self.i;
            let start_line = self.line;
            let start_col = self.col(start);
            match b {
                b'\n' => {
                    self.newline(self.i);
                    self.i += 1;
                }
                _ if b.is_ascii_whitespace() => self.i += 1,
                b'/' if self.at(self.i + 1) == b'/' => {
                    while self.i < self.src.len() && self.src[self.i] != b'\n' {
                        self.i += 1;
                    }
                    self.push(TokKind::Comment, start_line, start_col, &full[start..self.i]);
                }
                b'/' if self.at(self.i + 1) == b'*' => {
                    self.i += 2;
                    let mut depth = 1usize;
                    while self.i < self.src.len() && depth > 0 {
                        match (self.src[self.i], self.at(self.i + 1)) {
                            (b'/', b'*') => {
                                depth += 1;
                                self.i += 2;
                            }
                            (b'*', b'/') => {
                                depth -= 1;
                                self.i += 2;
                            }
                            (b'\n', _) => {
                                self.newline(self.i);
                                self.i += 1;
                            }
                            _ => self.i += 1,
                        }
                    }
                    self.push(TokKind::Comment, start_line, start_col, &full[start..self.i]);
                }
                b'"' => {
                    self.i += 1;
                    self.skip_str_body();
                    self.push(TokKind::Str, start_line, start_col, &full[start..self.i]);
                }
                b'\'' => {
                    self.lex_quote(full, start, start_line, start_col);
                }
                _ if b.is_ascii_digit() => {
                    self.lex_number();
                    self.push(TokKind::Num, start_line, start_col, &full[start..self.i]);
                }
                _ if is_ident_start(b) => {
                    if (b == b'r' || b == b'b') && self.try_raw_str(full, start) {
                        continue;
                    }
                    while self.i < self.src.len() && is_ident_byte(self.src[self.i]) {
                        self.i += 1;
                    }
                    // byte-string head: fold `b` into the following literal
                    if &full[start..self.i] == "b" && self.at(self.i) == b'"' {
                        self.i += 1;
                        self.skip_str_body();
                        self.push(TokKind::Str, start_line, start_col, &full[start..self.i]);
                    } else if &full[start..self.i] == "b" && self.at(self.i) == b'\'' {
                        // byte-char head: `lex_quote` slices from `start`,
                        // so the token text keeps the `b` prefix
                        self.lex_quote(full, start, start_line, start_col);
                    } else {
                        let text = &full[start..self.i];
                        self.push(TokKind::Ident, start_line, start_col, text);
                    }
                }
                _ => {
                    let rest = &full[self.i..];
                    let op = MULTI_OPS.iter().find(|op| rest.starts_with(**op));
                    if let Some(op) = op {
                        self.i += op.len();
                        self.push(TokKind::Punct, start_line, start_col, op);
                    } else {
                        self.i += 1;
                        self.push(TokKind::Punct, start_line, start_col, &full[start..self.i]);
                    }
                }
            }
        }
        self.out
    }

    /// Disambiguate `'` between char literals and lifetimes.
    fn lex_quote(&mut self, full: &str, start: usize, start_line: u32, start_col: u32) {
        let next = self.at(self.i + 1);
        if next == b'\\' {
            // escaped char literal: scan to the closing quote
            self.i += 2; // ' and backslash
            self.i += 1; // the escaped byte (covers \', \\, \n, and heads \x, \u)
            while self.i < self.src.len() && self.src[self.i] != b'\'' {
                self.i += 1;
            }
            self.i = (self.i + 1).min(self.src.len());
            self.push(TokKind::Char, start_line, start_col, &full[start..self.i]);
        } else if is_ident_byte(next) {
            // 'x' is a char literal; 'ident (no closing quote) is a lifetime
            let mut k = self.i + 1;
            while k < self.src.len() && is_ident_byte(self.src[k]) {
                k += 1;
            }
            if self.at(k) == b'\'' {
                self.i = k + 1;
                self.push(TokKind::Char, start_line, start_col, &full[start..self.i]);
            } else {
                self.i = k;
                self.push(TokKind::Lifetime, start_line, start_col, &full[start..self.i]);
            }
        } else if next != 0 && self.at(self.i + 2) == b'\'' {
            // one-byte punctuation char literal: ' ' , '%' , '-'
            self.i += 3;
            self.push(TokKind::Char, start_line, start_col, &full[start..self.i]);
        } else {
            self.i += 1;
            self.push(TokKind::Punct, start_line, start_col, "'");
        }
    }

    /// Numeric literal: digits, `_`, alnum suffixes/exponents, and a `.`
    /// only when it starts a fraction (so `0..n` stays a range).
    fn lex_number(&mut self) {
        while self.i < self.src.len() {
            let b = self.src[self.i];
            if is_ident_byte(b) {
                // exponent sign: 1e-5 / 2.5E+3
                if (b == b'e' || b == b'E')
                    && (self.at(self.i + 1) == b'+' || self.at(self.i + 1) == b'-')
                    && self.at(self.i + 2).is_ascii_digit()
                {
                    self.i += 2;
                }
                self.i += 1;
            } else if b == b'.' && self.at(self.i + 1).is_ascii_digit() {
                self.i += 1;
            } else {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        tokenize(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_numbers_ops() {
        let toks = kinds("let x = a.len() as u32 + 1e-5;");
        assert!(toks.contains(&(TokKind::Ident, "as".into())));
        assert!(toks.contains(&(TokKind::Num, "1e-5".into())));
        let toks = kinds("for i in 0..n { v += 2.5f32; }");
        assert!(toks.contains(&(TokKind::Num, "0".into())));
        assert!(toks.contains(&(TokKind::Punct, "..".into())));
        assert!(toks.contains(&(TokKind::Punct, "+=".into())));
        assert!(toks.contains(&(TokKind::Num, "2.5f32".into())));
    }

    #[test]
    fn char_vs_lifetime() {
        let toks = kinds("fn f<'a>(x: &'static str) { s.push('x'); s.push('\\n'); t('-') }");
        let lifetimes: Vec<_> =
            toks.iter().filter(|t| t.0 == TokKind::Lifetime).map(|t| t.1.clone()).collect();
        assert_eq!(lifetimes, vec!["'a", "'static"]);
        assert_eq!(toks.iter().filter(|t| t.0 == TokKind::Char).count(), 3);
    }

    #[test]
    fn strings_and_comments() {
        let toks = kinds("// HashMap in a comment\nlet s = \"HashMap.iter()\"; /* unsafe */");
        assert_eq!(toks.iter().filter(|t| t.0 == TokKind::Comment).count(), 2);
        assert!(!toks.iter().any(|t| t.0 == TokKind::Ident && t.1 == "HashMap"));
        let toks = kinds("let r = r#\"raw \\ \"quoted\" body\"#;");
        assert_eq!(toks.iter().filter(|t| t.0 == TokKind::Str).count(), 1);
    }

    #[test]
    fn line_numbers() {
        let toks = tokenize("a\nbb\n  ccc");
        assert_eq!(toks.len(), 3);
        assert_eq!((toks[0].line, toks[0].col), (1, 1));
        assert_eq!((toks[1].line, toks[1].col), (2, 1));
        assert_eq!((toks[2].line, toks[2].col), (3, 3));
    }

    #[test]
    fn multiline_string_tracks_lines() {
        let toks = tokenize("let s = \"a\nb\";\nx");
        let x = toks.iter().find(|t| t.text == "x").unwrap();
        assert_eq!(x.line, 3);
    }
}
