//! Scoped thread-pool parallelism — the OpenMP substitute for the CPU-CELL
//! baseline (the offline vendor set has no `rayon`).
//!
//! `parallel_for_chunks` splits an index range into contiguous chunks and
//! runs one std thread per chunk via `std::thread::scope`; worker closures
//! get `(thread_id, range)` so callers can keep per-thread accumulation
//! buffers (the standard race-free pattern for force scatter).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use: `ORCS_THREADS` env override, else the
/// available hardware parallelism.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("ORCS_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `body(thread_id, start..end)` over `0..n` split into `threads`
/// contiguous chunks. Blocks until all workers finish.
pub fn parallel_for_chunks<F>(n: usize, threads: usize, body: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n < 2 {
        body(0, 0..n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let body = &body;
            s.spawn(move || body(t, lo..hi));
        }
    });
}

/// Dynamic work-stealing variant: workers atomically grab blocks of
/// `block` indices. Better for irregular per-item cost (clustered scenes,
/// variable radii) where static chunking load-imbalances.
pub fn parallel_for_dynamic<F>(n: usize, threads: usize, block: usize, body: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n < 2 {
        body(0, 0..n);
        return;
    }
    let cursor = AtomicUsize::new(0);
    let block = block.max(1);
    std::thread::scope(|s| {
        for t in 0..threads {
            let body = &body;
            let cursor = &cursor;
            s.spawn(move || loop {
                let lo = cursor.fetch_add(block, Ordering::Relaxed);
                if lo >= n {
                    break;
                }
                let hi = (lo + block).min(n);
                body(t, lo..hi);
            });
        }
    });
}

/// Map `0..n` in parallel into a pre-allocated output vector. `f` must be
/// pure per-index.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        parallel_for_chunks(n, threads, |_, range| {
            let p = out_ptr; // copy the Send wrapper into the closure
            for i in range {
                // SAFETY: chunks are disjoint; each index written once.
                unsafe { *p.0.add(i) = f(i) };
            }
        });
    }
    out
}

/// Chunked parallel reduction: each worker builds a private accumulator
/// with `init`, folds its index range into it with `body`, and the
/// per-thread accumulators are returned in thread order (deterministic
/// merging is the caller's job — this is the race-free substitute for GPU
/// atomic scatter, see DESIGN.md §Hardware-Adaptation).
pub fn parallel_reduce<R, I, F>(n: usize, threads: usize, init: I, body: F) -> Vec<R>
where
    R: Send,
    I: Fn() -> R + Sync,
    F: Fn(&mut R, usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        let mut acc = init();
        for i in 0..n {
            body(&mut acc, i);
        }
        return vec![acc];
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let init = &init;
            let body = &body;
            handles.push(s.spawn(move || {
                let mut acc = init();
                for i in lo..hi {
                    body(&mut acc, i);
                }
                acc
            }));
        }
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    })
}

/// Pointer wrapper asserting Send for disjoint-range writes.
struct SendPtr<T>(*mut T);
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn chunks_cover_all_indices_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for_chunks(1000, 7, |_, range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn dynamic_covers_all_indices_once() {
        let hits: Vec<AtomicU64> = (0..1003).map(|_| AtomicU64::new(0)).collect();
        parallel_for_dynamic(1003, 5, 16, |_, range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_matches_serial() {
        let v = parallel_map(257, 4, |i| i * i);
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i * i);
        }
    }

    #[test]
    fn reduce_sums_correctly() {
        let parts = parallel_reduce(1000, 8, || 0u64, |acc, i| *acc += i as u64);
        let total: u64 = parts.into_iter().sum();
        assert_eq!(total, 999 * 1000 / 2);
    }

    #[test]
    fn reduce_single_thread() {
        let parts = parallel_reduce(10, 1, || 0u64, |acc, i| *acc += i as u64);
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0], 45);
    }

    #[test]
    fn single_thread_and_empty() {
        parallel_for_chunks(0, 4, |_, r| assert!(r.is_empty()));
        let v = parallel_map(5, 1, |i| i + 1);
        assert_eq!(v, vec![1, 2, 3, 4, 5]);
    }
}
