//! Fig. 8 — BVH rebuild/update schemes: `gradient` vs `fixed-200` vs `avg`
//! over the 3x4 scenario grid, periodic BC, RT-REF pipeline.
//!
//! For every (distribution, radius, policy) the bench runs the simulation
//! and records the per-step simulated RT cost (BVH op + query) plus rebuild
//! marks and the average interactions per particle — the exact series the
//! paper plots. Prints cumulative totals (the legend numbers of Fig. 8) and
//! gradient's speedup over the best alternative.

use anyhow::Result;

use super::common::{paper_grid, BenchOpts};
use crate::coordinator::metrics::fmt_ms;
use crate::coordinator::report::{results_dir, CsvWriter, TextTable};
use crate::core::config::Boundary;
use crate::frnn::ApproachKind;
use crate::gradient::BvhAction;

pub const POLICIES: [&str; 3] = ["gradient", "fixed-200", "avg"];

/// Paper: n = 140k, 2000 steps. Bench default: n = 20k, 400 steps.
const N_DEFAULT: usize = 4_000;
const STEPS_DEFAULT: usize = 120;

pub fn run(opts: &BenchOpts) -> Result<()> {
    let (n, steps) = opts.size(N_DEFAULT, STEPS_DEFAULT);
    println!("== Fig. 8: BVH rebuild/update schemes (n={n}, {steps} steps, periodic BC) ==");
    println!("   paper: n=140k, 2000 steps on RTXPRO; shape target: gradient fastest,");
    println!("   up to ~3.4x over second best at small constant radius\n");

    let mut csv = CsvWriter::create(
        &results_dir().join("fig8_bvh_policies.csv"),
        &["case", "policy", "step", "rt_ms", "action", "interactions_pp", "cum_rt_ms"],
    )?;
    let mut table = TextTable::new(&[
        "case", "gradient(ms)", "fixed-200(ms)", "avg(ms)", "grad speedup", "rebuilds g/f/a",
    ]);

    for case in paper_grid() {
        let mut totals = Vec::new();
        let mut rebuilds = Vec::new();
        for policy in POLICIES {
            let summary = opts
                .run_with(&case, n, Boundary::Periodic, ApproachKind::RtRef, policy, steps, true,
                    |sim| {
                        // visible per-step motion at bench scale: the paper's
                        // 140k-particle systems move vigorously over 2000
                        // steps; compress that into 150 hot steps
                        sim.dt = 0.02;
                        sim.vel_scale = 2.0;
                    })?
                .ok_or_else(|| {
                    anyhow::anyhow!("RT-REF rejected {} with policy {policy}", case.tag())
                })?;
            let mut cum = 0.0;
            let mut n_rebuilds = 0u64;
            for rec in &summary.records {
                cum += rec.rt_ms;
                let action = match rec.bvh_action {
                    Some(BvhAction::Build) => {
                        n_rebuilds += 1;
                        "build"
                    }
                    Some(BvhAction::Update) => "update",
                    None => "-",
                };
                csv.row(&[
                    case.tag(),
                    policy.to_string(),
                    rec.step.to_string(),
                    format!("{:.4}", rec.rt_ms),
                    action.to_string(),
                    format!("{:.2}", rec.interactions as f64 * 2.0 / n as f64),
                    format!("{:.3}", cum),
                ])?;
            }
            totals.push(summary.total_rt_ms);
            rebuilds.push(n_rebuilds);
        }
        let second_best =
            totals[1..].iter().cloned().fold(f64::INFINITY, f64::min).max(1e-12);
        let speedup = second_best / totals[0].max(1e-12);
        table.row(vec![
            case.tag(),
            fmt_ms(totals[0]),
            fmt_ms(totals[1]),
            fmt_ms(totals[2]),
            format!("{speedup:.2}x"),
            format!("{}/{}/{}", rebuilds[0], rebuilds[1], rebuilds[2]),
        ]);
    }

    println!("{}", table.render());
    println!("CSV: {}", results_dir().join("fig8_bvh_policies.csv").display());
    Ok(())
}
