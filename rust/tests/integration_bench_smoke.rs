//! Bench-suite smoke: every table/figure generator runs end-to-end at tiny
//! sizes and writes its CSV outputs.

use std::sync::{Arc, Mutex};

use orcs::benchsuite::common::BenchOpts;
use orcs::core::config::Boundary;
use orcs::frnn::RustKernels;

/// `ORCS_RESULTS` is process-global; serialize the smoke tests around it.
static ENV_LOCK: Mutex<()> = Mutex::new(());

fn with_results_dir<F: FnOnce(&BenchOpts)>(dir: &std::path::Path, f: F) {
    let _guard = ENV_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    std::env::set_var("ORCS_RESULTS", dir);
    let opts = BenchOpts {
        threads: 2,
        hw: orcs::rtcore::profile::DEFAULT_GPU,
        kernels: Arc::new(RustKernels { threads: 2 }),
        quick: false,
        steps_override: Some(4),
        n_override: Some(300),
        seed: 1,
    };
    f(&opts);
}

#[test]
fn fig8_smoke() {
    let dir = std::env::temp_dir().join("orcs_smoke_fig8");
    with_results_dir(&dir, |opts| orcs::benchsuite::fig8::run(opts).unwrap());
    assert!(dir.join("fig8_bvh_policies.csv").exists());
    let text = std::fs::read_to_string(dir.join("fig8_bvh_policies.csv")).unwrap();
    assert!(text.lines().count() > 12 * 3 * 4, "expected per-step rows for 36 runs");
    assert!(text.contains("gradient") && text.contains("fixed-200") && text.contains("avg"));
}

#[test]
fn table2_smoke() {
    let dir = std::env::temp_dir().join("orcs_smoke_table2");
    with_results_dir(&dir, |opts| orcs::benchsuite::table2::run(opts).unwrap());
    let text = std::fs::read_to_string(dir.join("table2_sim_perf.csv")).unwrap();
    // 12 cases x 4 columns x 5 approaches minus unsupported perse cells
    let rows = text.lines().count() - 1;
    assert!(rows >= 12 * 4 * 4, "rows={rows}");
    assert!(text.contains("RT-REF") && text.contains("CPU-CELL@64c"));
}

#[test]
fn fig9_fig10_smoke() {
    let dir = std::env::temp_dir().join("orcs_smoke_fig910");
    with_results_dir(&dir, |opts| {
        orcs::benchsuite::fig9_10::run(opts, Boundary::Wall).unwrap();
        orcs::benchsuite::fig9_10::run(opts, Boundary::Periodic).unwrap();
    });
    let wall = std::fs::read_to_string(dir.join("fig9_speedup_wall.csv")).unwrap();
    let periodic = std::fs::read_to_string(dir.join("fig10_speedup_periodic.csv")).unwrap();
    assert!(wall.contains("speedup") && wall.lines().count() > 10);
    assert!(periodic.lines().count() > 10);
}

#[test]
fn fig11_fig12_smoke() {
    let dir = std::env::temp_dir().join("orcs_smoke_fig1112");
    with_results_dir(&dir, |opts| orcs::benchsuite::fig11_12::run(opts).unwrap());
    let power = std::fs::read_to_string(dir.join("fig11_power.csv")).unwrap();
    let ee = std::fs::read_to_string(dir.join("fig12_energy_eff.csv")).unwrap();
    assert!(power.lines().count() > 20);
    // 2 BCs x 3 cases x 5 approaches (minus '-' cells) rows
    assert!(ee.lines().count() > 20);
    // power values must sit between idle and peak of the profile
    for line in power.lines().skip(1) {
        let w: f64 = line.rsplit(',').next().unwrap().parse().unwrap();
        assert!(w >= 50.0 && w <= 600.0, "implausible power {w}");
    }
}

#[test]
fn sharded_smoke() {
    let dir = std::env::temp_dir().join("orcs_smoke_sharded");
    with_results_dir(&dir, |opts| orcs::benchsuite::sharded::run(opts).unwrap());
    let text = std::fs::read_to_string(dir.join("sharded_scaling.csv")).unwrap();
    // the S sweep, the OOM-relief device and the heterogeneous fleet rows
    for needle in ["1x1x1", "2x2x2", "3x3x3", "TITANRTX-4MB", "TITANRTX+L40"] {
        assert!(text.contains(needle), "missing {needle}");
    }
}

#[test]
fn fig13_smoke() {
    let dir = std::env::temp_dir().join("orcs_smoke_fig13");
    with_results_dir(&dir, |opts| orcs::benchsuite::fig13::run(opts).unwrap());
    let text = std::fs::read_to_string(dir.join("fig13_scaling.csv")).unwrap();
    for gpu in ["TITANRTX", "A40", "L40", "RTXPRO"] {
        assert!(text.contains(gpu), "missing {gpu}");
    }
}
