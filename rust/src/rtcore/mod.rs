//! The simulated RT-core / GPU hardware layer.
//!
//! Real RT cores are opaque silicon: the paper measures them with CUDA
//! events and NVML. Our substitute counts every operation the algorithms
//! perform ([`OpCounts`]) and converts counts into *simulated time* through
//! a roofline model parameterized per GPU generation ([`profile`],
//! [`timing`]), plus an analytic power model ([`power`]). See DESIGN.md
//! §Hardware-Adaptation for the calibration rationale.

pub mod fleet;
pub mod power;
pub mod profile;
pub mod timing;

pub use profile::HwProfile;
pub use timing::PhaseTimes;

/// Operation counters for one simulation step. Backends fill the fields
/// relevant to their pipeline; the timing model prices them.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct OpCounts {
    // ---- BVH lifecycle ----
    /// Primitives processed by a full build this step (0 = no build).
    pub bvh_built_prims: u64,
    /// Primitives processed by a refit this step (0 = no refit).
    pub bvh_refit_prims: u64,

    // ---- RT traversal (RT-core box units + SM intersection shaders) ----
    /// Ray–AABB tests.
    pub aabb_tests: u64,
    /// Sphere intersection tests (intersection-shader invocations).
    pub sphere_tests: u64,
    /// Rays launched (primary + gamma).
    pub rays: u64,

    // ---- In-shader work (ORCS pipelines) ----
    /// LJ pair-force evaluations performed inside intersection shaders.
    pub isect_force_evals: u64,
    /// Payload accumulations (ORCS-persé).
    pub payload_accums: u64,
    /// Atomic global-memory adds (ORCS-forces scatter; RT-REF cross-list
    /// inserts under variable radius).
    pub atomic_adds: u64,

    // ---- Neighbor list (RT-REF) ----
    /// Entries appended to the neighbor list.
    pub nbr_list_writes: u64,
    /// Peak neighbor-list allocation in bytes (n * k_max * 4) — the OOM
    /// quantity of §4.2.
    pub nbr_list_bytes_peak: u64,

    // ---- Separate compute kernels ----
    /// Pair evaluations in the standalone force kernel (RT-REF).
    pub force_kernel_pairs: u64,
    /// Particles advanced by the integration kernel.
    pub integrate_particles: u64,
    /// Kernel launches (fixed overhead each).
    pub kernel_launches: u64,

    // ---- Cell-list methods ----
    /// Candidate pair distance tests during cell sweeps.
    pub cell_pair_tests: u64,
    /// Cells visited during sweeps (per-particle lookup overhead — what a
    /// cell method pays even when cells are empty, e.g. r=1 scenes).
    pub cell_visits: u64,
    /// Pair-force evaluations from cell sweeps.
    pub cell_force_evals: u64,
    /// Particles binned during grid construction.
    pub grid_binned: u64,
    /// Elements radix-sorted (GPU-CELL z-ordering).
    pub sort_elems: u64,

    // ---- Physics bookkeeping ----
    /// Physical pair interactions, counted once per unordered pair (the
    /// `I` of the paper's EE metric, Eq. 10).
    pub interactions: u64,
}

impl OpCounts {
    pub fn add(&mut self, o: &OpCounts) {
        self.bvh_built_prims += o.bvh_built_prims;
        self.bvh_refit_prims += o.bvh_refit_prims;
        self.aabb_tests += o.aabb_tests;
        self.sphere_tests += o.sphere_tests;
        self.rays += o.rays;
        self.isect_force_evals += o.isect_force_evals;
        self.payload_accums += o.payload_accums;
        self.atomic_adds += o.atomic_adds;
        self.nbr_list_writes += o.nbr_list_writes;
        self.nbr_list_bytes_peak = self.nbr_list_bytes_peak.max(o.nbr_list_bytes_peak);
        self.force_kernel_pairs += o.force_kernel_pairs;
        self.integrate_particles += o.integrate_particles;
        self.kernel_launches += o.kernel_launches;
        self.cell_pair_tests += o.cell_pair_tests;
        self.cell_visits += o.cell_visits;
        self.cell_force_evals += o.cell_force_evals;
        self.grid_binned += o.grid_binned;
        self.sort_elems += o.sort_elems;
        self.interactions += o.interactions;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_accumulates_and_peaks() {
        let mut a = OpCounts { aabb_tests: 10, nbr_list_bytes_peak: 100, ..Default::default() };
        let b = OpCounts {
            aabb_tests: 5,
            nbr_list_bytes_peak: 50,
            interactions: 3,
            ..Default::default()
        };
        a.add(&b);
        assert_eq!(a.aabb_tests, 15);
        assert_eq!(a.nbr_list_bytes_peak, 100); // max, not sum
        assert_eq!(a.interactions, 3);
    }
}
