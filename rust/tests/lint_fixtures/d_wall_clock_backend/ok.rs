// Clean twin: wall metering arrives as a value produced by the blessed
// telemetry::wallclock::WallTimer at the call boundary, so the step path
// itself never touches a raw clock.
pub fn step_forces(pos: &mut [f32], elapsed_s: f64) -> f64 {
    for p in pos.iter_mut() {
        *p += 0.5;
    }
    elapsed_s
}
