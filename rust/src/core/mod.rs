//! Core value types: 3-vectors, AABBs, deterministic RNG, scene generation
//! and simulation configuration.

pub mod aabb;
pub mod config;
pub mod distributions;
pub mod rng;
pub mod vec3;
