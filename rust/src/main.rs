//! `orcs` — the leader binary: CLI over the coordinator engine and the
//! benchmark suite. See `orcs help` / [`orcs::cli::USAGE`].

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::Result;

use orcs::benchsuite::{chaos, common::BenchOpts, fig11_12, fig13, fig8, fig9_10, sharded, table2};
use orcs::cli::{Args, USAGE};
use orcs::coordinator::metrics::{fmt_ms, percentile};
use orcs::coordinator::report::{results_dir, CsvWriter, TextTable};
use orcs::coordinator::{Engine, EngineConfig};
use orcs::core::config::{Boundary, ShardSpec};
use orcs::frnn::ApproachKind;
use orcs::shard::{ShardedConfig, ShardedEngine};
use orcs::telemetry::{chrome, Recorder};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    match args.subcommand.as_str() {
        "simulate" => simulate(&args),
        "trace" => trace_cmd(&args),
        "bench-fig8" => fig8::run(&BenchOpts::from_args(&args)?),
        "bench-table2" => table2::run(&BenchOpts::from_args(&args)?),
        "bench-fig9" => fig9_10::run(&BenchOpts::from_args(&args)?, Boundary::Wall),
        "bench-fig10" => fig9_10::run(&BenchOpts::from_args(&args)?, Boundary::Periodic),
        "bench-fig11" | "bench-fig12" => fig11_12::run(&BenchOpts::from_args(&args)?),
        "bench-fig13" => fig13::run(&BenchOpts::from_args(&args)?),
        "bench-sharded" => sharded::run(&BenchOpts::from_args(&args)?),
        "bench-chaos" => chaos::run(&BenchOpts::from_args(&args)?),
        "lint" => orcs::analysis::run_cli(&args),
        "inspect-artifacts" => inspect_artifacts(),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand `{other}`\n\n{USAGE}");
            std::process::exit(2);
        }
    }
}

/// Apply the telemetry CLI flags to a recorder: full span-tree retention
/// when a trace export is requested, flight-recorder depth from `--flight`.
fn configure_recorder(args: &Args, rec: &mut Recorder, force_trace: bool) -> Result<()> {
    if force_trace || args.get("trace-out").is_some() {
        rec.enable_trace();
    }
    if let Some(k) = args.get("flight") {
        rec.set_flight_len(k.parse()?);
    }
    Ok(())
}

/// Write the Chrome-trace JSON and metrics exports requested by
/// `--trace-out` / `--metrics-out` (with `orcs trace` defaults under
/// `results/` when `with_defaults` is set). The trace is validated before
/// it is written, so a malformed export fails the run — the CI smoke leg
/// relies on exactly that.
fn export_telemetry(args: &Args, rec: &Recorder, with_defaults: bool) -> Result<()> {
    let trace_path = match args.get("trace-out") {
        Some(p) => Some(PathBuf::from(p)),
        None if with_defaults => Some(results_dir().join("trace.json")),
        None => None,
    };
    if let Some(path) = trace_path {
        chrome::validate(rec.steps())
            .map_err(|e| anyhow::anyhow!("recorded spans are inconsistent: {e}"))?;
        let js = chrome::render(rec.steps(), &rec.lanes());
        chrome::validate_json(&js)
            .map_err(|e| anyhow::anyhow!("rendered trace JSON is malformed: {e}"))?;
        std::fs::write(&path, &js)?;
        println!(
            "trace: {} ({} steps, {} lanes)",
            path.display(),
            rec.steps().len(),
            rec.lanes().len()
        );
    }
    let metrics_path = match args.get("metrics-out") {
        Some(p) => Some(PathBuf::from(p)),
        None if with_defaults => Some(results_dir().join("metrics.json")),
        None => None,
    };
    if let Some(path) = metrics_path {
        std::fs::write(&path, rec.metrics().to_json())?;
        let prom = path.with_extension("prom");
        std::fs::write(&prom, rec.metrics().to_prometheus())?;
        println!("metrics: {} + {}", path.display(), prom.display());
    }
    Ok(())
}

/// Human phase-breakdown table (p50/p95 per phase, time share) plus
/// per-lane straggler attribution over the recorded span tree.
fn print_phase_breakdown(rec: &Recorder) {
    let steps = rec.steps();
    if steps.is_empty() {
        println!("no recorded steps (tracing is enabled by `orcs trace` or --trace-out)");
        return;
    }
    let mut by_phase: BTreeMap<&'static str, Vec<f64>> = BTreeMap::new();
    let mut total = 0.0;
    for st in steps {
        for sp in &st.spans {
            by_phase.entry(sp.phase.label()).or_default().push(sp.dur_ms);
            total += sp.dur_ms;
        }
    }
    let mut t = TextTable::new(&["phase", "spans", "total ms", "p50 ms", "p95 ms", "share"]);
    for (label, durs) in &by_phase {
        let sum: f64 = durs.iter().sum();
        let share = if total > 0.0 { 100.0 * sum / total } else { 0.0 };
        t.row(vec![
            label.to_string(),
            durs.len().to_string(),
            fmt_ms(sum),
            fmt_ms(percentile(durs, 50.0)),
            fmt_ms(percentile(durs, 95.0)),
            format!("{share:.1}%"),
        ]);
    }
    println!("phase breakdown over {} step(s):", steps.len());
    println!("{}", t.render());

    let lanes = rec.lanes();
    if lanes.len() > 1 {
        let mut straggler_steps: BTreeMap<u32, u64> = BTreeMap::new();
        let mut busy_ms: BTreeMap<u32, f64> = BTreeMap::new();
        for st in steps {
            let mut per_lane: BTreeMap<u32, f64> = BTreeMap::new();
            for sp in &st.spans {
                *per_lane.entry(sp.lane).or_insert(0.0) += sp.dur_ms;
            }
            let mut worst: Option<(u32, f64)> = None;
            for (&lane, &ms) in &per_lane {
                *busy_ms.entry(lane).or_insert(0.0) += ms;
                let better = match worst {
                    None => true,
                    Some((_, w)) => ms > w,
                };
                if better {
                    worst = Some((lane, ms));
                }
            }
            if let Some((lane, _)) = worst {
                *straggler_steps.entry(lane).or_insert(0) += 1;
            }
        }
        let mut t = TextTable::new(&["lane", "busy ms", "straggler steps"]);
        for (lane, name) in &lanes {
            t.row(vec![
                name.clone(),
                fmt_ms(busy_ms.get(lane).copied().unwrap_or(0.0)),
                straggler_steps.get(lane).copied().unwrap_or(0).to_string(),
            ]);
        }
        println!("straggler attribution (busiest lane per step):");
        println!("{}", t.render());
    }
}

/// `orcs trace`: run a scenario with full tracing and emit the Chrome
/// trace, Prometheus/JSON metrics, and a human phase-breakdown table.
fn trace_cmd(args: &Args) -> Result<()> {
    let quick = args.has("quick");
    let mut sim = args.sim_config()?;
    if quick && args.get("n").is_none() {
        sim.n = 2_000;
    }
    let steps = args.get_usize("steps", if quick { 12 } else { 100 })?;
    let policy = args.get_or("policy", "gradient").to_string();
    if let Some(spec) = args.shards()? {
        let fleet = match args.fleet()? {
            Some(f) => f,
            None => vec![args.hw()?],
        };
        let cfg = ShardedConfig {
            policy,
            fleet,
            backend: args.backend(ApproachKind::RtRef)?,
            threads: orcs::parallel::num_threads(),
            check_oom: !args.has("no-oom-check"),
            resilience: args.resilience(steps as u64, spec.count())?,
            ..ShardedConfig::new(sim.clone(), spec)
        };
        let kernels = Engine::kernels_for(sim.force_path, cfg.threads)?;
        println!(
            "trace (sharded): {} | grid {} | backend={} | {} steps",
            cfg.sim.tag(),
            cfg.spec,
            cfg.backend.label(),
            steps
        );
        let mut engine = ShardedEngine::new(cfg, kernels)?;
        configure_recorder(args, engine.telemetry_mut(), true)?;
        engine.run(steps, false)?;
        export_telemetry(args, engine.telemetry(), true)?;
        print_phase_breakdown(engine.telemetry());
    } else {
        let approach = args.approach(ApproachKind::OrcsForces)?;
        let cfg = EngineConfig {
            policy,
            hw: args.hw()?,
            threads: orcs::parallel::num_threads(),
            check_oom: !args.has("no-oom-check"),
            resilience: args.resilience(steps as u64, 1)?,
            ..EngineConfig::new(sim.clone(), approach)
        };
        let kernels = Engine::kernels_for(sim.force_path, cfg.threads)?;
        println!(
            "trace: {} | {} | hw={} | {} steps",
            cfg.sim.tag(),
            approach,
            cfg.hw.name,
            steps
        );
        let mut engine = Engine::new(cfg, kernels)?;
        configure_recorder(args, engine.telemetry_mut(), true)?;
        engine.run(steps, false)?;
        export_telemetry(args, engine.telemetry(), true)?;
        print_phase_breakdown(engine.telemetry());
    }
    Ok(())
}

/// `orcs simulate`: run one scenario end to end with full metering.
fn simulate(args: &Args) -> Result<()> {
    if let Some(spec) = args.shards()? {
        return simulate_sharded(args, spec);
    }
    let quick = args.has("quick");
    let mut sim = args.sim_config()?;
    if quick && args.get("n").is_none() {
        sim.n = 2_000;
    }
    let approach = args.approach(ApproachKind::OrcsForces)?;
    let steps = args.get_usize("steps", if quick { 12 } else { 100 })?;
    let policy = args.get_or("policy", "gradient").to_string();
    let cfg = EngineConfig {
        policy,
        hw: args.hw()?,
        threads: orcs::parallel::num_threads(),
        check_oom: !args.has("no-oom-check"),
        resilience: args.resilience(steps as u64, 1)?,
        ..EngineConfig::new(sim.clone(), approach)
    };
    let kernels = Engine::kernels_for(sim.force_path, cfg.threads)?;
    println!(
        "simulate: {} | {} | policy={} | hw={} | kernels={} | {} steps",
        cfg.sim.tag(),
        approach,
        cfg.policy,
        cfg.hw.name,
        kernels.name(),
        steps
    );
    let mut engine = Engine::new(cfg, kernels)?;
    configure_recorder(args, engine.telemetry_mut(), false)?;
    let resilient = engine.cfg.resilience.active();
    let trace_path = args.get("trace").map(std::path::PathBuf::from);
    let keep_trace = trace_path.is_some();
    let report_every = (steps / 10).max(1);

    let mut records = Vec::new();
    for s in 0..steps {
        let rec = match if resilient { engine.step_resilient() } else { engine.step() } {
            Ok(rec) => rec,
            Err(e) => {
                // fault forensics: the last K steps, incl. the failing one
                let dump = engine.telemetry().flight_dump();
                if !dump.is_empty() {
                    eprintln!("{dump}");
                }
                return Err(e.into());
            }
        };
        for ev in engine.take_events() {
            println!("  {ev}");
        }
        if s % report_every == 0 || s + 1 == steps {
            println!(
                "  step {:>6}  sim {:>9.4} ms  rt {:>9.4} ms  {:>7.0} W  {:>10} int  {}",
                rec.step,
                rec.sim_ms,
                rec.rt_ms,
                rec.energy.avg_power_w,
                rec.interactions,
                match rec.bvh_action {
                    Some(orcs::gradient::BvhAction::Build) => "rebuild",
                    Some(orcs::gradient::BvhAction::Update) => "update",
                    None => "",
                },
            );
        }
        if let Some(bytes) = rec.oom_bytes {
            println!("  OOM: neighbor list would need {bytes} bytes on {}", engine.cfg.hw.name);
            break;
        }
        if keep_trace {
            records.push(rec);
        }
    }

    let ke = engine.state.kinetic_energy();
    println!(
        "done: {} steps | KE {:.3} | momentum |p| {:.4} | finite={}",
        engine.state.step_count,
        ke,
        engine.state.total_momentum().norm(),
        engine.state.is_finite()
    );

    if let Some(path) = trace_path {
        let mut csv = CsvWriter::create(
            &path,
            &["step", "sim_ms", "rt_ms", "power_w", "energy_j", "interactions", "action"],
        )?;
        for rec in &records {
            csv.row(&[
                rec.step.to_string(),
                format!("{:.5}", rec.sim_ms),
                format!("{:.5}", rec.rt_ms),
                format!("{:.1}", rec.energy.avg_power_w),
                format!("{:.6}", rec.energy.energy_j),
                rec.interactions.to_string(),
                format!("{:?}", rec.bvh_action),
            ])?;
        }
        println!("trace: {}", path.display());
    }
    export_telemetry(args, engine.telemetry(), false)?;
    let _ = results_dir();
    Ok(())
}

/// `orcs simulate --shards S`: the sharded engine — per-shard BVHs and
/// policies, halo exchange, per-shard OOM, optional heterogeneous fleet.
fn simulate_sharded(args: &Args, spec: ShardSpec) -> Result<()> {
    // the sharded engine has no per-step CSV trace yet — reject rather
    // than silently ignore the flag
    anyhow::ensure!(args.get("trace").is_none(), "--trace is not supported with --shards yet");
    anyhow::ensure!(
        args.get("fleet").is_none() || args.get("hw").is_none(),
        "--hw conflicts with --fleet (the fleet list binds per-shard devices)"
    );
    let quick = args.has("quick");
    let mut sim = args.sim_config()?;
    if quick && args.get("n").is_none() {
        sim.n = 2_000;
    }
    let steps = args.get_usize("steps", if quick { 12 } else { 100 })?;
    let policy = args.get_or("policy", "gradient").to_string();
    let fleet = match args.fleet()? {
        Some(f) => f,
        None => vec![args.hw()?],
    };
    let cfg = ShardedConfig {
        policy,
        fleet,
        backend: args.backend(ApproachKind::RtRef)?,
        threads: orcs::parallel::num_threads(),
        check_oom: !args.has("no-oom-check"),
        resilience: args.resilience(steps as u64, spec.count())?,
        ..ShardedConfig::new(sim.clone(), spec)
    };
    let kernels = Engine::kernels_for(sim.force_path, cfg.threads)?;
    println!(
        "simulate (sharded): {} | grid {} | backend={} | policy={} | kernels={} | {} steps",
        cfg.sim.tag(),
        cfg.spec,
        cfg.backend.label(),
        cfg.policy,
        kernels.name(),
        steps
    );
    let mut engine = ShardedEngine::new(cfg, kernels)?;
    configure_recorder(args, engine.telemetry_mut(), false)?;
    let summary = engine.run(steps, true)?;
    for ev in &summary.events {
        println!("  {ev}");
    }
    let report_every = (steps / 10).max(1);
    for (k, rec) in summary.records.iter().enumerate() {
        if k % report_every == 0 || k + 1 == summary.records.len() {
            println!(
                "  step {:>6}  sim {:>9.4} ms  straggler s{:<3} {:>9.4} J  {:>8} ghosts  {:>6} migr",
                rec.step, rec.sim_ms, rec.straggler, rec.energy_j, rec.ghost_entries,
                rec.migrations,
            );
        }
        if let Some((shard, bytes)) = rec.oom {
            println!(
                "  OOM: shard {shard} neighbor list would need {bytes} bytes on {}",
                engine.shard_hw(shard).name
            );
        }
    }
    let mut t = TextTable::new(&[
        "shard", "hw", "owned", "ghosts", "builds", "updates", "forced", "upd/build", "k_max",
        "listless",
    ]);
    for (k, tot) in summary.per_shard.iter().enumerate() {
        let st = summary.steps.max(1);
        t.row(vec![
            k.to_string(),
            engine.shard_hw(k).name.to_string(),
            format!("{:.0}", tot.owned_sum as f64 / st as f64),
            format!("{:.0}", tot.ghosts_sum as f64 / st as f64),
            tot.builds.to_string(),
            tot.updates.to_string(),
            tot.forced_builds.to_string(),
            format!("{:.2}", tot.update_ratio()),
            tot.max_k_max.to_string(),
            tot.listless_steps.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "done: {} steps ({} replayed) | fleet {} | avg step {:.4} ms | {:.3} J | EE {:.1} int/J \
         | finite={}",
        summary.steps,
        summary.replayed_steps,
        summary.fleet,
        summary.avg_sim_ms,
        summary.total_energy_j,
        summary.ee,
        engine.state.is_finite()
    );
    export_telemetry(args, engine.telemetry(), false)?;
    Ok(())
}

/// `orcs inspect-artifacts`: load and list the PJRT artifact set.
fn inspect_artifacts() -> Result<()> {
    let dir = orcs::runtime::XlaRuntime::default_dir();
    println!("artifact dir: {}", dir.display());
    let rt = orcs::runtime::XlaRuntime::load(&dir)?;
    for (k, exe) in &rt.lj_forces {
        println!("  lj_forces  K={k:<4} ({})", exe.name);
    }
    println!("  integrate        ({})", rt.integrate.name);
    if let Some(r) = &rt.lj_forces_ref {
        println!("  lj_forces_ref    ({})", r.name);
    }
    println!("all artifacts compiled on PJRT CPU OK");
    Ok(())
}
