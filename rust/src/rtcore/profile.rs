//! Hardware profiles for the four GPU generations of the paper's scaling
//! study (Table 1 / Fig. 13) plus the CPU reference.
//!
//! Rates are *effective sustained* figures derived from public specs and
//! calibrated so that the reproduced tables preserve the paper's ordering
//! and approximate ratios (see EXPERIMENTS.md §Calibration). Absolute
//! numbers are explicitly not the target — the shapes are.

/// Which device executes an approach (affects the power model).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeviceKind {
    Gpu,
    Cpu,
}

/// Sustained-rate hardware profile (all rates per second).
#[derive(Clone, Copy, Debug)]
pub struct HwProfile {
    pub name: &'static str,
    pub kind: DeviceKind,
    /// Ray–AABB tests / s (RT-core box units).
    pub rt_box_rate: f64,
    /// Intersection-shader invocations / s.
    pub rt_isect_rate: f64,
    /// LJ pair-force evaluations / s in compute kernels (SM or CPU cores).
    pub pair_eval_rate: f64,
    /// Atomic f32 global adds / s.
    pub atomic_rate: f64,
    /// Main-memory bandwidth, bytes / s.
    pub mem_bw: f64,
    /// BVH full-build throughput, prims / s.
    pub bvh_build_rate: f64,
    /// BVH refit throughput, prims / s.
    pub bvh_refit_rate: f64,
    /// Radix-sort throughput, elems / s (GPU-CELL z-ordering).
    pub sort_rate: f64,
    /// Grid binning throughput, particles / s.
    pub grid_rate: f64,
    /// Cell lookups / s during sweeps (bounded by memory latency).
    pub cell_visit_rate: f64,
    /// Integration throughput, particles / s.
    pub integrate_rate: f64,
    /// Fixed kernel-launch overhead, seconds.
    pub launch_overhead_s: f64,
    /// Device memory capacity, bytes (neighbor-list OOM threshold, §4.2).
    pub vram_bytes: u64,
    /// Idle board power, watts.
    pub idle_w: f64,
    /// Peak board power, watts (600 W for the Blackwell part, Table 1).
    pub peak_w: f64,
}

const GB: u64 = 1024 * 1024 * 1024;

/// TITAN RTX — Turing, 2018. 72 RT cores, 24 GB GDDR6 @ 672 GB/s, 280 W.
pub const TITANRTX: HwProfile = HwProfile {
    name: "TITANRTX",
    kind: DeviceKind::Gpu,
    rt_box_rate: 110e9,
    rt_isect_rate: 9e9,
    pair_eval_rate: 11e9,
    atomic_rate: 6.5e9,
    mem_bw: 672e9,
    bvh_build_rate: 0.55e9,
    bvh_refit_rate: 4.5e9,
    sort_rate: 1.8e9,
    grid_rate: 6e9,
    cell_visit_rate: 5e9,
    integrate_rate: 9e9,
    launch_overhead_s: 6e-6,
    vram_bytes: 24 * GB,
    idle_w: 65.0,
    peak_w: 280.0,
};

/// A40 — Ampere, 2020. 84 RT cores (gen 2), 48 GB @ 696 GB/s, 300 W.
pub const A40: HwProfile = HwProfile {
    name: "A40",
    kind: DeviceKind::Gpu,
    rt_box_rate: 170e9,
    rt_isect_rate: 14e9,
    pair_eval_rate: 17e9,
    atomic_rate: 10e9,
    mem_bw: 696e9,
    bvh_build_rate: 0.9e9,
    bvh_refit_rate: 7e9,
    sort_rate: 2.8e9,
    grid_rate: 9e9,
    cell_visit_rate: 8e9,
    integrate_rate: 14e9,
    launch_overhead_s: 5e-6,
    vram_bytes: 48 * GB,
    idle_w: 60.0,
    peak_w: 300.0,
};

/// L40 — Ada Lovelace, 2022. 142 RT cores (gen 3), 48 GB @ 864 GB/s, 300 W.
/// The paper singles this part out as the energy-efficiency sweet spot.
pub const L40: HwProfile = HwProfile {
    name: "L40",
    kind: DeviceKind::Gpu,
    rt_box_rate: 340e9,
    rt_isect_rate: 26e9,
    pair_eval_rate: 30e9,
    atomic_rate: 18e9,
    mem_bw: 864e9,
    bvh_build_rate: 1.7e9,
    bvh_refit_rate: 13e9,
    sort_rate: 5e9,
    grid_rate: 16e9,
    cell_visit_rate: 14e9,
    integrate_rate: 26e9,
    launch_overhead_s: 4e-6,
    vram_bytes: 48 * GB,
    idle_w: 55.0,
    peak_w: 300.0,
};

/// RTX Pro 6000 Blackwell Server Edition — 2025. 96 GB @ ~1.8 TB/s, 600 W.
/// Performance scales up strongly; EE scales less (paper §4.3's observed
/// trend change).
pub const RTXPRO: HwProfile = HwProfile {
    name: "RTXPRO",
    kind: DeviceKind::Gpu,
    rt_box_rate: 560e9,
    rt_isect_rate: 42e9,
    pair_eval_rate: 50e9,
    atomic_rate: 28e9,
    mem_bw: 1792e9,
    bvh_build_rate: 2.8e9,
    bvh_refit_rate: 22e9,
    sort_rate: 8e9,
    grid_rate: 26e9,
    cell_visit_rate: 22e9,
    integrate_rate: 42e9,
    launch_overhead_s: 4e-6,
    vram_bytes: 96 * GB,
    idle_w: 90.0,
    peak_w: 600.0,
};

/// AMD EPYC 9534, 64 cores — the CPU-CELL@64c reference host (Table 1).
/// RT fields are unused (no RT units); pair rate models 64 cores of
/// vectorized LJ.
pub const EPYC64: HwProfile = HwProfile {
    name: "CPU-EPYC64",
    kind: DeviceKind::Cpu,
    rt_box_rate: 0.0,
    rt_isect_rate: 0.0,
    pair_eval_rate: 2.2e9,
    atomic_rate: 0.8e9,
    mem_bw: 460e9,
    bvh_build_rate: 0.08e9,
    bvh_refit_rate: 0.6e9,
    sort_rate: 0.6e9,
    grid_rate: 2.5e9,
    cell_visit_rate: 0.8e9,
    integrate_rate: 3e9,
    launch_overhead_s: 1e-6,
    vram_bytes: 768 * GB, // host RAM
    idle_w: 95.0,
    peak_w: 290.0,
};

/// The scaling-study GPU set, oldest to newest (Fig. 13's x-axis).
pub const GENERATIONS: [&HwProfile; 4] = [&TITANRTX, &A40, &L40, &RTXPRO];

/// Default GPU for Table 2 / Figs 9–12 (the paper's testbed GPU, Table 1).
pub const DEFAULT_GPU: &HwProfile = &RTXPRO;

/// Look up a profile by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<&'static HwProfile> {
    let n = name.to_ascii_uppercase();
    match n.as_str() {
        "TITANRTX" | "TITAN" | "TURING" => Some(&TITANRTX),
        "A40" | "AMPERE" => Some(&A40),
        "L40" | "LOVELACE" | "ADA" => Some(&L40),
        "RTXPRO" | "BLACKWELL" => Some(&RTXPRO),
        "CPU" | "EPYC64" | "CPU-EPYC64" => Some(&EPYC64),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generations_monotonically_faster() {
        for w in GENERATIONS.windows(2) {
            let (a, b) = (w[0], w[1]);
            assert!(b.rt_box_rate > a.rt_box_rate, "{} vs {}", a.name, b.name);
            assert!(b.pair_eval_rate > a.pair_eval_rate);
            assert!(b.mem_bw >= a.mem_bw);
        }
    }

    #[test]
    fn lovelace_jump_is_largest_rt_scaling() {
        // the paper: strongest scaling A40 -> L40
        let turing_to_ampere = A40.rt_box_rate / TITANRTX.rt_box_rate;
        let ampere_to_lovelace = L40.rt_box_rate / A40.rt_box_rate;
        let lovelace_to_blackwell = RTXPRO.rt_box_rate / L40.rt_box_rate;
        assert!(ampere_to_lovelace > turing_to_ampere);
        assert!(ampere_to_lovelace > lovelace_to_blackwell);
    }

    #[test]
    fn blackwell_power_jump() {
        assert_eq!(RTXPRO.peak_w, 600.0);
        assert_eq!(L40.peak_w, 300.0);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("l40").unwrap().name, "L40");
        assert_eq!(by_name("blackwell").unwrap().name, "RTXPRO");
        assert!(by_name("h100").is_none());
    }
}
