//! Symplectic-Euler integration and the optional velocity-rescale
//! thermostat.
//!
//! The paper's pipelines end each step by "applying forces in parallel to
//! the particles" (§3.2); this module is that kernel's Rust reference. The
//! XLA artifact `integrate_c4096` implements the same update and is used on
//! the hot path by RT-REF / ORCS-forces when `ForcePath::Xla` is selected;
//! `integration_runtime.rs` cross-checks the two.

use crate::physics::boundary;
use crate::physics::state::SimState;

/// Advance positions and velocities one step from `state.force`
/// (unit mass): `v += F dt; x += v dt`, then apply boundary conditions.
pub fn step(state: &mut SimState) {
    let dt = state.dt;
    let (boundary_mode, box_l) = (state.boundary, state.box_l);
    for i in 0..state.n() {
        let f = state.params.cap(state.force[i]);
        let mut v = state.vel[i] + f * dt;
        let mut p = state.pos[i] + v * dt;
        boundary::apply(boundary_mode, box_l, &mut p, &mut v);
        state.pos[i] = p;
        state.vel[i] = v;
    }
    state.step_count += 1;
}

/// Integrate from externally supplied new positions/velocities (the XLA
/// path computes the Euler update on-device; boundary handling stays in
/// Rust — see DESIGN.md §Three-layer).
pub fn apply_integrated(state: &mut SimState, new_pos: &[[f32; 3]], new_vel: &[[f32; 3]]) {
    assert_eq!(new_pos.len(), state.n());
    assert_eq!(new_vel.len(), state.n());
    let (boundary_mode, box_l) = (state.boundary, state.box_l);
    for i in 0..state.n() {
        // lint:allow(P-INDEX-LIT): [f32; 3] rows — literal lanes always exist
        let mut p = crate::core::vec3::Vec3::new(new_pos[i][0], new_pos[i][1], new_pos[i][2]);
        // lint:allow(P-INDEX-LIT): [f32; 3] rows — literal lanes always exist
        let mut v = crate::core::vec3::Vec3::new(new_vel[i][0], new_vel[i][1], new_vel[i][2]);
        boundary::apply(boundary_mode, box_l, &mut p, &mut v);
        state.pos[i] = p;
        state.vel[i] = v;
    }
    state.step_count += 1;
}

/// Velocity-rescale thermostat: scale all velocities so the kinetic energy
/// matches `target_ke`. Keeps long benchmark runs bounded; disabled unless a
/// scenario requests it.
pub fn rescale_to_ke(state: &mut SimState, target_ke: f64) {
    let ke = state.kinetic_energy();
    if ke <= 0.0 {
        return;
    }
    let s = (target_ke / ke).sqrt() as f32;
    for v in &mut state.vel {
        *v = *v * s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::{Boundary, SimConfig};
    use crate::core::vec3::Vec3;

    fn tiny_state(boundary: Boundary) -> SimState {
        let cfg = SimConfig { n: 2, boundary, dt: 0.1, ..SimConfig::default() };
        let mut s = SimState::from_config(&cfg);
        s.pos = vec![Vec3::new(1.0, 1.0, 1.0), Vec3::new(2.0, 2.0, 2.0)];
        s.vel = vec![Vec3::ZERO; 2];
        s.force = vec![Vec3::new(1.0, 0.0, 0.0), Vec3::ZERO];
        s
    }

    #[test]
    fn euler_update() {
        let mut s = tiny_state(Boundary::Periodic);
        step(&mut s);
        // v = 1*0.1 = 0.1; x = 1 + 0.1*0.1 = 1.01
        assert!((s.vel[0].x - 0.1).abs() < 1e-6);
        assert!((s.pos[0].x - 1.01).abs() < 1e-6);
        assert_eq!(s.pos[1], Vec3::new(2.0, 2.0, 2.0));
        assert_eq!(s.step_count, 1);
    }

    #[test]
    fn wall_reflection_in_step() {
        let mut s = tiny_state(Boundary::Wall);
        s.pos[0] = Vec3::new(999.9, 500.0, 500.0);
        s.vel[0] = Vec3::new(10.0, 0.0, 0.0);
        s.force[0] = Vec3::ZERO;
        s.dt = 1.0;
        step(&mut s);
        assert!(s.pos[0].x <= 1000.0);
        assert!(s.vel[0].x < 0.0, "velocity should flip");
    }

    #[test]
    fn force_cap_applies() {
        let mut s = tiny_state(Boundary::Periodic);
        s.params.f_max = 0.5;
        s.force[0] = Vec3::new(100.0, 0.0, 0.0);
        step(&mut s);
        assert!((s.vel[0].x - 0.05).abs() < 1e-6); // capped at 0.5 * dt
    }

    #[test]
    fn apply_integrated_matches_step() {
        let mut a = tiny_state(Boundary::Periodic);
        let mut b = a.clone();
        step(&mut a);
        // replicate externally
        let dt = b.dt;
        let mut np = Vec::new();
        let mut nv = Vec::new();
        for i in 0..b.n() {
            let f = b.params.cap(b.force[i]);
            let v = b.vel[i] + f * dt;
            let p = b.pos[i] + v * dt;
            np.push([p.x, p.y, p.z]);
            nv.push([v.x, v.y, v.z]);
        }
        apply_integrated(&mut b, &np, &nv);
        assert_eq!(a.pos, b.pos);
        assert_eq!(a.vel, b.vel);
        assert_eq!(a.step_count, b.step_count);
    }

    #[test]
    fn thermostat_rescales() {
        let mut s = tiny_state(Boundary::Periodic);
        s.vel = vec![Vec3::new(2.0, 0.0, 0.0), Vec3::new(0.0, 2.0, 0.0)];
        rescale_to_ke(&mut s, 1.0);
        assert!((s.kinetic_energy() - 1.0).abs() < 1e-5);
    }
}
