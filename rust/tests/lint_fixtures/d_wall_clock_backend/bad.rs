// Fixture: a wall clock in a backend step path still fires the rule.
pub fn step_forces(pos: &mut [f32]) -> f64 {
    let t0 = std::time::Instant::now();
    for p in pos.iter_mut() {
        *p += 0.5;
    }
    t0.elapsed().as_secs_f64()
}
