//! Numerical watchdog: per-step divergence detection.
//!
//! Two checks after every integrated step: [`SimState::is_finite`] (NaN /
//! Inf anywhere in positions or velocities) and a kinetic-energy drift
//! bound (KE may not jump by more than `ke_growth`× between consecutive
//! accepted steps — a symplectic integrator on a bounded-force system
//! cannot do that legitimately, but an exploding `dt` or a corrupted
//! velocity can). On failure the owning engine restores its pre-step
//! snapshot, halves `dt`, forces a BVH rebuild and retries under a bounded
//! backoff.

use crate::physics::state::SimState;

/// Watchdog knobs. Default is **disabled** — the watchdog clones the state
/// every step when armed, so it is strictly opt-in.
#[derive(Clone, Debug)]
pub struct WatchdogCfg {
    pub enabled: bool,
    /// Allowed kinetic-energy growth factor between accepted steps.
    pub ke_growth: f64,
    /// Retry budget per step before giving up with
    /// [`crate::resilience::SimError::NumericalDivergence`].
    pub max_retries: u32,
}

impl Default for WatchdogCfg {
    fn default() -> Self {
        WatchdogCfg { enabled: false, ke_growth: 64.0, max_retries: 4 }
    }
}

/// Tracks the kinetic-energy anchor across accepted steps.
#[derive(Clone, Debug, Default)]
pub struct Watchdog {
    /// KE of the last *accepted* step (committed only on success).
    last_ke: Option<f64>,
}

impl Watchdog {
    /// Validate the post-step state. On `Ok` the KE anchor advances; on
    /// `Err` it stays at the last accepted step so a retry is judged
    /// against the same baseline.
    pub fn check(&mut self, cfg: &WatchdogCfg, state: &SimState) -> Result<(), String> {
        if !state.is_finite() {
            return Err("non-finite position or velocity".into());
        }
        let ke = state.kinetic_energy();
        if let Some(prev) = self.last_ke {
            // the floor keeps near-zero-KE scenes (cold lattices) from
            // tripping on absolute noise
            let floor = 1e-9 * state.n().max(1) as f64;
            if ke > cfg.ke_growth * (prev + floor) {
                return Err(format!("kinetic energy jumped {prev:.3e} -> {ke:.3e}"));
            }
        }
        self.last_ke = Some(ke);
        Ok(())
    }

    /// Forget the KE anchor (after a checkpoint restore the next accepted
    /// step re-anchors).
    pub fn reset(&mut self) {
        self.last_ke = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::SimConfig;
    use crate::core::vec3::Vec3;

    fn small_state() -> SimState {
        SimState::from_config(&SimConfig { n: 32, ..SimConfig::default() })
    }

    #[test]
    fn accepts_healthy_steps_and_anchors_ke() {
        let cfg = WatchdogCfg { enabled: true, ..WatchdogCfg::default() };
        let mut wd = Watchdog::default();
        let state = small_state();
        assert!(wd.check(&cfg, &state).is_ok());
        assert!(wd.check(&cfg, &state).is_ok(), "same KE passes again");
    }

    #[test]
    fn trips_on_non_finite() {
        let cfg = WatchdogCfg::default();
        let mut wd = Watchdog::default();
        let mut state = small_state();
        state.vel[0] = Vec3::new(f32::NAN, 0.0, 0.0);
        let err = wd.check(&cfg, &state).unwrap_err();
        assert!(err.contains("non-finite"), "{err}");
    }

    #[test]
    fn trips_on_ke_blowup_without_moving_anchor() {
        let cfg = WatchdogCfg::default();
        let mut wd = Watchdog::default();
        let mut state = small_state();
        wd.check(&cfg, &state).unwrap();
        let saved = state.vel[0];
        state.vel[0] = state.vel[0] * 1e15 + Vec3::splat(1e15);
        let err = wd.check(&cfg, &state).unwrap_err();
        assert!(err.contains("kinetic energy"), "{err}");
        // the anchor did not move: restoring the snapshot passes again
        state.vel[0] = saved;
        assert!(wd.check(&cfg, &state).is_ok());
    }
}
