//! Simulation configuration: the knobs of the paper's evaluation (§4).

use std::fmt;

/// Initial particle position distribution (paper Fig. 7).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParticleDist {
    /// Regular grid positions.
    Lattice,
    /// Random uniform positions in the box.
    Disordered,
    /// Random normal cluster `N(mu = rand, sigma = 25)`.
    Cluster,
}

impl ParticleDist {
    pub const ALL: [ParticleDist; 3] =
        [ParticleDist::Lattice, ParticleDist::Disordered, ParticleDist::Cluster];

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "lattice" | "l" => Some(Self::Lattice),
            "disordered" | "d" => Some(Self::Disordered),
            "cluster" | "c" => Some(Self::Cluster),
            _ => None,
        }
    }
}

impl fmt::Display for ParticleDist {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Lattice => write!(f, "Lattice"),
            Self::Disordered => write!(f, "Disordered"),
            Self::Cluster => write!(f, "Cluster"),
        }
    }
}

/// Search-radius distribution (paper §4.1: r=1, r=160, U[1,160], LN(1,2)∈[1,330]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RadiusDist {
    /// All particles share one radius.
    Const(f32),
    /// Uniform in `[lo, hi]`.
    Uniform(f32, f32),
    /// `exp(N(mu, sigma))` clamped to `[lo, hi]`.
    LogNormal { mu: f64, sigma: f64, lo: f32, hi: f32 },
}

impl RadiusDist {
    /// The paper's four benchmark radius distributions.
    pub fn paper_set() -> [RadiusDist; 4] {
        [
            RadiusDist::Const(1.0),
            RadiusDist::Const(160.0),
            RadiusDist::Uniform(1.0, 160.0),
            RadiusDist::LogNormal { mu: 1.0, sigma: 2.0, lo: 1.0, hi: 330.0 },
        ]
    }

    /// True when every particle has the same radius (ORCS-persé requirement).
    pub fn is_uniform_radius(&self) -> bool {
        matches!(self, RadiusDist::Const(_))
    }

    pub fn parse(s: &str) -> Option<Self> {
        let s = s.to_ascii_lowercase();
        if let Some(v) = s.strip_prefix("const:") {
            return v.parse().ok().map(RadiusDist::Const);
        }
        if let Some(v) = s.strip_prefix("uniform:") {
            let mut it = v.split(',');
            let lo = it.next()?.parse().ok()?;
            let hi = it.next()?.parse().ok()?;
            return Some(RadiusDist::Uniform(lo, hi));
        }
        if let Some(v) = s.strip_prefix("lognormal:") {
            let mut it = v.split(',');
            let mu = it.next()?.parse().ok()?;
            let sigma = it.next()?.parse().ok()?;
            let lo = it.next()?.parse().ok()?;
            let hi = it.next()?.parse().ok()?;
            return Some(RadiusDist::LogNormal { mu, sigma, lo, hi });
        }
        match s.as_str() {
            "r1" => Some(RadiusDist::Const(1.0)),
            "r160" => Some(RadiusDist::Const(160.0)),
            "u" | "u1-160" => Some(RadiusDist::Uniform(1.0, 160.0)),
            "ln" | "ln1-330" => {
                Some(RadiusDist::LogNormal { mu: 1.0, sigma: 2.0, lo: 1.0, hi: 330.0 })
            }
            _ => None,
        }
    }
}

impl fmt::Display for RadiusDist {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Const(r) => write!(f, "r={r}"),
            Self::Uniform(lo, hi) => write!(f, "U[{lo},{hi}]"),
            Self::LogNormal { mu, sigma, lo, hi } => {
                write!(f, "LN({mu},{sigma})[{lo},{hi}]")
            }
        }
    }
}

/// Boundary conditions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Boundary {
    /// Reflective walls.
    Wall,
    /// Periodic wrap with minimum-image interactions (contribution #3
    /// handles this case with gamma rays in the RT pipelines).
    Periodic,
}

impl Boundary {
    pub const ALL: [Boundary; 2] = [Boundary::Wall, Boundary::Periodic];

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "wall" | "w" => Some(Self::Wall),
            "periodic" | "p" => Some(Self::Periodic),
            _ => None,
        }
    }
}

impl fmt::Display for Boundary {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Wall => write!(f, "Wall"),
            Self::Periodic => write!(f, "Periodic"),
        }
    }
}

/// Sharded domain decomposition spec: the box splits into an `s × s × s`
/// grid of equal subdomains, each stepped as its own device with a private
/// BVH and rebuild-policy instance (see [`crate::shard`]). `s = 1` is the
/// degenerate single-shard decomposition (one subdomain covering the box,
/// still exercising the halo/ghost machinery).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    /// Subdomains per axis.
    pub s: usize,
}

impl ShardSpec {
    pub fn new(s: usize) -> Self {
        ShardSpec { s: s.max(1) }
    }

    /// Total shard count, `s³`.
    pub fn count(&self) -> usize {
        self.s * self.s * self.s
    }

    /// Parse `"2"` (or `"2x2x2"`) into a spec. Only cubic grids are
    /// supported; a mismatched `AxBxC` form is rejected.
    pub fn parse(spec: &str) -> Option<Self> {
        let spec = spec.trim().to_ascii_lowercase();
        if let Some((a, rest)) = spec.split_once('x') {
            let (b, c) = rest.split_once('x')?;
            let (a, b, c): (usize, usize, usize) =
                (a.parse().ok()?, b.parse().ok()?, c.parse().ok()?);
            if a != b || b != c || a == 0 {
                return None;
            }
            return Some(ShardSpec::new(a));
        }
        let s: usize = spec.parse().ok()?;
        if s == 0 {
            None
        } else {
            Some(ShardSpec::new(s))
        }
    }
}

impl fmt::Display for ShardSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{0}x{0}x{0}", self.s)
    }
}

/// Which physics-kernel path the coordinator uses for gather-style force
/// evaluation (RT-REF) and integration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ForcePath {
    /// AOT-lowered JAX/Pallas HLO executed through PJRT — the paper-faithful
    /// "separate GPU compute kernel". Default for `simulate` and the e2e
    /// example.
    Xla,
    /// Pure-Rust oracle path; used by tests as reference and by very large
    /// bench sweeps where PJRT-CPU dispatch overhead would dominate
    /// wall-clock (simulated times are identical on both paths).
    Rust,
}

/// Full scenario configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Number of particles.
    pub n: usize,
    /// Cubic box side (paper: 1000).
    pub box_l: f32,
    pub particle_dist: ParticleDist,
    pub radius_dist: RadiusDist,
    pub boundary: Boundary,
    /// Integration time step.
    pub dt: f32,
    /// LJ well depth.
    pub epsilon: f32,
    /// sigma_i = r_i / sigma_factor (classic cutoff r_c = 2.5 sigma).
    pub sigma_factor: f32,
    /// Force-magnitude cap for numerical stability in dense clusters.
    pub f_max: f32,
    /// RNG seed for scene + dynamics.
    pub seed: u64,
    pub force_path: ForcePath,
    /// Std-dev of the initial thermal velocity kick (scene temperature).
    pub vel_scale: f32,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            n: 10_000,
            box_l: 1000.0,
            particle_dist: ParticleDist::Disordered,
            radius_dist: RadiusDist::Const(1.0),
            boundary: Boundary::Periodic,
            dt: 1e-3,
            epsilon: 1.0,
            sigma_factor: 2.5,
            f_max: 1e4,
            seed: 0xC0FFEE,
            force_path: ForcePath::Rust,
            vel_scale: 0.05,
        }
    }
}

impl SimConfig {
    /// Short human tag, used in CSV outputs: `Lattice/r=1/Wall/n=50000`.
    pub fn tag(&self) -> String {
        format!(
            "{}/{}/{}/n={}",
            self.particle_dist, self.radius_dist, self.boundary, self.n
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_particle_dist() {
        assert_eq!(ParticleDist::parse("lattice"), Some(ParticleDist::Lattice));
        assert_eq!(ParticleDist::parse("D"), Some(ParticleDist::Disordered));
        assert_eq!(ParticleDist::parse("c"), Some(ParticleDist::Cluster));
        assert_eq!(ParticleDist::parse("x"), None);
    }

    #[test]
    fn parse_radius_dist() {
        assert_eq!(RadiusDist::parse("r1"), Some(RadiusDist::Const(1.0)));
        assert_eq!(RadiusDist::parse("const:2.5"), Some(RadiusDist::Const(2.5)));
        assert_eq!(
            RadiusDist::parse("uniform:1,160"),
            Some(RadiusDist::Uniform(1.0, 160.0))
        );
        match RadiusDist::parse("ln") {
            Some(RadiusDist::LogNormal { mu, sigma, lo, hi }) => {
                assert_eq!((mu, sigma, lo, hi), (1.0, 2.0, 1.0, 330.0));
            }
            other => panic!("bad parse: {other:?}"),
        }
    }

    #[test]
    fn paper_set_matches_section_4() {
        let set = RadiusDist::paper_set();
        assert_eq!(set[0], RadiusDist::Const(1.0));
        assert_eq!(set[1], RadiusDist::Const(160.0));
        assert!(set[0].is_uniform_radius());
        assert!(!set[2].is_uniform_radius());
    }

    #[test]
    fn shard_spec_parses_and_counts() {
        assert_eq!(ShardSpec::parse("2"), Some(ShardSpec::new(2)));
        assert_eq!(ShardSpec::parse("3x3x3"), Some(ShardSpec::new(3)));
        assert_eq!(ShardSpec::parse("2x2x3"), None);
        assert_eq!(ShardSpec::parse("0"), None);
        assert_eq!(ShardSpec::parse("blob"), None);
        assert_eq!(ShardSpec::new(3).count(), 27);
        assert_eq!(ShardSpec::new(2).to_string(), "2x2x2");
    }

    #[test]
    fn tag_is_stable() {
        let c = SimConfig::default();
        assert_eq!(c.tag(), "Disordered/r=1/Periodic/n=10000");
    }
}
