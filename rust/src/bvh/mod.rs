//! The BVH substrate — our stand-in for the GPU RT cores' acceleration
//! structure.
//!
//! The paper manages the OptiX BVH through exactly two operations: **build**
//! (full reconstruction, optimal tree for the current particle positions)
//! and **update** (refit: recompute node bounds over the existing topology).
//! We reproduce both, plus a stack traversal with *exact operation counters*
//! (AABB tests, sphere tests) that feed the RT-core timing model
//! ([`crate::rtcore`]). Refit-induced degradation — the phenomenon the
//! `gradient` optimizer exploits — emerges structurally: as particles move,
//! refitted node bounds overlap more and traversal touches more nodes.
//!
//! # Node layout: 4-wide SoA with 8-bit quantized child boxes
//!
//! Nodes are **4-wide** ([`Bvh4Node`]), mirroring the wide BVHs RT silicon
//! actually traverses, and the four child boxes are stored **quantized**
//! (Howard et al., PAPERS.md): a per-node `anchor` plus per-axis
//! power-of-two scales (one exponent byte each) define an integer frame,
//! and each lane's bounds are 8-bit offsets in that frame, transposed into
//! per-axis lanes (`qmin_x[4]; qmin_y[4]; …`). That shrinks a node from
//! the 128 bytes of the uncompressed f32 layout to under 64 bytes — one
//! cache line per node fetch instead of two — which is the hot-path
//! currency for both traversal (re-fetches per ray) and refit (streams
//! every node).
//!
//! Quantization uses **conservative rounding**: mins round down, maxs
//! round up (with f32 fix-up loops, see [`Bvh4Node::requantize`]), so every
//! dequantized lane box *contains* its exact box. Traversal therefore can
//! widen — visit a node an exact tree would have culled — but never miss,
//! and the exact sphere test at the leaves keeps neighbor lists bitwise
//! identical to an uncompressed tree. The traversal hot loop never
//! dequantizes: the query point is quantized once per node and lanes are
//! tested with pure integer compares (see [`Bvh4Node::quantize_query`] and
//! [`simd`], which provides explicit SSE2/NEON kernels for the 4-lane
//! test).
//!
//! The array is laid out in **breadth-first order** — all nodes of depth
//! `d` precede depth `d + 1` (ranges recorded in [`Bvh::level_starts`]) —
//! which makes a reverse index sweep a valid bottom-up order *and* lets
//! [`Bvh::refit`] process each level as an embarrassingly parallel slice
//! (level-partitioned refit, bit-identical to the serial sweep).
//!
//! Builds collapse a binary topology into this layout (see [`builder`]) and
//! are multi-threaded; queries run through the batched, allocation-free
//! traversal engine (see [`traverse`]: [`traverse::QueryScratch`] /
//! [`Bvh::query_batch`] / [`Bvh::query_batch_ordered`]); builds, refits and
//! queries all scale with `ORCS_THREADS`.

pub mod builder;
pub mod quality;
pub mod simd;
pub mod traverse;

use crate::core::aabb::Aabb;
use crate::core::vec3::Vec3;
use crate::parallel;

/// Maximum primitives per leaf lane. 4 mirrors typical hardware BVH widths.
pub const LEAF_SIZE: usize = 4;

/// Branching factor of the wide SoA node layout.
pub const BVH4_WIDTH: usize = 4;

/// Sentinel child value marking an unused lane.
pub const INVALID_LANE: u32 = u32::MAX;

/// Quantized-bound sentinels for empty lanes: `qmin > qmax` by more than
/// the traversal's ±1 integer slack, so the lane test fails for every
/// query point and empty lanes need no special-casing on the hot path.
const QMIN_EMPTY: u8 = 255;
const QMAX_EMPTY: u8 = 0;

/// Exponent-byte range for the per-axis power-of-two scales. The low clamp
/// keeps the scale a normal f32 (`2^-126`); the high clamp keeps the exact
/// reciprocal ([`exp_inv_scale`]) normal too. In practice the widen loop in
/// [`scale_exp_for`] stops well below the cap: `255 · 2^(e-127)` overflows
/// f32 around `e = 248`, at which point the frame trivially covers any
/// finite extent.
const SCALE_EXP_MIN: u8 = 1;
const SCALE_EXP_MAX: u8 = 253;

/// The power-of-two scale encoded by exponent byte `e`: `2^(e - 127)` (an
/// f32 with exponent field `e` and zero mantissa — multiplying by it is
/// exact).
#[inline(always)]
pub fn exp_scale(e: u8) -> f32 {
    f32::from_bits((e as u32) << 23)
}

/// The exact reciprocal of [`exp_scale`]: `2^(127 - e)`. Exponent bytes
/// are clamped to [`SCALE_EXP_MAX`] at quantization time so the reciprocal
/// stays a normal f32.
#[inline(always)]
pub fn exp_inv_scale(e: u8) -> f32 {
    f32::from_bits((254 - e.min(SCALE_EXP_MAX) as u32) << 23)
}

/// Smallest exponent byte whose frame `anchor + [0, 255]·2^(e-127)` covers
/// `hi` *in f32 arithmetic*. The bit-level guess can be one step short of
/// the analytic answer after rounding; the widen loop makes the cover
/// claim exact rather than analytic, which is what the conservative
/// containment contract rests on.
fn scale_exp_for(anchor: f32, hi: f32) -> u8 {
    let ext = (hi - anchor).max(0.0);
    // ext < 2^(be - 126) by the f32 exponent bits, so 255·2^(be - 134)
    // already exceeds it; start at `be - 7` and widen as needed.
    let be = (ext.to_bits() >> 23) & 0xff;
    let mut e = (be as i32 - 7).clamp(SCALE_EXP_MIN as i32, SCALE_EXP_MAX as i32) as u8;
    while e < SCALE_EXP_MAX && anchor + 255.0 * exp_scale(e) < hi {
        e += 1;
    }
    e
}

/// Largest `q` in `[0, 255]` with `anchor + q·scale <= v`: quantize a box
/// *min* rounding down. The f32 fix-up loop (runs 0–1 iterations in
/// practice) repairs any upward rounding of the float floor, so the
/// dequantized min never exceeds the exact min.
#[inline]
fn quantize_down(v: f32, anchor: f32, e: u8) -> u8 {
    let t = ((v - anchor) * exp_inv_scale(e)).clamp(0.0, 255.0);
    let mut q = t as u8;
    let scale = exp_scale(e);
    while q > 0 && anchor + q as f32 * scale > v {
        q -= 1;
    }
    q
}

/// Smallest `q` in `[0, 255]` with `anchor + q·scale >= v`: quantize a box
/// *max* rounding up. [`scale_exp_for`] chose the exponent so `q = 255`
/// provably covers the frame's top corner in f32 arithmetic, so the fix-up
/// loop always terminates with the cover contract satisfied.
#[inline]
fn quantize_up(v: f32, anchor: f32, e: u8) -> u8 {
    let t = ((v - anchor) * exp_inv_scale(e)).clamp(0.0, 255.0);
    let mut q = t.ceil() as u8;
    let scale = exp_scale(e);
    while q < 255 && anchor + q as f32 * scale < v {
        q += 1;
    }
    q
}

/// One 4-wide SoA BVH node with 8-bit quantized child boxes. A per-node
/// frame — `anchor` (component-wise min over the used lanes' boxes) plus a
/// power-of-two scale per axis (`scale_exp`, see [`exp_scale`]) — maps each
/// lane's bounds to byte offsets, transposed into per-axis lanes so a point
/// query tests four boxes with straight-line integer compares
/// ([`simd::lane_mask`]). Lane `l` is:
///
/// * **internal** when `count[l] == 0` and `child[l] != INVALID_LANE` —
///   `child[l]` is the node index of the subtree;
/// * **leaf** when `count[l] > 0` — `child[l]` is the first index of a
///   `count[l]`-long range of [`Bvh::prim_order`];
/// * **empty** when `child[l] == INVALID_LANE` — its quantized bounds are
///   the inverted sentinel (`qmin = 255 > qmax = 0`), so every lane test
///   fails and no special-casing is needed on the traversal hot path.
///
/// Dequantized lane boxes ([`Bvh4Node::lane_aabb`]) *contain* the exact
/// boxes they were quantized from (conservative rounding, see
/// [`Bvh4Node::requantize`]); the exact sphere test at the leaves keeps
/// query results bitwise identical to an uncompressed tree.
///
/// The layout is `#[repr(C)]` and must stay within one 64-byte cache line
/// (59 B data + tail padding = 60 B; the uncompressed f32 layout was
/// 128 B). [`crate::rtcore::timing`] prices node fetches by this size.
#[repr(C)]
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Bvh4Node {
    /// Quantization frame origin: component-wise min over used lane boxes.
    pub anchor: [f32; 3],
    /// Per-lane child reference (node index or `prim_order` start).
    pub child: [u32; BVH4_WIDTH],
    /// Per-axis power-of-two scale exponent byte (see [`exp_scale`]).
    pub scale_exp: [u8; 3],
    /// Per-lane primitive count (0 for internal and empty lanes). Fits a
    /// byte because leaves hold at most [`LEAF_SIZE`] primitives.
    pub count: [u8; BVH4_WIDTH],
    /// Quantized lane mins per axis (offsets from `anchor` in scale units,
    /// rounded down).
    pub qmin_x: [u8; BVH4_WIDTH],
    pub qmin_y: [u8; BVH4_WIDTH],
    pub qmin_z: [u8; BVH4_WIDTH],
    /// Quantized lane maxs per axis (rounded up).
    pub qmax_x: [u8; BVH4_WIDTH],
    pub qmax_y: [u8; BVH4_WIDTH],
    pub qmax_z: [u8; BVH4_WIDTH],
}

// The point of the quantized layout: one node per cache line. The timing
// meter and the bench table both key off this size staying <= 64.
const _: () = assert!(std::mem::size_of::<Bvh4Node>() <= 64);

impl Bvh4Node {
    /// A node with four empty lanes (inverted quantized sentinels).
    pub const EMPTY: Bvh4Node = Bvh4Node {
        anchor: [0.0; 3],
        child: [INVALID_LANE; BVH4_WIDTH],
        scale_exp: [SCALE_EXP_MIN; 3],
        count: [0; BVH4_WIDTH],
        qmin_x: [QMIN_EMPTY; BVH4_WIDTH],
        qmin_y: [QMIN_EMPTY; BVH4_WIDTH],
        qmin_z: [QMIN_EMPTY; BVH4_WIDTH],
        qmax_x: [QMAX_EMPTY; BVH4_WIDTH],
        qmax_y: [QMAX_EMPTY; BVH4_WIDTH],
        qmax_z: [QMAX_EMPTY; BVH4_WIDTH],
    };

    #[inline(always)]
    pub fn lane_used(&self, lane: usize) -> bool {
        self.child[lane] != INVALID_LANE
    }

    #[inline(always)]
    pub fn lane_is_leaf(&self, lane: usize) -> bool {
        self.count[lane] > 0
    }

    /// Dequantize one lane's box. The result **contains** the exact box
    /// the lane was quantized from (conservative rounding contract);
    /// unused lanes dequantize to [`Aabb::EMPTY`].
    #[inline]
    pub fn lane_aabb(&self, lane: usize) -> Aabb {
        if !self.lane_used(lane) {
            return Aabb::EMPTY;
        }
        let [ax, ay, az] = self.anchor;
        let [ex, ey, ez] = self.scale_exp;
        let (sx, sy, sz) = (exp_scale(ex), exp_scale(ey), exp_scale(ez));
        Aabb::new(
            Vec3::new(
                ax + self.qmin_x[lane] as f32 * sx,
                ay + self.qmin_y[lane] as f32 * sy,
                az + self.qmin_z[lane] as f32 * sz,
            ),
            Vec3::new(
                ax + self.qmax_x[lane] as f32 * sx,
                ay + self.qmax_y[lane] as f32 * sy,
                az + self.qmax_z[lane] as f32 * sz,
            ),
        )
    }

    /// Union of all used lane boxes = overall (dequantized, conservative)
    /// bounds of this node's subtree.
    #[inline]
    pub fn lanes_union(&self) -> Aabb {
        let mut bb = Aabb::EMPTY;
        for lane in 0..BVH4_WIDTH {
            bb.grow(&self.lane_aabb(lane));
        }
        bb
    }

    /// Build a node from up to [`BVH4_WIDTH`] lane entries
    /// `(box, child, count)`, quantizing all lanes against a shared frame
    /// computed from them (see [`Bvh4Node::requantize`]). `count` must be
    /// `0` for internal lanes and at most [`LEAF_SIZE`] for leaf lanes.
    pub fn pack(lanes: &[(Aabb, u32, u32)]) -> Bvh4Node {
        debug_assert!(lanes.len() <= BVH4_WIDTH);
        let mut node = Bvh4Node::EMPTY;
        let mut boxes = [Aabb::EMPTY; BVH4_WIDTH];
        for (lane, &(bb, child, count)) in lanes.iter().enumerate() {
            debug_assert!(count as usize <= LEAF_SIZE, "lane count exceeds LEAF_SIZE");
            node.child[lane] = child;
            node.count[lane] = count as u8;
            boxes[lane] = bb;
        }
        node.requantize(&boxes);
        node
    }

    /// Recompute the quantization frame from the used lanes' `boxes` and
    /// requantize every lane with **conservative rounding** — mins round
    /// down ([`quantize_down`]), maxs round up ([`quantize_up`]) — so each
    /// dequantized lane box contains its exact input box. Topology
    /// (`child`/`count`) is untouched; entries of `boxes` at unused lanes
    /// are ignored.
    ///
    /// This is a pure function of `(topology, boxes)` with no ordering
    /// freedom, and it is the *single* quantization site: the build
    /// collapse, the serial refit and the level-parallel refit all route
    /// through here, which is what keeps parallel refits node-for-node
    /// bitwise identical to serial ones.
    pub fn requantize(&mut self, boxes: &[Aabb; BVH4_WIDTH]) {
        let mut lo = Vec3::splat(f32::INFINITY);
        let mut hi = Vec3::splat(f32::NEG_INFINITY);
        let mut any = false;
        for lane in 0..BVH4_WIDTH {
            if self.lane_used(lane) {
                lo = lo.min(boxes[lane].lo);
                hi = hi.max(boxes[lane].hi);
                any = true;
            }
        }
        if !any {
            // no used lanes: reset to the always-miss sentinel frame
            let (child, count) = (self.child, self.count);
            *self = Bvh4Node { child, count, ..Bvh4Node::EMPTY };
            return;
        }
        self.anchor = [lo.x, lo.y, lo.z];
        let (ex, ey, ez) =
            (scale_exp_for(lo.x, hi.x), scale_exp_for(lo.y, hi.y), scale_exp_for(lo.z, hi.z));
        self.scale_exp = [ex, ey, ez];
        for lane in 0..BVH4_WIDTH {
            if !self.lane_used(lane) {
                self.qmin_x[lane] = QMIN_EMPTY;
                self.qmin_y[lane] = QMIN_EMPTY;
                self.qmin_z[lane] = QMIN_EMPTY;
                self.qmax_x[lane] = QMAX_EMPTY;
                self.qmax_y[lane] = QMAX_EMPTY;
                self.qmax_z[lane] = QMAX_EMPTY;
                continue;
            }
            let bb = &boxes[lane];
            self.qmin_x[lane] = quantize_down(bb.lo.x, lo.x, ex);
            self.qmin_y[lane] = quantize_down(bb.lo.y, lo.y, ey);
            self.qmin_z[lane] = quantize_down(bb.lo.z, lo.z, ez);
            self.qmax_x[lane] = quantize_up(bb.hi.x, lo.x, ex);
            self.qmax_y[lane] = quantize_up(bb.hi.y, lo.y, ey);
            self.qmax_z[lane] = quantize_up(bb.hi.z, lo.z, ez);
        }
    }

    /// Quantize a query point into this node's integer frame: per axis,
    /// `trunc((p - anchor) / scale)` clamped to `[-1, 256]`. A lane test
    /// then compares with ±1 integer slack (`qp + 1 >= qmin` and
    /// `qp - 1 <= qmax`, see [`simd::lane_mask`]): the slack absorbs the
    /// one unit the float product/truncation can be off by, so a point
    /// inside a dequantized lane box **always** passes — the test can
    /// widen (conservative) but never miss. The clamp bounds the integer
    /// range (no overflow on the ±1) and is done in f32 *before* the cast
    /// so scalar `as` and SIMD `cvtt` saturation can never be observed to
    /// differ. Positions must be NaN-free (the watchdog guarantees it);
    /// ±inf inputs clamp safely.
    #[inline(always)]
    pub fn quantize_query(&self, p: Vec3) -> [i32; 3] {
        let [ax, ay, az] = self.anchor;
        let [ex, ey, ez] = self.scale_exp;
        [
            ((p.x - ax) * exp_inv_scale(ex)).clamp(-1.0, 256.0) as i32,
            ((p.y - ay) * exp_inv_scale(ey)).clamp(-1.0, 256.0) as i32,
            ((p.z - az) * exp_inv_scale(ez)).clamp(-1.0, 256.0) as i32,
        ]
    }
}

/// Build heuristic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BuildKind {
    /// Median split on the longest centroid axis — fast, decent quality
    /// (models hardware LBVH-style builders).
    Median,
    /// Binned surface-area heuristic — slower build, better tree (models
    /// high-quality builds). 16 bins.
    BinnedSah,
    /// Morton-order linear BVH (HLBVH-family, paper refs [29][32]): radix
    /// sort primitives by Z-order, then split sorted ranges at their
    /// midpoint. Fastest build, lowest quality — the hardware-builder
    /// extreme of the build/quality trade-off ablation.
    Lbvh,
}

/// A bounding volume hierarchy over particle search spheres.
#[derive(Clone, Debug)]
pub struct Bvh {
    /// BVH4 nodes in breadth-first order: children always live at higher
    /// indices than their parent, and each depth occupies one contiguous
    /// range (see [`Bvh::level_starts`]). Empty for a zero-primitive scene.
    pub nodes: Vec<Bvh4Node>,
    /// `level_starts[d]..level_starts[d + 1]` is the node range at depth
    /// `d`; `level_starts.last() == nodes.len()`. Drives the
    /// level-partitioned parallel refit.
    pub level_starts: Vec<u32>,
    /// Permutation of primitive ids; leaf lanes reference ranges of it.
    pub prim_order: Vec<u32>,
    pub n_prims: usize,
    pub kind: BuildKind,
    /// Number of refits applied since the last full build.
    pub refits_since_build: u32,
}

/// Minimum nodes in one depth level before the refit sweep goes parallel
/// (below this, thread spawn costs more than the per-node work saves).
const REFIT_PARALLEL_MIN: usize = 128;

impl Bvh {
    /// Number of (4-wide) nodes.
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Root bounding box ([`Aabb::EMPTY`] for a zero-primitive scene).
    pub fn root_aabb(&self) -> Aabb {
        self.nodes.first().map_or(Aabb::EMPTY, |n| n.lanes_union())
    }

    /// Refit ("update" in RT-core terms): recompute every lane's AABB from
    /// current sphere positions without changing the topology. O(nodes),
    /// parallelized over [`crate::parallel::num_threads`] workers.
    pub fn refit(&mut self, pos: &[Vec3], radius: &[f32]) {
        self.refit_with_threads(pos, radius, parallel::num_threads());
    }

    /// [`Bvh::refit`] with an explicit worker count.
    ///
    /// The sweep is **level-partitioned**: depth levels are processed
    /// bottom-up (the same reverse-topological guarantee as a reverse index
    /// sweep over the BFS layout), and the nodes *within* one level are
    /// mutually independent — a leaf lane reads only primitive data and an
    /// internal lane reads only strictly deeper (already-refit) nodes — so
    /// each level fans out across threads. Every node executes the exact
    /// same arithmetic as the serial sweep — including the whole-node
    /// requantization ([`Bvh4Node::requantize`]) — so the result is
    /// bit-identical for any thread count.
    pub fn refit_with_threads(&mut self, pos: &[Vec3], radius: &[f32], threads: usize) {
        debug_assert_eq!(pos.len(), self.n_prims);
        let threads = threads.max(1);
        {
            let Bvh { nodes, level_starts, prim_order, .. } = self;
            let node_ptr = parallel::SendPtr(nodes.as_mut_ptr());
            let prim_order: &[u32] = prim_order.as_slice();
            let levels = level_starts.len().saturating_sub(1);
            for level in (0..levels).rev() {
                let lo = level_starts[level] as usize;
                let hi = level_starts[level + 1] as usize;
                let width = hi - lo;
                if threads == 1 || width < REFIT_PARALLEL_MIN {
                    for slot in lo..hi {
                        // SAFETY: serial sweep, no concurrent access.
                        unsafe { refit_node(node_ptr.0, slot, prim_order, pos, radius) };
                    }
                } else {
                    parallel::parallel_for_chunks_grained(width, threads, 64, |_, range| {
                        for k in range {
                            // SAFETY: slots within one level are written by
                            // exactly one worker each (disjoint chunks) and
                            // child reads target strictly deeper levels,
                            // which were completed before this level began.
                            unsafe { refit_node(node_ptr.0, lo + k, prim_order, pos, radius) };
                        }
                    });
                }
            }
        }
        self.refits_since_build += 1;
    }

    /// Validate structural invariants (tests / debug builds).
    pub fn check_invariants(&self, pos: &[Vec3], radius: &[f32]) -> Result<(), String> {
        // prim_order is a permutation
        let mut seen = vec![false; self.n_prims];
        for &p in &self.prim_order {
            let p = p as usize;
            if p >= self.n_prims {
                return Err(format!("prim id {p} out of range"));
            }
            if seen[p] {
                return Err(format!("prim id {p} duplicated"));
            }
            seen[p] = true;
        }
        if !seen.iter().all(|&s| s) {
            return Err("prim_order not a full permutation".into());
        }
        if self.n_prims == 0 {
            if !self.nodes.is_empty() {
                return Err("empty scene must have no nodes".into());
            }
            return Ok(());
        }
        if self.nodes.is_empty() {
            return Err("non-empty scene with no nodes".into());
        }
        // level table sane
        if self.level_starts.first() != Some(&0)
            || self.level_starts.last().copied() != Some(self.nodes.len() as u32)
            // lint:allow(P-INDEX-LIT): windows(2) yields exactly-2 slices
            || self.level_starts.windows(2).any(|w| w[0] >= w[1])
        {
            return Err(format!("bad level_starts {:?}", self.level_starts));
        }
        // every lane bounds its content (dequantized boxes are conservative,
        // so containment holds *exactly*, not just within EPS); leaf lanes
        // cover prim_order exactly once; internal lanes point strictly
        // forward
        let mut covered = vec![false; self.n_prims];
        for (i, n) in self.nodes.iter().enumerate() {
            for lane in 0..BVH4_WIDTH {
                if !n.lane_used(lane) {
                    if n.count[lane] != 0 {
                        return Err(format!("node {i} empty lane {lane} with count"));
                    }
                    continue;
                }
                if n.count[lane] as usize > LEAF_SIZE {
                    return Err(format!("node {i} lane {lane} count exceeds LEAF_SIZE"));
                }
                let bb = n.lane_aabb(lane);
                if n.lane_is_leaf(lane) {
                    let first = n.child[lane] as usize;
                    let cnt = n.count[lane] as usize;
                    if first + cnt > self.prim_order.len() {
                        return Err(format!("node {i} lane {lane} range out of bounds"));
                    }
                    for k in first..first + cnt {
                        if covered[k] {
                            return Err(format!("prim slot {k} referenced twice"));
                        }
                        covered[k] = true;
                        let p = self.prim_order[k] as usize;
                        let sb = Aabb::of_sphere(pos[p], radius[p]);
                        if !contains_box(&bb, &sb) {
                            return Err(format!("node {i} lane {lane} does not bound prim {p}"));
                        }
                    }
                } else {
                    let c = n.child[lane] as usize;
                    if c <= i || c >= self.nodes.len() {
                        return Err(format!("node {i} lane {lane} bad child index {c}"));
                    }
                    let cb = self.nodes[c].lanes_union();
                    if !contains_box(&bb, &cb) {
                        return Err(format!("node {i} lane {lane} does not bound child {c}"));
                    }
                }
            }
        }
        if !covered.iter().all(|&c| c) {
            return Err("leaf lanes do not cover every prim_order slot".into());
        }
        Ok(())
    }
}

/// Recompute the lane boxes of `nodes[slot]` — leaf lanes from current
/// primitive spheres, internal lanes from the (already-refit) child node's
/// dequantized lane union — then requantize the whole node against the
/// fresh frame ([`Bvh4Node::requantize`]). Quantizing against the child's
/// *dequantized* union (not an exact subtree box) keeps conservative
/// containment transitive through the quantized frames. Shared by the
/// serial and the level-parallel sweeps so both produce bit-identical
/// results.
///
/// # Safety
/// `nodes` must be valid for the whole node array; `nodes[slot]` must not
/// be accessed concurrently, and the child slots referenced by `slot` must
/// not be written concurrently (guaranteed by bottom-up level ordering).
unsafe fn refit_node(
    nodes: *mut Bvh4Node,
    slot: usize,
    prim_order: &[u32],
    pos: &[Vec3],
    radius: &[f32],
) {
    let node = &mut *nodes.add(slot);
    let mut boxes = [Aabb::EMPTY; BVH4_WIDTH];
    for lane in 0..BVH4_WIDTH {
        let c = node.child[lane];
        if c == INVALID_LANE {
            continue;
        }
        boxes[lane] = if node.count[lane] > 0 {
            let first = c as usize;
            let mut bb = Aabb::EMPTY;
            for k in first..first + node.count[lane] as usize {
                let p = prim_order[k] as usize;
                bb.grow(&Aabb::of_sphere(pos[p], radius[p]));
            }
            bb
        } else {
            // children live at higher indices -> already refit
            (*nodes.add(c as usize)).lanes_union()
        };
    }
    node.requantize(&boxes);
}

fn contains_box(outer: &Aabb, inner: &Aabb) -> bool {
    const EPS: f32 = 1e-3;
    inner.is_empty()
        || (outer.lo.x <= inner.lo.x + EPS
            && outer.lo.y <= inner.lo.y + EPS
            && outer.lo.z <= inner.lo.z + EPS
            && outer.hi.x >= inner.hi.x - EPS
            && outer.hi.y >= inner.hi.y - EPS
            && outer.hi.z >= inner.hi.z - EPS)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Rng;

    fn random_scene(n: usize, seed: u64) -> (Vec<Vec3>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let pos = (0..n)
            .map(|_| {
                Vec3::new(
                    rng.range_f32(0.0, 100.0),
                    rng.range_f32(0.0, 100.0),
                    rng.range_f32(0.0, 100.0),
                )
            })
            .collect();
        let radius = (0..n).map(|_| rng.range_f32(0.5, 5.0)).collect();
        (pos, radius)
    }

    #[test]
    fn node_fits_one_cache_line() {
        // the acceptance gate of the quantized layout (also asserted at
        // compile time above)
        assert!(std::mem::size_of::<Bvh4Node>() <= 64);
    }

    #[test]
    fn pack_roundtrip_is_conservative() {
        let mut rng = Rng::new(41);
        for _ in 0..200 {
            let mut lanes = Vec::new();
            let k = 1 + rng.below(BVH4_WIDTH);
            for lane in 0..k {
                let lo = Vec3::new(
                    rng.range_f32(-50.0, 50.0),
                    rng.range_f32(-50.0, 50.0),
                    rng.range_f32(-50.0, 50.0),
                );
                let ext = Vec3::new(
                    rng.range_f32(0.0, 30.0),
                    rng.range_f32(0.0, 30.0),
                    rng.range_f32(0.0, 30.0),
                );
                lanes.push((Aabb::new(lo, lo + ext), lane as u32, 0u32));
            }
            let node = Bvh4Node::pack(&lanes);
            for (lane, (bb, _, _)) in lanes.iter().enumerate() {
                let got = node.lane_aabb(lane);
                assert!(
                    got.lo.x <= bb.lo.x
                        && got.lo.y <= bb.lo.y
                        && got.lo.z <= bb.lo.z
                        && got.hi.x >= bb.hi.x
                        && got.hi.y >= bb.hi.y
                        && got.hi.z >= bb.hi.z,
                    "lane {lane}: dequantized {got:?} does not contain exact {bb:?}"
                );
            }
            for lane in k..BVH4_WIDTH {
                assert!(!node.lane_used(lane));
                assert!(node.lane_aabb(lane).is_empty());
            }
        }
    }

    #[test]
    fn quantize_helpers_bracket_the_value() {
        let mut rng = Rng::new(42);
        for _ in 0..500 {
            let anchor = rng.range_f32(-1e6, 1e6);
            let hi = anchor + rng.range_f32(0.0, 1e6);
            let e = scale_exp_for(anchor, hi);
            let scale = exp_scale(e);
            // the frame covers the top corner
            assert!(anchor + 255.0 * scale >= hi, "e={e} anchor={anchor} hi={hi}");
            let v = anchor + (hi - anchor) * rng.f32();
            let qd = quantize_down(v, anchor, e);
            let qu = quantize_up(v, anchor, e);
            assert!(anchor + qd as f32 * scale <= v, "down e={e} v={v}");
            assert!(anchor + qu as f32 * scale >= v, "up e={e} v={v}");
        }
    }

    #[test]
    fn zero_extent_frames_are_valid() {
        // coincident content: extent 0 on every axis
        let at = Vec3::new(3.5, -7.25, 1e-3);
        let node = Bvh4Node::pack(&[(Aabb::new(at, at), 0, 2)]);
        let bb = node.lane_aabb(0);
        assert!(bb.lo.x <= at.x && bb.hi.x >= at.x);
        assert!(bb.lo.y <= at.y && bb.hi.y >= at.y);
        assert!(bb.lo.z <= at.z && bb.hi.z >= at.z);
        // a query at the point must pass the integer lane test
        let qp = node.quantize_query(at);
        assert_eq!(simd::lane_mask_with(simd::Kernel::Scalar, &node, qp), 1);
    }

    #[test]
    fn build_invariants_hold_both_kinds() {
        for kind in [BuildKind::Median, BuildKind::BinnedSah] {
            let (pos, radius) = random_scene(500, 9);
            let bvh = Bvh::build(&pos, &radius, kind);
            bvh.check_invariants(&pos, &radius).unwrap();
            assert_eq!(bvh.n_prims, 500);
            assert_eq!(bvh.refits_since_build, 0);
        }
    }

    #[test]
    fn refit_keeps_invariants_after_motion() {
        let (mut pos, radius) = random_scene(300, 10);
        let mut bvh = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
        let mut rng = Rng::new(77);
        for round in 1..=5 {
            for p in pos.iter_mut() {
                *p += Vec3::new(
                    rng.range_f32(-2.0, 2.0),
                    rng.range_f32(-2.0, 2.0),
                    rng.range_f32(-2.0, 2.0),
                );
            }
            bvh.refit(&pos, &radius);
            bvh.check_invariants(&pos, &radius).unwrap();
            assert_eq!(bvh.refits_since_build, round);
        }
    }

    #[test]
    fn single_and_tiny_inputs() {
        let pos = vec![Vec3::splat(1.0)];
        let radius = vec![2.0];
        let bvh = Bvh::build(&pos, &radius, BuildKind::Median);
        bvh.check_invariants(&pos, &radius).unwrap();
        assert_eq!(bvh.node_count(), 1);
        assert!(bvh.nodes[0].lane_is_leaf(0));
        assert_eq!(bvh.nodes[0].count[0], 1);
        assert!(!bvh.nodes[0].lane_used(1));
    }

    #[test]
    fn empty_scene_is_valid() {
        let bvh = Bvh::build(&[], &[], BuildKind::BinnedSah);
        bvh.check_invariants(&[], &[]).unwrap();
        assert_eq!(bvh.node_count(), 0);
        assert!(bvh.root_aabb().is_empty());
        let mut bvh = bvh;
        bvh.refit(&[], &[]); // must not panic
        assert_eq!(bvh.refits_since_build, 1);
    }

    #[test]
    fn refit_grows_root_when_particles_spread() {
        let (mut pos, radius) = random_scene(100, 11);
        let mut bvh = Bvh::build(&pos, &radius, BuildKind::Median);
        let before = bvh.root_aabb().surface_area();
        for p in pos.iter_mut() {
            *p = *p * 2.0; // spread out
        }
        bvh.refit(&pos, &radius);
        assert!(bvh.root_aabb().surface_area() > before);
        bvh.check_invariants(&pos, &radius).unwrap();
    }

    #[test]
    fn parallel_refit_equals_serial_node_for_node() {
        // large enough that leaf levels clear REFIT_PARALLEL_MIN; node
        // equality is bitwise over the whole quantized layout (anchor,
        // exponents, offsets), so parallel requantization must execute the
        // exact serial arithmetic
        let (mut pos, radius) = random_scene(20_000, 12);
        let base = Bvh::build_with_threads(&pos, &radius, BuildKind::BinnedSah, 1);
        let mut rng = Rng::new(13);
        let mut serial = base.clone();
        let mut par = base;
        for _ in 0..3 {
            for p in pos.iter_mut() {
                *p += Vec3::new(
                    rng.range_f32(-2.0, 2.0),
                    rng.range_f32(-2.0, 2.0),
                    rng.range_f32(-2.0, 2.0),
                );
            }
            serial.refit_with_threads(&pos, &radius, 1);
            par.refit_with_threads(&pos, &radius, 8);
            assert_eq!(serial.nodes, par.nodes, "parallel refit diverged from serial");
        }
        par.check_invariants(&pos, &radius).unwrap();
    }

    #[test]
    fn bfs_levels_partition_nodes() {
        let (pos, radius) = random_scene(5000, 14);
        let bvh = Bvh::build(&pos, &radius, BuildKind::Median);
        assert_eq!(*bvh.level_starts.last().unwrap() as usize, bvh.node_count());
        // every internal lane points into a strictly deeper level
        for level in 0..bvh.level_starts.len() - 1 {
            let next = bvh.level_starts[level + 1];
            for s in bvh.level_starts[level]..next {
                let n = &bvh.nodes[s as usize];
                for lane in 0..BVH4_WIDTH {
                    if n.lane_used(lane) && !n.lane_is_leaf(lane) {
                        assert!(n.child[lane] >= next, "child in same or earlier level");
                    }
                }
            }
        }
    }
}
