//! CPU-CELL@64c — the parallel cell-list baseline (Ihmsen et al. [13],
//! adapted as in the paper §4.2: forces are computed directly from the cell
//! sweep, no fixed-size neighbor list, so dense scenes cannot OOM).
//!
//! The [`CellGrid`] here is also the substrate for [`super::gpu_cell`].

use crate::core::config::Boundary;
use crate::core::vec3::Vec3;
use crate::frnn::{Backend, StepCtx, StepResult, WallPhases};
use crate::parallel;
use crate::physics::state::SimState;
use crate::resilience::SimResult;
use crate::rtcore::OpCounts;
use crate::telemetry::wallclock::WallTimer;

/// Uniform grid over the box with counting-sort cell buckets.
#[derive(Clone, Debug)]
pub struct CellGrid {
    pub dims: usize,
    pub cell: f32,
    /// CSR: particles of cell `c` are `items[starts[c]..starts[c+1]]`.
    pub starts: Vec<u32>,
    pub items: Vec<u32>,
}

/// Above this per-axis resolution a dense cell array is wasteful; the
/// keyed [`SparseGrid`] takes over (compact cell lists, as in Ihmsen
/// et al. [13]).
pub const DENSE_DIMS_CAP: usize = 64;

/// Radius-sized cells behind an ordered map: the small-radius regime (r=1
/// in a 1000³ box needs 10⁹ virtual cells) where a dense array cannot
/// exist but fine cells are exactly what makes the paper's CPU-CELL fast.
///
/// The cell map is a `BTreeMap`, not a `HashMap`: any iteration over it
/// is in key order by construction, so hash order can never leak into
/// results (lint rule D-HASH-ITER). The sweep path only issues point
/// `get`s — ~27 probes per particle — where the tree's `O(log c)` probe
/// replaces the multiplicative cell-key hasher this struct used to carry
/// (EXPERIMENTS.md §Perf #7 replaced SipHash for the same reason).
#[derive(Clone, Debug)]
pub struct SparseGrid {
    pub dims: i64,
    pub cell: f32,
    map: std::collections::BTreeMap<i64, Vec<u32>>,
}

impl SparseGrid {
    pub fn build(pos: &[Vec3], box_l: f32, dims: usize) -> SparseGrid {
        let dims_i = dims as i64;
        let cell = box_l / dims as f32;
        let mut map: std::collections::BTreeMap<i64, Vec<u32>> =
            std::collections::BTreeMap::new();
        for (i, &p) in pos.iter().enumerate() {
            let cx = ((p.x / cell) as i64).min(dims_i - 1);
            let cy = ((p.y / cell) as i64).min(dims_i - 1);
            let cz = ((p.z / cell) as i64).min(dims_i - 1);
            map.entry((cz * dims_i + cy) * dims_i + cx).or_default().push(i as u32);
        }
        SparseGrid { dims: dims_i, cell, map }
    }

    /// Visit every particle in the 27 cells around `p` (cell >= r_max so a
    /// reach of 1 always covers the cutoff).
    pub fn sweep<F: FnMut(u32)>(&self, p: Vec3, boundary: Boundary, mut visit: F) {
        let d = self.dims;
        let cx = ((p.x / self.cell) as i64).min(d - 1);
        let cy = ((p.y / self.cell) as i64).min(d - 1);
        let cz = ((p.z / self.cell) as i64).min(d - 1);
        for dz in -1..=1i64 {
            for dy in -1..=1i64 {
                for dx in -1..=1i64 {
                    let (mut x, mut y, mut z) = (cx + dx, cy + dy, cz + dz);
                    match boundary {
                        Boundary::Periodic => {
                            x = x.rem_euclid(d);
                            y = y.rem_euclid(d);
                            z = z.rem_euclid(d);
                        }
                        Boundary::Wall => {
                            if !(0..d).contains(&x)
                                || !(0..d).contains(&y)
                                || !(0..d).contains(&z)
                            {
                                continue;
                            }
                        }
                    }
                    if let Some(items) = self.map.get(&((z * d + y) * d + x)) {
                        for &j in items {
                            visit(j);
                        }
                    }
                }
            }
        }
    }
}

/// Dense array or hashed grid, chosen by resolution.
pub enum Grid {
    Dense(CellGrid),
    Sparse(SparseGrid),
}

impl Grid {
    /// Build the right grid for the scene: radius-sized cells, hashed when
    /// a dense array at that resolution would be infeasible.
    pub fn build(pos: &[Vec3], box_l: f32, r_max: f32) -> Grid {
        let by_radius = ((box_l / r_max.max(1e-3)).floor() as usize).max(1);
        if by_radius > DENSE_DIMS_CAP {
            Grid::Sparse(SparseGrid::build(pos, box_l, by_radius))
        } else {
            Grid::Dense(CellGrid::build(pos, box_l, by_radius))
        }
    }
}

impl CellGrid {
    /// Choose grid resolution: cells at least `r_max` wide (so a reach of 1
    /// covers the cutoff), but never more than O(n) cells in total.
    pub fn choose_dims(n: usize, box_l: f32, r_max: f32) -> usize {
        let by_radius = (box_l / r_max.max(1e-3)).floor() as usize;
        let by_count = ((2 * n.max(1)) as f64).cbrt().ceil() as usize;
        by_radius.clamp(1, by_count.max(4))
    }

    pub fn build(pos: &[Vec3], box_l: f32, dims: usize) -> CellGrid {
        let dims = dims.max(1);
        let cell = box_l / dims as f32;
        let n_cells = dims * dims * dims;
        let mut counts = vec![0u32; n_cells + 1];
        let idx_of = |p: Vec3| -> usize {
            let cx = ((p.x / cell) as usize).min(dims - 1);
            let cy = ((p.y / cell) as usize).min(dims - 1);
            let cz = ((p.z / cell) as usize).min(dims - 1);
            (cz * dims + cy) * dims + cx
        };
        for &p in pos {
            counts[idx_of(p) + 1] += 1;
        }
        for c in 0..n_cells {
            counts[c + 1] += counts[c];
        }
        let starts = counts.clone();
        let mut cursor = counts;
        let mut items = vec![0u32; pos.len()];
        for (i, &p) in pos.iter().enumerate() {
            let c = idx_of(p);
            items[cursor[c] as usize] = i as u32;
            cursor[c] += 1;
        }
        CellGrid { dims, cell, starts, items }
    }

    #[inline]
    pub fn cell_index(&self, p: Vec3) -> (i64, i64, i64) {
        (
            ((p.x / self.cell) as i64).min(self.dims as i64 - 1),
            ((p.y / self.cell) as i64).min(self.dims as i64 - 1),
            ((p.z / self.cell) as i64).min(self.dims as i64 - 1),
        )
    }

    #[inline]
    pub fn cell_items(&self, cx: i64, cy: i64, cz: i64) -> &[u32] {
        let d = self.dims as i64;
        debug_assert!((0..d).contains(&cx) && (0..d).contains(&cy) && (0..d).contains(&cz));
        let c = ((cz * d + cy) * d + cx) as usize;
        &self.items[self.starts[c] as usize..self.starts[c + 1] as usize]
    }

    /// Visit every particle in cells within `reach` of `p`'s cell,
    /// respecting boundary mode (wrap vs clamp). The visitor receives the
    /// particle index; distance filtering is the caller's job.
    pub fn sweep<F: FnMut(u32)>(
        &self,
        p: Vec3,
        reach: i64,
        boundary: Boundary,
        mut visit: F,
    ) {
        let d = self.dims as i64;
        let (cx, cy, cz) = self.cell_index(p);
        for dz in -reach..=reach {
            for dy in -reach..=reach {
                for dx in -reach..=reach {
                    let (mut x, mut y, mut z) = (cx + dx, cy + dy, cz + dz);
                    match boundary {
                        Boundary::Periodic => {
                            x = x.rem_euclid(d);
                            y = y.rem_euclid(d);
                            z = z.rem_euclid(d);
                        }
                        Boundary::Wall => {
                            if !(0..d).contains(&x) || !(0..d).contains(&y) || !(0..d).contains(&z)
                            {
                                continue;
                            }
                        }
                    }
                    for &j in self.cell_items(x, y, z) {
                        visit(j);
                    }
                }
            }
        }
    }

    /// Cell reach needed to cover `r_max` interactions.
    pub fn reach_for(&self, r_max: f32) -> i64 {
        (r_max / self.cell).ceil() as i64
    }
}

/// Run one cell-sweep force pass; shared by CPU-CELL and GPU-CELL.
/// Returns (forces, pair_tests, force_evals, cell_visits).
pub fn cell_forces(
    state: &SimState,
    grid: &Grid,
    threads: usize,
) -> (Vec<Vec3>, u64, u64, u64) {
    let n = state.n();
    // Dense-grid sweep bounds; under periodic wrap a reach beyond
    // (dims-1)/2 would visit cells twice — in that degenerate regime (huge
    // radii / tiny grids) fall back to an exact all-particles sweep. Walls
    // never wrap, so the full reach is always safe (out-of-range cells are
    // skipped). Sparse grids always have cell >= r_max, so reach is 1.
    let (reach, full_sweep) = match grid {
        Grid::Dense(g) => {
            let needed = g.reach_for(state.r_max);
            let max_periodic = (g.dims as i64 - 1) / 2;
            (needed, state.boundary == Boundary::Periodic && needed > max_periodic)
        }
        Grid::Sparse(_) => (1, false),
    };

    // cells visited per particle sweep (lookup overhead)
    let visits_per_sweep: u64 = if full_sweep {
        n as u64 // degenerate: treated as one visit per candidate row
    } else {
        match grid {
            Grid::Dense(_) => {
                let w = (2 * reach + 1) as u64;
                w * w * w
            }
            Grid::Sparse(_) => 27,
        }
    };

    let results = parallel::parallel_reduce(
        n,
        threads,
        || (vec![Vec3::ZERO; n], 0u64, 0u64),
        |(forces, tests, evals), i| {
            let p = state.pos[i];
            let mut body = |j: u32| {
                let j = j as usize;
                if j == i {
                    return;
                }
                *tests += 1;
                let dx = crate::physics::boundary::displacement(
                    p,
                    state.pos[j],
                    state.boundary,
                    state.box_l,
                );
                if let Some(fij) =
                    state.params.pair_force(dx, state.radius[i], state.radius[j])
                {
                    forces[i] += fij;
                    *evals += 1;
                }
            };
            match grid {
                _ if full_sweep => {
                    // degenerate small grid: visit all particles once
                    for j in 0..n as u32 {
                        body(j);
                    }
                }
                Grid::Dense(g) => g.sweep(p, reach, state.boundary, body),
                Grid::Sparse(g) => g.sweep(p, state.boundary, body),
            }
        },
    );

    // merge per-thread force buffers (first buffer reused as accumulator)
    let mut iter = results.into_iter();
    let Some((mut forces, mut tests, mut evals)) = iter.next() else {
        return (vec![Vec3::ZERO; n], 0, 0, visits_per_sweep * n as u64);
    };
    for (f2, t2, e2) in iter {
        for (a, b) in forces.iter_mut().zip(f2) {
            *a += b;
        }
        tests += t2;
        evals += e2;
    }
    (forces, tests, evals, visits_per_sweep * n as u64)
}

/// CPU-CELL@64c backend.
pub struct CpuCell {
    _priv: (),
}

impl CpuCell {
    pub fn new() -> Self {
        CpuCell { _priv: () }
    }
}

impl Default for CpuCell {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for CpuCell {
    fn name(&self) -> &'static str {
        "CPU-CELL@64c"
    }

    fn step(&mut self, state: &mut SimState, ctx: &mut StepCtx) -> SimResult<StepResult> {
        let mut counts = OpCounts::default();
        let mut wall = WallPhases::default();

        let t0 = WallTimer::start();
        let grid = Grid::build(&state.pos, state.box_l, state.r_max);
        counts.grid_binned += state.n() as u64;
        wall.search = t0.elapsed_s();

        let t1 = WallTimer::start();
        let (forces, tests, evals, visits) = cell_forces(state, &grid, ctx.threads);
        state.force = forces;
        counts.cell_pair_tests += tests;
        counts.cell_force_evals += evals;
        counts.cell_visits += visits;
        counts.interactions += evals / 2; // each pair evaluated from both ends
        wall.force = t1.elapsed_s();

        let t2 = WallTimer::start();
        crate::physics::integrator::step(state);
        counts.integrate_particles += state.n() as u64;
        wall.integrate = t2.elapsed_s();

        Ok(StepResult { counts, bvh_action: None, oom_bytes: None, wall })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::{Boundary, RadiusDist, SimConfig};
    use crate::frnn::brute;
    use crate::frnn::RustKernels;
    use crate::rtcore::profile::EPYC64;

    fn mk_state(n: usize, boundary: Boundary, radius: RadiusDist, box_l: f32) -> SimState {
        let cfg = SimConfig { n, boundary, radius_dist: radius, box_l, ..SimConfig::default() };
        SimState::from_config(&cfg)
    }

    #[test]
    fn grid_build_partitions_all_particles() {
        let state = mk_state(500, Boundary::Periodic, RadiusDist::Const(10.0), 100.0);
        let grid = CellGrid::build(&state.pos, 100.0, 10);
        assert_eq!(grid.items.len(), 500);
        let mut seen = vec![false; 500];
        for &i in &grid.items {
            assert!(!seen[i as usize]);
            seen[i as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
        // every particle is in the cell the index function says
        for i in 0..500 {
            let (cx, cy, cz) = grid.cell_index(state.pos[i]);
            assert!(grid.cell_items(cx, cy, cz).contains(&(i as u32)));
        }
    }

    #[test]
    fn cell_forces_match_brute_force() {
        for boundary in [Boundary::Wall, Boundary::Periodic] {
            for radius in [RadiusDist::Const(6.0), RadiusDist::Uniform(2.0, 12.0)] {
                let state = mk_state(300, boundary, radius, 100.0);
                let grid = Grid::build(&state.pos, state.box_l, state.r_max);
                let (forces, _, _, _) = cell_forces(&state, &grid, 4);
                let want = brute::forces(&state);
                for i in 0..state.n() {
                    let d = (forces[i] - want[i]).norm();
                    assert!(
                        d <= 1e-3 * want[i].norm().max(1.0),
                        "{boundary:?} {radius:?} particle {i}: {:?} vs {:?}",
                        forces[i],
                        want[i]
                    );
                }
            }
        }
    }

    #[test]
    fn sparse_grid_selected_for_small_radii() {
        let state = mk_state(300, Boundary::Periodic, RadiusDist::Const(0.5), 100.0);
        assert!(matches!(
            Grid::build(&state.pos, state.box_l, state.r_max),
            Grid::Sparse(_)
        ));
        let state = mk_state(300, Boundary::Periodic, RadiusDist::Const(10.0), 100.0);
        assert!(matches!(
            Grid::build(&state.pos, state.box_l, state.r_max),
            Grid::Dense(_)
        ));
    }

    #[test]
    fn sparse_grid_forces_match_brute_force() {
        // tiny radii in a big box: the regime only the hashed grid handles
        for boundary in [Boundary::Wall, Boundary::Periodic] {
            let cfg = SimConfig {
                n: 400,
                boundary,
                radius_dist: RadiusDist::Const(3.0),
                box_l: 400.0,
                ..SimConfig::default()
            };
            // clustered positions so some pairs actually interact
            let mut state = SimState::from_config(&cfg);
            for (k, p) in state.pos.iter_mut().enumerate() {
                if k % 2 == 0 {
                    let anchor = state_anchor(k);
                    *p = anchor;
                } else {
                    let anchor = state_anchor(k - 1);
                    *p = anchor + Vec3::new(1.5, 0.5, -0.5);
                }
            }
            let grid = Grid::build(&state.pos, state.box_l, state.r_max);
            assert!(matches!(grid, Grid::Sparse(_)));
            let (forces, _, evals, _) = cell_forces(&state, &grid, 3);
            assert!(evals > 0, "test scene produced no interactions");
            let want = brute::forces(&state);
            for i in 0..state.n() {
                let d = (forces[i] - want[i]).norm();
                assert!(d <= 1e-3 * want[i].norm().max(1.0), "{boundary:?} particle {i}");
            }
        }
    }

    /// Deterministic pseudo-cluster anchors spread through the box.
    fn state_anchor(k: usize) -> Vec3 {
        let h = (k as u32).wrapping_mul(2654435761);
        Vec3::new(
            2.0 + (h % 396) as f32,
            2.0 + ((h >> 8) % 396) as f32,
            2.0 + ((h >> 16) % 396) as f32,
        )
    }

    #[test]
    fn sparse_sweep_wraps_across_periodic_faces() {
        let pos = vec![Vec3::new(0.5, 50.0, 50.0), Vec3::new(99.5, 50.0, 50.0)];
        let grid = SparseGrid::build(&pos, 100.0, 100); // cell = 1
        let mut seen = Vec::new();
        grid.sweep(pos[0], Boundary::Periodic, |j| seen.push(j));
        assert!(seen.contains(&1), "periodic sweep must reach across the face");
        let mut seen_wall = Vec::new();
        grid.sweep(pos[0], Boundary::Wall, |j| seen_wall.push(j));
        assert!(!seen_wall.contains(&1), "wall sweep must not wrap");
    }

    #[test]
    fn huge_radius_degenerates_gracefully() {
        // r_max comparable to the box: grid degenerates to a near-full sweep
        let state = mk_state(100, Boundary::Periodic, RadiusDist::Const(60.0), 100.0);
        let grid = Grid::build(&state.pos, state.box_l, state.r_max);
        let (forces, _, _, _) = cell_forces(&state, &grid, 2);
        let want = brute::forces(&state);
        for i in 0..state.n() {
            let d = (forces[i] - want[i]).norm();
            assert!(d <= 1e-2 * want[i].norm().max(1.0), "particle {i}");
        }
    }

    #[test]
    fn backend_step_runs_and_counts() {
        let mut state = mk_state(200, Boundary::Periodic, RadiusDist::Const(8.0), 100.0);
        let kernels = RustKernels { threads: 2 };
        let mut ctx = StepCtx {
            threads: 2,
            kernels: &kernels,
            hw: &EPYC64,
            check_oom: false,
            vram_budget: None,
        };
        let mut backend = CpuCell::new();
        let r = backend.step(&mut state, &mut ctx).unwrap();
        assert!(r.counts.cell_pair_tests > 0);
        assert!(r.counts.integrate_particles == 200);
        assert_eq!(state.step_count, 1);
        assert!(state.is_finite());
    }
}
