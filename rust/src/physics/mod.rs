//! Lennard-Jones physics: potential/force (paper Eqs. 2–4), boundary
//! conditions and the integrator.

pub mod boundary;
pub mod integrator;
pub mod lj;
pub mod state;

pub use boundary::displacement;
pub use lj::LjParams;
pub use state::SimState;
